//! Batching-policy study on the live serve path: sweep the dynamic
//! batcher's window and plot the throughput/latency trade-off, with a
//! mixed workload (PaperNet inference + raw conv requests for every conv
//! artifact in the manifest).
//!
//! Run: `cargo run --release --example batch_serving [-- --requests 256]`

use std::time::{Duration, Instant};

use pasconv::coordinator::{BatchConfig, Coordinator, Payload};
use pasconv::runtime::{default_artifact_dir, ArtifactKind, Runtime, Tensor};
use pasconv::util::bench::Table;
use pasconv::util::cli::Args;
use pasconv::util::rng::Rng;
use pasconv::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get_usize("requests", 256);
    let dir = default_artifact_dir();

    // conv request templates from the manifest
    let rt = Runtime::new(&dir)?;
    let mut conv_templates = vec![];
    for kind in [ArtifactKind::ConvSingle, ArtifactKind::ConvMulti] {
        for a in rt.artifacts_of_kind(kind) {
            conv_templates.push(a.problem()?);
        }
    }
    drop(rt);
    println!("{} conv shapes + PaperNet; {} requests per config\n", conv_templates.len(), n);

    let mut table = Table::new(&[
        "window",
        "max_batch",
        "req/s",
        "p50 lat",
        "p99 lat",
        "mean batch",
    ]);
    for (window_us, max_batch) in
        [(0u64, 1usize), (500, 4), (1_000, 8), (2_000, 8), (5_000, 8), (10_000, 8)]
    {
        let mut coord = Coordinator::start(
            &dir,
            BatchConfig { max_batch, max_wait: Duration::from_micros(window_us) },
        )?;
        let mut rng = Rng::new(0xBA7C);
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                if i % 4 == 3 {
                    // every 4th request is a raw conv
                    let p = conv_templates[i % conv_templates.len()];
                    let image = if p.is_single_channel() {
                        Tensor::randn(vec![p.wy, p.wx], &mut rng)
                    } else {
                        Tensor::randn(vec![p.c, p.wy, p.wx], &mut rng)
                    };
                    let filters = if p.is_single_channel() {
                        Tensor::randn(vec![p.m, p.k, p.k], &mut rng)
                    } else {
                        Tensor::randn(vec![p.m, p.c, p.k, p.k], &mut rng)
                    };
                    coord.submit(Payload::Conv {
                        op: pasconv::conv::ConvOp::dense(p),
                        image,
                        filters,
                    })
                } else {
                    coord.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) })
                }
            })
            .collect();
        let mut lats = vec![];
        for rx in rxs {
            let resp = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
            lats.push(resp.latency_secs);
        }
        let wall = t0.elapsed().as_secs_f64();
        let s = Summary::of(&lats);
        let m = coord.metrics();
        table.row(&[
            format!("{:.1}ms", window_us as f64 / 1000.0),
            max_batch.to_string(),
            format!("{:.0}", n as f64 / wall),
            format!("{:.2}ms", s.p50 * 1e3),
            format!("{:.2}ms", s.p99 * 1e3),
            format!("{:.2}", m.mean_batch_size()),
        ]);
        coord.shutdown();
    }
    table.print();
    println!("\nbatch_serving OK");
    Ok(())
}
