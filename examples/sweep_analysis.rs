//! Analysis walk-through: what the paper's model *decides* and why.
//!
//! For the Figs. 4/5 sweeps this prints, per problem:
//!   * the §3.1 P/Q decision (method, divisors, prefetch vs V_s volume)
//!     or the §3.2 stride-fixed parameters (S, M', W'x),
//!   * the working set vs S_shared and Th vs N_FMA,
//!   * the simulated time vs every baseline.
//!
//! Run: `cargo run --release --example sweep_analysis [-- --gpu titanx]`

use pasconv::analytic::{choose_single, choose_stride_fixed, SingleMethod};
use pasconv::baselines::{cudnn_proxy, dac17, tan128};
use pasconv::conv::suites::{fig4_suite, fig5_suite};
use pasconv::gpusim::{gtx_1080ti, simulate, titan_x_maxwell};
use pasconv::plans::paper_plan_for;
use pasconv::util::bench::Table;
use pasconv::util::cli::Args;

fn main() {
    let args = Args::parse();
    let g = match args.get_or("gpu", "1080ti") {
        "titanx" => titan_x_maxwell(),
        _ => gtx_1080ti(),
    };
    println!(
        "GPU: {}   N_FMA = {}   V_s = {} B   S_shared = {} KB\n",
        g.name,
        g.n_fma(),
        g.v_s(),
        g.shared_mem_bytes / 1024
    );

    println!("== §3.1 single-channel decisions (Fig. 4 suite) ==");
    let mut t = Table::new(&["problem", "method", "P", "Q", "D (KB)", "Th/N_FMA", "strategy"]);
    for p in fig4_suite() {
        let c = choose_single(&p, &g);
        let (d, th) = match c.method {
            SingleMethod::FilterSplit => (c.d1_bytes, c.th1),
            SingleMethod::MapSplit => (c.d2_bytes, c.th2),
        };
        t.row(&[
            p.label(),
            format!("{:?}", c.method),
            c.p.to_string(),
            c.q.to_string(),
            format!("{:.1}", d as f64 / 1024.0),
            format!("{:.2}", th as f64 / g.n_fma() as f64),
            if c.uses_prefetch { "prefetch".into() } else { "V_s volume".into() },
        ]);
    }
    t.print();

    println!("\n== §3.2 stride-fixed decisions (Fig. 5 suite, S = 32) ==");
    let mut t = Table::new(&["problem", "S", "M'", "W'x", "W'y", "smem (KB)", "hides latency"]);
    for p in fig5_suite() {
        let c = choose_stride_fixed(&p, &g, 32);
        t.row(&[
            p.label(),
            c.s_bytes.to_string(),
            c.m_prime.to_string(),
            c.wx_prime.to_string(),
            c.wy_prime.to_string(),
            format!("{:.1}", c.smem_bytes as f64 / 1024.0),
            c.hides_latency.to_string(),
        ]);
    }
    t.print();

    println!("\n== simulated comparison, all kernels (subset) ==");
    let mut t = Table::new(&["problem", "ours", "cudnn", "dac17", "tan128"]);
    for p in fig5_suite().into_iter().step_by(4) {
        let us = |s: f64| format!("{:.1}µs", s * 1e6);
        t.row(&[
            p.label(),
            us(simulate(&g, &paper_plan_for(&p, &g)).seconds),
            us(simulate(&g, &cudnn_proxy::plan(&p, &g)).seconds),
            us(simulate(&g, &dac17::plan(&p, &g)).seconds),
            us(simulate(&g, &tan128::plan(&p, &g)).seconds),
        ]);
    }
    t.print();
    println!("\nsweep_analysis OK");
}
