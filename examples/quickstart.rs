//! Quickstart: the whole stack in one file.
//!
//! 1. load the AOT'd artifacts (built once by `make artifacts`);
//! 2. run a multi-channel convolution through PJRT (the §3.2
//!    stride-fixed Pallas kernel's numerics);
//! 3. verify against the in-repo CPU oracle;
//! 4. ask the paper's analytic model how this problem would be divided
//!    on the GTX 1080Ti, and compare the simulated time with cuDNN's.
//!
//! Run: `cargo run --release --example quickstart`

use pasconv::baselines::cudnn_proxy;
use pasconv::conv::{conv2d_multi_cpu, max_abs_diff, ConvProblem};
use pasconv::coordinator::plan_advice;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::paper_plan_for;
use pasconv::runtime::{default_artifact_dir, Runtime, Tensor};
use pasconv::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // -- 1. the runtime ----------------------------------------------------
    let mut rt = Runtime::new(&default_artifact_dir())?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts: {}", rt.names().join(", "));

    // -- 2. a real convolution through the AOT'd Pallas kernel -------------
    let name = "multi_c32_w14_m32_k3";
    let p: ConvProblem = rt.artifact(name)?.problem()?;
    println!("\nrunning {name}: {}", p.label());
    let mut rng = Rng::new(42);
    let image = Tensor::randn(vec![p.c, p.wy, p.wx], &mut rng);
    let filters = Tensor::randn(vec![p.m, p.c, p.k, p.k], &mut rng);
    let out = rt.execute_conv(name, &image, &filters)?;
    println!("output shape: {:?}", out.shape);

    // -- 3. verify vs the CPU oracle ---------------------------------------
    let want = conv2d_multi_cpu(&p, &image.data, &filters.data);
    let diff = max_abs_diff(&out.data, &want);
    println!("max |PJRT - CPU oracle| = {diff:.2e}");
    assert!(diff < 1e-2, "numeric mismatch");

    // -- 4. the paper's model for this problem -----------------------------
    let g = gtx_1080ti();
    println!("\non the paper's {}:", g.name);
    println!("  plan: {}", plan_advice(&p, &g));
    let ours = simulate(&g, &paper_plan_for(&p, &g));
    let base = simulate(&g, &cudnn_proxy::plan(&p, &g));
    println!(
        "  simulated: ours {:.1} µs vs cuDNN-proxy {:.1} µs  ->  {:.2}x",
        ours.seconds * 1e6,
        base.seconds * 1e6,
        base.seconds / ours.seconds
    );
    println!("\nquickstart OK");
    Ok(())
}
