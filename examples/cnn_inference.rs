//! End-to-end validation driver (DESIGN.md §6): serve a small real CNN
//! (PaperNet — single-channel stem + stride-fixed body, the paper's two
//! kernels) on a synthetic digit corpus through the full stack:
//!
//!   client -> coordinator (queue + dynamic batcher) -> PJRT executor
//!
//! and report latency percentiles + throughput.  The recorded run lives
//! in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example cnn_inference [-- --requests 512 --window-ms 2]`

use std::time::{Duration, Instant};

use pasconv::coordinator::{BatchConfig, Coordinator, Payload};
use pasconv::runtime::{default_artifact_dir, Tensor};
use pasconv::util::cli::Args;
use pasconv::util::rng::Rng;
use pasconv::util::stats::Summary;

/// Synthetic "digit": a bright KxK blob at a class-dependent position on
/// a noisy 28x28 canvas — enough structure that logits depend on input.
fn synth_digit(rng: &mut Rng, class: usize) -> Tensor {
    let mut img = vec![0f32; 28 * 28];
    for v in img.iter_mut() {
        *v = 0.1 * rng.next_normal() as f32;
    }
    let cy = 4 + (class % 5) * 4;
    let cx = 4 + (class / 5) * 4;
    for dy in 0..5 {
        for dx in 0..5 {
            img[(cy + dy) * 28 + cx + dx] += 1.0;
        }
    }
    Tensor::new(vec![1, 28, 28], img).unwrap()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get_usize("requests", 512);
    let window_ms = args.get_usize("window-ms", 2) as u64;

    let mut coord = Coordinator::start(
        &default_artifact_dir(),
        BatchConfig { max_batch: 8, max_wait: Duration::from_millis(window_ms) },
    )?;
    println!("coordinator up; serving {n} PaperNet requests (batch window {window_ms} ms)");

    let mut rng = Rng::new(0xD161);
    let t0 = Instant::now();
    let rxs: Vec<_> =
        (0..n).map(|i| coord.submit(Payload::Cnn { image: synth_digit(&mut rng, i % 10) })).collect();

    let mut latencies = Vec::with_capacity(n);
    let mut batch_sizes = Vec::with_capacity(n);
    let mut argmax_counts = [0usize; 10];
    for rx in rxs {
        let resp = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        latencies.push(resp.latency_secs);
        batch_sizes.push(resp.batch_size as f64);
        let top = resp
            .output
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        argmax_counts[top] += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = Summary::of(&latencies);
    println!("\n== e2e serving results ==");
    println!("requests           : {n}");
    println!("wall time          : {wall:.3} s");
    println!("throughput         : {:.0} req/s", n as f64 / wall);
    println!(
        "latency            : p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  max {:.2} ms",
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.p99 * 1e3,
        s.max * 1e3
    );
    println!(
        "mean batch size    : {:.2} (target 8)",
        batch_sizes.iter().sum::<f64>() / batch_sizes.len() as f64
    );
    println!("prediction spread  : {argmax_counts:?} (untrained weights; spread = inputs matter)");
    println!("metrics json       : {}", coord.metrics().to_json().render());

    // untrained net, but logits must not be constant across classes
    assert!(
        argmax_counts.iter().filter(|&&c| c > 0).count() >= 2,
        "all inputs predicted identically — serve path broken"
    );
    coord.shutdown();
    println!("\ncnn_inference OK");
    Ok(())
}
