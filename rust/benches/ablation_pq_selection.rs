//! Ablation: the **§3.1 P/Q selection procedure** — does the closed-form
//! choice beat naive divisions?
//!
//! For each Fig. 4 case, compares the chosen (P, Q) against (a) no
//! division (P=Q=1, everything resident or the raw volume strategy) and
//! (b) maximal division (P=Wy or Q=M) under the same simulator.
//!
//! Run: `cargo bench --bench ablation_pq_selection`

use pasconv::analytic::single::{choose, SingleChoice, SingleMethod};
use pasconv::conv::suites::fig4_suite;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::single_channel::plan_with_choice;
use pasconv::util::bench::Table;
use pasconv::util::stats::geomean;

fn force(c: &SingleChoice, p: usize, q: usize, base: &pasconv::conv::ConvProblem,
         g: &pasconv::gpusim::GpuSpec) -> SingleChoice {
    use pasconv::analytic::single::{d1_bytes, d2_bytes, th1, th2};
    SingleChoice {
        method: c.method,
        p,
        q,
        d1_bytes: d1_bytes(base, g, p),
        d2_bytes: d2_bytes(base, g, q),
        th1: th1(base, g, p),
        th2: th2(base, g, q),
        uses_prefetch: c.uses_prefetch,
    }
}

fn main() {
    let g = gtx_1080ti();
    println!("== §3.1 ablation: chosen P/Q vs naive divisions ==\n");
    let mut t = Table::new(&["problem", "chosen", "t chosen", "t undivided", "t max-division",
        "vs undiv", "vs max"]);
    let (mut vs_undiv, mut vs_max) = (vec![], vec![]);
    for prob in fig4_suite() {
        let c = choose(&prob, &g);
        let t_chosen = simulate(&g, &plan_with_choice(&prob, &g, &c)).seconds;
        let undiv = force(&c, 1, 1, &prob, &g);
        let t_undiv = simulate(&g, &plan_with_choice(&prob, &g, &undiv)).seconds;
        let maxed = match c.method {
            SingleMethod::FilterSplit => force(&c, prob.wy, 1, &prob, &g),
            SingleMethod::MapSplit => force(&c, 1, prob.m, &prob, &g),
        };
        let t_max = simulate(&g, &plan_with_choice(&prob, &g, &maxed)).seconds;
        vs_undiv.push(t_undiv / t_chosen);
        vs_max.push(t_max / t_chosen);
        t.row(&[
            prob.label(),
            format!("{:?} P={} Q={}", c.method, c.p, c.q),
            format!("{:.1}µs", t_chosen * 1e6),
            format!("{:.1}µs", t_undiv * 1e6),
            format!("{:.1}µs", t_max * 1e6),
            format!("{:.2}x", t_undiv / t_chosen),
            format!("{:.2}x", t_max / t_chosen),
        ]);
    }
    t.print();
    println!(
        "\ngeomean advantage: vs undivided {:.2}x, vs max-division {:.2}x",
        geomean(&vs_undiv),
        geomean(&vs_max)
    );
    // the procedure must never lose to either naive policy (>2% tolerance
    // for cases where they coincide)
    assert!(vs_undiv.iter().all(|&x| x > 0.98), "chosen P/Q loses to no division");
    assert!(vs_max.iter().all(|&x| x > 0.98), "chosen P/Q loses to max division");
    assert!(geomean(&vs_max) > 1.02, "max-division not distinguishable");
    println!("ablation_pq_selection OK");
}
