//! Fleet scaling bench: throughput and latency of the multi-GPU
//! scheduler under one fixed offered load, across 1/2/4/8 homogeneous
//! devices, a heterogeneous fleet, and the four placement policies —
//! the EXPERIMENTS.md §8 table — plus the capped per-device memory
//! pools of the §11 multi-tenant table.
//!
//! The fleet runs in virtual time (service seconds from the
//! cross-backend dispatched cost model,
//! `backend::batched_dispatch_seconds`, per device spec), so every
//! number here is exact and deterministic: no wall clock, no
//! artifacts, no flakiness.
//!
//! Run: `cargo bench --bench e2e_fleet`
//! CI check mode (asserts only, summary table): append `-- --check`.

use std::collections::HashSet;

use pasconv::fleet::{mean_service_secs, offered_load, Arrival, Fleet, FleetConfig, Policy};
use pasconv::gpusim::{gtx_1080ti, titan_x_maxwell, GpuSpec};
use pasconv::util::bench::Table;
use pasconv::util::cli::Args;
use pasconv::util::stats::Summary;

struct RunResult {
    accepted: u64,
    rejected: u64,
    completed: usize,
    /// requests per virtual second (completed / makespan)
    throughput: f64,
    makespan: f64,
    lat: Summary,
    affinity_spills: u64,
    /// per-device utilization (busy / makespan), min..max
    util_min: f64,
    util_max: f64,
    /// rejections attributable to pool pressure (queue slots existed)
    mem_rejected: u64,
    /// worst per-device pool high-water mark, bytes
    pool_peak: usize,
}

fn run(
    specs: Vec<GpuSpec>,
    policy: Policy,
    queue_bound: usize,
    capacity_bytes: Option<usize>,
    load: &[Arrival],
) -> RunResult {
    let mut fleet = Fleet::new(specs, FleetConfig { policy, queue_bound, capacity_bytes });
    let mut completions = Vec::with_capacity(load.len());
    for a in load {
        // reactive serving: jobs finishing before this arrival free
        // their queue slots first
        completions.extend(fleet.complete_until(a.t));
        fleet.submit(a.conv, Some(a.model));
    }
    completions.extend(fleet.drain());
    // every accepted job completes exactly once — the bench re-checks the
    // proptest invariant on the real load
    let ids: HashSet<u64> = completions.iter().map(|c| c.job).collect();
    assert_eq!(ids.len(), completions.len(), "duplicate completion");
    assert_eq!(completions.len() as u64, fleet.stats.accepted, "lost job");
    let makespan = completions.iter().map(|c| c.finish).fold(0.0f64, f64::max);
    let lats: Vec<f64> = completions.iter().map(|c| c.latency()).collect();
    let (mut umin, mut umax) = (f64::INFINITY, 0.0f64);
    let mut pool_peak = 0usize;
    for d in fleet.devices() {
        let u = d.busy_secs / makespan.max(1e-30);
        umin = umin.min(u);
        umax = umax.max(u);
        // the hard invariants every run re-checks on the real load: the
        // pool cap held at the high-water mark and the drain released
        // every reservation
        let p = d.pool();
        assert!(p.stats.peak_in_use_slab <= p.capacity(), "pool cap burst on device {}", d.id);
        assert_eq!(p.in_use_slab_bytes(), 0, "drain left bytes resident on device {}", d.id);
        pool_peak = pool_peak.max(p.stats.peak_in_use_slab);
    }
    RunResult {
        accepted: fleet.stats.accepted,
        rejected: fleet.stats.rejected,
        completed: completions.len(),
        throughput: completions.len() as f64 / makespan.max(1e-30),
        makespan,
        lat: Summary::of(&lats),
        affinity_spills: fleet.stats.affinity_spills,
        util_min: umin,
        util_max: umax,
        mem_rejected: fleet.stats.mem_rejected,
        pool_peak,
    }
}

fn main() {
    let args = Args::parse();
    let check_only = args.has("check");
    let n = args.get_usize("requests", 512);
    let g = gtx_1080ti();

    // offered rate: ~6x one device's capacity on the mean request, so
    // 1/2/4 devices saturate (work-limited) and 8 approaches the
    // arrival-limited ceiling — equal offered load for every row
    let probe = offered_load(256, 1.0, 0xF1EE7, None);
    let mean_service = mean_service_secs(&probe, &g);
    let rate = 6.0 / mean_service;
    let load = offered_load(n, rate, 0xF1EE7, None);
    println!(
        "== e2e fleet: {n} requests at {:.0} req/s offered ({:.1}x one {}'s capacity) ==\n",
        rate,
        6.0,
        g.name
    );

    let mut t = Table::new(&[
        "devices", "fleet", "policy", "req/s", "p50 lat", "p99 lat", "util", "speedup",
    ]);
    let mut row = |devices: String, fleet_name: &str, policy: Policy, r: &RunResult, base: f64| {
        t.row(&[
            devices,
            fleet_name.to_string(),
            policy.label().to_string(),
            format!("{:.0}", r.throughput),
            format!("{:.2}ms", r.lat.p50 * 1e3),
            format!("{:.2}ms", r.lat.p99 * 1e3),
            format!("{:.0}-{:.0}%", 100.0 * r.util_min, 100.0 * r.util_max),
            format!("{:.2}x", r.throughput / base),
        ]);
    };

    // ---- homogeneous scaling, least-loaded ----
    let unbounded = n; // accept everything: equal *served* load per row
    let r1 = run(vec![g.clone()], Policy::LeastLoaded, unbounded, None, &load);
    let base = r1.throughput;
    row("1".into(), "1080Ti", Policy::LeastLoaded, &r1, base);
    let mut speedup4 = 0.0;
    let mut results = vec![(1usize, r1)];
    for d in [2usize, 4, 8] {
        let r = run(vec![g.clone(); d], Policy::LeastLoaded, unbounded, None, &load);
        row(d.to_string(), "1080Ti", Policy::LeastLoaded, &r, base);
        if d == 4 {
            speedup4 = r.throughput / base;
        }
        results.push((d, r));
    }

    // ---- policies at 4 homogeneous devices ----
    let rr4 = run(vec![g.clone(); 4], Policy::RoundRobin, unbounded, None, &load);
    row("4".into(), "1080Ti", Policy::RoundRobin, &rr4, base);
    // strict pinning (queues never fill): the warmth/balance trade-off
    let af4 = run(vec![g.clone(); 4], Policy::ModelAffinity, unbounded, None, &load);
    row("4".into(), "1080Ti", Policy::ModelAffinity, &af4, base);
    // bounded queues: pressure spills off the hot shard and recovers
    // most of the balance while keeping models pinned when possible
    let af4b = run(vec![g.clone(); 4], Policy::ModelAffinity, 8, None, &load);
    row("4 (bound 8)".into(), "1080Ti", Policy::ModelAffinity, &af4b, base);

    // ---- heterogeneous fleet: 2x Pascal + 2x Maxwell ----
    let hetero = || vec![g.clone(), g.clone(), titan_x_maxwell(), titan_x_maxwell()];
    let het_ll = run(hetero(), Policy::LeastLoaded, unbounded, None, &load);
    row("4".into(), "2xPascal+2xMaxwell", Policy::LeastLoaded, &het_ll, base);
    let het_rr = run(hetero(), Policy::RoundRobin, unbounded, None, &load);
    row("4".into(), "2xPascal+2xMaxwell", Policy::RoundRobin, &het_rr, base);
    t.print();

    // ---- bounded admission under the same overload ----
    let bounded = run(vec![g.clone(); 2], Policy::LeastLoaded, 8, None, &load);
    println!(
        "\nadmission (2 devices, queue bound 8): accepted {} rejected {} ({:.0}% shed), p99 {:.2}ms",
        bounded.accepted,
        bounded.rejected,
        100.0 * bounded.rejected as f64 / n as f64,
        bounded.lat.p99 * 1e3,
    );

    // ---- multi-tenant capped pools (EXPERIMENTS §11) ----
    // same offered load, 4 devices, pools capped in units of the
    // largest job footprint: tight caps shed on memory, roomy caps
    // co-locate tenants, bytes-aware placement spreads residency.
    // Queue bound 64 so memory — not queue slots — is the binding
    // constraint (at bound 8 the queues fill long before a 2x-job pool
    // does and nothing ever sheds on memory; the mirror pins this)
    let max_fp = load.iter().map(|a| a.conv.footprint_bytes()).max().unwrap();
    let tight = run(vec![g.clone(); 4], Policy::LeastLoaded, 64, Some(2 * max_fp), &load);
    let roomy = run(vec![g.clone(); 4], Policy::LeastLoaded, 64, Some(5 * max_fp), &load);
    let tight_bytes =
        run(vec![g.clone(); 4], Policy::LeastLoadedBytes, 64, Some(2 * max_fp), &load);
    let mut pt = Table::new(&[
        "cap", "policy", "accepted", "shed (mem)", "pool peak", "p99 lat",
    ]);
    let mut prow = |cap_mult: usize, policy: Policy, r: &RunResult| {
        pt.row(&[
            format!("{cap_mult}x job"),
            policy.label().to_string(),
            format!("{}", r.accepted),
            format!("{} ({})", r.rejected, r.mem_rejected),
            format!("{:.0}%", 100.0 * r.pool_peak as f64 / (cap_mult * max_fp) as f64),
            format!("{:.2}ms", r.lat.p99 * 1e3),
        ]);
    };
    println!("\nmulti-tenant pools (4 devices, queue bound 64, job footprint {max_fp} B):");
    prow(2, Policy::LeastLoaded, &tight);
    prow(2, Policy::LeastLoadedBytes, &tight_bytes);
    prow(5, Policy::LeastLoaded, &roomy);
    pt.print();

    // capped-pool gates: the cap held everywhere (asserted inside run),
    // tight caps shed on memory while roomy ones keep multiple tenants
    // resident, and uncapped runs never count memory rejections
    assert!(tight.mem_rejected > 0, "2x-job caps must shed on memory under 6x overload");
    assert!(tight.pool_peak <= 2 * max_fp);
    assert!(
        roomy.pool_peak > max_fp,
        "roomy caps must co-locate >= 2 jobs on one shard (peak {} vs job {max_fp})",
        roomy.pool_peak
    );
    assert!(roomy.mem_rejected <= tight.mem_rejected, "more headroom cannot shed more");
    assert!(roomy.accepted >= tight.accepted, "more headroom cannot admit less");
    assert!(
        tight_bytes.accepted >= tight.accepted,
        "bytes-aware placement must admit at least as much under a tight cap"
    );
    for (_, r) in &results {
        assert_eq!(r.mem_rejected, 0, "uncapped runs never reject on memory");
    }

    // ---- the gates CI runs this bench for ----
    assert!(
        speedup4 >= 3.0,
        "4 homogeneous devices must give >= 3x the 1-device throughput (got {speedup4:.2}x)"
    );
    for (d, r) in &results {
        assert_eq!(r.completed, n, "{d} devices: every accepted job completes");
        assert_eq!(r.rejected, 0, "{d} devices: unbounded run must not shed");
        assert!(r.lat.p99 >= r.lat.p50 && r.lat.p50 > 0.0);
        assert!(r.makespan > 0.0);
    }
    // more devices never hurt throughput at equal offered load
    for w in results.windows(2) {
        assert!(
            w[1].1.throughput >= w[0].1.throughput * 0.999,
            "throughput regressed from {} to {} devices",
            w[0].0,
            w[1].0
        );
    }
    // on the heterogeneous fleet, cost-aware placement beats blind RR
    assert!(
        het_ll.makespan <= het_rr.makespan * 1.001,
        "least-loaded lost to round-robin on a heterogeneous fleet: {} vs {}",
        het_ll.makespan,
        het_rr.makespan
    );
    // affinity kept every model pinned (spills only under pressure):
    // unbounded = zero spills, bounded = spills engage and rebalance
    assert!(af4.completed == n);
    assert_eq!(af4.affinity_spills, 0, "unbounded affinity must never spill");
    assert!(af4b.affinity_spills > 0, "bounded affinity must spill under overload");
    assert!(
        af4b.throughput > af4.throughput,
        "pressure spilling must beat strict pinning under overload"
    );
    // bounded admission sheds under overload instead of queueing forever
    assert!(bounded.rejected > 0, "2 bounded devices must shed at 6x overload");
    assert_eq!(bounded.accepted + bounded.rejected, n as u64);

    if !check_only {
        println!("\nhomogeneous scaling (least-loaded): ");
        for (d, r) in &results {
            println!(
                "  {d} device(s): {:.0} req/s, makespan {:.3}s, util {:.0}-{:.0}%",
                r.throughput, r.makespan, 100.0 * r.util_min, 100.0 * r.util_max
            );
        }
        println!(
            "affinity at 4 devices: {} spills / {} requests",
            af4.affinity_spills, n
        );
    }
    println!("\ne2e_fleet OK ({speedup4:.2}x at 4 devices)");
}
