//! Ablation: **cross-backend dispatch vs the tuned paper-kernel-only
//! path** over every suite workload (Fig. 4, Fig. 5, the CNN-model
//! layer mix, and Fig. 5 on Maxwell).
//!
//! The tuner (PR 1) searches *within* the paper's algorithm; the
//! dispatcher (`backend::dispatch`) additionally chooses *between*
//! algorithms — the paper kernels, the cuDNN implicit-GEMM proxy,
//! DAC'17, Tan's 128-B discipline, Winograd and FFT — per problem,
//! under the same simulator.  The never-lose invariant is structural
//! (the paper-tuned backend is always in the candidate set); this bench
//! reports where leaving the paper's algorithm wins and regenerates the
//! EXPERIMENTS.md §9 table.
//!
//! Run: `cargo bench --bench ablation_dispatch`
//! CI check mode (asserts + summary only): append `-- --check`.

use std::collections::BTreeMap;

use pasconv::backend::Dispatcher;
use pasconv::conv::suites::{all_cnn_layers, all_cnn_ops, fig4_suite, fig5_suite, mobilenet_v1};
use pasconv::conv::{ConvOp, ConvProblem};
use pasconv::gpusim::{gtx_1080ti, titan_x_maxwell, GpuSpec};
use pasconv::util::bench::Table;
use pasconv::util::cli::Args;
use pasconv::util::stats::geomean;

struct SuiteResult {
    geomean: f64,
    max: f64,
    /// workloads where a non-paper backend won, by backend tag
    wins: BTreeMap<String, usize>,
}

fn run_suite(
    registry: &Dispatcher,
    name: &str,
    suite: &[ConvProblem],
    g: &GpuSpec,
    check_only: bool,
) -> SuiteResult {
    let mut table =
        Table::new(&["problem", "tuned (µs)", "dispatched (µs)", "speedup", "backend"]);
    let mut speedups = Vec::with_capacity(suite.len());
    let mut wins: BTreeMap<String, usize> = BTreeMap::new();
    for p in suite {
        let d = registry.decide(p, g);
        // the acceptance gate: dispatch never loses to paper-tuned-only
        assert!(
            d.cycles <= d.tuned_cycles * (1.0 + 1e-9),
            "{}: dispatcher lost ({} > {})",
            p.label(),
            d.cycles,
            d.tuned_cycles
        );
        if d.backend != "paper-tuned" {
            *wins.entry(d.backend.clone()).or_insert(0) += 1;
        }
        speedups.push(d.speedup());
        table.row(&[
            p.label(),
            format!("{:.1}", g.cycles_to_secs(d.tuned_cycles) * 1e6),
            format!("{:.1}", g.cycles_to_secs(d.cycles) * 1e6),
            format!("{:.2}x", d.speedup()),
            d.backend.clone(),
        ]);
    }
    let r = SuiteResult {
        geomean: geomean(&speedups),
        max: speedups.iter().cloned().fold(1.0, f64::max),
        wins,
    };
    println!("-- {name} on {} ({} workloads) --", g.name, suite.len());
    if !check_only {
        table.print();
    }
    let non_paper: usize = r.wins.values().sum();
    println!(
        "   geomean {:.3}x  max {:.2}x  non-paper wins {}/{} {:?}\n",
        r.geomean,
        r.max,
        non_paper,
        suite.len(),
        r.wins
    );
    r
}

/// The op-level half of the ablation: every model op (stride / pad /
/// groups included) ranked against the naive lowered paper-tuned floor.
fn run_op_suite(
    registry: &Dispatcher,
    name: &str,
    suite: &[ConvOp],
    g: &GpuSpec,
    check_only: bool,
) -> SuiteResult {
    let mut table =
        Table::new(&["op", "lowered floor (µs)", "dispatched (µs)", "speedup", "backend"]);
    let mut speedups = Vec::with_capacity(suite.len());
    let mut wins: BTreeMap<String, usize> = BTreeMap::new();
    for op in suite {
        let d = registry.decide_op(op, g);
        // the ISSUE-5 acceptance gate: never lose to the lowered floor
        assert!(
            d.cycles <= d.tuned_cycles * (1.0 + 1e-9),
            "{}: op dispatcher lost ({} > {})",
            op.label(),
            d.cycles,
            d.tuned_cycles
        );
        if d.backend != "paper-tuned" {
            *wins.entry(d.backend.clone()).or_insert(0) += 1;
        }
        speedups.push(d.speedup());
        table.row(&[
            op.label(),
            format!("{:.1}", g.cycles_to_secs(d.tuned_cycles) * 1e6),
            format!("{:.1}", g.cycles_to_secs(d.cycles) * 1e6),
            format!("{:.2}x", d.speedup()),
            d.backend.clone(),
        ]);
    }
    let r = SuiteResult {
        geomean: geomean(&speedups),
        max: speedups.iter().cloned().fold(1.0, f64::max),
        wins,
    };
    println!("-- {name} on {} ({} ops) --", g.name, suite.len());
    if !check_only {
        table.print();
    }
    let non_paper: usize = r.wins.values().sum();
    println!(
        "   geomean {:.3}x  max {:.2}x  non-paper wins {}/{} {:?}\n",
        r.geomean,
        r.max,
        non_paper,
        suite.len(),
        r.wins
    );
    r
}

fn main() {
    let args = Args::parse();
    let check_only = args.has("check");
    let registry = Dispatcher::full();
    println!("== ablation: cross-backend dispatch vs tuned paper kernels only ==\n");
    let g = gtx_1080ti();
    let t = titan_x_maxwell();

    let results = [
        run_suite(&registry, "Fig. 4 suite (single-channel)", &fig4_suite(), &g, check_only),
        run_suite(&registry, "Fig. 5 suite (multi-channel)", &fig5_suite(), &g, check_only),
        run_suite(&registry, "CNN model layers", &all_cnn_layers(), &g, check_only),
        run_suite(&registry, "Fig. 5 suite (portability)", &fig5_suite(), &t, check_only),
    ];

    // ---- the op layer: model ops vs the naive lowered floor ----
    let op_results = [
        run_op_suite(&registry, "All model ops (5 models)", &all_cnn_ops(), &g, check_only),
        run_op_suite(&registry, "MobileNetV1 ops", &mobilenet_v1(), &g, check_only),
        run_op_suite(&registry, "MobileNetV1 ops (portability)", &mobilenet_v1(), &t, check_only),
    ];
    for r in &op_results {
        assert!(r.geomean >= 1.0 - 1e-9, "op suite geomean below 1.0: {}", r.geomean);
    }
    // native stride/group schedules must genuinely beat the naive
    // lowering somewhere (the strided ResNet/MobileNet regime)
    let best_op = op_results.iter().map(|r| r.max).fold(0.0, f64::max);
    assert!(best_op > 1.05, "no op ever beat its naive lowering ({best_op})");

    // ---- the gates CI runs this bench for ----
    // geomean >= 1.0 everywhere (never-lose, aggregated)...
    for r in &results {
        assert!(r.geomean >= 1.0 - 1e-9, "suite geomean below 1.0: {}", r.geomean);
    }
    // ...and strictly > 1.0 where a baseline legitimately wins (the
    // compute-bound K=3 regime lives in the Fig. 5 + CNN suites)
    let best = results.iter().map(|r| r.geomean).fold(0.0, f64::max);
    assert!(best > 1.001, "dispatch never beat the paper-only path anywhere ({best})");
    let non_paper: usize = results.iter().flat_map(|r| r.wins.values()).sum();
    assert!(non_paper > 0, "no non-paper backend ever selected");

    println!(
        "ablation_dispatch OK (best suite geomean {:.3}x, {} non-paper wins)",
        best, non_paper
    );
}
