//! Regenerates the **§4 Maxwell portability result** — both kernels on
//! the GTX Titan X (Maxwell) vs cuDNN.
//!
//! Paper: "We also implemented our two kernels on Maxwell series GPU GTX
//! Titan X, and it also showed that our performance is faster than Cudnn
//! on the same GPU by 1.3X to 3.7X in the single-channel convolution and
//! 1.08X to 1.8X in the multi-channel convolution."
//!
//! Run: `cargo bench --bench maxwell_titanx`

use pasconv::baselines::cudnn_proxy;
use pasconv::conv::suites::{fig4_suite, fig5_suite};
use pasconv::gpusim::{simulate, titan_x_maxwell};
use pasconv::plans::paper_plan_for;
use pasconv::util::bench::Table;
use pasconv::util::stats::geomean;

fn main() {
    let t = titan_x_maxwell();
    println!("== Maxwell portability: {} ==\n", t.name);

    for (label, suite, paper_range) in [
        ("single-channel (Fig. 4 suite)", fig4_suite(), "1.3x .. 3.7x"),
        ("multi-channel (Fig. 5 suite)", fig5_suite(), "1.08x .. 1.8x"),
    ] {
        println!("-- {label} --");
        let mut table = Table::new(&["problem", "ours (µs)", "cudnn (µs)", "speedup"]);
        let mut speedups = vec![];
        for p in suite {
            let ours = simulate(&t, &paper_plan_for(&p, &t)).seconds;
            let base = simulate(&t, &cudnn_proxy::plan(&p, &t)).seconds;
            speedups.push(base / ours);
            table.row(&[
                p.label(),
                format!("{:.1}", ours * 1e6),
                format!("{:.1}", base * 1e6),
                format!("{:.2}x", base / ours),
            ]);
        }
        table.print();
        let (min, max) = (
            speedups.iter().cloned().fold(f64::INFINITY, f64::min),
            speedups.iter().cloned().fold(0.0, f64::max),
        );
        println!(
            "range {:.2}x .. {:.2}x   geomean {:.2}x    (paper: {paper_range})\n",
            min,
            max,
            geomean(&speedups)
        );
    }
    println!("maxwell_titanx OK");
}
