//! Ablation: **tuned plans vs the paper's closed-form plans** over every
//! suite workload (Fig. 4, Fig. 5, and the CNN-model layer mix).
//!
//! The paper's §3 procedures pick exactly one P/Q division
//! (single-channel) and one stride-fixed block shape (multi-channel) per
//! problem.  `tuner` instead searches the full legal plan space
//! (enumerate → closed-form score → top-K simulate) with the paper's
//! pick as a floor.  This bench reports where the search wins, by how
//! much, and what it picked — the "tuned vs paper-fixed" section of
//! EXPERIMENTS.md is regenerated from this output.  The never-lose
//! invariant is asserted inside `tuner::suite_report` (shared with the
//! `tune` CLI subcommand, so both always report the same numbers).
//!
//! Run: `cargo bench --bench ablation_tuned_vs_paper`

use pasconv::conv::suites::{all_cnn_layers, fig4_suite, fig5_suite};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, titan_x_maxwell, GpuSpec};
use pasconv::tuner;

fn run_suite(name: &str, suite: &[ConvProblem], g: &GpuSpec) -> usize {
    println!("-- {name} on {} ({} workloads) --", g.name, suite.len());
    let r = tuner::suite_report(suite, g);
    r.table.print();
    println!(
        "   improved {}/{}  geomean {:.3}x  max {:.2}x\n",
        r.improved, r.total, r.geomean_speedup, r.max_speedup
    );
    r.improved
}

fn main() {
    println!("== ablation: plan-space tuning vs the paper's fixed §3 picks ==\n");
    let g = gtx_1080ti();
    let t = titan_x_maxwell();

    let mut total_improved = 0;
    total_improved += run_suite("Fig. 4 suite (single-channel)", &fig4_suite(), &g);
    total_improved += run_suite("Fig. 5 suite (multi-channel)", &fig5_suite(), &g);
    total_improved += run_suite("CNN model layers", &all_cnn_layers(), &g);
    total_improved += run_suite("Fig. 5 suite (portability)", &fig5_suite(), &t);

    assert!(total_improved > 0, "tuning never improved anything — search broken?");
    println!("ablation_tuned_vs_paper OK ({total_improved} workloads improved)");
}
