//! Whole-model conv-stack comparison — §4's "convolutions which are
//! commonly used in popular CNN models [AlexNet][GoogLeNet][VGG][ResNet]"
//! aggregated per model, now at the op level: each network's REAL conv
//! ops ('same' padding, ResNet-18's native stride-2 transitions,
//! MobileNetV1's depthwise/pointwise stack) priced end-to-end under the
//! paper's plans *and* the tuner's vs the cuDNN proxy's lowered route,
//! plus the small-map share that drives the difference (the paper's §1
//! motivation).  Layer times are summed flat — the graph-level view
//! (pools, skips, memory plan) is the `e2e_models` bench.
//!
//! Run: `cargo bench --bench model_stacks`

use pasconv::backend::{ConvBackend, CudnnProxy};
use pasconv::conv::suites::{model_ops, small_map_fraction};
use pasconv::conv::ConvOp;
use pasconv::gpusim::{gtx_1080ti, simulate, Epilogue, GpuSpec, KernelPlan};
use pasconv::plans::{op_plan_for, paper_op_plan_for};
use pasconv::util::bench::Table;

fn stack_time(
    g: &GpuSpec,
    ops: &[ConvOp],
    plan_fn: &dyn Fn(&ConvOp, &GpuSpec) -> KernelPlan,
) -> f64 {
    ops.iter().map(|op| simulate(g, &plan_fn(op, g)).seconds).sum()
}

fn main() {
    let g = gtx_1080ti();
    println!("== CNN model conv-op stacks on {} ==\n", g.name);
    let mut t = Table::new(&[
        "model",
        "ops",
        "maps<32",
        "paper (ms)",
        "tuned (ms)",
        "cudnn (ms)",
        "paper speedup",
        "tuned speedup",
    ]);
    let mut speedups = vec![];
    for (name, ops) in model_ops() {
        let paper = stack_time(&g, &ops, &|op, g| paper_op_plan_for(op, Epilogue::None, g));
        let tuned = stack_time(&g, &ops, &|op, g| op_plan_for(op, Epilogue::None, g));
        let base = stack_time(&g, &ops, &|op, g| CudnnProxy.op_plan(op, g));
        assert!(
            tuned <= paper * (1.0 + 1e-9),
            "{name}: tuned stack {tuned} slower than paper {paper}"
        );
        speedups.push((name, base / paper, base / tuned, small_map_fraction(&ops)));
        t.row(&[
            name.to_string(),
            ops.len().to_string(),
            format!("{:.0}%", 100.0 * small_map_fraction(&ops)),
            format!("{:.3}", paper * 1e3),
            format!("{:.3}", tuned * 1e3),
            format!("{:.3}", base * 1e3),
            format!("{:.2}x", base / paper),
            format!("{:.2}x", base / tuned),
        ]);
    }
    t.print();

    // the paper's §1 motivation: models dominated by small maps benefit
    // the most — AlexNet (all < 32 px) must beat VGG-16 (mostly large)
    let alex = speedups.iter().find(|(n, ..)| *n == "alexnet").unwrap();
    let vgg = speedups.iter().find(|(n, ..)| *n == "vgg16").unwrap();
    assert!(alex.3 > vgg.3, "small-map shares out of order");
    assert!(alex.1 > vgg.1, "AlexNet's paper speedup must exceed VGG-16's");
    // every stack wins vs the proxy under the tuned plans
    for (name, _, tuned_speedup, _) in &speedups {
        assert!(
            *tuned_speedup > 1.0,
            "{name}: tuned stack lost to the cudnn proxy ({tuned_speedup:.2}x)"
        );
    }
    println!("\nmodel_stacks OK");
}
