//! Whole-model conv-stack comparison — §4's "convolutions which are
//! commonly used in popular CNN models [AlexNet][GoogLeNet][VGG][ResNet]"
//! aggregated per model: the end-to-end conv time of each network under
//! our kernels vs the cuDNN proxy, plus the small-map share that drives
//! the difference (the paper's §1 motivation).
//!
//! Run: `cargo bench --bench model_stacks`

use pasconv::baselines::cudnn_proxy;
use pasconv::conv::suites::{alexnet, googlenet_inception3a, resnet18, small_map_fraction, vgg16};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::paper_plan_for;
use pasconv::util::bench::Table;

fn stack_time(g: &pasconv::gpusim::GpuSpec, layers: &[ConvProblem], ours: bool) -> f64 {
    layers
        .iter()
        .map(|p| {
            let plan = if ours { paper_plan_for(p, g) } else { cudnn_proxy::plan(p, g) };
            simulate(g, &plan).seconds
        })
        .sum()
}

fn main() {
    let g = gtx_1080ti();
    println!("== CNN model conv stacks on {} ==\n", g.name);
    let models: [(&str, Vec<ConvProblem>); 4] = [
        ("AlexNet (stride-1 convs)", alexnet()),
        ("VGG-16", vgg16()),
        ("ResNet-18", resnet18()),
        ("GoogLeNet inception(3a)", googlenet_inception3a()),
    ];
    let mut t = Table::new(&[
        "model",
        "layers",
        "maps<32",
        "ours (ms)",
        "cudnn (ms)",
        "model speedup",
    ]);
    let mut speedups = vec![];
    for (name, layers) in &models {
        let ours = stack_time(&g, layers, true);
        let base = stack_time(&g, layers, false);
        speedups.push((name, base / ours, small_map_fraction(layers)));
        t.row(&[
            name.to_string(),
            layers.len().to_string(),
            format!("{:.0}%", 100.0 * small_map_fraction(layers)),
            format!("{:.3}", ours * 1e3),
            format!("{:.3}", base * 1e3),
            format!("{:.2}x", base / ours),
        ]);
    }
    t.print();

    // the paper's §1 motivation: models dominated by small maps benefit
    // the most — speedup should correlate with the small-map share
    let alex = speedups.iter().find(|(n, _, _)| n.starts_with("AlexNet")).unwrap();
    let vgg = speedups.iter().find(|(n, _, _)| n.starts_with("VGG")).unwrap();
    println!(
        "\nsmall-map-heavy AlexNet ({:.0}% < 32px): {:.2}x   vs map-heavy VGG-16 ({:.0}%): {:.2}x",
        100.0 * alex.2,
        alex.1,
        100.0 * vgg.2,
        vgg.1
    );
    assert!(speedups.iter().all(|(_, s, _)| *s > 1.0), "a model stack regressed");
    println!("model_stacks OK");
}
