//! Whole-model conv-stack comparison — §4's "convolutions which are
//! commonly used in popular CNN models [AlexNet][GoogLeNet][VGG][ResNet]"
//! aggregated per model: the end-to-end conv time of each network under
//! the paper's plans *and* the tuner's (PR 1) vs the cuDNN proxy, plus
//! the small-map share that drives the difference (the paper's §1
//! motivation).  Layer times are summed flat — the graph-level view
//! (pools, pads, skips, memory plan) is the `e2e_models` bench.
//!
//! Run: `cargo bench --bench model_stacks`

use pasconv::baselines::cudnn_proxy;
use pasconv::conv::suites::{alexnet, googlenet_inception3a, resnet18, small_map_fraction, vgg16};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate, GpuSpec, KernelPlan};
use pasconv::plans::{paper_plan_for, plan_for};
use pasconv::util::bench::Table;

fn stack_time(
    g: &GpuSpec,
    layers: &[ConvProblem],
    plan_fn: fn(&ConvProblem, &GpuSpec) -> KernelPlan,
) -> f64 {
    layers.iter().map(|p| simulate(g, &plan_fn(p, g)).seconds).sum()
}

fn main() {
    let g = gtx_1080ti();
    println!("== CNN model conv stacks on {} ==\n", g.name);
    let models: [(&str, Vec<ConvProblem>); 4] = [
        ("AlexNet (stride-1 convs)", alexnet()),
        ("VGG-16", vgg16()),
        ("ResNet-18", resnet18()),
        ("GoogLeNet inception(3a)", googlenet_inception3a()),
    ];
    let mut t = Table::new(&[
        "model",
        "layers",
        "maps<32",
        "paper (ms)",
        "tuned (ms)",
        "cudnn (ms)",
        "paper speedup",
        "tuned speedup",
    ]);
    let mut speedups = vec![];
    for (name, layers) in &models {
        let paper = stack_time(&g, layers, paper_plan_for);
        let tuned = stack_time(&g, layers, plan_for);
        let base = stack_time(&g, layers, cudnn_proxy::plan);
        assert!(
            tuned <= paper * (1.0 + 1e-9),
            "{name}: tuned stack {tuned} slower than paper {paper}"
        );
        speedups.push((name, base / paper, base / tuned, small_map_fraction(layers)));
        t.row(&[
            name.to_string(),
            layers.len().to_string(),
            format!("{:.0}%", 100.0 * small_map_fraction(layers)),
            format!("{:.3}", paper * 1e3),
            format!("{:.3}", tuned * 1e3),
            format!("{:.3}", base * 1e3),
            format!("{:.2}x", base / paper),
            format!("{:.2}x", base / tuned),
        ]);
    }
    t.print();

    // the paper's §1 motivation: models dominated by small maps benefit
    // the most — speedup should correlate with the small-map share
    let alex = speedups.iter().find(|(n, ..)| n.starts_with("AlexNet")).unwrap();
    let vgg = speedups.iter().find(|(n, ..)| n.starts_with("VGG")).unwrap();
    println!(
        "\nsmall-map-heavy AlexNet ({:.0}% < 32px): {:.2}x paper / {:.2}x tuned   \
         vs map-heavy VGG-16 ({:.0}%): {:.2}x paper / {:.2}x tuned",
        100.0 * alex.3,
        alex.1,
        alex.2,
        100.0 * vgg.3,
        vgg.1,
        vgg.2
    );
    assert!(speedups.iter().all(|(_, s, ..)| *s > 1.0), "a model stack regressed");
    // PR-1's tuner must show up at the model level too: every stack at
    // least as fast as paper, and visibly faster somewhere
    assert!(
        speedups.iter().any(|(_, paper_s, tuned_s, _)| *tuned_s > *paper_s * 1.01),
        "tuning invisible at model level"
    );
    println!("model_stacks OK");
}
