//! Ablation: the **§4 preliminary evaluation** that fixed M' = 64 and
//! W'x = 128 — "According to our preliminary evaluation, when M' = 64
//! and W'x = 128, the performance becomes best."
//!
//! Sweeps the (M', W'x) grid at S = 32 over a large Fig. 5 layer and
//! reports the best cell; the paper's operating point must sit in the
//! winning region.
//!
//! Run: `cargo bench --bench ablation_block_params`

use pasconv::analytic::multi::{working_set_bytes, wy_prime, StrideFixedChoice};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::stride_fixed::plan_with_choice;
use pasconv::util::bench::Table;

fn main() {
    let g = gtx_1080ti();
    let p = ConvProblem::multi(256, 224, 256, 3); // big-map Fig. 5 layer
    let s_bytes = 32;
    println!("== §3.2/§4 ablation: (M', W'x) grid at S=32, {} ==\n", p.label());

    let m_vals = [8usize, 16, 32, 64, 128, 256];
    let wx_vals = [32usize, 64, 128, 256];
    let mut t = Table::new(&["M' \\ W'x", "32", "64", "128", "256"]);
    let mut best = (f64::INFINITY, 0usize, 0usize);
    for &m in &m_vals {
        let mut row = vec![format!("{m}")];
        for &wx in &wx_vals {
            let c = StrideFixedChoice {
                s_bytes,
                wx_prime: wx,
                m_prime: m,
                wy_prime: wy_prime(s_bytes, p.k),
                smem_bytes: working_set_bytes(s_bytes, wx, m, p.k),
                hides_latency: false,
            };
            if c.smem_bytes > g.shared_mem_bytes as usize / 2 {
                row.push("(smem)".into());
                continue;
            }
            let secs = simulate(&g, &plan_with_choice(&p, &g, &c)).seconds;
            if secs < best.0 {
                best = (secs, m, wx);
            }
            row.push(format!("{:.0}µs", secs * 1e6));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "\nbest cell: M'={} W'x={} ({:.0}µs)   paper: M'=64, W'x=128 best",
        best.1,
        best.2,
        best.0 * 1e6
    );
    // the paper's point must be within 10% of the grid optimum
    let paper_choice = StrideFixedChoice {
        s_bytes,
        wx_prime: 128,
        m_prime: 64,
        wy_prime: wy_prime(s_bytes, p.k),
        smem_bytes: working_set_bytes(s_bytes, 128, 64, p.k),
        hides_latency: true,
    };
    let paper_secs = simulate(&g, &plan_with_choice(&p, &g, &paper_choice)).seconds;
    println!("paper's point: {:.0}µs ({:.1}% off the optimum)", paper_secs * 1e6,
        100.0 * (paper_secs / best.0 - 1.0));
    assert!(paper_secs <= 1.10 * best.0, "paper operating point not near-optimal");
    println!("ablation_block_params OK");
}
