//! The paper's **§1 taxonomy**, executable: direct ([1]-style), FFT [13],
//! Winograd [8] and GEMM (Implicit-GEMM [12] / cuDNN) families against
//! the paper's kernels, across representative CNN layers.
//!
//! Expected shape (all documented properties, asserted below):
//!  * FFT loses badly at K in {1,3,5} (padded filter transforms);
//!  * Winograd is the strongest competitor on large K=3 layers
//!    (2.25x multiply reduction) and weak on small ones (transform
//!    overhead);
//!  * the paper's kernels win the small-map regime its CNN workloads
//!    live in.
//!
//! Run: `cargo bench --bench algo_taxonomy`

use pasconv::baselines::{cudnn_proxy, dac17, fft_conv, winograd};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::paper_plan_for;
use pasconv::util::bench::Table;

fn main() {
    let g = gtx_1080ti();
    println!("== §1 algorithm taxonomy on {} (times in µs) ==\n", g.name);
    let layers = [
        ConvProblem::multi(64, 56, 64, 3),    // ResNet body
        ConvProblem::multi(256, 14, 256, 3),  // deep small-map layer
        ConvProblem::multi(512, 7, 512, 3),   // deepest layer
        ConvProblem::multi(128, 28, 128, 1),  // pointwise
        ConvProblem::multi(16, 28, 32, 5),    // GoogLeNet 5x5 branch
        ConvProblem::multi(96, 27, 256, 5),   // AlexNet conv2
    ];
    let mut t = Table::new(&["layer", "ours", "gemm (cudnn)", "winograd", "fft", "direct [1]"]);
    for p in &layers {
        let us = |s: f64| format!("{:.1}", s * 1e6);
        let t_ours = simulate(&g, &paper_plan_for(p, &g)).seconds;
        let t_gemm = simulate(&g, &cudnn_proxy::plan(p, &g)).seconds;
        let t_wino = if p.k == 3 {
            Some(simulate(&g, &winograd::plan(p, &g)).seconds)
        } else {
            None
        };
        let t_fft = simulate(&g, &fft_conv::plan(p, &g)).seconds;
        let t_direct = simulate(&g, &dac17::plan(p, &g)).seconds;
        t.row(&[
            p.label(),
            us(t_ours),
            us(t_gemm),
            t_wino.map(us).unwrap_or_else(|| "n/a (K!=3)".into()),
            us(t_fft),
            us(t_direct),
        ]);
        // documented shape assertions
        assert!(t_fft > t_ours, "{}: FFT should lose at small K", p.label());
        if p.wy <= 14 {
            assert!(t_ours < t_gemm, "{}: small-map regime must favour ours", p.label());
        }
    }
    t.print();

    // winograd is the credible rival on big K=3 layers
    let big = ConvProblem::multi(256, 56, 256, 3);
    let r = simulate(&g, &winograd::plan(&big, &g)).seconds
        / simulate(&g, &paper_plan_for(&big, &g)).seconds;
    println!(
        "\nwinograd / ours on {}: {:.2} (close contest on large K=3 layers, as [8] predicts)",
        big.label(),
        r
    );
    assert!(r > 0.4 && r < 2.5, "winograd balance implausible: {r}");
    println!("algo_taxonomy OK");
}
