//! Regenerates the **§4 comparison against [1]** (Chen et al., DAC'17).
//!
//! Paper: "In [1], a different GPU is used, and a direct comparison is
//! not possible. However, when K=3, our performance is 4X faster than
//! [1] on GPU the peak performance of which is 2.4X faster than that
//! used in [1]."
//!
//! Here both kernels run on the *same* simulated 1080Ti, so the expected
//! like-for-like margin is ~4 / 2.4 ≈ 1.7x, concentrated on maps < 32
//! (their fixed-assignment flaw).  The K40 peak normalization is printed
//! alongside for the paper's cross-GPU arithmetic.
//!
//! Run: `cargo bench --bench dac17_comparison`

use pasconv::baselines::dac17;
use pasconv::conv::suites::FIG5_POINTS;
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate, tesla_k40};
use pasconv::plans::paper_plan_for;
use pasconv::util::bench::Table;
use pasconv::util::stats::geomean;

fn main() {
    let g = gtx_1080ti();
    let k40 = tesla_k40();
    println!("== §4 comparison vs [1] (DAC'17), K = 3, {} ==\n", g.name);
    println!(
        "peak normalization: 1080Ti / K40 = {:.2}x (paper: 2.4x)\n",
        g.peak_flops() / k40.peak_flops()
    );

    let mut t = Table::new(&[
        "problem",
        "ours (µs)",
        "dac17 (µs)",
        "dac17 SMs",
        "same-GPU speedup",
        "paper-normalized",
    ]);
    let mut all = vec![];
    let mut small = vec![];
    let norm = g.peak_flops() / k40.peak_flops();
    for &(w, c) in &FIG5_POINTS {
        let p = ConvProblem::multi(c, w, c, 3);
        let ours = simulate(&g, &paper_plan_for(&p, &g)).seconds;
        let dac = simulate(&g, &dac17::plan(&p, &g));
        let s = dac.seconds / ours;
        all.push(s);
        if w < 32 {
            small.push(s);
        }
        t.row(&[
            p.label(),
            format!("{:.1}", ours * 1e6),
            format!("{:.1}", dac.seconds * 1e6),
            format!("{:.0}", dac.sm_utilization * g.sm_count as f64),
            format!("{s:.2}x"),
            // the paper's cross-GPU framing: our kernel on the 1080Ti vs
            // [1] on its 2.4x-slower GPU
            format!("{:.2}x", s * norm),
        ]);
    }
    t.print();
    println!(
        "\nsame-GPU geomean {:.2}x (maps < 32: {:.2}x)   paper-normalized geomean {:.2}x (paper: ~4x at K=3)",
        geomean(&all),
        geomean(&small),
        geomean(&all) * norm
    );
    assert!(geomean(&small) > geomean(&all), "small-map concentration missing");
    println!("dac17_comparison OK");
}
