//! Regenerates **Table 1** — the GTX 1080Ti access parameters.
//!
//! Two parts:
//!  * *measured*: Mei & Chu [5]-style microbenchmarks run against the
//!    simulated memory system — a dependent pointer-chase recovers the
//!    latency, a saturating stream recovers the transmission rate.  This
//!    is the self-consistency gate of DESIGN.md §3: the simulator must
//!    report back the parameters it was built from.
//!  * *derived*: the paper's §2.2 arithmetic (N_FMA, V_s, thread/warp
//!    requirements) from those parameters, pinned to the paper's values.
//!
//! Run: `cargo bench --bench table1_microbench`

use pasconv::gpusim::memory::{transfer_cycles, AccessConfig};
use pasconv::gpusim::{gtx_1080ti, titan_x_maxwell, GpuSpec};
use pasconv::util::bench::Table;

/// Pointer-chase: dependent 4-B accesses expose the raw latency (the
/// stream term is negligible at 4 B).
fn measure_latency(g: &GpuSpec) -> f64 {
    let cfg = AccessConfig {
        segment_bytes: 32,
        sms_active: 1,
        threads_per_sm: g.threads_required_per_sm() as u32, // stream term ~0
    };
    let chase_len = 1000.0;
    // each dependent access pays full latency; total / n = latency
    (0..1000)
        .map(|_| transfer_cycles(g, &cfg, 4.0))
        .sum::<f64>()
        / chase_len
}

/// Stream: slope of transfer time over volume at full occupancy gives
/// bytes per cycle.
fn measure_bytes_per_cycle(g: &GpuSpec) -> f64 {
    let cfg = AccessConfig {
        segment_bytes: 128,
        sms_active: 1,
        threads_per_sm: g.threads_required_per_sm() as u32,
    };
    let (small, large) = (1e6, 9e6);
    let dt = transfer_cycles(g, &cfg, large) - transfer_cycles(g, &cfg, small);
    (large - small) / dt
}

fn main() {
    for g in [gtx_1080ti(), titan_x_maxwell()] {
        println!("== Table 1 reproduction: {} ({}) ==", g.name, g.architecture);
        let lat = measure_latency(&g);
        let bpc = measure_bytes_per_cycle(&g);
        let mut t = Table::new(&["parameter", "measured/derived", "paper (1080Ti)"]);
        let paper = |s: &str| if g.name == "GTX 1080Ti" { s.to_string() } else { "—".into() };
        t.row(&[
            "Global Memory Latency (cycles)".into(),
            format!("{lat:.0}"),
            paper("258"),
        ]);
        t.row(&["Bandwidth (GB/s)".into(), format!("{:.0}", g.bandwidth_gb_s), paper("484")]);
        t.row(&["Base clock (MHz)".into(), format!("{:.0}", g.clock_mhz), paper("1480")]);
        t.row(&["SM".into(), g.sm_count.to_string(), paper("28")]);
        t.row(&[
            "Transmission Rate (B/cycle)".into(),
            format!("{bpc:.0}"),
            paper("327"),
        ]);
        t.row(&[
            "Data Requirement (bytes)".into(),
            g.data_requirement_bytes().to_string(),
            paper("84,366 (327x258)"),
        ]);
        t.row(&[
            "Thread Requirement/SM".into(),
            g.threads_required_per_sm().to_string(),
            paper("768"),
        ]);
        t.row(&[
            "Warp Requirement/SM".into(),
            g.warps_required_per_sm().to_string(),
            paper("24"),
        ]);
        t.row(&[
            "Data Requirement/SM (bytes)".into(),
            g.data_requirement_per_sm().to_string(),
            paper("3072"),
        ]);
        t.row(&[
            "Flops/clock cycle/core".into(),
            g.fma_per_core_cycle.to_string(),
            paper("2"),
        ]);
        t.row(&["N_FMA (derived, §2.2)".into(), g.n_fma().to_string(), paper("66,048")]);
        t.row(&["V_s (derived, §2.2)".into(), g.v_s().to_string(), paper("86,016")]);
        t.print();

        if g.name == "GTX 1080Ti" {
            // self-consistency gate: measured == configured == paper
            assert!((lat - 258.0).abs() < 1.0, "latency {lat}");
            assert!((bpc - g.bytes_per_cycle()).abs() < 2.0, "bpc {bpc}");
            assert_eq!(g.n_fma(), 66_048);
            assert_eq!(g.v_s(), 86_016);
        }
        println!();
    }
    println!("table1 OK");
}
