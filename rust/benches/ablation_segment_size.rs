//! Ablation: the **§3.2 segment-size choice** S.
//!
//! The paper's argument: S must be a multiple of 32 B (coalescing);
//! 32/64 B are "acceptable" vs 128 B and buy a larger M'; the natural
//! per-filter segment of [1] (K*K*4 B — 4 B at K=1, 36 B at K=3) causes
//! "serious performance reduction".  This bench sweeps S over the Fig. 5
//! suite and prints where each value wins.
//!
//! Run: `cargo bench --bench ablation_segment_size`

use pasconv::conv::suites::fig5_suite;
use pasconv::gpusim::memory::segment_efficiency;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::stride_fixed;
use pasconv::util::bench::Table;

fn main() {
    let g = gtx_1080ti();
    println!("== §3.2 ablation: filter segment size S ==\n");
    println!("coalescing model: eff(4)={:.2} eff(36)={:.2} eff(32)={:.2} eff(64)={:.2} eff(128)={:.2}\n",
        segment_efficiency(4), segment_efficiency(36), segment_efficiency(32),
        segment_efficiency(64), segment_efficiency(128));

    let svals = [32usize, 64, 128];
    let mut t = Table::new(&["problem", "S=32 (µs)", "S=64 (µs)", "S=128 (µs)", "best"]);
    let mut wins = [0usize; 3];
    let mut sum = [0f64; 3];
    for p in fig5_suite() {
        let times: Vec<f64> = svals
            .iter()
            .map(|&s| simulate(&g, &stride_fixed::plan_with_segment(&p, &g, s)).seconds)
            .collect();
        let best = (0..3).min_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap()).unwrap();
        wins[best] += 1;
        for i in 0..3 {
            sum[i] += times[i];
        }
        t.row(&[
            p.label(),
            format!("{:.1}", times[0] * 1e6),
            format!("{:.1}", times[1] * 1e6),
            format!("{:.1}", times[2] * 1e6),
            format!("S={}", svals[best]),
        ]);
    }
    t.print();
    println!("\nwins: S=32 x{}, S=64 x{}, S=128 x{}", wins[0], wins[1], wins[2]);
    println!(
        "total suite time: S=32 {:.0}µs, S=64 {:.0}µs, S=128 {:.0}µs",
        sum[0] * 1e6,
        sum[1] * 1e6,
        sum[2] * 1e6
    );
    println!("paper: S in {{32, 64}} used; 128 trades M' down (and 36/4-B segments of [1] are ruinous)");
    // the paper's operating points must cover the suite well: the best
    // S∈{32,64} total within ~15% of the best-of-all
    let best_3264 = sum[0].min(sum[1]);
    let best_all = sum.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(best_3264 <= 1.15 * best_all, "S in {{32,64}} not competitive");
    println!("ablation_segment_size OK");
}
