//! Regenerates **Figure 5** — multi-channel convolution performance vs
//! cuDNN v7.1 on the GTX 1080Ti (simulated substrate, DESIGN.md §3).
//!
//! Paper claims: "our method is faster than Cudnn in all tested cases,
//! and the throughput has been increased by 1.05X to 2X, with an average
//! increase of 1.39X" (M' = 64, W'x = 128, S in {32, 64}).
//!
//! Run: `cargo bench --bench fig5_multi_channel`

use pasconv::baselines::cudnn_proxy;
use pasconv::conv::suites::{FIG5_POINTS, PAPER_KS};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::paper_plan_for;
use pasconv::util::bench::Table;
use pasconv::util::stats::geomean;

fn main() {
    let g = gtx_1080ti();
    println!("== Figure 5: multi-channel convolution, {} ==\n", g.name);
    let mut all = vec![];
    for &k in &PAPER_KS {
        println!("-- K = {k} --");
        let mut t = Table::new(&[
            "map",
            "C=M",
            "plan",
            "ours (µs)",
            "cudnn (µs)",
            "ours GFLOP/s",
            "speedup",
        ]);
        for &(w, c) in &FIG5_POINTS {
            let p = ConvProblem::multi(c, w, c, k);
            let plan = paper_plan_for(&p, &g);
            let ours = simulate(&g, &plan);
            let base = simulate(&g, &cudnn_proxy::plan(&p, &g));
            let s = base.seconds / ours.seconds;
            all.push(s);
            t.row(&[
                w.to_string(),
                c.to_string(),
                plan.name.clone(),
                format!("{:.1}", ours.seconds * 1e6),
                format!("{:.1}", base.seconds * 1e6),
                format!("{:.0}", ours.gflops),
                format!("{s:.2}x"),
            ]);
        }
        t.print();
        println!();
    }
    let (min, max) = (
        all.iter().cloned().fold(f64::INFINITY, f64::min),
        all.iter().cloned().fold(0.0, f64::max),
    );
    println!(
        "speedup range {:.2}x .. {:.2}x   mean {:.2}x   geomean {:.2}x",
        min,
        max,
        all.iter().sum::<f64>() / all.len() as f64,
        geomean(&all)
    );
    println!("paper:        1.05x .. 2x     average 1.39x");
    assert!(min > 1.0, "must win everywhere (paper claim)");
}
