//! End-to-end model execution through the graph layer — replaces the
//! flat per-layer summation with real network structure: topological
//! scheduling, pool/pad/add/concat glue, and the liveness-based arena
//! memory plan.  For each §4 model it reports paper-plan vs tuned-plan
//! end-to-end latency, the conv/glue split, and peak arena memory vs
//! the naive keep-everything footprint.
//!
//! Run: `cargo bench --bench e2e_models`
//! CI check mode (asserts only, summary table): append `-- --check`.

use pasconv::graph::{execute, fuse, model_graph, ModelReport, MODEL_NAMES};
use pasconv::gpusim::gtx_1080ti;
use pasconv::plans::{op_plan_for, paper_op_plan_for};
use pasconv::util::bench::{fmt_mib, Table};
use pasconv::util::cli::Args;

fn main() {
    let args = Args::parse();
    let check_only = args.has("check");
    let g = gtx_1080ti();
    println!("== end-to-end model graphs on {} ==\n", g.name);

    let mut t = Table::new(&[
        "model",
        "nodes",
        "convs",
        "paper (ms)",
        "tuned (ms)",
        "tuning",
        "glue share",
        "arena (MiB)",
        "naive (MiB)",
        "saved",
    ]);
    let mut reports: Vec<(&str, ModelReport, ModelReport)> = vec![];
    for name in MODEL_NAMES {
        let graph = model_graph(name).expect("model builds");
        let paper = execute(&graph, &g, paper_op_plan_for);
        let tuned = execute(&graph, &g, op_plan_for);
        t.row(&[
            name.to_string(),
            tuned.nodes.len().to_string(),
            tuned.conv_layers.to_string(),
            format!("{:.3}", paper.total_seconds * 1e3),
            format!("{:.3}", tuned.total_seconds * 1e3),
            format!("{:.2}x", paper.total_seconds / tuned.total_seconds),
            format!("{:.0}%", 100.0 * tuned.glue_seconds / tuned.total_seconds),
            fmt_mib(tuned.arena.peak_bytes),
            fmt_mib(tuned.arena.naive_bytes),
            format!("{:.0}%", 100.0 * tuned.arena.saved_fraction()),
        ]);
        reports.push((name, paper, tuned));
    }
    t.print();

    // ---- the gates CI runs this bench for ----
    for (name, paper, tuned) in &reports {
        assert!(
            tuned.total_seconds <= paper.total_seconds * (1.0 + 1e-9),
            "{name}: tuned graph slower than paper graph"
        );
        assert!(
            tuned.arena.peak_bytes <= tuned.arena.naive_bytes,
            "{name}: arena exceeds naive sum"
        );
        // conv kernels carry a substantial share everywhere; on the
        // model bodies they dominate outright.  The inception *cell* is
        // the honest exception: six small convs against a 3x3/s1 pool +
        // concat leave glue a large share (see EXPERIMENTS.md §7)
        assert!(
            tuned.conv_seconds > 0.25 * tuned.total_seconds,
            "{name}: convs vanished ({})",
            tuned.summary()
        );
        if *name != "inception3a" {
            assert!(
                tuned.conv_seconds > tuned.glue_seconds,
                "{name}: glue dominates ({})",
                tuned.summary()
            );
        }
        // (per-node plan identity vs standalone `plans::plan_for` is
        // gated by rust/tests/integration_graph.rs, not re-checked here)
    }
    // branch/skip-structured models must show real memory wins
    for name in ["resnet18", "inception3a", "mobilenet_v1"] {
        let (_, _, tuned) = reports.iter().find(|(n, ..)| *n == name).unwrap();
        assert!(
            tuned.arena.peak_bytes < tuned.arena.naive_bytes,
            "{name}: no arena savings"
        );
    }
    // epilogue fusion + zero-copy concat: never loses end to end, and
    // the inception cell — the glue-dominated outlier above — sheds at
    // least 2x of its glue seconds (EXPERIMENTS §14)
    for (name, _, tuned) in &reports {
        let graph = model_graph(name).expect("model builds");
        let (fgraph, rep) = fuse(&graph, &g, op_plan_for);
        let fused = execute(&fgraph, &g, op_plan_for);
        assert!(rep.nodes_fused > 0, "{name}: nothing fused");
        assert!(
            fused.total_seconds <= tuned.total_seconds * (1.0 + 1e-9),
            "{name}: fused graph slower than unfused"
        );
        assert!(
            fused.glue_seconds <= tuned.glue_seconds,
            "{name}: fusion grew the glue"
        );
        if *name == "inception3a" {
            assert!(
                tuned.glue_seconds >= 2.0 * fused.glue_seconds,
                "{name}: glue {:.1}µs -> {:.1}µs is under the 2x §14 gate",
                tuned.glue_seconds * 1e6,
                fused.glue_seconds * 1e6
            );
        }
    }

    if !check_only {
        for (_, _, tuned) in &reports {
            println!("\n{}", tuned.summary());
        }
    }
    println!("\ne2e_models OK");
}
