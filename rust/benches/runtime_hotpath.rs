//! Real-numerics hot path: PJRT CPU execution latency of every AOT'd
//! conv artifact (the serve path's compute), plus the overhead split
//! (literal construction vs execution).  This is the L3 §Perf baseline
//! of EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench runtime_hotpath`

use std::time::Instant;

use pasconv::runtime::{default_artifact_dir, ArtifactKind, Runtime, Tensor};
use pasconv::util::bench::{fmt_time, Table};
use pasconv::util::rng::Rng;
use pasconv::util::stats::Summary;

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts`");
        std::process::exit(1);
    }
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(0xB16B);
    let iters = 30;

    println!("== PJRT hot path: conv artifacts ({} iters each) ==\n", iters);
    let mut t = Table::new(&["artifact", "GFLOP", "p50", "p95", "GFLOP/s @p50"]);
    for kind in [ArtifactKind::ConvSingle, ArtifactKind::ConvMulti, ArtifactKind::ConvIm2col] {
        let names: Vec<String> =
            rt.artifacts_of_kind(kind).iter().map(|a| a.name.clone()).collect();
        for name in names {
            let p = rt.artifact(&name).unwrap().problem().unwrap();
            let (img, flt) = if kind == ArtifactKind::ConvSingle {
                (
                    Tensor::randn(vec![p.wy, p.wx], &mut rng),
                    Tensor::randn(vec![p.m, p.k, p.k], &mut rng),
                )
            } else {
                (
                    Tensor::randn(vec![p.c, p.wy, p.wx], &mut rng),
                    Tensor::randn(vec![p.m, p.c, p.k, p.k], &mut rng),
                )
            };
            rt.execute_conv(&name, &img, &flt).unwrap(); // warm + compile
            let mut samples = vec![];
            for _ in 0..iters {
                let t0 = Instant::now();
                let _ = rt.execute_conv(&name, &img, &flt).unwrap();
                samples.push(t0.elapsed().as_secs_f64());
            }
            let s = Summary::of(&samples);
            let gflop = p.flops() as f64 / 1e9;
            t.row(&[
                name.clone(),
                format!("{gflop:.4}"),
                fmt_time(s.p50),
                fmt_time(s.p95),
                format!("{:.2}", gflop / s.p50),
            ]);
        }
    }
    t.print();

    // overhead split on one artifact: literal build vs execute
    println!("\n== overhead split (multi_c32_w14_m32_k3) ==");
    let p = rt.artifact("multi_c32_w14_m32_k3").unwrap().problem().unwrap();
    let img = Tensor::randn(vec![p.c, p.wy, p.wx], &mut rng);
    let flt = Tensor::randn(vec![p.m, p.c, p.k, p.k], &mut rng);
    rt.execute_conv("multi_c32_w14_m32_k3", &img, &flt).unwrap();
    let mut lit = vec![];
    for _ in 0..200 {
        let t0 = Instant::now();
        let a = xla::Literal::vec1(&img.data).reshape(&img.dims_i64()).unwrap();
        let b = xla::Literal::vec1(&flt.data).reshape(&flt.dims_i64()).unwrap();
        std::hint::black_box((a, b));
        lit.push(t0.elapsed().as_secs_f64());
    }
    let mut full = vec![];
    for _ in 0..200 {
        let t0 = Instant::now();
        let _ = rt.execute_conv("multi_c32_w14_m32_k3", &img, &flt).unwrap();
        full.push(t0.elapsed().as_secs_f64());
    }
    let (ls, fs) = (Summary::of(&lit), Summary::of(&full));
    println!(
        "literal build p50 {}   end-to-end p50 {}   literal share {:.0}%",
        fmt_time(ls.p50),
        fmt_time(fs.p50),
        100.0 * ls.p50 / fs.p50
    );
    println!("\nruntime_hotpath OK");
}
