//! End-to-end serving bench: coordinator throughput and latency under
//! synthetic PaperNet load, across batch windows — the L3 §Perf
//! experiment of EXPERIMENTS.md (batching policy / queueing).
//!
//! Run: `cargo bench --bench e2e_serving`

use std::time::{Duration, Instant};

use pasconv::coordinator::{BatchConfig, Coordinator, Payload};
use pasconv::runtime::{default_artifact_dir, Tensor};
use pasconv::util::bench::Table;
use pasconv::util::rng::Rng;
use pasconv::util::stats::Summary;

fn run(n: usize, cfg: BatchConfig) -> (f64, Summary, f64) {
    let mut coord = Coordinator::start(&default_artifact_dir(), cfg).unwrap();
    let mut rng = Rng::new(0xE2E);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| coord.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }))
        .collect();
    let lats: Vec<f64> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap().latency_secs)
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    let mbs = coord.metrics().mean_batch_size();
    coord.shutdown();
    (n as f64 / wall, Summary::of(&lats), mbs)
}

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts`");
        std::process::exit(1);
    }
    let n = 512;
    println!("== e2e serving: {n} PaperNet requests per config ==\n");
    let mut t = Table::new(&["max_batch", "window", "req/s", "p50 lat", "p99 lat", "mean batch"]);
    let mut unbatched_tput = 0.0;
    let mut best_batched_tput: f64 = 0.0;
    for (mb, win_us) in [(1usize, 0u64), (4, 1_000), (8, 1_000), (8, 2_000), (8, 5_000)] {
        let (tput, s, mbs) =
            run(n, BatchConfig { max_batch: mb, max_wait: Duration::from_micros(win_us) });
        if mb == 1 {
            unbatched_tput = tput;
        } else {
            best_batched_tput = best_batched_tput.max(tput);
        }
        t.row(&[
            mb.to_string(),
            format!("{:.1}ms", win_us as f64 / 1000.0),
            format!("{tput:.0}"),
            format!("{:.2}ms", s.p50 * 1e3),
            format!("{:.2}ms", s.p99 * 1e3),
            format!("{mbs:.2}"),
        ]);
    }
    t.print();
    println!(
        "\nbatching speedup (best batched / unbatched): {:.2}x",
        best_batched_tput / unbatched_tput
    );
    assert!(
        best_batched_tput > unbatched_tput,
        "dynamic batching must improve throughput"
    );
    println!("e2e_serving OK");
}
