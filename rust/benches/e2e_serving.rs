//! End-to-end serving bench: coordinator throughput and latency under
//! synthetic PaperNet load, across batch windows — the L3 §Perf
//! experiment of EXPERIMENTS.md (batching policy / queueing) — plus a
//! mixed-traffic section where `Mix::conv_burst` emits identical
//! back-to-back conv templates so the queue thread's same-problem
//! coalescer actually gets compatible neighbors to merge.
//!
//! Run: `cargo bench --bench e2e_serving`

use std::time::{Duration, Instant};

use pasconv::coordinator::{Arrivals, BatchConfig, Coordinator, Mix, Payload, Workload};
use pasconv::runtime::{default_artifact_dir, ArtifactKind, Runtime, Tensor};
use pasconv::util::bench::Table;
use pasconv::util::rng::Rng;
use pasconv::util::stats::Summary;

fn run(n: usize, cfg: BatchConfig) -> (f64, Summary, f64) {
    let mut coord = Coordinator::start(&default_artifact_dir(), cfg).unwrap();
    let mut rng = Rng::new(0xE2E);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| coord.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }))
        .collect();
    let lats: Vec<f64> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap().latency_secs)
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    let mbs = coord.metrics().mean_batch_size();
    coord.shutdown();
    (n as f64 / wall, Summary::of(&lats), mbs)
}

/// Mixed conv+CNN traffic through `Workload` with a conv burst length;
/// returns (mean conv micro-batch size, conv batches executed).  Note
/// the rows differ in realized conv share, not just clustering: bursts
/// multiply each conv trigger (see `Mix::conv_fraction` docs), which is
/// fine here — the section reports coalescing behavior, not a
/// fixed-mix throughput comparison.
fn run_mixed(n: usize, conv_burst: usize, cfg: BatchConfig) -> (f64, u64) {
    let dir = default_artifact_dir();
    let rt = Runtime::new(&dir).unwrap();
    let mut templates = vec![];
    for kind in [ArtifactKind::ConvSingle, ArtifactKind::ConvMulti] {
        for a in rt.artifacts_of_kind(kind) {
            templates.push(pasconv::conv::ConvOp::dense(a.problem().unwrap()));
        }
    }
    drop(rt);
    let mut coord = Coordinator::start(&dir, cfg).unwrap();
    let mut w = Workload::new(
        Arrivals::Burst,
        Mix { conv_fraction: 0.5, conv_burst },
        templates,
        0xC0A1,
    );
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let (payload, gap) = w.next();
            std::thread::sleep(gap); // Burst: zero
            coord.submit(payload)
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = coord.metrics();
    coord.shutdown();
    (m.mean_conv_batch_size(), m.conv_batches_executed)
}

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts`");
        std::process::exit(1);
    }
    let n = 512;
    println!("== e2e serving: {n} PaperNet requests per config ==\n");
    let mut t = Table::new(&["max_batch", "window", "req/s", "p50 lat", "p99 lat", "mean batch"]);
    let mut unbatched_tput = 0.0;
    let mut best_batched_tput: f64 = 0.0;
    for (mb, win_us) in [(1usize, 0u64), (4, 1_000), (8, 1_000), (8, 2_000), (8, 5_000)] {
        let (tput, s, mbs) =
            run(n, BatchConfig { max_batch: mb, max_wait: Duration::from_micros(win_us) });
        if mb == 1 {
            unbatched_tput = tput;
        } else {
            best_batched_tput = best_batched_tput.max(tput);
        }
        t.row(&[
            mb.to_string(),
            format!("{:.1}ms", win_us as f64 / 1000.0),
            format!("{tput:.0}"),
            format!("{:.2}ms", s.p50 * 1e3),
            format!("{:.2}ms", s.p99 * 1e3),
            format!("{mbs:.2}"),
        ]);
    }
    t.print();
    println!(
        "\nbatching speedup (best batched / unbatched): {:.2}x",
        best_batched_tput / unbatched_tput
    );
    assert!(
        best_batched_tput > unbatched_tput,
        "dynamic batching must improve throughput"
    );

    // ---- conv micro-batch coalescing under correlated traffic ----
    println!("\n== conv coalescing: 256 mixed requests, window 2ms ==\n");
    let cfg = BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) };
    let mut ct = Table::new(&["conv_burst", "conv batches", "mean conv batch"]);
    let mut coalesced_mean = 0.0;
    for burst in [1usize, 4] {
        let (mean, batches) = run_mixed(256, burst, cfg);
        if burst > 1 {
            coalesced_mean = mean;
        }
        ct.row(&[burst.to_string(), batches.to_string(), format!("{mean:.2}")]);
    }
    ct.print();
    assert!(
        coalesced_mean > 1.0,
        "bursty compatible traffic must coalesce (mean conv batch {coalesced_mean:.2})"
    );
    println!("\ne2e_serving OK");
}
