//! Regenerates **Figure 4** — single-channel convolution performance vs
//! cuDNN v7.1 on the GTX 1080Ti (simulated substrate, DESIGN.md §3).
//!
//! Paper claims: "Our method is faster than Cudnn v7.1 in all tested
//! cases. The performance gain is 1.5X to 5.6X, and its average is 2.6X."
//!
//! Run: `cargo bench --bench fig4_single_channel`

use pasconv::baselines::cudnn_proxy;
use pasconv::conv::suites::{FIG4_POINTS, PAPER_KS};
use pasconv::conv::ConvProblem;
use pasconv::gpusim::{gtx_1080ti, simulate};
use pasconv::plans::paper_plan_for;
use pasconv::util::bench::Table;
use pasconv::util::stats::geomean;

fn main() {
    let g = gtx_1080ti();
    println!("== Figure 4: single-channel convolution, {} ==\n", g.name);
    let mut all = vec![];
    for &k in &PAPER_KS {
        println!("-- K = {k} --");
        let mut t =
            Table::new(&["map", "M", "ours (µs)", "cudnn (µs)", "ours GFLOP/s", "speedup"]);
        for &(w, m) in &FIG4_POINTS {
            let p = ConvProblem::single(w, m, k);
            let ours = simulate(&g, &paper_plan_for(&p, &g));
            let base = simulate(&g, &cudnn_proxy::plan(&p, &g));
            let s = base.seconds / ours.seconds;
            all.push(s);
            t.row(&[
                w.to_string(),
                m.to_string(),
                format!("{:.1}", ours.seconds * 1e6),
                format!("{:.1}", base.seconds * 1e6),
                format!("{:.0}", ours.gflops),
                format!("{s:.2}x"),
            ]);
        }
        t.print();
        println!();
    }
    let (min, max) = (
        all.iter().cloned().fold(f64::INFINITY, f64::min),
        all.iter().cloned().fold(0.0, f64::max),
    );
    println!(
        "speedup range {:.2}x .. {:.2}x   mean {:.2}x   geomean {:.2}x",
        min,
        max,
        all.iter().sum::<f64>() / all.len() as f64,
        geomean(&all)
    );
    println!("paper:        1.5x .. 5.6x    average 2.6x");
    assert!(min > 1.0, "must win everywhere (paper claim)");
}
