//! Offline stand-in for the `anyhow` crate (the vendor registry of this
//! repo has no network access — see rust/vendor/README.md).
//!
//! Implements exactly the surface pasconv uses: `Error` (a message plus
//! an optional source chain), `Result<T>`, the `anyhow!` / `bail!`
//! macros, and the `Context` extension trait on `Result` and `Option`.
//! `{}` prints the outermost message, `{:#}` the whole chain, matching
//! the real crate's formatting contract.

use std::fmt;

/// Error: an owned message with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` under a new context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.source;
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // mirrors anyhow's Debug: message plus a caused-by list
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Blanket conversion from any standard error, so `?` lifts library
/// errors into `anyhow::Error` (the real crate's behaviour). `Error`
/// itself deliberately does not implement `std::error::Error`, which is
/// what keeps this impl coherent with `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!(...)`: build an `Error` from a format string or a value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `bail!(...)`: early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("boom".into());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: boom");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "absent").unwrap_err().to_string(), "absent");
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        let name = "x";
        assert_eq!(anyhow!("inline {name}").to_string(), "inline x");
        assert_eq!(anyhow!("args {}", 3).to_string(), "args 3");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }
}
