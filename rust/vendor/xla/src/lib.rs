//! Offline stub of the `xla` PJRT bindings (the real crate links libxla,
//! which is not available in this vendor set — see rust/vendor/README.md).
//!
//! The stub keeps the exact API surface `pasconv::runtime` compiles
//! against: client construction, HLO text loading and compilation all
//! succeed (so manifests parse and the executable cache works), but
//! `execute` returns a descriptive error.  Every runtime integration
//! test and bench gates on the artifact directory existing, so with the
//! stub in place `cargo test` stays green; swap the real bindings in via
//! the `[patch]` section of Cargo.toml when libxla is present.

use std::fmt;

/// Error type of the bindings (a plain message in the stub).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const OFFLINE: &str =
    "offline stub cannot execute HLO (rebuild with the real xla bindings)";

/// PJRT client handle.
pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    /// CPU plugin client. Succeeds in the stub so startup paths work.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu (offline stub)".to_string() })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {})
    }
}

/// Parsed HLO module (the stub stores the text only).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Reads the HLO text file; fails only on I/O errors so missing or
    /// unreadable artifacts surface exactly as with the real bindings.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper.
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execution is unavailable offline.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(OFFLINE.to_string()))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(OFFLINE.to_string()))
    }
}

/// Host literal (shape + f32 payload in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
}

impl Literal {
    /// Rank-1 literal over an f32 slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    /// Reshape to `dims`; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {:?} wants {} elements, literal has {}",
                dims,
                n,
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Unwrap a 1-tuple result (never produced by the stub).
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error(OFFLINE.to_string()))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }
}

/// Conversion target of `Literal::to_vec`.
pub trait FromF32 {
    fn from_f32(x: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

/// Array shape (dims only).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_and_compile_succeed() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        let comp = XlaComputation {};
        assert!(c.compile(&comp).is_ok());
    }

    #[test]
    fn literal_roundtrip_and_reshape_check() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn execute_is_a_clean_offline_error() {
        let exe = PjRtLoadedExecutable {};
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
