//! §3.2 — stride-fixed block parameter selection for the multi-channel
//! kernel: pick (S, W'x, M') so that global-memory access stays
//! coalesced, FMA/loaded-byte exceeds the latency-hiding threshold, and
//! the double-buffered working set fits half the shared memory.

use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::GpuSpec;

/// A chosen stride-fixed block configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StrideFixedChoice {
    /// filter segment size along ch, bytes (32 or 64 in the paper)
    pub s_bytes: usize,
    /// feature-map strip width in pixels (W'x; multiple of 32 px = 128 B)
    pub wx_prime: usize,
    /// filters applied in parallel per SM (M')
    pub m_prime: usize,
    /// feature-map lines needed per segment: W'y = ceil(S / (K*4))
    pub wy_prime: usize,
    /// double-buffered working set, bytes (must be <= S_shared / 2)
    pub smem_bytes: usize,
    /// whether the §3.2(3) M' >= N_FMA*4/(S*W'x) requirement is met
    pub hides_latency: bool,
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// W'y of §3.2: lines of the feature map one S-byte filter segment needs.
pub fn wy_prime(s_bytes: usize, k: usize) -> usize {
    ceil_div(s_bytes, k * BYTES_F32)
}

/// §3.2(3): minimum M' for latency hiding given S and W'x.
pub fn m_prime_min(spec: &GpuSpec, s_bytes: usize, wx_prime: usize) -> usize {
    ceil_div(spec.n_fma() as usize * BYTES_F32, s_bytes * wx_prime)
}

/// One pipeline-stage buffer for (S, W'x, M'): S x M' filter bytes plus
/// W'y lines x W'x pixels of map.  The classic §3.2(4) double buffer is
/// two of these; an s-stage pipeline keeps s resident.
pub fn stage_bytes_multi(s_bytes: usize, wx_prime: usize, m_prime: usize, k: usize) -> usize {
    s_bytes * m_prime + wy_prime(s_bytes, k) * wx_prime * BYTES_F32
}

/// §3.2(4): the double-buffer working set for (S, W'x, M').
pub fn working_set_bytes(s_bytes: usize, wx_prime: usize, m_prime: usize, k: usize) -> usize {
    // two buffers resident (current + prefetch)
    2 * stage_bytes_multi(s_bytes, wx_prime, m_prime, k)
}

/// Working set of an s-stage pipeline (§3.2(4) generalized): s stage
/// buffers resident at once.  `staged_working_set_bytes(.., 2)` is
/// exactly `working_set_bytes`.
pub fn staged_working_set_bytes(
    s_bytes: usize,
    wx_prime: usize,
    m_prime: usize,
    k: usize,
    stages: u32,
) -> usize {
    stages as usize * stage_bytes_multi(s_bytes, wx_prime, m_prime, k)
}

/// Latency-hiding FMA threshold for an s-stage pipeline: with s-1 loads
/// in flight the per-round compute only needs to cover 1/(s-1) of the
/// memory latency, so the §3.2(3) N_FMA requirement divides by (s-1).
pub fn n_fma_required(spec: &GpuSpec, stages: u32) -> f64 {
    spec.n_fma() as f64 / (stages.saturating_sub(1).max(1)) as f64
}

/// Choose (S, W'x, M') for a problem following §3.2 steps 1–4.
///
/// S comes from the caller (32 or 64; the ablation bench sweeps it);
/// W'x defaults to the paper's best 128 px but shrinks to the map width
/// for small maps; M' is the smallest value satisfying §3.2(3) that
/// still fits §3.2(4), preferring divisors of M, clamped to M.
pub fn choose(p: &ConvProblem, spec: &GpuSpec, s_bytes: usize) -> StrideFixedChoice {
    assert!(p.valid(), "invalid problem");
    assert!(s_bytes % 32 == 0, "S must be a multiple of 32 bytes (§3.2 step 1)");

    // Step 2: W'x — multiple of 128 B = 32 px; paper's preliminary best
    // is 128 px. The feature map is stored contiguously, so a strip may
    // span rows on small maps (that is what makes W'x = 128 achievable
    // for W = 7..112); when a whole channel map fits a 256-px strip the
    // kernel takes it in one fetch.
    let out_px = p.oy() * p.ox();
    let map_px = ceil_div(out_px, 32) * 32;
    let wx_prime = if map_px <= 256 { map_px } else { 128 };

    // Step 3: M' from the FMA requirement.
    let mut m_prime = m_prime_min(spec, s_bytes, wx_prime).max(1);
    // prefer the next divisor-of-M at or above the minimum (whole groups)
    if m_prime <= p.m {
        while p.m % m_prime != 0 {
            m_prime += 1;
        }
    } else {
        m_prime = p.m; // fewer filters than the minimum: use them all
    }

    // Step 4: shrink M' (then W'x) until the double-buffer fits S_shared/2.
    let half = spec.shared_mem_bytes as usize / 2;
    let mut wx_eff = wx_prime;
    while working_set_bytes(s_bytes, wx_eff, m_prime, p.k) > half && m_prime > 1 {
        m_prime = (1..=m_prime - 1).rev().find(|d| p.m % d == 0).unwrap_or(1);
    }
    while working_set_bytes(s_bytes, wx_eff, m_prime, p.k) > half && wx_eff > 32 {
        wx_eff -= 32;
    }

    // Occupancy: the grid is (M/M') filter groups x output strips; on
    // small maps (few strips) a large M' leaves SMs idle — reduce M'
    // over divisors of M until every SM has a block (the same "adapt the
    // division to the input size" fix the paper applies against [1]).
    let strips = ceil_div(out_px, wx_eff).max(1);
    while m_prime > 1 && ceil_div(p.m, m_prime) * strips < spec.sm_count as usize {
        let next = (1..m_prime).rev().find(|d| p.m % d == 0).unwrap_or(1);
        if next == m_prime {
            break;
        }
        m_prime = next;
    }

    // §3.2(3) with the paper's own rounding tolerance: their chosen
    // operating point (S=32, W'x=128, M'=64) sits at 64*8*128 = 65,536
    // FMA/round vs N_FMA = 66,048 — they round 64.5 down to the
    // warp-friendly 64, i.e. accept ~95% coverage.
    let round_fma = (m_prime * (s_bytes / BYTES_F32) * wx_eff) as f64;
    let hides = round_fma >= 0.95 * spec.n_fma() as f64;
    StrideFixedChoice {
        s_bytes,
        wx_prime: wx_eff,
        m_prime,
        wy_prime: wy_prime(s_bytes, p.k),
        smem_bytes: working_set_bytes(s_bytes, wx_eff, m_prime, p.k),
        hides_latency: hides,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::fig5_suite;
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn paper_operating_point_m64_wx128() {
        // §4: "when M' = 64 and W'x = 128, the performance becomes best"
        // §3.2(3) with S=32, W'x=128: M' >= 66048*4/(32*128) = 64.5 -> 65;
        // the paper rounds to its warp-friendly 64 — our divisor search
        // lands on the nearest divisor >= the bound for M >= 65, and the
        // bound itself confirms the paper's arithmetic.
        let g = gtx_1080ti();
        assert_eq!(m_prime_min(&g, 32, 128), 65); // ceil(66048*4 / 4096)
        let p = ConvProblem::multi(256, 224, 256, 3);
        let c = choose(&p, &g, 32);
        assert_eq!(c.wx_prime, 128);
        assert!(c.m_prime >= 64 && c.m_prime <= 128, "M'={}", c.m_prime);
        assert!(c.hides_latency);
    }

    #[test]
    fn working_set_respects_half_shared_memory() {
        let g = gtx_1080ti();
        for p in fig5_suite() {
            for s in [32, 64] {
                let c = choose(&p, &g, s);
                assert!(
                    c.smem_bytes <= g.shared_mem_bytes as usize / 2,
                    "{} S={}: {} B",
                    p.label(),
                    s,
                    c.smem_bytes
                );
            }
        }
    }

    #[test]
    fn staged_working_set_generalizes_double_buffer() {
        // stages=2 is the classic §3.2(4) working set; each extra stage
        // adds exactly one stage buffer.
        for (s, wx, mp, k) in [(32, 128, 64, 3), (64, 96, 32, 5), (32, 224, 16, 1)] {
            let stage = stage_bytes_multi(s, wx, mp, k);
            assert_eq!(staged_working_set_bytes(s, wx, mp, k, 2), working_set_bytes(s, wx, mp, k));
            assert_eq!(staged_working_set_bytes(s, wx, mp, k, 2), 2 * stage);
            assert_eq!(staged_working_set_bytes(s, wx, mp, k, 4), 4 * stage);
        }
    }

    #[test]
    fn deeper_pipelines_relax_the_fma_threshold() {
        // Th >= N_FMA / (s-1): depth 3 halves the requirement, depth 4
        // cuts it to a third; depth 2 is the paper's original bound.
        let g = gtx_1080ti();
        let n = g.n_fma() as f64;
        assert_eq!(n_fma_required(&g, 2), n);
        assert_eq!(n_fma_required(&g, 3), n / 2.0);
        assert_eq!(n_fma_required(&g, 4), n / 3.0);
    }

    #[test]
    fn wy_prime_formula() {
        // §3.2: W'y = ceil(S / (K*4))
        assert_eq!(wy_prime(32, 1), 8);
        assert_eq!(wy_prime(32, 3), 3);
        assert_eq!(wy_prime(64, 3), 6);
        assert_eq!(wy_prime(32, 5), 2);
    }

    #[test]
    fn small_maps_shrink_wx_prime() {
        // 7x7/K=3 -> 25 output px: the strip covers the whole output,
        // rounded up to a 32-px (128 B) fetch.
        let g = gtx_1080ti();
        let p = ConvProblem::multi(512, 7, 512, 3);
        let c = choose(&p, &g, 32);
        assert_eq!(c.wx_prime, 32);
        // 14x14/K=1 -> 196 px fits a single 224-px strip
        let c2 = choose(&ConvProblem::multi(256, 14, 256, 1), &g, 32);
        assert_eq!(c2.wx_prime, 224);
        // large maps use the paper's 128-px strip
        let c3 = choose(&ConvProblem::multi(64, 112, 64, 3), &g, 32);
        assert_eq!(c3.wx_prime, 128);
    }

    #[test]
    fn larger_s_allows_smaller_m_prime() {
        // §3.2 step 1: "Small S allows larger M'" — conversely the S=64
        // minimum M' is half the S=32 one.
        let g = gtx_1080ti();
        assert_eq!(m_prime_min(&g, 64, 128), ceil_div(m_prime_min(&g, 32, 128), 2));
    }

    #[test]
    fn m_prime_divides_m_when_feasible() {
        let g = gtx_1080ti();
        for p in fig5_suite() {
            let c = choose(&p, &g, 32);
            assert!(
                p.m % c.m_prime == 0 || c.m_prime == p.m,
                "{}: M'={} M={}",
                p.label(),
                c.m_prime,
                p.m
            );
        }
    }

    #[test]
    #[should_panic(expected = "multiple of 32")]
    fn rejects_non_multiple_s() {
        let g = gtx_1080ti();
        choose(&ConvProblem::multi(64, 14, 64, 3), &g, 36);
    }

    #[test]
    fn latency_hiding_holds_for_compute_rich_fig5() {
        // §3: multi-channel "has enough work" — true whenever the
        // problem's arithmetic intensity clears the machine balance
        // (FMA per DRAM byte the chip can absorb). The K=1 smallest-map
        // cases sit below the balance and are inherently memory-bound on
        // *any* schedule; the occupancy rule rightly trades M' down there.
        let g = gtx_1080ti();
        let balance =
            g.fma_per_sm_cycle() as f64 * g.sm_count as f64 / g.bytes_per_cycle();
        let mut checked = 0;
        for p in fig5_suite() {
            // skip memory-bound problems and those where the occupancy
            // rule must trade M' below the latency-hiding bound
            let strips = (p.oy() * p.ox() + 127) / 128;
            let occupancy_bound = (p.m + 63) / 64 * strips < g.sm_count as usize;
            if p.arithmetic_intensity() < 4.0 * balance || occupancy_bound {
                continue;
            }
            for s in [32, 64] {
                let c = choose(&p, &g, s);
                assert!(c.hides_latency, "{} S={}", p.label(), s);
            }
            checked += 1;
        }
        assert!(checked >= 5, "only {checked} compute-rich cases");
    }
}
