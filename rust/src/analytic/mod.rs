//! The paper's closed-form performance model: §2.2 strategy selection
//! (`occupancy`), §3.1 single-channel P/Q procedure (`single`), §3.2
//! stride-fixed block parameters (`multi`).  `plans` consumes these to
//! build the per-SM schedules the simulator times.

pub mod multi;
pub mod occupancy;
pub mod single;

pub use multi::{choose as choose_stride_fixed, StrideFixedChoice};
pub use occupancy::{paper_launch, strategy_for, LaunchGeometry, Strategy};
pub use single::{choose as choose_single, SingleChoice, SingleMethod};
