//! §2.2 — the two latency-hiding strategies and the decision rule
//! between them, plus the launch geometry the paper fixes in §4
//! (2 blocks/SM x 512 threads, <=128 registers/thread).

use crate::gpusim::GpuSpec;

/// Which §2.2 strategy a kernel uses to survive the global-memory latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// approach 1: >= N_FMA operations per round, latency hidden by
    /// double-buffered prefetch
    Prefetch,
    /// approach 2: transfer >= V_s bytes continuously to keep the bus busy
    Volume,
}

/// §2.2 decision: prefetch if the per-round FMA count covers N_FMA.
pub fn strategy_for(spec: &GpuSpec, fma_per_round: u64) -> Strategy {
    if fma_per_round >= spec.n_fma() {
        Strategy::Prefetch
    } else {
        Strategy::Volume
    }
}

/// The paper's §4 launch geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaunchGeometry {
    pub blocks: u32,
    pub threads_per_block: u32,
    pub max_registers_per_thread: u32,
}

/// §4: "N_block = 2 x N_SM blocks are used. Two blocks are assigned to
/// each SM, and 512 threads are assigned to each block. Thus, the maximum
/// number of registers for each thread is constrained to 128."
/// (The paper divides the 64K-register file by the 512 threads of one
/// block — 128/thread — relying on the two blocks time-sharing the file;
/// we reproduce their arithmetic.)
pub fn paper_launch(spec: &GpuSpec) -> LaunchGeometry {
    let blocks = 2 * spec.sm_count;
    let threads_per_block = 512;
    let max_regs = spec.registers_per_sm / threads_per_block;
    LaunchGeometry { blocks, threads_per_block, max_registers_per_thread: max_regs }
}

impl LaunchGeometry {
    pub fn threads_per_sm(&self, spec: &GpuSpec) -> u32 {
        (self.blocks / spec.sm_count) * self.threads_per_block
    }
}

/// Is a transfer volume large enough for the Volume strategy? (>= V_s)
pub fn volume_sufficient(spec: &GpuSpec, total_bytes: u64) -> bool {
    total_bytes >= spec.v_s()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, titan_x_maxwell};

    #[test]
    fn threshold_exactly_n_fma() {
        let g = gtx_1080ti();
        assert_eq!(strategy_for(&g, g.n_fma()), Strategy::Prefetch);
        assert_eq!(strategy_for(&g, g.n_fma() - 1), Strategy::Volume);
    }

    #[test]
    fn paper_launch_numbers() {
        // §4: 2x28 blocks, 512 threads/block, 128 regs/thread on 1080Ti
        let g = gtx_1080ti();
        let l = paper_launch(&g);
        assert_eq!(l.blocks, 56);
        assert_eq!(l.threads_per_block, 512);
        assert_eq!(l.max_registers_per_thread, 128); // 64K regs / 512 threads
        assert_eq!(l.threads_per_sm(&g), 1024);
    }

    #[test]
    fn launch_covers_thread_requirement() {
        // 1024 resident threads/SM > the 768 Table-1 requirement: the
        // paper's geometry can keep the bus busy.
        let g = gtx_1080ti();
        let l = paper_launch(&g);
        assert!(l.threads_per_sm(&g) as u64 >= g.threads_required_per_sm());
    }

    #[test]
    fn volume_threshold_is_v_s() {
        let g = gtx_1080ti();
        assert!(volume_sufficient(&g, g.v_s()));
        assert!(!volume_sufficient(&g, g.v_s() - 1));
    }

    #[test]
    fn maxwell_needs_more_fma_per_round() {
        let (g, t) = (gtx_1080ti(), titan_x_maxwell());
        // a round that hides latency on Pascal may not on Maxwell
        let mid = (g.n_fma() + t.n_fma()) / 2;
        assert_eq!(strategy_for(&g, mid), Strategy::Prefetch);
        assert_eq!(strategy_for(&t, mid), Strategy::Volume);
    }
}
