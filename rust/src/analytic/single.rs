//! §3.1 — the single-channel P/Q selection procedure, implemented
//! equation-by-equation.
//!
//! Method 1 divides the *filters* along `m` across SMs and streams the
//! feature map in `P` pieces along `y` (eqs. 4–6).  Method 2 divides the
//! *feature map* along `y` across SMs and streams the filters in `Q`
//! pieces (eqs. 7–9).  P and Q are bounded above by the `Th >= N_FMA`
//! latency-hiding requirement and below by the on-chip capacity
//! (`D <= S_shared`, plus the register-file bound the paper mentions),
//! and the method with the smaller resident working set wins (§3.1
//! step 4).  When no feasible P/Q exists the kernel falls back to the
//! §2.2 "volume" strategy (transfer > V_s continuously, P = Q = 1).

use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::GpuSpec;

/// Which §3.1 division was selected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SingleMethod {
    /// method 1: filters split across SMs, map streamed in P pieces
    FilterSplit,
    /// method 2: map split across SMs, filters streamed in Q pieces
    MapSplit,
}

/// Outcome of the §3.1 procedure for one problem on one GPU.
#[derive(Clone, Debug)]
pub struct SingleChoice {
    pub method: SingleMethod,
    pub p: usize,
    pub q: usize,
    /// eq. (5) resident bytes for the chosen P
    pub d1_bytes: usize,
    /// eq. (8) resident bytes for the chosen Q
    pub d2_bytes: usize,
    /// eq. (6) FMA ops per round for the chosen P
    pub th1: u64,
    /// eq. (9) FMA ops per round for the chosen Q
    pub th2: u64,
    /// whether the chosen division satisfies Th >= N_FMA (prefetch mode);
    /// false = the V_s volume strategy (§2.2 approach 2)
    pub uses_prefetch: bool,
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// eq. (5): resident bytes per SM under method 1 with P map pieces.
pub fn d1_bytes(p: &ConvProblem, spec: &GpuSpec, pp: usize) -> usize {
    let m_per_sm = ceil_div(p.m, spec.sm_count as usize);
    (p.k * p.k * m_per_sm + (ceil_div(p.wy, pp) + p.k - 1) * p.wx) * BYTES_F32
}

/// eq. (6): FMA ops executable per round under method 1.
pub fn th1(p: &ConvProblem, spec: &GpuSpec, pp: usize) -> u64 {
    let m_per_sm = ceil_div(p.m, spec.sm_count as usize);
    (p.k * p.k * m_per_sm * ceil_div(p.wy, pp) * p.wx) as u64
}

/// eq. (8): resident bytes per SM under method 2 with Q filter pieces.
pub fn d2_bytes(p: &ConvProblem, spec: &GpuSpec, q: usize) -> usize {
    let wy_per_sm = ceil_div(p.wy, spec.sm_count as usize);
    (p.k * p.k * ceil_div(p.m, q) + (wy_per_sm + p.k - 1) * p.wx) * BYTES_F32
}

/// eq. (9): FMA ops executable per round under method 2.
pub fn th2(p: &ConvProblem, spec: &GpuSpec, q: usize) -> u64 {
    let wy_per_sm = ceil_div(p.wy, spec.sm_count as usize);
    (p.k * p.k * ceil_div(p.m, q) * wy_per_sm * p.wx) as u64
}

/// The register-file bound the paper folds into the lower bound of P/Q:
/// §4 fixes 2 blocks x 512 threads per SM, max 128 registers per thread;
/// per-thread working data must also fit, which caps the usable on-chip
/// bytes at S_shared plus the register file backing the accumulators.
/// We conservatively require D <= S_shared (the paper's stated bound).
fn onchip_budget(spec: &GpuSpec) -> usize {
    spec.shared_mem_bytes as usize
}

/// §3.1 steps 1–4: choose P, Q and the method.
pub fn choose(p: &ConvProblem, spec: &GpuSpec) -> SingleChoice {
    assert!(p.is_single_channel(), "single-channel problem expected");
    assert!(p.valid(), "invalid problem");
    let n_fma = spec.n_fma();
    let budget = onchip_budget(spec);

    // Step 1 upper bounds (Th >= N_FMA):
    //   P <= K*K*ceil(M/N_sm)*Wy*Wx / N_FMA  and  P <= Wy
    let m_per_sm = ceil_div(p.m, spec.sm_count as usize);
    let p_hi = (((p.k * p.k * m_per_sm * p.wy * p.wx) as u64 / n_fma) as usize).min(p.wy);
    let wy_per_sm = ceil_div(p.wy, spec.sm_count as usize);
    let q_hi = (((p.k * p.k * p.m * wy_per_sm * p.wx) as u64 / n_fma) as usize).min(p.m);

    // Step 2 lower bounds (D <= S_shared): smallest integer P/Q that fits.
    let p_lo = (1..=p.wy).find(|&pp| d1_bytes(p, spec, pp) <= budget);
    let q_lo = (1..=p.m).find(|&q| d2_bytes(p, spec, q) <= budget);

    // Step 3: the minimum feasible value in [lo, hi], if any.
    let p_pick = p_lo.filter(|&lo| lo <= p_hi);
    let q_pick = q_lo.filter(|&lo| lo <= q_hi);

    let (pp, q, uses_prefetch) = match (p_pick, q_pick) {
        (None, None) => (1, 1, false), // §3.1 step 3: no feasible value -> P=Q=1
        (Some(pp), None) => (pp, 1, true),
        (None, Some(q)) => (1, q, true),
        (Some(pp), Some(q)) => (pp, q, true),
    };

    // Step 4: compare the working sets and keep the smaller (more on-chip
    // slack); reset the loser's divisor to 1.
    let d1 = d1_bytes(p, spec, pp);
    let d2 = d2_bytes(p, spec, q);
    let method = if !uses_prefetch {
        // volume fallback: method 1 shape (filters per SM, map streamed)
        SingleMethod::FilterSplit
    } else if p_pick.is_some() && (q_pick.is_none() || d1 <= d2) {
        SingleMethod::FilterSplit
    } else {
        SingleMethod::MapSplit
    };

    let (pp, q) = match method {
        SingleMethod::FilterSplit => (pp, 1),
        SingleMethod::MapSplit => (1, q),
    };

    SingleChoice {
        method,
        p: pp,
        q,
        d1_bytes: d1_bytes(p, spec, pp),
        d2_bytes: d2_bytes(p, spec, q),
        th1: th1(p, spec, pp),
        th2: th2(p, spec, q),
        uses_prefetch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::fig4_suite;
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn chosen_division_fits_shared_memory() {
        let g = gtx_1080ti();
        for p in fig4_suite() {
            let c = choose(&p, &g);
            let d = match c.method {
                SingleMethod::FilterSplit => c.d1_bytes,
                SingleMethod::MapSplit => c.d2_bytes,
            };
            if c.uses_prefetch {
                assert!(d <= g.shared_mem_bytes as usize, "{}: D={} over budget", p.label(), d);
            }
        }
    }

    #[test]
    fn prefetch_divisions_hide_latency() {
        let g = gtx_1080ti();
        for p in fig4_suite() {
            let c = choose(&p, &g);
            if c.uses_prefetch {
                let th = match c.method {
                    SingleMethod::FilterSplit => c.th1,
                    SingleMethod::MapSplit => c.th2,
                };
                assert!(th >= g.n_fma(), "{}: Th={} < N_FMA", p.label(), th);
            }
        }
    }

    #[test]
    fn divisors_in_valid_ranges() {
        let g = gtx_1080ti();
        for p in fig4_suite() {
            let c = choose(&p, &g);
            assert!(c.p >= 1 && c.p <= p.wy);
            assert!(c.q >= 1 && c.q <= p.m);
            // step 4 resets the losing divisor to 1
            assert!(c.p == 1 || c.q == 1);
        }
    }

    #[test]
    fn large_map_forces_division() {
        // 1024x1024 map (4 MB) cannot be resident: P (or Q with the map
        // split across SMs) must engage.
        let g = gtx_1080ti();
        let p = ConvProblem::single(1024, 32, 3);
        let c = choose(&p, &g);
        assert!(c.uses_prefetch);
        match c.method {
            SingleMethod::FilterSplit => assert!(c.p > 1, "P={} for 4MB map", c.p),
            SingleMethod::MapSplit => {
                // map split over 28 SMs: 37 lines/SM is resident-able; fine
            }
        }
    }

    #[test]
    fn small_map_small_m_lacks_prefetch_work() {
        // 28x28 with few small filters: even undivided, Th < N_FMA ->
        // the paper's volume strategy engages (the regime where [1] loses).
        let g = gtx_1080ti();
        let p = ConvProblem::single(28, 32, 1);
        let c = choose(&p, &g);
        // Th1 at P=1: 1*1*ceil(32/28)*28*28 = 1568 << 66048
        assert!(!c.uses_prefetch);
        assert_eq!((c.p, c.q), (1, 1));
    }

    #[test]
    fn eq5_and_eq8_formulas() {
        // hand-check eq.(5)/(8) on a crafted case
        let g = gtx_1080ti();
        let p = ConvProblem::single(56, 56, 3); // m=56 -> 2 filters/SM
        assert_eq!(d1_bytes(&p, &g, 2), (9 * 2 + (28 + 2) * 56) * 4);
        assert_eq!(th1(&p, &g, 2), (9 * 2 * 28 * 56) as u64);
        assert_eq!(d2_bytes(&p, &g, 4), (9 * 14 + (2 + 2) * 56) * 4);
        assert_eq!(th2(&p, &g, 4), (9 * 14 * 2 * 56) as u64);
    }

    #[test]
    fn th_monotone_decreasing_in_divisor() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(224, 64, 3);
        let mut last = u64::MAX;
        for pp in [1, 2, 4, 8, 16] {
            let t = th1(&p, &g, pp);
            assert!(t <= last);
            last = t;
        }
    }

    #[test]
    fn d_monotone_decreasing_in_divisor() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(224, 64, 3);
        let mut last = usize::MAX;
        for pp in [1, 2, 4, 8, 16] {
            let d = d1_bytes(&p, &g, pp);
            assert!(d <= last);
            last = d;
        }
    }
}
