//! L5 observability — roofline counters, virtual-time span tracing,
//! and exports, threaded through every layer without touching any
//! timed arithmetic.
//!
//! The paper's whole argument is a memory-efficiency ratio (FMAs per
//! byte fetched from global memory, §1); this layer makes that ratio —
//! and everything around it — observable end to end:
//!
//! * `roofline` — per-kernel counters projected from
//!   `gpusim::simulate_detailed` (DRAM loads/stores, FMA count,
//!   FMA/byte, achieved vs peak FLOP/s and bandwidth, occupancy, cycle
//!   split), valid for plain, `batched`, `decimated` and `grouped`
//!   plans alike;
//! * `span` / `sink` — the virtual-time span model, its structural
//!   validator, and the `TraceSink` trait with `NoopSink` (the
//!   default) and `Recorder`;
//! * `fleet_trace` — the arrival→completion pump that traces the full
//!   request lifecycle (arrival, coalescer lane, admission + pool
//!   reservation, queue wait, batched execution with roofline attrs,
//!   completion, rejections with causes, pool alloc/free/evict);
//! * `report` — the EXPERIMENTS §12 roofline tables (Fig.4 / Fig.5 /
//!   five models), mirrored by `python/mirror/validate_trace.py`;
//! * `chrome` — Chrome-trace/Perfetto JSON export;
//! * `prometheus` — text exposition of `coordinator::Metrics`.
//!
//! Zero-cost contract: every emission site observes results the timed
//! path already computed and is guarded by `sink.enabled()`; with
//! `NoopSink` all pinned tables stay bit-identical
//! (`rust/tests/trace_difftests.rs` gates this).

pub mod chrome;
pub mod fleet_trace;
pub mod prometheus;
pub mod report;
pub mod roofline;
pub mod sink;
pub mod span;

pub use chrome::chrome_json;
pub use fleet_trace::run_traced;
pub use prometheus::exposition;
pub use report::{
    batched_model_rows, fig4_rows, fig5_rows, model_rows, problem_row, roofline_table, rows_json,
    RooflineRow,
};
pub use roofline::Roofline;
pub use sink::{NoopSink, Recorder, TraceSink};
pub use span::{validate, validate_disjoint, Event, Instant, Span, SpanId, EPS};
