//! The sink trait every traced code path writes through, and its two
//! implementations: `NoopSink` (the default — zero allocation, zero
//! branching beyond one `enabled()` check at each emission site) and
//! `Recorder` (an in-memory buffer that validates and exports).
//!
//! The zero-cost contract: traced variants *observe* results that were
//! already computed — they never add arithmetic to the timed path — and
//! every emission site is guarded by `sink.enabled()`.  With `NoopSink`
//! the guarded blocks are dead, so all pinned timings stay
//! bit-identical (gated by `rust/tests/trace_difftests.rs`).

use super::chrome::chrome_json;
use super::span::{validate, Event, SpanId};

/// Where trace events go.
pub trait TraceSink {
    /// Emitters must guard every event-construction block with this —
    /// it is the whole zero-cost-when-disabled mechanism.
    fn enabled(&self) -> bool;
    /// Record one event.  May be a no-op.
    fn record(&mut self, ev: Event);
    /// Allocate a fresh span id (0 when disabled; real ids start at 1).
    fn next_span_id(&mut self) -> SpanId;
}

/// The disabled sink: answers `false`, drops everything, hands out 0.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn enabled(&self) -> bool {
        false
    }
    fn record(&mut self, _ev: Event) {}
    fn next_span_id(&mut self) -> SpanId {
        0
    }
}

/// An in-memory recorder: keeps events in emission order, validates
/// them, and renders Chrome-trace JSON.
#[derive(Debug, Default)]
pub struct Recorder {
    events: Vec<Event>,
    next_id: SpanId,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder { events: Vec::new(), next_id: 0 }
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Run the structural validator over everything recorded so far.
    pub fn validate(&self) -> Result<(), String> {
        validate(&self.events)
    }

    /// Render everything recorded so far as Chrome-trace JSON
    /// (loadable by Perfetto / `chrome://tracing`).
    pub fn chrome_json(&self) -> String {
        chrome_json(&self.events)
    }
}

impl TraceSink for Recorder {
    fn enabled(&self) -> bool {
        true
    }
    fn record(&mut self, ev: Event) {
        self.events.push(ev);
    }
    fn next_span_id(&mut self) -> SpanId {
        self.next_id += 1;
        self.next_id
    }
}

#[cfg(test)]
mod tests {
    use super::super::span::{Instant, Span};
    use super::*;

    #[test]
    fn noop_sink_is_disabled_and_ids_are_zero() {
        let mut s = NoopSink;
        assert!(!s.enabled());
        assert_eq!(s.next_span_id(), 0);
        s.record(Event::Instant(Instant::new("a", "x", 0.0))); // dropped
    }

    #[test]
    fn recorder_keeps_order_and_mints_fresh_ids() {
        let mut r = Recorder::new();
        assert!(r.enabled());
        let a = r.next_span_id();
        let b = r.next_span_id();
        assert_eq!((a, b), (1, 2));
        r.record(Event::Span(Span::new(a, None, "t", "outer", 0.0, 2.0)));
        r.record(Event::Span(Span::new(b, Some(a), "t", "inner", 0.0, 1.0)));
        assert_eq!(r.len(), 2);
        r.validate().unwrap();
        assert!(r.chrome_json().contains("traceEvents"));
    }
}
