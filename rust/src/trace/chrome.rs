//! Chrome-trace ("Trace Event Format") JSON writer — the output loads
//! directly into Perfetto (ui.perfetto.dev) or `chrome://tracing`.
//!
//! Mapping: one process (pid 1) per trace; each span track becomes a
//! thread (tid assigned in first-appearance order, named via `ph:"M"`
//! thread_name metadata); spans are complete events (`ph:"X"`) with
//! `ts`/`dur` in *microseconds of virtual time* (virtual seconds ×
//! 1e6); instants are `ph:"i"` with thread scope.  Structured span
//! attributes land in `args`, alongside `span_id`/`parent_id` so the
//! tree survives the export.

use std::collections::HashMap;

use crate::util::json::Json;

use super::span::Event;

const US_PER_SEC: f64 = 1e6;

fn args_obj(attrs: &[(String, Json)]) -> Json {
    let mut o = Json::obj();
    for (k, v) in attrs {
        o = o.set(k, v.clone());
    }
    o
}

/// Render an emission-ordered event stream as Chrome-trace JSON.
pub fn chrome_json(events: &[Event]) -> String {
    // tids in first-appearance order so Perfetto's lane order follows
    // the trace's own narrative (coordinator first, then requests…)
    let mut order: Vec<&str> = Vec::new();
    let mut tid_of: HashMap<&str, usize> = HashMap::new();
    for ev in events {
        let track = ev.track();
        if !tid_of.contains_key(track) {
            tid_of.insert(track, order.len() + 1);
            order.push(track);
        }
    }

    let mut out: Vec<Json> = Vec::with_capacity(events.len() + order.len());
    for track in &order {
        out.push(
            Json::obj()
                .set("ph", "M".into())
                .set("pid", 1usize.into())
                .set("tid", tid_of[track].into())
                .set("name", "thread_name".into())
                .set("args", Json::obj().set("name", (*track).into())),
        );
    }
    for ev in events {
        let tid = tid_of[ev.track()];
        match ev {
            Event::Span(s) => {
                let mut args = args_obj(&s.attrs).set("span_id", s.id.to_string().as_str().into());
                if let Some(p) = s.parent {
                    args = args.set("parent_id", p.to_string().as_str().into());
                }
                out.push(
                    Json::obj()
                        .set("ph", "X".into())
                        .set("pid", 1usize.into())
                        .set("tid", tid.into())
                        .set("name", s.name.as_str().into())
                        .set("cat", "pasconv".into())
                        .set("ts", (s.t0 * US_PER_SEC).into())
                        .set("dur", (s.duration() * US_PER_SEC).into())
                        .set("args", args),
                );
            }
            Event::Instant(i) => {
                out.push(
                    Json::obj()
                        .set("ph", "i".into())
                        .set("s", "t".into())
                        .set("pid", 1usize.into())
                        .set("tid", tid.into())
                        .set("name", i.name.as_str().into())
                        .set("cat", "pasconv".into())
                        .set("ts", (i.t * US_PER_SEC).into())
                        .set("args", args_obj(&i.attrs)),
                );
            }
        }
    }

    Json::obj()
        .set("displayTimeUnit", "ms".into())
        .set("traceEvents", Json::Arr(out))
        .render()
}

#[cfg(test)]
mod tests {
    use super::super::span::{Instant, Span};
    use super::*;

    #[test]
    fn spans_and_instants_export_with_virtual_microseconds() {
        let evs = vec![
            Event::Span(
                Span::new(1, None, "req:1", "request", 0.5, 1.5).attr("model", "vgg16".into()),
            ),
            Event::Span(Span::new(2, Some(1), "req:1", "execute", 1.0, 1.5)),
            Event::Instant(Instant::new("pool:dev0", "alloc", 0.5).attr("bytes", 1024usize.into())),
        ];
        let s = chrome_json(&evs);
        assert!(s.contains("\"displayTimeUnit\":\"ms\""));
        assert!(s.contains("\"thread_name\""));
        assert!(s.contains("\"name\":\"req:1\""), "track metadata names the lane");
        assert!(s.contains("\"ts\":500000"), "0.5 virtual seconds -> 5e5 us");
        assert!(s.contains("\"dur\":1000000"));
        assert!(s.contains("\"parent_id\":\"1\""));
        assert!(s.contains("\"ph\":\"i\""));
        assert!(s.contains("\"model\":\"vgg16\""));
    }

    #[test]
    fn tids_follow_first_appearance() {
        let evs = vec![
            Event::Instant(Instant::new("coordinator", "arrival", 0.0)),
            Event::Instant(Instant::new("dev:0", "x", 1.0)),
            Event::Instant(Instant::new("coordinator", "arrival", 2.0)),
        ];
        let s = chrome_json(&evs);
        let coord = s.find("\"name\":\"coordinator\"").unwrap();
        let dev = s.find("\"name\":\"dev:0\"").unwrap();
        assert!(coord < dev, "coordinator appeared first, lane order keeps it first");
    }
}
