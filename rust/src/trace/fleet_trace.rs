//! Traced fleet driving: the one arrival→completion pump shared by the
//! `fleet` CLI and the trace difftests/proptests.
//!
//! `run_traced(fleet, arrivals, sink)` performs EXACTLY the untraced
//! sequence — `complete_until(a.t)` before each `submit`, `drain` at
//! the end — and, only when `sink.enabled()`, additionally emits the
//! full request lifecycle:
//!
//! * `coordinator` track — `arrival` instants for every offered
//!   request, `reject` instants for every refusal with a `cause`
//!   attribute (`"memory"` vs `"queue_full"`, from the `mem_rejected`
//!   delta);
//! * `req:{job}` track — a `request` root span (arrival → finish) with
//!   child spans `coalesce` (lane tag), `admit` (device + pool
//!   reservation), `queue` (arrival → start), `execute` (start →
//!   finish, with the dispatched backend and its roofline counters);
//! * `dev:{d}` track — one `run` span per job (FIFO ⇒ strictly
//!   disjoint, checked by `validate_disjoint`);
//! * `pool:dev{d}` track — `alloc`/`free`/`evict` instants mirroring
//!   the reservation lifecycle.
//!
//! All emission reads state the scheduler already computed; nothing
//! here feeds back into placement or timing, so the no-op sink is
//! bit-identical to the plain loop (gated by
//! `rust/tests/trace_difftests.rs`).

use std::collections::HashMap;

use crate::backend;
use crate::conv::ConvOp;
use crate::fleet::{Arrival, Completion, Fleet};
use crate::util::json::Json;

use super::roofline::Roofline;
use super::sink::TraceSink;
use super::span::{Event, Instant, Span};

/// Dispatched backend name + roofline attrs for one (op, batch, spec),
/// memoized — fleets repeat the same few dozen shapes thousands of
/// times.
type RoofCache = HashMap<(ConvOp, usize, &'static str), (String, Vec<(String, Json)>)>;

fn roofline_for(
    cache: &mut RoofCache,
    conv: &crate::conv::BatchedConvOp,
    spec: &crate::gpusim::GpuSpec,
) -> (String, Vec<(String, Json)>) {
    cache
        .entry((conv.op, conv.n, spec.name))
        .or_insert_with(|| {
            let d = backend::batched_op_dispatched(conv, spec);
            let plan = backend::registry()
                .backend(&d.backend)
                .expect("dispatcher returned a registered backend")
                .op_plan(&conv.op, spec)
                .batched(conv.n);
            (d.backend, Roofline::measure(spec, &plan).attrs())
        })
        .clone()
}

fn emit_frees(sink: &mut dyn TraceSink, done: &[Completion]) {
    for c in done {
        sink.record(Event::Instant(
            Instant::new(&format!("pool:dev{}", c.device), "free", c.finish)
                .attr("job", c.job.to_string().as_str().into())
                .attr("bytes", c.conv.footprint_bytes().into()),
        ));
    }
}

/// Drive `fleet` through `arrivals` (then drain), tracing through
/// `sink`.  Returns every completion in event order — exactly what the
/// untraced pump returns.
pub fn run_traced(
    fleet: &mut Fleet,
    arrivals: &[Arrival],
    sink: &mut dyn TraceSink,
) -> Vec<Completion> {
    let mut completions: Vec<Completion> = Vec::with_capacity(arrivals.len());
    let mut roof_cache: RoofCache = HashMap::new();
    let mut emitted = 0usize;

    for a in arrivals {
        completions.extend(fleet.complete_until(a.t));
        if sink.enabled() {
            emit_frees(sink, &completions[emitted..]);
            emitted = completions.len();
            sink.record(Event::Instant(
                Instant::new("coordinator", "arrival", a.t)
                    .attr("model", a.model.into())
                    .attr("op", a.conv.op.label().as_str().into())
                    .attr("batch", a.conv.n.into()),
            ));
        }

        let mem_before = fleet.stats.mem_rejected;
        let evict_before: Vec<u64> = if sink.enabled() {
            fleet.devices().iter().map(|d| d.pool().stats.evictions).collect()
        } else {
            Vec::new()
        };

        let placed = fleet.submit(a.conv, Some(a.model));
        if !sink.enabled() {
            continue;
        }

        match placed {
            Some(pl) => {
                let (backend_name, roof_attrs) =
                    roofline_for(&mut roof_cache, &a.conv, &fleet.devices()[pl.device].spec);
                let dev = &fleet.devices()[pl.device];
                let track = format!("req:{}", pl.job);
                let footprint = a.conv.footprint_bytes();

                let rid = sink.next_span_id();
                let root = Span::new(rid, None, &track, "request", a.t, pl.finish)
                    .attr("job", pl.job.to_string().as_str().into())
                    .attr("model", a.model.into())
                    .attr("op", a.conv.op.label().as_str().into())
                    .attr("batch", a.conv.n.into())
                    .attr("device", pl.device.into())
                    .attr("queue_wait_s", (pl.start - a.t).into())
                    .attr("service_s", (pl.finish - pl.start).into());
                sink.record(Event::Span(root));

                let cid = sink.next_span_id();
                sink.record(Event::Span(
                    Span::new(cid, Some(rid), &track, "coalesce", a.t, a.t)
                        .attr("lane", a.conv.op.label().as_str().into())
                        .attr("images", a.conv.n.into()),
                ));
                let aid = sink.next_span_id();
                sink.record(Event::Span(
                    Span::new(aid, Some(rid), &track, "admit", a.t, a.t)
                        .attr("device", pl.device.into())
                        .attr("footprint_bytes", footprint.into())
                        .attr("pool_in_use_bytes", dev.pool().in_use_slab_bytes().into()),
                ));
                let qid = sink.next_span_id();
                sink.record(Event::Span(
                    Span::new(qid, Some(rid), &track, "queue", a.t, pl.start)
                        .attr("jobs_ahead", (dev.queue_len() - 1).into()),
                ));
                let xid = sink.next_span_id();
                let mut exec = Span::new(xid, Some(rid), &track, "execute", pl.start, pl.finish)
                    .attr("backend", backend_name.as_str().into());
                for (k, v) in &roof_attrs {
                    exec = exec.attr(k, v.clone());
                }
                sink.record(Event::Span(exec));

                let did = sink.next_span_id();
                sink.record(Event::Span(
                    Span::new(did, None, &format!("dev:{}", pl.device), "run", pl.start, pl.finish)
                        .attr("job", pl.job.to_string().as_str().into())
                        .attr("model", a.model.into())
                        .attr("op", a.conv.op.label().as_str().into()),
                ));

                sink.record(Event::Instant(
                    Instant::new(&format!("pool:dev{}", pl.device), "alloc", a.t)
                        .attr("job", pl.job.to_string().as_str().into())
                        .attr("bytes", footprint.into())
                        .attr("in_use_bytes", dev.pool().in_use_slab_bytes().into()),
                ));
                for (i, d) in fleet.devices().iter().enumerate() {
                    let delta = d.pool().stats.evictions - evict_before[i];
                    for _ in 0..delta {
                        sink.record(Event::Instant(
                            Instant::new(&format!("pool:dev{i}"), "evict", a.t)
                                .attr("trigger_job", pl.job.to_string().as_str().into()),
                        ));
                    }
                }
            }
            None => {
                let cause = if fleet.stats.mem_rejected > mem_before { "memory" } else { "queue_full" };
                sink.record(Event::Instant(
                    Instant::new("coordinator", "reject", a.t)
                        .attr("cause", cause.into())
                        .attr("model", a.model.into())
                        .attr("op", a.conv.op.label().as_str().into())
                        .attr("batch", a.conv.n.into())
                        .attr("footprint_bytes", a.conv.footprint_bytes().into()),
                ));
            }
        }
    }

    let drained = fleet.drain();
    completions.extend(drained);
    if sink.enabled() {
        emit_frees(sink, &completions[emitted..]);
    }
    completions
}

#[cfg(test)]
mod tests {
    use super::super::sink::{NoopSink, Recorder};
    use super::super::span::{validate_disjoint, Event};
    use super::*;
    use crate::fleet::{offered_load, FleetConfig, Policy};
    use crate::gpusim::gtx_1080ti;

    fn small_fleet(cap: Option<usize>) -> Fleet {
        Fleet::homogeneous(
            2,
            &gtx_1080ti(),
            FleetConfig { policy: Policy::LeastLoaded, queue_bound: 4, capacity_bytes: cap },
        )
    }

    #[test]
    fn traced_run_validates_and_matches_untraced_completions() {
        let load = offered_load(48, 2000.0, 0xF1EE7, None);
        let mut plain = small_fleet(None);
        let mut noop = NoopSink;
        let base = run_traced(&mut plain, &load, &mut noop);

        let mut traced = small_fleet(None);
        let mut rec = Recorder::new();
        let got = run_traced(&mut traced, &load, &mut rec);

        assert_eq!(base.len(), got.len());
        for (x, y) in base.iter().zip(&got) {
            assert_eq!(x.job, y.job);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "tracing shifted timing");
        }
        rec.validate().unwrap();
        validate_disjoint(rec.events(), "dev:").unwrap();
        // every accepted request has a root span and an execute child
        let requests = rec
            .events()
            .iter()
            .filter(|e| matches!(e, Event::Span(s) if s.name == "request"))
            .count();
        let executes = rec
            .events()
            .iter()
            .filter(|e| matches!(e, Event::Span(s) if s.name == "execute"))
            .count();
        assert_eq!(requests as u64, traced.stats.accepted);
        assert_eq!(executes, requests);
    }

    #[test]
    fn mem_rejections_carry_the_memory_cause() {
        let load = offered_load(64, 5000.0, 0xF1EE7, Some(8));
        let cap = load[0].conv.footprint_bytes() * 2;
        let mut f = small_fleet(Some(cap));
        let mut rec = Recorder::new();
        run_traced(&mut f, &load, &mut rec);
        assert!(f.stats.mem_rejected > 0, "tiny pool must shed on memory");
        let mem_causes = rec
            .events()
            .iter()
            .filter(|e| match e {
                Event::Instant(i) => {
                    i.name == "reject"
                        && i.attrs.iter().any(|(k, v)| {
                            k == "cause" && v.render() == "\"memory\""
                        })
                }
                _ => false,
            })
            .count();
        assert_eq!(mem_causes as u64, f.stats.mem_rejected);
        rec.validate().unwrap();
    }
}
