//! Virtual-time span model and its structural validator.
//!
//! A `Span` is a half-open interval `[t0, t1]` of *virtual* seconds on
//! a named track (one track per timeline lane: `coordinator`,
//! `req:{job}`, `dev:{d}`, `pool:dev{d}`, `model:{name}` …), optionally
//! parented to another span by id.  An `Instant` is a point event on a
//! track.  Both carry structured attributes (`util::json::Json`
//! values), so exports never re-derive anything.
//!
//! `validate` enforces the invariants every emitter in this crate must
//! keep (and which `rust/tests/trace_proptests.rs` and the Python
//! mirror check on real fleet traces):
//!
//! 1. every timestamp is finite and `t1 >= t0`;
//! 2. span ids are unique;
//! 3. a child lies inside its parent's interval (well-nested by id);
//! 4. on any one track, spans are nested-or-disjoint — no partial
//!    overlap (well-nested by time);
//! 5. per (track, name) stream, emission order is monotone in virtual
//!    time (spans by `t0`, instants by `t`).
//!
//! All comparisons use an absolute `EPS` so exactly-touching intervals
//! (a queue span ending where the execute span starts) are legal.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::json::Json;

/// Absolute tolerance for interval comparisons, seconds.  Virtual
/// timestamps are exact f64 arithmetic, but derived endpoints (t0 +
/// cumulative sums) can differ from a parent's endpoint by rounding.
pub const EPS: f64 = 1e-9;

/// Span identifier; 0 is reserved (the no-op sink's answer).
pub type SpanId = u64;

/// A closed interval of virtual time on a track.
#[derive(Clone, Debug)]
pub struct Span {
    pub id: SpanId,
    pub parent: Option<SpanId>,
    pub track: String,
    pub name: String,
    /// virtual seconds
    pub t0: f64,
    pub t1: f64,
    pub attrs: Vec<(String, Json)>,
}

impl Span {
    pub fn new(id: SpanId, parent: Option<SpanId>, track: &str, name: &str, t0: f64, t1: f64) -> Span {
        Span { id, parent, track: track.to_string(), name: name.to_string(), t0, t1, attrs: Vec::new() }
    }

    /// Attach a structured attribute (builder style).
    pub fn attr(mut self, key: &str, value: Json) -> Span {
        self.attrs.push((key.to_string(), value));
        self
    }

    pub fn duration(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// A point event on a track (pool alloc/free/evict, arrivals, rejects).
#[derive(Clone, Debug)]
pub struct Instant {
    pub track: String,
    pub name: String,
    /// virtual seconds
    pub t: f64,
    pub attrs: Vec<(String, Json)>,
}

impl Instant {
    pub fn new(track: &str, name: &str, t: f64) -> Instant {
        Instant { track: track.to_string(), name: name.to_string(), t, attrs: Vec::new() }
    }

    pub fn attr(mut self, key: &str, value: Json) -> Instant {
        self.attrs.push((key.to_string(), value));
        self
    }
}

/// What a `TraceSink` records.
#[derive(Clone, Debug)]
pub enum Event {
    Span(Span),
    Instant(Instant),
}

impl Event {
    pub fn track(&self) -> &str {
        match self {
            Event::Span(s) => &s.track,
            Event::Instant(i) => &i.track,
        }
    }
}

/// Check the five structural invariants over an emission-ordered event
/// stream.  `Err` carries a human-readable description of the first
/// violation found.
pub fn validate(events: &[Event]) -> Result<(), String> {
    let mut ids: BTreeSet<SpanId> = BTreeSet::new();
    let mut by_id: BTreeMap<SpanId, (f64, f64)> = BTreeMap::new();

    // pass 1: field sanity, id uniqueness, interval table
    for ev in events {
        match ev {
            Event::Span(s) => {
                if !s.t0.is_finite() || !s.t1.is_finite() {
                    return Err(format!("span {} '{}': non-finite time", s.id, s.name));
                }
                if s.t1 < s.t0 {
                    return Err(format!("span {} '{}': t1 {} < t0 {}", s.id, s.name, s.t1, s.t0));
                }
                if !ids.insert(s.id) {
                    return Err(format!("duplicate span id {}", s.id));
                }
                by_id.insert(s.id, (s.t0, s.t1));
            }
            Event::Instant(i) => {
                if !i.t.is_finite() {
                    return Err(format!("instant '{}': non-finite time", i.name));
                }
            }
        }
    }

    // pass 2: parent containment (well-nested by id)
    for ev in events {
        if let Event::Span(s) = ev {
            if let Some(pid) = s.parent {
                let Some(&(pt0, pt1)) = by_id.get(&pid) else {
                    return Err(format!("span {} '{}': unknown parent {}", s.id, s.name, pid));
                };
                if s.t0 < pt0 - EPS || s.t1 > pt1 + EPS {
                    return Err(format!(
                        "span {} '{}' [{}, {}] escapes parent {} [{}, {}]",
                        s.id, s.name, s.t0, s.t1, pid, pt0, pt1
                    ));
                }
            }
        }
    }

    // pass 3: per-track nested-or-disjoint (well-nested by time).
    // Sort each track's spans by (t0 asc, t1 desc) and sweep a stack:
    // a span must either start after the enclosing span ends, or end
    // inside it.  Partial overlap is the only failure.
    let mut per_track: BTreeMap<&str, Vec<&Span>> = BTreeMap::new();
    for ev in events {
        if let Event::Span(s) = ev {
            per_track.entry(s.track.as_str()).or_default().push(s);
        }
    }
    for (track, spans) in per_track.iter_mut() {
        spans.sort_by(|a, b| a.t0.total_cmp(&b.t0).then(b.t1.total_cmp(&a.t1)));
        let mut stack: Vec<&Span> = Vec::new();
        for s in spans.iter() {
            while let Some(top) = stack.last() {
                if top.t1 <= s.t0 + EPS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(top) = stack.last() {
                if s.t1 > top.t1 + EPS {
                    return Err(format!(
                        "track '{}': span {} '{}' [{}, {}] partially overlaps {} '{}' [{}, {}]",
                        track, s.id, s.name, s.t0, s.t1, top.id, top.name, top.t0, top.t1
                    ));
                }
            }
            stack.push(s);
        }
    }

    // pass 4: per-(track, name) monotone emission timestamps
    let mut last_span: BTreeMap<(&str, &str), f64> = BTreeMap::new();
    let mut last_instant: BTreeMap<(&str, &str), f64> = BTreeMap::new();
    for ev in events {
        match ev {
            Event::Span(s) => {
                let key = (s.track.as_str(), s.name.as_str());
                if let Some(&prev) = last_span.get(&key) {
                    if s.t0 + EPS < prev {
                        return Err(format!(
                            "track '{}': span stream '{}' not monotone ({} after {})",
                            s.track, s.name, s.t0, prev
                        ));
                    }
                }
                last_span.insert(key, s.t0);
            }
            Event::Instant(i) => {
                let key = (i.track.as_str(), i.name.as_str());
                if let Some(&prev) = last_instant.get(&key) {
                    if i.t + EPS < prev {
                        return Err(format!(
                            "track '{}': instant stream '{}' not monotone ({} after {})",
                            i.track, i.name, i.t, prev
                        ));
                    }
                }
                last_instant.insert(key, i.t);
            }
        }
    }

    Ok(())
}

/// Additionally require that spans on every track whose name starts
/// with `prefix` are *strictly disjoint* (a device runs one job at a
/// time — nesting is not enough there).
pub fn validate_disjoint(events: &[Event], prefix: &str) -> Result<(), String> {
    let mut per_track: BTreeMap<&str, Vec<&Span>> = BTreeMap::new();
    for ev in events {
        if let Event::Span(s) = ev {
            if s.track.starts_with(prefix) {
                per_track.entry(s.track.as_str()).or_default().push(s);
            }
        }
    }
    for (track, spans) in per_track.iter_mut() {
        spans.sort_by(|a, b| a.t0.total_cmp(&b.t0));
        for w in spans.windows(2) {
            if w[1].t0 + EPS < w[0].t1 {
                return Err(format!(
                    "track '{}': spans {} and {} overlap ([{}, {}] vs [{}, {}])",
                    track, w[0].id, w[1].id, w[0].t0, w[0].t1, w[1].t0, w[1].t1
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: SpanId, parent: Option<SpanId>, track: &str, name: &str, t0: f64, t1: f64) -> Event {
        Event::Span(Span::new(id, parent, track, name, t0, t1))
    }

    #[test]
    fn nested_and_sequential_spans_validate() {
        let evs = vec![
            span(1, None, "req:1", "request", 0.0, 10.0),
            span(2, Some(1), "req:1", "queue", 0.0, 4.0),
            span(3, Some(1), "req:1", "execute", 4.0, 10.0),
            span(4, None, "req:2", "request", 5.0, 12.0),
            Event::Instant(Instant::new("pool:dev0", "alloc", 0.0)),
            Event::Instant(Instant::new("pool:dev0", "alloc", 5.0)),
        ];
        validate(&evs).unwrap();
    }

    #[test]
    fn partial_overlap_on_a_track_is_rejected() {
        let evs = vec![
            span(1, None, "dev:0", "run", 0.0, 5.0),
            span(2, None, "dev:0", "run", 3.0, 8.0),
        ];
        assert!(validate(&evs).unwrap_err().contains("partially overlaps"));
    }

    #[test]
    fn child_escaping_parent_is_rejected() {
        let evs = vec![
            span(1, None, "req:1", "request", 0.0, 5.0),
            span(2, Some(1), "req:1", "execute", 4.0, 7.0),
        ];
        assert!(validate(&evs).unwrap_err().contains("escapes parent"));
    }

    #[test]
    fn duplicate_ids_and_unknown_parents_are_rejected() {
        let dup = vec![
            span(1, None, "a", "x", 0.0, 1.0),
            span(1, None, "b", "y", 0.0, 1.0),
        ];
        assert!(validate(&dup).unwrap_err().contains("duplicate"));
        let orphan = vec![span(2, Some(9), "a", "x", 0.0, 1.0)];
        assert!(validate(&orphan).unwrap_err().contains("unknown parent"));
    }

    #[test]
    fn non_monotone_stream_is_rejected() {
        let evs = vec![
            span(1, None, "dev:0", "run", 5.0, 6.0),
            span(2, None, "dev:0", "run", 0.0, 1.0),
        ];
        assert!(validate(&evs).unwrap_err().contains("not monotone"));
    }

    #[test]
    fn disjointness_check_catches_nested_device_spans() {
        let evs = vec![
            span(1, None, "dev:0", "run", 0.0, 10.0),
            span(2, None, "dev:0", "warm", 2.0, 4.0),
        ];
        validate(&evs).unwrap(); // nested is fine in general...
        assert!(validate_disjoint(&evs, "dev:").is_err()); // ...not on a device
        validate_disjoint(&evs, "pool:").unwrap(); // other prefixes untouched
    }
}
