//! Prometheus text exposition (version 0.0.4) of `coordinator::Metrics`
//! — counters, pool gauges, the cumulative latency histogram, and
//! per-class p50/p99 summaries from the log-bucketed histograms (no
//! sample retention anywhere).

use std::fmt::Write;

use crate::coordinator::Metrics;

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

/// Render `m` in Prometheus text format.
pub fn exposition(m: &Metrics) -> String {
    let mut out = String::new();
    counter(&mut out, "pasconv_requests_total", "requests received", m.requests);
    counter(&mut out, "pasconv_responses_total", "responses served", m.responses);
    counter(&mut out, "pasconv_errors_total", "request errors", m.errors);
    counter(&mut out, "pasconv_batches_total", "batches executed", m.batches_executed);
    counter(&mut out, "pasconv_batched_requests_total", "requests served via batches", m.batched_requests);
    counter(&mut out, "pasconv_conv_batches_total", "coalesced conv micro-batches", m.conv_batches_executed);
    counter(&mut out, "pasconv_coalesced_convs_total", "conv requests coalesced", m.coalesced_convs);
    counter(&mut out, "pasconv_plans_tuned_total", "conv plans pre-tuned", m.plans_tuned);
    counter(&mut out, "pasconv_pooled_models_total", "pooled model executions", m.pooled_models);
    counter(&mut out, "pasconv_pool_evictions_total", "pool slab evictions", m.pool_evictions);
    counter(&mut out, "pasconv_pool_reuse_hits_total", "pool slab reuse hits", m.pool_reuse_hits);
    gauge(&mut out, "pasconv_pool_capacity_bytes", "executor pool cap", m.pool_capacity_bytes);
    gauge(&mut out, "pasconv_pool_in_use_bytes", "executor pool occupancy", m.pool_in_use_bytes);
    gauge(&mut out, "pasconv_pool_fragmentation_bytes", "slab minus requested bytes", m.pool_fragmentation_bytes);
    gauge(&mut out, "pasconv_pool_peak_bytes", "peak pool occupancy", m.pool_peak_bytes);

    // the latency histogram, cumulative le-buckets per the exposition
    // format (all times are VIRTUAL seconds)
    let name = "pasconv_latency_virtual_seconds";
    let _ = writeln!(out, "# HELP {name} request latency in virtual seconds");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (le, c) in m.latency.buckets() {
        cum += c;
        let _ = writeln!(out, "{name}_bucket{{le=\"{le:e}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", m.latency.count());
    let _ = writeln!(out, "{name}_sum {}", m.latency.sum());
    let _ = writeln!(out, "{name}_count {}", m.latency.count());

    // per-class quantile summaries from the per-class histograms
    let cname = "pasconv_class_latency_virtual_seconds";
    if !m.latency_by_class.is_empty() {
        let _ = writeln!(out, "# HELP {cname} per-class latency quantiles (virtual seconds)");
        let _ = writeln!(out, "# TYPE {cname} summary");
        for (class, h) in &m.latency_by_class {
            for q in [0.5, 0.9, 0.99] {
                let _ = writeln!(
                    out,
                    "{cname}{{class=\"{class}\",quantile=\"{q}\"}} {}",
                    h.quantile(q)
                );
            }
            let _ = writeln!(out, "{cname}_sum{{class=\"{class}\"}} {}", h.sum());
            let _ = writeln!(out, "{cname}_count{{class=\"{class}\"}} {}", h.count());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_renders_counters_buckets_and_classes() {
        let mut m = Metrics::default();
        m.requests = 7;
        m.record_response("vgg16_b4", 1e-3);
        m.record_response("vgg16_b4", 4e-3);
        m.record_response("alexnet_b1", 2e-4);
        let s = exposition(&m);
        assert!(s.contains("pasconv_requests_total 7"));
        assert!(s.contains("# TYPE pasconv_latency_virtual_seconds histogram"));
        assert!(s.contains("le=\"+Inf\"} 3"));
        assert!(s.contains("pasconv_latency_virtual_seconds_count 3"));
        assert!(s.contains("class=\"vgg16_b4\",quantile=\"0.99\""));
        assert!(s.contains("pasconv_class_latency_virtual_seconds_count{class=\"alexnet_b1\"} 1"));
        // cumulative buckets are monotone
        let mut last = 0u64;
        for line in s.lines().filter(|l| l.starts_with("pasconv_latency_virtual_seconds_bucket{le=\"")) {
            if line.contains("+Inf") {
                continue;
            }
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{line}");
            last = v;
        }
    }
}
