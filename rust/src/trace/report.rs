//! The roofline report: one row per workload answering "why did the
//! winner win" in the paper's own units — FMA per fetched byte, and
//! achieved fractions of peak FLOP/s and peak DRAM bandwidth.
//!
//! Three suites, matching EXPERIMENTS §12 (pinned there and replayed by
//! `python/mirror/validate_trace.py`):
//! * Fig.4 single-channel problems (K = 1, 3, 5), dispatched backend;
//! * Fig.5 multi-channel problems, dispatched backend;
//! * the five model graphs, aggregated over their dispatched conv
//!   plans + glue traffic.
//!
//! Model rows aggregate: FMA/B = Σ conv FMAs / Σ conv loaded bytes
//! (the figure of merit only counts kernel fetches); achieved GFLOP/s
//! and bandwidth divide by the *whole-model* execution time from
//! `graph::execute`, with bandwidth counting all DRAM traffic (conv
//! loads + stores + glue bytes).  A model's bottleneck is whichever
//! peak fraction sits higher on the roofline.

use crate::backend;
use crate::conv::{suites, ConvProblem};
use crate::gpusim::GpuSpec;
use crate::graph::{execute, model_graph, node_glue_bytes, Op, MODEL_NAMES};
use crate::util::bench::Table;
use crate::util::json::Json;

use super::roofline::Roofline;

/// One row of the §12 report.
#[derive(Clone, Debug)]
pub struct RooflineRow {
    pub label: String,
    pub backend: String,
    /// pipeline depth / loading strategy ("2/cyc", "4/ord"; "-" for
    /// aggregate model rows that mix plans)
    pub staging: String,
    pub fma_per_byte: f64,
    pub gflops: f64,
    /// achieved % of peak FLOP/s
    pub flops_pct: f64,
    /// % of peak DRAM bandwidth the timing model charged
    pub bw_charged_pct: f64,
    /// % of peak DRAM bandwidth over ALL traffic; charged <= total
    /// <= 100 structurally since the bus floor entered the model
    pub bw_total_pct: f64,
    pub bottleneck: String,
}

impl RooflineRow {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("label", self.label.as_str().into())
            .set("backend", self.backend.as_str().into())
            .set("staging", self.staging.as_str().into())
            .set("fma_per_byte", self.fma_per_byte.into())
            .set("gflops", self.gflops.into())
            .set("flops_pct", self.flops_pct.into())
            .set("bw_charged_pct", self.bw_charged_pct.into())
            .set("bw_total_pct", self.bw_total_pct.into())
            .set("bottleneck", self.bottleneck.as_str().into())
    }
}

/// Roofline row for one problem under cross-backend dispatch.
pub fn problem_row(p: &ConvProblem, spec: &GpuSpec) -> RooflineRow {
    let d = backend::dispatched(p, spec);
    let plan = backend::dispatch_plan(p, spec);
    let roof = Roofline::measure(spec, &plan);
    RooflineRow {
        label: p.label(),
        backend: d.backend,
        staging: format!("{}/{}", plan.stages, plan.loading.tag()),
        fma_per_byte: roof.fma_per_byte,
        gflops: roof.gflops,
        flops_pct: 100.0 * roof.flops_frac,
        bw_charged_pct: 100.0 * roof.bw_frac_charged,
        bw_total_pct: 100.0 * roof.bw_frac_total,
        bottleneck: roof.bottleneck.to_string(),
    }
}

pub fn fig4_rows(spec: &GpuSpec) -> Vec<RooflineRow> {
    suites::fig4_suite().iter().map(|p| problem_row(p, spec)).collect()
}

pub fn fig5_rows(spec: &GpuSpec) -> Vec<RooflineRow> {
    suites::fig5_suite().iter().map(|p| problem_row(p, spec)).collect()
}

/// Aggregate roofline rows for the five model graphs under op
/// dispatch (`backend::dispatch_fused_op_plan`), glue traffic included
/// in the bandwidth numerator.
pub fn model_rows(spec: &GpuSpec) -> Vec<RooflineRow> {
    MODEL_NAMES
        .iter()
        .map(|name| {
            let g = model_graph(name).expect("canonical model name");
            let mut fma = 0.0;
            let mut conv_loads = 0.0;
            let mut conv_stores = 0.0;
            let mut conv_charged = 0.0;
            let mut glue = 0.0;
            for n in g.nodes() {
                match &n.op {
                    Op::Conv { conv, epilogue } => {
                        let plan = backend::dispatch_fused_op_plan(conv, *epilogue, spec);
                        let b = crate::gpusim::simulate_detailed(spec, &plan);
                        fma += plan.total_fma;
                        conv_loads += plan.dram_load_bytes();
                        conv_stores += plan.output_bytes + plan.epilogue_read_bytes;
                        conv_charged += plan.dram_load_bytes()
                            + b.writeback_cycles * spec.bytes_per_cycle();
                    }
                    _ => glue += node_glue_bytes(&g, n.id),
                }
            }
            let report = execute(&g, spec, backend::dispatch_fused_op_plan);
            let secs = report.total_seconds.max(f64::MIN_POSITIVE);
            let gflops = 2.0 * fma / secs / 1e9;
            let flops_frac = 2.0 * fma / secs / spec.peak_flops();
            let bw_charged = (conv_charged + glue) / secs / 1e9 / spec.bandwidth_gb_s;
            let bw_total =
                (conv_loads + conv_stores + glue) / secs / 1e9 / spec.bandwidth_gb_s;
            RooflineRow {
                label: name.to_string(),
                backend: "dispatched".to_string(),
                staging: "-".to_string(),
                fma_per_byte: fma / conv_loads.max(1.0),
                gflops,
                flops_pct: 100.0 * flops_frac,
                bw_charged_pct: 100.0 * bw_charged,
                bw_total_pct: 100.0 * bw_total,
                bottleneck: if bw_total >= flops_frac { "memory" } else { "compute" }.to_string(),
            }
        })
        .collect()
}

/// `model_rows` at a serving batch: conv plans run their batched
/// schedule with filter residency (`KernelPlan::batched_resident`), so
/// FMA/byte is the honest *post-residency* ratio — filter bytes a
/// resident layer does not re-stream leave the denominator.  At
/// `batch = 1` this degenerates to per-image pricing.
pub fn batched_model_rows(spec: &GpuSpec, batch: usize) -> Vec<RooflineRow> {
    assert!(batch >= 1, "batch must be >= 1");
    MODEL_NAMES
        .iter()
        .map(|name| {
            let g = model_graph(name).expect("canonical model name");
            let mut fma = 0.0;
            let mut conv_loads = 0.0;
            let mut conv_stores = 0.0;
            let mut conv_charged = 0.0;
            let mut glue = 0.0;
            for n in g.nodes() {
                match &n.op {
                    Op::Conv { conv, epilogue } => {
                        let plan = backend::dispatch_fused_op_plan(conv, *epilogue, spec)
                            .batched_resident(batch, spec);
                        let b = crate::gpusim::simulate_detailed(spec, &plan);
                        fma += plan.total_fma;
                        conv_loads += plan.dram_load_bytes();
                        conv_stores += plan.output_bytes + plan.epilogue_read_bytes;
                        conv_charged += plan.dram_load_bytes()
                            + b.writeback_cycles * spec.bytes_per_cycle();
                    }
                    _ => glue += node_glue_bytes(&g, n.id) * batch as f64,
                }
            }
            let report =
                crate::graph::execute_batched(&g, spec, backend::dispatch_fused_op_plan, batch);
            let secs = report.total_seconds.max(f64::MIN_POSITIVE);
            let gflops = 2.0 * fma / secs / 1e9;
            let flops_frac = 2.0 * fma / secs / spec.peak_flops();
            let bw_charged = (conv_charged + glue) / secs / 1e9 / spec.bandwidth_gb_s;
            let bw_total =
                (conv_loads + conv_stores + glue) / secs / 1e9 / spec.bandwidth_gb_s;
            RooflineRow {
                label: format!("{name} xb{batch}"),
                backend: "dispatched".to_string(),
                staging: "-".to_string(),
                fma_per_byte: fma / conv_loads.max(1.0),
                gflops,
                flops_pct: 100.0 * flops_frac,
                bw_charged_pct: 100.0 * bw_charged,
                bw_total_pct: 100.0 * bw_total,
                bottleneck: if bw_total >= flops_frac { "memory" } else { "compute" }.to_string(),
            }
        })
        .collect()
}

/// Render rows as the fixed-width table EXPERIMENTS pins.
pub fn roofline_table(rows: &[RooflineRow]) -> Table {
    let mut t = Table::new(&[
        "workload",
        "backend",
        "s/load",
        "FMA/B",
        "GFLOP/s",
        "flops %",
        "bw % chg",
        "bw % tot",
        "bottleneck",
    ]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            r.backend.clone(),
            r.staging.clone(),
            format!("{:.2}", r.fma_per_byte),
            format!("{:.0}", r.gflops),
            format!("{:.1}", r.flops_pct),
            format!("{:.1}", r.bw_charged_pct),
            format!("{:.1}", r.bw_total_pct),
            r.bottleneck.clone(),
        ]);
    }
    t
}

/// Rows as a JSON array (the `--json` path and BENCH emission).
pub fn rows_json(rows: &[RooflineRow]) -> Json {
    Json::Arr(rows.iter().map(|r| r.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn fig_suites_produce_full_row_sets_with_sane_fractions() {
        let g = gtx_1080ti();
        let f4 = fig4_rows(&g);
        let f5 = fig5_rows(&g);
        assert_eq!(f4.len(), suites::fig4_suite().len());
        assert_eq!(f5.len(), suites::fig5_suite().len());
        for r in f4.iter().chain(&f5) {
            assert!(r.fma_per_byte > 0.0, "{}", r.label);
            // flops % may top 100 ONLY for winograd rows (they report
            // *effective*, direct-conv-equivalent FLOPs); every other
            // backend is bounded by the machine
            assert!(r.flops_pct > 0.0 && r.flops_pct.is_finite(), "{}", r.label);
            assert!(
                r.flops_pct <= 100.0 + 1e-9 || r.backend == "winograd",
                "{}: flops {}% from {}",
                r.label,
                r.flops_pct,
                r.backend
            );
            // the store-accounting fix: charged <= total <= 100 with
            // NO exceptions — the bus floor makes them structural
            assert!(r.bw_charged_pct > 0.0, "{}", r.label);
            assert!(
                r.bw_charged_pct <= r.bw_total_pct + 1e-9,
                "{}: charged {} > total {}",
                r.label,
                r.bw_charged_pct,
                r.bw_total_pct
            );
            assert!(r.bw_total_pct <= 100.0 + 1e-9, "{}: bw {}", r.label, r.bw_total_pct);
            assert!(!r.backend.is_empty());
            // staging column is always a depth/loading pair for
            // dispatched single plans
            assert!(r.staging.contains('/'), "{}: staging {:?}", r.label, r.staging);
        }
    }

    #[test]
    fn model_rows_cover_all_models_and_multi_channel_beats_single_on_ratio() {
        let g = gtx_1080ti();
        let rows = model_rows(&g);
        assert_eq!(rows.len(), MODEL_NAMES.len());
        for r in &rows {
            assert!(r.fma_per_byte > 0.0, "{}", r.label);
            assert!(r.gflops > 0.0);
            assert!(r.bottleneck == "memory" || r.bottleneck == "compute");
        }
        // VGG's 3x3 multi-channel stacks sustain a far higher
        // FMA-per-byte than MobileNet's depthwise-heavy body — the
        // paper's core claim about data reuse, visible in the report
        let vgg = rows.iter().find(|r| r.label == "vgg16").unwrap();
        let mob = rows.iter().find(|r| r.label == "mobilenet_v1").unwrap();
        assert!(vgg.fma_per_byte > mob.fma_per_byte);
    }

    #[test]
    fn batched_rows_report_post_residency_intensity() {
        let g = gtx_1080ti();
        let per_image = batched_model_rows(&g, 1);
        let batched = batched_model_rows(&g, 16);
        assert_eq!(batched.len(), MODEL_NAMES.len());
        // at batch 1 the batched pricing IS model_rows' per-image pricing
        for (a, b) in per_image.iter().zip(model_rows(&g)) {
            assert!((a.fma_per_byte - b.fma_per_byte).abs() < 1e-9, "{}", a.label);
        }
        for (b1, b16) in per_image.iter().zip(&batched) {
            // residency can only strip filter bytes from the
            // denominator, never add traffic: intensity is monotone
            assert!(
                b16.fma_per_byte >= b1.fma_per_byte - 1e-9,
                "{}: xb16 {} < xb1 {}",
                b16.label,
                b16.fma_per_byte,
                b1.fma_per_byte
            );
            assert!(b16.bw_total_pct <= 100.0 + 1e-9, "{}", b16.label);
        }
    }

    #[test]
    fn table_renders_every_row() {
        let g = gtx_1080ti();
        let rows = model_rows(&g);
        let s = roofline_table(&rows).to_string();
        for r in &rows {
            assert!(s.contains(&r.label));
        }
    }
}
