//! Roofline counters for one kernel execution — the paper's own
//! figure of merit (FMAs per byte fetched from global memory, §1) made
//! first-class, plus achieved-vs-peak fractions against the `GpuSpec`
//! and the cycle decomposition from `gpusim::simulate_detailed`.
//!
//! Nothing here recomputes timing: a `Roofline` is a pure projection of
//! a `SimBreakdown`, so measuring a plan costs one extra `simulate`
//! call *outside* any timed path and can never drift from the pinned
//! numbers (`simulate` IS `simulate_detailed(..).result`).

use crate::gpusim::{simulate_detailed, GpuSpec, KernelPlan, SimBreakdown};
use crate::util::json::Json;

/// One kernel's position against the machine's roofline.
#[derive(Clone, Debug)]
pub struct Roofline {
    pub kernel: String,
    pub gpu: &'static str,
    pub seconds: f64,
    pub cycles: f64,
    /// bytes fetched from global memory (chip-wide)
    pub dram_load_bytes: f64,
    /// bytes written back to global memory (the plan's output)
    pub dram_store_bytes: f64,
    /// fused writeback epilogue tag ("none" when unfused)
    pub epilogue: String,
    /// bytes the fused epilogue streams in through the writeback tail
    /// (the residual operand of `AddResidual`; 0 otherwise)
    pub epilogue_read_bytes: f64,
    pub total_fma: f64,
    /// the paper's figure of merit: FMAs per *fetched* byte
    pub fma_per_byte: f64,
    pub gflops: f64,
    /// achieved fraction of peak FLOP/s
    pub flops_frac: f64,
    /// achieved DRAM bandwidth (loads + stores), GB/s
    pub bw_gb_s: f64,
    /// bandwidth the timing model actually charged (loads + the charged
    /// writeback cycles converted back to bytes), GB/s
    pub bw_charged_gb_s: f64,
    /// charged fraction of peak DRAM bandwidth — what the model billed
    pub bw_frac_charged: f64,
    /// ALL-traffic fraction of peak DRAM bandwidth (loads + stores).
    /// Since the DRAM bus floor entered the timing model, charged <=
    /// total <= 1.0 structurally: the model can no longer claim a
    /// kernel moved more bytes per second than the bus can carry.
    pub bw_frac_total: f64,
    /// resident threads per SM over the device maximum
    pub occupancy: f64,
    /// fraction of SMs with work
    pub sm_frac: f64,
    /// cycle shares of the critical path.  Load and compute overlap in
    /// the prefetch pipeline, so load + compute + stall + writeback +
    /// launch need NOT sum to 1 — the shares say where cycles were
    /// *spent*, not a partition.
    pub load_frac: f64,
    pub compute_frac: f64,
    pub stall_frac: f64,
    pub writeback_frac: f64,
    pub launch_frac: f64,
    pub latency_hidden: bool,
    pub bottleneck: &'static str,
}

impl Roofline {
    /// Simulate `plan` on `spec` and project the counters.
    pub fn measure(spec: &GpuSpec, plan: &KernelPlan) -> Roofline {
        Roofline::from_breakdown(spec, plan, &simulate_detailed(spec, plan))
    }

    /// Project counters from an already-computed breakdown (no timing
    /// work here at all).
    pub fn from_breakdown(spec: &GpuSpec, plan: &KernelPlan, b: &SimBreakdown) -> Roofline {
        let r = &b.result;
        let cycles = r.cycles.max(1.0);
        let secs = r.seconds.max(f64::MIN_POSITIVE);
        let traffic = r.dram_load_bytes + plan.output_bytes + plan.epilogue_read_bytes;
        let bw_gb_s = traffic / secs / 1e9;
        let charged = r.dram_load_bytes + b.writeback_cycles * spec.bytes_per_cycle();
        let bw_charged_gb_s = charged / secs / 1e9;
        Roofline {
            kernel: r.name.clone(),
            gpu: spec.name,
            seconds: r.seconds,
            cycles: r.cycles,
            dram_load_bytes: r.dram_load_bytes,
            dram_store_bytes: plan.output_bytes,
            epilogue: plan.epilogue.tag(),
            epilogue_read_bytes: plan.epilogue_read_bytes,
            total_fma: plan.total_fma,
            fma_per_byte: r.fma_per_byte,
            gflops: r.gflops,
            flops_frac: r.efficiency,
            bw_gb_s,
            bw_charged_gb_s,
            bw_frac_charged: bw_charged_gb_s / spec.bandwidth_gb_s,
            bw_frac_total: bw_gb_s / spec.bandwidth_gb_s,
            occupancy: plan.threads_per_sm as f64 / spec.max_threads_per_sm as f64,
            sm_frac: r.sm_utilization,
            load_frac: b.load_cycles / cycles,
            compute_frac: b.compute_cycles / cycles,
            stall_frac: b.stall_cycles / cycles,
            writeback_frac: b.writeback_cycles / cycles,
            launch_frac: b.launch_overhead_cycles / cycles,
            latency_hidden: r.latency_hidden,
            bottleneck: r.bottleneck,
        }
    }

    /// The compact attribute set span emitters attach to execute spans.
    pub fn attrs(&self) -> Vec<(String, Json)> {
        vec![
            ("kernel".to_string(), self.kernel.as_str().into()),
            ("fma_per_byte".to_string(), self.fma_per_byte.into()),
            ("gflops".to_string(), self.gflops.into()),
            ("flops_frac".to_string(), self.flops_frac.into()),
            ("bw_gb_s".to_string(), self.bw_gb_s.into()),
            ("bw_frac_charged".to_string(), self.bw_frac_charged.into()),
            ("bw_frac_total".to_string(), self.bw_frac_total.into()),
            ("dram_load_bytes".to_string(), self.dram_load_bytes.into()),
            ("dram_store_bytes".to_string(), self.dram_store_bytes.into()),
            ("epilogue".to_string(), self.epilogue.as_str().into()),
            ("epilogue_read_bytes".to_string(), self.epilogue_read_bytes.into()),
            ("occupancy".to_string(), self.occupancy.into()),
            ("bottleneck".to_string(), self.bottleneck.into()),
        ]
    }

    /// The full counter set, for `--json` outputs.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("kernel", self.kernel.as_str().into())
            .set("gpu", self.gpu.into())
            .set("seconds", self.seconds.into())
            .set("cycles", self.cycles.into())
            .set("dram_load_bytes", self.dram_load_bytes.into())
            .set("dram_store_bytes", self.dram_store_bytes.into())
            .set("epilogue", self.epilogue.as_str().into())
            .set("epilogue_read_bytes", self.epilogue_read_bytes.into())
            .set("total_fma", self.total_fma.into())
            .set("fma_per_byte", self.fma_per_byte.into())
            .set("gflops", self.gflops.into())
            .set("flops_frac", self.flops_frac.into())
            .set("bw_gb_s", self.bw_gb_s.into())
            .set("bw_charged_gb_s", self.bw_charged_gb_s.into())
            .set("bw_frac_charged", self.bw_frac_charged.into())
            .set("bw_frac_total", self.bw_frac_total.into())
            .set("occupancy", self.occupancy.into())
            .set("sm_frac", self.sm_frac.into())
            .set("load_frac", self.load_frac.into())
            .set("compute_frac", self.compute_frac.into())
            .set("stall_frac", self.stall_frac.into())
            .set("writeback_frac", self.writeback_frac.into())
            .set("launch_frac", self.launch_frac.into())
            .set("latency_hidden", self.latency_hidden.into())
            .set("bottleneck", self.bottleneck.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvProblem;
    use crate::gpusim::gtx_1080ti;
    use crate::plans::paper_plan_for;

    #[test]
    fn counters_are_consistent_with_the_plan_and_spec() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(56, 256, 3);
        let plan = paper_plan_for(&p, &g);
        let roof = Roofline::measure(&g, &plan);
        assert_eq!(roof.gpu, g.name);
        assert!(roof.seconds > 0.0);
        assert!((roof.dram_load_bytes - plan.dram_load_bytes()).abs() < 1e-6);
        assert!((roof.fma_per_byte - plan.fma_per_byte()).abs() < 1e-9);
        assert!(roof.flops_frac > 0.0 && roof.flops_frac <= 1.0);
        // the store-accounting fix: with the DRAM bus floor in the
        // timing model, charged <= total <= 1.0 with no exceptions —
        // no kernel can claim more bytes/s than the bus carries
        assert!(roof.bw_frac_charged > 0.0);
        assert!(
            roof.bw_frac_charged <= roof.bw_frac_total + 1e-9,
            "charged {} > total {}",
            roof.bw_frac_charged,
            roof.bw_frac_total
        );
        assert!(roof.bw_frac_total <= 1.0 + 1e-9, "bw_frac_total {}", roof.bw_frac_total);
        assert!(roof.occupancy > 0.0 && roof.occupancy <= 1.0);
        // achieved bandwidth equals traffic over time by construction
        let traffic =
            roof.dram_load_bytes + roof.dram_store_bytes + roof.epilogue_read_bytes;
        assert!((roof.bw_gb_s - traffic / roof.seconds / 1e9).abs() < 1e-9);
        assert_eq!(roof.epilogue, "none");
        assert_eq!(roof.epilogue_read_bytes, 0.0);
    }

    #[test]
    fn fused_plans_report_their_epilogue_traffic() {
        use crate::gpusim::Epilogue;
        let g = gtx_1080ti();
        let p = ConvProblem::multi(64, 28, 128, 3);
        let plan = paper_plan_for(&p, &g);
        let fused = plan.fused(Epilogue::AddResidual, (p.oy(), p.ox()));
        let roof = Roofline::measure(&g, &fused);
        assert_eq!(roof.epilogue, "add");
        assert!((roof.epilogue_read_bytes - plan.output_bytes).abs() < 1e-6);
        // the residual stream is real traffic: total bw fraction rises
        let base = Roofline::measure(&g, &plan);
        assert!(roof.bw_gb_s > 0.0 && roof.seconds >= base.seconds);
        let pooled = plan.fused(Epilogue::MaxPoolWriteback { k: 2, stride: 2 }, (p.oy(), p.ox()));
        let proof = Roofline::measure(&g, &pooled);
        assert_eq!(proof.epilogue, "pool2s2");
        assert!(proof.dram_store_bytes < base.dram_store_bytes);
        let j = proof.to_json().render();
        assert!(j.contains("\"epilogue\""), "{j}");
    }

    #[test]
    fn batched_variant_raises_fma_per_byte_never_lowers() {
        // filters re-streamed per image is the conservative model, but
        // launch amortization means per-image seconds shrink; the ratio
        // itself is a pure plan property and must match the plan's
        let g = gtx_1080ti();
        let p = ConvProblem::multi(64, 28, 128, 3);
        let plan = paper_plan_for(&p, &g).batched(4);
        let roof = Roofline::measure(&g, &plan);
        assert!((roof.fma_per_byte - plan.fma_per_byte()).abs() < 1e-9);
        assert!(roof.cycles > 0.0);
    }
}
