//! Summary statistics for the in-repo bench harness (criterion is not in
//! the offline vendor set).
//!
//! Hardened edge behavior (these feed CLI/bench reporting paths that
//! must never panic on a degenerate run):
//!  * sorting is NaN-safe (`f64::total_cmp` — NaNs order last instead
//!    of panicking the comparator);
//!  * `Summary::of(&[])` is the all-zero summary with `n = 0`;
//!  * `geomean(&[])` is 1.0 (the empty product's identity);
//!  * `mean(&[])` is 0.0;
//!  * `percentile_sorted(&[], _)` is 0.0, and `p` is clamped to
//!    [0, 100].

/// Summary of a sample of measurements (times in seconds, or any unit).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// The defined empty-sample summary (`n = 0`, all stats zero).
    pub fn empty() -> Summary {
        Summary { n: 0, mean: 0.0, std_dev: 0.0, min: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 }
    }

    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::empty();
        }
        let mut xs: Vec<f64> = samples.to_vec();
        xs.sort_by(|a, b| a.total_cmp(b));
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: xs[0],
            p50: percentile_sorted(&xs, 50.0),
            p95: percentile_sorted(&xs, 95.0),
            p99: percentile_sorted(&xs, 99.0),
            max: xs[n - 1],
        }
    }
}

/// Nearest-rank percentile on a pre-sorted slice.  `p` is clamped to
/// [0, 100]; the empty slice yields 0.0 instead of panicking.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = (p / 100.0 * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Geometric mean — how the paper aggregates speedups ("average 2.6X").
/// The empty slice yields 1.0: the multiplicative identity, so folding
/// suite reports over zero workloads is a no-op instead of a panic.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Arithmetic mean (0.0 on the empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn summary_unsorted_input() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_known() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn empty_inputs_are_defined_not_panics() {
        assert_eq!(Summary::of(&[]), Summary::empty());
        assert_eq!(Summary::of(&[]).n, 0);
        assert_eq!(geomean(&[]), 1.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    #[test]
    fn nan_samples_sort_instead_of_panicking() {
        // total_cmp orders NaN greatest: min/median stay meaningful,
        // max reflects the poisoned tail, and nothing unwinds
        let s = Summary::of(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn percentile_clamps_out_of_range_p() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&xs, -5.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 250.0), 3.0);
    }
}
