//! Offline stand-ins for crates missing from the vendored registry:
//! `rng` (rand), `stats`+`bench` (criterion), `cli` (clap), `prop`
//! (proptest), `json` (serde_json). Each is the minimal surface the rest
//! of the repo needs, fully unit-tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
