//! In-repo property-test harness (proptest is not in the offline vendor
//! set).  Deterministic seeded case generation + a simple halving shrinker
//! for integer tuples; used by rust/tests/proptests.rs on the simulator
//! and analytic-model invariants.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE }
    }
}

/// Outcome of a single case check.
pub type CheckResult = Result<(), String>;

/// Run `prop` against `cases` generated inputs; on failure, attempt to
/// shrink via `shrink` (returns candidate smaller inputs) and panic with
/// the smallest failing case found.
pub fn check<T, G, P, S>(cfg: &Config, mut gen: G, mut prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> CheckResult,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first shrink candidate
            // that still fails, up to a bounded number of rounds.
            let mut best = input.clone();
            let mut best_msg = msg;
            'shrinking: for _ in 0..64 {
                for cand in shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'shrinking;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {seed}): {best_msg}\n  minimal input: {best:?}",
                seed = cfg.seed
            );
        }
    }
}

/// Convenience: property over inputs with no shrinking.
pub fn check_no_shrink<T, G, P>(cfg: &Config, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> CheckResult,
{
    check(cfg, gen, prop, |_| vec![]);
}

/// Halving shrinker for a usize with a lower bound.
pub fn shrink_usize(x: usize, lo: usize) -> Vec<usize> {
    let mut out = vec![];
    if x > lo {
        out.push(lo);
        let mid = lo + (x - lo) / 2;
        if mid != lo && mid != x {
            out.push(mid);
        }
        if x - 1 != lo {
            out.push(x - 1);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check_no_shrink(
            &Config { cases: 64, seed: 1 },
            |r| r.range_usize(0, 100),
            |&x| if x <= 100 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check_no_shrink(
            &Config { cases: 64, seed: 2 },
            |r| r.range_usize(0, 100),
            |&x| if x < 40 { Ok(()) } else { Err(format!("{x} >= 40")) },
        );
    }

    #[test]
    fn shrinker_reduces_failure() {
        // Property "x < 40" fails for x >= 40; the minimal failing input
        // reachable through shrink_usize(_, 0) should be well below the
        // first random failure.
        let result = std::panic::catch_unwind(|| {
            check(
                &Config { cases: 64, seed: 3 },
                |r| r.range_usize(0, 1000),
                |&x| if x < 40 { Ok(()) } else { Err(format!("{x}")) },
                |&x| shrink_usize(x, 0),
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // shrank to exactly the boundary
        assert!(msg.contains("minimal input: 40"), "msg: {msg}");
    }

    #[test]
    fn shrink_usize_candidates() {
        assert!(shrink_usize(10, 0).contains(&0));
        assert!(shrink_usize(10, 0).contains(&5));
        assert!(shrink_usize(10, 0).contains(&9));
        assert!(shrink_usize(0, 0).is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let mut v = vec![];
            check_no_shrink(
                &Config { cases: 16, seed },
                |r| r.range_usize(0, 1_000_000),
                |&x| {
                    v.push(x);
                    Ok(())
                },
            );
            v
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
