//! Minimal bench harness (`harness = false` targets) — criterion is not in
//! the offline vendor set, so benches use this: warmup, timed iterations,
//! summary stats, and a uniform table printer shared by every
//! figure/table-regeneration bench.

use std::time::Instant;

use super::stats::Summary;

/// Time `f` for `iters` iterations after `warmup` warmup runs.
/// Returns per-iteration seconds.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples
}

/// Run + summarize in one call.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, f: F) -> Summary {
    Summary::of(&time_fn(warmup, iters, f))
}

/// Fixed-width table printer used by all bench binaries so their output
/// reads like the paper's tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        print!("{self}");
    }
}

/// Render the aligned table ( `.to_string()` comes via `ToString`).
impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        writeln!(f, "{}", fmt_row(&self.headers))?;
        writeln!(f, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)))?;
        for row in &self.rows {
            writeln!(f, "{}", fmt_row(row))?;
        }
        Ok(())
    }
}

/// Format a byte count as MiB with two decimals (memory-plan tables).
pub fn fmt_mib(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / (1 << 20) as f64)
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_iterations() {
        let mut n = 0;
        let samples = time_fn(2, 5, || n += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(n, 7); // warmup + timed
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn bench_returns_summary() {
        let s = bench(0, 3, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.n, 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["10".into(), "200".into()]);
        let s = t.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains('a') && lines[0].contains("bbb"));
        assert!(lines[3].contains("10") && lines[3].contains("200"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn fmt_mib_two_decimals() {
        assert_eq!(fmt_mib(1 << 20), "1.00");
        assert_eq!(fmt_mib(3 * (1 << 19)), "1.50");
        assert_eq!(fmt_mib(0), "0.00");
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
