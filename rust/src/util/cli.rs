//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args —
//! enough for the coordinator binary, the examples and the benches.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("integer flag")).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect("float flag")).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--k", "v", "--x=y"]);
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.get("x"), Some("y"));
    }

    #[test]
    fn bare_flag_is_true() {
        let a = parse(&["--verbose", "--n", "3"]);
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn bare_flag_before_another_flag() {
        let a = parse(&["--a", "--b", "1"]);
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.get_usize("b", 0), 1);
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["cmd", "--k", "v", "arg2"]);
        assert_eq!(a.positional(), &["cmd".to_string(), "arg2".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("x", 1.5), 1.5);
    }
}
