//! Deterministic PRNG (SplitMix64 + xoshiro256**), no external crates.
//!
//! The offline vendor set has no `rand`; this is the standard public-domain
//! xoshiro256** generator seeded through SplitMix64, used by the workload
//! generators, the property-test harness and the synthetic data makers.

/// SplitMix64 — used to expand a single u64 seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = hi - lo + 1;
        // Lemire-style rejection-free-enough reduction; bias is negligible
        // for the test-case spans used here (all << 2^32).
        lo + (self.next_u64() % span)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Vector of standard-normal f32s — synthetic images/filters.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal() as f32).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.range_u64(0, 3) {
                0 => lo_seen = true,
                3 => hi_seen = true,
                1 | 2 => {}
                _ => panic!("out of range"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..50_000).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn choose_covers_all_elements() {
        let mut r = Rng::new(11);
        let xs = [1, 2, 3];
        let mut seen = [false; 3];
        for _ in 0..1000 {
            seen[*r.choose(&xs) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
