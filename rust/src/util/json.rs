//! Minimal JSON writer (serde is not in the offline vendor set).
//!
//! Benches and the coordinator's metrics endpoint emit machine-readable
//! results with this; only writing is needed (nothing in the repo parses
//! JSON back — the manifest uses a simpler key=value format).

use std::collections::BTreeMap;

/// A JSON value. BTreeMap keeps object key order deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: Json) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object");
        }
        self
    }

    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".into(),
            Json::Bool(b) => b.to_string(),
            Json::Num(x) => {
                if x.is_finite() {
                    // integers render without trailing .0
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        format!("{}", *x as i64)
                    } else {
                        format!("{x}")
                    }
                } else {
                    "null".into() // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => escape(s),
            Json::Arr(xs) => {
                let inner: Vec<String> = xs.iter().map(|x| x.render()).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Obj(m) => {
                let inner: Vec<String> =
                    m.iter().map(|(k, v)| format!("{}:{}", escape(k), v.render())).collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(3.0).render(), "3");
        assert_eq!(Json::from(3.5).render(), "3.5");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn object_deterministic_order() {
        let j = Json::obj().set("b", 1.0.into()).set("a", 2.0.into());
        assert_eq!(j.render(), "{\"a\":2,\"b\":1}");
    }

    #[test]
    fn array_and_nesting() {
        let j = Json::Arr(vec![Json::from(1.0), Json::obj().set("k", "v".into())]);
        assert_eq!(j.render(), "[1,{\"k\":\"v\"}]");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
    }
}
