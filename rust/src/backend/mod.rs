//! Unified backend layer: every way this stack can execute a
//! convolution — the paper's kernels (tuned and §3 closed-form), the
//! CPU reference, and all four comparison baselines — behind ONE
//! `ConvBackend` trait, plus the `dispatch` module that picks the
//! fastest legal backend per problem.
//!
//! Motivation (cuConv, arXiv 2103.16234; kubecl's runtime-per-backend
//! split): no single convolution algorithm dominates across CNN layer
//! shapes.  Implicit GEMM wins some large-map layers, Winograd wins
//! big K=3 layers, the paper's direct kernels win small maps and K=1 —
//! cuDNN's own advantage is *per-problem algorithm choice*.  Before
//! this layer, the baselines in `rust/src/baselines/` were bench-only
//! dead ends that could never be selected; now each is a first-class
//! backend with an honest `supports()` envelope, and the dispatcher
//! (`backend::dispatch`) can route any suite problem to whichever
//! algorithm the simulator prices fastest, never losing to the
//! paper-kernel-only path (the paper-tuned backend is always in the
//! candidate set).
//!
//! Each backend answers four questions:
//!  * `supports` — can this algorithm run this problem at all?  (e.g.
//!    Winograd F(2x2,3x3) is K=3-only, [16]'s 128-B fetch discipline is
//!    only defined for the multi-channel stride-fixed schedule);
//!  * `plan` — the `KernelPlan` (per-SM round schedule) it would
//!    execute, timed like every other plan by `gpusim::simulate`;
//!  * `cycles`/`seconds` + batched variants — its simulated cost, the
//!    quantity the dispatcher, graph executor and fleet pricing use;
//!  * `execute_reference` — eq.(1) computed in the backend's own
//!    traversal order (im2col gather, strip-mined, 2x2-tiled, ...),
//!    bit-identical to `conv::cpu::conv2d_multi_cpu` by construction:
//!    every output element accumulates its terms in the same
//!    (c asc, i asc, j asc) order into one f64.  The differential
//!    tests (`rust/tests/backend_difftests.rs`) pin that identity, so
//!    a backend's index arithmetic (halos, tiles, segments) is checked
//!    against the oracle even though its *timing* model is analytic.
//!    (Transform-domain numerics — Winograd/FFT — live in
//!    `python/compile/kernels/`; the Rust side's contract is the
//!    direct-conv semantics every algorithm must reproduce.)

pub mod dispatch;
mod impls;
pub mod reference;

pub use dispatch::{
    batched_dispatch_seconds, batched_op_dispatch_seconds, batched_op_dispatched,
    dispatch_advice, dispatch_batched_plan, dispatch_fused_op_plan, dispatch_op_plan,
    dispatch_plan, dispatched, fused_op_dispatched, op_dispatch_advice, op_dispatched, Decision,
    Dispatcher,
};
pub use impls::{
    CpuReference, CudnnProxy, Dac17, FftConv, PaperClosedForm, PaperTuned, Tan128, Winograd,
    BACKEND_NAMES,
};

use crate::conv::{op as convop, BatchedConv, BatchedConvOp, ConvOp, ConvProblem};
use crate::gpusim::{simulate, Epilogue, GpuSpec, KernelPlan};

/// How a backend covers a `ConvOp` (the op layer's honest analogue of
/// `supports()`): natively — its own schedule handles the op's
/// stride/pad/groups — or through the exact lowering (pad folded into
/// the map, groups batched under one launch, stride-1 output computed
/// in full and decimated).  The dispatcher prices native routes
/// against the paper-tuned LOWERED floor, which it structurally never
/// loses to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpCoverage {
    /// the backend's own schedule expresses the op (no wasted work)
    Native,
    /// served through the exact lowering onto stride-1/valid/dense
    Lowered,
    /// neither the op nor its lowered unit is in the support envelope
    Unsupported,
}

impl OpCoverage {
    pub fn supported(&self) -> bool {
        !matches!(self, OpCoverage::Unsupported)
    }
}

/// One convolution algorithm as an executable backend.  Object-safe:
/// the dispatcher holds `Box<dyn ConvBackend>` and iterates the
/// registry per problem.
pub trait ConvBackend: Send + Sync {
    /// Stable identifier — the tag `PlanCache` dispatch entries and
    /// `Response.plan` advice carry (must be one of `BACKEND_NAMES`).
    fn name(&self) -> &'static str;

    /// Honest support envelope: `plan` may be called only on problems
    /// this returns `true` for (`plan` panics otherwise, like the
    /// underlying builders always have).
    fn supports(&self, p: &ConvProblem) -> bool;

    /// The per-SM execution schedule this backend would run.
    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan;

    /// The batch-`n` schedule: one launch, warm pipeline
    /// (`KernelPlan::batched` — same contract for every backend).
    fn batched_plan(&self, b: &BatchedConv, spec: &GpuSpec) -> KernelPlan {
        assert!(b.valid(), "invalid batched problem");
        self.plan(&b.problem, spec).batched(b.n)
    }

    /// Simulated execution cycles on `spec` — the dispatcher's ranking
    /// quantity.
    fn cycles(&self, p: &ConvProblem, spec: &GpuSpec) -> f64 {
        simulate(spec, &self.plan(p, spec)).cycles
    }

    /// `cycles` in seconds.
    fn seconds(&self, p: &ConvProblem, spec: &GpuSpec) -> f64 {
        spec.cycles_to_secs(self.cycles(p, spec))
    }

    /// Simulated cycles of the batch-`n` schedule.
    fn batched_cycles(&self, b: &BatchedConv, spec: &GpuSpec) -> f64 {
        simulate(spec, &self.batched_plan(b, spec)).cycles
    }

    /// `batched_cycles` in seconds — what fleet queues accumulate.
    fn batched_seconds(&self, b: &BatchedConv, spec: &GpuSpec) -> f64 {
        spec.cycles_to_secs(self.batched_cycles(b, spec))
    }

    /// eq.(1) in this backend's traversal order — bit-identical to
    /// `conv::cpu::conv2d_multi_cpu` on every supported problem (the
    /// differential-test contract; see the module docs).
    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32>;

    // ---- the op layer: stride / padding / groups ----

    /// Op coverage.  Default: dense ops inherit `supports()` natively;
    /// everything else is served through the exact lowering whenever
    /// the lowered unit is in the envelope.  The paper backends
    /// override this — their strip schedules handle stride natively
    /// (decimated output schedule) and groups natively (side-by-side
    /// groups on idle SMs).
    fn op_coverage(&self, op: &ConvOp) -> OpCoverage {
        if !op.valid() {
            return OpCoverage::Unsupported;
        }
        if op.is_dense() {
            return if self.supports(&op.core) {
                OpCoverage::Native
            } else {
                OpCoverage::Unsupported
            };
        }
        if self.supports(&op.lower().unit) {
            OpCoverage::Lowered
        } else {
            OpCoverage::Unsupported
        }
    }

    /// The schedule this backend would run for an op.  Default: the
    /// naive lowered schedule — the per-group unit plan repeated under
    /// ONE launch (`KernelPlan::batched`), computing the full stride-1
    /// output.  May only be called where `op_coverage` is supported.
    fn op_plan(&self, op: &ConvOp, spec: &GpuSpec) -> KernelPlan {
        assert!(
            self.op_coverage(op).supported(),
            "{} cannot run {}",
            self.name(),
            op.label()
        );
        if op.is_dense() {
            return self.plan(&op.core, spec);
        }
        let l = op.lower();
        let unit = self.plan(&l.unit, spec);
        let mut plan = unit.batched(l.groups);
        plan.name = op_plan_name(&unit.name, op, false);
        plan
    }

    /// The fused-epilogue op schedule: this backend's op plan with `ep`
    /// absorbed into the writeback tail (`KernelPlan::fused` on the
    /// op's true output map).  `Epilogue::None` IS `op_plan` — the
    /// unfused path stays the structural floor of the fused axis.
    fn fused_op_plan(&self, op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> KernelPlan {
        self.op_plan(op, spec).fused(ep, (op.oy(), op.ox()))
    }

    /// Simulated cycles of the fused op schedule on `spec`.
    fn fused_op_cycles(&self, op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> f64 {
        simulate(spec, &self.fused_op_plan(op, ep, spec)).cycles
    }

    /// The batch-`n` op schedule (one launch, warm pipeline).
    fn batched_op_plan(&self, b: &BatchedConvOp, spec: &GpuSpec) -> KernelPlan {
        assert!(b.valid(), "invalid batched op");
        self.op_plan(&b.op, spec).batched(b.n)
    }

    /// Simulated cycles of the op schedule on `spec`.
    fn op_cycles(&self, op: &ConvOp, spec: &GpuSpec) -> f64 {
        simulate(spec, &self.op_plan(op, spec)).cycles
    }

    /// `op_cycles` in seconds.
    fn op_seconds(&self, op: &ConvOp, spec: &GpuSpec) -> f64 {
        spec.cycles_to_secs(self.op_cycles(op, spec))
    }

    /// Simulated cycles of the batch-`n` op schedule.
    fn batched_op_cycles(&self, b: &BatchedConvOp, spec: &GpuSpec) -> f64 {
        simulate(spec, &self.batched_op_plan(b, spec)).cycles
    }

    /// `batched_op_cycles` in seconds — what fleet shards accumulate.
    fn batched_op_seconds(&self, b: &BatchedConvOp, spec: &GpuSpec) -> f64 {
        spec.cycles_to_secs(self.batched_op_cycles(b, spec))
    }

    /// Op semantics through this backend's own unit traversal: the
    /// exact lowering (zero-embed per group -> `execute_reference` on
    /// the unit -> decimate -> concatenate).  Bit-identical to
    /// `conv::conv2d_op_cpu` on every supported op, because
    /// `execute_reference` is bit-identical to the oracle on the unit
    /// and the lowering identities are exact (see `conv::op`).
    fn execute_op_reference(&self, op: &ConvOp, image: &[f32], filters: &[f32]) -> Vec<f32> {
        assert!(
            self.op_coverage(op).supported(),
            "{} cannot run {}",
            self.name(),
            op.label()
        );
        convop::conv2d_op_lowered_with(op, image, filters, &|p, img, flt| {
            self.execute_reference(p, img, flt)
        })
    }

    /// Batched op reference: `n` independent single-image op runs.
    fn execute_op_reference_batched(
        &self,
        b: &BatchedConvOp,
        images: &[f32],
        filters: &[f32],
    ) -> Vec<f32> {
        assert!(b.valid(), "invalid batched op");
        assert_eq!(images.len(), b.map_elems(), "batched op image size");
        let per_in = b.op.map_elems();
        let mut out = Vec::with_capacity(b.out_elems());
        for i in 0..b.n {
            out.extend(self.execute_op_reference(
                &b.op,
                &images[i * per_in..(i + 1) * per_in],
                filters,
            ));
        }
        out
    }

    /// Batched reference semantics: definitionally `n` independent
    /// single-image runs (the same contract as `conv2d_batched_cpu`).
    fn execute_reference_batched(
        &self,
        b: &BatchedConv,
        images: &[f32],
        filters: &[f32],
    ) -> Vec<f32> {
        assert!(b.valid(), "invalid batched problem");
        assert_eq!(images.len(), b.map_elems(), "batched image size");
        let per_in = b.problem.map_elems();
        let per_out = b.problem.out_elems();
        let mut out = Vec::with_capacity(b.n * per_out);
        for i in 0..b.n {
            out.extend(self.execute_reference(
                &b.problem,
                &images[i * per_in..(i + 1) * per_in],
                filters,
            ));
        }
        out
    }
}

/// The op-plan display name: the unit plan's name plus the op's
/// schedule tags (" gG" for groups, " sS" for stride), with " lowered"
/// appended when the stride-1 output is computed in full and decimated
/// afterwards (the naive route) rather than natively shrunk.
pub(crate) fn op_plan_name(unit_name: &str, op: &ConvOp, native: bool) -> String {
    let mut s = unit_name.to_string();
    if op.groups > 1 {
        s.push_str(&format!(" g{}", op.groups));
    }
    if op.stride > 1 {
        s.push_str(&format!(" s{}", op.stride));
    }
    if !native && !op.is_dense() {
        s.push_str(" lowered");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gtx_1080ti;
    use crate::util::rng::Rng;

    #[test]
    fn default_batched_plan_matches_kernel_plan_batched() {
        let g = gtx_1080ti();
        let b = BatchedConv::new(ConvProblem::multi(16, 14, 16, 3), 4);
        let backend = PaperClosedForm;
        let via_trait = backend.batched_plan(&b, &g);
        let direct = backend.plan(&b.problem, &g).batched(4);
        assert_eq!(via_trait.name, direct.name);
        assert_eq!(via_trait.rounds.len(), direct.rounds.len());
        let diff = (backend.batched_cycles(&b, &g) - simulate(&g, &direct).cycles).abs();
        assert!(diff < 1e-9);
    }

    #[test]
    fn default_batched_reference_loops_single_images() {
        let p = ConvProblem::multi(3, 8, 4, 3);
        let b = BatchedConv::new(p, 3);
        let mut rng = Rng::new(11);
        let images = rng.normal_vec(b.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let backend = CpuReference;
        let batched = backend.execute_reference_batched(&b, &images, &filters);
        for i in 0..b.n {
            let single = backend.execute_reference(
                &p,
                &images[i * p.map_elems()..(i + 1) * p.map_elems()],
                &filters,
            );
            assert_eq!(
                &batched[i * p.out_elems()..(i + 1) * p.out_elems()],
                &single[..],
                "image {i}"
            );
        }
    }

    #[test]
    fn seconds_are_cycles_over_clock() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(8, 14, 16, 3);
        let backend = PaperClosedForm;
        let c = backend.cycles(&p, &g);
        assert!((backend.seconds(&p, &g) - g.cycles_to_secs(c)).abs() < 1e-18);
    }

    #[test]
    fn dense_op_coverage_and_plan_match_the_problem_path() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(16, 14, 16, 3);
        let op = ConvOp::dense(p);
        for b in [&PaperTuned as &dyn ConvBackend, &PaperClosedForm, &CudnnProxy] {
            assert_eq!(b.op_coverage(&op), OpCoverage::Native, "{}", b.name());
            assert_eq!(b.op_plan(&op, &g).name, b.plan(&p, &g).name, "{}", b.name());
            assert!((b.op_cycles(&op, &g) - b.cycles(&p, &g)).abs() < 1e-9);
        }
    }

    #[test]
    fn default_lowered_op_plan_batches_the_unit() {
        let g = gtx_1080ti();
        let op = ConvOp { core: ConvProblem::multi(8, 14, 8, 3), stride: 1, pad: 1, groups: 2 };
        let b = CudnnProxy;
        assert_eq!(b.op_coverage(&op), OpCoverage::Lowered);
        let plan = b.op_plan(&op, &g);
        assert!(plan.name.contains("g2") && plan.name.contains("lowered"), "{}", plan.name);
        let unit = b.plan(&op.lower().unit, &g);
        assert_eq!(plan.rounds.len(), 2 * unit.rounds.len());
    }

    #[test]
    fn op_coverage_respects_unit_envelopes() {
        // winograd's K=3 envelope applies to the lowered unit; a K=5
        // depthwise op is out, a K=3 depthwise op is in (single-channel
        // unit); tan128 rejects depthwise entirely (single-channel unit)
        let dw3 = ConvOp::depthwise(8, 14, 3, 1);
        let dw5 = ConvOp::depthwise(8, 14, 5, 1);
        assert!(Winograd.op_coverage(&dw3).supported());
        assert!(!Winograd.op_coverage(&dw5).supported());
        assert!(!Tan128.op_coverage(&dw3).supported());
        let invalid = ConvOp { core: ConvProblem::multi(3, 8, 4, 3), stride: 1, pad: 0, groups: 2 };
        assert_eq!(PaperTuned.op_coverage(&invalid), OpCoverage::Unsupported);
    }

    #[test]
    fn op_reference_bit_identical_to_generalized_oracle() {
        let mut rng = Rng::new(0x0A11);
        let ops = [
            ConvOp::same(ConvProblem::multi(4, 9, 6, 3)),
            ConvOp::strided(ConvProblem::multi(3, 11, 4, 3), 2, 1),
            ConvOp::depthwise(6, 10, 3, 2),
        ];
        for op in ops {
            let image = rng.normal_vec(op.map_elems());
            let filters = rng.normal_vec(op.filter_elems());
            let oracle = crate::conv::conv2d_op_cpu(&op, &image, &filters);
            for b in [&PaperTuned as &dyn ConvBackend, &CpuReference, &CudnnProxy] {
                let got = b.execute_op_reference(&op, &image, &filters);
                assert!(
                    got.len() == oracle.len()
                        && got.iter().zip(&oracle).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} diverges on {}",
                    b.name(),
                    op.label()
                );
            }
        }
    }

    #[test]
    fn batched_op_cycles_monotone_and_amortizing() {
        let g = gtx_1080ti();
        let op = ConvOp::strided(ConvProblem::multi(16, 28, 32, 3), 2, 1);
        let single = PaperTuned.batched_op_cycles(&BatchedConvOp::single(op), &g);
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8] {
            let c = PaperTuned.batched_op_cycles(&BatchedConvOp::new(op, n), &g);
            assert!(c > last, "n={n}");
            assert!(c <= n as f64 * single * (1.0 + 1e-9), "n={n}: no amortization");
            last = c;
        }
    }
}
