//! Unified backend layer: every way this stack can execute a
//! convolution — the paper's kernels (tuned and §3 closed-form), the
//! CPU reference, and all four comparison baselines — behind ONE
//! `ConvBackend` trait, plus the `dispatch` module that picks the
//! fastest legal backend per problem.
//!
//! Motivation (cuConv, arXiv 2103.16234; kubecl's runtime-per-backend
//! split): no single convolution algorithm dominates across CNN layer
//! shapes.  Implicit GEMM wins some large-map layers, Winograd wins
//! big K=3 layers, the paper's direct kernels win small maps and K=1 —
//! cuDNN's own advantage is *per-problem algorithm choice*.  Before
//! this layer, the baselines in `rust/src/baselines/` were bench-only
//! dead ends that could never be selected; now each is a first-class
//! backend with an honest `supports()` envelope, and the dispatcher
//! (`backend::dispatch`) can route any suite problem to whichever
//! algorithm the simulator prices fastest, never losing to the
//! paper-kernel-only path (the paper-tuned backend is always in the
//! candidate set).
//!
//! Each backend answers four questions:
//!  * `supports` — can this algorithm run this problem at all?  (e.g.
//!    Winograd F(2x2,3x3) is K=3-only, [16]'s 128-B fetch discipline is
//!    only defined for the multi-channel stride-fixed schedule);
//!  * `plan` — the `KernelPlan` (per-SM round schedule) it would
//!    execute, timed like every other plan by `gpusim::simulate`;
//!  * `cycles`/`seconds` + batched variants — its simulated cost, the
//!    quantity the dispatcher, graph executor and fleet pricing use;
//!  * `execute_reference` — eq.(1) computed in the backend's own
//!    traversal order (im2col gather, strip-mined, 2x2-tiled, ...),
//!    bit-identical to `conv::cpu::conv2d_multi_cpu` by construction:
//!    every output element accumulates its terms in the same
//!    (c asc, i asc, j asc) order into one f64.  The differential
//!    tests (`rust/tests/backend_difftests.rs`) pin that identity, so
//!    a backend's index arithmetic (halos, tiles, segments) is checked
//!    against the oracle even though its *timing* model is analytic.
//!    (Transform-domain numerics — Winograd/FFT — live in
//!    `python/compile/kernels/`; the Rust side's contract is the
//!    direct-conv semantics every algorithm must reproduce.)

pub mod dispatch;
mod impls;
pub mod reference;

pub use dispatch::{
    batched_dispatch_seconds, dispatch_advice, dispatch_batched_plan, dispatch_plan, dispatched,
    Decision, Dispatcher,
};
pub use impls::{
    CpuReference, CudnnProxy, Dac17, FftConv, PaperClosedForm, PaperTuned, Tan128, Winograd,
    BACKEND_NAMES,
};

use crate::conv::{BatchedConv, ConvProblem};
use crate::gpusim::{simulate, GpuSpec, KernelPlan};

/// One convolution algorithm as an executable backend.  Object-safe:
/// the dispatcher holds `Box<dyn ConvBackend>` and iterates the
/// registry per problem.
pub trait ConvBackend: Send + Sync {
    /// Stable identifier — the tag `PlanCache` dispatch entries and
    /// `Response.plan` advice carry (must be one of `BACKEND_NAMES`).
    fn name(&self) -> &'static str;

    /// Honest support envelope: `plan` may be called only on problems
    /// this returns `true` for (`plan` panics otherwise, like the
    /// underlying builders always have).
    fn supports(&self, p: &ConvProblem) -> bool;

    /// The per-SM execution schedule this backend would run.
    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan;

    /// The batch-`n` schedule: one launch, warm pipeline
    /// (`KernelPlan::batched` — same contract for every backend).
    fn batched_plan(&self, b: &BatchedConv, spec: &GpuSpec) -> KernelPlan {
        assert!(b.valid(), "invalid batched problem");
        self.plan(&b.problem, spec).batched(b.n)
    }

    /// Simulated execution cycles on `spec` — the dispatcher's ranking
    /// quantity.
    fn cycles(&self, p: &ConvProblem, spec: &GpuSpec) -> f64 {
        simulate(spec, &self.plan(p, spec)).cycles
    }

    /// `cycles` in seconds.
    fn seconds(&self, p: &ConvProblem, spec: &GpuSpec) -> f64 {
        spec.cycles_to_secs(self.cycles(p, spec))
    }

    /// Simulated cycles of the batch-`n` schedule.
    fn batched_cycles(&self, b: &BatchedConv, spec: &GpuSpec) -> f64 {
        simulate(spec, &self.batched_plan(b, spec)).cycles
    }

    /// `batched_cycles` in seconds — what fleet queues accumulate.
    fn batched_seconds(&self, b: &BatchedConv, spec: &GpuSpec) -> f64 {
        spec.cycles_to_secs(self.batched_cycles(b, spec))
    }

    /// eq.(1) in this backend's traversal order — bit-identical to
    /// `conv::cpu::conv2d_multi_cpu` on every supported problem (the
    /// differential-test contract; see the module docs).
    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32>;

    /// Batched reference semantics: definitionally `n` independent
    /// single-image runs (the same contract as `conv2d_batched_cpu`).
    fn execute_reference_batched(
        &self,
        b: &BatchedConv,
        images: &[f32],
        filters: &[f32],
    ) -> Vec<f32> {
        assert!(b.valid(), "invalid batched problem");
        assert_eq!(images.len(), b.map_elems(), "batched image size");
        let per_in = b.problem.map_elems();
        let per_out = b.problem.out_elems();
        let mut out = Vec::with_capacity(b.n * per_out);
        for i in 0..b.n {
            out.extend(self.execute_reference(
                &b.problem,
                &images[i * per_in..(i + 1) * per_in],
                filters,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gtx_1080ti;
    use crate::util::rng::Rng;

    #[test]
    fn default_batched_plan_matches_kernel_plan_batched() {
        let g = gtx_1080ti();
        let b = BatchedConv::new(ConvProblem::multi(16, 14, 16, 3), 4);
        let backend = PaperClosedForm;
        let via_trait = backend.batched_plan(&b, &g);
        let direct = backend.plan(&b.problem, &g).batched(4);
        assert_eq!(via_trait.name, direct.name);
        assert_eq!(via_trait.rounds.len(), direct.rounds.len());
        let diff = (backend.batched_cycles(&b, &g) - simulate(&g, &direct).cycles).abs();
        assert!(diff < 1e-9);
    }

    #[test]
    fn default_batched_reference_loops_single_images() {
        let p = ConvProblem::multi(3, 8, 4, 3);
        let b = BatchedConv::new(p, 3);
        let mut rng = Rng::new(11);
        let images = rng.normal_vec(b.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let backend = CpuReference;
        let batched = backend.execute_reference_batched(&b, &images, &filters);
        for i in 0..b.n {
            let single = backend.execute_reference(
                &p,
                &images[i * p.map_elems()..(i + 1) * p.map_elems()],
                &filters,
            );
            assert_eq!(
                &batched[i * p.out_elems()..(i + 1) * p.out_elems()],
                &single[..],
                "image {i}"
            );
        }
    }

    #[test]
    fn seconds_are_cycles_over_clock() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(8, 14, 16, 3);
        let backend = PaperClosedForm;
        let c = backend.cycles(&p, &g);
        assert!((backend.seconds(&p, &g) - g.cycles_to_secs(c)).abs() < 1e-18);
    }
}
