//! Reference semantics in each backend's own traversal order.
//!
//! Every function computes eq.(1) — valid cross-correlation, stride 1 —
//! over the same layouts as `conv::cpu::conv2d_multi_cpu` (image
//! (C, Wy, Wx), filters (M, C, K, K), output (M, Oy, Ox)), and is
//! **bit-identical** to it by construction: each output element owns
//! one f64 accumulator that receives its C*K*K products one term at a
//! time in ascending (c, i, j) order, cast to f32 exactly once at the
//! end.  Summation order within an element is the only thing f64
//! rounding is sensitive to here, so the *outer* traversal (output
//! tiles, filter groups, im2col gathers, channel planes) is free to
//! follow the backend's real data movement — which is exactly what the
//! differential tests want exercised: the halo / tile / segment index
//! arithmetic of each algorithm against the plain-loop oracle.

use crate::conv::ConvProblem;

/// Ceiling division (shared helper, local to keep the module lean).
fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

fn check_sizes(p: &ConvProblem, image: &[f32], filters: &[f32]) {
    assert!(p.valid(), "invalid problem");
    assert_eq!(image.len(), p.map_elems(), "image size");
    assert_eq!(filters.len(), p.filter_elems(), "filter size");
}

/// Implicit-GEMM traversal (the cuDNN proxy): C[M, Oy*Ox] =
/// A[M, C*K*K] x B[C*K*K, Oy*Ox] over (TM, TN, TK) tiles, the B tile
/// gathered im2col-style on the fly.  The k index enumerates (c, i, j)
/// in ascending flattened order, so each output element's accumulation
/// chain matches the direct loop exactly.
pub fn im2col_gemm(
    p: &ConvProblem,
    image: &[f32],
    filters: &[f32],
    tm: usize,
    tn: usize,
    tk: usize,
) -> Vec<f32> {
    check_sizes(p, image, filters);
    assert!(tm >= 1 && tn >= 1 && tk >= 1);
    let (oy, ox, k, kk) = (p.oy(), p.ox(), p.k, p.k * p.k);
    let (n_g, k_g) = (oy * ox, p.c * kk);
    let mut out = vec![0f32; p.m * n_g];
    for m0 in (0..p.m).step_by(tm) {
        let m1 = (m0 + tm).min(p.m);
        for n0 in (0..n_g).step_by(tn) {
            let n1 = (n0 + tn).min(n_g);
            let mut acc = vec![0f64; (m1 - m0) * (n1 - n0)];
            for k0 in (0..k_g).step_by(tk) {
                let k1 = (k0 + tk).min(k_g);
                // one k-step: gather the B tile element-wise and rank-1
                // update the accumulator tile
                for kg in k0..k1 {
                    let (ch, r) = (kg / kk, kg % kk);
                    let (i, j) = (r / k, r % k);
                    for n in n0..n1 {
                        let (y, x) = (n / ox, n % ox);
                        let b = image[ch * p.wy * p.wx + (y + i) * p.wx + (x + j)] as f64;
                        for fm in m0..m1 {
                            acc[(fm - m0) * (n1 - n0) + (n - n0)] +=
                                filters[fm * k_g + kg] as f64 * b;
                        }
                    }
                }
            }
            for fm in m0..m1 {
                for n in n0..n1 {
                    out[fm * n_g + n] = acc[(fm - m0) * (n1 - n0) + (n - n0)] as f32;
                }
            }
        }
    }
    out
}

/// Stride-fixed traversal (the paper's multi-channel kernel, and [16]):
/// filters in groups of `m_prime`, output pixels in linear strips of
/// `wx_prime`, the flattened (c, i, j) filter walked in segments of
/// `seg_elems` elements (= S bytes / 4).  Segments partition the
/// ascending filter index, so per-element chains stay in oracle order.
pub fn strip_mined(
    p: &ConvProblem,
    image: &[f32],
    filters: &[f32],
    wx_prime: usize,
    m_prime: usize,
    seg_elems: usize,
) -> Vec<f32> {
    check_sizes(p, image, filters);
    assert!(wx_prime >= 1 && m_prime >= 1 && seg_elems >= 1);
    let (oy, ox, k, kk) = (p.oy(), p.ox(), p.k, p.k * p.k);
    let (n_g, k_g) = (oy * ox, p.c * kk);
    let mut out = vec![0f32; p.m * n_g];
    for g0 in (0..p.m).step_by(m_prime) {
        let g1 = (g0 + m_prime).min(p.m);
        for s0 in (0..n_g).step_by(wx_prime) {
            let s1 = (s0 + wx_prime).min(n_g);
            let mut acc = vec![0f64; (g1 - g0) * (s1 - s0)];
            for seg0 in (0..k_g).step_by(seg_elems) {
                let seg1 = (seg0 + seg_elems).min(k_g);
                for fm in g0..g1 {
                    for px in s0..s1 {
                        let (y, x) = (px / ox, px % ox);
                        let a = &mut acc[(fm - g0) * (s1 - s0) + (px - s0)];
                        for t in seg0..seg1 {
                            let (ch, r) = (t / kk, t % kk);
                            let (i, j) = (r / k, r % k);
                            *a += image[ch * p.wy * p.wx + (y + i) * p.wx + (x + j)] as f64
                                * filters[fm * k_g + t] as f64;
                        }
                    }
                }
            }
            for fm in g0..g1 {
                for px in s0..s1 {
                    out[fm * n_g + px] = acc[(fm - g0) * (s1 - s0) + (px - s0)] as f32;
                }
            }
        }
    }
    out
}

/// Fixed 2-D output strips, one channel at a time ([1]'s fixed per-SM
/// assignment with natural whole-filter segments: the channel loop is
/// outermost, each channel applying its full K x K filter).
pub fn strip_tiled_2d(
    p: &ConvProblem,
    image: &[f32],
    filters: &[f32],
    strip_rows: usize,
    strip_cols: usize,
    m_prime: usize,
) -> Vec<f32> {
    check_sizes(p, image, filters);
    assert!(strip_rows >= 1 && strip_cols >= 1 && m_prime >= 1);
    let (oy, ox, k, kk) = (p.oy(), p.ox(), p.k, p.k * p.k);
    let k_g = p.c * kk;
    let mut out = vec![0f32; p.m * oy * ox];
    for g0 in (0..p.m).step_by(m_prime) {
        let g1 = (g0 + m_prime).min(p.m);
        for ty in (0..oy).step_by(strip_rows) {
            let ty1 = (ty + strip_rows).min(oy);
            for tx in (0..ox).step_by(strip_cols) {
                let tx1 = (tx + strip_cols).min(ox);
                let cols = tx1 - tx;
                let mut acc = vec![0f64; (g1 - g0) * (ty1 - ty) * cols];
                for ch in 0..p.c {
                    let ibase = ch * p.wy * p.wx;
                    for fm in g0..g1 {
                        let fbase = fm * k_g + ch * kk;
                        for y in ty..ty1 {
                            for x in tx..tx1 {
                                let ai = ((fm - g0) * (ty1 - ty) + (y - ty)) * cols + (x - tx);
                                let a = &mut acc[ai];
                                for i in 0..k {
                                    for j in 0..k {
                                        *a += image[ibase + (y + i) * p.wx + (x + j)] as f64
                                            * filters[fbase + i * k + j] as f64;
                                    }
                                }
                            }
                        }
                    }
                }
                for fm in g0..g1 {
                    for y in ty..ty1 {
                        for x in tx..tx1 {
                            let ai = ((fm - g0) * (ty1 - ty) + (y - ty)) * cols + (x - tx);
                            out[fm * oy * ox + y * ox + x] = acc[ai] as f32;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Output tiled `tile x tile` with the halo'd input patch gathered into
/// a local buffer first (the Winograd F(2x2,3x3) data movement: every
/// 2x2 output tile reads its overlapping (tile+K-1)^2 input patch).
/// The arithmetic stays direct — the transform-domain numerics live in
/// `python/compile/kernels/winograd.py` — so the patch-gather indexing
/// is exercised while the semantics stay bit-exact.
pub fn output_tiled(p: &ConvProblem, image: &[f32], filters: &[f32], tile: usize) -> Vec<f32> {
    check_sizes(p, image, filters);
    assert!(tile >= 1);
    let (oy, ox, k, kk) = (p.oy(), p.ox(), p.k, p.k * p.k);
    let k_g = p.c * kk;
    let mut out = vec![0f32; p.m * oy * ox];
    let patch_dim = tile + k - 1;
    let mut patch = vec![0f32; p.c * patch_dim * patch_dim];
    for ty in (0..oy).step_by(tile) {
        let th = tile.min(oy - ty);
        for tx in (0..ox).step_by(tile) {
            let tw = tile.min(ox - tx);
            // gather the (th+K-1) x (tw+K-1) patch for every channel
            let (ph, pw) = (th + k - 1, tw + k - 1);
            for ch in 0..p.c {
                for py in 0..ph {
                    for px in 0..pw {
                        patch[ch * patch_dim * patch_dim + py * patch_dim + px] =
                            image[ch * p.wy * p.wx + (ty + py) * p.wx + (tx + px)];
                    }
                }
            }
            for fm in 0..p.m {
                for y in 0..th {
                    for x in 0..tw {
                        let mut acc = 0f64;
                        for ch in 0..p.c {
                            let pbase = ch * patch_dim * patch_dim;
                            let fbase = fm * k_g + ch * kk;
                            for i in 0..k {
                                for j in 0..k {
                                    acc += patch[pbase + (y + i) * patch_dim + (x + j)] as f64
                                        * filters[fbase + i * k + j] as f64;
                                }
                            }
                        }
                        out[fm * oy * ox + (ty + y) * ox + (tx + x)] = acc as f32;
                    }
                }
            }
        }
    }
    out
}

/// Channel-plane accumulation (the FFT schedule: one frequency-domain
/// multiply-accumulate per channel, summed across channels into the
/// output spectrum).  Here: per-channel spatial correlations accumulated
/// plane by plane into per-output f64 accumulators.
pub fn channel_planes(p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
    check_sizes(p, image, filters);
    let (oy, ox, k, kk) = (p.oy(), p.ox(), p.k, p.k * p.k);
    let k_g = p.c * kk;
    let mut acc = vec![0f64; p.m * oy * ox];
    for ch in 0..p.c {
        for fm in 0..p.m {
            for y in 0..oy {
                for x in 0..ox {
                    let a = &mut acc[fm * oy * ox + y * ox + x];
                    for i in 0..k {
                        for j in 0..k {
                            *a += image[ch * p.wy * p.wx + (y + i) * p.wx + (x + j)] as f64
                                * filters[fm * k_g + ch * kk + i * k + j] as f64;
                        }
                    }
                }
            }
        }
    }
    acc.into_iter().map(|v| v as f32).collect()
}

/// Row-piece traversal (the paper's single-channel §3.1 kernels, and
/// the generic piece-wise prefetch shape): output rows in `pieces`
/// equal chunks (the P division), filters in chunks of `m_chunk` (the
/// Q division / per-SM filter assignment).  Works for any C — the
/// per-element accumulation is always full-depth (c, i, j).
pub fn row_pieces(
    p: &ConvProblem,
    image: &[f32],
    filters: &[f32],
    pieces: usize,
    m_chunk: usize,
) -> Vec<f32> {
    check_sizes(p, image, filters);
    assert!(pieces >= 1 && m_chunk >= 1);
    let (oy, ox, k, kk) = (p.oy(), p.ox(), p.k, p.k * p.k);
    let k_g = p.c * kk;
    let piece_rows = ceil_div(oy, pieces).max(1);
    let mut out = vec![0f32; p.m * oy * ox];
    for r0 in (0..oy).step_by(piece_rows) {
        let r1 = (r0 + piece_rows).min(oy);
        for g0 in (0..p.m).step_by(m_chunk) {
            let g1 = (g0 + m_chunk).min(p.m);
            for fm in g0..g1 {
                for y in r0..r1 {
                    for x in 0..ox {
                        let mut acc = 0f64;
                        for ch in 0..p.c {
                            let ibase = ch * p.wy * p.wx;
                            let fbase = fm * k_g + ch * kk;
                            for i in 0..k {
                                for j in 0..k {
                                    acc += image[ibase + (y + i) * p.wx + (x + j)] as f64
                                        * filters[fbase + i * k + j] as f64;
                                }
                            }
                        }
                        out[fm * oy * ox + y * ox + x] = acc as f32;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d_multi_cpu;
    use crate::util::rng::Rng;

    fn bit_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    fn cases() -> Vec<ConvProblem> {
        vec![
            ConvProblem::single(9, 3, 3),
            ConvProblem::single(16, 5, 1),
            ConvProblem::multi(3, 11, 4, 3),
            ConvProblem::multi(5, 7, 6, 5),
            ConvProblem::multi(4, 8, 3, 1),
        ]
    }

    #[test]
    fn every_traversal_bit_identical_to_oracle() {
        let mut rng = Rng::new(0xBAC0);
        for p in cases() {
            let image = rng.normal_vec(p.map_elems());
            let filters = rng.normal_vec(p.filter_elems());
            let want = conv2d_multi_cpu(&p, &image, &filters);
            // odd tile/strip/segment sizes on purpose: partial tiles and
            // ragged segments are where indexing bugs live
            for (name, got) in [
                ("im2col", im2col_gemm(&p, &image, &filters, 3, 5, 4)),
                ("strip_mined", strip_mined(&p, &image, &filters, 7, 2, 5)),
                ("strip_2d", strip_tiled_2d(&p, &image, &filters, 3, 4, 2)),
                ("tiled", output_tiled(&p, &image, &filters, 2)),
                ("planes", channel_planes(&p, &image, &filters)),
                ("rows", row_pieces(&p, &image, &filters, 3, 2)),
            ] {
                assert!(bit_eq(&got, &want), "{name} differs on {}", p.label());
            }
        }
    }

    #[test]
    fn degenerate_block_sizes_cover_whole_problem() {
        let p = ConvProblem::multi(2, 6, 3, 3);
        let mut rng = Rng::new(7);
        let image = rng.normal_vec(p.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let want = conv2d_multi_cpu(&p, &image, &filters);
        // blocks larger than the problem degrade to one full pass
        assert!(bit_eq(&im2col_gemm(&p, &image, &filters, 999, 999, 999), &want));
        assert!(bit_eq(&strip_mined(&p, &image, &filters, 999, 999, 999), &want));
        assert!(bit_eq(&output_tiled(&p, &image, &filters, 64), &want));
        assert!(bit_eq(&row_pieces(&p, &image, &filters, 1, 999), &want));
    }

    #[test]
    #[should_panic(expected = "image size")]
    fn size_mismatch_panics() {
        let p = ConvProblem::single(4, 1, 1);
        im2col_gemm(&p, &[0.0; 3], &[1.0], 8, 8, 8);
    }
}
