//! Cross-backend autodispatch: price every legal backend for a problem
//! under the simulator and serve the fastest — cuDNN's own per-problem
//! algorithm-choice advantage, reproduced on top of our backends.
//!
//! The never-lose invariant is structural: the paper-tuned backend
//! supports every valid problem, its plans are legality-gated by the
//! tuner already, and it seeds the ranking — so the dispatcher's pick
//! is at most `tuned_cycles`, exactly like the tuner never loses to the
//! paper's closed forms one layer down.  Decisions are memoized in the
//! same process-wide `PlanCache` as tuning results (extended with
//! `kind=dispatch` entries, `pasconv tune --save/--load` persists
//! both), so steady-state serving pays one hash lookup per problem.
//!
//! Consumers: `graph::execute` (per-layer algorithm choice inside one
//! model — `dispatch_plan` is a `graph::Planner`), the coordinator's
//! `Router::warm_plans` (pre-dispatches every routed problem; the pick
//! returns on the wire in `Response.plan`), and the fleet's per-shard
//! job pricing (`batched_dispatch_seconds` — heterogeneous fleets can
//! pick different algorithms per GPU generation).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::conv::{BatchedConv, ConvProblem};
use crate::gpusim::{simulate, GpuSpec, KernelPlan};
use crate::tuner;

use super::impls::{
    CpuReference, CudnnProxy, Dac17, FftConv, PaperClosedForm, PaperTuned, Tan128, Winograd,
};
use super::ConvBackend;

/// The backend tag the paper-tuned floor carries.
pub const PAPER_TUNED: &str = "paper-tuned";

/// One dispatch outcome: which backend won and at what simulated cost,
/// with the paper-tuned floor it was measured against.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    /// winning backend tag (one of `BACKEND_NAMES`)
    pub backend: String,
    /// simulated cycles of the winner's plan
    pub cycles: f64,
    /// simulated cycles of the paper-tuned plan (the floor:
    /// `cycles <= tuned_cycles` always)
    pub tuned_cycles: f64,
}

impl Decision {
    /// Paper-tuned cycles over dispatched cycles (>= 1 by construction).
    pub fn speedup(&self) -> f64 {
        self.tuned_cycles / self.cycles
    }
}

/// A backend registry + the ranking logic.  `Dispatcher::full()` is the
/// production set; tests build narrower ones to isolate behaviors.
pub struct Dispatcher {
    backends: Vec<Box<dyn ConvBackend>>,
}

impl Dispatcher {
    /// Every backend, paper-tuned first (the floor the ranking seeds
    /// from; see `BACKEND_NAMES` for the canonical order).
    pub fn full() -> Dispatcher {
        Dispatcher {
            backends: vec![
                Box::new(PaperTuned),
                Box::new(PaperClosedForm),
                Box::new(CudnnProxy),
                Box::new(Dac17),
                Box::new(Tan128),
                Box::new(Winograd),
                Box::new(FftConv),
                Box::new(CpuReference),
            ],
        }
    }

    pub fn backends(&self) -> &[Box<dyn ConvBackend>] {
        &self.backends
    }

    /// Registry lookup by tag.
    pub fn backend(&self, name: &str) -> Option<&dyn ConvBackend> {
        self.backends.iter().find(|b| b.name() == name).map(|b| b.as_ref())
    }

    /// Backends that could run `p` at all (support envelope only; the
    /// per-spec legality gate is applied during `decide`).
    pub fn candidates(&self, p: &ConvProblem) -> Vec<&dyn ConvBackend> {
        self.backends.iter().filter(|b| b.supports(p)).map(|b| b.as_ref()).collect()
    }

    /// Full ranking for one problem: simulate every supporting backend
    /// whose plan is launchable on `spec` (`tuner::is_legal` — same
    /// occupancy gate the tuner applies to its own candidates), keep
    /// the fastest.  Ties stay with the earlier registry entry, so the
    /// paper-tuned floor wins exact ties deterministically.
    pub fn decide(&self, p: &ConvProblem, spec: &GpuSpec) -> Decision {
        self.decide_n(p, 1, spec)
    }

    /// `decide` for a batch: backends are ranked on their batch-`n`
    /// schedules directly (launch overhead amortizes differently per
    /// backend — the ranking can legitimately flip with `n`).
    pub fn decide_batched(&self, b: &BatchedConv, spec: &GpuSpec) -> Decision {
        assert!(b.valid(), "invalid batched problem");
        self.decide_n(&b.problem, b.n, spec)
    }

    /// The one ranking routine both entry points share
    /// (`KernelPlan::batched(1)` is the identity, so n = 1 IS the
    /// single-image ranking) — the legality gate and tie-breaking live
    /// only here, mirrored once by `python/mirror/backends.py`.
    fn decide_n(&self, p: &ConvProblem, n: usize, spec: &GpuSpec) -> Decision {
        let tuned = self.backend(PAPER_TUNED).expect("paper-tuned backend in every registry");
        assert!(tuned.supports(p), "invalid problem {p:?}");
        let tuned_cycles = simulate(spec, &tuned.plan(p, spec).batched(n)).cycles;
        let mut best = (PAPER_TUNED, tuned_cycles);
        for b in &self.backends {
            if b.name() == PAPER_TUNED || !b.supports(p) {
                continue;
            }
            let plan = b.plan(p, spec);
            if !tuner::is_legal(spec, &plan) {
                continue;
            }
            let cycles = simulate(spec, &plan.batched(n)).cycles;
            if cycles < best.1 {
                best = (b.name(), cycles);
            }
        }
        Decision { backend: best.0.to_string(), cycles: best.1, tuned_cycles }
    }
}

/// The process-wide registry every memoized entry point shares.
pub fn registry() -> &'static Dispatcher {
    static REGISTRY: OnceLock<Dispatcher> = OnceLock::new();
    REGISTRY.get_or_init(Dispatcher::full)
}

/// Memoized dispatch decision for `(p, spec)` — one full ranking per
/// process (or zero, when preloaded via `tuner::preload`).
pub fn dispatched(p: &ConvProblem, spec: &GpuSpec) -> Decision {
    if let Some(d) = tuner::cached_dispatch(p, spec) {
        return d;
    }
    // rank outside the cache lock: deciding tunes the paper floor,
    // which takes the same lock
    let d = registry().decide(p, spec);
    tuner::store_dispatch(p, spec, d.clone());
    d
}

/// The dispatched `KernelPlan` for a problem — a `graph::Planner`, so
/// `graph::execute(&g, &spec, backend::dispatch_plan)` gives every
/// layer of a model its own algorithm.
pub fn dispatch_plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    let d = dispatched(p, spec);
    registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .plan(p, spec)
}

/// Memo key for batched decisions: (problem, batch n, spec name).
type BatchedKey = (ConvProblem, usize, &'static str);

fn batched_memo() -> &'static Mutex<HashMap<BatchedKey, Decision>> {
    static MEMO: OnceLock<Mutex<HashMap<BatchedKey, Decision>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Memoized batched dispatch decision (in-process only — batch sizes
/// are a serving-time axis, not a tuning artifact worth persisting).
pub fn batched_dispatched(b: &BatchedConv, spec: &GpuSpec) -> Decision {
    if b.n == 1 {
        return dispatched(&b.problem, spec);
    }
    let key = (b.problem, b.n, spec.name);
    if let Some(d) = batched_memo().lock().unwrap().get(&key) {
        return d.clone();
    }
    let d = registry().decide_batched(b, spec);
    batched_memo().lock().unwrap().insert(key, d.clone());
    d
}

/// The dispatched batch-`n` schedule.
pub fn dispatch_batched_plan(b: &BatchedConv, spec: &GpuSpec) -> KernelPlan {
    let d = batched_dispatched(b, spec);
    registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .batched_plan(b, spec)
}

/// Predicted seconds of a batch under cross-backend dispatch — what
/// fleet shards price jobs with (per-shard: a heterogeneous fleet's
/// Pascal and Maxwell devices can pick different algorithms for the
/// same job).
pub fn batched_dispatch_seconds(b: &BatchedConv, spec: &GpuSpec) -> f64 {
    spec.cycles_to_secs(batched_dispatched(b, spec).cycles)
}

/// Human-readable dispatch advice (router / CLI / `Response.plan`):
/// names the chosen backend and its margin over the paper-tuned floor.
pub fn dispatch_advice(p: &ConvProblem, spec: &GpuSpec) -> String {
    let d = dispatched(p, spec);
    let plan = registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .plan(p, spec);
    if d.backend == PAPER_TUNED {
        // the paper kernel won: surface the tuner's own advice string
        format!("{} (dispatch: paper-tuned; {})", plan.name, tuner::advice(p, spec))
    } else {
        format!("{} (dispatch: {}, {:.2}x vs paper-tuned)", plan.name, d.backend, d.speedup())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::{fig4_suite, fig5_suite};
    use crate::gpusim::{gtx_1080ti, titan_x_maxwell};
    use crate::plans;

    #[test]
    fn never_loses_to_the_tuned_paper_path() {
        let g = gtx_1080ti();
        let d = registry();
        for p in fig4_suite().into_iter().chain(fig5_suite()).step_by(3) {
            let dec = d.decide(&p, &g);
            assert!(
                dec.cycles <= dec.tuned_cycles * (1.0 + 1e-9),
                "{}: dispatch lost ({} > {})",
                p.label(),
                dec.cycles,
                dec.tuned_cycles
            );
            assert!(dec.speedup() >= 1.0 - 1e-9);
        }
    }

    #[test]
    fn a_baseline_wins_somewhere() {
        // the whole point of dispatch: Winograd's 2.25x multiply
        // reduction beats the direct kernels on big compute-bound K=3
        // layers (the VGG body regime)
        let g = gtx_1080ti();
        let dec = registry().decide(&ConvProblem::multi(256, 56, 256, 3), &g);
        assert_ne!(dec.backend, PAPER_TUNED, "no baseline ever selected");
        assert!(dec.speedup() > 1.0, "winner does not actually win");
    }

    #[test]
    fn paper_tuned_wins_its_headline_regime() {
        // small multi-channel maps are the paper's own win; dispatch
        // must keep serving the paper kernel there
        let g = gtx_1080ti();
        let dec = registry().decide(&ConvProblem::multi(256, 14, 256, 1), &g);
        assert_eq!(dec.backend, PAPER_TUNED, "paper kernel lost its home turf");
    }

    #[test]
    fn cpu_reference_is_never_dispatched() {
        let g = gtx_1080ti();
        for p in fig5_suite().into_iter().step_by(4) {
            assert_ne!(registry().decide(&p, &g).backend, "cpu-reference", "{}", p.label());
        }
    }

    #[test]
    fn memoized_decision_matches_fresh_ranking() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(128, 28, 128, 3);
        let fresh = registry().decide(&p, &g);
        let a = dispatched(&p, &g);
        let b = dispatched(&p, &g);
        assert_eq!(a, b);
        assert_eq!(a, fresh);
        // and the plan materializes under the winner's name
        let plan = dispatch_plan(&p, &g);
        let direct = registry().backend(&a.backend).unwrap().plan(&p, &g);
        assert_eq!(plan.name, direct.name);
    }

    #[test]
    fn batched_dispatch_bounded_by_tuned_batched_path() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(64, 56, 64, 3);
        for n in [1usize, 2, 4, 8] {
            let b = BatchedConv::new(p, n);
            let secs = batched_dispatch_seconds(&b, &g);
            let tuned = plans::batched_seconds(&b, &g);
            assert!(secs <= tuned * (1.0 + 1e-9), "n={n}: {secs} > tuned {tuned}");
            assert!(secs > 0.0 && secs.is_finite());
        }
    }

    #[test]
    fn batched_dispatch_monotone_and_amortizing() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(16, 7, 32, 3);
        let single = batched_dispatch_seconds(&BatchedConv::single(p), &g);
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8] {
            let t = batched_dispatch_seconds(&BatchedConv::new(p, n), &g);
            assert!(t > last, "n={n}");
            assert!(t <= n as f64 * single * (1.0 + 1e-9), "n={n}: no amortization");
            last = t;
        }
    }

    #[test]
    fn per_spec_decisions_can_differ_across_gpu_generations() {
        // the fleet's reason to dispatch per shard: each spec ranks for
        // itself.  Both specs' decisions respect their own floors.
        let g = gtx_1080ti();
        let t = titan_x_maxwell();
        for p in fig5_suite().into_iter().step_by(5) {
            for spec in [&g, &t] {
                let d = registry().decide(&p, spec);
                assert!(d.cycles <= d.tuned_cycles * (1.0 + 1e-9), "{}", spec.name);
            }
        }
    }

    #[test]
    fn advice_names_the_backend_and_the_tuned_floor() {
        let g = gtx_1080ti();
        let wino = dispatch_advice(&ConvProblem::multi(256, 56, 256, 3), &g);
        assert!(wino.contains("winograd") && wino.contains("tuned"), "{wino}");
        let ours = dispatch_advice(&ConvProblem::multi(256, 14, 256, 1), &g);
        assert!(ours.contains("paper-tuned") && ours.contains("tuned"), "{ours}");
    }
}
