//! Cross-backend autodispatch: price every legal backend for a conv op
//! under the simulator and serve the fastest — cuDNN's own per-problem
//! algorithm-choice advantage, reproduced on top of our backends and
//! extended to the op layer (stride / padding / groups).
//!
//! The never-lose invariant is structural at every level: the
//! paper-tuned backend covers every valid op (natively — decimated
//! strip schedule for stride, side-by-side groups on idle SMs — or
//! through the exact lowering, whichever simulates faster), and the
//! ranking's floor is the paper-tuned **naive lowered** schedule (full
//! stride-1 output, sequential groups under one launch).  The floor is
//! in the candidate set by construction, so `Decision::cycles <=
//! Decision::tuned_cycles` always — for dense ops this degenerates to
//! exactly the pre-op-layer problem ranking.
//!
//! Decisions are memoized in the same process-wide `PlanCache` as
//! tuning results (v3 `kind=dispatch` entries carry stride/pad/groups;
//! `pasconv tune --save/--load` persists both), so steady-state serving
//! pays one hash lookup per op.
//!
//! Consumers: `graph::execute` (per-layer algorithm choice inside one
//! model — `dispatch_fused_op_plan` is a `graph::Planner`), the
//! coordinator's `Router::warm_plans` (pre-dispatches every routed op;
//! the pick returns on the wire in `Response.plan`), and the fleet's
//! per-shard job pricing (`batched_op_dispatch_seconds` —
//! heterogeneous fleets can pick different algorithms per GPU
//! generation).

use std::sync::OnceLock;

use crate::conv::{BatchedConv, BatchedConvOp, ConvOp, ConvProblem};
use crate::gpusim::{simulate, Epilogue, GpuSpec, KernelPlan};
use crate::tuner;

use super::impls::{
    CpuReference, CudnnProxy, Dac17, FftConv, PaperClosedForm, PaperTuned, Tan128, Winograd,
};
use super::ConvBackend;

/// The backend tag the paper-tuned floor carries.
pub const PAPER_TUNED: &str = "paper-tuned";

/// One dispatch outcome: which backend won and at what simulated cost,
/// with the paper-tuned floor it was measured against.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    /// winning backend tag (one of `BACKEND_NAMES`)
    pub backend: String,
    /// simulated cycles of the winner's op plan
    pub cycles: f64,
    /// simulated cycles of the paper-tuned naive lowered plan (the
    /// floor: `cycles <= tuned_cycles` always; for dense ops this IS
    /// the tuned paper plan)
    pub tuned_cycles: f64,
}

impl Decision {
    /// Paper-tuned-lowered cycles over dispatched cycles (>= 1 by
    /// construction).
    pub fn speedup(&self) -> f64 {
        self.tuned_cycles / self.cycles
    }
}

/// The naive lowered schedule of `op` on `b`: the per-group unit plan
/// repeated under one launch, full stride-1 output.  For dense ops this
/// is just `b.plan(core)`.  The paper-tuned instance of this is the
/// dispatcher's never-lose floor.
fn lowered_plan(b: &dyn ConvBackend, op: &ConvOp, spec: &GpuSpec) -> KernelPlan {
    if op.is_dense() {
        return b.plan(&op.core, spec);
    }
    let l = op.lower();
    b.plan(&l.unit, spec).batched(l.groups)
}

/// A backend registry + the ranking logic.  `Dispatcher::full()` is the
/// production set; tests build narrower ones to isolate behaviors.
pub struct Dispatcher {
    backends: Vec<Box<dyn ConvBackend>>,
}

impl Dispatcher {
    /// Every backend, paper-tuned first (the floor the ranking seeds
    /// from; see `BACKEND_NAMES` for the canonical order).
    pub fn full() -> Dispatcher {
        Dispatcher {
            backends: vec![
                Box::new(PaperTuned),
                Box::new(PaperClosedForm),
                Box::new(CudnnProxy),
                Box::new(Dac17),
                Box::new(Tan128),
                Box::new(Winograd),
                Box::new(FftConv),
                Box::new(CpuReference),
            ],
        }
    }

    pub fn backends(&self) -> &[Box<dyn ConvBackend>] {
        &self.backends
    }

    /// Registry lookup by tag.
    pub fn backend(&self, name: &str) -> Option<&dyn ConvBackend> {
        self.backends.iter().find(|b| b.name() == name).map(|b| b.as_ref())
    }

    /// Backends that could run `p` at all (support envelope only; the
    /// per-spec legality gate is applied during `decide`).
    pub fn candidates(&self, p: &ConvProblem) -> Vec<&dyn ConvBackend> {
        self.backends.iter().filter(|b| b.supports(p)).map(|b| b.as_ref()).collect()
    }

    /// Backends whose op coverage (native or lowered) includes `op`.
    pub fn op_candidates(&self, op: &ConvOp) -> Vec<&dyn ConvBackend> {
        self.backends
            .iter()
            .filter(|b| b.op_coverage(op).supported())
            .map(|b| b.as_ref())
            .collect()
    }

    /// Full ranking for one dense problem (the historical entry point;
    /// identical to `decide_op` on the dense-wrapped op).
    pub fn decide(&self, p: &ConvProblem, spec: &GpuSpec) -> Decision {
        self.decide_op_n(&ConvOp::dense(*p), 1, spec)
    }

    /// `decide` for a dense batch.
    pub fn decide_batched(&self, b: &BatchedConv, spec: &GpuSpec) -> Decision {
        assert!(b.valid(), "invalid batched problem");
        self.decide_op_n(&ConvOp::dense(b.problem), b.n, spec)
    }

    /// Full ranking for one op.
    pub fn decide_op(&self, op: &ConvOp, spec: &GpuSpec) -> Decision {
        self.decide_op_n(op, 1, spec)
    }

    /// `decide_op` for a batch: backends are ranked on their batch-`n`
    /// op schedules directly.
    pub fn decide_batched_op(&self, b: &BatchedConvOp, spec: &GpuSpec) -> Decision {
        assert!(b.valid(), "invalid batched op");
        self.decide_op_n(&b.op, b.n, spec)
    }

    /// The one ranking routine every entry point shares
    /// (`KernelPlan::batched(1)` is the identity, so n = 1 IS the
    /// single-image ranking) — the floor, the legality gate and
    /// tie-breaking live only here, mirrored once by
    /// `python/mirror/backends.py`.  Ties stay with the earlier
    /// registry entry, so the paper-tuned floor wins exact ties
    /// deterministically.
    fn decide_op_n(&self, op: &ConvOp, n: usize, spec: &GpuSpec) -> Decision {
        assert!(op.valid(), "invalid op {op:?}");
        let tuned = self.backend(PAPER_TUNED).expect("paper-tuned backend in every registry");
        // the never-lose floor: the paper-tuned naive lowering,
        // re-streamed per image (no residency credit — the floor stays
        // what pre-op-native serving actually dispatched)
        let tuned_cycles = simulate(spec, &lowered_plan(tuned, op, spec).batched(n)).cycles;
        // paper-tuned is ranked on its batched OP plan — the op-native
        // tuned schedule, with cross-image filter residency where it
        // qualifies — which never prices above its own lowered floor
        let mut best = (
            PAPER_TUNED,
            simulate(spec, &tuned.batched_op_plan(&BatchedConvOp::new(*op, n), spec)).cycles,
        );
        for b in &self.backends {
            if b.name() == PAPER_TUNED || !b.op_coverage(op).supported() {
                continue;
            }
            let plan = b.op_plan(op, spec);
            if !tuner::is_legal(spec, &plan) {
                continue;
            }
            let cycles = simulate(spec, &plan.batched(n)).cycles;
            if cycles < best.1 {
                best = (b.name(), cycles);
            }
        }
        Decision { backend: best.0.to_string(), cycles: best.1, tuned_cycles }
    }

    /// Full ranking for one fused op: the same routine as `decide_op`,
    /// with every candidate's plan carrying `ep` in its writeback tail
    /// and the floor being the paper-tuned naive lowered schedule fused
    /// the same way.  `Epilogue::None` reduces EXACTLY to `decide_op` —
    /// the unfused path stays the structural never-lose floor of the
    /// fused axis (the graph fusion pass separately refuses any rewrite
    /// whose fused plan prices above unfused-conv + glue).
    pub fn decide_fused_op(&self, op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> Decision {
        if ep.is_none() {
            return self.decide_op_n(op, 1, spec);
        }
        assert!(op.valid(), "invalid op {op:?}");
        let out_hw = (op.oy(), op.ox());
        let tuned = self.backend(PAPER_TUNED).expect("paper-tuned backend in every registry");
        let tuned_cycles =
            simulate(spec, &lowered_plan(tuned, op, spec).fused(ep, out_hw)).cycles;
        // paper-tuned's native-vs-lowered memo was decided on UNFUSED
        // cycles; take the explicit min against the fused floor so
        // `cycles <= tuned_cycles` stays structural under any epilogue
        let seed =
            simulate(spec, &tuned.fused_op_plan(op, ep, spec)).cycles.min(tuned_cycles);
        let mut best = (PAPER_TUNED, seed);
        for b in &self.backends {
            if b.name() == PAPER_TUNED || !b.op_coverage(op).supported() {
                continue;
            }
            let plan = b.fused_op_plan(op, ep, spec);
            if !tuner::is_legal(spec, &plan) {
                continue;
            }
            let cycles = simulate(spec, &plan).cycles;
            if cycles < best.1 {
                best = (b.name(), cycles);
            }
        }
        Decision { backend: best.0.to_string(), cycles: best.1, tuned_cycles }
    }
}

/// The process-wide registry every memoized entry point shares.
pub fn registry() -> &'static Dispatcher {
    static REGISTRY: OnceLock<Dispatcher> = OnceLock::new();
    REGISTRY.get_or_init(Dispatcher::full)
}

/// Memoized dispatch decision for `(op, spec)` — one full ranking per
/// process (or zero, when preloaded via `tuner::preload`).
pub fn op_dispatched(op: &ConvOp, spec: &GpuSpec) -> Decision {
    if let Some(d) = tuner::cached_dispatch(op, spec) {
        return d;
    }
    // rank outside the cache lock: deciding tunes the paper floor,
    // which takes the same lock
    let d = registry().decide_op(op, spec);
    tuner::store_dispatch(op, spec, d.clone());
    d
}

/// Memoized dispatch decision for a dense problem.
pub fn dispatched(p: &ConvProblem, spec: &GpuSpec) -> Decision {
    op_dispatched(&ConvOp::dense(*p), spec)
}

/// The dispatched `KernelPlan` for an unfused op — the `Epilogue::None`
/// slice of `dispatch_fused_op_plan` (which is the `graph::Planner`
/// that gives every layer of a model its own algorithm).
pub fn dispatch_op_plan(op: &ConvOp, spec: &GpuSpec) -> KernelPlan {
    let d = op_dispatched(op, spec);
    registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .op_plan(op, spec)
}

/// The dispatched plan for a dense problem.
pub fn dispatch_plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    dispatch_op_plan(&ConvOp::dense(*p), spec)
}

/// Memoized dispatch decision for `(op, epilogue, spec)` — persisted as
/// PlanCache v5 `kind=dispatch epilogue=...` entries.  `Epilogue::None`
/// IS `op_dispatched` (same cache key, same ranking).
pub fn fused_op_dispatched(op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> Decision {
    if ep.is_none() {
        return op_dispatched(op, spec);
    }
    if let Some(d) = tuner::cached_dispatch_fused(op, ep, spec) {
        return d;
    }
    let d = registry().decide_fused_op(op, ep, spec);
    tuner::store_dispatch_fused(op, ep, spec, d.clone());
    d
}

/// The dispatched fused `KernelPlan` for an op — what the graph fusion
/// pass serves for a conv node that absorbed its consumer.
pub fn dispatch_fused_op_plan(op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> KernelPlan {
    if ep.is_none() {
        return dispatch_op_plan(op, spec);
    }
    let d = fused_op_dispatched(op, ep, spec);
    registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .fused_op_plan(op, ep, spec)
}

/// Memoized batched op dispatch decision — persisted as PlanCache v6
/// `kind=dispatch n=...` entries (`tune --save/--load` carries them, so
/// a preloaded fleet pays zero batched rankings; pre-v6 this memo was
/// in-process only).  `n = 1` is exactly the historical fused-op key.
pub fn batched_op_dispatched(b: &BatchedConvOp, spec: &GpuSpec) -> Decision {
    if b.n == 1 {
        return op_dispatched(&b.op, spec);
    }
    if let Some(d) = tuner::cached_dispatch_batched(&b.op, Epilogue::None, b.n, spec) {
        return d;
    }
    let d = registry().decide_batched_op(b, spec);
    tuner::store_dispatch_batched(&b.op, Epilogue::None, b.n, spec, d.clone());
    d
}

/// Memoized batched dispatch decision for a dense batch.
pub fn batched_dispatched(b: &BatchedConv, spec: &GpuSpec) -> Decision {
    batched_op_dispatched(&BatchedConvOp::dense(b), spec)
}

/// The dispatched batch-`n` schedule for a dense batch.
pub fn dispatch_batched_plan(b: &BatchedConv, spec: &GpuSpec) -> KernelPlan {
    let bo = BatchedConvOp::dense(b);
    let d = batched_op_dispatched(&bo, spec);
    registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .batched_op_plan(&bo, spec)
}

/// Predicted seconds of a batched op under cross-backend dispatch —
/// what fleet shards price jobs with (per-shard: a heterogeneous
/// fleet's Pascal and Maxwell devices can pick different algorithms
/// for the same job).
pub fn batched_op_dispatch_seconds(b: &BatchedConvOp, spec: &GpuSpec) -> f64 {
    spec.cycles_to_secs(batched_op_dispatched(b, spec).cycles)
}

/// `batched_op_dispatch_seconds` for a dense batch.
pub fn batched_dispatch_seconds(b: &BatchedConv, spec: &GpuSpec) -> f64 {
    batched_op_dispatch_seconds(&BatchedConvOp::dense(b), spec)
}

/// Human-readable dispatch advice for a dense problem (router / CLI /
/// `Response.plan`): names the chosen backend and its margin over the
/// paper-tuned floor.
pub fn dispatch_advice(p: &ConvProblem, spec: &GpuSpec) -> String {
    let d = dispatched(p, spec);
    let plan = registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .plan(p, spec);
    if d.backend == PAPER_TUNED {
        // the paper kernel won: surface the tuner's own advice string
        format!("{} (dispatch: paper-tuned; {})", plan.name, tuner::advice(p, spec))
    } else {
        format!("{} (dispatch: {}, {:.2}x vs paper-tuned)", plan.name, d.backend, d.speedup())
    }
}

/// Dispatch advice for an op: dense ops get the historical problem
/// advice; lowered/native ops name the backend and the margin over the
/// naive lowered paper-tuned floor.
pub fn op_dispatch_advice(op: &ConvOp, spec: &GpuSpec) -> String {
    if op.is_dense() {
        return dispatch_advice(&op.core, spec);
    }
    let d = op_dispatched(op, spec);
    let plan = registry()
        .backend(&d.backend)
        .expect("cached decision names a registered backend")
        .op_plan(op, spec);
    format!(
        "{} (dispatch: {}, {:.2}x vs lowered paper-tuned)",
        plan.name,
        d.backend,
        d.speedup()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::{all_cnn_ops, fig4_suite, fig5_suite};
    use crate::gpusim::{gtx_1080ti, titan_x_maxwell};
    use crate::plans;

    #[test]
    fn never_loses_to_the_tuned_paper_path() {
        let g = gtx_1080ti();
        let d = registry();
        for p in fig4_suite().into_iter().chain(fig5_suite()).step_by(3) {
            let dec = d.decide(&p, &g);
            assert!(
                dec.cycles <= dec.tuned_cycles * (1.0 + 1e-9),
                "{}: dispatch lost ({} > {})",
                p.label(),
                dec.cycles,
                dec.tuned_cycles
            );
            assert!(dec.speedup() >= 1.0 - 1e-9);
        }
    }

    #[test]
    fn op_dispatch_never_loses_to_the_lowered_floor() {
        // the op layer's acceptance gate, sampled (the full sweep runs
        // in backend_difftests + ablation_dispatch --check)
        let g = gtx_1080ti();
        for op in all_cnn_ops().into_iter().step_by(4) {
            let dec = registry().decide_op(&op, &g);
            assert!(
                dec.cycles <= dec.tuned_cycles * (1.0 + 1e-9),
                "{}: op dispatch lost ({} > {})",
                op.label(),
                dec.cycles,
                dec.tuned_cycles
            );
        }
    }

    #[test]
    fn a_baseline_wins_somewhere() {
        // the whole point of dispatch: Winograd's 2.25x multiply
        // reduction beats the direct kernels on big compute-bound K=3
        // layers (the VGG body regime)
        let g = gtx_1080ti();
        let dec = registry().decide(&ConvProblem::multi(256, 56, 256, 3), &g);
        assert_ne!(dec.backend, PAPER_TUNED, "no baseline ever selected");
        assert!(dec.speedup() > 1.0, "winner does not actually win");
    }

    #[test]
    fn paper_tuned_wins_its_headline_regime() {
        // small multi-channel maps are the paper's own win; dispatch
        // must keep serving the paper kernel there
        let g = gtx_1080ti();
        let dec = registry().decide(&ConvProblem::multi(256, 14, 256, 1), &g);
        assert_eq!(dec.backend, PAPER_TUNED, "paper kernel lost its home turf");
    }

    #[test]
    fn cpu_reference_is_never_dispatched() {
        let g = gtx_1080ti();
        for p in fig5_suite().into_iter().step_by(4) {
            assert_ne!(registry().decide(&p, &g).backend, "cpu-reference", "{}", p.label());
        }
    }

    #[test]
    fn memoized_decision_matches_fresh_ranking() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(128, 28, 128, 3);
        let fresh = registry().decide(&p, &g);
        let a = dispatched(&p, &g);
        let b = dispatched(&p, &g);
        assert_eq!(a, b);
        assert_eq!(a, fresh);
        // and the plan materializes under the winner's name
        let plan = dispatch_plan(&p, &g);
        let direct = registry().backend(&a.backend).unwrap().plan(&p, &g);
        assert_eq!(plan.name, direct.name);
    }

    #[test]
    fn memoized_op_decision_matches_fresh_ranking() {
        let g = gtx_1080ti();
        let op = ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1);
        let fresh = registry().decide_op(&op, &g);
        let a = op_dispatched(&op, &g);
        assert_eq!(a, op_dispatched(&op, &g));
        assert_eq!(a, fresh);
        let plan = dispatch_op_plan(&op, &g);
        let direct = registry().backend(&a.backend).unwrap().op_plan(&op, &g);
        assert_eq!(plan.name, direct.name);
    }

    #[test]
    fn dense_op_decisions_equal_problem_decisions() {
        // the degenerate case must be EXACT: the op layer changes
        // nothing for the paper's own stride-1/valid/dense workloads
        let g = gtx_1080ti();
        for p in fig5_suite().into_iter().step_by(5) {
            let via_problem = registry().decide(&p, &g);
            let via_op = registry().decide_op(&ConvOp::dense(p), &g);
            assert_eq!(via_problem, via_op, "{}", p.label());
        }
    }

    #[test]
    fn batched_dispatch_bounded_by_tuned_batched_path() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(64, 56, 64, 3);
        for n in [1usize, 2, 4, 8] {
            let b = BatchedConv::new(p, n);
            let secs = batched_dispatch_seconds(&b, &g);
            let tuned = plans::batched_seconds(&b, &g);
            assert!(secs <= tuned * (1.0 + 1e-9), "n={n}: {secs} > tuned {tuned}");
            assert!(secs > 0.0 && secs.is_finite());
        }
    }

    #[test]
    fn batched_op_dispatch_monotone_and_amortizing() {
        let g = gtx_1080ti();
        let op = ConvOp::depthwise(64, 28, 3, 1);
        let single = batched_op_dispatch_seconds(&BatchedConvOp::single(op), &g);
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8] {
            let t = batched_op_dispatch_seconds(&BatchedConvOp::new(op, n), &g);
            assert!(t > last, "n={n}");
            assert!(t <= n as f64 * single * (1.0 + 1e-9), "n={n}: no amortization");
            last = t;
        }
    }

    #[test]
    fn per_spec_decisions_can_differ_across_gpu_generations() {
        // the fleet's reason to dispatch per shard: each spec ranks for
        // itself.  Both specs' decisions respect their own floors.
        let g = gtx_1080ti();
        let t = titan_x_maxwell();
        for p in fig5_suite().into_iter().step_by(5) {
            for spec in [&g, &t] {
                let d = registry().decide(&p, spec);
                assert!(d.cycles <= d.tuned_cycles * (1.0 + 1e-9), "{}", spec.name);
            }
        }
    }

    #[test]
    fn fused_none_decision_is_exactly_the_unfused_decision() {
        let g = gtx_1080ti();
        for op in all_cnn_ops().into_iter().step_by(6) {
            let unfused = registry().decide_op(&op, &g);
            let fused = registry().decide_fused_op(&op, Epilogue::None, &g);
            assert_eq!(unfused, fused, "{}", op.label());
        }
    }

    #[test]
    fn fused_dispatch_never_loses_to_its_fused_lowered_floor() {
        let g = gtx_1080ti();
        let op = ConvOp::same(ConvProblem::multi(64, 28, 64, 3));
        for ep in [
            Epilogue::Relu,
            Epilogue::AddResidual,
            Epilogue::MaxPoolWriteback { k: 2, stride: 2 },
        ] {
            let d = registry().decide_fused_op(&op, ep, &g);
            assert!(
                d.cycles <= d.tuned_cycles * (1.0 + 1e-9),
                "{}: fused dispatch lost ({} > {})",
                ep.tag(),
                d.cycles,
                d.tuned_cycles
            );
        }
    }

    #[test]
    fn fused_pool_decision_prices_below_the_unfused_conv() {
        // the tentpole's win in one line: a pooled writeback shrinks
        // stores 4x, so the fused conv is never slower than unfused
        let g = gtx_1080ti();
        let op = ConvOp::same(ConvProblem::multi(64, 56, 64, 3));
        let unfused = registry().decide_op(&op, &g);
        let pooled =
            registry().decide_fused_op(&op, Epilogue::MaxPoolWriteback { k: 2, stride: 2 }, &g);
        assert!(pooled.cycles <= unfused.cycles * (1.0 + 1e-9));
        // relu is free in the tail: identical cost, identical winner
        let relu = registry().decide_fused_op(&op, Epilogue::Relu, &g);
        assert!((relu.cycles - unfused.cycles).abs() <= 1e-9 * unfused.cycles);
        assert_eq!(relu.backend, unfused.backend);
    }

    #[test]
    fn memoized_fused_decision_matches_fresh_ranking() {
        let g = gtx_1080ti();
        let op = ConvOp::same(ConvProblem::multi(32, 28, 32, 3));
        let ep = Epilogue::MaxPoolWriteback { k: 2, stride: 2 };
        let fresh = registry().decide_fused_op(&op, ep, &g);
        let a = fused_op_dispatched(&op, ep, &g);
        assert_eq!(a, fused_op_dispatched(&op, ep, &g));
        assert_eq!(a, fresh);
        let plan = dispatch_fused_op_plan(&op, ep, &g);
        assert!(plan.name.contains("+pool2s2"), "{}", plan.name);
        assert_eq!(plan.epilogue, ep);
    }

    #[test]
    fn advice_names_the_backend_and_the_tuned_floor() {
        let g = gtx_1080ti();
        let wino = dispatch_advice(&ConvProblem::multi(256, 56, 256, 3), &g);
        assert!(wino.contains("winograd") && wino.contains("tuned"), "{wino}");
        let ours = dispatch_advice(&ConvProblem::multi(256, 14, 256, 1), &g);
        assert!(ours.contains("paper-tuned") && ours.contains("tuned"), "{ours}");
        let dw = op_dispatch_advice(&ConvOp::depthwise(512, 14, 3, 1), &g);
        assert!(dw.contains("lowered paper-tuned"), "{dw}");
    }
}
