//! The concrete `ConvBackend` implementations: the paper's kernels
//! (tuned and closed-form), the CPU reference, and the four baselines
//! promoted from bench-only cost formulas to first-class backends.
//!
//! Timing goes through the same builders the benches always used
//! (`plans::*`, `baselines::*`); what this module adds is the uniform
//! trait surface — `supports()` envelopes the dispatcher can trust, and
//! reference semantics in each algorithm's own traversal order
//! (`backend::reference`).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::baselines::{cudnn_proxy, dac17, fft_conv, tan128, winograd};
use crate::conv::{conv2d_multi_cpu, BatchedConvOp, ConvOp, ConvProblem, BYTES_F32};
use crate::gpusim::{simulate, Epilogue, GpuSpec, KernelPlan, Loading, Round};
use crate::plans::{single_channel, stride_fixed};
use crate::tuner;

use super::reference;
use super::{op_plan_name, ConvBackend, OpCoverage};

/// The paper kernels' native op schedule: stride shrinks the output
/// strip schedule (`KernelPlan::decimated` — only the kept rows'
/// FMAs/writeback are charged), groups run side by side on idle SMs
/// (`KernelPlan::grouped`), padding is already folded into the unit's
/// enlarged map.  The naive lowered schedule (full stride-1 output,
/// sequential groups) is priced too and the faster of the two served —
/// the same never-lose structure as the tuner one layer down, so the
/// paper backends' op route can never price above their own lowering.
/// The native-vs-lowered outcome is memoized per (op, spec, unit
/// source): the serving path materializes dispatched plans per request,
/// and re-simulating both routes every time would make "serving never
/// searches" a lie on non-dense ops.
fn paper_op_plan(unit: KernelPlan, op: &ConvOp, spec: &GpuSpec, tuned_unit: bool) -> KernelPlan {
    static CHOICE: OnceLock<Mutex<HashMap<(ConvOp, &'static str, bool), bool>>> =
        OnceLock::new();
    let memo = CHOICE.get_or_init(|| Mutex::new(HashMap::new()));
    let l = op.lower();
    let build_native = |unit: &KernelPlan| {
        let mut p = unit.decimated(op.output_keep_fraction()).grouped(l.groups, spec.sm_count);
        p.name = op_plan_name(&unit.name, op, true);
        p
    };
    let build_lowered = |unit: &KernelPlan| {
        let mut p = unit.batched(l.groups);
        p.name = op_plan_name(&unit.name, op, false);
        p
    };
    let key = (*op, spec.name, tuned_unit);
    let cached = memo.lock().unwrap().get(&key).copied();
    let native_wins = match cached {
        Some(w) => w,
        None => {
            let w = simulate(spec, &build_native(&unit)).cycles
                <= simulate(spec, &build_lowered(&unit)).cycles;
            memo.lock().unwrap().insert(key, w);
            w
        }
    };
    if native_wins {
        build_native(&unit)
    } else {
        build_lowered(&unit)
    }
}

/// Every registered backend tag, in dispatcher registry order.  Cache
/// entries (`kind=dispatch backend=...`) must carry one of these.
pub const BACKEND_NAMES: [&str; 8] = [
    "paper-tuned",
    "paper",
    "cudnn-proxy",
    "dac17",
    "tan128",
    "winograd",
    "fft",
    "cpu-reference",
];

/// The paper's kernels under the plan-space tuner — the serving default
/// and the floor the dispatcher never loses to.
pub struct PaperTuned;

impl ConvBackend for PaperTuned {
    fn name(&self) -> &'static str {
        "paper-tuned"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid()
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        tuner::tuned_plan(p, spec)
    }

    fn op_coverage(&self, op: &ConvOp) -> OpCoverage {
        if op.valid() {
            OpCoverage::Native
        } else {
            OpCoverage::Unsupported
        }
    }

    /// Non-dense ops go through the OP-NATIVE tuner (`tuner::tuned_op`):
    /// the plan space is searched under the decimated/grouped/fused/
    /// batched objective itself, seeded by the inherited-geometry plan
    /// (the unit-tuned params pushed through the serving transforms —
    /// exactly what this method returned before the op-native search
    /// existed), so the route is never-lose vs the old one by
    /// construction.
    fn op_plan(&self, op: &ConvOp, spec: &GpuSpec) -> KernelPlan {
        assert!(op.valid(), "invalid op {op:?}");
        if op.is_dense() {
            return self.plan(&op.core, spec);
        }
        tuner::tuned_op_plan(op, Epilogue::None, 1, spec)
    }

    /// Fused ops are RE-TUNED over the epilogue axis (the fused floor
    /// reprices the writeback tail, which can flip the plan ranking —
    /// e.g. a pooled writeback shrinks stores 4x and shifts the best
    /// M'/W'x trade-off), instead of inheriting the `Epilogue::None`
    /// winner's geometry.  Never-lose vs the inherited fused plan by
    /// the same seeding argument as `op_plan`.
    fn fused_op_plan(&self, op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> KernelPlan {
        if ep.is_none() {
            return self.op_plan(op, spec);
        }
        tuner::tuned_op_plan(op, ep, 1, spec)
    }

    /// Batched ops are tuned under the batch-`n` objective, where
    /// cross-image filter residency (`KernelPlan::batched_resident`)
    /// can reward geometries the single-image ranking never picks
    /// (e.g. wider M' so the per-SM filter block fits beside the
    /// staging buffers and is streamed once per wave instead of once
    /// per image).
    fn batched_op_plan(&self, b: &BatchedConvOp, spec: &GpuSpec) -> KernelPlan {
        assert!(b.valid(), "invalid batched op");
        if b.n == 1 {
            return self.op_plan(&b.op, spec);
        }
        tuner::tuned_op_plan(&b.op, Epilogue::None, b.n, spec)
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        paper_reference(p, image, filters)
    }
}

/// The paper's verbatim §3 closed-form picks (the `--no-tune` path):
/// single-channel through the §3.1 P/Q procedure, multi-channel through
/// the §3.2 stride-fixed block method.
pub struct PaperClosedForm;

impl ConvBackend for PaperClosedForm {
    fn name(&self) -> &'static str {
        "paper"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid()
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        if p.is_single_channel() {
            single_channel::plan(p, spec)
        } else {
            stride_fixed::plan(p, spec)
        }
    }

    fn op_coverage(&self, op: &ConvOp) -> OpCoverage {
        if op.valid() {
            OpCoverage::Native
        } else {
            OpCoverage::Unsupported
        }
    }

    fn op_plan(&self, op: &ConvOp, spec: &GpuSpec) -> KernelPlan {
        assert!(op.valid(), "invalid op {op:?}");
        if op.is_dense() {
            return self.plan(&op.core, spec);
        }
        paper_op_plan(self.plan(&op.lower().unit, spec), op, spec, false)
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        paper_reference(p, image, filters)
    }
}

/// Both paper kernels share their reference traversal: §3.1 row pieces
/// for single-channel, §3.2 strips + 32-B filter segments for
/// multi-channel.  Parameters are representative fixed shapes (the
/// traversal is spec-free; results are parameter-independent by the
/// bit-exactness construction).
fn paper_reference(p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
    if p.is_single_channel() {
        reference::row_pieces(p, image, filters, 4, 64)
    } else {
        reference::strip_mined(p, image, filters, 128, 64, 32 / BYTES_F32)
    }
}

/// Host fallback: the Rust CPU oracle as a backend.  Its `plan` is a
/// coarse one-core host model — all compulsory bytes streamed once and
/// the full FMA volume issued at `HOST_FMA_FRACTION` of one SM's rate —
/// priced through the same simulator so the dispatcher can rank it
/// (it never wins on anything a GPU backend supports; gated by tests).
/// Its `execute_reference` IS `conv2d_multi_cpu`, making it the anchor
/// the differential tests compare every other backend against.
pub struct CpuReference;

/// One host core's FMA issue as a fraction of one SM's 128 x 2 / cycle:
/// a 16-lane FMA unit (AVX-class), ~47 GFLOP/s at the 1080Ti's clock.
pub const HOST_FMA_FRACTION: f64 = 0.0625;

impl ConvBackend for CpuReference {
    fn name(&self) -> &'static str {
        "cpu-reference"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid()
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        assert!(p.valid());
        let load_bytes = ((p.map_elems() + p.filter_elems()) * BYTES_F32) as f64;
        KernelPlan {
            name: "cpu-reference[host]".into(),
            rounds: vec![Round::new(load_bytes, 128, p.fma_ops() as f64)],
            sms_active: 1,
            threads_per_sm: 512,
            compute_efficiency: HOST_FMA_FRACTION,
            output_bytes: (p.out_elems() * BYTES_F32) as f64,
            smem_bytes_per_sm: 0,
            total_fma: p.fma_ops() as f64,
            // no kernel launch on the host path
            launch_overhead_cycles: 0.0,
            stages: 2,
            loading: Loading::Cyclic,
            stage_bytes: 0,
            epilogue: Epilogue::None,
            epilogue_read_bytes: 0.0,
            filter_resident_smem_bytes: 0,
            filter_l2_footprint_bytes: 0,
        }
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        conv2d_multi_cpu(p, image, filters)
    }
}

/// Implicit GEMM [12] — the cuDNN proxy of Figs. 4/5, with its internal
/// cudnnFindBestAlgorithm-style tile search.
pub struct CudnnProxy;

impl ConvBackend for CudnnProxy {
    fn name(&self) -> &'static str {
        "cudnn-proxy"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid()
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        cudnn_proxy::plan(p, spec)
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        reference::im2col_gemm(p, image, filters, 64, 64, 8)
    }
}

/// Chen et al. [1] (DAC'17): fixed 32x32 per-SM strips, whole-filter
/// segments.
pub struct Dac17;

impl ConvBackend for Dac17 {
    fn name(&self) -> &'static str {
        "dac17"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid()
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        dac17::plan(p, spec)
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        reference::strip_tiled_2d(
            p,
            image,
            filters,
            dac17::FIXED_STRIP_ROWS,
            dac17::FIXED_STRIP_ROWS,
            dac17::DAC17_M_PRIME,
        )
    }
}

/// Tan et al. [16]: the 128-B fetch discipline.  Only defined for the
/// multi-channel stride-fixed schedule — the §3.2 trade-off it sits on
/// has no single-channel analogue, so `supports` is honest about it.
pub struct Tan128;

impl ConvBackend for Tan128 {
    fn name(&self) -> &'static str {
        "tan128"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid() && !p.is_single_channel()
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        // the underlying builder tolerates C=1; the backend contract
        // does not — enforce the envelope here so an out-of-envelope
        // call fails loudly instead of pricing an undefined schedule
        assert!(self.supports(p), "tan128 backend is multi-channel only");
        tan128::plan(p, spec)
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        reference::strip_mined(p, image, filters, 128, 16, tan128::S_BYTES / BYTES_F32)
    }
}

/// Winograd F(2x2,3x3) [8]: K=3, stride 1 only (every problem in this
/// stack is stride 1, so the envelope reduces to K=3).
pub struct Winograd;

impl ConvBackend for Winograd {
    fn name(&self) -> &'static str {
        "winograd"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid() && p.k == 3
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        winograd::plan(p, spec)
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        reference::output_tiled(p, image, filters, 2)
    }
}

/// FFT convolution [13]: always legal, rarely fast at CNN filter sizes
/// (the padded filter transforms) — which is exactly what per-problem
/// dispatch is for.
pub struct FftConv;

impl ConvBackend for FftConv {
    fn name(&self) -> &'static str {
        "fft"
    }

    fn supports(&self, p: &ConvProblem) -> bool {
        p.valid()
    }

    fn plan(&self, p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
        fft_conv::plan(p, spec)
    }

    fn execute_reference(&self, p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
        reference::channel_planes(p, image, filters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, simulate};
    use crate::plans;

    #[test]
    fn paper_backends_wrap_the_plan_layer_exactly() {
        let g = gtx_1080ti();
        for p in [ConvProblem::single(56, 64, 3), ConvProblem::multi(64, 28, 64, 3)] {
            let tuned = PaperTuned.plan(&p, &g);
            assert_eq!(tuned.name, plans::plan_for(&p, &g).name, "{}", p.label());
            let paper = PaperClosedForm.plan(&p, &g);
            assert_eq!(paper.name, plans::paper_plan_for(&p, &g).name, "{}", p.label());
        }
    }

    #[test]
    fn supports_envelopes_are_honest() {
        let k3 = ConvProblem::multi(8, 14, 8, 3);
        let k5 = ConvProblem::multi(8, 14, 8, 5);
        let single = ConvProblem::single(28, 8, 3);
        let invalid = ConvProblem { c: 0, wy: 8, wx: 8, m: 1, k: 1 };
        assert!(Winograd.supports(&k3) && !Winograd.supports(&k5));
        assert!(Winograd.supports(&single), "K=3 single-channel is in envelope");
        assert!(Tan128.supports(&k3) && !Tan128.supports(&single));
        for b in all_for_test() {
            assert!(!b.supports(&invalid), "{} accepts an invalid problem", b.name());
        }
    }

    fn all_for_test() -> Vec<Box<dyn ConvBackend>> {
        vec![
            Box::new(PaperTuned),
            Box::new(PaperClosedForm),
            Box::new(CudnnProxy),
            Box::new(Dac17),
            Box::new(Tan128),
            Box::new(Winograd),
            Box::new(FftConv),
            Box::new(CpuReference),
        ]
    }

    #[test]
    fn names_match_registry_constant() {
        // guard the PRODUCTION registry, not a test-local copy: a new
        // backend added to Dispatcher::full() without a BACKEND_NAMES
        // entry would break the v2 cache save/load round-trip
        let registry = crate::backend::Dispatcher::full();
        let names: Vec<&str> = registry.backends().iter().map(|b| b.name()).collect();
        assert_eq!(names, BACKEND_NAMES.to_vec());
        // and the list the other tests iterate stays in sync with it
        let local: Vec<&str> = all_for_test().iter().map(|b| b.name()).collect();
        assert_eq!(local, names);
    }

    #[test]
    fn every_backend_simulates_where_it_supports() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(32, 14, 32, 3);
        for b in all_for_test() {
            assert!(b.supports(&p), "{}", b.name());
            let r = simulate(&g, &b.plan(&p, &g));
            assert!(r.seconds > 0.0 && r.seconds.is_finite(), "{}", b.name());
        }
    }

    #[test]
    fn paper_native_op_route_never_loses_to_its_own_lowering() {
        let g = gtx_1080ti();
        for op in [
            ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1),
            ConvOp::strided(ConvProblem::multi(64, 56, 128, 1), 2, 0),
            ConvOp::depthwise(32, 112, 3, 2),
            ConvOp::same(ConvProblem::multi(128, 28, 128, 3)),
        ] {
            assert_eq!(PaperTuned.op_coverage(&op), OpCoverage::Native, "{}", op.label());
            let l = op.lower();
            let lowered = PaperTuned.plan(&l.unit, &g).batched(l.groups);
            let native = PaperTuned.op_plan(&op, &g);
            assert!(
                simulate(&g, &native).cycles
                    <= simulate(&g, &lowered).cycles * (1.0 + 1e-9),
                "{}: native op route lost to its own lowering",
                op.label()
            );
        }
        // strided decimation is a genuine win, not just a tie
        let s2 = ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1);
        let l = s2.lower();
        let lowered = simulate(&g, &PaperTuned.plan(&l.unit, &g).batched(l.groups)).cycles;
        let native = simulate(&g, &PaperTuned.op_plan(&s2, &g)).cycles;
        assert!(native < lowered * 0.95, "stride-2 native {native} vs lowered {lowered}");
    }

    #[test]
    fn host_plan_is_orders_of_magnitude_slower_than_gpu_plans() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(64, 28, 64, 3);
        let host = CpuReference.seconds(&p, &g);
        let gpu = PaperTuned.seconds(&p, &g);
        assert!(host > 20.0 * gpu, "host {host} vs gpu {gpu}");
    }
}
