//! pasconv — CLI for the paper-reproduction stack.
//!
//! Subcommands:
//!   list                          show the artifact registry
//!   simulate --c --w --m --k      run one problem through the analytic
//!                                 model + simulator vs all baselines,
//!                                 with the dispatcher's pick called out
//!                                 (--stride/--pad/--groups lift it to a
//!                                 ConvOp priced through the op layer)
//!   serve [--requests N]          demo serve loop: synthetic CNN traffic
//!                                 through the coordinator, metrics out
//!   sweep [--suite fig4|fig5]     print the paper's figure sweeps
//!   tune [--suite ...]            search the plan space per workload and
//!                                 report tuned vs paper-fixed plans
//!   model [--model vgg16]         execute a whole model graph: end-to-end
//!                                 latency + arena memory plan, with
//!                                 epilogue fusion + zero-copy concat on
//!                                 by default (--no-fuse for the unfused
//!                                 floor; --report adds the per-node
//!                                 breakdown and the fusion summary)
//!   fleet [--devices N]           multi-GPU fleet simulation: batched
//!                                 conv traffic across N device shards
//!                                 under a placement policy, virtual-time
//!                                 throughput/latency/utilization out
//!                                 (--capacity-mib caps each shard's
//!                                 memory pool: multi-tenant admission
//!                                 with pool-pressure shedding)
//!   trace [--suite ...]           the roofline report (EXPERIMENTS §12):
//!                                 FMA-per-byte + achieved-vs-peak for the
//!                                 Fig.4/Fig.5 workloads and the five
//!                                 models; --trace-out writes a Perfetto
//!                                 trace of the model graphs
//!
//! `simulate`, `model` and `fleet` take `--json` (machine-readable
//! output via util::json) and `--trace-out FILE` (Chrome-trace/Perfetto
//! JSON of the run, virtual time); `serve` takes `--prometheus` (text
//! exposition of the coordinator metrics).
//!
//! `simulate` and `model` route through the cross-backend dispatcher by
//! default (per-problem / per-layer algorithm choice, never losing to
//! the tuned paper kernels); `--no-dispatch` pins them to the tuned
//! paper kernels only, `--no-tune` to the paper's closed-form §3 picks.
//! `sweep` always uses the paper kernels — it regenerates the paper's
//! figures, where "ours" must mean the paper's algorithm.

use std::path::Path;
use std::time::Duration;

use pasconv::baselines::{cudnn_proxy, dac17, tan128};
use pasconv::conv::suites::{all_cnn_layers, fig4_suite, fig5_suite};
use pasconv::conv::{ConvOp, ConvProblem};
use pasconv::coordinator::{plan_advice, BatchConfig, Coordinator, Payload};
use pasconv::gpusim::{gtx_1080ti, simulate, titan_x_maxwell, Epilogue, GpuSpec, KernelPlan};
use pasconv::plans::{op_plan_for, paper_op_plan_for, paper_plan_for, plan_for};
use pasconv::runtime::{default_artifact_dir, Runtime, Tensor};
use pasconv::tuner;
use pasconv::tuner::PlanCache;
use pasconv::util::bench::Table;
use pasconv::util::cli::Args;
use pasconv::util::json::Json;
use pasconv::util::rng::Rng;

fn main() {
    let args = Args::parse();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let rc = match cmd {
        "list" => cmd_list(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "tune" => cmd_tune(&args),
        "model" => cmd_model(&args),
        "fleet" => cmd_fleet(&args),
        "trace" => cmd_trace(&args),
        _ => {
            eprintln!(
                "usage: pasconv <list|simulate|serve|sweep|tune|model|fleet|trace> [flags]\n\
                 \n  list                              artifact registry\
                 \n  simulate --c C --w W --m M --k K  one problem, all kernels, simulated\
                 \n           [--stride S --pad P --groups G] op-level pricing\
                 \n           [--no-dispatch|--no-tune] (default: cross-backend dispatch)\
                 \n  serve [--requests N]              demo serving loop with batching\
                 \n  sweep [--suite fig4|fig5] [--gpu 1080ti|titanx] [--no-tune]\
                 \n  tune [--suite fig4|fig5|cnn|all] [--gpu 1080ti|titanx]\
                 \n       [--save FILE] [--load FILE]  plan-space search vs paper picks\
                 \n       [--ops [--model NAME] [--n B]] op-native mode: tune a model's\
                 \n                                    (op, epilogue) pairs directly at\
                 \n                                    batch B (filter residency priced)\
                 \n  model [--model NAME|all] [--gpu ...] [--no-dispatch|--no-tune]\
                 \n        [--no-fuse] [--report]      whole-model graph execution:\
                 \n                                    latency + arena memory plan +\
                 \n                                    per-layer backend choices; fused\
                 \n                                    epilogues + zero-copy concat by\
                 \n                                    default (--no-fuse for the plain\
                 \n                                    glue-kernel floor)\
                 \n  fleet [--devices N] [--policy rr|least|bytes|affinity] [--requests N]\
                 \n        [--batch B] [--queue-bound Q] [--overload X] [--hetero]\
                 \n        [--capacity-mib M]           virtual-time multi-GPU fleet run\
                 \n                                    (M > 0 caps each shard's memory\
                 \n                                    pool; admission sheds on memory)\
                 \n  trace [--suite fig4|fig5|models|all] [--gpu ...]\
                 \n                                    roofline report: FMA/byte +\
                 \n                                    achieved-vs-peak per workload\
                 \n\
                 \n  simulate/model/fleet also take:   --json (machine-readable output)\
                 \n                                    --trace-out FILE (Perfetto trace)\
                 \n  serve also takes:                 --prometheus (metrics exposition)\n"
            );
            if cmd == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(rc);
}

/// The problem planner `simulate` uses: the cross-backend dispatcher
/// by default, the tuned paper kernel under `--no-dispatch`, the
/// paper's closed-form pick under `--no-tune`.
fn planner(args: &Args) -> fn(&ConvProblem, &GpuSpec) -> KernelPlan {
    if args.has("no-tune") {
        paper_plan_for
    } else if args.has("no-dispatch") {
        plan_for
    } else {
        pasconv::backend::dispatch_plan
    }
}

/// The op planner `model` uses (a `graph::Planner`): same three modes,
/// lifted to the op layer — every mode handles stride/pad/groups and
/// fused writeback epilogues.
fn op_planner(args: &Args) -> fn(&ConvOp, Epilogue, &GpuSpec) -> KernelPlan {
    if args.has("no-tune") {
        paper_op_plan_for
    } else if args.has("no-dispatch") {
        op_plan_for
    } else {
        pasconv::backend::dispatch_fused_op_plan
    }
}

/// The planner the figure sweeps use: paper kernels only ("ours" in a
/// figure regeneration must mean the paper's algorithm, not whichever
/// baseline the dispatcher picked).
fn paper_only_planner(args: &Args) -> fn(&ConvProblem, &GpuSpec) -> KernelPlan {
    if args.has("no-tune") {
        paper_plan_for
    } else {
        plan_for
    }
}

fn cmd_list(_args: &Args) -> i32 {
    let dir = default_artifact_dir();
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("error: {e:#} — run `make artifacts`");
            return 1;
        }
    };
    println!("platform: {}", rt.platform());
    println!("artifacts in {}:", dir.display());
    let mut t = Table::new(&["name", "kind", "problem"]);
    for name in rt.names() {
        let a = rt.artifact(&name).unwrap();
        let desc = a
            .problem()
            .map(|p| p.label())
            .unwrap_or_else(|_| format!("PaperNet batch={}", a.batch().unwrap_or(0)));
        t.row(&[name.clone(), format!("{:?}", a.kind), desc]);
    }
    t.print();
    0
}

fn gpu_from(args: &Args) -> GpuSpec {
    match args.get_or("gpu", "1080ti") {
        "titanx" => titan_x_maxwell(),
        _ => gtx_1080ti(),
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let p = ConvProblem {
        c: args.get_usize("c", 1),
        wy: args.get_usize("w", 56),
        wx: args.get_usize("w", 56),
        m: args.get_usize("m", 64),
        k: args.get_usize("k", 3),
    };
    let op = ConvOp {
        core: p,
        stride: args.get_usize("stride", 1),
        pad: args.get_usize("pad", 0),
        groups: args.get_usize("groups", 1),
    };
    if !op.valid() {
        eprintln!("invalid op {op:?}");
        return 2;
    }
    let g = gpu_from(args);
    let json = args.has("json");
    let rows: Vec<(String, KernelPlan)> = if !op.is_dense() {
        // op-level pricing: native/lowered routes vs the lowered floor,
        // honoring the same mode flags as the dense path
        if !json {
            println!("op: {}   GPU: {}", op.label(), g.name);
            println!("lowered unit: {}", op.lower().unit.label());
        }
        let mode: &str = if args.has("no-tune") {
            "paper §3 (op)"
        } else if args.has("no-dispatch") {
            "paper-tuned (op)"
        } else {
            if !json {
                println!("dispatch: {}", pasconv::backend::op_dispatch_advice(&op, &g));
            }
            "dispatched"
        };
        let mut rows: Vec<(String, KernelPlan)> =
            vec![(mode.to_string(), op_planner(args)(&op, Epilogue::None, &g))];
        if mode != "paper-tuned (op)" {
            rows.push(("paper-tuned (op)".to_string(), op_plan_for(&op, Epilogue::None, &g)));
        }
        if mode != "paper §3 (op)" {
            rows.push((
                "paper §3 (op)".to_string(),
                paper_op_plan_for(&op, Epilogue::None, &g),
            ));
        }
        if !json {
            let ours = simulate(&g, &rows[0].1).seconds;
            let mut t = Table::new(&["route", "plan", "time", "GFLOP/s", "bottleneck", "vs pick"]);
            for (route, plan) in &rows {
                let r = simulate(&g, plan);
                t.row(&[
                    route.clone(),
                    r.name.clone(),
                    format!("{:.1}µs", r.seconds * 1e6),
                    format!("{:.0}", r.gflops),
                    r.bottleneck.to_string(),
                    format!("{:.2}x", r.seconds / ours),
                ]);
            }
            t.print();
        }
        rows
    } else {
        let plan_fn = planner(args);
        if !json {
            println!("problem: {}   GPU: {}", p.label(), g.name);
            println!("paper advice: {}", plan_advice(&p, &g));
            if !args.has("no-tune") {
                println!("tuner advice: {}", tuner::advice(&p, &g));
                if !args.has("no-dispatch") {
                    println!("dispatch:     {}", pasconv::backend::dispatch_advice(&p, &g));
                }
            }
        }
        let plans = vec![
            plan_fn(&p, &g),
            cudnn_proxy::plan(&p, &g),
            dac17::plan(&p, &g),
            tan128::plan(&p, &g),
        ];
        if !json {
            let ours = simulate(&g, &plans[0]).seconds;
            let mut t = Table::new(&[
                "kernel", "time", "GFLOP/s", "eff", "SMs", "bottleneck", "FMA/B", "vs ours",
            ]);
            for plan in &plans {
                let r = simulate(&g, plan);
                t.row(&[
                    r.name.clone(),
                    format!("{:.1}µs", r.seconds * 1e6),
                    format!("{:.0}", r.gflops),
                    format!("{:.1}%", 100.0 * r.efficiency),
                    format!("{:.0}", r.sm_utilization * g.sm_count as f64),
                    r.bottleneck.to_string(),
                    format!("{:.1}", r.fma_per_byte),
                    format!("{:.2}x", r.seconds / ours),
                ]);
            }
            t.print();
        }
        plans.into_iter().map(|plan| (plan.name.clone(), plan)).collect()
    };
    simulate_exports(args, &g, &op.label(), &rows)
}

/// Shared `--json` / `--trace-out` tail for `simulate`: the JSON view
/// carries every row's full roofline counters; the trace lays the
/// simulated kernels end-to-end on one virtual-time track.
fn simulate_exports(args: &Args, g: &GpuSpec, workload: &str, rows: &[(String, KernelPlan)]) -> i32 {
    use pasconv::trace::{Event, Recorder, Roofline, Span, TraceSink};
    if args.has("json") {
        let arr = Json::Arr(
            rows.iter()
                .map(|(label, plan)| {
                    Json::obj()
                        .set("route", label.as_str().into())
                        .set("roofline", Roofline::measure(g, plan).to_json())
                })
                .collect(),
        );
        println!(
            "{}",
            Json::obj()
                .set("workload", workload.into())
                .set("gpu", g.name.into())
                .set("rows", arr)
                .render()
        );
    }
    if let Some(path) = args.get("trace-out") {
        let mut rec = Recorder::new();
        let mut t = 0.0;
        for (label, plan) in rows {
            let roof = Roofline::measure(g, plan);
            let id = rec.next_span_id();
            let mut sp = Span::new(id, None, workload, label, t, t + roof.seconds);
            for (k, v) in roof.attrs() {
                sp = sp.attr(&k, v);
            }
            rec.record(Event::Span(sp));
            t += roof.seconds;
        }
        return write_trace(path, &rec);
    }
    0
}

/// Validate + write a recorded trace as Chrome-trace/Perfetto JSON.
fn write_trace(path: &str, rec: &pasconv::trace::Recorder) -> i32 {
    if let Err(e) = rec.validate() {
        eprintln!("internal error: trace failed validation: {e}");
        return 1;
    }
    if let Err(e) = std::fs::write(path, rec.chrome_json()) {
        eprintln!("error writing {path}: {e}");
        return 1;
    }
    println!("trace written to {path} ({} events)", rec.len());
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let n = args.get_usize("requests", 256);
    let dir = default_artifact_dir();
    let mut c = match Coordinator::start_with_gpu(
        &dir,
        BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        &gpu_from(args),
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#} — run `make artifacts`");
            return 1;
        }
    };
    println!("serving {n} synthetic PaperNet requests...");
    let mut rng = Rng::new(0xFEED);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }))
        .collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = c.metrics();
    println!("served {ok}/{n} in {:.2}s  ({:.0} req/s)", dt, ok as f64 / dt);
    println!("metrics: {}", m.to_json().render());
    if args.has("prometheus") {
        println!("\n{}", pasconv::trace::exposition(&m));
    }
    c.shutdown();
    0
}

fn cmd_sweep(args: &Args) -> i32 {
    let g = gpu_from(args);
    let plan_fn = paper_only_planner(args);
    let suite = match args.get_or("suite", "fig4") {
        "fig5" => fig5_suite(),
        _ => fig4_suite(),
    };
    let mut t = Table::new(&["problem", "ours", "cudnn-proxy", "speedup"]);
    let mut speedups = vec![];
    for p in suite {
        let ours = simulate(&g, &plan_fn(&p, &g)).seconds;
        let base = simulate(&g, &cudnn_proxy::plan(&p, &g)).seconds;
        speedups.push(base / ours);
        t.row(&[
            p.label(),
            format!("{:.1}µs", ours * 1e6),
            format!("{:.1}µs", base * 1e6),
            format!("{:.2}x", base / ours),
        ]);
    }
    t.print();
    println!(
        "average speedup on {}: {:.2}x",
        g.name,
        speedups.iter().sum::<f64>() / speedups.len() as f64
    );
    0
}

fn cmd_model(args: &Args) -> i32 {
    use pasconv::trace::{NoopSink, Recorder, TraceSink};

    let g = gpu_from(args);
    let plan_fn = op_planner(args);
    let which = args.get_or("model", "all");
    let json = args.has("json");
    let names: Vec<&str> = if which == "all" {
        pasconv::graph::MODEL_NAMES.to_vec()
    } else {
        vec![which]
    };
    let mut rec = Recorder::new();
    let mut noop = NoopSink;
    let trace_path = args.get("trace-out");
    let mut t = Table::new(&[
        "model",
        "nodes",
        "convs",
        "fused",
        "latency (ms)",
        "conv share",
        "arena (MiB)",
        "naive (MiB)",
        "saved",
        "backends",
    ]);
    let mut json_rows: Vec<Json> = Vec::new();
    for name in names {
        let graph = match pasconv::graph::model_graph(name) {
            Ok(gr) => gr,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        };
        // epilogue fusion + zero-copy concat by default: relu / add /
        // pool tails fold into their convs (`--no-fuse` executes the
        // plain graph, the structural never-lose floor)
        let (graph, fusion) = if args.has("no-fuse") {
            (graph, pasconv::graph::FusionReport::default())
        } else {
            pasconv::graph::fuse(&graph, &g, plan_fn)
        };
        // each model gets its own virtual-time track starting at 0
        let sink: &mut dyn TraceSink =
            if trace_path.is_some() { &mut rec } else { &mut noop };
        let r = pasconv::graph::execute_batched_traced(&graph, &g, plan_fn, 1, sink, 0.0, name);
        if args.has("report") && !json {
            println!("== {} on {} ==", r.model, r.gpu);
            r.table().print();
            println!("{}", r.summary());
            if fusion.nodes_fused > 0 {
                println!(
                    "fused {} nodes; glue eliminated: {} ({:.1}µs on {})",
                    fusion.nodes_fused,
                    pasconv::util::bench::fmt_mib(fusion.glue_bytes_eliminated as usize),
                    g.cycles_to_secs(fusion.glue_cycles_eliminated) * 1e6,
                    g.name
                );
            }
            println!();
        }
        // the distinct kernel families the planner chose (with the
        // dispatcher this is the per-layer backend mix, e.g.
        // "ours-multi+winograd"; paper-only planners show one family)
        let mut families: Vec<String> = r
            .nodes
            .iter()
            .filter(|n| n.kind == "conv")
            .map(|n| n.detail.split([' ', '[']).next().unwrap_or(&n.detail).to_string())
            .collect();
        families.sort();
        families.dedup();
        if json {
            json_rows.push(
                Json::obj()
                    .set("model", r.model.as_str().into())
                    .set("gpu", r.gpu.into())
                    .set("nodes", r.nodes.len().into())
                    .set("conv_layers", r.conv_layers.into())
                    .set("nodes_fused", fusion.nodes_fused.into())
                    .set("glue_bytes_eliminated", fusion.glue_bytes_eliminated.into())
                    .set(
                        "glue_seconds_eliminated",
                        g.cycles_to_secs(fusion.glue_cycles_eliminated).into(),
                    )
                    .set("latency_ms", (r.total_seconds * 1e3).into())
                    .set("conv_seconds", r.conv_seconds.into())
                    .set("glue_seconds", r.glue_seconds.into())
                    .set("arena_bytes", r.arena.peak_bytes.into())
                    .set("naive_bytes", r.arena.naive_bytes.into())
                    .set("saved_fraction", r.arena.saved_fraction().into())
                    .set("backends", families.join("+").as_str().into()),
            );
        } else {
            t.row(&[
                r.model.clone(),
                r.nodes.len().to_string(),
                r.conv_layers.to_string(),
                fusion.nodes_fused.to_string(),
                format!("{:.3}", r.total_seconds * 1e3),
                format!("{:.0}%", 100.0 * r.conv_seconds / r.total_seconds),
                pasconv::util::bench::fmt_mib(r.arena.peak_bytes),
                pasconv::util::bench::fmt_mib(r.arena.naive_bytes),
                format!("{:.0}%", 100.0 * r.arena.saved_fraction()),
                families.join("+"),
            ]);
        }
    }
    if json {
        println!("{}", Json::Arr(json_rows).render());
    } else {
        t.print();
    }
    if let Some(path) = trace_path {
        return write_trace(path, &rec);
    }
    0
}

fn cmd_fleet(args: &Args) -> i32 {
    use pasconv::fleet::{mean_service_secs, offered_load, Fleet, FleetConfig, Policy};
    use pasconv::trace::{run_traced, NoopSink, Recorder, TraceSink};

    let devices = args.get_usize("devices", 4);
    let n = args.get_usize("requests", 256);
    let batch = args.get_usize("batch", 4);
    let queue_bound = args.get_usize("queue-bound", 32);
    let overload = args.get_f64("overload", 4.0);
    // per-shard pool cap; 0 (the default) = the card's own DRAM
    let capacity_mib = args.get_usize("capacity-mib", 0);
    let capacity_bytes = (capacity_mib > 0).then(|| capacity_mib * 1024 * 1024);
    let Some(policy) = Policy::parse(args.get_or("policy", "least")) else {
        eprintln!("unknown policy (want rr|least|bytes|affinity)");
        return 2;
    };
    let g = gpu_from(args);
    let specs: Vec<GpuSpec> = if args.has("hetero") {
        // alternate the two paper testbeds across the shards
        (0..devices)
            .map(|i| if i % 2 == 0 { gtx_1080ti() } else { titan_x_maxwell() })
            .collect()
    } else {
        vec![g.clone(); devices]
    };
    let json = args.has("json");
    let names: Vec<&str> = specs.iter().map(|s| s.name).collect();
    if !json {
        println!(
            "fleet: {} devices [{}], policy {}, queue bound {queue_bound}, batch {batch}, pool cap {}",
            devices,
            names.join(", "),
            policy.label(),
            if capacity_mib > 0 { format!("{capacity_mib} MiB") } else { "device DRAM".to_string() },
        );
    }

    // model-tagged batched conv traffic over the §4 model layers
    // (fleet::traffic — the same generator the e2e_fleet bench replays);
    // offered rate: `overload` x one reference device's capacity.
    // The pump is trace::run_traced: with the no-op sink it is EXACTLY
    // the plain complete_until/submit/drain loop (difftest-gated).
    let mut fleet = Fleet::new(specs, FleetConfig { policy, queue_bound, capacity_bytes });
    let probe = offered_load(64, 1.0, 0xF1EE7, Some(batch));
    let rate = overload / mean_service_secs(&probe, &g);
    let load = offered_load(n, rate, 0xF1EE7, Some(batch));
    let mut rec = Recorder::new();
    let mut noop = NoopSink;
    let trace_path = args.get("trace-out");
    let sink: &mut dyn TraceSink = if trace_path.is_some() { &mut rec } else { &mut noop };
    let completions = run_traced(&mut fleet, &load, sink);
    let makespan = completions.iter().map(|c| c.finish).fold(0.0f64, f64::max);
    let lats: Vec<f64> = completions.iter().map(|c| c.latency()).collect();
    let s = pasconv::util::stats::Summary::of(&lats);
    let st = fleet.stats;
    let frag: usize = fleet.devices().iter().map(|d| d.pool().fragmentation_bytes()).sum();
    let peak_total: usize =
        fleet.devices().iter().map(|d| d.pool().stats.peak_in_use_slab).sum();
    let cap_total: usize = fleet.devices().iter().map(|d| d.pool().capacity()).sum();
    let evict_total: u64 = fleet.devices().iter().map(|d| d.pool().stats.evictions).sum();
    let reuse_total: u64 = fleet.devices().iter().map(|d| d.pool().stats.reuse_hits).sum();

    // fusion wins per shard: each (device, model) pair the traffic
    // actually landed, priced through the epilogue-fusion pass on that
    // shard's spec — (device, model, jobs, fused nodes, glue seconds
    // saved per inference).  Traffic tags are model_graph names.
    let mut served: std::collections::BTreeMap<(usize, String), usize> =
        std::collections::BTreeMap::new();
    for c in &completions {
        if let Some(m) = &c.model {
            *served.entry((c.device, m.clone())).or_insert(0) += 1;
        }
    }
    let fusion_rows: Vec<(usize, String, usize, usize, f64)> = served
        .iter()
        .map(|((dev, model), jobs)| {
            let graph = pasconv::graph::model_graph(model).expect("traffic tags are model names");
            let spec = &fleet.devices()[*dev].spec;
            let (_, rep) =
                pasconv::graph::fuse(&graph, spec, pasconv::backend::dispatch_fused_op_plan);
            (*dev, model.clone(), *jobs, rep.nodes_fused, spec.cycles_to_secs(rep.glue_cycles_eliminated))
        })
        .collect();

    // filter-residency wins per shard: the same (device, model) pairs,
    // priced through the batched executor at the traffic batch —
    // (device, model, resident conv layers, DRAM filter bytes NOT
    // re-streamed per batch execution)
    let residency_rows: Vec<(usize, String, usize, f64)> = served
        .iter()
        .map(|((dev, model), _)| {
            let graph = pasconv::graph::model_graph(model).expect("traffic tags are model names");
            let spec = &fleet.devices()[*dev].spec;
            let (fused, _) =
                pasconv::graph::fuse(&graph, spec, pasconv::backend::dispatch_fused_op_plan);
            let rep = pasconv::graph::execute_batched(
                &fused,
                spec,
                pasconv::backend::dispatch_fused_op_plan,
                batch.max(1),
            );
            (*dev, model.clone(), rep.resident_conv_layers, rep.resident_filter_bytes_saved)
        })
        .collect();

    if json {
        let per_device = Json::Arr(
            fleet
                .devices()
                .iter()
                .map(|d| {
                    let p = d.pool();
                    Json::obj()
                        .set("device", d.id.into())
                        .set("spec", d.spec.name.into())
                        .set("jobs", (d.completed as usize).into())
                        .set("busy_s", d.busy_secs.into())
                        .set("util", (d.busy_secs / makespan.max(1e-30)).into())
                        .set("pool_peak_bytes", p.stats.peak_in_use_slab.into())
                        .set("pool_capacity_bytes", p.capacity().into())
                        .set("evictions", (p.stats.evictions as usize).into())
                        .set("reuse_hits", (p.stats.reuse_hits as usize).into())
                        .set(
                            "fusion",
                            Json::Arr(
                                fusion_rows
                                    .iter()
                                    .filter(|(dev, ..)| *dev == d.id)
                                    .map(|(_, model, jobs, fused, saved)| {
                                        Json::obj()
                                            .set("model", model.as_str().into())
                                            .set("jobs", (*jobs).into())
                                            .set("nodes_fused", (*fused).into())
                                            .set("glue_saved_s", (*saved).into())
                                    })
                                    .collect(),
                            ),
                        )
                        .set(
                            "residency",
                            Json::Arr(
                                residency_rows
                                    .iter()
                                    .filter(|(dev, ..)| *dev == d.id)
                                    .map(|(_, model, layers, saved)| {
                                        Json::obj()
                                            .set("model", model.as_str().into())
                                            .set("resident_layers", (*layers).into())
                                            .set("filter_bytes_saved", (*saved).into())
                                    })
                                    .collect(),
                            ),
                        )
                })
                .collect(),
        );
        println!(
            "{}",
            Json::obj()
                .set("devices", devices.into())
                .set("policy", policy.label().into())
                .set("batch", batch.into())
                .set("queue_bound", queue_bound.into())
                .set("offered_rate_rps", rate.into())
                .set("overload", overload.into())
                .set("submitted", (st.submitted as usize).into())
                .set("accepted", (st.accepted as usize).into())
                .set("rejected", (st.rejected as usize).into())
                .set("mem_rejected", (st.mem_rejected as usize).into())
                .set("images", (st.batched_images as usize).into())
                .set("affinity_spills", (st.affinity_spills as usize).into())
                .set("makespan_s", makespan.into())
                .set("throughput_rps", (completions.len() as f64 / makespan.max(1e-30)).into())
                .set("p50_ms", (s.p50 * 1e3).into())
                .set("p99_ms", (s.p99 * 1e3).into())
                .set("pool_peak_bytes", peak_total.into())
                .set("pool_evictions", (evict_total as usize).into())
                .set("pool_reuse_hits", (reuse_total as usize).into())
                .set("pool_fragmentation_bytes", frag.into())
                .set("per_device", per_device)
                .render()
        );
    } else {
        let mut table = Table::new(&[
            "device", "spec", "jobs", "busy (s)", "util", "pool peak", "evict", "reuse",
        ]);
        for d in fleet.devices() {
            let p = d.pool();
            table.row(&[
                d.id.to_string(),
                d.spec.name.to_string(),
                d.completed.to_string(),
                format!("{:.3}", d.busy_secs),
                format!("{:.0}%", 100.0 * d.busy_secs / makespan.max(1e-30)),
                format!(
                    "{} ({:.0}%)",
                    pasconv::util::bench::fmt_mib(p.stats.peak_in_use_slab),
                    100.0 * p.stats.peak_in_use_slab as f64 / p.capacity() as f64
                ),
                p.stats.evictions.to_string(),
                p.stats.reuse_hits.to_string(),
            ]);
        }
        let busy_total: f64 = fleet.devices().iter().map(|d| d.busy_secs).sum();
        let jobs_total: u64 = fleet.devices().iter().map(|d| d.completed).sum();
        table.row(&[
            "TOTAL".to_string(),
            "-".to_string(),
            jobs_total.to_string(),
            format!("{:.3}", busy_total),
            format!(
                "{:.0}%",
                100.0 * busy_total / (makespan.max(1e-30) * fleet.device_count() as f64)
            ),
            format!(
                "{} ({:.0}%)",
                pasconv::util::bench::fmt_mib(peak_total),
                100.0 * peak_total as f64 / cap_total.max(1) as f64
            ),
            evict_total.to_string(),
            reuse_total.to_string(),
        ]);
        table.print();
        println!(
            "\noffered {:.0} req/s ({overload:.1}x capacity); accepted {}/{} ({} shed, {} on memory), {} images",
            rate, st.accepted, st.submitted, st.rejected, st.mem_rejected, st.batched_images
        );
        println!(
            "virtual makespan {:.3}s -> {:.0} req/s served; p50 {:.2}ms p99 {:.2}ms; {} affinity spills; residual pool fragmentation {} B",
            makespan,
            completions.len() as f64 / makespan.max(1e-30),
            s.p50 * 1e3,
            s.p99 * 1e3,
            st.affinity_spills,
            frag
        );
        println!(
            "pool totals: peak {} MiB, {} evictions, {} reuse hits",
            pasconv::util::bench::fmt_mib(peak_total),
            evict_total,
            reuse_total
        );
        if !fusion_rows.is_empty() {
            println!("\nfusion wins per shard (epilogue fusion + zero-copy concat):");
            let mut ft = Table::new(&[
                "device", "model", "jobs", "fused nodes", "glue saved / inference",
            ]);
            for (dev, model, jobs, fused, saved) in &fusion_rows {
                ft.row(&[
                    dev.to_string(),
                    model.clone(),
                    jobs.to_string(),
                    fused.to_string(),
                    format!("{:.1} µs", saved * 1e6),
                ]);
            }
            ft.print();
        }
        if residency_rows.iter().any(|(.., saved)| *saved > 0.0) {
            println!("\nfilter-residency wins per shard (batched serving at xb{batch}):");
            let mut rt = Table::new(&[
                "device", "model", "resident layers", "filter bytes saved / batch",
            ]);
            for (dev, model, layers, saved) in &residency_rows {
                rt.row(&[
                    dev.to_string(),
                    model.clone(),
                    layers.to_string(),
                    format!("{} MiB", pasconv::util::bench::fmt_mib(*saved as usize)),
                ]);
            }
            rt.print();
        }
    }
    if let Some(path) = trace_path {
        return write_trace(path, &rec);
    }
    0
}

fn cmd_trace(args: &Args) -> i32 {
    use pasconv::trace::{fig4_rows, fig5_rows, model_rows, roofline_table, rows_json, Recorder};

    let g = gpu_from(args);
    let json = args.has("json");
    let suite = args.get_or("suite", "all");
    let mut sections: Vec<(&str, Vec<pasconv::trace::RooflineRow>)> = Vec::new();
    if suite == "fig4" || suite == "all" {
        sections.push(("fig4", fig4_rows(&g)));
    }
    if suite == "fig5" || suite == "all" {
        sections.push(("fig5", fig5_rows(&g)));
    }
    if suite == "models" || suite == "all" {
        sections.push(("models", model_rows(&g)));
    }
    if sections.is_empty() {
        eprintln!("unknown suite {suite} (want fig4|fig5|models|all)");
        return 2;
    }
    if json {
        let mut out = Json::obj().set("gpu", g.name.into());
        for (name, rows) in &sections {
            out = out.set(name, rows_json(rows));
        }
        println!("{}", out.render());
    } else {
        for (name, rows) in &sections {
            println!("== roofline: {} on {} ==", name, g.name);
            roofline_table(rows).print();
            println!();
        }
    }
    if let Some(path) = args.get("trace-out") {
        // a Perfetto view of the five model graphs: one track per
        // model, per-node child spans with roofline counters
        let mut rec = Recorder::new();
        for name in pasconv::graph::MODEL_NAMES {
            let graph = pasconv::graph::model_graph(name).expect("canonical model name");
            pasconv::graph::execute_batched_traced(
                &graph,
                &g,
                pasconv::backend::dispatch_fused_op_plan,
                1,
                &mut rec,
                0.0,
                name,
            );
        }
        return write_trace(path, &rec);
    }
    0
}

fn cmd_tune(args: &Args) -> i32 {
    let g = gpu_from(args);
    if let Some(path) = args.get("load") {
        match PlanCache::load(Path::new(path)) {
            Ok(cache) => {
                let n = tuner::preload(cache);
                println!("preloaded {n} cache entries (plans + dispatch) from {path}");
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        }
    }
    if args.has("ops") {
        // op-native mode: tune each of a model's (op, epilogue) pairs
        // directly under the batched objective instead of inheriting
        // the stride-1 unit geometry
        let n = args.get_usize("n", 16);
        let model = args.get_or("model", "mobilenet_v1");
        let graph = match pasconv::graph::model_graph(model) {
            Ok(g) => g,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        };
        // the epilogue axis comes from the serving-time fusion rewrite,
        // so this prices exactly the (op, epilogue) pairs serving runs
        let (fused, _) =
            pasconv::graph::fuse(&graph, &g, pasconv::backend::dispatch_fused_op_plan);
        let mut ops: Vec<(pasconv::conv::ConvOp, pasconv::gpusim::Epilogue)> = vec![];
        for node in fused.nodes() {
            if let pasconv::graph::Op::Conv { conv, epilogue } = &node.op {
                if !ops.contains(&(*conv, *epilogue)) {
                    ops.push((*conv, *epilogue));
                }
            }
        }
        println!(
            "== op-native tuning on {} ({model}: {} distinct (op, epilogue) pairs, batch {n}) ==\n",
            g.name,
            ops.len()
        );
        let report = tuner::op_suite_report(&ops, n, &g);
        report.table.print();
        println!(
            "\nimproved on {}/{} ops; {} filter-resident; geomean speedup {:.3}x, max {:.2}x",
            report.improved, report.total, report.resident, report.geomean_speedup, report.max_speedup
        );
        if let Some(path) = args.get("save") {
            let snap = tuner::snapshot();
            if let Err(e) = snap.save(Path::new(path)) {
                eprintln!("error: {e:#}");
                return 1;
            }
            println!(
                "saved {} plan + {} op + {} dispatch entries to {path}",
                snap.len(),
                snap.op_len(),
                snap.dispatch_len()
            );
        }
        return 0;
    }
    let mut suite = match args.get_or("suite", "all") {
        "fig4" => fig4_suite(),
        "fig5" => fig5_suite(),
        "cnn" => all_cnn_layers(),
        _ => {
            let mut v = fig4_suite();
            v.extend(fig5_suite());
            for p in all_cnn_layers() {
                if !v.contains(&p) {
                    v.push(p);
                }
            }
            v
        }
    };
    suite.retain(|p| p.valid());

    println!("== plan-space tuning on {} ({} workloads) ==\n", g.name, suite.len());
    let report = tuner::suite_report(&suite, &g);
    report.table.print();
    println!(
        "\nimproved on {}/{} workloads; geomean speedup {:.3}x, max {:.2}x",
        report.improved, report.total, report.geomean_speedup, report.max_speedup
    );
    // cross-backend dispatch over the same suite, so `--save` persists
    // a complete v2 cache (plan + dispatch entries) and a coordinator
    // loading it starts with zero search of either kind
    let non_paper = suite
        .iter()
        .filter(|p| pasconv::backend::dispatched(p, &g).backend != "paper-tuned")
        .count();
    println!("dispatch: {non_paper}/{} workloads leave the paper kernels", suite.len());
    if let Some(path) = args.get("save") {
        let snap = tuner::snapshot();
        if let Err(e) = snap.save(Path::new(path)) {
            eprintln!("error: {e:#}");
            return 1;
        }
        println!(
            "saved {} plan + {} dispatch entries to {path}",
            snap.len(),
            snap.dispatch_len()
        );
    }
    0
}
