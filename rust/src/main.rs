//! pasconv — CLI for the paper-reproduction stack.
//!
//! Subcommands:
//!   list                          show the artifact registry
//!   simulate --c --w --m --k      run one problem through the analytic
//!                                 model + simulator vs all baselines
//!   serve [--requests N]          demo serve loop: synthetic CNN traffic
//!                                 through the coordinator, metrics out
//!   sweep [--suite fig4|fig5]     print the paper's figure sweeps
//!   tune [--suite ...]            search the plan space per workload and
//!                                 report tuned vs paper-fixed plans
//!   model [--model vgg16]         execute a whole model graph: end-to-end
//!                                 latency + arena memory plan
//!                                 (--report adds the per-node breakdown)
//!
//! `--no-tune` pins simulate/sweep/model to the paper's closed-form §3
//! picks.

use std::path::Path;
use std::time::Duration;

use pasconv::baselines::{cudnn_proxy, dac17, tan128};
use pasconv::conv::suites::{all_cnn_layers, fig4_suite, fig5_suite};
use pasconv::conv::ConvProblem;
use pasconv::coordinator::{plan_advice, BatchConfig, Coordinator, Payload};
use pasconv::gpusim::{gtx_1080ti, simulate, titan_x_maxwell, GpuSpec, KernelPlan};
use pasconv::plans::{paper_plan_for, plan_for};
use pasconv::runtime::{default_artifact_dir, Runtime, Tensor};
use pasconv::tuner;
use pasconv::tuner::PlanCache;
use pasconv::util::bench::Table;
use pasconv::util::cli::Args;
use pasconv::util::rng::Rng;

fn main() {
    let args = Args::parse();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let rc = match cmd {
        "list" => cmd_list(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "tune" => cmd_tune(&args),
        "model" => cmd_model(&args),
        _ => {
            eprintln!(
                "usage: pasconv <list|simulate|serve|sweep|tune|model> [flags]\n\
                 \n  list                              artifact registry\
                 \n  simulate --c C --w W --m M --k K  one problem, all kernels, simulated\
                 \n  serve [--requests N]              demo serving loop with batching\
                 \n  sweep [--suite fig4|fig5] [--gpu 1080ti|titanx] [--no-tune]\
                 \n  tune [--suite fig4|fig5|cnn|all] [--gpu 1080ti|titanx]\
                 \n       [--save FILE] [--load FILE]  plan-space search vs paper picks\
                 \n  model [--model NAME|all] [--gpu ...] [--no-tune] [--report]\
                 \n                                    whole-model graph execution:\
                 \n                                    latency + arena memory plan\n"
            );
            if cmd == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(rc);
}

/// The planner the figure commands use: tuned by default, the paper's
/// closed-form pick under `--no-tune`.
fn planner(args: &Args) -> fn(&ConvProblem, &GpuSpec) -> KernelPlan {
    if args.has("no-tune") {
        paper_plan_for
    } else {
        plan_for
    }
}

fn cmd_list(_args: &Args) -> i32 {
    let dir = default_artifact_dir();
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("error: {e:#} — run `make artifacts`");
            return 1;
        }
    };
    println!("platform: {}", rt.platform());
    println!("artifacts in {}:", dir.display());
    let mut t = Table::new(&["name", "kind", "problem"]);
    for name in rt.names() {
        let a = rt.artifact(&name).unwrap();
        let desc = a
            .problem()
            .map(|p| p.label())
            .unwrap_or_else(|_| format!("PaperNet batch={}", a.batch().unwrap_or(0)));
        t.row(&[name.clone(), format!("{:?}", a.kind), desc]);
    }
    t.print();
    0
}

fn gpu_from(args: &Args) -> GpuSpec {
    match args.get_or("gpu", "1080ti") {
        "titanx" => titan_x_maxwell(),
        _ => gtx_1080ti(),
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let p = ConvProblem {
        c: args.get_usize("c", 1),
        wy: args.get_usize("w", 56),
        wx: args.get_usize("w", 56),
        m: args.get_usize("m", 64),
        k: args.get_usize("k", 3),
    };
    if !p.valid() {
        eprintln!("invalid problem {p:?}");
        return 2;
    }
    let g = gpu_from(args);
    let plan_fn = planner(args);
    println!("problem: {}   GPU: {}", p.label(), g.name);
    println!("paper advice: {}", plan_advice(&p, &g));
    if !args.has("no-tune") {
        println!("tuner advice: {}", tuner::advice(&p, &g));
    }
    let plans =
        vec![plan_fn(&p, &g), cudnn_proxy::plan(&p, &g), dac17::plan(&p, &g), tan128::plan(&p, &g)];
    let ours = simulate(&g, &plans[0]).seconds;
    let mut t =
        Table::new(&["kernel", "time", "GFLOP/s", "eff", "SMs", "bottleneck", "FMA/B", "vs ours"]);
    for plan in &plans {
        let r = simulate(&g, plan);
        t.row(&[
            r.name.clone(),
            format!("{:.1}µs", r.seconds * 1e6),
            format!("{:.0}", r.gflops),
            format!("{:.1}%", 100.0 * r.efficiency),
            format!("{:.0}", r.sm_utilization * g.sm_count as f64),
            r.bottleneck.to_string(),
            format!("{:.1}", r.fma_per_byte),
            format!("{:.2}x", r.seconds / ours),
        ]);
    }
    t.print();
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let n = args.get_usize("requests", 256);
    let dir = default_artifact_dir();
    let mut c = match Coordinator::start_with_gpu(
        &dir,
        BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        &gpu_from(args),
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#} — run `make artifacts`");
            return 1;
        }
    };
    println!("serving {n} synthetic PaperNet requests...");
    let mut rng = Rng::new(0xFEED);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| c.submit(Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut rng) }))
        .collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = c.metrics();
    println!("served {ok}/{n} in {:.2}s  ({:.0} req/s)", dt, ok as f64 / dt);
    println!("metrics: {}", m.to_json().render());
    c.shutdown();
    0
}

fn cmd_sweep(args: &Args) -> i32 {
    let g = gpu_from(args);
    let plan_fn = planner(args);
    let suite = match args.get_or("suite", "fig4") {
        "fig5" => fig5_suite(),
        _ => fig4_suite(),
    };
    let mut t = Table::new(&["problem", "ours", "cudnn-proxy", "speedup"]);
    let mut speedups = vec![];
    for p in suite {
        let ours = simulate(&g, &plan_fn(&p, &g)).seconds;
        let base = simulate(&g, &cudnn_proxy::plan(&p, &g)).seconds;
        speedups.push(base / ours);
        t.row(&[
            p.label(),
            format!("{:.1}µs", ours * 1e6),
            format!("{:.1}µs", base * 1e6),
            format!("{:.2}x", base / ours),
        ]);
    }
    t.print();
    println!(
        "average speedup on {}: {:.2}x",
        g.name,
        speedups.iter().sum::<f64>() / speedups.len() as f64
    );
    0
}

fn cmd_model(args: &Args) -> i32 {
    let g = gpu_from(args);
    let plan_fn = planner(args);
    let which = args.get_or("model", "all");
    let names: Vec<&str> = if which == "all" {
        pasconv::graph::MODEL_NAMES.to_vec()
    } else {
        vec![which]
    };
    let mut t = Table::new(&[
        "model",
        "nodes",
        "convs",
        "latency (ms)",
        "conv share",
        "arena (MiB)",
        "naive (MiB)",
        "saved",
    ]);
    for name in names {
        let graph = match pasconv::graph::model_graph(name) {
            Ok(gr) => gr,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        };
        let r = pasconv::graph::execute(&graph, &g, plan_fn);
        if args.has("report") {
            println!("== {} on {} ==", r.model, r.gpu);
            r.table().print();
            println!("{}\n", r.summary());
        }
        t.row(&[
            r.model.clone(),
            r.nodes.len().to_string(),
            r.conv_layers.to_string(),
            format!("{:.3}", r.total_seconds * 1e3),
            format!("{:.0}%", 100.0 * r.conv_seconds / r.total_seconds),
            pasconv::util::bench::fmt_mib(r.arena.peak_bytes),
            pasconv::util::bench::fmt_mib(r.arena.naive_bytes),
            format!("{:.0}%", 100.0 * r.arena.saved_fraction()),
        ]);
    }
    t.print();
    0
}

fn cmd_tune(args: &Args) -> i32 {
    let g = gpu_from(args);
    if let Some(path) = args.get("load") {
        match PlanCache::load(Path::new(path)) {
            Ok(cache) => {
                let n = tuner::preload(cache);
                println!("preloaded {n} cached plans from {path}");
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        }
    }
    let mut suite = match args.get_or("suite", "all") {
        "fig4" => fig4_suite(),
        "fig5" => fig5_suite(),
        "cnn" => all_cnn_layers(),
        _ => {
            let mut v = fig4_suite();
            v.extend(fig5_suite());
            for p in all_cnn_layers() {
                if !v.contains(&p) {
                    v.push(p);
                }
            }
            v
        }
    };
    suite.retain(|p| p.valid());

    println!("== plan-space tuning on {} ({} workloads) ==\n", g.name, suite.len());
    let report = tuner::suite_report(&suite, &g);
    report.table.print();
    println!(
        "\nimproved on {}/{} workloads; geomean speedup {:.3}x, max {:.2}x",
        report.improved, report.total, report.geomean_speedup, report.max_speedup
    );
    if let Some(path) = args.get("save") {
        let snap = tuner::snapshot();
        if let Err(e) = snap.save(Path::new(path)) {
            eprintln!("error: {e:#}");
            return 1;
        }
        println!("saved {} cache entries to {path}", snap.len());
    }
    0
}
