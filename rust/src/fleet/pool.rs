//! Per-device size-classed exclusive memory pool — the multi-tenant
//! shard allocator.
//!
//! PR 2's arena plans one execution at a time; a shard serving many
//! concurrent graph executions needs their footprints to share the
//! device under a hard byte cap.  This pool slices device memory into
//! *slabs*: each slab belongs to exactly one size class (the request
//! rounded up to the `ARENA_ALIGN` = 256 B lattice — the same
//! granularity the arena planner uses, so pooled accounting composes
//! exactly with `ArenaPlan` bytes) and hosts at most one live
//! allocation at a time (exclusive — overlap is impossible by
//! construction; the stateful proptests check the accounting that
//! encodes it).  A freed slab parks on its class's free list and is
//! reused best-fit-within-class (exact class match, LIFO — the warmest
//! slab first); carving a new slab is only allowed while total carved
//! bytes stay under the cap, and when carving would overflow, *free*
//! slabs are evicted (largest class first, then most recently carved)
//! until the request fits or nothing free remains.  In-use slabs are
//! never evicted: an allocation failure is an explicit `PoolError` the
//! admission path turns into a rejection — never a deadlock.
//!
//! Fragmentation here is the slab-vs-request gap, bounded per live
//! allocation by `ARENA_ALIGN - 1` bytes (class = request rounded up to
//! 256); the aggregate bound is proptest-gated.  Because `can_fit` and
//! `alloc` share one decision procedure (exact class reuse, else carve
//! budget after evicting everything free), admission checks are exact:
//! `can_fit(b)` true implies the very next `alloc(b)` succeeds.

use std::collections::{BTreeMap, HashMap};

use crate::graph::ARENA_ALIGN;

/// Round a request up to its size class: the `ARENA_ALIGN` lattice.
/// Zero-byte requests still occupy one minimal slab (an allocation is
/// an identity, not just bytes).
pub fn size_class(bytes: usize) -> usize {
    let b = bytes.max(1);
    (b + ARENA_ALIGN - 1) / ARENA_ALIGN * ARENA_ALIGN
}

/// Why an allocation or free failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PoolError {
    /// the request's class cannot fit even after evicting every free
    /// slab — the caller must reject or queue, not wait
    Exhausted { requested: usize, class: usize, capacity: usize, in_use_slab: usize },
    /// free of an id that is not live (never allocated, or already
    /// freed) — the exactly-once-free contract
    UnknownAlloc(u64),
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::Exhausted { requested, class, capacity, in_use_slab } => write!(
                f,
                "pool exhausted: request {requested} B (class {class}) vs capacity {capacity} B with {in_use_slab} B in use"
            ),
            PoolError::UnknownAlloc(id) => write!(f, "free of unknown allocation {id}"),
        }
    }
}

/// Monotone counters over the pool's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// successful allocations
    pub allocs: u64,
    /// successful frees
    pub frees: u64,
    /// allocations served by reusing a parked slab of the exact class
    pub reuse_hits: u64,
    /// slabs carved fresh from capacity
    pub carved: u64,
    /// free slabs evicted to make room for a carve
    pub evictions: u64,
    /// allocations refused (pool exhausted)
    pub failed_allocs: u64,
    /// high-water mark of in-use slab bytes
    pub peak_in_use_slab: usize,
    /// high-water mark of in-use requested bytes
    pub peak_in_use_requested: usize,
}

#[derive(Clone, Copy, Debug)]
struct Slab {
    class: usize,
}

#[derive(Clone, Copy, Debug)]
struct Allocation {
    slab: u64,
    requested: usize,
}

/// One device's exclusive memory pool under a hard byte cap.
#[derive(Clone, Debug)]
pub struct DevicePool {
    capacity: usize,
    slabs: HashMap<u64, Slab>,
    /// parked (free) slabs by class; within a class the last-freed slab
    /// is reused first (LIFO — warmest)
    free_by_class: BTreeMap<usize, Vec<u64>>,
    live: HashMap<u64, Allocation>,
    next_slab: u64,
    next_alloc: u64,
    /// sum of classes of every slab, free + in use — the quantity the
    /// cap bounds
    slab_total: usize,
    /// sum of classes of in-use slabs
    in_use_slab: usize,
    /// sum of raw requested bytes of live allocations
    in_use_requested: usize,
    pub stats: PoolStats,
}

impl DevicePool {
    pub fn new(capacity: usize) -> DevicePool {
        assert!(capacity >= ARENA_ALIGN, "pool capacity below one slab class");
        DevicePool {
            capacity,
            slabs: HashMap::new(),
            free_by_class: BTreeMap::new(),
            live: HashMap::new(),
            next_slab: 1,
            next_alloc: 1,
            slab_total: 0,
            in_use_slab: 0,
            in_use_requested: 0,
            stats: PoolStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Bytes of carved slabs (free + in use); never exceeds capacity.
    pub fn slab_bytes(&self) -> usize {
        self.slab_total
    }

    /// Bytes of slabs currently hosting a live allocation.
    pub fn in_use_slab_bytes(&self) -> usize {
        self.in_use_slab
    }

    /// Raw requested bytes of live allocations.
    pub fn in_use_requested_bytes(&self) -> usize {
        self.in_use_requested
    }

    /// Bytes parked on free lists, reusable without carving.
    pub fn free_slab_bytes(&self) -> usize {
        self.slab_total - self.in_use_slab
    }

    /// Slab-vs-request overhead across live allocations — bounded by
    /// `ARENA_ALIGN - 1` per allocation (class rounding only).
    pub fn fragmentation_bytes(&self) -> usize {
        self.in_use_slab - self.in_use_requested
    }

    /// In-use slab bytes as a fraction of capacity.
    pub fn occupancy(&self) -> f64 {
        self.in_use_slab as f64 / self.capacity as f64
    }

    /// Occupancy if a request of `bytes` were admitted on top of the
    /// current residents — the placement policy's pressure signal.
    pub fn occupancy_with(&self, bytes: usize) -> f64 {
        (self.in_use_slab + size_class(bytes)) as f64 / self.capacity as f64
    }

    pub fn live_allocs(&self) -> usize {
        self.live.len()
    }

    /// Exact admission check: would `alloc(bytes)` succeed right now?
    /// True iff a parked slab of the class exists, or the class fits in
    /// capacity once everything free is evicted.
    pub fn can_fit(&self, bytes: usize) -> bool {
        let class = size_class(bytes);
        self.free_by_class.get(&class).map_or(false, |v| !v.is_empty())
            || self.in_use_slab + class <= self.capacity
    }

    /// Allocate `bytes`: exact-class reuse, else carve (evicting free
    /// slabs largest-class-first if the cap is hit).  Returns the
    /// allocation id to pass to `free`.
    pub fn alloc(&mut self, bytes: usize) -> Result<u64, PoolError> {
        let class = size_class(bytes);
        let slab = if let Some(id) = self.take_free(class) {
            self.stats.reuse_hits += 1;
            id
        } else {
            // evict free slabs until the carve fits (largest class
            // first, most recently carved within a class — deterministic)
            while self.slab_total + class > self.capacity && self.evict_one() {}
            if self.slab_total + class > self.capacity {
                self.stats.failed_allocs += 1;
                return Err(PoolError::Exhausted {
                    requested: bytes,
                    class,
                    capacity: self.capacity,
                    in_use_slab: self.in_use_slab,
                });
            }
            let id = self.next_slab;
            self.next_slab += 1;
            self.slabs.insert(id, Slab { class });
            self.slab_total += class;
            self.stats.carved += 1;
            id
        };
        let id = self.next_alloc;
        self.next_alloc += 1;
        self.live.insert(id, Allocation { slab, requested: bytes });
        self.in_use_slab += class;
        self.in_use_requested += bytes;
        self.stats.allocs += 1;
        self.stats.peak_in_use_slab = self.stats.peak_in_use_slab.max(self.in_use_slab);
        self.stats.peak_in_use_requested =
            self.stats.peak_in_use_requested.max(self.in_use_requested);
        Ok(id)
    }

    /// Release allocation `id`; its slab parks on the class free list.
    /// Freeing an unknown (or already freed) id is an error and leaves
    /// the pool untouched — exactly-once semantics.
    pub fn free(&mut self, id: u64) -> Result<(), PoolError> {
        let a = self.live.remove(&id).ok_or(PoolError::UnknownAlloc(id))?;
        let class = self.slabs[&a.slab].class;
        self.in_use_slab -= class;
        self.in_use_requested -= a.requested;
        self.free_by_class.entry(class).or_default().push(a.slab);
        self.stats.frees += 1;
        Ok(())
    }

    /// Evict every parked slab, returning the bytes reclaimed — the
    /// explicit trim the CLI / coordinator can trigger.
    pub fn evict_free(&mut self) -> usize {
        let before = self.slab_total;
        while self.evict_one() {}
        before - self.slab_total
    }

    /// Pop the warmest parked slab of exactly `class`.
    fn take_free(&mut self, class: usize) -> Option<u64> {
        let list = self.free_by_class.get_mut(&class)?;
        let id = list.pop()?;
        if list.is_empty() {
            self.free_by_class.remove(&class);
        }
        Some(id)
    }

    /// Evict one free slab — largest class first, highest (most recent)
    /// slab id within the class.  False when nothing is parked.
    fn evict_one(&mut self) -> bool {
        let Some((&class, _)) = self.free_by_class.iter().next_back() else {
            return false;
        };
        let list = self.free_by_class.get_mut(&class).expect("class present");
        let id = list.pop().expect("free list non-empty");
        if list.is_empty() {
            self.free_by_class.remove(&class);
        }
        self.slabs.remove(&id);
        self.slab_total -= class;
        self.stats.evictions += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_class_is_the_arena_lattice() {
        assert_eq!(size_class(0), 256);
        assert_eq!(size_class(1), 256);
        assert_eq!(size_class(256), 256);
        assert_eq!(size_class(257), 512);
        assert_eq!(size_class(1024), 1024);
    }

    #[test]
    fn alloc_free_roundtrip_accounts_exactly() {
        let mut p = DevicePool::new(4096);
        let a = p.alloc(300).unwrap();
        assert_eq!(p.in_use_slab_bytes(), 512);
        assert_eq!(p.in_use_requested_bytes(), 300);
        assert_eq!(p.fragmentation_bytes(), 212);
        assert_eq!(p.slab_bytes(), 512);
        p.free(a).unwrap();
        assert_eq!(p.in_use_slab_bytes(), 0);
        assert_eq!(p.slab_bytes(), 512, "freed slab stays carved, parked");
        assert_eq!(p.live_allocs(), 0);
    }

    #[test]
    fn exact_class_reuse_is_lifo() {
        let mut p = DevicePool::new(4096);
        let a = p.alloc(512).unwrap();
        let b = p.alloc(512).unwrap();
        p.free(a).unwrap();
        p.free(b).unwrap();
        assert_eq!(p.stats.carved, 2);
        let _c = p.alloc(512).unwrap();
        assert_eq!(p.stats.reuse_hits, 1);
        assert_eq!(p.stats.carved, 2, "no new carve");
        assert_eq!(p.slab_bytes(), 1024);
    }

    #[test]
    fn cap_is_hard_and_eviction_reclaims_free_slabs() {
        let mut p = DevicePool::new(1024);
        let a = p.alloc(512).unwrap();
        let b = p.alloc(512).unwrap();
        assert!(!p.can_fit(256), "cap full with live allocs");
        assert_eq!(p.alloc(256).unwrap_err(), PoolError::Exhausted {
            requested: 256,
            class: 256,
            capacity: 1024,
            in_use_slab: 1024,
        });
        assert_eq!(p.stats.failed_allocs, 1);
        p.free(b).unwrap();
        // a 256 B request can't reuse the 512 B slab (class mismatch)
        // but carving 256 evicts the parked 512 to fit under the cap
        assert!(p.can_fit(256));
        let _c = p.alloc(256).unwrap();
        assert_eq!(p.stats.evictions, 1);
        assert!(p.slab_bytes() <= p.capacity());
        p.free(a).unwrap();
    }

    #[test]
    fn can_fit_agrees_with_alloc() {
        let mut p = DevicePool::new(2048);
        let mut ids = vec![];
        for bytes in [100, 600, 256, 900, 512, 64] {
            let fits = p.can_fit(bytes);
            match p.alloc(bytes) {
                Ok(id) => {
                    assert!(fits, "alloc({bytes}) succeeded but can_fit said no");
                    ids.push(id);
                }
                Err(_) => assert!(!fits, "can_fit({bytes}) true but alloc failed"),
            }
        }
        for id in ids {
            p.free(id).unwrap();
        }
    }

    #[test]
    fn double_free_and_unknown_free_are_errors() {
        let mut p = DevicePool::new(1024);
        let a = p.alloc(100).unwrap();
        p.free(a).unwrap();
        assert_eq!(p.free(a).unwrap_err(), PoolError::UnknownAlloc(a));
        assert_eq!(p.free(999).unwrap_err(), PoolError::UnknownAlloc(999));
        assert_eq!(p.stats.frees, 1, "failed frees don't count");
    }

    #[test]
    fn eviction_prefers_largest_class() {
        let mut p = DevicePool::new(2048);
        let a = p.alloc(256).unwrap();
        let b = p.alloc(1024).unwrap();
        p.free(a).unwrap();
        p.free(b).unwrap();
        // carving 768 needs 768 over a 2048 cap with 1280 parked:
        // fits without eviction (1280 + 768 <= 2048)
        let c = p.alloc(768).unwrap();
        assert_eq!(p.stats.evictions, 0);
        // now carving another 768 (total would be 2816) evicts the
        // 1024 first — one eviction suffices
        let d = p.alloc(768).unwrap();
        assert_eq!(p.stats.evictions, 1);
        assert!(p.slab_bytes() <= 2048);
        p.free(c).unwrap();
        p.free(d).unwrap();
    }

    #[test]
    fn evict_free_trims_everything_parked() {
        let mut p = DevicePool::new(4096);
        let ids: Vec<u64> = (0..4).map(|_| p.alloc(512).unwrap()).collect();
        for id in ids {
            p.free(id).unwrap();
        }
        assert_eq!(p.free_slab_bytes(), 2048);
        assert_eq!(p.evict_free(), 2048);
        assert_eq!(p.slab_bytes(), 0);
        assert_eq!(p.stats.evictions, 4);
    }

    #[test]
    fn occupancy_and_pressure_signal() {
        let mut p = DevicePool::new(1024);
        assert_eq!(p.occupancy(), 0.0);
        let _a = p.alloc(512).unwrap();
        assert!((p.occupancy() - 0.5).abs() < 1e-12);
        assert!((p.occupancy_with(512) - 1.0).abs() < 1e-12);
        assert!(p.occupancy_with(1024) > 1.0, "over-cap pressure visible");
    }

    #[test]
    fn peaks_are_high_water_marks() {
        let mut p = DevicePool::new(4096);
        let a = p.alloc(1000).unwrap();
        let b = p.alloc(1000).unwrap();
        p.free(a).unwrap();
        p.free(b).unwrap();
        let _c = p.alloc(100).unwrap();
        assert_eq!(p.stats.peak_in_use_requested, 2000);
        assert_eq!(p.stats.peak_in_use_slab, 2048);
    }
}
