//! Synthetic fleet traffic: model-tagged batched-conv request streams.
//! ONE definition shared by the `fleet` CLI subcommand and the
//! `e2e_fleet` bench (and mirrored line-for-line by
//! `python/mirror/validate_fleet.py`), so the three can never drift.

use crate::backend;
use crate::conv::{suites, BatchedConvOp, ConvOp};
use crate::gpusim::GpuSpec;
use crate::util::rng::Rng;

/// One offered request: arrival time, batched op, model tag (affinity
/// key).
pub struct Arrival {
    pub t: f64,
    pub conv: BatchedConvOp,
    pub model: &'static str,
}

/// Conv ops per model tag — what the affinity policy pins to shards.
/// Real op geometry throughout: ResNet-18's stride-2 transitions and
/// MobileNetV1's depthwise/pointwise stack ride the same stream as the
/// 'same'-padded AlexNet/VGG bodies.
pub fn model_layers() -> Vec<(&'static str, Vec<ConvOp>)> {
    vec![
        ("alexnet", suites::alexnet()),
        ("resnet18", suites::resnet18()),
        ("vgg16", suites::vgg16()),
        ("mobilenet_v1", suites::mobilenet_v1()),
    ]
}

/// A fixed Poisson request stream at `rate` req/s: replaying the same
/// (n, rate, seed, batch) always yields the same sequence, which is how
/// every fleet configuration sees equal offered load.  `batch` None
/// draws n ∈ {1, 2, 4, 8} per request; `Some(b)` fixes it (the CLI's
/// `--batch` knob) without consuming an RNG draw.
pub fn offered_load(n: usize, rate: f64, seed: u64, batch: Option<usize>) -> Vec<Arrival> {
    let models = model_layers();
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let u = rng.next_f64().max(f64::MIN_POSITIVE);
        t += -u.ln() / rate;
        let (model, layers) = &models[rng.range_usize(0, models.len() - 1)];
        let op = *rng.choose(layers);
        let b = batch.unwrap_or_else(|| [1usize, 2, 4, 8][rng.range_usize(0, 3)]);
        out.push(Arrival { t, conv: BatchedConvOp::new(op, b), model: *model });
    }
    out
}

/// Mean predicted service seconds of `load` on one `spec` — the
/// capacity yardstick offered rates are calibrated against
/// (`rate = overload / mean_service_secs(probe, spec)`).  Priced like
/// the fleet prices: through the cross-backend dispatcher.
pub fn mean_service_secs(load: &[Arrival], spec: &GpuSpec) -> f64 {
    assert!(!load.is_empty(), "empty probe");
    load.iter()
        .map(|a| backend::batched_op_dispatch_seconds(&a.conv, spec))
        .sum::<f64>()
        / load.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn stream_is_deterministic_and_monotone() {
        let a = offered_load(64, 100.0, 7, None);
        let b = offered_load(64, 100.0, 7, None);
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.t, x.conv, x.model), (y.t, y.conv, y.model));
        }
        for w in a.windows(2) {
            assert!(w[1].t > w[0].t, "arrival times must increase");
        }
    }

    #[test]
    fn fixed_batch_skips_the_batch_draw_only() {
        let free = offered_load(32, 100.0, 9, None);
        let fixed = offered_load(32, 100.0, 9, Some(4));
        assert!(fixed.iter().all(|a| a.conv.n == 4));
        assert!(free.iter().any(|a| a.conv.n != 4));
        // same gaps and problems up to the first post-draw divergence:
        // the first request's t and problem must match exactly
        assert_eq!(free[0].t, fixed[0].t);
        assert_eq!(free[0].conv.op, fixed[0].conv.op);
    }

    #[test]
    fn models_come_from_the_registry() {
        let tags: Vec<&str> = model_layers().iter().map(|(m, _)| *m).collect();
        for a in offered_load(64, 100.0, 11, None) {
            assert!(tags.contains(&a.model), "{}", a.model);
            let (_, layers) = model_layers().swap_remove(
                tags.iter().position(|t| *t == a.model).unwrap(),
            );
            assert!(layers.contains(&a.conv.op));
        }
    }

    #[test]
    fn mean_service_positive_and_batch_monotone() {
        let g = gtx_1080ti();
        let s1 = mean_service_secs(&offered_load(16, 1.0, 3, Some(1)), &g);
        let s8 = mean_service_secs(&offered_load(16, 1.0, 3, Some(8)), &g);
        assert!(s1 > 0.0);
        assert!(s8 > s1, "bigger batches cost more in total");
        assert!(s8 < 8.0 * s1, "but amortize vs 8 launches");
    }
}
