//! The fleet scheduler: N simulated devices behind one batch-aware
//! admission path, driven in virtual time.
//!
//! `submit` prices the job on every shard through that shard's own
//! dispatcher (`backend::batched_op_dispatch_seconds` under each device's
//! spec — heterogeneous fleets price differently per shard AND can
//! pick different algorithms per GPU generation for the same job),
//! asks the placement policy for a device, and either enqueues (fixing
//! the job's start/finish deterministically, FIFO) or rejects when the
//! policy finds every bounded queue full.
//! `next_completion` pops the globally earliest finishing job and
//! advances the virtual clock; `drain` runs the fleet dry.
//!
//! Everything is deterministic given the submission sequence — the
//! stateful proptests in `rust/tests/fleet_proptests.rs` replay an
//! independent reference model against every transition.

use std::collections::HashMap;

use crate::backend;
use crate::conv::{BatchedConvOp, ConvOp};
use crate::gpusim::GpuSpec;

use super::device::{Completion, Device};
use super::policy::{
    least_loaded_bytes_pick, least_loaded_pick, round_robin_pick, PlacementCandidate, Policy,
};

/// Fleet-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    pub policy: Policy,
    /// max jobs resident per device (running + waiting); admission
    /// rejects once the policy finds every candidate at the bound.  A
    /// coalesced batch occupies ONE slot whatever its `n` — batching
    /// buys admission capacity as well as launch amortization.
    pub queue_bound: usize,
    /// per-device pool cap, bytes; None = the card's own DRAM
    /// (`spec.dram_bytes` — effectively unbounded for conv traffic, so
    /// pre-pool behavior is preserved exactly)
    pub capacity_bytes: Option<usize>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { policy: Policy::LeastLoaded, queue_bound: 32, capacity_bytes: None }
    }
}

/// Admission outcome for an accepted job.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub job: u64,
    pub device: usize,
    /// predicted start/finish in virtual seconds (exact under FIFO)
    pub start: f64,
    pub finish: f64,
}

/// Fleet counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetStats {
    pub submitted: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// total images across accepted jobs (sum of batch `n`)
    pub batched_images: u64,
    /// affinity jobs that spilled off their sticky shard (queue full)
    pub affinity_spills: u64,
    /// rejections attributable to memory: some shard had a queue slot
    /// free, but no shard's pool fit the job's planned footprint
    pub mem_rejected: u64,
}

/// A multi-GPU fleet in virtual time.
pub struct Fleet {
    devices: Vec<Device>,
    cfg: FleetConfig,
    now: f64,
    rr_cursor: usize,
    /// sticky model -> device assignments (ModelAffinity policy)
    affinity: HashMap<String, usize>,
    next_job: u64,
    /// memoized predicted seconds per (op, batch, device spec)
    cost_cache: HashMap<(ConvOp, usize, &'static str), f64>,
    pub stats: FleetStats,
}

impl Fleet {
    pub fn new(specs: Vec<GpuSpec>, cfg: FleetConfig) -> Fleet {
        assert!(!specs.is_empty(), "fleet needs at least one device");
        assert!(cfg.queue_bound >= 1, "queue bound must be >= 1");
        if let Some(cap) = cfg.capacity_bytes {
            assert!(cap >= crate::graph::ARENA_ALIGN, "pool capacity below one slab class");
        }
        Fleet {
            devices: specs
                .into_iter()
                .enumerate()
                .map(|(i, s)| Device::new(i, s, cfg.capacity_bytes))
                .collect(),
            cfg,
            now: 0.0,
            rr_cursor: 0,
            affinity: HashMap::new(),
            next_job: 1,
            cost_cache: HashMap::new(),
            stats: FleetStats::default(),
        }
    }

    /// `n` identical devices.
    pub fn homogeneous(n: usize, spec: &GpuSpec, cfg: FleetConfig) -> Fleet {
        Fleet::new(vec![spec.clone(); n], cfg)
    }

    pub fn config(&self) -> FleetConfig {
        self.cfg
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// The virtual clock, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Move the virtual clock forward (arrival processes drive this);
    /// moving backward is a no-op — time is monotone.
    pub fn advance_to(&mut self, t: f64) {
        self.now = self.now.max(t);
    }

    /// Jobs accepted but not yet completed, fleet-wide.
    pub fn in_flight(&self) -> usize {
        self.devices.iter().map(|d| d.queue_len()).sum()
    }

    /// Predicted service seconds of a batch on device `device` — the
    /// cross-backend dispatched cost (`backend::batched_op_dispatch_seconds`)
    /// under that device's spec, memoized per (problem, n, spec).
    pub fn predicted_service(&mut self, conv: &BatchedConvOp, device: usize) -> f64 {
        service_for(&mut self.cost_cache, &self.devices[device].spec, conv)
    }

    /// The sticky shard a model is pinned to, if assigned yet.
    pub fn affinity_shard(&self, model: &str) -> Option<usize> {
        self.affinity.get(model).copied()
    }

    /// Admission: price the job on every shard, check each shard's pool
    /// for the job's planned footprint, place per policy.  `None` =
    /// rejected: every candidate queue at its bound, or — on capped
    /// pools — no shard's pool fits the footprint (`mem_rejected`).
    /// Rejection is immediate; a job never waits on memory (no
    /// deadlock), the caller re-submits later if it wants queueing.
    pub fn submit(&mut self, conv: BatchedConvOp, model: Option<&str>) -> Option<Placement> {
        assert!(conv.valid(), "invalid batched op");
        self.stats.submitted += 1;
        let bytes = conv.footprint_bytes();
        let cands: Vec<PlacementCandidate> = (0..self.devices.len())
            .map(|i| PlacementCandidate {
                device: i,
                queue_len: self.devices[i].queue_len(),
                queue_bound: self.cfg.queue_bound,
                ready_at: self.devices[i].ready_at(self.now),
                service: service_for(&mut self.cost_cache, &self.devices[i].spec, &conv),
                fits: self.devices[i].pool().can_fit(bytes),
                occupancy_after: self.devices[i].pool().occupancy_with(bytes),
            })
            .collect();

        let pick = match self.cfg.policy {
            Policy::RoundRobin => {
                let p = round_robin_pick(&cands, self.rr_cursor);
                if let Some(d) = p {
                    self.rr_cursor = (d + 1) % self.devices.len();
                }
                p
            }
            Policy::LeastLoaded => least_loaded_pick(&cands),
            Policy::LeastLoadedBytes => least_loaded_bytes_pick(&cands),
            Policy::ModelAffinity => match model.and_then(|m| self.affinity.get(m).copied()) {
                // untagged, or first sight of this model: least-loaded;
                // the pin is recorded below ONLY if the job is accepted
                // (a rejected first submission must not pin anything)
                None => least_loaded_pick(&cands),
                Some(shard) if cands[shard].admissible() => Some(shard),
                Some(_) => {
                    // sticky shard saturated (queue or pool): spill,
                    // keep the pin
                    let spill = least_loaded_pick(&cands);
                    if spill.is_some() {
                        self.stats.affinity_spills += 1;
                    }
                    spill
                }
            },
        };

        let Some(d) = pick else {
            self.stats.rejected += 1;
            if cands.iter().any(|c| !c.full()) {
                // a queue slot existed somewhere — memory blocked this one
                self.stats.mem_rejected += 1;
            }
            return None;
        };
        if self.cfg.policy == Policy::ModelAffinity {
            if let Some(m) = model {
                self.affinity.entry(m.to_string()).or_insert(d);
            }
        }
        let id = self.next_job;
        self.next_job += 1;
        self.stats.accepted += 1;
        self.stats.batched_images += conv.n as u64;
        let service = cands[d].service;
        let job =
            self.devices[d].place(id, conv, model.map(str::to_string), self.now, service, bytes);
        Some(Placement { job: id, device: d, start: job.start, finish: job.finish })
    }

    /// Pop the globally earliest finishing job (lowest device id on
    /// ties) and advance the clock to its finish time.
    pub fn next_completion(&mut self) -> Option<Completion> {
        let d = self
            .devices
            .iter()
            .filter_map(|d| d.head_finish().map(|f| (d.id, f)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)))?
            .0;
        let c = self.devices[d].complete_head().expect("head exists");
        self.now = self.now.max(c.finish);
        self.stats.completed += 1;
        Some(c)
    }

    /// Pop every job that finishes at or before `t` (event order) and
    /// advance the clock to `t`.  Arrival-driven callers pump this
    /// before each submission so queues drain as virtual time passes —
    /// otherwise a bounded fleet looks permanently full the moment its
    /// slots fill once.
    pub fn complete_until(&mut self, t: f64) -> Vec<Completion> {
        let mut out = Vec::new();
        loop {
            let next_finish = self
                .devices
                .iter()
                .filter_map(|d| d.head_finish())
                .fold(f64::INFINITY, f64::min);
            if next_finish > t {
                break;
            }
            out.push(self.next_completion().expect("head exists"));
        }
        self.advance_to(t);
        out
    }

    /// Run the fleet dry, returning completions in event order.
    pub fn drain(&mut self) -> Vec<Completion> {
        let mut out = Vec::with_capacity(self.in_flight());
        while let Some(c) = self.next_completion() {
            out.push(c);
        }
        out
    }
}

/// Predicted seconds for `conv` on `spec`, through the memo table:
/// each spec dispatches for itself, so a Pascal and a Maxwell shard can
/// run different algorithms for the same job.
fn service_for(
    cache: &mut HashMap<(ConvOp, usize, &'static str), f64>,
    spec: &GpuSpec,
    conv: &BatchedConvOp,
) -> f64 {
    *cache
        .entry((conv.op, conv.n, spec.name))
        .or_insert_with(|| backend::batched_op_dispatch_seconds(conv, spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvProblem;
    use crate::gpusim::{gtx_1080ti, titan_x_maxwell};

    fn conv(n: usize) -> BatchedConvOp {
        BatchedConvOp::new(crate::conv::ConvOp::dense(ConvProblem::multi(8, 14, 16, 3)), n)
    }

    fn fleet(n: usize, policy: Policy, bound: usize) -> Fleet {
        Fleet::homogeneous(
            n,
            &gtx_1080ti(),
            FleetConfig { policy, queue_bound: bound, capacity_bytes: None },
        )
    }

    #[test]
    fn burst_balances_across_homogeneous_least_loaded() {
        let mut f = fleet(4, Policy::LeastLoaded, 8);
        for _ in 0..8 {
            assert!(f.submit(conv(1), None).is_some());
        }
        for d in f.devices() {
            assert_eq!(d.queue_len(), 2, "identical jobs spread evenly");
        }
        let done = f.drain();
        assert_eq!(done.len(), 8);
        assert_eq!(f.stats.completed, 8);
        assert_eq!(f.in_flight(), 0);
    }

    #[test]
    fn queue_bound_rejects_when_saturated() {
        let mut f = fleet(2, Policy::LeastLoaded, 2);
        for i in 0..4 {
            assert!(f.submit(conv(1), None).is_some(), "job {i} fits");
        }
        assert!(f.submit(conv(1), None).is_none(), "fleet saturated");
        assert_eq!(f.stats.rejected, 1);
        assert_eq!(f.stats.accepted, 4);
        // draining one slot readmits
        f.next_completion().unwrap();
        assert!(f.submit(conv(1), None).is_some());
    }

    #[test]
    fn completions_pop_in_finish_order_and_advance_time() {
        let mut f = fleet(2, Policy::RoundRobin, 8);
        for _ in 0..6 {
            f.submit(conv(1), None).unwrap();
        }
        let mut last = 0.0;
        let done = f.drain();
        assert_eq!(done.len(), 6);
        for c in &done {
            assert!(c.finish >= last, "event order");
            last = c.finish;
        }
        assert!((f.now() - last).abs() < 1e-15, "clock at last finish");
    }

    #[test]
    fn round_robin_cycles_devices() {
        let mut f = fleet(3, Policy::RoundRobin, 8);
        let devs: Vec<usize> =
            (0..6).map(|_| f.submit(conv(1), None).unwrap().device).collect();
        assert_eq!(devs, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_faster_device_on_hetero_fleet() {
        // 1080Ti + Titan X: the Pascal card serves the same job faster,
        // so an empty fleet's first placement lands there
        let mut f = Fleet::new(
            vec![titan_x_maxwell(), gtx_1080ti()],
            FleetConfig { policy: Policy::LeastLoaded, queue_bound: 8, capacity_bytes: None },
        );
        let c = conv(4);
        let t_maxwell = f.predicted_service(&c, 0);
        let t_pascal = f.predicted_service(&c, 1);
        assert!(t_pascal < t_maxwell, "pascal {t_pascal} vs maxwell {t_maxwell}");
        assert_eq!(f.submit(c, None).unwrap().device, 1);
    }

    #[test]
    fn affinity_sticks_and_spills() {
        let mut f = fleet(3, Policy::ModelAffinity, 2);
        let d0 = f.submit(conv(1), Some("vgg16")).unwrap().device;
        assert_eq!(f.affinity_shard("vgg16"), Some(d0));
        assert_eq!(f.submit(conv(1), Some("vgg16")).unwrap().device, d0, "sticky");
        // shard full -> spill elsewhere, pin unchanged
        let spilled = f.submit(conv(1), Some("vgg16")).unwrap().device;
        assert_ne!(spilled, d0);
        assert_eq!(f.stats.affinity_spills, 1);
        assert_eq!(f.affinity_shard("vgg16"), Some(d0));
        // a different model lands on a different shard (d0 is busiest)
        let other = f.submit(conv(1), Some("resnet18")).unwrap().device;
        assert_ne!(other, d0);
    }

    #[test]
    fn rejected_first_submission_does_not_pin() {
        // a model first seen while the fleet is saturated must not be
        // pinned to an arbitrary shard; the pin forms on first ACCEPTED
        // placement
        let mut f = fleet(2, Policy::ModelAffinity, 1);
        f.submit(conv(1), Some("alexnet")).unwrap();
        f.submit(conv(1), Some("alexnet")).unwrap(); // spills to device 1
        assert!(f.submit(conv(1), Some("vgg16")).is_none(), "fleet saturated");
        assert_eq!(f.affinity_shard("vgg16"), None, "rejection pinned a shard");
        // capacity frees on device 0 first; vgg16 pins where it lands
        f.next_completion().unwrap();
        let d = f.submit(conv(1), Some("vgg16")).unwrap().device;
        assert_eq!(f.affinity_shard("vgg16"), Some(d));
    }

    #[test]
    fn batch_occupies_one_slot_and_amortizes() {
        let mut f = fleet(1, Policy::LeastLoaded, 1);
        let single = f.predicted_service(&conv(1), 0);
        let batched = f.predicted_service(&conv(8), 0);
        assert!(batched < 8.0 * single, "batching must amortize");
        assert!(batched > single);
        // the 8-image batch takes the single queue slot
        assert!(f.submit(conv(8), None).is_some());
        assert!(f.submit(conv(1), None).is_none(), "slot taken");
        assert_eq!(f.stats.batched_images, 8);
    }

    #[test]
    fn complete_until_frees_bounded_slots_as_time_passes() {
        let mut f = fleet(1, Policy::LeastLoaded, 2);
        let s = f.predicted_service(&conv(1), 0);
        assert!(f.submit(conv(1), None).is_some());
        assert!(f.submit(conv(1), None).is_some());
        assert!(f.submit(conv(1), None).is_none(), "bound hit");
        // nothing finishes before s
        assert!(f.complete_until(0.5 * s).is_empty());
        assert_eq!(f.now(), 0.5 * s);
        // by 2.5 s both queued jobs have drained; slots reopen
        let done = f.complete_until(2.5 * s);
        assert_eq!(done.len(), 2);
        assert_eq!(f.now(), 2.5 * s, "clock lands on the target time");
        assert!(f.submit(conv(1), None).is_some());
    }

    fn capped_fleet(n: usize, policy: Policy, bound: usize, cap: usize) -> Fleet {
        Fleet::homogeneous(
            n,
            &gtx_1080ti(),
            FleetConfig { policy, queue_bound: bound, capacity_bytes: Some(cap) },
        )
    }

    #[test]
    fn pool_cap_rejects_and_counts_mem_rejections() {
        let b = conv(1).footprint_bytes();
        // one device, room for exactly two resident jobs, deep queue
        let mut f = capped_fleet(1, Policy::LeastLoaded, 8, 2 * b);
        assert!(f.submit(conv(1), None).is_some());
        assert!(f.submit(conv(1), None).is_some());
        assert!(f.submit(conv(1), None).is_none(), "pool full");
        assert_eq!(f.stats.rejected, 1);
        assert_eq!(f.stats.mem_rejected, 1, "queue had slots: memory-caused");
        assert!(f.devices()[0].pool().in_use_requested_bytes() <= 2 * b);
        // completion releases the reservation and readmits
        f.next_completion().unwrap();
        assert!(f.submit(conv(1), None).is_some());
        assert_eq!(f.stats.mem_rejected, 1);
    }

    #[test]
    fn queue_rejections_are_not_mem_rejections() {
        let mut f = fleet(1, Policy::LeastLoaded, 1);
        assert!(f.submit(conv(1), None).is_some());
        assert!(f.submit(conv(1), None).is_none(), "queue bound hit");
        assert_eq!(f.stats.rejected, 1);
        assert_eq!(f.stats.mem_rejected, 0, "every queue was full: not memory");
    }

    #[test]
    fn bytes_policy_spreads_residency_under_pressure() {
        let b = conv(1).footprint_bytes();
        // two shards, each fits 3 residents; plain least-loaded packs by
        // completion, bytes-aware placement keeps occupancy balanced
        let mut f = capped_fleet(2, Policy::LeastLoadedBytes, 8, 3 * b);
        for _ in 0..6 {
            assert!(f.submit(conv(1), None).is_some());
        }
        let occ: Vec<usize> =
            f.devices().iter().map(|d| d.pool().in_use_requested_bytes()).collect();
        assert_eq!(occ, vec![3 * b, 3 * b], "residency balanced");
        assert!(f.submit(conv(1), None).is_none(), "both pools full");
        assert_eq!(f.stats.mem_rejected, 1);
        let done = f.drain();
        assert_eq!(done.len(), 6);
        for d in f.devices() {
            assert_eq!(d.pool().in_use_requested_bytes(), 0, "drained pools empty");
        }
    }

    #[test]
    fn uncapped_fleet_behaves_exactly_as_before() {
        // default capacity (the card's DRAM) never blocks conv traffic:
        // stats and placements match the queue-only regime
        let mut f = fleet(2, Policy::LeastLoaded, 2);
        for i in 0..4 {
            assert!(f.submit(conv(1), None).is_some(), "job {i}");
        }
        assert!(f.submit(conv(1), None).is_none());
        assert_eq!(f.stats.mem_rejected, 0);
        for d in f.devices() {
            assert_eq!(d.pool().capacity(), d.spec.dram_bytes as usize);
        }
    }

    #[test]
    fn virtual_clock_monotone_under_advance() {
        let mut f = fleet(1, Policy::LeastLoaded, 4);
        f.advance_to(5.0);
        assert_eq!(f.now(), 5.0);
        f.advance_to(2.0);
        assert_eq!(f.now(), 5.0, "time never rewinds");
        let p = f.submit(conv(1), None).unwrap();
        assert_eq!(p.start, 5.0, "idle device starts at arrival");
    }
}
