//! One simulated GPU shard: a `GpuSpec`, a bounded FIFO work queue, and
//! the virtual-time bookkeeping (when the tail of the queue drains).
//!
//! Timing is deterministic: a job's start/finish are fixed at placement
//! (FIFO, no preemption), so the whole fleet is an event-driven
//! simulation the stateful proptests can mirror exactly.

use std::collections::VecDeque;

use crate::conv::BatchedConvOp;
use crate::gpusim::GpuSpec;

use super::pool::DevicePool;

/// One queued (or running) batched-conv job.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub conv: BatchedConvOp,
    /// model-affinity tag the submitter attached (None = untagged)
    pub model: Option<String>,
    /// virtual time the job entered the fleet, seconds
    pub arrival: f64,
    /// predicted execution seconds on the device it was placed on
    pub service: f64,
    /// virtual time execution starts (the queue ahead has drained)
    pub start: f64,
    /// `start + service`
    pub finish: f64,
    /// planned device footprint reserved in the shard's pool while the
    /// job is resident (`BatchedConvOp::footprint_bytes`)
    pub bytes: usize,
    /// the pool allocation backing that reservation
    pub alloc: u64,
}

/// A completed job, as reported by `Fleet::next_completion`.
#[derive(Clone, Debug)]
pub struct Completion {
    pub job: u64,
    pub device: usize,
    pub conv: BatchedConvOp,
    /// the affinity tag the job was submitted with — lets consumers
    /// attribute completions (and shard hotspots) per model
    pub model: Option<String>,
    pub arrival: f64,
    pub start: f64,
    pub finish: f64,
}

impl Completion {
    /// Queueing + service latency in virtual seconds.
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// One simulated device of the fleet.
#[derive(Debug)]
pub struct Device {
    pub id: usize,
    pub spec: GpuSpec,
    queue: VecDeque<Job>,
    /// virtual time the last queued job finishes (monotone)
    tail_finish: f64,
    /// jobs completed on this device
    pub completed: u64,
    /// service seconds of completed jobs (utilization numerator)
    pub busy_secs: f64,
    /// the shard's memory pool: every resident job holds a reservation
    /// from placement until completion, under the pool's hard cap
    pool: DevicePool,
}

impl Device {
    /// `capacity` overrides the pool cap; None caps at the card's DRAM
    /// (`spec.dram_bytes` — effectively unbounded for conv jobs, so
    /// capacity-unaware callers keep their exact pre-pool behavior).
    pub fn new(id: usize, spec: GpuSpec, capacity: Option<usize>) -> Device {
        let cap = capacity.unwrap_or(spec.dram_bytes as usize);
        Device {
            id,
            spec,
            queue: VecDeque::new(),
            tail_finish: 0.0,
            completed: 0,
            busy_secs: 0.0,
            pool: DevicePool::new(cap),
        }
    }

    /// The shard's memory pool (read-only — placement/completion own
    /// the mutations).
    pub fn pool(&self) -> &DevicePool {
        &self.pool
    }

    /// Jobs resident (running + waiting).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Virtual time this device could start a new job submitted at `now`.
    pub fn ready_at(&self, now: f64) -> f64 {
        self.tail_finish.max(now)
    }

    /// Seconds of queued work still ahead of a job arriving at `now`.
    pub fn backlog_secs(&self, now: f64) -> f64 {
        (self.tail_finish - now).max(0.0)
    }

    /// Finish time of the job at the head of the queue, if any —
    /// the device's next completion event.
    pub fn head_finish(&self) -> Option<f64> {
        self.queue.front().map(|j| j.finish)
    }

    /// Append a job: start when the tail drains (or immediately), fixed
    /// FIFO timing, and reserve its planned footprint in the pool for
    /// its whole residency.  The caller enforces the queue bound AND
    /// checks `pool().can_fit(bytes)` first — placement on a shard
    /// whose pool cannot fit the job panics rather than deadlocks.
    pub(crate) fn place(&mut self, id: u64, conv: BatchedConvOp, model: Option<String>,
        now: f64, service: f64, bytes: usize) -> &Job {
        let alloc = self
            .pool
            .alloc(bytes)
            .unwrap_or_else(|e| panic!("device {}: admission let through {e}", self.id));
        let start = self.ready_at(now);
        let finish = start + service;
        self.tail_finish = finish;
        self.queue.push_back(Job { id, conv, model, arrival: now, service, start, finish, bytes, alloc });
        self.queue.back().expect("just pushed")
    }

    /// Pop the head job as a completion event, releasing its pool
    /// reservation.
    pub(crate) fn complete_head(&mut self) -> Option<Completion> {
        let j = self.queue.pop_front()?;
        self.completed += 1;
        self.busy_secs += j.service;
        self.pool.free(j.alloc).expect("resident job holds a live reservation");
        Some(Completion {
            job: j.id,
            device: self.id,
            conv: j.conv,
            model: j.model,
            arrival: j.arrival,
            start: j.start,
            finish: j.finish,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvProblem;
    use crate::gpusim::gtx_1080ti;

    fn job() -> BatchedConvOp {
        BatchedConvOp::new(crate::conv::ConvOp::dense(ConvProblem::multi(8, 14, 16, 3)), 2)
    }

    #[test]
    fn fifo_timing_is_cumulative() {
        let mut d = Device::new(0, gtx_1080ti(), None);
        assert_eq!(d.queue_len(), 0);
        assert_eq!(d.backlog_secs(5.0), 0.0);
        let (s1, f1) = {
            let j = d.place(1, job(), None, 10.0, 2.0, job().footprint_bytes());
            (j.start, j.finish)
        };
        assert_eq!((s1, f1), (10.0, 12.0));
        let f2 = d.place(2, job(), None, 10.5, 3.0, job().footprint_bytes()).finish;
        assert_eq!(f2, 15.0); // queued behind job 1
        assert_eq!(d.queue_len(), 2);
        assert!((d.backlog_secs(10.5) - 4.5).abs() < 1e-12);
        assert_eq!(d.head_finish(), Some(12.0));
    }

    #[test]
    fn idle_device_starts_at_submission_time() {
        let mut d = Device::new(3, gtx_1080ti(), None);
        d.place(1, job(), None, 0.0, 1.0, 1024);
        d.complete_head().unwrap();
        // queue drained at t=1; a job arriving at t=7 starts at 7
        let j = d.place(2, job(), None, 7.0, 1.0, 1024);
        assert_eq!(j.start, 7.0);
        assert_eq!(j.finish, 8.0);
    }

    #[test]
    fn completion_carries_job_identity_and_latency() {
        let mut d = Device::new(1, gtx_1080ti(), None);
        d.place(9, job(), Some("vgg16".into()), 2.0, 4.0, 1024);
        let c = d.complete_head().unwrap();
        assert_eq!((c.job, c.device), (9, 1));
        assert_eq!(c.model.as_deref(), Some("vgg16"));
        assert_eq!(c.arrival, 2.0);
        assert!((c.latency() - 4.0).abs() < 1e-12);
        assert_eq!(d.completed, 1);
        assert!((d.busy_secs - 4.0).abs() < 1e-12);
        assert!(d.complete_head().is_none());
    }

    #[test]
    fn residency_holds_and_releases_the_pool_reservation() {
        let b = job().footprint_bytes();
        let mut d = Device::new(0, gtx_1080ti(), Some(2 * b));
        d.place(1, job(), None, 0.0, 1.0, b);
        d.place(2, job(), None, 0.0, 1.0, b);
        assert_eq!(d.pool().in_use_requested_bytes(), 2 * b);
        assert!(!d.pool().can_fit(b), "cap reached with two residents");
        d.complete_head().unwrap();
        assert_eq!(d.pool().in_use_requested_bytes(), b);
        assert!(d.pool().can_fit(b), "completion frees the reservation");
    }
}
