//! L4 fleet — multi-GPU serving: N simulated devices (heterogeneous
//! `GpuSpec`s allowed), bounded per-device work queues, a batch-aware
//! admission path, and pluggable placement (`policy`): round-robin,
//! least-loaded-by-predicted-completion (costed through each shard's
//! own backend dispatcher per device spec — a Pascal and a Maxwell
//! shard can pick different algorithms for the same job), and
//! model-affinity (a graph's pre-dispatched decisions stay warm on
//! their shard).
//!
//! The fleet runs in *virtual time*: job service times come from the
//! dispatched batched cost model
//! (`backend::batched_op_dispatch_seconds`), placements fix
//! start/finish deterministically (FIFO, no preemption), and
//! `next_completion`/`drain` advance an event-driven clock.  That keeps
//! the `e2e_fleet` scaling bench and the stateful proptests
//! (`rust/tests/fleet_proptests.rs`) exact and flake-free — no wall
//! clock anywhere.
//!
//! Layer map: `device` (shard + job timing), `policy` (placement
//! arithmetic), `scheduler` (admission, clock, completions, stats).

pub mod device;
pub mod policy;
pub mod scheduler;
pub mod traffic;

pub use device::{Completion, Device, Job};
pub use policy::{least_loaded_pick, round_robin_pick, PlacementCandidate, Policy};
pub use scheduler::{Fleet, FleetConfig, FleetStats, Placement};
pub use traffic::{mean_service_secs, model_layers, offered_load, Arrival};
