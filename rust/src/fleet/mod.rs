//! L4 fleet — multi-GPU serving: N simulated devices (heterogeneous
//! `GpuSpec`s allowed), bounded per-device work queues, a batch-aware
//! admission path, and pluggable placement (`policy`): round-robin,
//! least-loaded-by-predicted-completion (costed through each shard's
//! own backend dispatcher per device spec — a Pascal and a Maxwell
//! shard can pick different algorithms for the same job), and
//! model-affinity (a graph's pre-dispatched decisions stay warm on
//! their shard).
//!
//! The fleet runs in *virtual time*: job service times come from the
//! dispatched batched cost model
//! (`backend::batched_op_dispatch_seconds`), placements fix
//! start/finish deterministically (FIFO, no preemption), and
//! `next_completion`/`drain` advance an event-driven clock.  That keeps
//! the `e2e_fleet` scaling bench and the stateful proptests
//! (`rust/tests/fleet_proptests.rs`) exact and flake-free — no wall
//! clock anywhere.
//!
//! Shards are *multi-tenant*: every device owns a size-classed
//! exclusive memory `pool` under a hard byte cap, a job's planned
//! footprint (`BatchedConvOp::footprint_bytes`) is reserved at
//! placement and released at completion, and admission is
//! pool-pressure-aware — a job no shard can fit is rejected
//! immediately (never queued against memory, so never deadlocked).
//! The `LeastLoadedBytes` policy weighs predicted completion by the
//! occupancy a placement would create (cycles AND bytes).
//!
//! Layer map: `pool` (per-device memory pool), `device` (shard + job
//! timing + pool residency), `policy` (placement arithmetic),
//! `scheduler` (admission, clock, completions, stats).

pub mod device;
pub mod policy;
pub mod pool;
pub mod scheduler;
pub mod traffic;

pub use device::{Completion, Device, Job};
pub use policy::{
    least_loaded_bytes_pick, least_loaded_pick, round_robin_pick, PlacementCandidate, Policy,
};
pub use pool::{size_class, DevicePool, PoolError, PoolStats};
pub use scheduler::{Fleet, FleetConfig, FleetStats, Placement};
pub use traffic::{mean_service_secs, model_layers, offered_load, Arrival};
