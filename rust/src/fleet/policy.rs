//! Placement policies: which shard gets the next job.
//!
//! `RoundRobin` ignores cost; `LeastLoaded` minimizes the job's
//! *predicted completion time* across devices using the `plans`/`gpusim`
//! cost model (which is what "least loaded" must mean on a heterogeneous
//! fleet — a faster device with a deeper queue can still win);
//! `LeastLoadedBytes` weighs that completion by memory-pool pressure
//! (least-loaded-by-cycles-AND-bytes: among shards the job fits on,
//! minimize `completion x (1 + occupancy-after-placement)` — a shard
//! finishing marginally earlier but nearly full loses to a cooler one);
//! `ModelAffinity` pins a model's traffic to one shard so its pre-tuned
//! plans stay warm, spilling to least-loaded only when the shard's
//! queue is full.
//!
//! Every policy treats the pool cap as HARD: a shard whose pool cannot
//! fit the job's planned footprint (`fits == false`) is never picked,
//! whatever its queue looks like — admission rejects rather than
//! deadlocks when no shard fits.
//!
//! The pure selection arithmetic lives here (unit-testable without a
//! fleet); `scheduler.rs` owns the state (round-robin cursor, sticky
//! affinity map) and the per-device pools.

/// Pluggable placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// cycle device ids, skipping full queues
    RoundRobin,
    /// minimize predicted completion (backlog + this job's cost there)
    LeastLoaded,
    /// minimize predicted completion weighted by pool pressure
    LeastLoadedBytes,
    /// sticky model -> shard mapping, least-loaded for untagged traffic
    ModelAffinity,
}

impl Policy {
    /// CLI spelling(s) -> policy.
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "rr" | "round-robin" => Some(Policy::RoundRobin),
            "least" | "least-loaded" => Some(Policy::LeastLoaded),
            "bytes" | "least-bytes" | "least-loaded-bytes" => Some(Policy::LeastLoadedBytes),
            "affinity" | "model-affinity" => Some(Policy::ModelAffinity),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::LeastLoadedBytes => "least-loaded-bytes",
            Policy::ModelAffinity => "model-affinity",
        }
    }
}

/// One device's admission snapshot for a specific job, at submission
/// time — everything a policy may look at.
#[derive(Clone, Copy, Debug)]
pub struct PlacementCandidate {
    pub device: usize,
    pub queue_len: usize,
    pub queue_bound: usize,
    /// virtual time the device could start this job (max(tail, now))
    pub ready_at: f64,
    /// predicted service seconds of THIS job on THIS device
    /// (`backend::batched_op_dispatch_seconds` under the device's spec)
    pub service: f64,
    /// would the job's planned footprint fit the shard's pool right now
    /// (`DevicePool::can_fit`)?  A hard constraint for every policy.
    pub fits: bool,
    /// pool occupancy if the job were admitted here
    /// (`DevicePool::occupancy_with` — may exceed 1.0 when it doesn't fit)
    pub occupancy_after: f64,
}

impl PlacementCandidate {
    pub fn full(&self) -> bool {
        self.queue_len >= self.queue_bound
    }

    /// Placeable: queue has a slot AND the pool fits the footprint.
    pub fn admissible(&self) -> bool {
        !self.full() && self.fits
    }

    /// Predicted completion if the job were placed here.
    pub fn completion(&self) -> f64 {
        self.ready_at + self.service
    }

    /// The cycles-AND-bytes score: completion inflated by the pool
    /// pressure the placement would create.  An empty pool scores the
    /// plain completion; a nearly-full one doubles it.
    pub fn weighted_completion(&self) -> f64 {
        self.completion() * (1.0 + self.occupancy_after)
    }
}

/// The least-loaded pick: the admissible device with the earliest
/// predicted completion, lowest id on ties.  None when every shard is
/// queue-full or pool-full (the admission path rejects).
pub fn least_loaded_pick(cands: &[PlacementCandidate]) -> Option<usize> {
    cands
        .iter()
        .filter(|c| c.admissible())
        .min_by(|a, b| {
            a.completion()
                .partial_cmp(&b.completion())
                .unwrap()
                .then(a.device.cmp(&b.device))
        })
        .map(|c| c.device)
}

/// The cycles-AND-bytes pick: minimize `weighted_completion` over
/// admissible shards, lowest id on ties.
pub fn least_loaded_bytes_pick(cands: &[PlacementCandidate]) -> Option<usize> {
    cands
        .iter()
        .filter(|c| c.admissible())
        .min_by(|a, b| {
            a.weighted_completion()
                .partial_cmp(&b.weighted_completion())
                .unwrap()
                .then(a.device.cmp(&b.device))
        })
        .map(|c| c.device)
}

/// The round-robin pick: first admissible device at or after `cursor`
/// (cyclic).  None when every device is queue- or pool-full.
pub fn round_robin_pick(cands: &[PlacementCandidate], cursor: usize) -> Option<usize> {
    let n = cands.len();
    (0..n).map(|i| (cursor + i) % n).find(|&i| cands[i].admissible()).map(|i| cands[i].device)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(device: usize, queue_len: usize, ready_at: f64, service: f64) -> PlacementCandidate {
        PlacementCandidate {
            device,
            queue_len,
            queue_bound: 4,
            ready_at,
            service,
            fits: true,
            occupancy_after: 0.0,
        }
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(Policy::parse("rr"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("round-robin"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("least"), Some(Policy::LeastLoaded));
        assert_eq!(Policy::parse("bytes"), Some(Policy::LeastLoadedBytes));
        assert_eq!(Policy::parse("least-loaded-bytes"), Some(Policy::LeastLoadedBytes));
        assert_eq!(Policy::parse("model-affinity"), Some(Policy::ModelAffinity));
        assert_eq!(Policy::parse("bogus"), None);
        assert_eq!(Policy::LeastLoaded.label(), "least-loaded");
        assert_eq!(Policy::LeastLoadedBytes.label(), "least-loaded-bytes");
    }

    #[test]
    fn least_loaded_minimizes_completion_not_queue_depth() {
        // device 0: short queue but slow for this job; device 1 finishes
        // earlier despite the deeper queue — the heterogeneous case
        let cands = [cand(0, 1, 0.0, 10.0), cand(1, 3, 2.0, 3.0)];
        assert_eq!(least_loaded_pick(&cands), Some(1));
    }

    #[test]
    fn least_loaded_skips_full_and_breaks_ties_low_id() {
        let mut cands = vec![cand(0, 4, 0.0, 1.0), cand(1, 0, 5.0, 1.0), cand(2, 0, 5.0, 1.0)];
        assert_eq!(least_loaded_pick(&cands), Some(1), "tie -> lowest id");
        cands[1].queue_len = 4;
        cands[2].queue_len = 4;
        assert_eq!(least_loaded_pick(&cands), None, "all full -> reject");
    }

    #[test]
    fn pool_cap_is_hard_for_every_policy() {
        let mut cands = vec![cand(0, 0, 0.0, 1.0), cand(1, 0, 5.0, 1.0)];
        cands[0].fits = false;
        assert_eq!(least_loaded_pick(&cands), Some(1), "earlier shard has no memory");
        assert_eq!(least_loaded_bytes_pick(&cands), Some(1));
        assert_eq!(round_robin_pick(&cands, 0), Some(1));
        cands[1].fits = false;
        assert_eq!(least_loaded_pick(&cands), None, "nowhere fits -> reject");
        assert_eq!(least_loaded_bytes_pick(&cands), None);
        assert_eq!(round_robin_pick(&cands, 0), None);
    }

    #[test]
    fn bytes_pick_trades_completion_for_headroom() {
        // shard 0 finishes a touch earlier but its pool would be 90%
        // full; shard 1 is a bit slower with a cold pool — bytes-aware
        // placement prefers the headroom, plain least-loaded does not
        let mut cands = vec![cand(0, 0, 0.0, 1.0), cand(1, 0, 0.0, 1.2)];
        cands[0].occupancy_after = 0.9;
        cands[1].occupancy_after = 0.1;
        assert_eq!(least_loaded_pick(&cands), Some(0));
        assert_eq!(least_loaded_bytes_pick(&cands), Some(1));
        // equal pressure: falls back to completion order, low id ties
        cands[0].occupancy_after = 0.1;
        assert_eq!(least_loaded_bytes_pick(&cands), Some(0));
    }

    #[test]
    fn round_robin_cycles_and_skips_full() {
        let cands = [cand(0, 0, 0.0, 1.0), cand(1, 4, 0.0, 1.0), cand(2, 0, 0.0, 1.0)];
        assert_eq!(round_robin_pick(&cands, 0), Some(0));
        assert_eq!(round_robin_pick(&cands, 1), Some(2), "skips the full device 1");
        assert_eq!(round_robin_pick(&cands, 2), Some(2));
        assert_eq!(round_robin_pick(&cands, 3), Some(0), "wraps");
        let full = [cand(0, 4, 0.0, 1.0)];
        assert_eq!(round_robin_pick(&full, 0), None);
    }
}
