//! Routing: map a request to the artifact that serves it, and attach the
//! plan advice — the backend dispatcher's memoized pick when the table
//! was warmed (`warm_plans`, run once at coordinator startup so serving
//! pays zero per-request search), or the paper's §3 closed-form note.
//! Registered model graphs route the same way: `warm_plans`
//! pre-dispatches every conv layer of every registered model (which
//! tunes the paper floor as a side effect), so `Payload::Model`
//! requests execute entirely from the decision cache, and the chosen
//! backend returns on the wire in `Response.plan`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::analytic;
use crate::conv::{BatchedConvOp, ConvOp, ConvProblem};
use crate::gpusim::GpuSpec;
use crate::graph;
use crate::runtime::{Artifact, ArtifactKind};

/// Static routing table built from the manifest at startup.
#[derive(Debug, Default)]
pub struct Router {
    conv_by_problem: HashMap<ConvProblem, String>,
    cnn_by_batch: Vec<(usize, String)>, // sorted by batch ascending
    /// registered models, built once at registration: (canonical name,
    /// shared graph), in registration order — routing a model is an
    /// Arc bump, never a rebuild or deep clone
    models: Vec<(String, Arc<graph::Graph>)>,
    /// dispatch advice per routed op, filled by `warm_plans`
    tuned_advice: HashMap<ConvOp, String>,
}

/// The synthetic route name for ops no PJRT artifact can serve (strided
/// / padded / grouped): the executor runs the exact CPU lowering
/// (`conv::conv2d_op_cpu`) instead of a compiled artifact.
pub const CPU_LOWERED: &str = "cpu-lowered";

impl Router {
    pub fn from_artifacts(artifacts: &[Artifact]) -> Router {
        let mut r = Router::default();
        for a in artifacts {
            match a.kind {
                ArtifactKind::ConvSingle | ArtifactKind::ConvMulti => {
                    if let Ok(p) = a.problem() {
                        r.conv_by_problem.insert(p, a.name.clone());
                    }
                }
                // baseline-numerics artifacts are reachable by name, not routed
                ArtifactKind::ConvIm2col
                | ArtifactKind::ConvWinograd
                | ArtifactKind::ConvFft => {}
                ArtifactKind::Cnn => {
                    if let Ok(b) = a.batch() {
                        r.cnn_by_batch.push((b, a.name.clone()));
                    }
                }
            }
        }
        r.cnn_by_batch.sort();
        r
    }

    /// The artifact serving a dense conv problem (exact shape match).
    pub fn route_conv(&self, p: &ConvProblem) -> Result<&str> {
        self.conv_by_problem
            .get(p)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("no artifact for problem {}", p.label()))
    }

    /// The route serving a conv op: dense ops need an artifact matching
    /// their core problem; strided/padded/grouped ops serve through the
    /// exact CPU lowering (`CPU_LOWERED`).
    pub fn route_op(&self, op: &ConvOp) -> Result<&str> {
        if !op.valid() {
            return Err(anyhow!("invalid conv op {}", op.label()));
        }
        if op.is_dense() {
            return self.route_conv(&op.core);
        }
        Ok(CPU_LOWERED)
    }

    /// The route serving an explicit batched op (served image-by-image
    /// against the warm executable or the CPU lowering) after
    /// validating the batch itself.
    pub fn route_batched(&self, b: &BatchedConvOp) -> Result<&str> {
        if !b.valid() {
            return Err(anyhow!("invalid batch: {} images of {}", b.n, b.op.label()));
        }
        self.route_op(&b.op)
    }

    /// Smallest CNN artifact batch >= n (or the largest available).
    pub fn route_cnn(&self, n: usize) -> Result<(usize, &str)> {
        if self.cnn_by_batch.is_empty() {
            return Err(anyhow!("no CNN artifacts in manifest"));
        }
        for (b, name) in &self.cnn_by_batch {
            if *b >= n {
                return Ok((*b, name));
            }
        }
        let (b, name) = self.cnn_by_batch.last().unwrap();
        Ok((*b, name))
    }

    /// Largest CNN batch available (the batcher's target).
    pub fn max_cnn_batch(&self) -> usize {
        self.cnn_by_batch.last().map(|(b, _)| *b).unwrap_or(1)
    }

    pub fn conv_problems(&self) -> Vec<ConvProblem> {
        let mut v: Vec<ConvProblem> = self.conv_by_problem.keys().cloned().collect();
        v.sort_by_key(|p| (p.c, p.wy, p.wx, p.m, p.k));
        v
    }

    /// Register a model for `Payload::Model` traffic.  The graph is
    /// built, validated, and stored once here (keyed by its canonical
    /// `Graph::name`); `warm_plans` then pre-tunes every conv layer and
    /// `route_model` is a pure lookup.  Duplicate registration is a
    /// no-op.
    pub fn register_model(&mut self, name: &str) -> Result<()> {
        let g = graph::model_graph(name)?;
        if !self.models.iter().any(|(m, _)| *m == g.name) {
            self.models.push((g.name.clone(), Arc::new(g)));
        }
        Ok(())
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.models.iter().map(|(m, _)| m.as_str()).collect()
    }

    /// The pre-built graph serving a registered model name.
    pub fn route_model(&self, name: &str) -> Result<Arc<graph::Graph>> {
        self.models.iter().find(|(m, _)| m == name).map(|(_, g)| g.clone()).ok_or_else(|| {
            anyhow!(
                "model '{name}' not registered (registered: {})",
                if self.models.is_empty() {
                    "none".to_string()
                } else {
                    self.models().join(", ")
                }
            )
        })
    }

    /// Every distinct conv op this router can be asked to plan: the
    /// routed artifacts (dense ops) plus every layer of every
    /// registered model (strided / padded / grouped ops included).
    pub fn plannable_ops(&self) -> Vec<ConvOp> {
        let mut v: Vec<ConvOp> = self.conv_problems().into_iter().map(ConvOp::dense).collect();
        for (_, g) in &self.models {
            for op in g.conv_ops() {
                if !v.contains(&op) {
                    v.push(op);
                }
            }
        }
        v
    }

    /// Pre-dispatch every plannable conv op up front — each op is
    /// ranked across all covering backends (which tunes the
    /// paper-kernel floor as a side effect, filling both process-wide
    /// caches) — and keep the advice strings; returns how many ops were
    /// warmed.  After this, serving never searches: a conv request's
    /// advice and every layer of a model execution are cache lookups,
    /// and the advice names the backend the dispatcher chose.
    pub fn warm_plans(&mut self, spec: &GpuSpec) -> usize {
        let ops = self.plannable_ops();
        for op in &ops {
            let advice = crate::backend::op_dispatch_advice(op, spec);
            self.tuned_advice.insert(*op, advice);
        }
        // serving fuses each model before executing it: run the same
        // rewrite here so every fused (conv, epilogue) pair's dispatch
        // decision — and the op-native retuned plans behind it — are
        // already cached when the first model request arrives
        for (_, g) in &self.models {
            let (fused, _) = graph::fuse(g, spec, crate::backend::dispatch_fused_op_plan);
            for n in fused.nodes() {
                if let graph::Op::Conv { conv, epilogue } = &n.op {
                    let _ = crate::backend::fused_op_dispatched(conv, *epilogue, spec);
                }
            }
        }
        ops.len()
    }

    /// Dispatch advice for a routed op (None before `warm_plans`).
    pub fn tuned_advice(&self, op: &ConvOp) -> Option<&str> {
        self.tuned_advice.get(op).map(|s| s.as_str())
    }
}

/// The §3 dispatch note attached to responses/logs: which of the paper's
/// kernels would run this problem on the real GPU, with its parameters.
pub fn plan_advice(p: &ConvProblem, spec: &GpuSpec) -> String {
    if p.is_single_channel() {
        let c = analytic::choose_single(p, spec);
        format!(
            "single-channel {:?} P={} Q={} ({})",
            c.method,
            c.p,
            c.q,
            if c.uses_prefetch { "prefetch" } else { "V_s volume" }
        )
    } else {
        let c = analytic::choose_stride_fixed(p, spec, 32);
        format!("stride-fixed S={} M'={} W'x={}", c.s_bytes, c.m_prime, c.wx_prime)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gtx_1080ti;
    use crate::runtime::manifest::parse_line;
    use std::path::Path;

    fn router() -> Router {
        let dir = Path::new("/tmp");
        let lines = [
            "name=s1 file=a.hlo.txt kind=conv_single wy=32 wx=32 m=16 k=3",
            "name=m1 file=b.hlo.txt kind=conv_multi c=8 wy=14 wx=14 m=16 k=3",
            "name=i1 file=c.hlo.txt kind=conv_im2col c=8 wy=14 wx=14 m=16 k=3",
            "name=p1 file=d.hlo.txt kind=cnn batch=1",
            "name=p8 file=e.hlo.txt kind=cnn batch=8",
        ];
        Router::from_artifacts(
            &lines.iter().map(|l| parse_line(dir, l).unwrap()).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn conv_routing_exact_match() {
        let r = router();
        assert_eq!(r.route_conv(&ConvProblem::single(32, 16, 3)).unwrap(), "s1");
        assert_eq!(r.route_conv(&ConvProblem::multi(8, 14, 16, 3)).unwrap(), "m1");
        assert!(r.route_conv(&ConvProblem::single(64, 16, 3)).is_err());
    }

    #[test]
    fn im2col_not_routed() {
        // baselines are reachable by explicit name only
        let r = router();
        // the multi artifact wins the shared shape
        assert_eq!(r.route_conv(&ConvProblem::multi(8, 14, 16, 3)).unwrap(), "m1");
    }

    #[test]
    fn batched_conv_routes_to_problem_artifact() {
        let r = router();
        let dense = ConvOp::dense(ConvProblem::multi(8, 14, 16, 3));
        let ok = BatchedConvOp::new(dense, 4);
        assert_eq!(r.route_batched(&ok).unwrap(), "m1");
        let zero = BatchedConvOp::new(dense, 0);
        assert!(r.route_batched(&zero).unwrap_err().to_string().contains("invalid batch"));
        let unknown = BatchedConvOp::new(ConvOp::dense(ConvProblem::single(64, 16, 3)), 2);
        assert!(r.route_batched(&unknown).is_err());
    }

    #[test]
    fn non_dense_ops_route_to_the_cpu_lowering() {
        let r = router();
        let s2 = ConvOp::strided(ConvProblem::multi(8, 14, 16, 3), 2, 1);
        assert_eq!(r.route_op(&s2).unwrap(), CPU_LOWERED);
        let dw = ConvOp::depthwise(8, 14, 3, 1);
        assert_eq!(r.route_batched(&BatchedConvOp::new(dw, 2)).unwrap(), CPU_LOWERED);
        // dense ops still demand an artifact
        assert!(r.route_op(&ConvOp::dense(ConvProblem::single(64, 16, 3))).is_err());
        // invalid ops fail loudly
        let bad = ConvOp { core: ConvProblem::multi(8, 14, 15, 3), stride: 1, pad: 0, groups: 2 };
        assert!(r.route_op(&bad).is_err());
    }

    #[test]
    fn cnn_routing_picks_smallest_covering_batch() {
        let r = router();
        assert_eq!(r.route_cnn(1).unwrap(), (1, "p1"));
        assert_eq!(r.route_cnn(2).unwrap(), (8, "p8"));
        assert_eq!(r.route_cnn(8).unwrap(), (8, "p8"));
        assert_eq!(r.route_cnn(20).unwrap(), (8, "p8")); // clamp to largest
        assert_eq!(r.max_cnn_batch(), 8);
    }

    #[test]
    fn plan_advice_mentions_the_right_kernel() {
        let g = gtx_1080ti();
        assert!(plan_advice(&ConvProblem::single(224, 64, 3), &g).contains("single-channel"));
        assert!(plan_advice(&ConvProblem::multi(64, 56, 64, 3), &g).contains("stride-fixed"));
    }

    #[test]
    fn model_registry_validates_and_routes() {
        let mut r = router();
        assert!(r.models().is_empty());
        assert!(r.route_model("resnet18").is_err(), "unregistered must not route");
        r.register_model("resnet18").unwrap();
        r.register_model("resnet18").unwrap(); // idempotent
        assert_eq!(r.models(), vec!["resnet18"]);
        let g = r.route_model("resnet18").unwrap();
        assert_eq!(g.name, "resnet18");
        assert!(r.register_model("papernet-9000").is_err(), "unknown model accepted");
    }

    #[test]
    fn warm_plans_covers_registered_model_layers() {
        let g = gtx_1080ti();
        let mut r = router();
        r.register_model("inception3a").unwrap();
        let n = r.warm_plans(&g);
        // 2 routed conv artifacts + 6 distinct inception ops
        assert_eq!(n, 2 + 6);
        for op in crate::conv::suites::googlenet_inception3a() {
            let advice = r.tuned_advice(&op).expect("model layer warmed");
            assert!(advice.contains("tuned"), "{advice}");
        }
    }

    #[test]
    fn warm_plans_caches_advice_for_every_routed_problem() {
        let g = gtx_1080ti();
        let mut r = router();
        let s1 = ConvOp::dense(ConvProblem::single(32, 16, 3));
        assert!(r.tuned_advice(&s1).is_none());
        let n = r.warm_plans(&g);
        assert_eq!(n, 2); // the two conv artifacts (s1, m1)
        let advice = r.tuned_advice(&s1).unwrap();
        assert!(advice.contains("tuned"), "{advice}");
        assert!(r.tuned_advice(&ConvOp::dense(ConvProblem::multi(8, 14, 16, 3))).is_some());
        // unrouted ops stay unadvised
        assert!(r.tuned_advice(&ConvOp::dense(ConvProblem::single(64, 16, 3))).is_none());
    }
}
