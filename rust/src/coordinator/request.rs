//! Request/response types of the serving API.

use std::time::Instant;

use crate::conv::{BatchedConvOp, ConvOp};
use crate::runtime::Tensor;

/// What a client asks for.
#[derive(Clone, Debug)]
pub enum Payload {
    /// one convolution op: dense ops route to the artifact matching
    /// their core problem; strided/padded/grouped ops serve through the
    /// exact CPU lowering.  The queue thread coalesces compatible
    /// (same-op) pending conv requests into a micro-batch under the
    /// `BatchConfig` latency budget
    Conv { op: ConvOp, image: Tensor, filters: Tensor },
    /// an explicit client-side batch: `batch.n` images (stacked on axis
    /// 0) through one filter set — served in one dispatch against the
    /// batch op's route
    BatchedConv { batch: BatchedConvOp, images: Tensor, filters: Tensor },
    /// one PaperNet inference: image (1, 28, 28); dynamically batched
    Cnn { image: Tensor },
    /// whole-model inference plan for a registered model: the graph
    /// executor's end-to-end latency + memory report under the tuned
    /// plans the router warmed at startup (L1 — no tensors move)
    Model { model: String },
}

impl Payload {
    pub fn kind_str(&self) -> &'static str {
        match self {
            Payload::Conv { .. } => "conv",
            Payload::BatchedConv { .. } => "batched-conv",
            Payload::Cnn { .. } => "cnn",
            Payload::Model { .. } => "model",
        }
    }
}

/// Headline numbers of a `Payload::Model` execution (the full per-node
/// breakdown stays server-side; clients wanting it use `graph::execute`
/// directly).
#[derive(Clone, Debug)]
pub struct ModelSummary {
    pub model: String,
    /// graph nodes executed
    pub nodes: usize,
    /// conv layer instances among them
    pub conv_layers: usize,
    /// simulated end-to-end model latency, seconds
    pub model_latency_secs: f64,
    /// planned peak device arena, bytes
    pub arena_peak_bytes: usize,
    /// peak bytes the execution held in the executor's shared device
    /// pool (per-tensor granularity — never worse than the arena peak)
    pub pooled_peak_bytes: usize,
    /// naive keep-everything-resident footprint, bytes
    pub naive_bytes: usize,
}

/// An in-flight request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    pub submitted: Instant,
}

/// The serve-path answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Tensor,
    /// end-to-end latency (submit -> response), seconds
    pub latency_secs: f64,
    /// artifact that served this request
    pub artifact: String,
    /// how many requests (or images, for an explicit `BatchedConv`)
    /// shared the executed batch
    pub batch_size: usize,
    /// id of the executed batch this response came from — identical
    /// across every response of one coalesced conv micro-batch or one
    /// dynamic CNN batch, and present on explicit `BatchedConv`
    /// executions; None only for work that runs outside any batch
    /// (models)
    pub batch_id: Option<u64>,
    /// human-readable planning note: for conv requests, the tuned-plan
    /// advice the router attached at routing time (when the table was
    /// warmed); for model requests, the `ModelReport::summary` line
    /// (structured numbers live in `model`); None for CNN traffic
    pub plan: Option<String>,
    /// model execution summary (`Payload::Model` requests only)
    pub model: Option<ModelSummary>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_kinds() {
        use crate::conv::ConvProblem;
        let conv = Payload::Conv {
            op: ConvOp::dense(ConvProblem::single(8, 1, 1)),
            image: Tensor::zeros(vec![8, 8]),
            filters: Tensor::zeros(vec![1, 1, 1]),
        };
        assert_eq!(conv.kind_str(), "conv");
        let batched = Payload::BatchedConv {
            batch: BatchedConvOp::new(ConvOp::dense(ConvProblem::single(8, 1, 1)), 2),
            images: Tensor::zeros(vec![2, 8, 8]),
            filters: Tensor::zeros(vec![1, 1, 1]),
        };
        assert_eq!(batched.kind_str(), "batched-conv");
        let cnn = Payload::Cnn { image: Tensor::zeros(vec![1, 28, 28]) };
        assert_eq!(cnn.kind_str(), "cnn");
        let model = Payload::Model { model: "resnet18".into() };
        assert_eq!(model.kind_str(), "model");
    }
}
