//! Request/response types of the serving API.

use std::time::Instant;

use crate::conv::ConvProblem;
use crate::runtime::Tensor;

/// What a client asks for.
#[derive(Clone, Debug)]
pub enum Payload {
    /// one convolution: routed to the conv artifact matching `problem`
    Conv { problem: ConvProblem, image: Tensor, filters: Tensor },
    /// one PaperNet inference: image (1, 28, 28); dynamically batched
    Cnn { image: Tensor },
}

impl Payload {
    pub fn kind_str(&self) -> &'static str {
        match self {
            Payload::Conv { .. } => "conv",
            Payload::Cnn { .. } => "cnn",
        }
    }
}

/// An in-flight request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    pub submitted: Instant,
}

/// The serve-path answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Tensor,
    /// end-to-end latency (submit -> response), seconds
    pub latency_secs: f64,
    /// artifact that served this request
    pub artifact: String,
    /// how many requests shared the executed batch
    pub batch_size: usize,
    /// tuned-plan advice the router attached at routing time (conv
    /// requests, when the table was warmed; None for CNN traffic)
    pub plan: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_kinds() {
        let conv = Payload::Conv {
            problem: ConvProblem::single(8, 1, 1),
            image: Tensor::zeros(vec![8, 8]),
            filters: Tensor::zeros(vec![1, 1, 1]),
        };
        assert_eq!(conv.kind_str(), "conv");
        let cnn = Payload::Cnn { image: Tensor::zeros(vec![1, 28, 28]) };
        assert_eq!(cnn.kind_str(), "cnn");
    }
}
