//! Serving metrics: counters + a log-bucketed latency histogram,
//! exportable as JSON (util::json — serde is not vendored).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Log-scale histogram for latencies in seconds (1 µs .. ~67 s).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts samples in [1µs * 2^i, 1µs * 2^(i+1))
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

const BASE: f64 = 1e-6;
const NBUCKETS: usize = 26;

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: vec![0; NBUCKETS], count: 0, sum: 0.0, max: 0.0 }
    }
}

impl Histogram {
    pub fn record(&mut self, secs: f64) {
        let idx = if secs <= BASE {
            0
        } else {
            ((secs / BASE).log2() as usize).min(NBUCKETS - 1)
        };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += secs;
        self.max = self.max.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// (upper edge in seconds, count) per bucket — the Prometheus
    /// exposition (`trace::prometheus`) turns these into cumulative
    /// `le` buckets.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, &c)| (BASE * 2f64.powi(i as i32 + 1), c))
            .collect()
    }

    /// Upper edge of the bucket containing the q-quantile (q in [0,1]).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return BASE * 2f64.powi(i as i32 + 1);
            }
        }
        self.max
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", (self.count as usize).into())
            .set("mean_s", self.mean().into())
            .set("p50_s", self.quantile(0.5).into())
            .set("p99_s", self.quantile(0.99).into())
            .set("max_s", self.max.into())
    }
}

/// All coordinator metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub batches_executed: u64,
    pub batched_requests: u64,
    /// coalesced conv micro-batches dispatched (including size-1 flushes)
    pub conv_batches_executed: u64,
    /// conv requests that rode those micro-batches
    pub coalesced_convs: u64,
    /// conv problems pre-tuned at startup (Router::warm_plans)
    pub plans_tuned: u64,
    /// model executions served through the executor's device pool
    pub pooled_models: u64,
    /// executor pool cap, bytes (the device's DRAM)
    pub pool_capacity_bytes: u64,
    /// executor pool gauges, sampled after the latest pooled execution
    pub pool_in_use_bytes: u64,
    pub pool_fragmentation_bytes: u64,
    /// executor pool counters (monotone)
    pub pool_peak_bytes: u64,
    pub pool_evictions: u64,
    pub pool_reuse_hits: u64,
    pub latency: Histogram,
    /// per request-class latency histograms (class = artifact label),
    /// so p50/p99-per-class never needs sample retention
    pub latency_by_class: BTreeMap<String, Histogram>,
    pub per_artifact: BTreeMap<String, u64>,
    /// fusion wins per model: (nodes fused, glue bytes eliminated per
    /// inference), recorded when a model graph is fused for serving
    pub fusion_by_model: BTreeMap<String, (u64, f64)>,
    /// filter-residency wins per model: (conv layers whose batched
    /// schedule kept filters smem-resident, DRAM filter bytes NOT
    /// re-streamed over the serving batch), recorded per model serve
    pub residency_by_model: BTreeMap<String, (u64, f64)>,
}

impl Metrics {
    pub fn record_response(&mut self, artifact: &str, latency_secs: f64) {
        self.responses += 1;
        self.latency.record(latency_secs);
        self.latency_by_class.entry(artifact.to_string()).or_default().record(latency_secs);
        *self.per_artifact.entry(artifact.to_string()).or_insert(0) += 1;
    }

    /// Mean requests per executed batch — the batching win.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches_executed == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches_executed as f64
        }
    }

    /// Mean conv requests per coalesced micro-batch (1.0 = nothing
    /// coalesced; > 1.0 = compatible neighbors shared a dispatch).
    pub fn mean_conv_batch_size(&self) -> f64 {
        if self.conv_batches_executed == 0 {
            0.0
        } else {
            self.coalesced_convs as f64 / self.conv_batches_executed as f64
        }
    }

    /// Record a model's fusion outcome (idempotent per model — the
    /// rewrite is deterministic, so every serve of the same model
    /// reports the same win).
    pub fn record_fusion(&mut self, model: &str, nodes_fused: u64, glue_bytes_eliminated: f64) {
        self.fusion_by_model
            .insert(model.to_string(), (nodes_fused, glue_bytes_eliminated));
    }

    /// Record a model's filter-residency outcome at its serving batch
    /// (idempotent per model, like `record_fusion` — the batched
    /// schedule is deterministic for a given batch size).
    pub fn record_residency(&mut self, model: &str, resident_layers: u64, filter_bytes_saved: f64) {
        self.residency_by_model
            .insert(model.to_string(), (resident_layers, filter_bytes_saved));
    }

    /// Sample the executor pool's occupancy/fragmentation/eviction state
    /// into the gauges (called by the executor after pooled work).
    pub fn observe_pool(&mut self, pool: &crate::fleet::DevicePool) {
        self.pool_capacity_bytes = pool.capacity() as u64;
        self.pool_in_use_bytes = pool.in_use_slab_bytes() as u64;
        self.pool_fragmentation_bytes = pool.fragmentation_bytes() as u64;
        self.pool_peak_bytes = pool.stats.peak_in_use_slab as u64;
        self.pool_evictions = pool.stats.evictions;
        self.pool_reuse_hits = pool.stats.reuse_hits;
    }

    pub fn to_json(&self) -> Json {
        let mut per = Json::obj();
        for (k, v) in &self.per_artifact {
            per = per.set(k, (*v as usize).into());
        }
        let pool = Json::obj()
            .set("capacity_bytes", (self.pool_capacity_bytes as usize).into())
            .set("in_use_bytes", (self.pool_in_use_bytes as usize).into())
            .set("fragmentation_bytes", (self.pool_fragmentation_bytes as usize).into())
            .set("peak_bytes", (self.pool_peak_bytes as usize).into())
            .set("evictions", (self.pool_evictions as usize).into())
            .set("reuse_hits", (self.pool_reuse_hits as usize).into())
            .set("pooled_models", (self.pooled_models as usize).into());
        Json::obj()
            .set("requests", (self.requests as usize).into())
            .set("responses", (self.responses as usize).into())
            .set("errors", (self.errors as usize).into())
            .set("batches", (self.batches_executed as usize).into())
            .set("mean_batch_size", self.mean_batch_size().into())
            .set("conv_batches", (self.conv_batches_executed as usize).into())
            .set("mean_conv_batch_size", self.mean_conv_batch_size().into())
            .set("plans_tuned", (self.plans_tuned as usize).into())
            .set("pool", pool)
            .set("fusion", {
                let mut f = Json::obj();
                for (m, &(n, b)) in &self.fusion_by_model {
                    f = f.set(
                        m,
                        Json::obj()
                            .set("nodes_fused", (n as usize).into())
                            .set("glue_bytes_eliminated", b.into()),
                    );
                }
                f
            })
            .set("residency", {
                let mut r = Json::obj();
                for (m, &(n, b)) in &self.residency_by_model {
                    r = r.set(
                        m,
                        Json::obj()
                            .set("resident_layers", (n as usize).into())
                            .set("filter_bytes_saved", b.into()),
                    );
                }
                r
            })
            .set("latency", self.latency.to_json())
            .set("latency_by_class", {
                let mut by = Json::obj();
                for (k, h) in &self.latency_by_class {
                    by = by.set(k, h.to_json());
                }
                by
            })
            .set("per_artifact", per)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_counts() {
        let mut h = Histogram::default();
        for _ in 0..10 {
            h.record(1e-3);
        }
        assert_eq!(h.count(), 10);
        assert!((h.mean() - 1e-3).abs() < 1e-9);
        assert_eq!(h.max(), 1e-3);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        let (p50, p99) = (h.quantile(0.5), h.quantile(0.99));
        assert!(p50 <= p99);
        assert!(p50 >= 4e-3 && p50 <= 1.3e-2, "p50={p50}");
    }

    #[test]
    fn histogram_extremes_clamp() {
        let mut h = Histogram::default();
        h.record(0.0); // below base
        h.record(1e9); // above top bucket
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn metrics_batch_accounting() {
        let mut m = Metrics::default();
        m.batches_executed = 4;
        m.batched_requests = 14;
        assert!((m.mean_batch_size() - 3.5).abs() < 1e-12);
        m.record_response("papernet_b8", 2e-3);
        assert_eq!(m.per_artifact["papernet_b8"], 1);
        let json = m.to_json().render();
        assert!(json.contains("\"mean_batch_size\":3.5"), "{json}");
    }

    #[test]
    fn empty_metrics_render() {
        let m = Metrics::default();
        assert!((m.mean_batch_size() - 0.0).abs() < 1e-12);
        assert!((m.mean_conv_batch_size() - 0.0).abs() < 1e-12);
        assert!(m.to_json().render().contains("\"requests\":0"));
    }

    #[test]
    fn pool_gauges_sample_and_render() {
        let mut m = Metrics::default();
        let mut pool = crate::fleet::DevicePool::new(4096);
        let a = pool.alloc(300).unwrap();
        let _b = pool.alloc(512).unwrap();
        pool.free(a).unwrap();
        m.pooled_models = 2;
        m.observe_pool(&pool);
        assert_eq!(m.pool_capacity_bytes, 4096);
        assert_eq!(m.pool_in_use_bytes, 512);
        assert_eq!(m.pool_peak_bytes, 1024);
        assert_eq!(m.pool_fragmentation_bytes, 0);
        let json = m.to_json().render();
        assert!(json.contains("\"pool\":{"), "{json}");
        assert!(json.contains("\"peak_bytes\":1024"), "{json}");
        assert!(json.contains("\"pooled_models\":2"), "{json}");
    }

    #[test]
    fn buckets_are_cumulative_consistent_and_classes_tracked() {
        let mut m = Metrics::default();
        m.record_response("vgg16_b4", 1e-3);
        m.record_response("vgg16_b4", 2e-3);
        m.record_response("alexnet_b1", 5e-4);
        let total: u64 = m.latency.buckets().iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 3, "every sample lands in a bucket");
        for w in m.latency.buckets().windows(2) {
            assert!(w[1].0 > w[0].0, "edges strictly increase");
        }
        assert!((m.latency.sum() - 3.5e-3).abs() < 1e-12);
        assert_eq!(m.latency_by_class["vgg16_b4"].count(), 2);
        assert_eq!(m.latency_by_class["alexnet_b1"].count(), 1);
        assert!(m.to_json().render().contains("\"latency_by_class\""));
    }

    #[test]
    fn fusion_wins_are_exported_per_model() {
        let mut m = Metrics::default();
        m.record_fusion("vgg16", 13, 1.5e8);
        m.record_fusion("vgg16", 13, 1.5e8); // idempotent
        m.record_fusion("resnet18", 16, 8.0e7);
        assert_eq!(m.fusion_by_model.len(), 2);
        assert_eq!(m.fusion_by_model["vgg16"].0, 13);
        let json = m.to_json().render();
        assert!(json.contains("\"fusion\":{"), "{json}");
        assert!(json.contains("\"nodes_fused\":13"), "{json}");
        assert!(json.contains("\"glue_bytes_eliminated\""), "{json}");
    }

    #[test]
    fn residency_wins_are_exported_per_model() {
        let mut m = Metrics::default();
        m.record_residency("mobilenet_v1", 13, 2.5e7);
        m.record_residency("mobilenet_v1", 13, 2.5e7); // idempotent
        m.record_residency("resnet18", 0, 0.0);
        assert_eq!(m.residency_by_model.len(), 2);
        assert_eq!(m.residency_by_model["mobilenet_v1"].0, 13);
        let json = m.to_json().render();
        assert!(json.contains("\"residency\":{"), "{json}");
        assert!(json.contains("\"resident_layers\":13"), "{json}");
        assert!(json.contains("\"filter_bytes_saved\""), "{json}");
    }

    #[test]
    fn conv_coalescing_accounting() {
        let mut m = Metrics::default();
        m.conv_batches_executed = 3;
        m.coalesced_convs = 9;
        assert!((m.mean_conv_batch_size() - 3.0).abs() < 1e-12);
        let json = m.to_json().render();
        assert!(json.contains("\"conv_batches\":3"), "{json}");
        assert!(json.contains("\"mean_conv_batch_size\":3"), "{json}");
    }
}
