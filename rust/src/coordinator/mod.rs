//! L3 coordinator — the serving layer: `request` types, `router`
//! (manifest -> artifact dispatch + §3 plan advice), `batcher` (dynamic
//! CNN batching + conv micro-batch coalescing), `server` (queue +
//! executor threads over the PJRT runtime), `metrics`.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod workload;

pub use batcher::{BatchConfig, Batcher, ConvCoalescer};
pub use metrics::Metrics;
pub use request::{ModelSummary, Payload, Request, Response};
pub use router::{plan_advice, Router, CPU_LOWERED};
pub use server::Coordinator;
pub use workload::{Arrivals, Mix, Workload};
