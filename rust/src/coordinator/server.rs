//! The serving coordinator: queue thread (routing + dynamic batching +
//! conv micro-batch coalescing) + executor thread (owns the PJRT
//! runtime).  Python never runs here.
//!
//!   client -> submit() -> [queue thread] -> Work -> [executor thread]
//!                               |                        |
//!                    Batcher<CnnItem> +              Runtime (PJRT)
//!                    ConvCoalescer<ConvItem>
//!
//! The queue thread holds compatible (same-problem) pending conv
//! requests for up to the `BatchConfig` latency budget and dispatches
//! them as ONE micro-batch: every response of the batch carries the
//! same `batch_id` and the same tuned-plan advice.  tokio is not in the
//! offline vendor set; std::thread + mpsc channels carry the same
//! structure (one queue task, one executor task, oneshot response
//! channels).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::{BatchConfig, Batcher, ConvCoalescer};
use super::metrics::Metrics;
use super::request::{ModelSummary, Payload, Request, Response};
use super::router::{Router, CPU_LOWERED};
use crate::conv::{conv2d_op_cpu, ConvOp};
use crate::gpusim::GpuSpec;
use crate::runtime::{Runtime, Tensor};

type Respond = Sender<Result<Response, String>>;

struct CnnItem {
    req: Request,
    respond: Respond,
}

struct ConvItem {
    req: Request,
    respond: Respond,
}

enum Work {
    /// a coalesced conv micro-batch: same op, one route (artifact or
    /// the CPU lowering), shared batch id + dispatch advice across
    /// every member
    ConvBatch {
        batch_id: u64,
        op: ConvOp,
        items: Vec<ConvItem>,
        advice: Option<String>,
    },
    /// an explicit client-side `Payload::BatchedConv` request (the
    /// client did the grouping; the id still identifies the dispatch)
    Batched { batch_id: u64, req: Request, respond: Respond, advice: Option<String> },
    CnnBatch { batch_id: u64, items: Vec<CnnItem> },
    /// a whole-model plan request, carrying the registry's pre-built
    /// shared graph — neither thread rebuilds or deep-clones it
    Model(Request, Respond, std::sync::Arc<crate::graph::Graph>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<Sender<(Request, Respond)>>,
    queue_thread: Option<JoinHandle<()>>,
    exec_thread: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Start the queue + executor threads over an artifact directory,
    /// attaching plan advice tuned for the paper's testbed (GTX 1080Ti).
    pub fn start(artifact_dir: &Path, batch_cfg: BatchConfig) -> Result<Coordinator> {
        Coordinator::start_with_gpu(artifact_dir, batch_cfg, &crate::gpusim::gtx_1080ti())
    }

    /// `start`, with an explicit GPU spec for the plan tuning (the
    /// advice attached to conv responses is spec-dependent).
    pub fn start_with_gpu(
        artifact_dir: &Path,
        batch_cfg: BatchConfig,
        gpu: &crate::gpusim::GpuSpec,
    ) -> Result<Coordinator> {
        // the manifest parses without a PJRT client; the client itself is
        // !Send (Rc internals), so the Runtime is constructed *inside*
        // the executor thread and signals readiness back
        let artifacts = crate::runtime::manifest::load_manifest(artifact_dir)?;
        let mut router = Router::from_artifacts(&artifacts);
        let metrics = Arc::new(Mutex::new(Metrics::default()));

        // the §4 model graphs are always servable (they are L1-only)
        for name in crate::graph::MODEL_NAMES {
            router.register_model(name).expect("built-in model");
        }

        // dispatch every routed conv problem and every registered model
        // layer across all backends once, before traffic: the queue
        // thread then serves decided plans — and model executions —
        // with zero per-request search
        let tuned = router.warm_plans(gpu);
        metrics.lock().unwrap().plans_tuned = tuned as u64;

        let (tx, rx) = channel::<(Request, Respond)>();
        let (work_tx, work_rx) = channel::<Work>();

        let queue_metrics = metrics.clone();
        let queue_router = router;
        let queue_thread = std::thread::Builder::new()
            .name("pasconv-queue".into())
            .spawn(move || queue_loop(rx, work_tx, queue_router, batch_cfg, queue_metrics))
            .expect("spawn queue thread");

        let exec_metrics = metrics.clone();
        let exec_dir = artifact_dir.to_path_buf();
        let exec_gpu = gpu.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let exec_thread = std::thread::Builder::new()
            .name("pasconv-exec".into())
            .spawn(move || {
                let mut runtime = match Runtime::new(&exec_dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                // warm the CNN executables so the first batch isn't a compile
                let router = Router::from_artifacts(
                    &runtime
                        .names()
                        .iter()
                        .map(|n| runtime.artifact(n).unwrap().clone())
                        .collect::<Vec<_>>(),
                );
                for b in [1usize, router.max_cnn_batch()] {
                    if let Ok((_, name)) = router.route_cnn(b) {
                        let _ = runtime.ensure_compiled(&name.to_string());
                    }
                }
                let _ = ready_tx.send(Ok(()));
                exec_loop(work_rx, runtime, exec_gpu, exec_metrics)
            })
            .expect("spawn exec thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))?
            .map_err(|e| anyhow!(e))?;

        Ok(Coordinator {
            tx: Some(tx),
            queue_thread: Some(queue_thread),
            exec_thread: Some(exec_thread),
            next_id: AtomicU64::new(1),
            metrics,
        })
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, payload: Payload) -> Receiver<Result<Response, String>> {
        let (resp_tx, resp_rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.lock().unwrap().requests += 1;
        let req = Request { id, payload, submitted: Instant::now() };
        if let Some(tx) = &self.tx {
            if tx.send((req, resp_tx.clone())).is_err() {
                let _ = resp_tx.send(Err("coordinator stopped".into()));
            }
        }
        resp_rx
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, payload: Payload) -> Result<Response> {
        self.submit(payload)
            .recv()
            .map_err(|_| anyhow!("coordinator dropped the request"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Drain and stop both threads.
    pub fn shutdown(&mut self) {
        self.tx.take(); // close the queue
        if let Some(t) = self.queue_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.exec_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn queue_loop(
    rx: Receiver<(Request, Respond)>,
    work_tx: Sender<Work>,
    router: Router,
    cfg: BatchConfig,
    metrics: Arc<Mutex<Metrics>>,
) {
    let cnn_cfg = BatchConfig { max_batch: cfg.max_batch.min(router.max_cnn_batch()), ..cfg };
    let mut batcher: Batcher<CnnItem> = Batcher::new(cnn_cfg);
    // conv lanes use the raw config (conv batches run image-by-image on
    // the artifact, so no manifest batch cap applies)
    let mut coalescer: ConvCoalescer<ConvItem> = ConvCoalescer::new(cfg);
    let mut next_batch_id: u64 = 1;
    let mut alloc_id = || {
        let id = next_batch_id;
        next_batch_id += 1;
        id
    };
    loop {
        // wait for the next request or the earliest batch deadline
        // (CNN batcher or any conv lane), whichever comes first
        let now = Instant::now();
        let deadline = match (batcher.deadline_in(now), coalescer.deadline_in(now)) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        let item = match deadline {
            Some(d) => match rx.recv_timeout(d) {
                Ok(x) => Some(x),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(x) => Some(x),
                Err(_) => break,
            },
        };
        let now = Instant::now();
        if let Some((req, respond)) = item {
            match &req.payload {
                Payload::Conv { op, .. } => {
                    // coalesce compatible conv requests into a micro-batch
                    // under the latency budget; the advice comes from the
                    // warmed table (zero search) and is shared batch-wide
                    if let Err(e) = router.route_op(op) {
                        metrics.lock().unwrap().errors += 1;
                        let _ = respond.send(Err(e.to_string()));
                    } else {
                        let o = *op;
                        if let Some((o, items)) =
                            coalescer.push(o, ConvItem { req, respond }, now)
                        {
                            let advice = router.tuned_advice(&o).map(|s| s.to_string());
                            let w =
                                Work::ConvBatch { batch_id: alloc_id(), op: o, items, advice };
                            if work_tx.send(w).is_err() {
                                break;
                            }
                        }
                    }
                }
                Payload::BatchedConv { batch, .. } => {
                    // explicit batches bypass coalescing: the client
                    // already did the grouping
                    let advice = router.tuned_advice(&batch.op).map(|s| s.to_string());
                    if let Err(e) = router.route_batched(batch) {
                        metrics.lock().unwrap().errors += 1;
                        let _ = respond.send(Err(e.to_string()));
                    } else {
                        let w = Work::Batched { batch_id: alloc_id(), req, respond, advice };
                        if work_tx.send(w).is_err() {
                            break;
                        }
                    }
                }
                Payload::Cnn { .. } => {
                    if let Some(items) = batcher.push(CnnItem { req, respond }, now) {
                        if work_tx.send(Work::CnnBatch { batch_id: alloc_id(), items }).is_err() {
                            break;
                        }
                    }
                }
                Payload::Model { model } => {
                    // the registry holds the graph built at registration;
                    // unknown names fail here with the registered list
                    match router.route_model(model) {
                        Ok(graph) => {
                            if work_tx.send(Work::Model(req, respond, graph)).is_err() {
                                break;
                            }
                        }
                        Err(e) => {
                            metrics.lock().unwrap().errors += 1;
                            let _ = respond.send(Err(e.to_string()));
                        }
                    }
                }
            }
        }
        let now = Instant::now();
        let mut disconnected = false;
        if let Some(items) = batcher.poll(now) {
            disconnected |= work_tx.send(Work::CnnBatch { batch_id: alloc_id(), items }).is_err();
        }
        for (o, items) in coalescer.poll(now) {
            let advice = router.tuned_advice(&o).map(|s| s.to_string());
            let w = Work::ConvBatch { batch_id: alloc_id(), op: o, items, advice };
            disconnected |= work_tx.send(w).is_err();
        }
        if disconnected {
            break;
        }
    }
    // shutdown: flush every pending lane and the CNN tail batch
    for (o, items) in coalescer.take_all() {
        let advice = router.tuned_advice(&o).map(|s| s.to_string());
        let _ = work_tx.send(Work::ConvBatch { batch_id: alloc_id(), op: o, items, advice });
    }
    if let Some(items) = batcher.take() {
        let _ = work_tx.send(Work::CnnBatch { batch_id: alloc_id(), items });
    }
}

/// The exact CPU lowering as an executor: validate tensor sizes
/// against the op's own accounting (grouped filters are
/// `M x C/G x K x K`) and run `conv::conv2d_op_cpu`.
fn execute_op_lowered(op: &ConvOp, image: &Tensor, filters: &Tensor) -> Result<Tensor> {
    if image.len() != op.core.map_elems() {
        return Err(anyhow!(
            "op image has {} elements, {} wants {}",
            image.len(),
            op.label(),
            op.core.map_elems()
        ));
    }
    if filters.len() != op.filter_elems() {
        return Err(anyhow!(
            "op filters have {} elements, {} wants {}",
            filters.len(),
            op.label(),
            op.filter_elems()
        ));
    }
    let out = conv2d_op_cpu(op, &image.data, &filters.data);
    Tensor::new(vec![op.core.m, op.oy(), op.ox()], out)
}

/// Run one conv op request body: dense ops against the (warm) PJRT
/// artifact, non-dense ops through the exact CPU lowering.
fn execute_conv_op(
    runtime: &mut Runtime,
    name: &str,
    op: &ConvOp,
    image: &Tensor,
    filters: &Tensor,
) -> Result<Tensor> {
    if name == CPU_LOWERED {
        execute_op_lowered(op, image, filters)
    } else {
        runtime.execute_conv(name, image, filters)
    }
}

/// Serve an explicit batched op: validate the stacked image tensor,
/// run each image against the route (artifact or CPU lowering), and
/// stack the outputs on a new leading axis.
fn execute_batched_conv(
    runtime: &mut Runtime,
    router: &Router,
    batch: &crate::conv::BatchedConvOp,
    images: &Tensor,
    filters: &Tensor,
) -> Result<(Tensor, String)> {
    let name = router.route_batched(batch)?.to_string();
    let p = &batch.op.core;
    let per_image: Vec<usize> = if p.is_single_channel() && batch.op.groups == 1 {
        vec![p.wy, p.wx]
    } else {
        vec![p.c, p.wy, p.wx]
    };
    let mut want = vec![batch.n];
    want.extend_from_slice(&per_image);
    if images.shape != want {
        return Err(anyhow!(
            "batched image shape {:?}, batch of {} wants {:?}",
            images.shape,
            batch.n,
            want
        ));
    }
    let mut outputs = Vec::with_capacity(batch.n);
    for i in 0..batch.n {
        let mut image = images.slice_axis0(i, i + 1)?;
        image.shape.remove(0); // (1, ...) -> per-image dims
        outputs.push(execute_conv_op(runtime, &name, &batch.op, &image, filters)?);
    }
    Ok((Tensor::stack(&outputs)?, name))
}

fn exec_loop(
    work_rx: Receiver<Work>,
    mut runtime: Runtime,
    gpu: GpuSpec,
    metrics: Arc<Mutex<Metrics>>,
) {
    let router = Router::from_artifacts(
        &runtime.names().iter().map(|n| runtime.artifact(n).unwrap().clone()).collect::<Vec<_>>(),
    );
    // the executor's device memory pool, persistent across requests:
    // model executions allocate per-tensor from it (capped at the
    // simulated card's DRAM), so repeat traffic reuses parked slabs
    let mut pool = crate::fleet::DevicePool::new(gpu.dram_bytes as usize);
    while let Ok(work) = work_rx.recv() {
        match work {
            Work::ConvBatch { batch_id, op, items, advice } => {
                let n = items.len();
                let name = match router.route_op(&op) {
                    Ok(nm) => nm.to_string(),
                    Err(e) => {
                        let mut m = metrics.lock().unwrap();
                        for it in &items {
                            let _ = it.respond.send(Err(e.to_string()));
                            m.errors += 1;
                        }
                        continue;
                    }
                };
                // one dispatch for the whole micro-batch: the executable
                // is compiled/warm after the first member, and every
                // response shares the batch id and the plan advice
                let mut outcomes = Vec::with_capacity(n);
                for it in &items {
                    let Payload::Conv { image, filters, .. } = &it.req.payload else {
                        outcomes.push(Err("internal: non-conv in conv batch".to_string()));
                        continue;
                    };
                    outcomes.push(
                        execute_conv_op(&mut runtime, &name, &op, image, filters)
                            .map_err(|e| e.to_string()),
                    );
                }
                // account under ONE lock, then send (same happens-before
                // contract as the CNN batch path)
                let latencies: Vec<f64> =
                    items.iter().map(|it| it.req.submitted.elapsed().as_secs_f64()).collect();
                {
                    let mut m = metrics.lock().unwrap();
                    m.conv_batches_executed += 1;
                    m.coalesced_convs += n as u64;
                    for (out, &l) in outcomes.iter().zip(&latencies) {
                        match out {
                            Ok(_) => m.record_response(&name, l),
                            Err(_) => m.errors += 1,
                        }
                    }
                }
                for ((it, out), &latency) in items.iter().zip(outcomes).zip(&latencies) {
                    let _ = it.respond.send(out.map(|output| Response {
                        id: it.req.id,
                        output,
                        latency_secs: latency,
                        artifact: name.clone(),
                        batch_size: n,
                        batch_id: Some(batch_id),
                        plan: advice.clone(),
                        model: None,
                    }));
                }
            }
            Work::Batched { batch_id, req, respond, advice } => {
                let Payload::BatchedConv { batch, images, filters } = &req.payload else {
                    let _ = respond.send(Err("internal: non-batched work".into()));
                    continue;
                };
                match execute_batched_conv(&mut runtime, &router, batch, images, filters) {
                    Ok((output, name)) => {
                        let latency = req.submitted.elapsed().as_secs_f64();
                        metrics.lock().unwrap().record_response(&name, latency);
                        let _ = respond.send(Ok(Response {
                            id: req.id,
                            output,
                            latency_secs: latency,
                            artifact: name,
                            batch_size: batch.n,
                            batch_id: Some(batch_id),
                            plan: advice,
                            model: None,
                        }));
                    }
                    Err(e) => {
                        metrics.lock().unwrap().errors += 1;
                        let _ = respond.send(Err(e.to_string()));
                    }
                }
            }
            Work::Model(req, respond, graph) => {
                // every layer was pre-dispatched by warm_plans, so this
                // is a pure walk over the decision cache + simulator —
                // each layer runs whatever backend won its dispatch.
                // Serving fuses first: relu/add/pool tails fold into
                // their convs and eligible concats go zero-copy (fused
                // decisions land in the same dispatch cache, so repeat
                // models pay the rewrite's search once).  Memory comes
                // from the executor's persistent device pool
                // (per-tensor alloc/free over the schedule) — repeat
                // models reuse parked slabs instead of planning a
                // fresh arena; timing is bit-identical either way.
                let (graph, fusion) =
                    crate::graph::fuse(&graph, &gpu, crate::backend::dispatch_fused_op_plan);
                let (report, pooled) = match crate::graph::execute_pooled(
                    &graph,
                    &gpu,
                    crate::backend::dispatch_fused_op_plan,
                    1,
                    &mut pool,
                ) {
                    Ok(r) => r,
                    Err(e) => {
                        metrics.lock().unwrap().errors += 1;
                        let _ = respond.send(Err(format!("model {}: {e}", graph.name)));
                        continue;
                    }
                };
                let artifact = format!("model:{}", graph.name);
                let latency = req.submitted.elapsed().as_secs_f64();
                {
                    let mut m = metrics.lock().unwrap();
                    m.record_response(&artifact, latency);
                    m.pooled_models += 1;
                    m.observe_pool(&pool);
                    m.record_fusion(
                        &graph.name,
                        fusion.nodes_fused as u64,
                        fusion.glue_bytes_eliminated,
                    );
                    m.record_residency(
                        &graph.name,
                        report.resident_conv_layers as u64,
                        report.resident_filter_bytes_saved,
                    );
                }
                // the output tensor carries the honest simulation data:
                // per-node seconds in schedule order
                let per_node: Vec<f32> =
                    report.nodes.iter().map(|n| n.seconds as f32).collect();
                let output = Tensor::new(vec![per_node.len()], per_node).expect("report tensor");
                let _ = respond.send(Ok(Response {
                    id: req.id,
                    output,
                    latency_secs: latency,
                    artifact,
                    batch_size: 1,
                    batch_id: None,
                    plan: Some(report.summary()),
                    model: Some(ModelSummary {
                        model: report.model.clone(),
                        nodes: report.nodes.len(),
                        conv_layers: report.conv_layers,
                        model_latency_secs: report.total_seconds,
                        arena_peak_bytes: report.arena.peak_bytes,
                        pooled_peak_bytes: pooled.peak_bytes,
                        naive_bytes: report.arena.naive_bytes,
                    }),
                }));
            }
            Work::CnnBatch { batch_id, items } => {
                let n = items.len();
                let (cap, name) = match router.route_cnn(n) {
                    Ok((b, n)) => (b, n.to_string()),
                    Err(e) => {
                        let mut m = metrics.lock().unwrap();
                        for it in &items {
                            let _ = it.respond.send(Err(e.to_string()));
                            m.errors += 1;
                        }
                        continue;
                    }
                };
                // build the padded batch buffer directly from the request
                // tensors (single copy — no intermediate clone + stack)
                let mut images: Vec<&Tensor> = Vec::with_capacity(items.len());
                for it in &items {
                    if let Payload::Cnn { image } = &it.req.payload {
                        images.push(image);
                    }
                }
                if images.len() != items.len()
                    || images.iter().any(|t| t.shape != images[0].shape)
                {
                    let mut m = metrics.lock().unwrap();
                    for it in &items {
                        let _ = it.respond.send(Err("malformed CNN batch".into()));
                        m.errors += 1;
                    }
                    continue;
                }
                let row = images[0].len();
                let mut data = Vec::with_capacity(cap * row);
                for im in &images {
                    data.extend_from_slice(&im.data);
                }
                data.resize(cap * row, 0.0); // zero-pad the tail slots
                let mut shape = vec![cap];
                shape.extend_from_slice(&images[0].shape);
                let batch = Tensor::new(shape, data).expect("batch shape");
                match runtime.execute_refs(&name, &[&batch]) {
                    Ok(out) => {
                        // account under ONE lock, then send: clients that
                        // have their response must also see it in the
                        // metrics (tests rely on that happens-before)
                        let latencies: Vec<f64> = items
                            .iter()
                            .map(|it| it.req.submitted.elapsed().as_secs_f64())
                            .collect();
                        {
                            let mut m = metrics.lock().unwrap();
                            m.batches_executed += 1;
                            m.batched_requests += n as u64;
                            for &l in &latencies {
                                m.record_response(&name, l);
                            }
                        }
                        for (i, it) in items.into_iter().enumerate() {
                            let row = out.slice_axis0(i, i + 1).unwrap();
                            let _ = it.respond.send(Ok(Response {
                                id: it.req.id,
                                output: row,
                                latency_secs: latencies[i],
                                artifact: name.clone(),
                                batch_size: n,
                                batch_id: Some(batch_id),
                                plan: None,
                                model: None,
                            }));
                        }
                    }
                    Err(e) => {
                        let mut m = metrics.lock().unwrap();
                        for it in &items {
                            let _ = it.respond.send(Err(e.to_string()));
                            m.errors += 1;
                        }
                    }
                }
            }
        }
    }
}
