//! The serving coordinator: queue thread (routing + dynamic batching) +
//! executor thread (owns the PJRT runtime).  Python never runs here.
//!
//!   client -> submit() -> [queue thread] -> Work -> [executor thread]
//!                               |                        |
//!                          Batcher<CnnItem>         Runtime (PJRT)
//!
//! tokio is not in the offline vendor set; std::thread + mpsc channels
//! carry the same structure (one queue task, one executor task, oneshot
//! response channels).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::{BatchConfig, Batcher};
use super::metrics::Metrics;
use super::request::{ModelSummary, Payload, Request, Response};
use super::router::Router;
use crate::gpusim::GpuSpec;
use crate::runtime::{Runtime, Tensor};

type Respond = Sender<Result<Response, String>>;

struct CnnItem {
    req: Request,
    respond: Respond,
}

enum Work {
    /// a conv request plus the tuned-plan advice the router attached
    Single(Request, Respond, Option<String>),
    CnnBatch(Vec<CnnItem>),
    /// a whole-model plan request, carrying the registry's pre-built
    /// shared graph — neither thread rebuilds or deep-clones it
    Model(Request, Respond, std::sync::Arc<crate::graph::Graph>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<Sender<(Request, Respond)>>,
    queue_thread: Option<JoinHandle<()>>,
    exec_thread: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Start the queue + executor threads over an artifact directory,
    /// attaching plan advice tuned for the paper's testbed (GTX 1080Ti).
    pub fn start(artifact_dir: &Path, batch_cfg: BatchConfig) -> Result<Coordinator> {
        Coordinator::start_with_gpu(artifact_dir, batch_cfg, &crate::gpusim::gtx_1080ti())
    }

    /// `start`, with an explicit GPU spec for the plan tuning (the
    /// advice attached to conv responses is spec-dependent).
    pub fn start_with_gpu(
        artifact_dir: &Path,
        batch_cfg: BatchConfig,
        gpu: &crate::gpusim::GpuSpec,
    ) -> Result<Coordinator> {
        // the manifest parses without a PJRT client; the client itself is
        // !Send (Rc internals), so the Runtime is constructed *inside*
        // the executor thread and signals readiness back
        let artifacts = crate::runtime::manifest::load_manifest(artifact_dir)?;
        let mut router = Router::from_artifacts(&artifacts);
        let metrics = Arc::new(Mutex::new(Metrics::default()));

        // the §4 model graphs are always servable (they are L1-only)
        for name in crate::graph::MODEL_NAMES {
            router.register_model(name).expect("built-in model");
        }

        // tune every routed conv problem and every registered model
        // layer once, before traffic: the queue thread then serves tuned
        // plans — and model executions — with zero per-request search
        let tuned = router.warm_plans(gpu);
        metrics.lock().unwrap().plans_tuned = tuned as u64;

        let (tx, rx) = channel::<(Request, Respond)>();
        let (work_tx, work_rx) = channel::<Work>();

        let queue_metrics = metrics.clone();
        let queue_router = router;
        let queue_thread = std::thread::Builder::new()
            .name("pasconv-queue".into())
            .spawn(move || queue_loop(rx, work_tx, queue_router, batch_cfg, queue_metrics))
            .expect("spawn queue thread");

        let exec_metrics = metrics.clone();
        let exec_dir = artifact_dir.to_path_buf();
        let exec_gpu = gpu.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let exec_thread = std::thread::Builder::new()
            .name("pasconv-exec".into())
            .spawn(move || {
                let mut runtime = match Runtime::new(&exec_dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                // warm the CNN executables so the first batch isn't a compile
                let router = Router::from_artifacts(
                    &runtime
                        .names()
                        .iter()
                        .map(|n| runtime.artifact(n).unwrap().clone())
                        .collect::<Vec<_>>(),
                );
                for b in [1usize, router.max_cnn_batch()] {
                    if let Ok((_, name)) = router.route_cnn(b) {
                        let _ = runtime.ensure_compiled(&name.to_string());
                    }
                }
                let _ = ready_tx.send(Ok(()));
                exec_loop(work_rx, runtime, exec_gpu, exec_metrics)
            })
            .expect("spawn exec thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))?
            .map_err(|e| anyhow!(e))?;

        Ok(Coordinator {
            tx: Some(tx),
            queue_thread: Some(queue_thread),
            exec_thread: Some(exec_thread),
            next_id: AtomicU64::new(1),
            metrics,
        })
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, payload: Payload) -> Receiver<Result<Response, String>> {
        let (resp_tx, resp_rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.lock().unwrap().requests += 1;
        let req = Request { id, payload, submitted: Instant::now() };
        if let Some(tx) = &self.tx {
            if tx.send((req, resp_tx.clone())).is_err() {
                let _ = resp_tx.send(Err("coordinator stopped".into()));
            }
        }
        resp_rx
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, payload: Payload) -> Result<Response> {
        self.submit(payload)
            .recv()
            .map_err(|_| anyhow!("coordinator dropped the request"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Drain and stop both threads.
    pub fn shutdown(&mut self) {
        self.tx.take(); // close the queue
        if let Some(t) = self.queue_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.exec_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn queue_loop(
    rx: Receiver<(Request, Respond)>,
    work_tx: Sender<Work>,
    router: Router,
    cfg: BatchConfig,
    metrics: Arc<Mutex<Metrics>>,
) {
    let cfg = BatchConfig { max_batch: cfg.max_batch.min(router.max_cnn_batch()), ..cfg };
    let mut batcher: Batcher<CnnItem> = Batcher::new(cfg);
    loop {
        // wait for the next request or the batch deadline, whichever first
        let item = match batcher.deadline_in(Instant::now()) {
            Some(d) => match rx.recv_timeout(d) {
                Ok(x) => Some(x),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(x) => Some(x),
                Err(_) => break,
            },
        };
        let now = Instant::now();
        if let Some((req, respond)) = item {
            match &req.payload {
                Payload::Conv { problem, .. } => {
                    // conv problems route 1:1 to artifacts — no batching;
                    // the advice comes from the warmed table (zero search)
                    let advice = router.tuned_advice(problem).map(|s| s.to_string());
                    if let Err(e) = router.route_conv(problem) {
                        metrics.lock().unwrap().errors += 1;
                        let _ = respond.send(Err(e.to_string()));
                    } else if work_tx.send(Work::Single(req, respond, advice)).is_err() {
                        break;
                    }
                }
                Payload::Cnn { .. } => {
                    if let Some(batch) = batcher.push(CnnItem { req, respond }, now) {
                        if work_tx.send(Work::CnnBatch(batch)).is_err() {
                            break;
                        }
                    }
                }
                Payload::Model { model } => {
                    // the registry holds the graph built at registration;
                    // unknown names fail here with the registered list
                    match router.route_model(model) {
                        Ok(graph) => {
                            if work_tx.send(Work::Model(req, respond, graph)).is_err() {
                                break;
                            }
                        }
                        Err(e) => {
                            metrics.lock().unwrap().errors += 1;
                            let _ = respond.send(Err(e.to_string()));
                        }
                    }
                }
            }
        }
        if let Some(batch) = batcher.poll(Instant::now()) {
            if work_tx.send(Work::CnnBatch(batch)).is_err() {
                break;
            }
        }
    }
    // shutdown: flush the tail batch
    if let Some(batch) = batcher.take() {
        let _ = work_tx.send(Work::CnnBatch(batch));
    }
}

fn exec_loop(
    work_rx: Receiver<Work>,
    mut runtime: Runtime,
    gpu: GpuSpec,
    metrics: Arc<Mutex<Metrics>>,
) {
    let router = Router::from_artifacts(
        &runtime.names().iter().map(|n| runtime.artifact(n).unwrap().clone()).collect::<Vec<_>>(),
    );
    while let Ok(work) = work_rx.recv() {
        match work {
            Work::Single(req, respond, plan_advice) => {
                let Payload::Conv { problem, image, filters } = &req.payload else {
                    let _ = respond.send(Err("internal: non-conv single work".into()));
                    continue;
                };
                let name = match router.route_conv(problem) {
                    Ok(n) => n.to_string(),
                    Err(e) => {
                        metrics.lock().unwrap().errors += 1;
                        let _ = respond.send(Err(e.to_string()));
                        continue;
                    }
                };
                match runtime.execute_conv(&name, image, filters) {
                    Ok(output) => {
                        let latency = req.submitted.elapsed().as_secs_f64();
                        metrics.lock().unwrap().record_response(&name, latency);
                        let _ = respond.send(Ok(Response {
                            id: req.id,
                            output,
                            latency_secs: latency,
                            artifact: name,
                            batch_size: 1,
                            plan: plan_advice,
                            model: None,
                        }));
                    }
                    Err(e) => {
                        metrics.lock().unwrap().errors += 1;
                        let _ = respond.send(Err(e.to_string()));
                    }
                }
            }
            Work::Model(req, respond, graph) => {
                // every layer was pre-tuned by warm_plans, so this is a
                // pure walk over the plan cache + simulator
                let report = crate::graph::execute(&graph, &gpu, crate::plans::plan_for);
                let artifact = format!("model:{}", graph.name);
                let latency = req.submitted.elapsed().as_secs_f64();
                metrics.lock().unwrap().record_response(&artifact, latency);
                // the output tensor carries the honest simulation data:
                // per-node seconds in schedule order
                let per_node: Vec<f32> =
                    report.nodes.iter().map(|n| n.seconds as f32).collect();
                let output = Tensor::new(vec![per_node.len()], per_node).expect("report tensor");
                let _ = respond.send(Ok(Response {
                    id: req.id,
                    output,
                    latency_secs: latency,
                    artifact,
                    batch_size: 1,
                    plan: Some(report.summary()),
                    model: Some(ModelSummary {
                        model: report.model.clone(),
                        nodes: report.nodes.len(),
                        conv_layers: report.conv_layers,
                        model_latency_secs: report.total_seconds,
                        arena_peak_bytes: report.arena.peak_bytes,
                        naive_bytes: report.arena.naive_bytes,
                    }),
                }));
            }
            Work::CnnBatch(items) => {
                let n = items.len();
                let (cap, name) = match router.route_cnn(n) {
                    Ok((b, n)) => (b, n.to_string()),
                    Err(e) => {
                        let mut m = metrics.lock().unwrap();
                        for it in &items {
                            let _ = it.respond.send(Err(e.to_string()));
                            m.errors += 1;
                        }
                        continue;
                    }
                };
                // build the padded batch buffer directly from the request
                // tensors (single copy — no intermediate clone + stack)
                let mut images: Vec<&Tensor> = Vec::with_capacity(items.len());
                for it in &items {
                    if let Payload::Cnn { image } = &it.req.payload {
                        images.push(image);
                    }
                }
                if images.len() != items.len()
                    || images.iter().any(|t| t.shape != images[0].shape)
                {
                    let mut m = metrics.lock().unwrap();
                    for it in &items {
                        let _ = it.respond.send(Err("malformed CNN batch".into()));
                        m.errors += 1;
                    }
                    continue;
                }
                let row = images[0].len();
                let mut data = Vec::with_capacity(cap * row);
                for im in &images {
                    data.extend_from_slice(&im.data);
                }
                data.resize(cap * row, 0.0); // zero-pad the tail slots
                let mut shape = vec![cap];
                shape.extend_from_slice(&images[0].shape);
                let batch = Tensor::new(shape, data).expect("batch shape");
                match runtime.execute_refs(&name, &[&batch]) {
                    Ok(out) => {
                        // account under ONE lock, then send: clients that
                        // have their response must also see it in the
                        // metrics (tests rely on that happens-before)
                        let latencies: Vec<f64> = items
                            .iter()
                            .map(|it| it.req.submitted.elapsed().as_secs_f64())
                            .collect();
                        {
                            let mut m = metrics.lock().unwrap();
                            m.batches_executed += 1;
                            m.batched_requests += n as u64;
                            for &l in &latencies {
                                m.record_response(&name, l);
                            }
                        }
                        for (i, it) in items.into_iter().enumerate() {
                            let row = out.slice_axis0(i, i + 1).unwrap();
                            let _ = it.respond.send(Ok(Response {
                                id: it.req.id,
                                output: row,
                                latency_secs: latencies[i],
                                artifact: name.clone(),
                                batch_size: n,
                                plan: None,
                                model: None,
                            }));
                        }
                    }
                    Err(e) => {
                        let mut m = metrics.lock().unwrap();
                        for it in &items {
                            let _ = it.respond.send(Err(e.to_string()));
                            m.errors += 1;
                        }
                    }
                }
            }
        }
    }
}
