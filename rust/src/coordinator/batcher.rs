//! Dynamic batcher — vLLM-style request grouping for the serve path.
//!
//! CNN requests are held briefly and grouped so one PJRT execution serves
//! up to `max_batch` of them (the papernet_b8 artifact); a batch closes
//! when full or when its oldest request has waited `max_wait`.  Conv
//! requests coalesce per problem shape through `ConvCoalescer` — a keyed
//! family of `Batcher`s, one per distinct `ConvProblem`, under the same
//! latency budget (requests for *different* shapes never batch: each
//! shape is its own artifact).
//!
//! The core is a pure state machine (`push`/`poll`) so the policy is unit
//! testable without threads; `server.rs` drives it from the queue thread.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::conv::ConvOp;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates items of type T into batches.
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatchConfig,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatchConfig) -> Batcher<T> {
        Batcher { cfg, pending: Vec::new(), oldest: None }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Add an item; returns a full batch if this item closed it.
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(item);
        if self.pending.len() >= self.cfg.max_batch {
            return self.take();
        }
        None
    }

    /// Check the deadline; returns the batch if the oldest item expired.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<T>> {
        match self.oldest {
            Some(t0) if now.duration_since(t0) >= self.cfg.max_wait && !self.pending.is_empty() => {
                self.take()
            }
            _ => None,
        }
    }

    /// Time until the current batch's deadline (drives recv_timeout).
    pub fn deadline_in(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t0| {
            let elapsed = now.duration_since(t0);
            self.cfg.max_wait.saturating_sub(elapsed)
        })
    }

    /// Flush whatever is pending (shutdown path).
    pub fn take(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest = None;
            return None;
        }
        self.oldest = None;
        Some(std::mem::take(&mut self.pending))
    }
}

/// Coalesces *compatible* conv requests — same `ConvOp` (shape AND
/// stride/pad/groups) — into micro-batches under one latency budget: a
/// keyed family of `Batcher`s sharing one `BatchConfig`.  Incompatible
/// ops ride in separate lanes and never delay each other.
#[derive(Debug)]
pub struct ConvCoalescer<T> {
    cfg: BatchConfig,
    lanes: HashMap<ConvOp, Batcher<T>>,
}

impl<T> ConvCoalescer<T> {
    pub fn new(cfg: BatchConfig) -> ConvCoalescer<T> {
        ConvCoalescer { cfg, lanes: HashMap::new() }
    }

    /// Pending requests across all lanes.
    pub fn len(&self) -> usize {
        self.lanes.values().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.values().all(|b| b.is_empty())
    }

    /// Add a request to its op's lane; returns that lane's batch if
    /// this request closed it (size `max_batch` reached).
    pub fn push(&mut self, op: ConvOp, item: T, now: Instant) -> Option<(ConvOp, Vec<T>)> {
        let cfg = self.cfg;
        let lane = self.lanes.entry(op).or_insert_with(|| Batcher::new(cfg));
        lane.push(item, now).map(|batch| (op, batch))
    }

    /// Flush every lane whose oldest request has exceeded the budget.
    pub fn poll(&mut self, now: Instant) -> Vec<(ConvOp, Vec<T>)> {
        let mut out = Vec::new();
        for (p, lane) in self.lanes.iter_mut() {
            if let Some(batch) = lane.poll(now) {
                out.push((*p, batch));
            }
        }
        out
    }

    /// Earliest deadline across lanes (drives the queue thread's
    /// recv_timeout, alongside the CNN batcher's own deadline).
    pub fn deadline_in(&self, now: Instant) -> Option<Duration> {
        self.lanes.values().filter_map(|b| b.deadline_in(now)).min()
    }

    /// Flush everything (shutdown path).
    pub fn take_all(&mut self) -> Vec<(ConvOp, Vec<T>)> {
        let mut out = Vec::new();
        for (p, lane) in self.lanes.iter_mut() {
            if let Some(batch) = lane.take() {
                out.push((*p, batch));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, wait_ms: u64) -> BatchConfig {
        BatchConfig { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(cfg(3, 1000));
        let t = Instant::now();
        assert!(b.push(1, t).is_none());
        assert!(b.push(2, t).is_none());
        let batch = b.push(3, t).expect("batch closed at max");
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let mut b = Batcher::new(cfg(8, 5));
        let t0 = Instant::now();
        b.push(1, t0);
        b.push(2, t0);
        assert!(b.poll(t0).is_none(), "deadline not reached");
        let later = t0 + Duration::from_millis(6);
        assert_eq!(b.poll(later).unwrap(), vec![1, 2]);
    }

    #[test]
    fn deadline_counts_from_oldest_item() {
        let mut b = Batcher::new(cfg(8, 10));
        let t0 = Instant::now();
        b.push(1, t0);
        b.push(2, t0 + Duration::from_millis(8)); // newer item must not reset
        assert!(b.poll(t0 + Duration::from_millis(10)).is_some());
    }

    #[test]
    fn deadline_in_shrinks() {
        let mut b = Batcher::new(cfg(8, 10));
        let t0 = Instant::now();
        assert!(b.deadline_in(t0).is_none());
        b.push(1, t0);
        let d = b.deadline_in(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn take_flushes_and_resets() {
        let mut b = Batcher::new(cfg(8, 10));
        assert!(b.take().is_none());
        b.push(7, Instant::now());
        assert_eq!(b.take().unwrap(), vec![7]);
        assert!(b.take().is_none());
        assert!(b.deadline_in(Instant::now()).is_none());
    }

    #[test]
    fn empty_poll_never_fires() {
        let mut b: Batcher<i32> = Batcher::new(cfg(2, 0));
        assert!(b.poll(Instant::now() + Duration::from_secs(1)).is_none());
    }

    use crate::conv::ConvProblem;

    fn p1() -> ConvOp {
        ConvOp::dense(ConvProblem::multi(8, 14, 16, 3))
    }

    fn p2() -> ConvOp {
        // a non-dense op coalesces in its own lane, keyed by the FULL op
        ConvOp::strided(ConvProblem::multi(8, 14, 16, 3), 2, 1)
    }

    #[test]
    fn coalescer_groups_by_problem_only() {
        let mut c: ConvCoalescer<i32> = ConvCoalescer::new(cfg(2, 1000));
        let t = Instant::now();
        assert!(c.push(p1(), 1, t).is_none());
        assert!(c.push(p2(), 2, t).is_none(), "different op params: separate lane");
        assert_eq!(c.len(), 2);
        let (p, batch) = c.push(p1(), 3, t).expect("p1 lane closed at max");
        assert_eq!(p, p1());
        assert_eq!(batch, vec![1, 3]);
        assert_eq!(c.len(), 1, "p2 still pending");
    }

    #[test]
    fn coalescer_poll_flushes_expired_lanes() {
        let mut c: ConvCoalescer<i32> = ConvCoalescer::new(cfg(8, 5));
        let t0 = Instant::now();
        c.push(p1(), 1, t0);
        c.push(p2(), 2, t0 + Duration::from_millis(4));
        assert!(c.poll(t0).is_empty());
        let fired = c.poll(t0 + Duration::from_millis(6));
        assert_eq!(fired.len(), 1, "only p1's lane expired");
        assert_eq!(fired[0], (p1(), vec![1]));
        let late = c.poll(t0 + Duration::from_millis(10));
        assert_eq!(late, vec![(p2(), vec![2])]);
        assert!(c.is_empty());
    }

    #[test]
    fn coalescer_deadline_is_earliest_lane() {
        let mut c: ConvCoalescer<i32> = ConvCoalescer::new(cfg(8, 10));
        let t0 = Instant::now();
        assert!(c.deadline_in(t0).is_none());
        c.push(p1(), 1, t0);
        c.push(p2(), 2, t0 + Duration::from_millis(6));
        let d = c.deadline_in(t0 + Duration::from_millis(8)).unwrap();
        assert!(d <= Duration::from_millis(2), "p1's lane expires first: {d:?}");
    }

    #[test]
    fn coalescer_take_all_flushes_every_lane() {
        let mut c: ConvCoalescer<i32> = ConvCoalescer::new(cfg(8, 1000));
        let t = Instant::now();
        c.push(p1(), 1, t);
        c.push(p1(), 2, t);
        c.push(p2(), 3, t);
        let mut all = c.take_all();
        all.sort_by_key(|(_, b)| b.len());
        assert_eq!(all, vec![(p2(), vec![3]), (p1(), vec![1, 2])]);
        assert!(c.is_empty());
        assert!(c.take_all().is_empty());
    }
}
