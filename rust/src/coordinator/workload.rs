//! Workload generation for the serving benches: Poisson arrivals and
//! mixed request streams — the traffic model behind the e2e experiments
//! (EXPERIMENTS.md) and `examples/batch_serving.rs`.

use std::time::Duration;

use crate::conv::ConvOp;
use crate::runtime::Tensor;
use crate::util::rng::Rng;

use super::request::Payload;

/// Arrival process for synthetic load.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// all requests at t = 0 (closed-loop burst)
    Burst,
    /// Poisson with the given mean rate (req/s) — exact exponential
    /// gaps, valid at any rate (a 0.1 req/s stream really does idle ~10 s
    /// between requests)
    Poisson { rate: f64 },
    /// Poisson with gaps clamped to `cap` — the seed's implicit 1 s
    /// clamp made explicit and configurable, for load generators that
    /// must bound worst-case idle time.  The clamp truncates the
    /// exponential tail, so the realized rate exceeds `rate` once
    /// 1/rate approaches `cap`; use plain `Poisson` when the rate
    /// itself is under test.
    PoissonCapped { rate: f64, cap: Duration },
    /// fixed inter-arrival gap
    Uniform { gap: Duration },
}

impl Arrivals {
    /// Inter-arrival delay before the next request.
    pub fn next_gap(&self, rng: &mut Rng) -> Duration {
        match *self {
            Arrivals::Burst => Duration::ZERO,
            Arrivals::Poisson { rate } => Duration::from_secs_f64(exp_gap(rng, rate)),
            Arrivals::PoissonCapped { rate, cap } => {
                Duration::from_secs_f64(exp_gap(rng, rate).min(cap.as_secs_f64()))
            }
            Arrivals::Uniform { gap } => gap,
        }
    }
}

/// Exponential inter-arrival sample: -ln(U)/rate.
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    assert!(rate > 0.0, "non-positive Poisson rate");
    let u = rng.next_f64().max(f64::MIN_POSITIVE);
    -u.ln() / rate
}

/// How much of the stream is raw conv traffic (vs CNN inference), and
/// how that traffic clusters.
#[derive(Clone, Copy, Debug)]
pub struct Mix {
    /// per-DECISION conv trigger rate: each non-burst request rolls
    /// conv with this probability.  With `conv_burst` = 1 this is also
    /// the stream share; with bursts, every trigger emits `conv_burst`
    /// convs, so the realized conv share of the stream rises to
    /// `b·f / (b·f + (1-f))` (e.g. f = 0.5, b = 4 → 80% conv).
    pub conv_fraction: f64,
    /// identical back-to-back conv repeats: when a conv template fires,
    /// the next `conv_burst - 1` requests reuse the SAME problem (fresh
    /// random tensors), modeling the correlated traffic real serving
    /// sees (one client, one layer shape).  The seed's generator drew
    /// every request independently, so the coordinator's same-problem
    /// coalescer had almost nothing to merge; `conv_burst > 1` is what
    /// makes `e2e_serving`'s coalescing rows exercise it.  1 = off.
    pub conv_burst: usize,
}

impl Default for Mix {
    fn default() -> Self {
        Mix { conv_fraction: 0.25, conv_burst: 1 }
    }
}

/// Generates a request stream over a set of conv problem templates.
pub struct Workload {
    pub arrivals: Arrivals,
    pub mix: Mix,
    pub conv_templates: Vec<ConvOp>,
    rng: Rng,
    /// remaining repeats of the current conv burst
    burst_left: usize,
    burst_op: Option<ConvOp>,
}

impl Workload {
    pub fn new(arrivals: Arrivals, mix: Mix, conv_templates: Vec<ConvOp>, seed: u64) -> Self {
        assert!(mix.conv_burst >= 1, "conv_burst must be >= 1");
        Workload {
            arrivals,
            mix,
            conv_templates,
            rng: Rng::new(seed),
            burst_left: 0,
            burst_op: None,
        }
    }

    fn conv_payload(&mut self, op: ConvOp) -> Payload {
        let p = op.core;
        let image = if p.is_single_channel() && op.groups == 1 {
            Tensor::randn(vec![p.wy, p.wx], &mut self.rng)
        } else {
            Tensor::randn(vec![p.c, p.wy, p.wx], &mut self.rng)
        };
        let filters = if p.is_single_channel() && op.groups == 1 {
            Tensor::randn(vec![p.m, p.k, p.k], &mut self.rng)
        } else {
            // grouped filters only read their group's channels
            Tensor::randn(vec![p.m, p.c / op.groups, p.k, p.k], &mut self.rng)
        };
        Payload::Conv { op, image, filters }
    }

    /// Next request payload + the delay to wait before submitting it.
    pub fn next(&mut self) -> (Payload, Duration) {
        let gap = self.arrivals.next_gap(&mut self.rng);
        if self.burst_left > 0 {
            self.burst_left -= 1;
            let op = self.burst_op.expect("burst in progress");
            return (self.conv_payload(op), gap);
        }
        let payload = if !self.conv_templates.is_empty()
            && self.rng.next_f64() < self.mix.conv_fraction
        {
            let op = *self.rng.choose(&self.conv_templates);
            if self.mix.conv_burst > 1 {
                self.burst_left = self.mix.conv_burst - 1;
                self.burst_op = Some(op);
            }
            self.conv_payload(op)
        } else {
            Payload::Cnn { image: Tensor::randn(vec![1, 28, 28], &mut self.rng) }
        };
        (payload, gap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvProblem;

    #[test]
    fn burst_has_zero_gaps() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(Arrivals::Burst.next_gap(&mut rng), Duration::ZERO);
        }
    }

    #[test]
    fn poisson_mean_matches_rate() {
        let mut rng = Rng::new(2);
        let a = Arrivals::Poisson { rate: 1000.0 };
        let mean: f64 =
            (0..20_000).map(|_| a.next_gap(&mut rng).as_secs_f64()).sum::<f64>() / 20_000.0;
        assert!((mean - 1e-3).abs() < 1e-4, "mean gap {mean}");
    }

    #[test]
    fn low_rate_poisson_mean_is_unclamped() {
        // the seed's .min(1.0) clamp pinned every sub-1-req/s stream to a
        // ~1 s mean; the exact sampler must recover 1/rate = 4 s
        let mut rng = Rng::new(21);
        let a = Arrivals::Poisson { rate: 0.25 };
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| a.next_gap(&mut rng).as_secs_f64()).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.2, "mean gap {mean}");
    }

    #[test]
    fn capped_poisson_clamps_and_distorts() {
        let mut rng = Rng::new(22);
        let cap = Duration::from_secs(1);
        let a = Arrivals::PoissonCapped { rate: 0.25, cap };
        let mut mean = 0.0;
        for _ in 0..5_000 {
            let g = a.next_gap(&mut rng);
            assert!(g <= cap);
            mean += g.as_secs_f64() / 5_000.0;
        }
        // truncated at the cap: the mean collapses toward it
        assert!(mean < 1.0, "mean gap {mean}");
    }

    #[test]
    fn uniform_gap_constant() {
        let mut rng = Rng::new(3);
        let a = Arrivals::Uniform { gap: Duration::from_millis(5) };
        assert_eq!(a.next_gap(&mut rng), Duration::from_millis(5));
    }

    #[test]
    fn mix_fraction_respected() {
        let mut w = Workload::new(
            Arrivals::Burst,
            Mix { conv_fraction: 0.5, conv_burst: 1 },
            vec![ConvOp::dense(ConvProblem::multi(4, 8, 4, 3))],
            7,
        );
        let n = 2000;
        let convs = (0..n)
            .filter(|_| matches!(w.next().0, Payload::Conv { .. }))
            .count();
        let frac = convs as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "conv fraction {frac}");
    }

    #[test]
    fn conv_payloads_have_template_shapes() {
        let t = ConvOp::dense(ConvProblem::multi(4, 8, 6, 3));
        let mut w =
            Workload::new(Arrivals::Burst, Mix { conv_fraction: 1.0, conv_burst: 1 }, vec![t], 9);
        for _ in 0..10 {
            let (payload, _) = w.next();
            let Payload::Conv { op, image, filters } = payload else {
                panic!("expected conv")
            };
            assert_eq!(op, t);
            assert_eq!(image.shape, vec![4, 8, 8]);
            assert_eq!(filters.shape, vec![6, 4, 3, 3]);
        }
    }

    #[test]
    fn depthwise_templates_carry_grouped_filter_shapes() {
        let t = ConvOp::depthwise(6, 8, 3, 1);
        let mut w =
            Workload::new(Arrivals::Burst, Mix { conv_fraction: 1.0, conv_burst: 1 }, vec![t], 15);
        let (payload, _) = w.next();
        let Payload::Conv { op, image, filters } = payload else { panic!("expected conv") };
        assert_eq!(op, t);
        assert_eq!(image.shape, vec![6, 8, 8]);
        assert_eq!(filters.shape, vec![6, 1, 3, 3], "M x C/G x K x K");
    }

    #[test]
    fn no_templates_means_all_cnn() {
        let mut w =
            Workload::new(Arrivals::Burst, Mix { conv_fraction: 1.0, conv_burst: 1 }, vec![], 11);
        for _ in 0..10 {
            assert!(matches!(w.next().0, Payload::Cnn { .. }));
        }
    }

    #[test]
    fn conv_burst_emits_identical_back_to_back_templates() {
        // conv_burst = 4: every conv run is 4 consecutive requests with
        // the SAME problem — what the coordinator's coalescer needs to
        // actually merge anything
        let templates = vec![
            ConvOp::dense(ConvProblem::multi(4, 8, 4, 3)),
            ConvOp::strided(ConvProblem::multi(4, 16, 4, 3), 2, 1),
        ];
        let mut w = Workload::new(
            Arrivals::Burst,
            Mix { conv_fraction: 0.5, conv_burst: 4 },
            templates,
            13,
        );
        let mut run_op: Option<ConvOp> = None;
        let mut run_len = 0usize;
        let mut runs = vec![];
        for _ in 0..2000 {
            match w.next().0 {
                Payload::Conv { op, .. } => {
                    if run_op == Some(op) {
                        run_len += 1;
                    } else {
                        if run_len > 0 {
                            runs.push(run_len);
                        }
                        run_op = Some(op);
                        run_len = 1;
                    }
                }
                _ => {
                    if run_len > 0 {
                        runs.push(run_len);
                    }
                    run_op = None;
                    run_len = 0;
                }
            }
        }
        assert!(!runs.is_empty());
        // every completed run is a multiple of the burst length (two
        // back-to-back bursts of the same template concatenate)
        assert!(
            runs.iter().all(|&r| r % 4 == 0),
            "non-multiple-of-burst runs: {runs:?}"
        );
    }

    #[test]
    fn burst_of_one_is_the_seed_behavior() {
        let p = ConvOp::dense(ConvProblem::multi(4, 8, 4, 3));
        let mut a = Workload::new(
            Arrivals::Burst,
            Mix { conv_fraction: 0.5, conv_burst: 1 },
            vec![p],
            21,
        );
        let mut b = Workload::new(Arrivals::Burst, Mix::default(), vec![p], 21);
        b.mix.conv_fraction = 0.5;
        for _ in 0..200 {
            assert_eq!(a.next().0.kind_str(), b.next().0.kind_str());
        }
    }

    #[test]
    #[should_panic(expected = "conv_burst")]
    fn zero_burst_rejected() {
        let _ = Workload::new(
            Arrivals::Burst,
            Mix { conv_fraction: 0.5, conv_burst: 0 },
            vec![],
            1,
        );
    }
}
