//! Baseline [1] — Chen et al., "Optimizing Memory Efficiency for
//! Convolution Kernels on Kepler GPUs" (DAC 2017), as an execution plan.
//!
//! The paper builds on [1]'s computation method but fixes two documented
//! weaknesses, which this plan reproduces:
//!
//! * **fixed per-SM assignment**: "[1] fixes the amount of the data
//!   assigned to each SM, which sometimes is not suitable to the small
//!   feature map.  ... the performances are negatively affected when
//!   the feature map size is smaller than 32."  The plan assigns a fixed
//!   FIXED_STRIP_ROWS-row strip per block; maps smaller than
//!   strips x SMs leave SMs idle.
//! * **natural filter segments**: "[1], the filter size is chosen as S
//!   (S = K x K x 4 bytes)" — 36 B for K=3, 4 B for K=1: non-coalesced
//!   global accesses (§3.2), unlike our 32/64-B stride-fixed segments.

use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::memory::segment_efficiency;
use crate::gpusim::pipeline::combined_efficiency;
use crate::gpusim::{Epilogue, GpuSpec, KernelPlan, Loading, Round};

/// The fixed feature-map strip height [1] assigns per block regardless of
/// the input size (their tuning for >= 32-px maps).
pub const FIXED_STRIP_ROWS: usize = 32;

/// Filters applied in parallel — [1] prioritizes parallelism ("higher
/// parallelism comes first").
pub const DAC17_M_PRIME: usize = 64;

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Build [1]'s plan for a (single- or multi-channel) problem.
pub fn plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    assert!(p.valid());
    // fixed 32x32 output-tile assignment (y-strips x x-strips), fixed M'
    // — tuned for >= 32-px maps; everything smaller starves the chip
    let y_strips = ceil_div(p.wy, FIXED_STRIP_ROWS);
    let x_strips = ceil_div(p.wx, FIXED_STRIP_ROWS);
    let m_prime = DAC17_M_PRIME.min(p.m);
    let groups = ceil_div(p.m, m_prime);
    let blocks = y_strips * x_strips * groups;
    // the under-utilization the paper exploits: blocks < SMs on small maps
    let sms_active = blocks.min(spec.sm_count as usize) as u32;

    // segment = one whole filter: K*K*4 bytes (odd, non-coalesced)
    let s_bytes = p.k * p.k * BYTES_F32;
    let segs = p.c; // walk the channel dimension one filter at a time
    let filter_bytes = (s_bytes * m_prime) as f64;
    let strip_rows = FIXED_STRIP_ROWS.min(p.wy);
    let strip_cols = FIXED_STRIP_ROWS.min(p.wx);
    let map_bytes_per_seg =
        ((strip_rows + p.k - 1) * (strip_cols + p.k - 1) * BYTES_F32) as f64;
    let eff = combined_efficiency(&[
        (filter_bytes, segment_efficiency(s_bytes)),
        (map_bytes_per_seg, segment_efficiency((strip_cols * BYTES_F32).min(128))),
    ]);
    let fma_per_round =
        (m_prime * p.k * p.k * strip_rows * strip_cols.min(p.ox())) as f64;

    let rounds_per_sm = ceil_div(blocks * segs, sms_active as usize);
    let rounds: Vec<Round> = (0..rounds_per_sm)
        .map(|_| Round::with_efficiency(filter_bytes + map_bytes_per_seg, 128, eff, fma_per_round))
        .collect();

    let smem = 2 * (s_bytes * m_prime
        + (strip_rows + p.k - 1) * (strip_cols + p.k - 1) * BYTES_F32);

    KernelPlan {
        name: format!("dac17[strip={} M'={}]", FIXED_STRIP_ROWS, m_prime),
        rounds,
        sms_active,
        threads_per_sm: 1024,
        compute_efficiency: 0.9,
        output_bytes: (p.out_elems() * BYTES_F32) as f64,
        smem_bytes_per_sm: (smem as u32).min(spec.shared_mem_bytes),
        total_fma: p.fma_ops() as f64,
        launch_overhead_cycles: 4_000.0,
        stages: 2,
        loading: Loading::Cyclic,
        stage_bytes: 0,
        epilogue: Epilogue::None,
        epilogue_read_bytes: 0.0,
        filter_resident_smem_bytes: 0,
        filter_l2_footprint_bytes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, simulate};

    #[test]
    fn small_maps_underutilize_sms() {
        // the paper's critique: W < 32 -> one strip; with M = 64 only one
        // block exists -> 1 of 28 SMs busy
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 14, 64, 3);
        let pl = plan(&p, &g);
        assert_eq!(pl.sms_active, 1, "{}", pl.name);
        let big = ConvProblem::multi(256, 224, 64, 3);
        assert!(plan(&big, &g).sms_active >= 7);
    }

    #[test]
    fn filter_segments_non_coalesced() {
        // K=3: 36-B segments -> combined efficiency well below ours
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 56, 256, 3);
        let pl = plan(&p, &g);
        let eff = pl.rounds[0].eff_override.unwrap();
        assert!(eff < 0.95, "eff={eff}");
    }

    #[test]
    fn simulates_across_map_sizes() {
        let g = gtx_1080ti();
        for w in [7, 14, 28, 56, 112, 224] {
            let p = ConvProblem::multi(128, w, 128, 3);
            let r = simulate(&g, &plan(&p, &g));
            assert!(r.seconds.is_finite() && r.seconds > 0.0, "W={w}");
        }
    }

    #[test]
    fn efficiency_collapses_below_32px() {
        // the Fig.-4/5 motivation: [1]'s efficiency on 14px maps is far
        // below its 224px efficiency
        let g = gtx_1080ti();
        let small = simulate(&g, &plan(&ConvProblem::multi(256, 14, 64, 3), &g));
        let large = simulate(&g, &plan(&ConvProblem::multi(256, 224, 64, 3), &g));
        assert!(
            large.efficiency > 4.0 * small.efficiency,
            "large={} small={}",
            large.efficiency,
            small.efficiency
        );
    }
}
