//! Baseline — Winograd F(2x2, 3x3) [8], the paper's §1 category 3, as an
//! execution plan (numerics: python/compile/kernels/winograd.py).
//!
//! Per 2x2 output tile and channel: 16 transform-domain multiplies
//! replace 36 direct FMAs (2.25x fewer "useful" multiplies), but
//!  * the input transform reads overlapping 4x4 tiles (4x the pixels of
//!    the 2x2 output they produce),
//!  * the in/out transforms cost ~(32 + 24) adds per tile per channel
//!    (executed on the same FMA pipes), and
//!  * transformed filters occupy 16/9 the space of the originals.
//! cuDNN's winograd path wins on large C*K=3 layers and loses where the
//! transform overhead dominates — this plan reproduces that balance so
//! the taxonomy bench can place the paper's kernels against it.

use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::memory::segment_efficiency;
use crate::gpusim::pipeline::combined_efficiency;
use crate::gpusim::{Epilogue, GpuSpec, KernelPlan, Loading, Round};

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Filters processed in parallel per block (typical winograd kernels).
pub const WINO_M_PRIME: usize = 32;
/// Channel depth per accumulation round.
pub const WINO_C_SEG: usize = 8;

/// Build the Winograd plan. Only valid for K = 3.
pub fn plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    assert!(p.valid());
    assert_eq!(p.k, 3, "Winograd F(2x2,3x3) requires K=3");
    let tiles_y = ceil_div(p.oy(), 2);
    let tiles_x = ceil_div(p.ox(), 2);
    let tiles = tiles_y * tiles_x;

    let m_prime = WINO_M_PRIME.min(p.m);
    let c_seg = WINO_C_SEG.min(p.c);
    let groups = ceil_div(p.m, m_prime);
    // one block owns a 32x32-pixel patch of tiles (16x16 tiles)
    let tile_patch = 16 * 16;
    let patches = ceil_div(tiles, tile_patch);
    let blocks = groups * patches;
    let sms_active = blocks.min(spec.sm_count as usize) as u32;
    let segs = ceil_div(p.c, c_seg);

    let tiles_per_block = tiles.min(tile_patch);
    // loads per round: each input pixel is read once into shared memory
    // and the overlapping 4x4 tiles are formed on chip — ~4 new pixels
    // per 2x2 tile plus the 2-pixel halo (~25% on a 32-px patch)
    let map_bytes = (tiles_per_block * 5 * c_seg * BYTES_F32) as f64;
    let filter_bytes = (m_prime * c_seg * 16 * BYTES_F32) as f64 / patches.min(16) as f64;
    let eff = combined_efficiency(&[
        (map_bytes, segment_efficiency(128)),
        (filter_bytes, segment_efficiency(64)),
    ]);

    // compute per round: 16 multiplies per (tile, m, c) + transform adds
    // (amortized: input transform per (tile, c): 32 ops; output transform
    // per (tile, m): 24 ops / segs)
    let mults = (tiles_per_block * m_prime * c_seg * 16) as f64;
    let in_transform = (tiles_per_block * c_seg * 32) as f64;
    let out_transform = (tiles_per_block * m_prime * 24) as f64 / segs as f64;
    let fma_per_round = mults + in_transform + out_transform;

    let rounds_per_sm = ceil_div(blocks * segs, sms_active as usize);
    let rounds: Vec<Round> = (0..rounds_per_sm)
        .map(|_| Round::with_efficiency(map_bytes + filter_bytes, 128, eff, fma_per_round))
        .collect();

    let smem = 2 * ((tiles_per_block.min(64) * 16 * c_seg + m_prime * c_seg * 16) * BYTES_F32);

    KernelPlan {
        name: format!("winograd[F(2x2,3x3) M'={m_prime}]"),
        rounds,
        sms_active,
        threads_per_sm: 1024,
        compute_efficiency: 0.85, // transform shuffles cost issue slots
        output_bytes: (p.out_elems() * BYTES_F32) as f64,
        smem_bytes_per_sm: (smem as u32).min(spec.shared_mem_bytes / 2),
        total_fma: p.fma_ops() as f64, // report against the direct-conv work
        launch_overhead_cycles: 4_000.0,
        stages: 2,
        loading: Loading::Cyclic,
        stage_bytes: 0,
        epilogue: Epilogue::None,
        epilogue_read_bytes: 0.0,
        filter_resident_smem_bytes: 0,
        filter_l2_footprint_bytes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, simulate};

    #[test]
    fn simulates_on_k3_layers() {
        let g = gtx_1080ti();
        for (c, w, m) in [(64, 56, 64), (256, 14, 256), (512, 7, 512)] {
            let p = ConvProblem::multi(c, w, m, 3);
            let r = simulate(&g, &plan(&p, &g));
            assert!(r.seconds.is_finite() && r.seconds > 0.0, "{}", p.label());
        }
    }

    #[test]
    #[should_panic(expected = "K=3")]
    fn rejects_non_k3() {
        let g = gtx_1080ti();
        plan(&ConvProblem::multi(64, 56, 64, 5), &g);
    }

    #[test]
    fn beats_direct_flops_on_big_k3_layers() {
        // the 2.25x multiply reduction should show as >1 apparent
        // efficiency headroom vs a same-FLOPs direct schedule on large
        // compute-bound layers: winograd's cycles per useful FMA < 1/peak
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 56, 256, 3);
        let r = simulate(&g, &plan(&p, &g));
        // direct-conv peak efficiency ceiling is compute_efficiency (0.9);
        // winograd can exceed it because total_fma counts direct-conv work
        assert!(r.efficiency > 0.9, "efficiency {}", r.efficiency);
    }

    #[test]
    fn transform_overhead_hurts_small_layers() {
        let g = gtx_1080ti();
        let small = ConvProblem::multi(16, 7, 16, 3);
        let big = ConvProblem::multi(256, 56, 256, 3);
        let e_small = simulate(&g, &plan(&small, &g)).efficiency;
        let e_big = simulate(&g, &plan(&big, &g)).efficiency;
        assert!(e_big > 2.0 * e_small, "big {} small {}", e_big, e_small);
    }
}
