//! Comparison baselines, each as a `KernelPlan` under the same simulator
//! as our kernels (like-for-like, the paper's own framing):
//!
//! * `cudnn_proxy` — Implicit GEMM [12], the Figs. 4/5 comparison target;
//! * `dac17` — Chen et al. [1]: fixed per-SM assignment + natural filter
//!   segments (the §4 "4x at K=3" comparison);
//! * `tan128` — Tan et al. [16]: 128-B segments, small M' (the §3.2
//!   trade-off discussion);
//! * `winograd` — F(2x2,3x3) [8] and `fft_conv` — FFT [13]: the §1
//!   taxonomy's categories 3 and 2, so all four convolution families are
//!   executable (numerics in python/compile/kernels/, timing here).

pub mod cudnn_proxy;
pub mod dac17;
pub mod fft_conv;
pub mod tan128;
pub mod winograd;
