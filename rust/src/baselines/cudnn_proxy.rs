//! cuDNN proxy — the Implicit-GEMM algorithm [12] as an execution plan.
//!
//! The comparison target of Figs. 4/5.  Implicit GEMM treats the
//! convolution as  C[M, Oy*Ox] = A[M, C*K*K] x B[C*K*K, Oy*Ox]  where B
//! (the im2col matrix) is never materialized in global memory: each
//! threadblock gathers its B-tile into shared memory on the fly.
//!
//! The model captures the three structural costs the paper's kernels
//! avoid — each is an explicitly documented property of tiled GEMM, not
//! a tuning fudge:
//!
//! * **k-padding**: the k-loop advances in TK-element steps; a GEMM
//!   depth of C*K*K that is not a multiple of TK burns whole steps on
//!   padding (for single-channel K=1 the depth is 1 -> 8x waste at
//!   TK=8 — the paper's biggest wins are exactly there);
//! * **tile quantization**: ceil(M/TM) x ceil(Oy*Ox/TN) blocks compute
//!   full tiles regardless of the useful fraction (25-px outputs of the
//!   7x7 maps of Fig. 5 waste most of a 128-wide tile);
//! * **im2col gather**: B-tile rows are output-row segments of length
//!   Ox, so the fetch segment is min(Ox, TN) pixels — short and
//!   misaligned for small maps, full 128-B only for large ones.
//!
//! Like cudnnFindBestAlgorithm, the proxy tries several tile shapes and
//! keeps the fastest.

use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::memory::segment_efficiency;
use crate::gpusim::pipeline::combined_efficiency;
use crate::gpusim::{simulate, Epilogue, GpuSpec, KernelPlan, Loading, Round};

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Implicit-GEMM plan for a fixed (TM, TN, TK) tile shape.
pub fn plan_with_tiles(
    p: &ConvProblem,
    spec: &GpuSpec,
    tm: usize,
    tn: usize,
    tk: usize,
) -> KernelPlan {
    assert!(p.valid());
    let m_g = p.m; // GEMM M
    let n_g = p.oy() * p.ox(); // GEMM N
    let k_g = p.c * p.k * p.k; // GEMM K (depth)

    let m_tiles = ceil_div(m_g, tm);
    let n_tiles = ceil_div(n_g, tn);
    let k_steps = ceil_div(k_g, tk);
    // v7.1's implicit GEMM runs one block per output tile — it has no
    // split-K reduction (that arrived in later cuDNN releases), so small
    // outputs cannot fill the chip: a third documented small-map weakness
    let blocks = m_tiles * n_tiles;

    // per k-step loads for one block, with L2 reuse: co-resident blocks in
    // the same GEMM row (column) re-read the same A (B) tile — it leaves
    // DRAM once per wave
    let wave = blocks.min(2 * spec.sm_count as usize).max(1);
    let a_readers = (wave as f64 / m_tiles as f64).clamp(1.0, n_tiles as f64);
    let b_readers = (wave as f64 / n_tiles as f64).clamp(1.0, m_tiles as f64);
    let a_bytes = (tm * tk * BYTES_F32) as f64 / a_readers; // filters (Fig. 1(b) layout)
    let b_bytes = (tk * tn * BYTES_F32) as f64 / b_readers; // im2col gather
    // B-tile gather segment: one output-row piece = min(Ox, TN) pixels,
    // starts misaligned for K>1 (window offsets j=1..K-1 shift the base)
    let b_seg_px = p.ox().min(tn);
    let mut b_eff = segment_efficiency(b_seg_px * BYTES_F32);
    if p.k > 1 {
        b_eff *= 0.85; // misaligned window starts within rows
    }
    let a_eff = segment_efficiency((tk * BYTES_F32).min(128));
    let eff = combined_efficiency(&[(a_bytes, a_eff), (b_bytes, b_eff)]);

    // every k-step computes the full tile, padded or not
    let fma_per_step = (tm * tn * tk) as f64;

    let sms_active = blocks.min(spec.sm_count as usize) as u32;
    let rounds_per_sm = ceil_div(blocks * k_steps, sms_active as usize);
    let rounds: Vec<Round> = (0..rounds_per_sm)
        .map(|_| Round::with_efficiency(a_bytes + b_bytes, 128, eff, fma_per_step))
        .collect();

    // double-buffered A+B tiles in shared memory
    let smem = 2 * ((tm * tk + tk * tn) * BYTES_F32);

    KernelPlan {
        name: format!("cudnn-igemm[{}x{}x{}]", tm, tn, tk),
        rounds,
        sms_active,
        threads_per_sm: 1024,
        // the B-tile gather spends issue slots on im2col index arithmetic
        // (div/mod per element) that the direct kernels do not pay — the
        // paper's §3 point about "clock cycles spent issuing these read
        // instructions"
        compute_efficiency: 0.82,
        output_bytes: (p.out_elems() * BYTES_F32) as f64,
        smem_bytes_per_sm: smem as u32,
        total_fma: p.fma_ops() as f64, // useful work only; padding burns cycles, not FLOPs
        // cuDNN API path: descriptor checks, heuristic dispatch and (for
        // the GEMM-family algorithms) staging kernels — ~8 µs vs the
        // ~2.7 µs bare kernel launch of the direct kernels
        launch_overhead_cycles: 12_000.0,
        stages: 2,
        loading: Loading::Cyclic,
        stage_bytes: 0,
        epilogue: Epilogue::None,
        epilogue_read_bytes: 0.0,
        filter_resident_smem_bytes: 0,
        filter_l2_footprint_bytes: 0,
    }
}

/// Tile shapes the proxy searches — the igemm variants cuDNN v7 ships.
pub const TILE_SHAPES: [(usize, usize, usize); 4] =
    [(128, 128, 8), (64, 128, 8), (64, 64, 8), (32, 64, 8)];

/// cudnnFindBestAlgorithm stand-in: fastest tile shape under the simulator.
pub fn plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    TILE_SHAPES
        .iter()
        .map(|&(tm, tn, tk)| plan_with_tiles(p, spec, tm, tn, tk))
        .min_by(|a, b| {
            simulate(spec, a).seconds.partial_cmp(&simulate(spec, b).seconds).unwrap()
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::{fig4_suite, fig5_suite};
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn simulates_on_both_figure_suites() {
        let g = gtx_1080ti();
        for p in fig4_suite().into_iter().chain(fig5_suite()) {
            let r = simulate(&g, &plan(&p, &g));
            assert!(r.seconds > 0.0 && r.seconds.is_finite(), "{}", p.label());
        }
    }

    #[test]
    fn k_padding_hurts_single_channel() {
        // C=1, K=1: GEMM depth 1 vs TK=8 — the padded schedule burns ~8x
        // the cycles of the useful work; efficiency collapses.
        let g = gtx_1080ti();
        let shallow = ConvProblem::single(224, 64, 1);
        let deep = ConvProblem::multi(512, 14, 64, 3); // depth 4608
        let r_shallow = simulate(&g, &plan(&shallow, &g));
        let r_deep = simulate(&g, &plan(&deep, &g));
        assert!(
            r_deep.efficiency > 3.0 * r_shallow.efficiency,
            "deep {} shallow {}",
            r_deep.efficiency,
            r_shallow.efficiency
        );
    }

    #[test]
    fn tile_quantization_hurts_small_maps() {
        // same depth & filters, 7x7 vs 56x56 maps: the small map wastes
        // most of each N-tile -> much lower efficiency
        let g = gtx_1080ti();
        let small = ConvProblem::multi(256, 7, 128, 3);
        let large = ConvProblem::multi(256, 56, 128, 3);
        let e_small = simulate(&g, &plan(&small, &g)).efficiency;
        let e_large = simulate(&g, &plan(&large, &g)).efficiency;
        assert!(e_large > 1.5 * e_small, "large {} small {}", e_large, e_small);
    }

    #[test]
    fn best_tile_beats_or_ties_all_fixed_tiles() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(128, 28, 128, 3);
        let best = simulate(&g, &plan(&p, &g)).seconds;
        for &(tm, tn, tk) in &TILE_SHAPES {
            let t = simulate(&g, &plan_with_tiles(&p, &g, tm, tn, tk)).seconds;
            assert!(best <= t * 1.0001);
        }
    }

    #[test]
    fn small_map_picks_smaller_tiles() {
        // the proxy's algorithm search should behave like cudnn's: tiny
        // outputs favour 32/64-wide tiles
        let g = gtx_1080ti();
        let p = ConvProblem::multi(512, 7, 512, 3); // N_g = 25
        let chosen = plan(&p, &g);
        assert!(
            chosen.name.contains("32x") || chosen.name.contains("64x64") || chosen.name.contains("[64x"),
            "{}",
            chosen.name
        );
    }

    #[test]
    fn scheduled_fma_covers_padded_gemm() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(28, 512, 1); // heavy padding case
        let pl = plan_with_tiles(&p, &g, 128, 128, 8);
        let scheduled: f64 =
            pl.rounds.iter().map(|r| r.fma_ops).sum::<f64>() * pl.sms_active as f64;
        // padded schedule >= 8x the useful work (depth 1 padded to 8)
        assert!(scheduled >= 7.0 * p.fma_ops() as f64, "{scheduled}");
    }
}
