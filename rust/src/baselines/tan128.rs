//! Baseline [16] — Tan et al. (SC'11) fetch discipline applied to
//! convolution: extend the filter segment S to 128 bytes for the highest
//! memory throughput, at the cost of parallelism.
//!
//! §3.2: "[16] tried to solve this problem by extending S to 128-bytes.
//! ... With this larger S, M' has to be kept small because of the
//! limited size of on-chip memory, and smaller M' means less
//! parallelism.  In [1], higher parallelism comes first, while in [16],
//! lower access delay has a higher priority."
//!
//! The plan is simply the stride-fixed schedule at S = 128 with M'
//! capped by the same S_shared/2 double-buffer constraint — i.e. the
//! other end of the trade-off our §3.2 method balances.

use crate::analytic::multi::{working_set_bytes, StrideFixedChoice, wy_prime};
use crate::conv::ConvProblem;
use crate::gpusim::{GpuSpec, KernelPlan};
use crate::plans::stride_fixed::plan_with_choice;

/// [16]'s segment size.
pub const S_BYTES: usize = 128;

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Build the S=128 plan: maximal coalescing, M' squeezed by on-chip space.
pub fn plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    assert!(p.valid());
    let out_px = p.oy() * p.ox();
    let map_px = ceil_div(out_px, 32) * 32;
    let wx_prime = if map_px <= 256 { map_px } else { 128 };
    let half = spec.shared_mem_bytes as usize / 2;

    // [16] keeps the fetch wide and shrinks parallelism to fit: the
    // largest M' whose double-buffered working set fits half the shared
    // memory, further halved because the 128-B segments quadruple the
    // filter-buffer footprint relative to S=32 at equal M'.
    let mut m_prime = p.m.min(16);
    while m_prime > 1 && working_set_bytes(S_BYTES, wx_prime, m_prime, p.k) > half {
        m_prime /= 2;
    }

    let c = StrideFixedChoice {
        s_bytes: S_BYTES,
        wx_prime,
        m_prime,
        wy_prime: wy_prime(S_BYTES, p.k),
        smem_bytes: working_set_bytes(S_BYTES, wx_prime, m_prime, p.k),
        hides_latency: false,
    };
    let mut plan = plan_with_choice(p, spec, &c);
    plan.name = format!("tan128[M'={}]", m_prime);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, simulate};
    use crate::plans::stride_fixed;

    #[test]
    fn m_prime_small() {
        // the point of the baseline: wide fetches, few parallel filters
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 56, 256, 3);
        let pl = plan(&p, &g);
        assert!(pl.name.contains("M'=16") || pl.name.contains("M'=8"), "{}", pl.name);
    }

    #[test]
    fn ours_loads_fewer_map_bytes() {
        // larger M' amortizes the map stream over more filters: our
        // FMA-per-byte must exceed [16]'s
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 56, 256, 3);
        let ours = stride_fixed::plan(&p, &g);
        let theirs = plan(&p, &g);
        assert!(
            ours.fma_per_byte() > 1.5 * theirs.fma_per_byte(),
            "ours={} theirs={}",
            ours.fma_per_byte(),
            theirs.fma_per_byte()
        );
    }

    #[test]
    fn ours_never_slower_and_wins_where_bandwidth_binds() {
        // the §3.2 trade-off resolved in our favour: where the problem is
        // compute-rich both schedules saturate the cores (ties allowed);
        // where DRAM bandwidth binds (K=1, small maps) [16]'s small M'
        // multiplies the map traffic and loses clearly.
        let g = gtx_1080ti();
        let mut speedups = vec![];
        for p in [
            ConvProblem::multi(256, 56, 256, 3),  // compute-rich: tie allowed
            ConvProblem::multi(128, 112, 128, 1), // K=1: smem crushes tan's M'
            ConvProblem::multi(256, 14, 256, 1),  // bandwidth-bound small map
            ConvProblem::multi(256, 28, 256, 1),
        ] {
            let t_ours = simulate(&g, &stride_fixed::plan(&p, &g)).seconds;
            let t_tan = simulate(&g, &plan(&p, &g)).seconds;
            assert!(t_ours <= 1.05 * t_tan, "{}: ours={} tan={}", p.label(), t_ours, t_tan);
            speedups.push(t_tan / t_ours);
        }
        let best = speedups.iter().cloned().fold(0.0, f64::max);
        assert!(best > 1.2, "no case where ours wins clearly: {speedups:?}");
    }

    #[test]
    fn simulates_cleanly() {
        let g = gtx_1080ti();
        for p in crate::conv::suites::fig5_suite() {
            let r = simulate(&g, &plan(&p, &g));
            assert!(r.seconds.is_finite() && r.seconds > 0.0);
        }
    }
}
