//! Baseline — FFT convolution [13], the paper's §1 category 2, as an
//! execution plan (numerics: python/compile/kernels/fft_conv.py).
//!
//! Cost model: 2-D real FFTs of every map channel and every filter
//! channel (zero-padded to the map size — the classic inefficiency for
//! small K), a complex pointwise multiply-accumulate over channels in
//! the frequency domain, and inverse FFTs per output map.  FLOP counts
//! use the standard 2.5 N log2 N per real 1-D FFT of length N.
//!
//! For K in {1,3,5} the padded filter transforms dominate — which is
//! exactly why neither the paper nor cuDNN's heuristics pick FFT in this
//! regime; the taxonomy bench makes that visible.

use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::{Epilogue, GpuSpec, KernelPlan, Loading, Round};

/// FLOPs of a 2-D real FFT over an H x W grid (row+column passes).
fn fft2_flops(h: usize, w: usize) -> f64 {
    let row = 2.5 * w as f64 * (w as f64).log2();
    let col = 2.5 * h as f64 * (h as f64).log2();
    h as f64 * row + w as f64 * col
}

/// Build the FFT-convolution plan.
pub fn plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    assert!(p.valid());
    let (h, w) = (p.wy, p.wx);
    let spec_elems = h * (w / 2 + 1); // rfft2 output size

    // total work (in FMA-equivalents = FLOPs/2)
    let fwd_maps = p.c as f64 * fft2_flops(h, w);
    let fwd_filters = (p.m * p.c) as f64 * fft2_flops(h, w); // zero-padded!
    let pointwise = (p.m * p.c * spec_elems) as f64 * 8.0; // complex MAC
    let inverse = p.m as f64 * fft2_flops(h, w);
    let total_flops = fwd_maps + fwd_filters + pointwise + inverse;
    let total_fma_cost = total_flops / 2.0;

    // traffic: maps + filters in; spectra spill to HBM between stages
    // (FFT stages are bandwidth-heavy; assume one spill round-trip)
    let bytes_in = (p.map_elems() + p.filter_elems()) * BYTES_F32;
    let spectra = (p.c + p.m * p.c + p.m) * spec_elems * 2 * BYTES_F32;
    let total_bytes = (bytes_in + 2 * spectra) as f64;

    // express as uniform rounds across all SMs (FFT kernels saturate the
    // chip; butterflies are strided but libraries pad to avoid the worst)
    let sms = spec.sm_count as usize;
    let rounds_n = 64usize;
    let per_round_bytes = total_bytes / (sms * rounds_n) as f64;
    let per_round_fma = total_fma_cost / (sms * rounds_n) as f64;
    let rounds: Vec<Round> =
        (0..rounds_n).map(|_| Round::with_efficiency(per_round_bytes, 128, 0.85, per_round_fma)).collect();

    KernelPlan {
        name: "fft-conv".into(),
        rounds,
        sms_active: spec.sm_count,
        threads_per_sm: 1024,
        compute_efficiency: 0.8, // butterfly shuffles + twiddle loads
        output_bytes: (p.out_elems() * BYTES_F32) as f64,
        smem_bytes_per_sm: 32 * 1024,
        total_fma: p.fma_ops() as f64, // report against direct-conv work
        launch_overhead_cycles: 12_000.0, // multi-kernel plan (fwd/mul/inv)
        stages: 2,
        loading: Loading::Cyclic,
        stage_bytes: 0,
        epilogue: Epilogue::None,
        epilogue_read_bytes: 0.0,
        filter_resident_smem_bytes: 0,
        filter_l2_footprint_bytes: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, simulate};
    use crate::plans::paper_plan_for;

    #[test]
    fn simulates_cleanly() {
        let g = gtx_1080ti();
        for (c, w, m, k) in [(64, 56, 64, 3), (256, 14, 256, 1), (16, 112, 16, 5)] {
            let p = ConvProblem::multi(c, w, m, k);
            let r = simulate(&g, &plan(&p, &g));
            assert!(r.seconds.is_finite() && r.seconds > 0.0, "{}", p.label());
        }
    }

    #[test]
    fn loses_badly_for_small_k() {
        // the padded filter transforms make FFT hopeless at K=3 on CNN
        // layers — the reason the paper's taxonomy dismisses category 2
        let g = gtx_1080ti();
        let p = ConvProblem::multi(128, 28, 128, 3);
        let t_fft = simulate(&g, &plan(&p, &g)).seconds;
        let t_ours = simulate(&g, &paper_plan_for(&p, &g)).seconds;
        assert!(t_fft > 3.0 * t_ours, "fft {} vs ours {}", t_fft, t_ours);
    }

    #[test]
    fn gap_narrows_with_larger_k() {
        // FFT cost is K-independent; direct cost grows with K^2 — the
        // ratio must move toward FFT as K grows
        let g = gtx_1080ti();
        let gap = |k: usize| {
            let p = ConvProblem::multi(64, 56, 64, k);
            simulate(&g, &plan(&p, &g)).seconds / simulate(&g, &paper_plan_for(&p, &g)).seconds
        };
        assert!(gap(5) < gap(3), "K=5 gap {} vs K=3 gap {}", gap(5), gap(3));
    }
}
