//! CPU numeric reference executor.
//!
//! Runs a graph's actual arithmetic on deterministic pseudo-random
//! inputs and weights so difftests can prove the fusion rewrite is
//! BIT-identical, not approximately equal.  Everything is keyed on node
//! *names*: the fused conv keeps its original name, so it draws the
//! same weights as its unfused ancestor, and the same `relu` / max-pool
//! fold functions are used for standalone glue nodes and for fused
//! epilogues — equality holds by construction wherever the rewrite is
//! mathematically exact (relu commutes with max-pool under a strict `>`
//! fold; float add is commutative).
//!
//! Layout is CHW, f32.  This is a correctness oracle, not a fast path:
//! difftests run it on small graphs and on model-shaped toys, never on
//! full 224x224 stacks.

use crate::conv::ConvOp;
use crate::gpusim::Epilogue;

use super::build::Graph;
use super::node::{Node, Op, Shape};

/// ReLU exactly as the kernels' writeback tail applies it: strict
/// compare, canonical +0.0 for everything non-positive.
#[inline]
pub fn relu(x: f32) -> f32 {
    if x > 0.0 {
        x
    } else {
        0.0
    }
}

/// Max-pool one CHW tensor with a `k` x `k` window and `stride`,
/// folding with a strict `>` (first element wins ties) — the same fold
/// the fused `MaxPoolWriteback` tail uses.
pub fn maxpool(data: &[f32], s: Shape, k: usize, stride: usize) -> Vec<f32> {
    let (py, px) = ((s.h - k) / stride + 1, (s.w - k) / stride + 1);
    let mut out = Vec::with_capacity(s.c * py * px);
    for c in 0..s.c {
        let plane = &data[c * s.h * s.w..(c + 1) * s.h * s.w];
        for y in 0..py {
            for x in 0..px {
                let mut m = plane[y * stride * s.w + x * stride];
                for ky in 0..k {
                    for kx in 0..k {
                        let v = plane[(y * stride + ky) * s.w + (x * stride + kx)];
                        if v > m {
                            m = v;
                        }
                    }
                }
                out.push(m);
            }
        }
    }
    out
}

/// Deterministic values in [-1, 1) from a name + salt (FNV-1a seed,
/// xorshift64* stream).  Node names are stable across the fusion
/// rewrite, so fused and unfused graphs draw identical tensors.
pub fn seeded(name: &str, salt: &str, len: usize) -> Vec<f32> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes().chain([0x1f]).chain(salt.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut x = h | 1;
    (0..len)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let bits = (x.wrapping_mul(0x2545_f491_4f6c_dd1d)) >> 40;
            (bits as f64 / (1u64 << 24) as f64 * 2.0 - 1.0) as f32
        })
        .collect()
}

/// Direct convolution of one CHW tensor under `op` (stride, symmetric
/// zero padding, groups), with weights drawn from `name`.  Accumulates
/// in f32 in a fixed loop order, so every executor that calls this gets
/// the same bits.
fn conv(input: &[f32], in_shape: Shape, op: &ConvOp, name: &str) -> Vec<f32> {
    let (c, m, k) = (op.core.c, op.core.m, op.core.k);
    let (oy, ox) = (op.oy(), op.ox());
    let cg = c / op.groups; // channels read per filter
    let w = seeded(name, "w", m * cg * k * k);
    let mut out = Vec::with_capacity(m * oy * ox);
    for f in 0..m {
        let g = f / (m / op.groups);
        let wf = &w[f * cg * k * k..(f + 1) * cg * k * k];
        for y in 0..oy {
            for x in 0..ox {
                let mut acc = 0.0f32;
                for ci in 0..cg {
                    let plane = &input
                        [(g * cg + ci) * in_shape.h * in_shape.w..][..in_shape.h * in_shape.w];
                    for ky in 0..k {
                        let iy = (y * op.stride + ky) as isize - op.pad as isize;
                        if iy < 0 || iy >= in_shape.h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (x * op.stride + kx) as isize - op.pad as isize;
                            if ix < 0 || ix >= in_shape.w as isize {
                                continue;
                            }
                            acc += plane[iy as usize * in_shape.w + ix as usize]
                                * wf[ci * k * k + ky * k + kx];
                        }
                    }
                }
                out.push(acc);
            }
        }
    }
    out
}

fn eval(n: &Node, inputs: &[(&[f32], Shape)]) -> Vec<f32> {
    match &n.op {
        Op::Input { shape } => seeded(&n.name, "data", shape.elems()),
        Op::Conv { conv: op, epilogue } => {
            let raw = conv(inputs[0].0, inputs[0].1, op, &n.name);
            match *epilogue {
                Epilogue::None => raw,
                Epilogue::Relu => raw.into_iter().map(relu).collect(),
                Epilogue::AddResidual => {
                    raw.iter().zip(inputs[1].0).map(|(a, b)| a + b).collect()
                }
                Epilogue::MaxPoolWriteback { k, stride } => {
                    maxpool(&raw, Shape::new(op.core.m, op.oy(), op.ox()), k, stride)
                }
            }
        }
        Op::Pad { h, w } => {
            let (src, s) = inputs[0];
            let (top, left) = ((h - s.h) / 2, (w - s.w) / 2);
            let mut out = vec![0.0f32; s.c * h * w];
            for c in 0..s.c {
                for y in 0..s.h {
                    let dst = (c * h + top + y) * w + left;
                    out[dst..dst + s.w]
                        .copy_from_slice(&src[(c * s.h + y) * s.w..][..s.w]);
                }
            }
            out
        }
        Op::Pool { k, stride } => maxpool(inputs[0].0, inputs[0].1, *k, *stride),
        Op::Relu => inputs[0].0.iter().copied().map(relu).collect(),
        Op::Add => inputs[0].0.iter().zip(inputs[1].0).map(|(a, b)| a + b).collect(),
        Op::Concat { .. } => {
            let mut out = Vec::with_capacity(n.shape.elems());
            for (d, _) in inputs {
                out.extend_from_slice(d);
            }
            out
        }
    }
}

/// Execute `g` numerically; returns the last node's tensor.
pub fn reference_output(g: &Graph) -> Vec<f32> {
    let mut vals: Vec<Vec<f32>> = Vec::with_capacity(g.len());
    for n in g.nodes() {
        let ins: Vec<(&[f32], Shape)> = n
            .inputs
            .iter()
            .map(|&i| (vals[i].as_slice(), g.node(i).shape))
            .collect();
        let v = eval(n, &ins);
        debug_assert_eq!(v.len(), n.shape.elems(), "{}: shape mismatch", n.name);
        vals.push(v);
    }
    vals.pop().expect("non-empty graph")
}

#[cfg(test)]
mod tests {
    use super::super::build::GraphBuilder;
    use super::*;
    use crate::conv::ConvProblem;

    fn toy() -> GraphBuilder {
        GraphBuilder::new("toy")
    }

    #[test]
    fn seeded_is_deterministic_and_name_keyed() {
        let a = seeded("conv1", "w", 64);
        assert_eq!(a, seeded("conv1", "w", 64));
        assert_ne!(a, seeded("conv2", "w", 64));
        assert_ne!(a, seeded("conv1", "data", 64));
        assert!(a.iter().all(|v| (-1.0..=1.0).contains(v)));
        // not degenerate
        assert!(a.iter().any(|v| *v > 0.25) && a.iter().any(|v| *v < -0.25));
    }

    #[test]
    fn relu_and_maxpool_commute_bitwise() {
        let data = seeded("x", "data", 4 * 8 * 8);
        let s = Shape::new(4, 8, 8);
        let a: Vec<f32> = maxpool(&data, s, 2, 2).into_iter().map(relu).collect();
        let pre: Vec<f32> = data.iter().copied().map(relu).collect();
        let b = maxpool(&pre, s, 2, 2);
        assert_eq!(a, b); // exact bits, not approx
    }

    #[test]
    fn graph_reference_runs_every_op() {
        let mut b = toy();
        let i = b.input("in", Shape::new(2, 8, 8));
        let c = b.conv_op("c", i, ConvOp::same(ConvProblem::multi(2, 8, 4, 3))).unwrap();
        let r = b.relu("r", c).unwrap();
        let p = b.pool("p", r, 2, 2).unwrap();
        let d = b.pad("pd", p, 6, 6).unwrap();
        let c2 = b.conv_op("c2", d, ConvOp::dense(ConvProblem::multi(4, 6, 4, 3))).unwrap();
        let a = b.add("a", Op::Add, &[p, c2]).unwrap();
        let cat = b.concat("cat", &[a, p]).unwrap();
        let g = b.finish().unwrap();
        let out = reference_output(&g);
        assert_eq!(out.len(), g.node(cat).shape.elems());
        assert!(out.iter().all(|v| v.is_finite()));
        // deterministic end to end
        assert_eq!(out, reference_output(&g));
        let _ = (i, c, r, d, a);
    }

    #[test]
    fn fused_epilogues_match_their_glue_ops_bitwise() {
        use crate::gpusim::Epilogue;
        // conv+relu == conv -> relu
        let mut b = toy();
        let i = b.input("in", Shape::new(2, 10, 10));
        let op = ConvOp::dense(ConvProblem::multi(2, 10, 3, 3));
        let c = b.conv_op("c", i, op).unwrap();
        b.relu("r", c).unwrap();
        let unfused = reference_output(&b.finish().unwrap());
        let mut b = toy();
        let i = b.input("in", Shape::new(2, 10, 10));
        b.add("c", Op::Conv { conv: op, epilogue: Epilogue::Relu }, &[i]).unwrap();
        assert_eq!(unfused, reference_output(&b.finish().unwrap()));

        // conv+pool == conv -> pool
        let mut b = toy();
        let i = b.input("in", Shape::new(2, 10, 10));
        let c = b.conv_op("c", i, op).unwrap();
        b.pool("p", c, 2, 2).unwrap();
        let unfused = reference_output(&b.finish().unwrap());
        let mut b = toy();
        let i = b.input("in", Shape::new(2, 10, 10));
        let ep = Epilogue::MaxPoolWriteback { k: 2, stride: 2 };
        b.add("c", Op::Conv { conv: op, epilogue: ep }, &[i]).unwrap();
        assert_eq!(unfused, reference_output(&b.finish().unwrap()));

        // conv+add == add(conv, residual), either operand order
        let res_op = ConvOp::dense(ConvProblem::multi(2, 10, 3, 3));
        let mut b = toy();
        let i = b.input("in", Shape::new(2, 10, 10));
        let c = b.conv_op("c", i, op).unwrap();
        let r = b.conv_op("res", i, res_op).unwrap();
        b.add("a", Op::Add, &[r, c]).unwrap();
        let unfused = reference_output(&b.finish().unwrap());
        let mut b = toy();
        let i = b.input("in", Shape::new(2, 10, 10));
        let r = b.conv_op("res", i, res_op).unwrap();
        b.add("c", Op::Conv { conv: op, epilogue: Epilogue::AddResidual }, &[i, r]).unwrap();
        assert_eq!(unfused, reference_output(&b.finish().unwrap()));
    }
}
