//! Graph executor — whole-network CNN inference over the L1 model.
//!
//! The paper evaluates convolutions drawn from AlexNet/VGG/ResNet/
//! GoogLeNet but treats each in isolation; this layer restores the
//! network structure around them.  A model is a DAG of nodes (`node`:
//! conv / pad / pool / add / concat) built and shape-checked by
//! `build`, memory-planned by `memory` (liveness + greedy arena
//! offsets, the Li-et-al. inter-layer optimization), and executed by
//! `exec` (topological schedule; conv nodes resolve through an
//! injected `Planner` — `backend::dispatch_fused_op_plan` for
//! per-layer cross-backend algorithm choice,
//! `plans::op_plan_for`/`paper_op_plan_for` for the paper-kernel-only
//! paths — and run under `gpusim`).  Conv nodes carry full `ConvOp`s:
//! stride-2 downsampling, op-level 'same' padding and depthwise groups
//! are first-class (ResNet-18 runs its true geometry; MobileNetV1 is a
//! registered model).
//!
//! Consumers: the `model` CLI subcommand and `e2e_models` bench report
//! end-to-end latency + peak arena memory per model; the coordinator
//! registers models with its `Router` so every layer is pre-dispatched
//! at startup and `Payload::Model` requests serve the cached decisions.
//!
//! `fuse` rewrites a built graph before execution: relu / residual-add /
//! max-pool tails fold into the producing conv's writeback epilogue and
//! eligible concats become zero-copy placement decisions (`memory`
//! aliases their producers into the concat allocation).  `reference` is
//! the CPU numeric executor the difftests use to prove the rewrite is
//! bit-identical.

pub mod build;
pub mod exec;
pub mod fuse;
pub mod memory;
pub mod node;
pub mod reference;

pub use build::{
    alexnet_graph, inception3a_graph, mobilenet_v1_graph, model_graph, resnet18_graph,
    vgg16_graph, Graph, GraphBuilder, MODEL_NAMES,
};
pub use exec::{
    execute, execute_batched, execute_batched_traced, execute_pooled, glue_stream_cycles,
    node_glue_bytes, node_glue_cycles, topo_order, ModelReport, NodeReport, Planner,
};
pub use fuse::{fuse, FusionReport};
pub use memory::{
    liveness, plan_arena, plan_pooled, zero_copy_aliases, ArenaPlan, Placement, PooledPlan,
    TensorLife, ARENA_ALIGN,
};
pub use node::{Node, NodeId, Op, Shape};
pub use reference::reference_output;
