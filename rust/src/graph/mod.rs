//! Graph executor — whole-network CNN inference over the L1 model.
//!
//! The paper evaluates convolutions drawn from AlexNet/VGG/ResNet/
//! GoogLeNet but treats each in isolation; this layer restores the
//! network structure around them.  A model is a DAG of nodes (`node`:
//! conv / pad / pool / add / concat) built and shape-checked by
//! `build`, memory-planned by `memory` (liveness + greedy arena
//! offsets, the Li-et-al. inter-layer optimization), and executed by
//! `exec` (topological schedule; conv nodes resolve through an
//! injected `Planner` — `backend::dispatch_op_plan` for per-layer
//! cross-backend algorithm choice,
//! `plans::op_plan_for`/`paper_op_plan_for` for the paper-kernel-only
//! paths — and run under `gpusim`).  Conv nodes carry full `ConvOp`s:
//! stride-2 downsampling, op-level 'same' padding and depthwise groups
//! are first-class (ResNet-18 runs its true geometry; MobileNetV1 is a
//! registered model).
//!
//! Consumers: the `model` CLI subcommand and `e2e_models` bench report
//! end-to-end latency + peak arena memory per model; the coordinator
//! registers models with its `Router` so every layer is pre-dispatched
//! at startup and `Payload::Model` requests serve the cached decisions.

pub mod build;
pub mod exec;
pub mod memory;
pub mod node;

pub use build::{
    alexnet_graph, inception3a_graph, mobilenet_v1_graph, model_graph, resnet18_graph,
    vgg16_graph, Graph, GraphBuilder, MODEL_NAMES,
};
pub use exec::{
    execute, execute_batched, execute_batched_traced, execute_pooled, node_glue_bytes, topo_order,
    ModelReport, NodeReport, Planner,
};
pub use memory::{
    liveness, plan_arena, plan_pooled, ArenaPlan, Placement, PooledPlan, TensorLife, ARENA_ALIGN,
};
pub use node::{Node, NodeId, Op, Shape};
