//! Whole-graph execution: a topological scheduler that resolves conv
//! nodes through an injected `Planner` —
//! `backend::dispatch_fused_op_plan` for per-layer cross-backend
//! algorithm choice (the serving default:
//! one model can run Winograd on its big K=3 layers and the paper
//! kernels on its small maps), `plans::op_plan_for` for the
//! tuned-paper-only path, `plans::paper_op_plan_for` for the §3 closed
//! forms — times every node under `gpusim`, and reports end-to-end
//! model latency next to the arena memory plan.  Conv
//! `NodeReport.detail` carries the chosen plan's name (with its
//! stride/group tags), so `model --report` shows the per-layer backend
//! picks.
//!
//! Glue operators (pool / pad / add / concat) have no FMA story — they
//! are DRAM-bound streams, charged launch overhead + one cold latency +
//! bytes over a derated bandwidth, the same accounting `gpusim` applies
//! to kernel loads.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::conv::{ConvOp, BYTES_F32};
use crate::gpusim::{simulate, Epilogue, GpuSpec, KernelPlan};
use crate::plans;
use crate::util::bench::Table;

use crate::fleet::pool::{DevicePool, PoolError};

use super::build::Graph;
use super::memory::{plan_arena, plan_pooled, ArenaPlan, PooledPlan};
use super::node::{NodeId, Op, Shape};

/// How a conv node resolves to a kernel plan.
/// `backend::dispatch_fused_op_plan` (cross-backend),
/// `plans::op_plan_for` (tuned paper kernel) and
/// `plans::paper_op_plan_for` (§3 closed forms) all fit — each handles
/// stride/pad/groups through the op layer's native schedules or the
/// exact lowering, then applies the node's fused epilogue to the plan
/// (`Epilogue::None` is the unfused path, bit-identical to the old
/// two-argument planners).
pub type Planner = fn(&ConvOp, Epilogue, &GpuSpec) -> KernelPlan;

/// Fraction of peak DRAM bandwidth the memory-bound glue kernels
/// sustain (simple streaming kernels: no coalescing hazards, but no
/// perfect bus residency either).
pub const GLUE_BW_EFFICIENCY: f64 = 0.8;

/// Kahn topological order, smallest ready id first — deterministic, and
/// equal to insertion order on builder-produced graphs.  Panics on a
/// cycle (unreachable for builder graphs, which only have forward
/// edges).
pub fn topo_order(g: &Graph) -> Vec<NodeId> {
    let consumers = g.consumers();
    let mut indeg: Vec<usize> = g.nodes().iter().map(|n| n.inputs.len()).collect();
    let mut ready: BinaryHeap<Reverse<NodeId>> = indeg
        .iter()
        .enumerate()
        .filter(|(_, &d)| d == 0)
        .map(|(id, _)| Reverse(id))
        .collect();
    let mut order = Vec::with_capacity(g.len());
    while let Some(Reverse(id)) = ready.pop() {
        order.push(id);
        for &c in &consumers[id] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                ready.push(Reverse(c));
            }
        }
    }
    assert_eq!(order.len(), g.len(), "graph has a cycle");
    order
}

/// DRAM bytes a glue node moves (reads + writes).  Pool reads each
/// window element — but when windows are overlap-free (stride >= k)
/// every input pixel is touched exactly once, so the read side is the
/// input tensor, not `windows * k * k` (which over-charged the common
/// stride == k pools and under-charged nothing).  Pad re-writes the
/// framed tensor, relu streams its tensor through, add reads both
/// operands, concat copies its inputs — unless it is zero-copy, where
/// the producers already wrote into the concat allocation and the node
/// moves nothing.
fn glue_bytes(g: &Graph, id: NodeId) -> f64 {
    let n = g.node(id);
    let out = n.shape.bytes() as f64;
    let ins: f64 = n.inputs.iter().map(|&i| g.node(i).shape.bytes() as f64).sum();
    match n.op {
        Op::Input { .. } | Op::Conv { .. } => 0.0,
        Op::Pool { k, stride } => {
            let reads = if stride >= k {
                g.node(n.inputs[0]).shape.elems()
            } else {
                n.shape.elems() * k * k
            };
            (reads * BYTES_F32) as f64 + out
        }
        Op::Concat { zero_copy: true } => 0.0,
        Op::Pad { .. } | Op::Relu | Op::Add | Op::Concat { zero_copy: false } => ins + out,
    }
}

/// Public read-only view of `glue_bytes` — the observability layer
/// (`trace::report`) aggregates model-level DRAM traffic from it.
pub fn node_glue_bytes(g: &Graph, id: NodeId) -> f64 {
    glue_bytes(g, id)
}

/// Cycles of a glue node's DRAM stream (`glue_cycles` over
/// `node_glue_bytes`) — the fusion pass prices eliminated glue with
/// the exact arithmetic the executor charges.
pub fn node_glue_cycles(g: &Graph, spec: &GpuSpec, id: NodeId) -> f64 {
    glue_cycles(spec, glue_bytes(g, id))
}

/// `glue_cycles` for a raw byte count — what a hypothetical glue node
/// moving `bytes` would cost (the fusion pass prices retained-but-
/// shrunk relu streams before the rewritten graph exists).
pub fn glue_stream_cycles(spec: &GpuSpec, bytes: f64) -> f64 {
    glue_cycles(spec, bytes)
}

/// Cycles for a memory-bound glue op moving `bytes` through DRAM.
fn glue_cycles(spec: &GpuSpec, bytes: f64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    plans::LAUNCH_OVERHEAD_CYCLES
        + spec.mem_latency_cycles as f64
        + bytes / (spec.bytes_per_cycle() * GLUE_BW_EFFICIENCY)
}

/// One scheduled node's timing.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub id: NodeId,
    pub name: String,
    pub kind: &'static str,
    /// kernel-plan name for convs, op summary otherwise
    pub detail: String,
    pub shape: Shape,
    pub seconds: f64,
}

/// End-to-end execution report for one model on one GPU.
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub model: String,
    pub gpu: &'static str,
    /// images pushed through the graph together (1 = single inference)
    pub batch: usize,
    /// per-node breakdown, in schedule order (`nodes[i].id` is the
    /// node executed at step i); node times cover the whole batch
    pub nodes: Vec<NodeReport>,
    pub total_seconds: f64,
    pub conv_seconds: f64,
    pub glue_seconds: f64,
    /// conv node count (layer instances)
    pub conv_layers: usize,
    /// conv layers whose batched schedule kept filters smem-resident
    /// across the batch's images (`KernelPlan::batched_resident` won)
    pub resident_conv_layers: usize,
    /// chip-wide DRAM filter bytes the resident layers did NOT re-stream
    /// over this batch execution, vs the re-streaming batched schedule
    pub resident_filter_bytes_saved: f64,
    /// arena plan scaled per image: every activation holds `batch`
    /// images, so peak/naive bytes are the per-image plan times `batch`
    pub arena: ArenaPlan,
}

impl ModelReport {
    /// Per-node breakdown table (the `--report` view).
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["step", "node", "kind", "out", "time (µs)", "plan / op"]);
        for (i, n) in self.nodes.iter().enumerate() {
            t.row(&[
                i.to_string(),
                n.name.clone(),
                n.kind.to_string(),
                n.shape.label(),
                format!("{:.1}", n.seconds * 1e6),
                n.detail.clone(),
            ]);
        }
        t
    }

    /// One-line summary (CLI, bench, coordinator logs).
    pub fn summary(&self) -> String {
        format!(
            "{}{}: {} nodes ({} convs) in {:.3} ms ({:.0}% conv) on {}; arena {} MiB vs naive {} MiB ({:.0}% saved)",
            self.model,
            if self.batch > 1 { format!(" xb{}", self.batch) } else { String::new() },
            self.nodes.len(),
            self.conv_layers,
            self.total_seconds * 1e3,
            100.0 * self.conv_seconds / self.total_seconds.max(1e-30),
            self.gpu,
            crate::util::bench::fmt_mib(self.arena.peak_bytes),
            crate::util::bench::fmt_mib(self.arena.naive_bytes),
            100.0 * self.arena.saved_fraction(),
        )
    }
}

/// Execute `g` on `spec`: schedule topologically, plan the arena, time
/// every node (convs through `planner` + `gpusim::simulate`, glue
/// through the DRAM stream model) and aggregate.
pub fn execute(g: &Graph, spec: &GpuSpec, planner: Planner) -> ModelReport {
    execute_batched(g, spec, planner, 1)
}

/// `execute` for a batch of `batch` images moving through the graph
/// together: conv nodes run their plan's batched schedule (one launch,
/// warm pipeline — `KernelPlan::batched`), glue nodes stream `batch`
/// times the bytes under one launch, and the arena holds `batch` images
/// per activation (per-image plan scaled by `batch`).
pub fn execute_batched(g: &Graph, spec: &GpuSpec, planner: Planner, batch: usize) -> ModelReport {
    assert!(batch >= 1, "batch must be >= 1");
    let order = topo_order(g);
    let mut arena = plan_arena(g, &order);
    // every activation carries `batch` images: offsets and sizes scale
    // uniformly, so the per-image plan times `batch` IS the batched plan
    arena.peak_bytes *= batch;
    arena.naive_bytes *= batch;
    for pl in &mut arena.placements {
        pl.offset *= batch;
        pl.life.bytes *= batch;
    }
    let mut nodes = Vec::with_capacity(order.len());
    let (mut conv_s, mut glue_s, mut convs) = (0.0f64, 0.0f64, 0usize);
    let (mut resident, mut resident_saved) = (0usize, 0.0f64);
    for &id in &order {
        let n = g.node(id);
        let (seconds, detail) = match &n.op {
            Op::Input { .. } => (0.0, "network input".to_string()),
            Op::Conv { conv, epilogue } => {
                let unit = planner(conv, *epilogue, spec);
                let plan = unit.batched_resident(batch, spec);
                if plan.name.ends_with("+fr") {
                    resident += 1;
                    resident_saved += unit.batched(batch).dram_load_bytes()
                        - plan.dram_load_bytes();
                }
                let r = simulate(spec, &plan);
                convs += 1;
                conv_s += r.seconds;
                (r.seconds, r.name)
            }
            op => {
                let s = spec
                    .cycles_to_secs(glue_cycles(spec, glue_bytes(g, id) * batch as f64));
                glue_s += s;
                let d = match *op {
                    Op::Pad { h, w } => format!("pad to {h}x{w}"),
                    Op::Pool { k, stride } => format!("maxpool {k}x{k}/s{stride}"),
                    Op::Relu => "relu".to_string(),
                    Op::Add => "residual add".to_string(),
                    Op::Concat { zero_copy: true } => {
                        format!("concat {} inputs (zero-copy)", n.inputs.len())
                    }
                    Op::Concat { zero_copy: false } => {
                        format!("concat {} inputs", n.inputs.len())
                    }
                    _ => unreachable!(),
                };
                (s, d)
            }
        };
        nodes.push(NodeReport {
            id,
            name: n.name.clone(),
            kind: n.op.kind(),
            detail,
            shape: n.shape,
            seconds,
        });
    }
    ModelReport {
        model: g.name.clone(),
        gpu: spec.name,
        batch,
        nodes,
        total_seconds: conv_s + glue_s,
        conv_seconds: conv_s,
        glue_seconds: glue_s,
        conv_layers: convs,
        resident_conv_layers: resident,
        resident_filter_bytes_saved: resident_saved,
        arena,
    }
}

/// `execute_batched`, additionally emitting a span tree through `sink`
/// when it is enabled: one root span `model:{name}` starting at
/// virtual time `t0` on `track`, one child span per scheduled node
/// laid end-to-end at its cumulative offset, conv children carrying
/// their plan's roofline counters.  The returned report IS
/// `execute_batched`'s — tracing observes it, never changes it (the
/// difftests pin bit-identity under both sinks).
pub fn execute_batched_traced(
    g: &Graph,
    spec: &GpuSpec,
    planner: Planner,
    batch: usize,
    sink: &mut dyn crate::trace::TraceSink,
    t0: f64,
    track: &str,
) -> ModelReport {
    let report = execute_batched(g, spec, planner, batch);
    if !sink.enabled() {
        return report;
    }
    let root_id = sink.next_span_id();
    let mut children = Vec::with_capacity(report.nodes.len());
    let mut t = t0;
    for n in &report.nodes {
        let id = sink.next_span_id();
        let mut sp = crate::trace::Span::new(id, Some(root_id), track, &n.name, t, t + n.seconds)
            .attr("kind", n.kind.into())
            .attr("detail", n.detail.as_str().into())
            .attr("seconds", n.seconds.into());
        if let Op::Conv { conv, epilogue } = &g.node(n.id).op {
            let plan = planner(conv, *epilogue, spec).batched_resident(batch, spec);
            for (k, v) in crate::trace::Roofline::measure(spec, &plan).attrs() {
                sp = sp.attr(&k, v);
            }
        }
        t += n.seconds;
        children.push(sp);
    }
    let root =
        crate::trace::Span::new(root_id, None, track, &format!("model:{}", report.model), t0, t)
            .attr("gpu", report.gpu.into())
            .attr("batch", report.batch.into())
            .attr("total_seconds", report.total_seconds.into())
            .attr("conv_seconds", report.conv_seconds.into())
            .attr("glue_seconds", report.glue_seconds.into());
    sink.record(crate::trace::Event::Span(root));
    for c in children {
        sink.record(crate::trace::Event::Span(c));
    }
    report
}

/// `execute_batched` against a shared device pool: the timing walk is
/// the exact same arithmetic (the returned `ModelReport` is
/// bit-identical to the unpooled path — pool state never influences
/// node timing), while the memory schedule allocates per-tensor from
/// `pool` instead of reserving a private arena (`plan_pooled`).  Errors
/// out — with the pool rolled back — when the execution cannot fit
/// under the pool's cap alongside its current residents.
pub fn execute_pooled(
    g: &Graph,
    spec: &GpuSpec,
    planner: Planner,
    batch: usize,
    pool: &mut DevicePool,
) -> Result<(ModelReport, PooledPlan), PoolError> {
    let order = topo_order(g);
    let pooled = plan_pooled(g, &order, batch, pool)?;
    Ok((execute_batched(g, spec, planner, batch), pooled))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build::{model_graph, GraphBuilder, MODEL_NAMES};
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn topo_order_respects_edges_on_all_models() {
        for name in MODEL_NAMES {
            let g = model_graph(name).unwrap();
            let order = topo_order(&g);
            let mut pos = vec![0usize; g.len()];
            for (i, &id) in order.iter().enumerate() {
                pos[id] = i;
            }
            for n in g.nodes() {
                for &i in &n.inputs {
                    assert!(pos[i] < pos[n.id], "{name}: {} before its input", n.name);
                }
            }
        }
    }

    #[test]
    fn builder_graphs_schedule_in_insertion_order() {
        let g = model_graph("resnet18").unwrap();
        let order = topo_order(&g);
        assert_eq!(order, (0..g.len()).collect::<Vec<_>>());
    }

    #[test]
    fn execute_produces_positive_breakdown() {
        let g = model_graph("alexnet").unwrap();
        let spec = gtx_1080ti();
        let r = execute(&g, &spec, plans::paper_op_plan_for);
        assert_eq!(r.nodes.len(), g.len());
        assert!(r.total_seconds > 0.0 && r.total_seconds.is_finite());
        assert!((r.conv_seconds + r.glue_seconds - r.total_seconds).abs() < 1e-12);
        assert_eq!(r.conv_layers, 4);
        // convs dominate glue on every §4 model
        assert!(r.conv_seconds > r.glue_seconds, "{}", r.summary());
        // per-node times sum to the total
        let sum: f64 = r.nodes.iter().map(|n| n.seconds).sum();
        assert!((sum - r.total_seconds).abs() < 1e-12);
    }

    #[test]
    fn conv_nodes_report_their_plan_names() {
        let g = model_graph("inception3a").unwrap();
        let spec = gtx_1080ti();
        let r = execute(&g, &spec, plans::paper_op_plan_for);
        for n in &r.nodes {
            if n.kind == "conv" {
                assert!(n.detail.contains("ours-"), "{}: {}", n.name, n.detail);
            }
        }
    }

    #[test]
    fn glue_costs_scale_with_bytes() {
        let spec = gtx_1080ti();
        let mut b = GraphBuilder::new("glue");
        let x = b.input("in", crate::graph::Shape::new(64, 56, 56));
        let small = b.pool("p", x, 2, 2).unwrap();
        let _ = b.pad("q", small, 32, 32).unwrap();
        let g = b.finish().unwrap();
        let pool = glue_bytes(&g, 1);
        let pad = glue_bytes(&g, 2);
        assert!(pool > 0.0 && pad > 0.0);
        // the 2x2/s2 pool reads the full 56x56 map once; the pad only
        // moves the quarter map plus its 32x32 frame
        assert!(pool > pad, "pool {pool} <= pad {pad}");
        assert!(glue_cycles(&spec, pool) > glue_cycles(&spec, pad));
        assert_eq!(glue_cycles(&spec, 0.0), 0.0);
    }

    #[test]
    fn overlap_free_pool_reads_each_input_pixel_once() {
        // stride >= k: windows tile the map without overlap, so the
        // read side is the input tensor — per-window pricing would
        // charge 13*13*4 = 676 elems on a 27x27 map and miss the odd
        // rim, while the kernel really streams all 729 pixels
        let mut b = GraphBuilder::new("pools");
        let x = b.input("in", crate::graph::Shape::new(1, 27, 27));
        let tiled = b.pool("tiled", x, 2, 2).unwrap();
        let g = b.finish().unwrap();
        let out = g.node(tiled).shape.bytes() as f64;
        assert_eq!(glue_bytes(&g, tiled), (27 * 27 * BYTES_F32) as f64 + out);

        // overlapping windows (stride < k) still pay per window
        let mut b = GraphBuilder::new("pools2");
        let x = b.input("in", crate::graph::Shape::new(1, 28, 28));
        let over = b.pool("over", x, 3, 1).unwrap();
        let g = b.finish().unwrap();
        let o = g.node(over);
        let out = o.shape.bytes() as f64;
        assert_eq!(
            glue_bytes(&g, over),
            (o.shape.elems() * 9 * BYTES_F32) as f64 + out
        );
    }

    #[test]
    fn relu_nodes_stream_their_tensor_and_zero_copy_concat_is_free() {
        let spec = gtx_1080ti();
        let mut b = GraphBuilder::new("glue2");
        let x = b.input("in", crate::graph::Shape::new(8, 14, 14));
        let r = b.relu("r", x).unwrap();
        let g = b.finish().unwrap();
        let bytes = g.node(x).shape.bytes() as f64 + g.node(r).shape.bytes() as f64;
        assert_eq!(glue_bytes(&g, r), bytes);
        assert!(glue_cycles(&spec, bytes) > 0.0);

        // a zero-copy concat moves nothing; the copying one moves 2x
        let mut b = GraphBuilder::new("cat");
        let x = b.input("in", crate::graph::Shape::new(8, 14, 14));
        let a = b.conv_same("a", x, crate::conv::ConvProblem::multi(8, 14, 8, 3)).unwrap();
        let c = b.conv_same("c", x, crate::conv::ConvProblem::multi(8, 14, 8, 3)).unwrap();
        let cat = b.concat("cat", &[a, c]).unwrap();
        let zc = b.add("cat.zc", Op::Concat { zero_copy: true }, &[a, c]).unwrap();
        let g = b.finish().unwrap();
        assert!(glue_bytes(&g, cat) > 0.0);
        assert_eq!(glue_bytes(&g, zc), 0.0);
    }

    #[test]
    fn batched_execution_amortizes_and_scales_arena() {
        let g = model_graph("alexnet").unwrap();
        let spec = gtx_1080ti();
        let one = execute_batched(&g, &spec, plans::paper_op_plan_for, 1);
        let four = execute_batched(&g, &spec, plans::paper_op_plan_for, 4);
        // batch=1 is exactly execute()
        let plain = execute(&g, &spec, plans::paper_op_plan_for);
        assert_eq!(plain.batch, 1);
        assert!((one.total_seconds - plain.total_seconds).abs() < 1e-15);
        // more work than one image, less than four independent runs
        assert!(four.total_seconds > one.total_seconds);
        assert!(four.total_seconds < 4.0 * one.total_seconds, "no amortization");
        // arena scaled per image
        assert_eq!(four.arena.peak_bytes, 4 * one.arena.peak_bytes);
        assert_eq!(four.arena.naive_bytes, 4 * one.arena.naive_bytes);
        assert!((four.arena.saved_fraction() - one.arena.saved_fraction()).abs() < 1e-12);
        assert!(four.summary().contains("xb4"), "{}", four.summary());
        // per-node times still sum to the total
        let sum: f64 = four.nodes.iter().map(|n| n.seconds).sum();
        assert!((sum - four.total_seconds).abs() < 1e-12);
    }

    #[test]
    fn report_table_and_summary_render() {
        let g = model_graph("vgg16").unwrap();
        let spec = gtx_1080ti();
        let r = execute(&g, &spec, plans::paper_op_plan_for);
        let t = r.table().to_string();
        assert!(t.contains("conv1_1") && t.contains("pool5"));
        let s = r.summary();
        assert!(s.contains("vgg16") && s.contains("MiB"), "{s}");
    }

    #[test]
    fn dispatched_graph_never_loses_and_names_backends() {
        // the dispatcher as a Planner: per-layer algorithm choice
        // inside one model, gated to never lose to tuned-paper-only
        let g = model_graph("vgg16").unwrap();
        let spec = gtx_1080ti();
        let tuned = execute(&g, &spec, plans::op_plan_for);
        let dispatched = execute(&g, &spec, crate::backend::dispatch_fused_op_plan);
        assert!(
            dispatched.total_seconds <= tuned.total_seconds * (1.0 + 1e-9),
            "dispatch lost: {} > {}",
            dispatched.total_seconds,
            tuned.total_seconds
        );
        assert!((dispatched.glue_seconds - tuned.glue_seconds).abs() < 1e-12);
        // the VGG body's big K=3 layers leave the paper kernels — the
        // per-layer backend choice is visible in the report details
        assert!(
            dispatched.nodes.iter().any(|n| n.kind == "conv" && !n.detail.starts_with("ours-")),
            "no per-layer backend choice visible"
        );
    }

    #[test]
    fn pooled_execution_timing_is_bit_identical() {
        let g = model_graph("resnet18").unwrap();
        let spec = gtx_1080ti();
        let plain = execute_batched(&g, &spec, crate::backend::dispatch_fused_op_plan, 2);
        let mut pool = DevicePool::new(spec.dram_bytes as usize);
        let (pooled, plan) =
            execute_pooled(&g, &spec, crate::backend::dispatch_fused_op_plan, 2, &mut pool).unwrap();
        assert_eq!(pooled.total_seconds.to_bits(), plain.total_seconds.to_bits());
        for (a, b) in pooled.nodes.iter().zip(&plain.nodes) {
            assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "node {}", a.name);
        }
        assert!(plan.peak_bytes <= plain.arena.peak_bytes);
        assert_eq!(pool.live_allocs(), 0);
    }

    #[test]
    fn diamond_graph_schedules_once_each() {
        // input feeding two branches joined by add — the smallest DAG
        // that is not a chain
        let mut b = GraphBuilder::new("diamond");
        let x = b.input("in", crate::graph::Shape::new(8, 14, 14));
        let l = b.conv_same("l", x, crate::conv::ConvProblem::multi(8, 14, 8, 3)).unwrap();
        let r = b.conv_same("r", x, crate::conv::ConvProblem::multi(8, 14, 8, 3)).unwrap();
        let _ = b.add_skip("join", l, r).unwrap();
        let g = b.finish().unwrap();
        let order = topo_order(&g);
        assert_eq!(order.len(), g.len());
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..g.len()).collect::<Vec<_>>());
    }
}
