//! Graph node types: activation shapes and the operator set the
//! evaluation models need — conv (carrying a full `ConvOp`: stride,
//! padding and groups are op-level, so 'same' models pad inside the
//! conv and downsampling models stride natively, plus a fused
//! `Epilogue` the writeback tail applies in-register), pad (pool
//! framing only — conv inputs no longer need graph-side pads), pool,
//! relu, elementwise add (ResNet skip connections) and channel concat
//! (Inception cells — optionally zero-copy: producers write disjoint
//! sub-ranges of the concat output directly).

use crate::conv::{ConvOp, BYTES_F32};
use crate::gpusim::Epilogue;

/// Shape of one activation tensor: `c` channels of `h` x `w`, f32.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub fn new(c: usize, h: usize, w: usize) -> Shape {
        Shape { c, h, w }
    }

    pub fn elems(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Device bytes of the tensor (f32, unaligned — the arena planner
    /// applies its allocation granularity on top).
    pub fn bytes(&self) -> usize {
        self.elems() * BYTES_F32
    }

    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.c, self.h, self.w)
    }
}

/// Index of a node within its graph (assigned by the builder).
pub type NodeId = usize;

/// One operator in the layer DAG.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// network input with a declared shape
    Input { shape: Shape },
    /// a convolution op (stride / padding / groups first-class) —
    /// resolved to a `KernelPlan` through the injected `Planner`
    /// (backend dispatch or the paper plans) at execution time.  A
    /// non-`None` epilogue is applied by the kernel's writeback tail:
    /// `Relu` clamps in-register, `AddResidual` streams a second input
    /// (the residual) through the tail, `MaxPoolWriteback` writes the
    /// decimated pooled output — the intermediate tensor never touches
    /// DRAM, so the node that used to consume it is gone from the graph
    Conv { conv: ConvOp, epilogue: Epilogue },
    /// zero-pad height/width up to `h` x `w` (channels unchanged) —
    /// retained for pool framing (e.g. inception's 'same' pool); conv
    /// padding is op-level now
    Pad { h: usize, w: usize },
    /// max pool with a `k` x `k` window and the given stride
    Pool { k: usize, stride: usize },
    /// elementwise ReLU (the models' inter-layer activation — the
    /// fusion pass folds it into the producing conv's epilogue)
    Relu,
    /// elementwise residual add of two same-shape tensors
    Add,
    /// channel concatenation of same-map tensors.  `zero_copy` means
    /// the arena planner places every producer inside the concat
    /// output's allocation (channel-prefix sub-ranges), so execution
    /// moves zero bytes for this node
    Concat { zero_copy: bool },
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Conv { .. } => "conv",
            Op::Pad { .. } => "pad",
            Op::Pool { .. } => "pool",
            Op::Relu => "relu",
            Op::Add => "add",
            Op::Concat { .. } => "concat",
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self, Op::Conv { .. })
    }

    /// The fused epilogue of a conv node (`None` for everything else).
    pub fn epilogue(&self) -> Epilogue {
        match self {
            Op::Conv { epilogue, .. } => *epilogue,
            _ => Epilogue::None,
        }
    }
}

/// One node of a built graph: operator + input edges + inferred output
/// shape.  Nodes are created through `GraphBuilder`, which guarantees
/// `inputs` only reference earlier nodes and that `shape` is consistent
/// with the operator's shape rule.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    /// output shape, inferred at build time
    pub shape: Shape,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let s = Shape::new(64, 56, 56);
        assert_eq!(s.elems(), 64 * 56 * 56);
        assert_eq!(s.bytes(), 64 * 56 * 56 * 4);
        assert_eq!(s.label(), "64x56x56");
    }

    #[test]
    fn op_kinds() {
        use crate::conv::ConvProblem;
        let c = ConvOp::dense(ConvProblem::single(8, 1, 1));
        assert_eq!(Op::Input { shape: Shape::new(1, 1, 1) }.kind(), "input");
        assert_eq!(Op::Conv { conv: c, epilogue: Epilogue::None }.kind(), "conv");
        assert_eq!(Op::Pad { h: 4, w: 4 }.kind(), "pad");
        assert_eq!(Op::Pool { k: 2, stride: 2 }.kind(), "pool");
        assert_eq!(Op::Relu.kind(), "relu");
        assert_eq!(Op::Add.kind(), "add");
        assert_eq!(Op::Concat { zero_copy: false }.kind(), "concat");
        assert_eq!(Op::Concat { zero_copy: true }.kind(), "concat");
        assert!(Op::Conv { conv: c, epilogue: Epilogue::None }.is_conv());
        assert!(!Op::Add.is_conv());
    }

    #[test]
    fn conv_epilogue_accessor() {
        use crate::conv::ConvProblem;
        let c = ConvOp::dense(ConvProblem::multi(8, 14, 8, 3));
        assert_eq!(Op::Conv { conv: c, epilogue: Epilogue::Relu }.epilogue(), Epilogue::Relu);
        assert_eq!(Op::Relu.epilogue(), Epilogue::None);
        assert_eq!(
            Op::Conv { conv: c, epilogue: Epilogue::MaxPoolWriteback { k: 2, stride: 2 } }
                .epilogue(),
            Epilogue::MaxPoolWriteback { k: 2, stride: 2 }
        );
    }
}
