//! Graph construction: a validating builder with shape inference, and
//! the evaluation models (AlexNet, VGG-16, ResNet-18, GoogLeNet
//! inception(3a), MobileNetV1) assembled from the *same* `ConvOp`s the
//! `conv::suites` lists evaluate — the graph layer adds the
//! inter-layer structure (pools, skips, branches) those flat lists
//! drop.
//!
//! Convention: convolution padding and stride are **op-level**
//! (`ConvOp`), so 'same' models carry their padding inside the conv
//! node and downsampling models stride natively — ResNet-18's stage
//! transitions are real 3x3/s2 convs with 1x1/s2 projections, not
//! pool + stride-1 approximations, and graph-side `Op::Pad` survives
//! only for pool framing (inception's 'same' pool).

use anyhow::{anyhow, Result};

use crate::conv::{suites, ConvOp, ConvProblem};
use crate::gpusim::Epilogue;

use super::node::{Node, NodeId, Op, Shape};

/// A validated DAG of layers.  Nodes are stored in insertion order and
/// every edge points from a lower to a higher id, so insertion order is
/// one topological order (the scheduler still derives its own).
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    nodes: Vec<Node>,
}

impl Graph {
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// consumers[id] = ids of nodes reading `id` (one entry per edge).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![vec![]; self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Nodes no other node consumes — the network outputs.
    pub fn outputs(&self) -> Vec<NodeId> {
        self.consumers()
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_empty())
            .map(|(id, _)| id)
            .collect()
    }

    /// Distinct conv ops in node order — what the router pre-dispatches
    /// for a registered model.
    pub fn conv_ops(&self) -> Vec<ConvOp> {
        let mut out: Vec<ConvOp> = vec![];
        for n in &self.nodes {
            if let Op::Conv { conv, .. } = n.op {
                if !out.contains(&conv) {
                    out.push(conv);
                }
            }
        }
        out
    }

    /// Number of conv nodes (layer instances, not distinct ops).
    pub fn conv_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_conv()).count()
    }

    /// Re-check every structural invariant the builder enforced — used
    /// by the property tests on generated graphs.
    pub fn validate(&self) -> Result<()> {
        for n in &self.nodes {
            for &i in &n.inputs {
                if i >= n.id {
                    return Err(anyhow!("{}: edge {} -> {} not forward", n.name, i, n.id));
                }
            }
            let ins: Vec<Shape> = n.inputs.iter().map(|&i| self.nodes[i].shape).collect();
            let got = infer_shape(&n.op, &ins)
                .map_err(|e| e.context(format!("node {}", n.name)))?;
            if got != n.shape {
                return Err(anyhow!(
                    "{}: stored shape {} != inferred {}",
                    n.name,
                    n.shape.label(),
                    got.label()
                ));
            }
        }
        if self.nodes.is_empty() {
            return Err(anyhow!("empty graph"));
        }
        Ok(())
    }
}

/// Shape rule of each operator over its input shapes.
pub fn infer_shape(op: &Op, inputs: &[Shape]) -> Result<Shape> {
    let arity = |n: usize| -> Result<()> {
        if inputs.len() == n {
            Ok(())
        } else {
            Err(anyhow!("{} wants {} inputs, got {}", op.kind(), n, inputs.len()))
        }
    };
    match *op {
        Op::Input { shape } => {
            arity(0)?;
            if shape.elems() == 0 {
                return Err(anyhow!("input with empty shape"));
            }
            Ok(shape)
        }
        Op::Conv { conv, epilogue } => {
            // an AddResidual conv reads its residual as a second input
            arity(if epilogue == Epilogue::AddResidual { 2 } else { 1 })?;
            if !conv.valid() {
                return Err(anyhow!("invalid conv op {}", conv.label()));
            }
            let want = Shape::new(conv.core.c, conv.core.wy, conv.core.wx);
            if inputs[0] != want {
                return Err(anyhow!(
                    "conv {} wants input {}, got {}",
                    conv.label(),
                    want.label(),
                    inputs[0].label()
                ));
            }
            let out = Shape::new(conv.core.m, conv.oy(), conv.ox());
            match epilogue {
                Epilogue::None | Epilogue::Relu => Ok(out),
                Epilogue::AddResidual => {
                    if inputs[1] != out {
                        return Err(anyhow!(
                            "fused residual {} does not match conv output {}",
                            inputs[1].label(),
                            out.label()
                        ));
                    }
                    Ok(out)
                }
                Epilogue::MaxPoolWriteback { k, stride } => {
                    if k < 1 || stride < 1 || k > out.h || k > out.w {
                        return Err(anyhow!(
                            "fused pool k={k} s={stride} does not fit {}",
                            out.label()
                        ));
                    }
                    let (py, px) = epilogue.pooled_hw(out.h, out.w);
                    Ok(Shape::new(out.c, py, px))
                }
            }
        }
        Op::Pad { h, w } => {
            arity(1)?;
            let s = inputs[0];
            if h < s.h || w < s.w {
                return Err(anyhow!("pad to {h}x{w} shrinks {}", s.label()));
            }
            Ok(Shape::new(s.c, h, w))
        }
        Op::Pool { k, stride } => {
            arity(1)?;
            let s = inputs[0];
            if k < 1 || stride < 1 || k > s.h || k > s.w {
                return Err(anyhow!("pool k={k} s={stride} does not fit {}", s.label()));
            }
            Ok(Shape::new(s.c, (s.h - k) / stride + 1, (s.w - k) / stride + 1))
        }
        Op::Relu => {
            arity(1)?;
            Ok(inputs[0])
        }
        Op::Add => {
            arity(2)?;
            if inputs[0] != inputs[1] {
                return Err(anyhow!(
                    "add of mismatched shapes {} vs {}",
                    inputs[0].label(),
                    inputs[1].label()
                ));
            }
            Ok(inputs[0])
        }
        Op::Concat { .. } => {
            if inputs.len() < 2 {
                return Err(anyhow!("concat wants >= 2 inputs, got {}", inputs.len()));
            }
            let (h, w) = (inputs[0].h, inputs[0].w);
            if inputs.iter().any(|s| s.h != h || s.w != w) {
                return Err(anyhow!("concat of mismatched maps"));
            }
            Ok(Shape::new(inputs.iter().map(|s| s.c).sum(), h, w))
        }
    }
}

/// Incremental graph builder.  Every `add` validates arity, edge
/// direction (inputs must already exist) and the operator's shape rule,
/// so a finished graph is structurally sound by construction.
#[derive(Clone, Debug)]
pub struct GraphBuilder {
    name: String,
    nodes: Vec<Node>,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder { name: name.to_string(), nodes: vec![] }
    }

    /// Generic validated insertion; the typed helpers below all land here.
    pub fn add(&mut self, name: &str, op: Op, inputs: &[NodeId]) -> Result<NodeId> {
        let mut shapes = Vec::with_capacity(inputs.len());
        for &i in inputs {
            let n = self
                .nodes
                .get(i)
                .ok_or_else(|| anyhow!("{name}: input node {i} does not exist"))?;
            shapes.push(n.shape);
        }
        let shape =
            infer_shape(&op, &shapes).map_err(|e| e.context(format!("node {name}")))?;
        let id = self.nodes.len();
        self.nodes.push(Node { id, name: name.to_string(), op, inputs: inputs.to_vec(), shape });
        Ok(id)
    }

    pub fn input(&mut self, name: &str, shape: Shape) -> NodeId {
        self.add(name, Op::Input { shape }, &[]).expect("input nodes cannot fail")
    }

    /// Output shape of an already-added node (graph generators and the
    /// model builders peek at intermediate shapes).
    pub fn node_shape(&self, id: NodeId) -> Shape {
        self.nodes[id].shape
    }

    /// Nodes added so far.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// A conv node carrying a full op (unfused; the fusion pass
    /// rewrites epilogues in).
    pub fn conv_op(&mut self, name: &str, input: NodeId, conv: ConvOp) -> Result<NodeId> {
        self.add(name, Op::Conv { conv, epilogue: Epilogue::None }, &[input])
    }

    /// A dense (stride-1, valid) conv — the historical builder entry.
    pub fn conv(&mut self, name: &str, input: NodeId, problem: ConvProblem) -> Result<NodeId> {
        self.conv_op(name, input, ConvOp::dense(problem))
    }

    /// 'same' convolution: op-level padding keeps the nominal map (odd
    /// K; K=1 needs no pad and gets none).  One node — no graph-side
    /// `Pad` follows.
    pub fn conv_same(&mut self, name: &str, input: NodeId, problem: ConvProblem) -> Result<NodeId> {
        let conv = if problem.k == 1 { ConvOp::dense(problem) } else { ConvOp::same(problem) };
        self.conv_op(name, input, conv)
    }

    pub fn pad(&mut self, name: &str, input: NodeId, h: usize, w: usize) -> Result<NodeId> {
        self.add(name, Op::Pad { h, w }, &[input])
    }

    pub fn pool(&mut self, name: &str, input: NodeId, k: usize, stride: usize) -> Result<NodeId> {
        self.add(name, Op::Pool { k, stride }, &[input])
    }

    pub fn relu(&mut self, name: &str, input: NodeId) -> Result<NodeId> {
        self.add(name, Op::Relu, &[input])
    }

    pub fn add_skip(&mut self, name: &str, a: NodeId, b: NodeId) -> Result<NodeId> {
        self.add(name, Op::Add, &[a, b])
    }

    pub fn concat(&mut self, name: &str, inputs: &[NodeId]) -> Result<NodeId> {
        self.add(name, Op::Concat { zero_copy: false }, inputs)
    }

    pub fn finish(self) -> Result<Graph> {
        if self.nodes.is_empty() {
            return Err(anyhow!("{}: empty graph", self.name));
        }
        Ok(Graph { name: self.name, nodes: self.nodes })
    }
}

// ---------------------------------------------------------------------------
// the evaluation models as graphs
// ---------------------------------------------------------------------------

/// Model names `model_graph` accepts (what the router registers and the
/// CLI's `--model` takes).
pub const MODEL_NAMES: [&str; 5] =
    ["alexnet", "vgg16", "resnet18", "inception3a", "mobilenet_v1"];

/// Build a named model graph.  Names are canonical (`MODEL_NAMES`):
/// every `Graph::name` equals the name that built it, so registries can
/// key on either interchangeably.
pub fn model_graph(name: &str) -> Result<Graph> {
    match name {
        "alexnet" => Ok(alexnet_graph()),
        "vgg16" => Ok(vgg16_graph()),
        "resnet18" => Ok(resnet18_graph()),
        "inception3a" => Ok(inception3a_graph()),
        "mobilenet_v1" => Ok(mobilenet_v1_graph()),
        _ => Err(anyhow!(
            "unknown model '{name}' (available: {})",
            MODEL_NAMES.join(", ")
        )),
    }
}

/// AlexNet's conv body (conv2..conv5, the `suites::alexnet` ops) with
/// its per-conv ReLUs and inter-stage 3x3/s2 max pools.
pub fn alexnet_graph() -> Graph {
    let l = suites::alexnet();
    let mut b = GraphBuilder::new("alexnet");
    let x = b.input("in", Shape::new(96, 27, 27));
    let x = b.conv_op("conv2", x, l[0]).expect("alexnet conv2");
    let x = b.relu("relu2", x).expect("alexnet relu2");
    let x = b.pool("pool2", x, 3, 2).expect("alexnet pool2");
    let x = b.conv_op("conv3", x, l[1]).expect("alexnet conv3");
    let x = b.relu("relu3", x).expect("alexnet relu3");
    let x = b.conv_op("conv4", x, l[2]).expect("alexnet conv4");
    let x = b.relu("relu4", x).expect("alexnet relu4");
    let x = b.conv_op("conv5", x, l[3]).expect("alexnet conv5");
    let x = b.relu("relu5", x).expect("alexnet relu5");
    b.pool("pool5", x, 3, 2).expect("alexnet pool5");
    b.finish().expect("alexnet graph")
}

/// VGG-16's 13-conv body: five blocks of 'same' 3x3 convs (each
/// followed by its ReLU), each block closed by a 2x2/s2 max pool.
/// Repeated layers reuse the same `ConvOp`, so the distinct ops are
/// exactly `suites::vgg16`.
pub fn vgg16_graph() -> Graph {
    let mut b = GraphBuilder::new("vgg16");
    let mut x = b.input("in", Shape::new(3, 224, 224));
    // (C_in, map, C_out, convs in block)
    let blocks: [(usize, usize, usize, usize); 5] = [
        (3, 224, 64, 2),
        (64, 112, 128, 2),
        (128, 56, 256, 3),
        (256, 28, 512, 3),
        (512, 14, 512, 3),
    ];
    for (bi, &(c_in, w, c_out, n)) in blocks.iter().enumerate() {
        for i in 0..n {
            let c = if i == 0 { c_in } else { c_out };
            let p = ConvProblem::multi(c, w, c_out, 3);
            x = b
                .conv_same(&format!("conv{}_{}", bi + 1, i + 1), x, p)
                .expect("vgg16 conv");
            x = b.relu(&format!("relu{}_{}", bi + 1, i + 1), x).expect("vgg16 relu");
        }
        x = b.pool(&format!("pool{}", bi + 1), x, 2, 2).expect("vgg16 pool");
    }
    b.finish().expect("vgg16 graph")
}

/// ResNet-18's body with its TRUE geometry: four stages of two basic
/// blocks on 56/28/14/7 maps; every stage transition downsamples with
/// a native 3x3/s2 conv and a 1x1/s2 projection on the skip — both on
/// the previous stage's map (the seed's pool + stride-1 approximation
/// is gone).  Every residual `Add` keeps its block input live across
/// the block — the lifetimes the arena planner exists for.
pub fn resnet18_graph() -> Graph {
    let mut b = GraphBuilder::new("resnet18");
    let mut x = b.input("in", Shape::new(64, 56, 56));
    // (C_in, C_out, input map, first-block stride) per stage
    let stages: [(usize, usize, usize, usize); 4] =
        [(64, 64, 56, 1), (64, 128, 56, 2), (128, 256, 28, 2), (256, 512, 14, 2)];
    for (si, &(c_in, c_out, w_in, stride)) in stages.iter().enumerate() {
        let s = si + 1;
        let w_out = (w_in - 1) / stride + 1;
        for blk in 1..=2usize {
            let transition = blk == 1 && (stride > 1 || c_in != c_out);
            let (ca, proj) = if transition {
                (
                    ConvOp::strided(ConvProblem::multi(c_in, w_in, c_out, 3), stride, 1),
                    Some(ConvOp::strided(ConvProblem::multi(c_in, w_in, c_out, 1), stride, 0)),
                )
            } else {
                (ConvOp::same(ConvProblem::multi(c_out, w_out, c_out, 3)), None)
            };
            let cb = ConvOp::same(ConvProblem::multi(c_out, w_out, c_out, 3));
            let a = b.conv_op(&format!("s{s}b{blk}c1"), x, ca).expect("resnet18 conv");
            let a = b.relu(&format!("s{s}b{blk}relu1"), a).expect("resnet18 relu");
            let c2 = b.conv_op(&format!("s{s}b{blk}c2"), a, cb).expect("resnet18 conv");
            let skip = match proj {
                Some(p) => b.conv_op(&format!("s{s}proj"), x, p).expect("resnet18 proj"),
                None => x,
            };
            let sum =
                b.add_skip(&format!("s{s}b{blk}add"), c2, skip).expect("resnet18 add");
            x = b.relu(&format!("s{s}b{blk}relu2"), sum).expect("resnet18 relu");
        }
    }
    b.finish().expect("resnet18 graph")
}

/// GoogLeNet inception(3a): four parallel branches over the 192x28x28
/// input (1x1 / 1x1+3x3 / 1x1+5x5 / 3x3-pool+1x1) concatenated to
/// 256x28x28 — built from `suites::googlenet_inception3a_branches`.
/// The conv padding is op-level; the pool branch keeps a graph-side
/// pad (pool framing, not a conv input transform).
pub fn inception3a_graph() -> Graph {
    let br = suites::googlenet_inception3a_branches();
    assert_eq!(br.len(), 4, "inception(3a) has four branches");
    let mut b = GraphBuilder::new("inception3a");
    let x = b.input("in", Shape::new(192, 28, 28));
    let b1 = b.conv_op("b1.1x1", x, br[0][0]).expect("inception b1");
    let b1 = b.relu("b1.relu", b1).expect("inception relu");
    let t = b.conv_op("b2.reduce", x, br[1][0]).expect("inception b2r");
    let t = b.relu("b2.reduce.relu", t).expect("inception relu");
    let b2 = b.conv_op("b2.3x3", t, br[1][1]).expect("inception b2");
    let b2 = b.relu("b2.relu", b2).expect("inception relu");
    let t = b.conv_op("b3.reduce", x, br[2][0]).expect("inception b3r");
    let t = b.relu("b3.reduce.relu", t).expect("inception relu");
    let b3 = b.conv_op("b3.5x5", t, br[2][1]).expect("inception b3");
    let b3 = b.relu("b3.relu", b3).expect("inception relu");
    let t = b.pool("b4.pool", x, 3, 1).expect("inception pool");
    let t = b.pad("b4.pool.pad", t, 28, 28).expect("inception pad");
    let b4 = b.conv_op("b4.proj", t, br[3][0]).expect("inception b4");
    let b4 = b.relu("b4.relu", b4).expect("inception relu");
    b.concat("concat", &[b1, b2, b3, b4]).expect("inception concat");
    b.finish().expect("inception3a graph")
}

/// MobileNetV1 (width 1.0, 224x224): the strided first conv, 13
/// depthwise-separable blocks (`suites::mobilenet_v1` in order), and
/// the global 7x7 pool — a model family the pre-op-layer graph could
/// not express at all.
pub fn mobilenet_v1_graph() -> Graph {
    let ops = suites::mobilenet_v1();
    let mut b = GraphBuilder::new("mobilenet_v1");
    let mut x = b.input("in", Shape::new(3, 224, 224));
    x = b.conv_op("conv1", x, ops[0]).expect("mobilenet conv1");
    x = b.relu("conv1.relu", x).expect("mobilenet relu");
    for (i, pair) in ops[1..].chunks(2).enumerate() {
        let blk = i + 1;
        x = b.conv_op(&format!("b{blk}.dw"), x, pair[0]).expect("mobilenet dw");
        x = b.relu(&format!("b{blk}.dw.relu"), x).expect("mobilenet relu");
        x = b.conv_op(&format!("b{blk}.pw"), x, pair[1]).expect("mobilenet pw");
        x = b.relu(&format!("b{blk}.pw.relu"), x).expect("mobilenet relu");
    }
    b.pool("avgpool", x, 7, 1).expect("mobilenet pool");
    b.finish().expect("mobilenet_v1 graph")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build_and_validate() {
        // (graph-ops == suite-ops is an acceptance gate, asserted in
        // rust/tests/integration_graph.rs)
        for name in MODEL_NAMES {
            let g = model_graph(name).unwrap();
            assert!(g.validate().is_ok(), "{name}");
            assert!(g.len() > 5, "{name}: only {} nodes", g.len());
        }
        assert!(model_graph("lenet").is_err());
    }

    #[test]
    fn vgg16_has_the_full_13_conv_body() {
        let g = vgg16_graph();
        assert_eq!(g.conv_nodes(), 13);
        // op-level 'same' padding: 13 convs + 13 relus + 5 pools +
        // input, no pads
        assert_eq!(g.len(), 32);
        // output after five 2x2 pools: 512 x 7 x 7
        let out = g.outputs();
        assert_eq!(out.len(), 1);
        assert_eq!(g.node(out[0]).shape, Shape::new(512, 7, 7));
    }

    #[test]
    fn alexnet_output_shape() {
        let g = alexnet_graph();
        let out = g.outputs();
        assert_eq!(out.len(), 1);
        assert_eq!(g.node(out[0]).shape, Shape::new(256, 6, 6));
        assert_eq!(g.conv_nodes(), 4);
        // conv2..5 each carry a ReLU; pools frame the stages
        assert_eq!(g.nodes().iter().filter(|n| matches!(n.op, Op::Relu)).count(), 4);
        assert_eq!(g.len(), 11);
    }

    #[test]
    fn resnet18_downsamples_with_native_stride() {
        let g = resnet18_graph();
        assert_eq!(g.conv_nodes(), 16 + 3); // 8 blocks x 2 convs + 3 projections
        // no pools survive: downsampling is conv-native now
        assert!(
            !g.nodes().iter().any(|n| matches!(n.op, Op::Pool { .. })),
            "pool-based downsampling approximation survived"
        );
        let strided: Vec<&Node> = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::Conv { conv, .. } if conv.stride == 2))
            .collect();
        assert_eq!(strided.len(), 6, "3 transitions x (conv + projection)");
        // every add has two distinct inputs (main path + skip)
        let adds: Vec<&Node> =
            g.nodes().iter().filter(|n| matches!(n.op, Op::Add)).collect();
        assert_eq!(adds.len(), 8);
        // one ReLU after each block's first conv and one after each add
        assert_eq!(g.nodes().iter().filter(|n| matches!(n.op, Op::Relu)).count(), 16);
        for a in adds {
            assert_ne!(a.inputs[0], a.inputs[1], "{}", a.name);
        }
        let out = g.outputs();
        assert_eq!(out.len(), 1);
        assert_eq!(g.node(out[0]).shape, Shape::new(512, 7, 7));
        // graph ops == the rebuilt suite
        let got = g.conv_ops();
        for op in crate::conv::suites::resnet18() {
            assert!(got.contains(&op), "missing {}", op.label());
        }
    }

    #[test]
    fn mobilenet_v1_builds_the_separable_stack() {
        let g = mobilenet_v1_graph();
        assert_eq!(g.conv_nodes(), 27);
        let out = g.outputs();
        assert_eq!(out.len(), 1);
        assert_eq!(g.node(out[0]).shape, Shape::new(1024, 1, 1));
        // depthwise nodes carry real grouped ops
        let dw = g
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::Conv { conv, .. } if conv.is_depthwise()))
            .count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn inception_concat_is_256_channels() {
        let g = inception3a_graph();
        let out = g.outputs();
        assert_eq!(out.len(), 1);
        let o = g.node(out[0]);
        assert!(matches!(o.op, Op::Concat { zero_copy: false }));
        assert_eq!(o.shape, Shape::new(256, 28, 28));
        assert_eq!(o.inputs.len(), 4);
        // the input feeds all four branches
        let consumers = g.consumers();
        assert!(consumers[0].len() >= 4, "input fan-out {}", consumers[0].len());
    }

    #[test]
    fn builder_rejects_shape_mismatches() {
        let mut b = GraphBuilder::new("bad");
        let x = b.input("in", Shape::new(8, 14, 14));
        // conv expecting 16 channels on an 8-channel tensor
        assert!(b.conv("c", x, ConvProblem::multi(16, 14, 8, 3)).is_err());
        // invalid op (bad group split)
        assert!(b
            .conv_op(
                "g",
                x,
                ConvOp { core: ConvProblem::multi(8, 14, 9, 3), stride: 1, pad: 0, groups: 2 }
            )
            .is_err());
        // pad cannot shrink
        assert!(b.pad("p", x, 7, 7).is_err());
        // pool window larger than the map
        assert!(b.pool("q", x, 15, 1).is_err());
        // add of mismatched shapes
        let y = b.pool("half", x, 2, 2).unwrap();
        assert!(b.add_skip("a", x, y).is_err());
        // concat needs >= 2 inputs
        assert!(b.concat("cat", &[x]).is_err());
        // fused pool epilogue must fit the conv's output map
        assert!(b
            .add(
                "fp",
                Op::Conv {
                    conv: ConvOp::dense(ConvProblem::multi(8, 14, 8, 3)),
                    epilogue: Epilogue::MaxPoolWriteback { k: 15, stride: 1 },
                },
                &[x]
            )
            .is_err());
        // a fused residual must match the conv output shape
        assert!(b
            .add(
                "fa",
                Op::Conv {
                    conv: ConvOp::dense(ConvProblem::multi(8, 14, 8, 3)),
                    epilogue: Epilogue::AddResidual,
                },
                &[x, x]
            )
            .is_err());
        // unknown input id
        assert!(b.conv("dangling", 99, ConvProblem::multi(8, 14, 8, 3)).is_err());
    }

    #[test]
    fn conv_same_is_one_padded_node() {
        let mut b = GraphBuilder::new("same");
        let x = b.input("in", Shape::new(16, 28, 28));
        let y = b.conv_same("c3", x, ConvProblem::multi(16, 28, 32, 3)).unwrap();
        assert_eq!(b.nodes[y].shape, Shape::new(32, 28, 28));
        assert!(matches!(b.nodes[y].op, Op::Conv { conv, .. } if conv.pad == 1));
        // K=1 needs no padding
        let z = b.conv_same("c1", y, ConvProblem::multi(32, 28, 32, 1)).unwrap();
        assert_eq!(b.nodes[z].shape, Shape::new(32, 28, 28));
        assert!(matches!(b.nodes[z].op, Op::Conv { conv, .. } if conv.is_dense()));
        // a strided conv node downsamples in one hop
        let s = b
            .conv_op("down", z, ConvOp::strided(ConvProblem::multi(32, 28, 64, 3), 2, 1))
            .unwrap();
        assert_eq!(b.nodes[s].shape, Shape::new(64, 14, 14));
        let g = b.finish().unwrap();
        assert!(g.validate().is_ok());
    }

    #[test]
    fn insertion_order_is_topological() {
        for name in MODEL_NAMES {
            let g = model_graph(name).unwrap();
            for n in g.nodes() {
                for &i in &n.inputs {
                    assert!(i < n.id, "{name}/{}: backward edge", n.name);
                }
            }
        }
    }
}
