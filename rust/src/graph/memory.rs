//! Inter-layer memory planning: tensor liveness over a schedule, and a
//! greedy offset-assignment arena allocator.
//!
//! The pre-graph model (`model_stacks` summation) implicitly holds every
//! layer's tensor for the whole network — the "naive sum of tensors".
//! Li et al. ("Optimizing Memory Efficiency for Deep Convolutional
//! Neural Networks on GPUs") show the real bound is the peak of
//! *simultaneously live* tensors; this module computes that peak and an
//! offset plan achieving it (best-fit-by-size, the TFLite/TVM shared
//! arena approach), so the reports can state bytes saved exactly.

use std::collections::HashMap;

use crate::fleet::pool::{DevicePool, PoolError};

use super::build::Graph;
use super::node::{NodeId, Op};

/// Device allocation granularity: every tensor is rounded up to this
/// before planning, so offsets are always usable as real sub-allocations.
pub const ARENA_ALIGN: usize = 256;

fn align(bytes: usize) -> usize {
    (bytes + ARENA_ALIGN - 1) / ARENA_ALIGN * ARENA_ALIGN
}

/// One tensor's lifetime under a schedule: produced at step `def_step`,
/// last read at step `last_use_step` (inclusive; schedule positions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorLife {
    /// producing node
    pub id: NodeId,
    /// aligned device bytes
    pub bytes: usize,
    pub def_step: usize,
    pub last_use_step: usize,
}

impl TensorLife {
    /// Do two lifetimes share any schedule step?
    pub fn overlaps(&self, other: &TensorLife) -> bool {
        self.def_step <= other.last_use_step && other.def_step <= self.last_use_step
    }
}

/// Producers that write straight into a zero-copy concat's output:
/// `producer id -> (concat id, exact byte offset of the producer's
/// channel prefix inside the concat tensor)`.  A producer only
/// qualifies when the concat is its SOLE consumer — a tensor read by
/// anyone else needs its own storage, so it keeps an owned placement
/// and the planner stays conservative.
pub fn zero_copy_aliases(g: &Graph) -> HashMap<NodeId, (NodeId, usize)> {
    let consumers = g.consumers();
    let mut out = HashMap::new();
    for n in g.nodes() {
        if !matches!(n.op, Op::Concat { zero_copy: true }) {
            continue;
        }
        let mut prefix = 0usize;
        for &i in &n.inputs {
            let bytes = g.node(i).shape.bytes();
            if consumers[i] == [n.id] {
                out.insert(i, (n.id, prefix));
            }
            prefix += bytes;
        }
    }
    out
}

/// Tensor lifetimes for `g` executed in `order` (`order[i]` runs at step
/// i; must be a permutation of the nodes in topological order).  Every
/// node produces one tensor; graph outputs stay live through the final
/// step.  A zero-copy concat's tensor is live from its EARLIEST aliased
/// producer's step — the producers write into it, so the allocation
/// must exist before the concat node itself is scheduled.
pub fn liveness(g: &Graph, order: &[NodeId]) -> Vec<TensorLife> {
    assert_eq!(order.len(), g.len(), "order must schedule every node exactly once");
    let mut pos = vec![usize::MAX; g.len()];
    for (i, &id) in order.iter().enumerate() {
        assert_eq!(pos[id], usize::MAX, "node {id} scheduled twice");
        pos[id] = i;
    }
    let consumers = g.consumers();
    let aliases = zero_copy_aliases(g);
    order
        .iter()
        .map(|&id| {
            let mut def = pos[id];
            if matches!(g.node(id).op, Op::Concat { zero_copy: true }) {
                for (&p, &(cid, _)) in &aliases {
                    if cid == id {
                        def = def.min(pos[p]);
                    }
                }
            }
            let last = consumers[id]
                .iter()
                .map(|&c| pos[c])
                .max()
                .unwrap_or(order.len() - 1); // outputs: live to the end
            assert!(last >= def, "node {id}: consumer scheduled before producer");
            TensorLife {
                id,
                bytes: align(g.node(id).shape.bytes()),
                def_step: def,
                last_use_step: last,
            }
        })
        .collect()
}

/// One tensor's placement in the arena.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub life: TensorLife,
    /// byte offset within the arena
    pub offset: usize,
    /// `Some(concat id)` when this tensor is a zero-copy sub-range of
    /// a concat output: `offset` points inside the concat's allocation
    /// (at the producer's channel prefix) and the bytes are owned by
    /// the concat placement, not this one
    pub alias_of: Option<NodeId>,
}

/// Offset plan for a whole schedule, plus the headline numbers.
#[derive(Clone, Debug)]
pub struct ArenaPlan {
    /// placements in schedule (def_step) order
    pub placements: Vec<Placement>,
    /// arena bytes required: max over tensors of offset + size
    pub peak_bytes: usize,
    /// sum of all tensor bytes — what keeping every tensor resident for
    /// the whole network (the flat per-layer model) would hold
    pub naive_bytes: usize,
}

impl ArenaPlan {
    pub fn saved_bytes(&self) -> usize {
        self.naive_bytes.saturating_sub(self.peak_bytes)
    }

    pub fn saved_fraction(&self) -> f64 {
        if self.naive_bytes == 0 {
            0.0
        } else {
            self.saved_bytes() as f64 / self.naive_bytes as f64
        }
    }

    /// Max bytes simultaneously live at any step — the information-
    /// theoretic floor no allocator can beat.  peak_bytes >= this; the
    /// gap is fragmentation.  Alias placements own no bytes (their
    /// storage is the concat's), so they are excluded.
    pub fn live_peak_bytes(&self) -> usize {
        let last = self.placements.iter().map(|p| p.life.last_use_step).max().unwrap_or(0);
        (0..=last)
            .map(|step| {
                self.placements
                    .iter()
                    .filter(|p| p.alias_of.is_none())
                    .filter(|p| p.life.def_step <= step && step <= p.life.last_use_step)
                    .map(|p| p.life.bytes)
                    .sum()
            })
            .max()
            .unwrap_or(0)
    }
}

/// Plan the arena for `g` under `order`: liveness, then greedy best-fit
/// offset assignment — tensors in size-descending order, each placed at
/// the lowest aligned offset free of every already-placed tensor whose
/// lifetime overlaps.  Never exceeds the naive sum (placing at the end
/// of everything placed so far is always available), and typically sits
/// near `live_peak_bytes`.
///
/// Producers of a zero-copy concat are not placed independently: each
/// becomes an alias placement at `concat offset + channel prefix`
/// inside the concat's allocation (which is live from the earliest
/// producer), so the concat's copy bytes AND the producers' separate
/// tensors both vanish from the plan.
pub fn plan_arena(g: &Graph, order: &[NodeId]) -> ArenaPlan {
    let lives = liveness(g, order);
    let aliases = zero_copy_aliases(g);
    let owned = |l: &TensorLife| !aliases.contains_key(&l.id);
    let naive: usize = lives.iter().filter(|l| owned(l)).map(|l| l.bytes).sum();

    let mut by_size: Vec<usize> = (0..lives.len()).filter(|&i| owned(&lives[i])).collect();
    by_size.sort_by(|&a, &b| {
        lives[b].bytes.cmp(&lives[a].bytes).then(lives[a].id.cmp(&lives[b].id))
    });

    let mut placements: Vec<Placement> = Vec::with_capacity(lives.len());
    for &i in &by_size {
        let life = lives[i];
        // already-placed lifetime-overlapping tensors, by offset
        let mut busy: Vec<(usize, usize)> = placements
            .iter()
            .filter(|p| p.life.overlaps(&life))
            .map(|p| (p.offset, p.offset + p.life.bytes))
            .collect();
        busy.sort_unstable();
        // first-fit scan over the gaps
        let mut offset = 0usize;
        for (lo, hi) in busy {
            if offset + life.bytes <= lo {
                break;
            }
            offset = offset.max(hi);
        }
        placements.push(Placement { life, offset, alias_of: None });
    }

    let peak = placements.iter().map(|p| p.offset + p.life.bytes).max().unwrap_or(0);

    // alias placements: inside the (already placed) concat allocation
    for l in lives.iter().filter(|l| !owned(l)) {
        let (cid, prefix) = aliases[&l.id];
        debug_assert_eq!(
            prefix % ARENA_ALIGN,
            0,
            "zero-copy sub-range offsets must be ARENA_ALIGN multiples"
        );
        let concat_off = placements
            .iter()
            .find(|p| p.life.id == cid)
            .expect("concat placed before its aliases")
            .offset;
        placements.push(Placement {
            life: *l,
            offset: concat_off + prefix,
            alias_of: Some(cid),
        });
    }

    placements.sort_by_key(|p| p.life.def_step);
    ArenaPlan { placements, peak_bytes: peak, naive_bytes: naive }
}

/// What one pooled execution did to its device pool — the multi-tenant
/// counterpart of `ArenaPlan`'s headline numbers.
#[derive(Clone, Copy, Debug)]
pub struct PooledPlan {
    /// high-water mark of THIS execution's live bytes in the pool —
    /// the per-tensor live floor, never worse than the arena peak
    /// (tensors are freed at last use instead of holding a whole-arena
    /// reservation, so a fragmented `ArenaPlan` is strictly beaten)
    pub peak_bytes: usize,
    /// sum of all tensor bytes (the naive keep-everything footprint)
    pub naive_bytes: usize,
    /// pool allocations this execution made (= owned tensors: every
    /// graph node except zero-copy concat producers, which write into
    /// the concat's allocation)
    pub allocs: u64,
    /// how many of them reused a parked slab instead of carving
    pub reuse_hits: u64,
    /// free slabs the pool evicted to make room during this execution
    pub evictions: u64,
}

/// Execute `g`'s memory schedule against a shared device pool: walk
/// `order`, allocating each node's tensor (scaled by `batch`) at its
/// definition step and freeing every tensor right after its last use —
/// per-tensor granularity, so many executions interleave on one pool
/// under its hard cap.  On exhaustion, every allocation this call made
/// is released and the error is returned (the pool is left consistent;
/// evictions of parked slabs along the way persist — they were free).
pub fn plan_pooled(
    g: &Graph,
    order: &[NodeId],
    batch: usize,
    pool: &mut DevicePool,
) -> Result<PooledPlan, PoolError> {
    assert!(batch >= 1, "batch must be >= 1");
    let lives = liveness(g, order);
    let aliases = zero_copy_aliases(g);
    let owned = |id: NodeId| !aliases.contains_key(&id);
    let naive: usize =
        lives.iter().filter(|l| owned(l.id)).map(|l| l.bytes * batch).sum();
    let (reuse0, evict0) = (pool.stats.reuse_hits, pool.stats.evictions);
    // which owned tensors come alive at each step — a zero-copy
    // concat's allocation materializes at its FIRST producer's step
    // (its widened def_step), not at its own; aliased producers
    // allocate nothing at all
    let mut alloc_at: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, l) in lives.iter().enumerate() {
        if owned(l.id) {
            alloc_at.entry(l.def_step).or_default().push(i);
        }
    }
    let mut ids: Vec<Option<u64>> = vec![None; lives.len()];
    let (mut live_now, mut peak) = (0usize, 0usize);
    let mut allocs = 0u64;
    for step in 0..lives.len() {
        for &i in alloc_at.get(&step).map(Vec::as_slice).unwrap_or(&[]) {
            let bytes = lives[i].bytes * batch;
            match pool.alloc(bytes) {
                Ok(id) => {
                    ids[i] = Some(id);
                    allocs += 1;
                }
                Err(e) => {
                    for id in ids.iter_mut().filter_map(Option::take) {
                        pool.free(id).expect("own allocation");
                    }
                    return Err(e);
                }
            }
            live_now += bytes;
            peak = peak.max(live_now);
        }
        // inputs whose last read is this step die now (they overlap the
        // step itself: read while the output is written, then released)
        for (j, l) in lives.iter().enumerate().take(step + 1) {
            if l.last_use_step == step {
                if let Some(id) = ids[j].take() {
                    pool.free(id).expect("own allocation");
                    live_now -= l.bytes * batch;
                }
            }
        }
    }
    debug_assert!(ids.iter().all(Option::is_none), "every tensor freed");
    Ok(PooledPlan {
        peak_bytes: peak,
        naive_bytes: naive,
        allocs,
        reuse_hits: pool.stats.reuse_hits - reuse0,
        evictions: pool.stats.evictions - evict0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvProblem;
    use crate::graph::build::{model_graph, GraphBuilder, MODEL_NAMES};
    use crate::graph::exec::topo_order;
    use crate::graph::node::Shape;

    fn chain(n: usize) -> Graph {
        // in -> conv -> conv -> ... (all same shape via conv_same)
        let mut b = GraphBuilder::new("chain");
        let mut x = b.input("in", Shape::new(8, 14, 14));
        for i in 0..n {
            x = b.conv_same(&format!("c{i}"), x, ConvProblem::multi(8, 14, 8, 3)).unwrap();
        }
        b.finish().unwrap()
    }

    #[test]
    fn alignment_rounds_up() {
        assert_eq!(align(1), 256);
        assert_eq!(align(256), 256);
        assert_eq!(align(257), 512);
    }

    #[test]
    fn chain_liveness_is_tight() {
        let g = chain(4);
        let order = topo_order(&g);
        let lives = liveness(&g, &order);
        // every non-output tensor dies at its single consumer's step
        let consumers = g.consumers();
        for l in &lives {
            if let Some(&c) = consumers[l.id].first() {
                assert_eq!(l.last_use_step, order.iter().position(|&x| x == c).unwrap());
            } else {
                assert_eq!(l.last_use_step, order.len() - 1);
            }
        }
    }

    #[test]
    fn chain_arena_is_two_buffers_deep() {
        // a pure chain only ever has producer + consumer live: the arena
        // peak is about two adjacent tensors, far below the naive sum
        let g = chain(8);
        let plan = plan_arena(&g, &topo_order(&g));
        assert!(plan.peak_bytes < plan.naive_bytes / 3, "peak {} naive {}", plan.peak_bytes, plan.naive_bytes);
        assert_eq!(plan.peak_bytes, plan.live_peak_bytes(), "chain should not fragment");
    }

    #[test]
    fn no_two_live_tensors_overlap_in_the_arena() {
        for name in MODEL_NAMES {
            let g = model_graph(name).unwrap();
            let plan = plan_arena(&g, &topo_order(&g));
            for (i, a) in plan.placements.iter().enumerate() {
                for b in &plan.placements[i + 1..] {
                    if a.life.overlaps(&b.life) {
                        let disjoint = a.offset + a.life.bytes <= b.offset
                            || b.offset + b.life.bytes <= a.offset;
                        assert!(
                            disjoint,
                            "{name}: nodes {} and {} overlap in space and time",
                            a.life.id, b.life.id
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn peak_bounded_by_floor_and_naive() {
        for name in MODEL_NAMES {
            let g = model_graph(name).unwrap();
            let plan = plan_arena(&g, &topo_order(&g));
            assert!(plan.peak_bytes >= plan.live_peak_bytes(), "{name}");
            assert!(plan.peak_bytes <= plan.naive_bytes, "{name}");
            // the whole point: real models reuse memory
            assert!(plan.saved_bytes() > 0, "{name}: nothing saved");
        }
    }

    #[test]
    fn offsets_are_aligned() {
        let g = model_graph("resnet18").unwrap();
        let plan = plan_arena(&g, &topo_order(&g));
        for p in &plan.placements {
            assert_eq!(p.offset % ARENA_ALIGN, 0);
            assert_eq!(p.life.bytes % ARENA_ALIGN, 0);
        }
    }

    #[test]
    fn pooled_plan_never_beats_the_floor_nor_loses_to_the_arena() {
        for name in MODEL_NAMES {
            let g = model_graph(name).unwrap();
            let order = topo_order(&g);
            let arena = plan_arena(&g, &order);
            let mut pool = DevicePool::new(1 << 30);
            let pooled = plan_pooled(&g, &order, 1, &mut pool).unwrap();
            assert_eq!(pooled.peak_bytes, arena.live_peak_bytes(), "{name}: pooled = floor");
            assert!(pooled.peak_bytes <= arena.peak_bytes, "{name}");
            assert_eq!(pooled.naive_bytes, arena.naive_bytes, "{name}");
            assert_eq!(pool.live_allocs(), 0, "{name}: everything freed");
            assert_eq!(pooled.allocs, g.len() as u64);
        }
    }

    #[test]
    fn pooled_plan_scales_with_batch_and_reuses_slabs() {
        let g = chain(6);
        let order = topo_order(&g);
        let mut pool = DevicePool::new(1 << 30);
        let one = plan_pooled(&g, &order, 1, &mut pool).unwrap();
        // same-shaped chain tensors: the second execution reuses the
        // first's parked slabs instead of carving
        let again = plan_pooled(&g, &order, 1, &mut pool).unwrap();
        assert_eq!(again.peak_bytes, one.peak_bytes);
        assert_eq!(again.reuse_hits, again.allocs, "all reused on the warm pool");
        let mut fresh = DevicePool::new(1 << 30);
        let four = plan_pooled(&g, &order, 4, &mut fresh).unwrap();
        assert_eq!(four.peak_bytes, 4 * one.peak_bytes);
        assert_eq!(four.naive_bytes, 4 * one.naive_bytes);
    }

    #[test]
    fn pooled_plan_exhaustion_rolls_back_cleanly() {
        let g = model_graph("vgg16").unwrap();
        let order = topo_order(&g);
        let mut pool = DevicePool::new(1 << 20); // 1 MiB: far below VGG's peak
        let before = pool.stats;
        let err = plan_pooled(&g, &order, 1, &mut pool).unwrap_err();
        assert!(matches!(err, PoolError::Exhausted { .. }), "{err}");
        assert_eq!(pool.live_allocs(), 0, "rollback freed everything");
        assert_eq!(pool.in_use_requested_bytes(), 0);
        assert_eq!(pool.stats.failed_allocs, before.failed_allocs + 1);
        assert!(pool.slab_bytes() <= pool.capacity());
    }

    #[test]
    fn zero_copy_concat_shares_the_concat_allocation() {
        // two convs feeding a zero-copy concat: each producer is an
        // alias placement inside the concat tensor at its channel
        // prefix, and the whole plan shrinks vs the copying concat
        let build = |zero_copy: bool| {
            let mut b = GraphBuilder::new("cat");
            let x = b.input("in", Shape::new(8, 8, 8));
            let a = b.conv_same("a", x, ConvProblem::multi(8, 8, 8, 3)).unwrap();
            let c = b.conv_same("c", x, ConvProblem::multi(8, 8, 8, 3)).unwrap();
            b.add("cat", Op::Concat { zero_copy }, &[a, c]).unwrap();
            b.finish().unwrap()
        };
        let fused = build(true);
        let plain = build(false);
        let order = topo_order(&fused);

        let aliases = zero_copy_aliases(&fused);
        assert_eq!(aliases.len(), 2);
        assert_eq!(aliases[&1], (3, 0));
        assert_eq!(aliases[&2], (3, 8 * 8 * 8 * 4));
        assert!(zero_copy_aliases(&plain).is_empty());

        let plan = plan_arena(&fused, &order);
        let cat = plan.placements.iter().find(|p| p.life.id == 3).unwrap();
        assert!(cat.alias_of.is_none());
        // the concat is live from its first producer's step
        assert_eq!(cat.life.def_step, 1);
        for (&pid, &(cid, prefix)) in &aliases {
            let alias = plan.placements.iter().find(|p| p.life.id == pid).unwrap();
            assert_eq!(alias.alias_of, Some(cid));
            assert_eq!(alias.offset, cat.offset + prefix);
            assert_eq!(alias.offset % ARENA_ALIGN, 0);
            // the sub-range stays inside the concat allocation
            assert!(alias.offset + fused.node(pid).shape.bytes() <= cat.offset + cat.life.bytes);
        }
        // the two sub-ranges are disjoint
        let mut subs: Vec<(usize, usize)> = aliases
            .iter()
            .map(|(&pid, &(_, prefix))| (prefix, prefix + fused.node(pid).shape.bytes()))
            .collect();
        subs.sort_unstable();
        assert!(subs[0].1 <= subs[1].0, "sub-ranges overlap: {subs:?}");

        // producers own no bytes: the fused plan is strictly smaller
        let plain_plan = plan_arena(&plain, &topo_order(&plain));
        assert!(plan.peak_bytes < plain_plan.peak_bytes);
        assert!(plan.naive_bytes < plain_plan.naive_bytes);
        assert_eq!(plan.peak_bytes, plan.live_peak_bytes());

        // the pooled walk agrees with the floor and skips alias allocs
        let mut pool = DevicePool::new(1 << 30);
        let pooled = plan_pooled(&fused, &order, 1, &mut pool).unwrap();
        assert_eq!(pooled.peak_bytes, plan.live_peak_bytes());
        assert_eq!(pooled.allocs, (fused.len() - 2) as u64);
        assert_eq!(pooled.naive_bytes, plan.naive_bytes);
        assert_eq!(pool.live_allocs(), 0);
    }

    #[test]
    fn shared_producers_are_not_aliased_into_a_zero_copy_concat() {
        // 'a' is read by a second consumer after the concat, so it must
        // keep its own storage even though the concat claims zero-copy
        let mut b = GraphBuilder::new("shared");
        let x = b.input("in", Shape::new(8, 8, 8));
        let a = b.conv_same("a", x, ConvProblem::multi(8, 8, 8, 3)).unwrap();
        let c = b.conv_same("c", x, ConvProblem::multi(8, 8, 8, 3)).unwrap();
        b.add("cat", Op::Concat { zero_copy: true }, &[a, c]).unwrap();
        b.relu("a.again", a).unwrap();
        let g = b.finish().unwrap();
        let aliases = zero_copy_aliases(&g);
        assert!(!aliases.contains_key(&a), "shared producer must own storage");
        // 'c' still aliases at its prefix past a's channels
        assert_eq!(aliases[&c], (3, 8 * 8 * 8 * 4));
    }

    #[test]
    fn overlap_predicate() {
        let mk = |d, l| TensorLife { id: 0, bytes: 256, def_step: d, last_use_step: l };
        assert!(mk(0, 2).overlaps(&mk(2, 4)));
        assert!(mk(2, 4).overlaps(&mk(0, 2)));
        assert!(!mk(0, 1).overlaps(&mk(2, 3)));
    }
}
