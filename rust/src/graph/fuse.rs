//! Epilogue fusion & zero-copy concat: the DAG rewrite that eliminates
//! the glue-kernel streams.
//!
//! The unfused executor runs every ReLU, residual add and channel
//! concat as its own DRAM-bound stream — each one a launch, a cold
//! memory latency, and a full read-modify-write of tensors the
//! producing conv just wrote.  This pass pattern-matches the chains
//! the evaluation models actually contain and folds them into the
//! producing conv's writeback tail (`gpusim::Epilogue`):
//!
//!   conv -> relu                 => conv(+relu)          relu is free in the tail
//!   conv -> pool                 => conv(+pool{k}s{s})   stores shrink by the pooled fraction
//!   conv -> relu -> pool         => conv(+pool), relu retargeted to the
//!                                   pooled (1/(stride^2)) tensor — exact
//!                                   because max-pool commutes with relu
//!   add(conv, r)                 => conv(+add) reading `r` through the tail,
//!                                   emitted at the add's schedule position
//!   concat(conv...)              => zero-copy concat: producers write
//!                                   disjoint channel-prefix sub-ranges of
//!                                   the concat allocation (`memory`), the
//!                                   copy bytes vanish
//!
//! Every rewrite is gated never-lose: the fused candidate is priced
//! with the SAME planner + simulator the executor will use, and the
//! rewrite only happens when fused cycles <= unfused cycles + the glue
//! cycles it eliminates.  The unfused graph therefore remains the
//! structural floor — `fuse` can only return something at least as
//! fast under the model.

use std::collections::HashMap;

use crate::gpusim::{simulate, Epilogue, GpuSpec};

use super::build::{Graph, GraphBuilder};
use super::exec::{glue_stream_cycles, node_glue_bytes, node_glue_cycles, Planner};
use super::memory::ARENA_ALIGN;
use super::node::{NodeId, Op};

/// What one `fuse` call did to a graph.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FusionReport {
    /// fused sites in the rewritten graph: convs that gained a
    /// non-`None` epilogue plus concats flipped to zero-copy
    pub nodes_fused: usize,
    /// total glue bytes of the original graph minus the rewritten one
    /// (eliminated relu/add/pool streams + deleted concat copies,
    /// net of retained-but-shrunk relu streams)
    pub glue_bytes_eliminated: f64,
    /// same accounting in simulated glue cycles on the target GPU
    pub glue_cycles_eliminated: f64,
}

/// One planned epilogue rewrite, recorded against ORIGINAL node ids.
#[derive(Clone, Copy, Debug)]
enum Rewrite {
    /// conv `conv` gains `ep`; `dead` (relu or pool) is deleted and its
    /// consumers read the fused conv
    Tail { conv: NodeId, ep: Epilogue, dead: NodeId },
    /// conv -> relu -> pool: conv gains the pool epilogue, `pool` is
    /// deleted, `relu` survives retargeted onto the pooled tensor
    TailThroughRelu { conv: NodeId, ep: Epilogue, relu: NodeId, pool: NodeId },
    /// add(conv, residual): the conv is deferred and re-emitted at the
    /// add's position carrying `AddResidual` + the residual edge
    Residual { conv: NodeId, add: NodeId, residual: NodeId },
}

/// Fuse `g` for `spec` under `planner`.  Returns the rewritten graph
/// (same name, same conv names — weights key on node names) and the
/// report.  Graphs with nothing to fuse come back structurally equal.
pub fn fuse(g: &Graph, spec: &GpuSpec, planner: Planner) -> (Graph, FusionReport) {
    let consumers = g.consumers();
    let sole = |id: NodeId, c: NodeId| consumers[id] == [c];
    let conv_of = |id: NodeId| match g.node(id).op {
        Op::Conv { conv, epilogue: Epilogue::None } => Some(conv),
        _ => None,
    };
    let conv_cycles = |id: NodeId, ep: Epilogue| {
        let conv = match g.node(id).op {
            Op::Conv { conv, .. } => conv,
            _ => unreachable!("candidate {id} is a conv"),
        };
        simulate(spec, &planner(&conv, ep, spec)).cycles
    };

    let mut claimed: Vec<bool> = vec![false; g.len()];
    let mut rewrites: Vec<Rewrite> = vec![];

    // 1) residual adds first: the add pattern needs the conv's epilogue
    //    slot, and folding the add eliminates the largest glue stream
    //    (two full reads + a write), so it outranks a relu claim on the
    //    same conv
    for n in g.nodes() {
        if !matches!(n.op, Op::Add) {
            continue;
        }
        let (u, v) = (n.inputs[0], n.inputs[1]);
        let pick = [u, v]
            .into_iter()
            .find(|&c| conv_of(c).is_some() && sole(c, n.id) && !claimed[c]);
        let Some(cid) = pick else { continue };
        let residual = if cid == u { v } else { u };
        let unfused = conv_cycles(cid, Epilogue::None) + node_glue_cycles(g, spec, n.id);
        let fused = conv_cycles(cid, Epilogue::AddResidual);
        if fused <= unfused * (1.0 + 1e-9) {
            claimed[cid] = true;
            claimed[n.id] = true;
            rewrites.push(Rewrite::Residual { conv: cid, add: n.id, residual });
        }
    }

    // 2) pool tails: conv -> pool and conv -> relu -> pool
    for n in g.nodes() {
        let Op::Pool { k, stride } = n.op else { continue };
        let ep = Epilogue::MaxPoolWriteback { k, stride };
        let r = n.inputs[0];
        if let Some(_c) = conv_of(r) {
            if sole(r, n.id) && !claimed[r] && !claimed[n.id] {
                let unfused = conv_cycles(r, Epilogue::None) + node_glue_cycles(g, spec, n.id);
                let fused = conv_cycles(r, ep);
                if fused <= unfused * (1.0 + 1e-9) {
                    claimed[r] = true;
                    claimed[n.id] = true;
                    rewrites.push(Rewrite::Tail { conv: r, ep, dead: n.id });
                }
            }
        } else if matches!(g.node(r).op, Op::Relu) && sole(r, n.id) && !claimed[r] {
            let cid = g.node(r).inputs[0];
            if conv_of(cid).is_some() && sole(cid, r) && !claimed[cid] && !claimed[n.id] {
                // relu survives, shrunk to the pooled tensor (exact:
                // relu(maxpool(x)) == maxpool(relu(x)) elementwise)
                let pooled_bytes = 2.0 * n.shape.bytes() as f64;
                let unfused = conv_cycles(cid, Epilogue::None)
                    + node_glue_cycles(g, spec, r)
                    + node_glue_cycles(g, spec, n.id);
                let fused =
                    conv_cycles(cid, ep) + glue_stream_cycles(spec, pooled_bytes);
                if fused <= unfused * (1.0 + 1e-9) {
                    claimed[cid] = true;
                    claimed[n.id] = true;
                    rewrites.push(Rewrite::TailThroughRelu {
                        conv: cid,
                        ep,
                        relu: r,
                        pool: n.id,
                    });
                }
            }
        }
    }

    // 3) plain relu tails on whatever convs are left
    for n in g.nodes() {
        if !matches!(n.op, Op::Relu) || claimed[n.id] {
            continue;
        }
        let cid = n.inputs[0];
        if conv_of(cid).is_none() || !sole(cid, n.id) || claimed[cid] {
            continue;
        }
        let unfused = conv_cycles(cid, Epilogue::None) + node_glue_cycles(g, spec, n.id);
        let fused = conv_cycles(cid, Epilogue::Relu);
        if fused <= unfused * (1.0 + 1e-9) {
            claimed[cid] = true;
            claimed[n.id] = true;
            rewrites.push(Rewrite::Tail { conv: cid, ep: Epilogue::Relu, dead: n.id });
        }
    }

    // materialize the epilogue rewrites
    let (orig_bytes, orig_cycles) = total_glue(g, spec);
    let g = rebuild(g, &rewrites);

    // 4) zero-copy concats on the REWRITTEN graph (its concat inputs
    //    are the fused convs after the relus between them are gone)
    let g = zero_copy_concats(&g);

    let (fused_bytes, fused_cycles) = total_glue(&g, spec);
    let report = FusionReport {
        nodes_fused: g
            .nodes()
            .iter()
            .filter(|n| {
                !n.op.epilogue().is_none()
                    || matches!(n.op, Op::Concat { zero_copy: true })
            })
            .count(),
        glue_bytes_eliminated: orig_bytes - fused_bytes,
        glue_cycles_eliminated: orig_cycles - fused_cycles,
    };
    (g, report)
}

/// Rebuild the graph applying the planned epilogue rewrites.  Walks the
/// original nodes in id order; deleted nodes map to their replacement's
/// new id, deferred residual convs are emitted at their add's position.
fn rebuild(g: &Graph, rewrites: &[Rewrite]) -> Graph {
    let mut epilogue: HashMap<NodeId, Epilogue> = HashMap::new();
    let mut dead: HashMap<NodeId, NodeId> = HashMap::new(); // old id -> stand-in old id
    let mut deferred: HashMap<NodeId, (NodeId, NodeId)> = HashMap::new(); // add -> (conv, residual)
    for r in rewrites {
        match *r {
            Rewrite::Tail { conv, ep, dead: d } => {
                epilogue.insert(conv, ep);
                dead.insert(d, conv);
            }
            Rewrite::TailThroughRelu { conv, ep, relu, pool } => {
                epilogue.insert(conv, ep);
                dead.insert(pool, relu); // pool consumers read the retained relu
            }
            Rewrite::Residual { conv, add, residual } => {
                epilogue.insert(conv, Epilogue::AddResidual);
                deferred.insert(add, (conv, residual));
            }
        }
    }
    let deferred_convs: HashMap<NodeId, NodeId> =
        deferred.iter().map(|(&add, &(conv, _))| (conv, add)).collect();

    let mut b = GraphBuilder::new(&g.name);
    let mut remap: HashMap<NodeId, NodeId> = HashMap::new();
    let resolve = |remap: &HashMap<NodeId, NodeId>, dead: &HashMap<NodeId, NodeId>,
                   mut id: NodeId| {
        while let Some(&d) = dead.get(&id) {
            id = d;
        }
        remap[&id]
    };
    for n in g.nodes() {
        if dead.contains_key(&n.id) {
            continue; // resolves through its stand-in
        }
        if deferred_convs.contains_key(&n.id) {
            continue; // emitted at its add's position
        }
        let new_id = if let Some(&(conv, residual)) = deferred.get(&n.id) {
            let cn = g.node(conv);
            let Op::Conv { conv: op, .. } = cn.op else { unreachable!() };
            let ins = [
                resolve(&remap, &dead, cn.inputs[0]),
                resolve(&remap, &dead, residual),
            ];
            let id = b
                .add(&cn.name, Op::Conv { conv: op, epilogue: Epilogue::AddResidual }, &ins)
                .expect("fused residual conv");
            remap.insert(conv, id);
            id
        } else {
            let op = match (&n.op, epilogue.get(&n.id)) {
                (Op::Conv { conv, .. }, Some(&ep)) => Op::Conv { conv: *conv, epilogue: ep },
                (op, _) => op.clone(),
            };
            let ins: Vec<NodeId> =
                n.inputs.iter().map(|&i| resolve(&remap, &dead, i)).collect();
            b.add(&n.name, op, &ins).expect("fused node")
        };
        remap.insert(n.id, new_id);
    }
    b.finish().expect("fused graph")
}

/// Flip every eligible concat to zero-copy: all inputs are convs whose
/// sole consumer is the concat, and every channel-prefix byte offset is
/// an `ARENA_ALIGN` multiple (so producers can be placed as real
/// sub-allocations of the concat tensor).
fn zero_copy_concats(g: &Graph) -> Graph {
    let consumers = g.consumers();
    let eligible = |id: NodeId| {
        let n = g.node(id);
        if !matches!(n.op, Op::Concat { zero_copy: false }) {
            return false;
        }
        let mut prefix = 0usize;
        for &i in &n.inputs {
            if !g.node(i).op.is_conv() || consumers[i] != [id] || prefix % ARENA_ALIGN != 0 {
                return false;
            }
            prefix += g.node(i).shape.bytes();
        }
        true
    };
    if !g.nodes().iter().any(|n| eligible(n.id)) {
        return g.clone();
    }
    let mut b = GraphBuilder::new(&g.name);
    for n in g.nodes() {
        let op = if eligible(n.id) { Op::Concat { zero_copy: true } } else { n.op.clone() };
        b.add(&n.name, op, &n.inputs).expect("zero-copy rewrite");
    }
    b.finish().expect("zero-copy graph")
}

/// Total glue bytes / cycles of a graph (every node) — the report is
/// re-measured on both graphs, so it's exactly what the executor will
/// charge, not a prediction.
fn total_glue(g: &Graph, spec: &GpuSpec) -> (f64, f64) {
    let mut bytes = 0.0;
    let mut cycles = 0.0;
    for n in g.nodes() {
        bytes += node_glue_bytes(g, n.id);
        cycles += node_glue_cycles(g, spec, n.id);
    }
    (bytes, cycles)
}

#[cfg(test)]
mod tests {
    use super::super::build::{
        alexnet_graph, inception3a_graph, mobilenet_v1_graph, resnet18_graph, vgg16_graph,
        GraphBuilder,
    };
    use super::super::exec::execute;
    use super::super::node::Shape;
    use super::*;
    use crate::conv::{ConvOp, ConvProblem};
    use crate::gpusim::gtx_1080ti;
    use crate::plans::paper_op_plan_for;

    fn run(g: &Graph) -> (Graph, FusionReport) {
        fuse(g, &gtx_1080ti(), paper_op_plan_for)
    }

    fn ep_of(g: &Graph, name: &str) -> Epilogue {
        g.nodes().iter().find(|n| n.name == name).unwrap_or_else(|| panic!("{name}?")).op.epilogue()
    }

    #[test]
    fn alexnet_fuses_relus_and_both_pools() {
        let (f, r) = run(&alexnet_graph());
        assert_eq!(f.len(), 7, "{:?}", f.nodes().iter().map(|n| &n.name).collect::<Vec<_>>());
        assert_eq!(r.nodes_fused, 4);
        assert_eq!(ep_of(&f, "conv2"), Epilogue::MaxPoolWriteback { k: 3, stride: 2 });
        assert_eq!(ep_of(&f, "conv3"), Epilogue::Relu);
        assert_eq!(ep_of(&f, "conv4"), Epilogue::Relu);
        assert_eq!(ep_of(&f, "conv5"), Epilogue::MaxPoolWriteback { k: 3, stride: 2 });
        // the relus between conv and pool survive, retargeted onto the
        // pooled (decimated) tensor
        let relu2 = f.nodes().iter().find(|n| n.name == "relu2").unwrap();
        assert!(matches!(relu2.op, Op::Relu));
        assert_eq!(relu2.shape, Shape::new(256, 13, 13));
        assert!(r.glue_bytes_eliminated > 0.0 && r.glue_cycles_eliminated > 0.0);
    }

    #[test]
    fn vgg16_fuses_every_conv() {
        let (f, r) = run(&vgg16_graph());
        assert_eq!(f.len(), 19); // input + 13 fused convs + 5 retained relus
        assert_eq!(r.nodes_fused, 13);
        assert!(f.nodes().iter().filter(|n| n.op.is_conv()).all(|n| !n.op.epilogue().is_none()));
        assert_eq!(
            f.nodes()
                .iter()
                .filter(|n| n.op.epilogue() == Epilogue::MaxPoolWriteback { k: 2, stride: 2 })
                .count(),
            5
        );
        assert_eq!(f.nodes().iter().filter(|n| matches!(n.op, Op::Relu)).count(), 5);
        assert!(!f.nodes().iter().any(|n| matches!(n.op, Op::Pool { .. })));
    }

    #[test]
    fn resnet18_folds_every_residual_add_into_its_conv() {
        let (f, r) = run(&resnet18_graph());
        assert_eq!(f.len(), 28); // 44 - 8 relu1 - 8 add
        assert_eq!(r.nodes_fused, 16);
        assert!(!f.nodes().iter().any(|n| matches!(n.op, Op::Add)));
        for s in 1..=4usize {
            for blk in 1..=2usize {
                assert_eq!(ep_of(&f, &format!("s{s}b{blk}c1")), Epilogue::Relu);
                let c2 = f
                    .nodes()
                    .iter()
                    .find(|n| n.name == format!("s{s}b{blk}c2"))
                    .unwrap();
                assert_eq!(c2.op.epilogue(), Epilogue::AddResidual);
                assert_eq!(c2.inputs.len(), 2, "residual edge");
                // the post-add relu stays glue (its producer is fused)
                assert!(f.nodes().iter().any(|n| n.name == format!("s{s}b{blk}relu2")
                    && matches!(n.op, Op::Relu)));
            }
        }
        // projections feed the adds' tails; they stay unfused
        for s in 2..=4usize {
            assert_eq!(ep_of(&f, &format!("s{s}proj")), Epilogue::None);
        }
    }

    #[test]
    fn inception_concat_goes_zero_copy() {
        let (f, r) = run(&inception3a_graph());
        assert_eq!(f.len(), 10);
        assert_eq!(r.nodes_fused, 7); // 6 conv+relu + the zero-copy concat
        for c in ["b1.1x1", "b2.reduce", "b2.3x3", "b3.reduce", "b3.5x5", "b4.proj"] {
            assert_eq!(ep_of(&f, c), Epilogue::Relu, "{c}");
        }
        let cat = f.nodes().iter().find(|n| n.name == "concat").unwrap();
        assert_eq!(cat.op, Op::Concat { zero_copy: true });
        // the pool branch's pool + pad framing survives (its input is
        // the network input, nothing to fuse into)
        assert!(f.nodes().iter().any(|n| matches!(n.op, Op::Pool { .. })));
        assert!(f.nodes().iter().any(|n| matches!(n.op, Op::Pad { .. })));
        // zero-copy concat moves no bytes
        assert_eq!(node_glue_bytes(&f, cat.id), 0.0);
    }

    #[test]
    fn mobilenet_fuses_the_global_pool_into_the_last_pointwise() {
        let (f, r) = run(&mobilenet_v1_graph());
        assert_eq!(f.len(), 29); // 56 - 26 relus - avgpool
        assert_eq!(r.nodes_fused, 27);
        assert_eq!(ep_of(&f, "b13.pw"), Epilogue::MaxPoolWriteback { k: 7, stride: 1 });
        let tail = f.nodes().iter().find(|n| n.name == "b13.pw.relu").unwrap();
        assert_eq!(tail.shape, Shape::new(1024, 1, 1));
    }

    #[test]
    fn fusion_never_loses_end_to_end_and_is_identity_without_candidates() {
        let spec = gtx_1080ti();
        for g in
            [alexnet_graph(), vgg16_graph(), resnet18_graph(), inception3a_graph()]
        {
            let (f, _) = run(&g);
            assert!(f.validate().is_ok(), "{}", g.name);
            let before = execute(&g, &spec, paper_op_plan_for).total_seconds;
            let after = execute(&f, &spec, paper_op_plan_for).total_seconds;
            assert!(after <= before * (1.0 + 1e-9), "{}: {after} > {before}", g.name);
        }
        // a conv chain with no glue: nothing to rewrite
        let mut b = GraphBuilder::new("plain");
        let x = b.input("in", Shape::new(8, 12, 12));
        let c = b.conv_op("c", x, ConvOp::same(ConvProblem::multi(8, 12, 8, 3))).unwrap();
        b.conv_op("d", c, ConvOp::same(ConvProblem::multi(8, 12, 8, 3))).unwrap();
        let g = b.finish().unwrap();
        let (f, r) = run(&g);
        assert_eq!(f.len(), g.len());
        assert_eq!(r, FusionReport::default());
    }

    #[test]
    fn shared_consumers_block_fusion_and_zero_copy() {
        // conv feeds BOTH a relu and a second conv: fusing the relu
        // would orphan the other consumer, so the conv stays unfused
        let mut b = GraphBuilder::new("shared");
        let x = b.input("in", Shape::new(8, 12, 12));
        let c = b.conv_op("c", x, ConvOp::same(ConvProblem::multi(8, 12, 8, 3))).unwrap();
        let r = b.relu("r", c).unwrap();
        let d = b.conv_op("d", c, ConvOp::same(ConvProblem::multi(8, 12, 8, 3))).unwrap();
        b.concat("cat", &[r, d]).unwrap();
        let g = b.finish().unwrap();
        let (f, _) = run(&g);
        assert_eq!(ep_of(&f, "c"), Epilogue::None);
        assert!(f.nodes().iter().any(|n| matches!(n.op, Op::Relu)));
        // d fused nothing either (its consumer is the concat) but the
        // concat can't go zero-copy: `r` is not a conv
        let cat = f.nodes().iter().find(|n| n.name == "cat").unwrap();
        assert_eq!(cat.op, Op::Concat { zero_copy: false });
    }
}
