//! Minimal dense f32 tensor — the runtime's wire type between the
//! coordinator and the PJRT executables.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Standard-normal random tensor (synthetic workloads).
    pub fn randn(shape: Vec<usize>, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: rng.normal_vec(n) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Shape as the i64 dims PJRT literals want.
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }

    /// Slice the leading axis: rows [lo, hi) of axis 0.
    pub fn slice_axis0(&self, lo: usize, hi: usize) -> Result<Tensor> {
        if self.shape.is_empty() || hi > self.shape[0] || lo > hi {
            bail!("slice [{lo},{hi}) out of bounds for shape {:?}", self.shape);
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Ok(Tensor { shape, data: self.data[lo * row..hi * row].to_vec() })
    }

    /// Stack tensors along a new leading axis (all shapes must match).
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack of zero tensors");
        }
        let inner = &parts[0].shape;
        let mut data = Vec::with_capacity(parts.len() * parts[0].len());
        for p in parts {
            if &p.shape != inner {
                bail!("stack shape mismatch: {:?} vs {:?}", p.shape, inner);
            }
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(inner);
        Ok(Tensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_and_dims() {
        let t = Tensor::zeros(vec![2, 4]);
        assert_eq!(t.len(), 8);
        assert_eq!(t.dims_i64(), vec![2, 4]);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        assert_eq!(Tensor::randn(vec![4], &mut r1), Tensor::randn(vec![4], &mut r2));
    }

    #[test]
    fn slice_axis0_rows() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let s = t.slice_axis0(1, 3).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
        assert!(t.slice_axis0(2, 4).is_err());
    }

    #[test]
    fn stack_roundtrips_slice() {
        let a = Tensor::new(vec![2], vec![1., 2.]).unwrap();
        let b = Tensor::new(vec![2], vec![3., 4.]).unwrap();
        let s = Tensor::stack(&[a.clone(), b]).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.slice_axis0(0, 1).unwrap().data, a.data);
    }

    #[test]
    fn stack_rejects_mismatched_shapes() {
        let a = Tensor::zeros(vec![2]);
        let b = Tensor::zeros(vec![3]);
        assert!(Tensor::stack(&[a, b]).is_err());
    }
}
