//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! One artifact per line, whitespace-separated `key=value` fields:
//!
//!   name=multi_c32_w14_m32_k3 file=multi_c32_w14_m32_k3.hlo.txt \
//!       kind=conv_multi c=32 wy=14 wx=14 m=32 k=3 dtype=f32
//!
//! `#`-prefixed lines and blank lines are comments.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::conv::ConvProblem;

/// What a compiled artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// (image (Wy,Wx), filters (M,K,K)) -> (out (M,Oy,Ox),)
    ConvSingle,
    /// (image (C,Wy,Wx), filters (M,C,K,K)) -> (out (M,Oy,Ox),)
    ConvMulti,
    /// same signature as ConvMulti, Implicit-GEMM numerics (baseline)
    ConvIm2col,
    /// same signature as ConvMulti, Winograd F(2x2,3x3) numerics (K=3)
    ConvWinograd,
    /// same signature as ConvMulti, FFT numerics (§1 category 2)
    ConvFft,
    /// (images (B,1,28,28)) -> (logits (B,10),) — PaperNet, weights baked
    Cnn,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "conv_single" => ArtifactKind::ConvSingle,
            "conv_multi" => ArtifactKind::ConvMulti,
            "conv_im2col" => ArtifactKind::ConvIm2col,
            "conv_winograd" => ArtifactKind::ConvWinograd,
            "conv_fft" => ArtifactKind::ConvFft,
            "cnn" => ArtifactKind::Cnn,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub kind: ArtifactKind,
    pub path: PathBuf,
    fields: HashMap<String, String>,
}

impl Artifact {
    pub fn field(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }

    pub fn field_usize(&self, key: &str) -> Result<usize> {
        self.field(key)
            .ok_or_else(|| anyhow!("artifact {}: missing field {key}", self.name))?
            .parse()
            .with_context(|| format!("artifact {}: field {key} not an integer", self.name))
    }

    /// The conv problem a conv-kind artifact solves.
    pub fn problem(&self) -> Result<ConvProblem> {
        match self.kind {
            ArtifactKind::ConvSingle => Ok(ConvProblem {
                c: 1,
                wy: self.field_usize("wy")?,
                wx: self.field_usize("wx")?,
                m: self.field_usize("m")?,
                k: self.field_usize("k")?,
            }),
            ArtifactKind::ConvMulti
            | ArtifactKind::ConvIm2col
            | ArtifactKind::ConvWinograd
            | ArtifactKind::ConvFft => Ok(ConvProblem {
                c: self.field_usize("c")?,
                wy: self.field_usize("wy")?,
                wx: self.field_usize("wx")?,
                m: self.field_usize("m")?,
                k: self.field_usize("k")?,
            }),
            ArtifactKind::Cnn => bail!("artifact {} is a CNN, not a conv", self.name),
        }
    }

    /// Batch size of a CNN artifact.
    pub fn batch(&self) -> Result<usize> {
        self.field_usize("batch")
    }
}

/// Parse a manifest line into an Artifact (paths relative to `dir`).
pub fn parse_line(dir: &Path, line: &str) -> Result<Artifact> {
    let mut fields = HashMap::new();
    for tok in line.split_whitespace() {
        let (k, v) =
            tok.split_once('=').ok_or_else(|| anyhow!("malformed manifest token {tok:?}"))?;
        fields.insert(k.to_string(), v.to_string());
    }
    let name =
        fields.get("name").ok_or_else(|| anyhow!("manifest line missing name: {line:?}"))?.clone();
    let kind = ArtifactKind::parse(
        fields.get("kind").ok_or_else(|| anyhow!("artifact {name}: missing kind"))?,
    )?;
    let file = fields.get("file").ok_or_else(|| anyhow!("artifact {name}: missing file"))?;
    Ok(Artifact { name, kind, path: dir.join(file), fields })
}

/// Load `manifest.txt` from an artifact directory.
pub fn load_manifest(dir: &Path) -> Result<Vec<Artifact>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
    let mut out = vec![];
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(parse_line(dir, line)?);
    }
    if out.is_empty() {
        bail!("manifest {} has no artifacts", path.display());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        PathBuf::from("/tmp")
    }

    #[test]
    fn parses_conv_line() {
        let a = parse_line(
            &dir(),
            "name=multi_c32 file=multi_c32.hlo.txt kind=conv_multi c=32 wy=14 wx=14 m=32 k=3 dtype=f32",
        )
        .unwrap();
        assert_eq!(a.name, "multi_c32");
        assert_eq!(a.kind, ArtifactKind::ConvMulti);
        assert_eq!(a.path, PathBuf::from("/tmp/multi_c32.hlo.txt"));
        let p = a.problem().unwrap();
        assert_eq!((p.c, p.wy, p.wx, p.m, p.k), (32, 14, 14, 32, 3));
    }

    #[test]
    fn parses_cnn_line() {
        let a = parse_line(
            &dir(),
            "name=papernet_b8 file=p.hlo.txt kind=cnn batch=8 classes=10 in_c=1 in_h=28 in_w=28 dtype=f32",
        )
        .unwrap();
        assert_eq!(a.kind, ArtifactKind::Cnn);
        assert_eq!(a.batch().unwrap(), 8);
        assert!(a.problem().is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_line(&dir(), "name=x file=y.hlo.txt").is_err()); // no kind
        assert!(parse_line(&dir(), "kind=conv_multi file=y.hlo.txt").is_err()); // no name
        assert!(parse_line(&dir(), "name=x kind=wat file=y.hlo.txt").is_err()); // bad kind
        assert!(parse_line(&dir(), "name=x kind=conv_multi file=y.hlo.txt junk").is_err());
    }

    #[test]
    fn missing_fields_reported_with_artifact_name() {
        let a = parse_line(&dir(), "name=x file=y.hlo.txt kind=conv_multi").unwrap();
        let err = a.problem().unwrap_err().to_string();
        assert!(err.contains('x'), "{err}");
    }

    #[test]
    fn single_channel_problem_has_c1() {
        let a = parse_line(
            &dir(),
            "name=s file=s.hlo.txt kind=conv_single wy=32 wx=32 m=16 k=3",
        )
        .unwrap();
        assert!(a.problem().unwrap().is_single_channel());
    }

    #[test]
    fn load_manifest_real_artifacts_if_built() {
        // integration-flavoured: only runs when `make artifacts` has run
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let arts = load_manifest(&dir).unwrap();
        assert!(arts.len() >= 10);
        assert!(arts.iter().any(|a| a.kind == ArtifactKind::Cnn));
        for a in &arts {
            assert!(a.path.exists(), "{} missing", a.path.display());
        }
    }
}
