//! PJRT runtime: the bridge between the rust serve path and the AOT'd
//! JAX/Pallas artifacts.  `manifest` is the aot.py contract, `tensor` the
//! wire type, `client` the PJRT wrapper with an executable cache.
//! Python never runs here — artifacts are plain HLO text on disk.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{default_artifact_dir, ExecStats, Runtime};
pub use manifest::{Artifact, ArtifactKind};
pub use tensor::Tensor;
