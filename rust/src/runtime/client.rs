//! PJRT runtime — loads the AOT'd HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin):
//!   PjRtClient::cpu() -> HloModuleProto::from_text_file ->
//!   XlaComputation::from_proto -> client.compile -> execute.
//!
//! Compiled executables are cached by artifact name — compilation happens
//! once per artifact per process, never on the serve path.  All HLO was
//! lowered with `return_tuple=True`, so every result is a 1-tuple and is
//! unwrapped with `to_tuple1()` (see python/compile/aot.py and
//! /opt/xla-example/README.md for why text, not serialized protos).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{load_manifest, Artifact, ArtifactKind};
use super::tensor::Tensor;

/// Stats the runtime keeps per artifact (the coordinator exports these).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// The PJRT runtime: client + artifact registry + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: HashMap<String, ExecStats>,
    dir: PathBuf,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.txt`; run
    /// `make artifacts` to produce it).
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let artifacts = load_manifest(artifact_dir)?
            .into_iter()
            .map(|a| (a.name.clone(), a))
            .collect();
        Ok(Runtime {
            client,
            artifacts,
            cache: HashMap::new(),
            stats: HashMap::new(),
            dir: artifact_dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    /// All artifacts of one kind, sorted by name.
    pub fn artifacts_of_kind(&self, kind: ArtifactKind) -> Vec<&Artifact> {
        let mut v: Vec<&Artifact> =
            self.artifacts.values().filter(|a| a.kind == kind).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let art = self.artifact(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            art.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", art.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.cache.insert(name.to_string(), exe);
        self.stats.entry(name.to_string()).or_default().compile_secs += dt;
        Ok(())
    }

    /// Execute an artifact on f32 tensors; returns the (single) output.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        self.execute_refs(name, &inputs.iter().collect::<Vec<_>>())
    }

    /// Execute without cloning the input tensors (hot-path variant: the
    /// serve loop holds the request's tensors and must not copy them
    /// again just to build the literals).
    pub fn execute_refs(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.ensure_compiled(name)?;
        let exe = self.cache.get(name).unwrap();

        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            literals.push(
                xla::Literal::vec1(&t.data)
                    .reshape(&t.dims_i64())
                    .with_context(|| format!("reshaping input to {:?}", t.shape))?,
            );
        }
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();

        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let shape = out.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = out.to_vec::<f32>().context("reading f32 output")?;

        let s = self.stats.entry(name.to_string()).or_default();
        s.executions += 1;
        s.total_secs += dt;

        Tensor::new(dims, data)
    }

    /// Execute a conv artifact, checking operand shapes against its manifest.
    pub fn execute_conv(&mut self, name: &str, image: &Tensor, filters: &Tensor) -> Result<Tensor> {
        let art = self.artifact(name)?;
        let p = art.problem()?;
        let want_img: Vec<usize> = match art.kind {
            ArtifactKind::ConvSingle => vec![p.wy, p.wx],
            _ => vec![p.c, p.wy, p.wx],
        };
        let want_flt: Vec<usize> = match art.kind {
            ArtifactKind::ConvSingle => vec![p.m, p.k, p.k],
            _ => vec![p.m, p.c, p.k, p.k],
        };
        if image.shape != want_img {
            bail!("{name}: image shape {:?}, artifact wants {:?}", image.shape, want_img);
        }
        if filters.shape != want_flt {
            bail!("{name}: filter shape {:?}, artifact wants {:?}", filters.shape, want_flt);
        }
        self.execute_refs(name, &[image, filters])
    }

    pub fn stats(&self, name: &str) -> Option<&ExecStats> {
        self.stats.get(name)
    }

    pub fn all_stats(&self) -> &HashMap<String, ExecStats> {
        &self.stats
    }
}

/// Default artifact directory: `$PASCONV_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PASCONV_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
