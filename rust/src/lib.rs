//! # pasconv
//!
//! Reproduction of "Fast Convolution Kernels on Pascal GPU with High
//! Memory Efficiency" (Chang, Onishi, Maruyama, 2022) as a three-layer
//! Rust + JAX + Pallas system.  See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * `conv`      — problem domain + CPU oracle + the paper's workload suites
//! * `gpusim`    — Pascal/Maxwell timing simulator (hardware substrate)
//! * `analytic`  — the paper's closed-form model (N_FMA, V_s, P/Q, stride-fixed)
//! * `plans`     — per-SM execution schedules for the paper's two kernels
//! * `tuner`     — plan-space search: enumerate → score → simulate → cache
//! * `baselines` — cuDNN proxy (implicit GEMM), DAC'17 [1], Tan [16],
//!   Winograd [8], FFT [13] — the comparison plans
//! * `backend`   — ONE `ConvBackend` trait over the paper kernels, the
//!   CPU reference and every baseline, plus cross-backend autodispatch
//!   (fastest legal algorithm per problem, never losing to paper-tuned)
//! * `graph`     — whole-network DAG executor: builder + shape inference,
//!   liveness-based arena memory planning, topological scheduling
//!   through `plans`/`tuner` and `gpusim`
//! * `runtime`   — PJRT client: load + execute the AOT'd HLO artifacts
//! * `coordinator` — request router, dynamic batcher + conv micro-batch
//!   coalescer, worker pool, metrics
//! * `fleet`     — multi-GPU scheduler: simulated device shards, bounded
//!   queues, batch-aware admission, pluggable placement policies
//! * `trace`     — observability: roofline counters, virtual-time span
//!   tracing (zero-cost when disabled), Chrome-trace/Perfetto and
//!   Prometheus exports
//! * `util`      — offline stand-ins (rng/stats/bench/cli/prop/json)
pub mod analytic;
pub mod backend;
pub mod baselines;
pub mod conv;
pub mod coordinator;
pub mod fleet;
pub mod gpusim;
pub mod graph;
pub mod plans;
pub mod runtime;
pub mod trace;
pub mod tuner;
pub mod util;
