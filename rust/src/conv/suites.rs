//! Workload suites — the convolutions the paper evaluates, and the
//! op-level model suites the graph/fleet layers serve.
//!
//! §4: "performances were evaluated using many convolutions which are
//! commonly used in popular CNN models [AlexNet][ResNet][VGG][GoogLeNet]".
//! Fig. 4 sweeps single-channel maps 28 -> 1K with M 512 -> 32 and
//! K in {1,3,5}; Fig. 5 sweeps multi-channel maps 7 -> 512 with C
//! 64 -> 512.  Those figure suites stay `ConvProblem` lists — they are
//! the paper's own stride-1/valid/dense evaluation points.
//!
//! The CNN-model suites are `ConvOp` lists with the networks' real
//! geometry: 'same' padding everywhere the models use it, ResNet-18's
//! true stride-2 downsampling convs and stride-2 1x1 projections (the
//! old stride-1-at-pooled-size approximation is gone), and the
//! MobileNetV1 depthwise-separable stack the op layer exists for.
//! `all_cnn_layers` exposes the deduplicated *lowered units* — the
//! stride-1 kernels the models actually execute — for the tuner and
//! dispatcher sweeps.

use super::op::ConvOp;
use super::problem::ConvProblem;

/// The paper's filter sizes: "The filter size is 1, 3 or 5".
pub const PAPER_KS: [usize; 3] = [1, 3, 5];

/// Fig. 4 sweep points: (map size, M), channels C = 1.
/// "we changed the sample size of the feature maps from 28 to 1K and the
/// size of the corresponding channels from 512 to 32" — inverse pairing,
/// as in CNN first layers.
pub const FIG4_POINTS: [(usize, usize); 6] =
    [(28, 512), (56, 256), (112, 128), (224, 64), (512, 32), (1024, 32)];

/// Fig. 5 sweep points: (map size, C). M = C (the square layers CNN
/// bodies use). "sample size ... from 7 to 512, channels from 64 to 512".
pub const FIG5_POINTS: [(usize, usize); 7] =
    [(7, 512), (14, 256), (28, 128), (56, 128), (112, 64), (224, 64), (512, 64)];

/// Every (map, M, K) case of Fig. 4.
pub fn fig4_suite() -> Vec<ConvProblem> {
    let mut out = vec![];
    for &k in &PAPER_KS {
        for &(w, m) in &FIG4_POINTS {
            out.push(ConvProblem::single(w, m, k));
        }
    }
    out
}

/// Every (map, C, K) case of Fig. 5.
pub fn fig5_suite() -> Vec<ConvProblem> {
    let mut out = vec![];
    for &k in &PAPER_KS {
        for &(w, c) in &FIG5_POINTS {
            out.push(ConvProblem::multi(c, w, c, k));
        }
    }
    out
}

/// AlexNet [15] conv body (conv2 on the 27x27 post-pool map, conv3-5 on
/// 13x13 — the "smaller than 32" regime), with its real 'same' padding.
pub fn alexnet() -> Vec<ConvOp> {
    vec![
        ConvOp::same(ConvProblem::multi(96, 27, 256, 5)),
        ConvOp::same(ConvProblem::multi(256, 13, 384, 3)),
        ConvOp::same(ConvProblem::multi(384, 13, 384, 3)),
        ConvOp::same(ConvProblem::multi(384, 13, 256, 3)),
    ]
}

/// VGG-16 [6] conv layers (all 'same' 3x3, maps 224 -> 14).
pub fn vgg16() -> Vec<ConvOp> {
    vec![
        ConvOp::same(ConvProblem::multi(3, 224, 64, 3)),
        ConvOp::same(ConvProblem::multi(64, 224, 64, 3)),
        ConvOp::same(ConvProblem::multi(64, 112, 128, 3)),
        ConvOp::same(ConvProblem::multi(128, 112, 128, 3)),
        ConvOp::same(ConvProblem::multi(128, 56, 256, 3)),
        ConvOp::same(ConvProblem::multi(256, 56, 256, 3)),
        ConvOp::same(ConvProblem::multi(256, 28, 512, 3)),
        ConvOp::same(ConvProblem::multi(512, 28, 512, 3)),
        ConvOp::same(ConvProblem::multi(512, 14, 512, 3)),
    ]
}

/// ResNet-18 [9] body layers with their REAL geometry: 'same' 3x3
/// blocks on 56/28/14/7 maps, and native stride-2 downsampling at
/// every stage transition — the 3x3/s2 first conv and the 1x1/s2
/// projection both run on the PREVIOUS stage's map (the seed's
/// stride-1-at-pooled-size approximation is deleted).
pub fn resnet18() -> Vec<ConvOp> {
    vec![
        ConvOp::same(ConvProblem::multi(64, 56, 64, 3)),
        ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1),
        ConvOp::strided(ConvProblem::multi(64, 56, 128, 1), 2, 0),
        ConvOp::same(ConvProblem::multi(128, 28, 128, 3)),
        ConvOp::strided(ConvProblem::multi(128, 28, 256, 3), 2, 1),
        ConvOp::strided(ConvProblem::multi(128, 28, 256, 1), 2, 0),
        ConvOp::same(ConvProblem::multi(256, 14, 256, 3)),
        ConvOp::strided(ConvProblem::multi(256, 14, 512, 3), 2, 1),
        ConvOp::strided(ConvProblem::multi(256, 14, 512, 1), 2, 0),
        ConvOp::same(ConvProblem::multi(512, 7, 512, 3)),
    ]
}

/// GoogLeNet [11] inception(3a) as its real multi-path structure: four
/// parallel branches over the 192-channel 28x28 input, concatenated to
/// 256 channels.  Each inner `Vec` is one branch in execution order
/// (the reduce conv feeds the following conv); the fourth branch's 1x1
/// projection follows the cell's 3x3 max pool.  The 3x3/5x5 convs use
/// their real 'same' padding.  `graph::inception3a_graph` builds the
/// DAG from this.
pub fn googlenet_inception3a_branches() -> Vec<Vec<ConvOp>> {
    vec![
        // 1x1 branch
        vec![ConvOp::dense(ConvProblem::multi(192, 28, 64, 1))],
        // 1x1 reduce -> 3x3 branch
        vec![
            ConvOp::dense(ConvProblem::multi(192, 28, 96, 1)),
            ConvOp::same(ConvProblem::multi(96, 28, 128, 3)),
        ],
        // 1x1 reduce -> 5x5 branch
        vec![
            ConvOp::dense(ConvProblem::multi(192, 28, 16, 1)),
            ConvOp::same(ConvProblem::multi(16, 28, 32, 5)),
        ],
        // 3x3 maxpool -> 1x1 projection branch
        vec![ConvOp::dense(ConvProblem::multi(192, 28, 32, 1))],
    ]
}

/// GoogLeNet [11] inception(3a) branches on the 28x28 map (K in {1,3,5})
/// — the flat layer list the per-layer sweeps use (the branch order of
/// `googlenet_inception3a_branches`, flattened).
pub fn googlenet_inception3a() -> Vec<ConvOp> {
    googlenet_inception3a_branches().into_iter().flatten().collect()
}

/// MobileNetV1 [Howard et al.] at width 1.0 on 224x224 input: the
/// strided first conv, then 13 depthwise-separable blocks (depthwise
/// 3x3 s1/s2 + pointwise 1x1) — 27 conv ops, none of which the
/// pre-op-layer stack could even represent.
pub fn mobilenet_v1() -> Vec<ConvOp> {
    let mut out = vec![ConvOp::strided(ConvProblem::multi(3, 224, 32, 3), 2, 1)];
    // (channels in, dw stride, channels out) per separable block
    let blocks: [(usize, usize, usize); 13] = [
        (32, 1, 64),
        (64, 2, 128),
        (128, 1, 128),
        (128, 2, 256),
        (256, 1, 256),
        (256, 2, 512),
        (512, 1, 512),
        (512, 1, 512),
        (512, 1, 512),
        (512, 1, 512),
        (512, 1, 512),
        (512, 2, 1024),
        (1024, 1, 1024),
    ];
    let mut w = 112;
    for &(c_in, stride, c_out) in &blocks {
        out.push(ConvOp::depthwise(c_in, w, 3, stride));
        w /= stride;
        out.push(ConvOp::pointwise(c_in, w, c_out));
    }
    out
}

/// Every model suite by canonical name, in `graph::MODEL_NAMES` order.
pub fn model_ops() -> Vec<(&'static str, Vec<ConvOp>)> {
    vec![
        ("alexnet", alexnet()),
        ("vgg16", vgg16()),
        ("resnet18", resnet18()),
        ("inception3a", googlenet_inception3a()),
        ("mobilenet_v1", mobilenet_v1()),
    ]
}

/// All model ops (all five models), deduplicated, in model order.
pub fn all_cnn_ops() -> Vec<ConvOp> {
    let mut out: Vec<ConvOp> = vec![];
    for (_, ops) in model_ops() {
        for op in ops {
            if !out.contains(&op) {
                out.push(op);
            }
        }
    }
    out
}

/// The deduplicated **lowered units** of the four §4 models — the
/// stride-1 valid dense problems their ops actually execute on the
/// paper kernels ("many convolutions commonly used in popular CNN
/// models").  This is what the tuner and the dispatcher ablations
/// sweep; MobileNet's units join through `all_cnn_ops` at the op level.
pub fn all_cnn_layers() -> Vec<ConvProblem> {
    let mut out: Vec<ConvProblem> = vec![];
    for op in alexnet()
        .into_iter()
        .chain(vgg16())
        .chain(resnet18())
        .chain(googlenet_inception3a())
    {
        let unit = op.lower().unit;
        if !out.contains(&unit) {
            out.push(unit);
        }
    }
    out
}

/// The fraction of ops on maps < 32 — the paper's §1 claim that "more
/// than half of the convolution layers are used for the calculation of
/// the images smaller than 32 (such as 28, 14, 7)".
pub fn small_map_fraction(ops: &[ConvOp]) -> f64 {
    let small = ops.iter().filter(|o| o.core.wy < 32).count();
    small as f64 / ops.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_covers_paper_ranges() {
        let suite = fig4_suite();
        assert_eq!(suite.len(), 18);
        assert!(suite.iter().all(|p| p.is_single_channel() && p.valid()));
        assert!(suite.iter().any(|p| p.wy == 28 && p.m == 512));
        assert!(suite.iter().any(|p| p.wy == 1024));
        let ks: std::collections::HashSet<usize> = suite.iter().map(|p| p.k).collect();
        assert_eq!(ks, [1usize, 3, 5].into_iter().collect());
    }

    #[test]
    fn fig5_covers_paper_ranges() {
        let suite = fig5_suite();
        assert_eq!(suite.len(), 21);
        assert!(suite.iter().all(|p| !p.is_single_channel() && p.valid()));
        assert!(suite.iter().any(|p| p.wy == 7 && p.c == 512));
        assert!(suite.iter().any(|p| p.wy == 512));
    }

    #[test]
    fn cnn_suites_valid() {
        for (name, suite) in model_ops() {
            assert!(!suite.is_empty(), "{name}");
            assert!(suite.iter().all(|o| o.valid()), "invalid op in {name}");
        }
    }

    #[test]
    fn resnet18_has_native_downsampling() {
        let ops = resnet18();
        assert_eq!(ops.len(), 10);
        let strided: Vec<&ConvOp> = ops.iter().filter(|o| o.stride == 2).collect();
        assert_eq!(strided.len(), 6, "three stage transitions, conv + projection each");
        for o in &strided {
            // stride-2 ops run on the PREVIOUS stage's map and halve it
            assert_eq!(o.oy() * 2, o.core.wy);
            if o.core.k == 1 {
                assert_eq!(o.pad, 0);
            } else {
                assert_eq!(o.pad, 1);
            }
        }
        // no stride-1-at-pooled-size approximations survive: every
        // 3x3 body conv keeps its map via 'same' padding
        for o in &ops {
            if o.stride == 1 {
                assert_eq!(o.oy(), o.core.wy, "{}", o.label());
            }
        }
    }

    #[test]
    fn mobilenet_v1_is_a_separable_stack() {
        let ops = mobilenet_v1();
        assert_eq!(ops.len(), 27, "conv1 + 13 x (dw + pw)");
        assert_eq!(ops[0].stride, 2);
        let dw: Vec<&ConvOp> = ops.iter().filter(|o| o.is_depthwise()).collect();
        assert_eq!(dw.len(), 13);
        assert_eq!(dw.iter().filter(|o| o.stride == 2).count(), 4);
        // blocks chain: dw keeps channels, pw expands them; final 1024x7x7
        let last = ops.last().unwrap();
        assert_eq!((last.core.m, last.oy()), (1024, 7));
        for pair in ops.windows(2) {
            assert_eq!(pair[0].core.m, pair[1].core.c, "stack does not chain");
            assert_eq!(pair[0].oy(), pair[1].core.wy, "stack maps do not chain");
        }
        // depthwise ops were unrepresentable pre-op-layer
        assert!(dw.iter().all(|o| o.groups == o.core.c && o.filter_elems() == o.core.c * 9));
    }

    #[test]
    fn paper_small_map_claim_holds_for_modern_models() {
        // §1: "more than half of the convolution layers are used for the
        // calculation of the images smaller than 32" — true for the
        // AlexNet/ResNet mixes that motivate the paper.
        assert!(small_map_fraction(&alexnet()) > 0.5);
        assert!(small_map_fraction(&resnet18()) > 0.5);
    }

    #[test]
    fn all_cnn_layers_are_deduped_lowered_units() {
        let all = all_cnn_layers();
        assert!(all.iter().all(|p| p.valid()));
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a, b, "duplicate unit survived dedup");
            }
        }
        // 'same' ops surface padded-map units
        assert!(all.contains(&ConvProblem::multi(64, 58, 64, 3)), "resnet 56+2 unit");
        assert!(all.contains(&ConvProblem::multi(3, 226, 64, 3)), "vgg 224+2 unit");
        // valid 1x1 projections stay unpadded
        assert!(all.contains(&ConvProblem::multi(64, 56, 128, 1)));
        // ops and units agree in count for the §4 models (no collisions)
        assert_eq!(all.len(), 29);
    }

    #[test]
    fn all_cnn_ops_cover_every_model() {
        let ops = all_cnn_ops();
        for (name, suite) in model_ops() {
            for op in suite {
                assert!(ops.contains(&op), "{name}: {} missing", op.label());
            }
        }
        assert!(ops.iter().any(|o| o.is_depthwise()));
        assert!(ops.iter().any(|o| o.stride == 2));
    }

    #[test]
    fn inception_branches_chain_and_flatten() {
        let branches = googlenet_inception3a_branches();
        assert_eq!(branches.len(), 4);
        // within a branch, each conv's filters become the next conv's
        // channels, and 'same' padding keeps the map at 28 throughout
        for branch in &branches {
            for pair in branch.windows(2) {
                assert_eq!(pair[0].core.m, pair[1].core.c, "branch does not chain");
                assert_eq!(pair[0].oy(), pair[1].core.wy, "branch changes maps");
            }
        }
        for branch in &branches {
            assert_eq!(branch[0].core.c, 192);
            assert!(branch.iter().all(|o| o.core.wy == 28 && o.oy() == 28));
        }
        // concat channel count is the GoogLeNet table's 256
        let out_channels: usize = branches.iter().map(|b| b.last().unwrap().core.m).sum();
        assert_eq!(out_channels, 256);
        // flattening preserves the historical flat list
        let flat = googlenet_inception3a();
        assert_eq!(flat.len(), 6);
        assert_eq!(flat[0], ConvOp::dense(ConvProblem::multi(192, 28, 64, 1)));
        assert_eq!(flat[2], ConvOp::same(ConvProblem::multi(96, 28, 128, 3)));
        assert_eq!(flat[5], ConvOp::dense(ConvProblem::multi(192, 28, 32, 1)));
    }

    #[test]
    fn fig5_k5_cases_remain_valid_on_smallest_map() {
        // the 7x7 map with K=5 still yields a 3x3 output
        let p = ConvProblem::multi(512, 7, 512, 5);
        assert!(p.valid());
        assert_eq!(p.oy(), 3);
    }
}
