//! Workload suites — the convolutions the paper evaluates.
//!
//! §4: "performances were evaluated using many convolutions which are
//! commonly used in popular CNN models [AlexNet][ResNet][VGG][GoogLeNet]".
//! Fig. 4 sweeps single-channel maps 28 -> 1K with M 512 -> 32 and
//! K in {1,3,5}; Fig. 5 sweeps multi-channel maps 7 -> 512 with C
//! 64 -> 512.  The CNN-model suites give the realistic layer mixes the
//! examples and the e2e bench serve.

use super::problem::ConvProblem;

/// The paper's filter sizes: "The filter size is 1, 3 or 5".
pub const PAPER_KS: [usize; 3] = [1, 3, 5];

/// Fig. 4 sweep points: (map size, M), channels C = 1.
/// "we changed the sample size of the feature maps from 28 to 1K and the
/// size of the corresponding channels from 512 to 32" — inverse pairing,
/// as in CNN first layers.
pub const FIG4_POINTS: [(usize, usize); 6] =
    [(28, 512), (56, 256), (112, 128), (224, 64), (512, 32), (1024, 32)];

/// Fig. 5 sweep points: (map size, C). M = C (the square layers CNN
/// bodies use). "sample size ... from 7 to 512, channels from 64 to 512".
pub const FIG5_POINTS: [(usize, usize); 7] =
    [(7, 512), (14, 256), (28, 128), (56, 128), (112, 64), (224, 64), (512, 64)];

/// Every (map, M, K) case of Fig. 4.
pub fn fig4_suite() -> Vec<ConvProblem> {
    let mut out = vec![];
    for &k in &PAPER_KS {
        for &(w, m) in &FIG4_POINTS {
            out.push(ConvProblem::single(w, m, k));
        }
    }
    out
}

/// Every (map, C, K) case of Fig. 5.
pub fn fig5_suite() -> Vec<ConvProblem> {
    let mut out = vec![];
    for &k in &PAPER_KS {
        for &(w, c) in &FIG5_POINTS {
            out.push(ConvProblem::multi(c, w, c, k));
        }
    }
    out
}

/// AlexNet [15] stride-1 conv layers (conv2 uses K=5 on 27x27 after pool;
/// conv3-5 are K=3 on 13x13 maps — the "smaller than 32" regime).
pub fn alexnet() -> Vec<ConvProblem> {
    vec![
        ConvProblem::multi(96, 27, 256, 5),
        ConvProblem::multi(256, 13, 384, 3),
        ConvProblem::multi(384, 13, 384, 3),
        ConvProblem::multi(384, 13, 256, 3),
    ]
}

/// VGG-16 [6] conv layers (all K=3, maps 224 -> 14).
pub fn vgg16() -> Vec<ConvProblem> {
    vec![
        ConvProblem::multi(3, 224, 64, 3),
        ConvProblem::multi(64, 224, 64, 3),
        ConvProblem::multi(64, 112, 128, 3),
        ConvProblem::multi(128, 112, 128, 3),
        ConvProblem::multi(128, 56, 256, 3),
        ConvProblem::multi(256, 56, 256, 3),
        ConvProblem::multi(256, 28, 512, 3),
        ConvProblem::multi(512, 28, 512, 3),
        ConvProblem::multi(512, 14, 512, 3),
    ]
}

/// ResNet-18 [9] body layers (K=3 blocks + K=1 projections, maps 56 -> 7).
pub fn resnet18() -> Vec<ConvProblem> {
    vec![
        ConvProblem::multi(64, 56, 64, 3),
        ConvProblem::multi(64, 28, 128, 3),
        ConvProblem::multi(64, 28, 128, 1),
        ConvProblem::multi(128, 28, 128, 3),
        ConvProblem::multi(128, 14, 256, 3),
        ConvProblem::multi(128, 14, 256, 1),
        ConvProblem::multi(256, 14, 256, 3),
        ConvProblem::multi(256, 7, 512, 3),
        ConvProblem::multi(256, 7, 512, 1),
        ConvProblem::multi(512, 7, 512, 3),
    ]
}

/// GoogLeNet [11] inception(3a) as its real multi-path structure: four
/// parallel branches over the 192-channel 28x28 input, concatenated to
/// 256 channels.  Each inner `Vec` is one branch in execution order
/// (the reduce conv feeds the following conv); the fourth branch's 1x1
/// projection follows the cell's 3x3 max pool.  `graph::inception3a_graph`
/// builds the DAG from this.
pub fn googlenet_inception3a_branches() -> Vec<Vec<ConvProblem>> {
    vec![
        // 1x1 branch
        vec![ConvProblem::multi(192, 28, 64, 1)],
        // 1x1 reduce -> 3x3 branch
        vec![ConvProblem::multi(192, 28, 96, 1), ConvProblem::multi(96, 28, 128, 3)],
        // 1x1 reduce -> 5x5 branch
        vec![ConvProblem::multi(192, 28, 16, 1), ConvProblem::multi(16, 28, 32, 5)],
        // 3x3 maxpool -> 1x1 projection branch
        vec![ConvProblem::multi(192, 28, 32, 1)],
    ]
}

/// GoogLeNet [11] inception(3a) branches on the 28x28 map (K in {1,3,5})
/// — the flat layer list the per-layer sweeps use (the branch order of
/// `googlenet_inception3a_branches`, flattened).
pub fn googlenet_inception3a() -> Vec<ConvProblem> {
    googlenet_inception3a_branches().into_iter().flatten().collect()
}

/// All CNN-model layers, deduplicated — "many convolutions commonly used
/// in popular CNN models".
pub fn all_cnn_layers() -> Vec<ConvProblem> {
    let mut out: Vec<ConvProblem> = vec![];
    for p in alexnet().into_iter().chain(vgg16()).chain(resnet18()).chain(googlenet_inception3a()) {
        if !out.contains(&p) {
            out.push(p);
        }
    }
    out
}

/// The fraction of layers on maps < 32 — the paper's §1 claim that "more
/// than half of the convolution layers are used for the calculation of
/// the images smaller than 32 (such as 28, 14, 7)".
pub fn small_map_fraction(layers: &[ConvProblem]) -> f64 {
    let small = layers.iter().filter(|p| p.wy < 32).count();
    small as f64 / layers.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_covers_paper_ranges() {
        let suite = fig4_suite();
        assert_eq!(suite.len(), 18);
        assert!(suite.iter().all(|p| p.is_single_channel() && p.valid()));
        assert!(suite.iter().any(|p| p.wy == 28 && p.m == 512));
        assert!(suite.iter().any(|p| p.wy == 1024));
        let ks: std::collections::HashSet<usize> = suite.iter().map(|p| p.k).collect();
        assert_eq!(ks, [1usize, 3, 5].into_iter().collect());
    }

    #[test]
    fn fig5_covers_paper_ranges() {
        let suite = fig5_suite();
        assert_eq!(suite.len(), 21);
        assert!(suite.iter().all(|p| !p.is_single_channel() && p.valid()));
        assert!(suite.iter().any(|p| p.wy == 7 && p.c == 512));
        assert!(suite.iter().any(|p| p.wy == 512));
    }

    #[test]
    fn cnn_suites_valid() {
        for suite in [alexnet(), vgg16(), resnet18(), googlenet_inception3a()] {
            assert!(!suite.is_empty());
            assert!(suite.iter().all(|p| p.valid()), "invalid problem in suite");
        }
    }

    #[test]
    fn paper_small_map_claim_holds_for_modern_models() {
        // §1: "more than half of the convolution layers are used for the
        // calculation of the images smaller than 32" — true for the
        // AlexNet/ResNet mixes that motivate the paper.
        assert!(small_map_fraction(&alexnet()) > 0.5);
        assert!(small_map_fraction(&resnet18()) > 0.5);
    }

    #[test]
    fn all_cnn_layers_dedups() {
        let all = all_cnn_layers();
        let total =
            alexnet().len() + vgg16().len() + resnet18().len() + googlenet_inception3a().len();
        assert!(all.len() <= total);
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a, b, "duplicate problem survived dedup");
            }
        }
    }

    #[test]
    fn inception_branches_chain_and_flatten() {
        let branches = googlenet_inception3a_branches();
        assert_eq!(branches.len(), 4);
        // within a branch, each conv's filters become the next conv's
        // channels (the structural fact the flat list cannot express)
        for branch in &branches {
            for pair in branch.windows(2) {
                assert_eq!(pair[0].m, pair[1].c, "branch does not chain");
                assert_eq!(pair[0].wy, pair[1].wy, "branch changes maps");
            }
        }
        // all branches start from the cell's 192-channel input (the pool
        // branch too — 3x3/s1 pooling keeps channels) and share the map
        for branch in &branches {
            assert_eq!(branch[0].c, 192);
            assert!(branch.iter().all(|p| p.wy == 28));
        }
        // concat channel count is the GoogLeNet table's 256
        let out_channels: usize = branches.iter().map(|b| b.last().unwrap().m).sum();
        assert_eq!(out_channels, 256);
        // flattening preserves the historical flat list
        let flat = googlenet_inception3a();
        assert_eq!(flat.len(), 6);
        assert_eq!(flat[0], ConvProblem::multi(192, 28, 64, 1));
        assert_eq!(flat[2], ConvProblem::multi(96, 28, 128, 3));
        assert_eq!(flat[5], ConvProblem::multi(192, 28, 32, 1));
    }

    #[test]
    fn fig5_k5_cases_remain_valid_on_smallest_map() {
        // the 7x7 map with K=5 still yields a 3x3 output
        let p = ConvProblem::multi(512, 7, 512, 5);
        assert!(p.valid());
        assert_eq!(p.oy(), 3);
    }
}
