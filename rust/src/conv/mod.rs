//! Convolution problem domain: shapes (`problem`), batched serving
//! payloads (`batched`), the paper's workload suites (`suites`), and a
//! direct CPU implementation used as the rust-side numeric oracle
//! (`cpu`).

pub mod batched;
pub mod cpu;
pub mod problem;
pub mod suites;

pub use batched::{conv2d_batched_cpu, BatchedConv};
pub use cpu::{conv2d_multi_cpu, conv2d_single_cpu, max_abs_diff};
pub use problem::{ConvProblem, BYTES_F32};
