//! Convolution problem domain: shapes (`problem`), the first-class op
//! layer with stride/padding/groups and its exact lowering (`op`),
//! batched serving payloads (`batched`), the workload suites
//! (`suites`), and a direct CPU implementation used as the rust-side
//! numeric oracle (`cpu`).

pub mod batched;
pub mod cpu;
pub mod op;
pub mod problem;
pub mod suites;

pub use batched::{conv2d_batched_cpu, BatchedConv};
pub use cpu::{conv2d_multi_cpu, conv2d_single_cpu, max_abs_diff};
pub use op::{
    conv2d_batched_op_cpu, conv2d_op_cpu, conv2d_op_lowered_cpu, conv2d_op_lowered_with,
    decimate, zero_embed, BatchedConvOp, ConvOp, Lowering,
};
pub use problem::{ConvProblem, BYTES_F32};
