//! Convolution problem definition — the paper's eq. (1)/(2) operands.
//!
//! All sizes follow the paper's notation: feature map `Wy x Wx` with `C`
//! channels, `M` filters of size `K x K x C`, valid cross-correlation,
//! stride 1, f32 (the paper's "single precision data").

/// One convolution layer instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvProblem {
    /// input channels (C = 1 means single-channel, eq. (2))
    pub c: usize,
    /// feature-map height W_y
    pub wy: usize,
    /// feature-map width W_x
    pub wx: usize,
    /// number of filters M
    pub m: usize,
    /// filter size K (square filters, as in the paper)
    pub k: usize,
}

pub const BYTES_F32: usize = 4;

impl ConvProblem {
    pub fn single(w: usize, m: usize, k: usize) -> ConvProblem {
        ConvProblem { c: 1, wy: w, wx: w, m, k }
    }

    pub fn multi(c: usize, w: usize, m: usize, k: usize) -> ConvProblem {
        ConvProblem { c, wy: w, wx: w, m, k }
    }

    pub fn is_single_channel(&self) -> bool {
        self.c == 1
    }

    /// Output height Oy = Wy - K + 1.
    pub fn oy(&self) -> usize {
        self.wy - self.k + 1
    }

    /// Output width Ox = Wx - K + 1.
    pub fn ox(&self) -> usize {
        self.wx - self.k + 1
    }

    pub fn valid(&self) -> bool {
        self.c >= 1 && self.m >= 1 && self.k >= 1 && self.k <= self.wy && self.k <= self.wx
    }

    /// Elements in the input feature map set.
    pub fn map_elems(&self) -> usize {
        self.c * self.wy * self.wx
    }

    /// Elements in the filter set.
    pub fn filter_elems(&self) -> usize {
        self.m * self.c * self.k * self.k
    }

    /// Elements in the output feature map set.
    pub fn out_elems(&self) -> usize {
        self.m * self.oy() * self.ox()
    }

    /// D_input of eq. (3): bytes of map + filters.
    pub fn input_bytes(&self) -> usize {
        (self.map_elems() + self.filter_elems()) * BYTES_F32
    }

    /// FMA operations to compute the full output (one FMA = one
    /// multiply-accumulate): M * Oy * Ox * C * K * K.
    pub fn fma_ops(&self) -> u64 {
        self.out_elems() as u64 * (self.c * self.k * self.k) as u64
    }

    /// FLOPs (2 per FMA) — for GFLOP/s reporting.
    pub fn flops(&self) -> u64 {
        2 * self.fma_ops()
    }

    /// Arithmetic intensity: FMAs per byte that *must* move from DRAM
    /// (compulsory traffic: inputs once + output once).
    pub fn arithmetic_intensity(&self) -> f64 {
        let bytes = (self.map_elems() + self.filter_elems() + self.out_elems()) * BYTES_F32;
        self.fma_ops() as f64 / bytes as f64
    }

    pub fn label(&self) -> String {
        if self.is_single_channel() {
            format!("single W={} M={} K={}", self.wy, self.m, self.k)
        } else {
            format!("multi C={} W={} M={} K={}", self.c, self.wy, self.m, self.k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shape_valid_conv() {
        let p = ConvProblem::single(28, 64, 5);
        assert_eq!(p.oy(), 24);
        assert_eq!(p.ox(), 24);
        assert_eq!(p.out_elems(), 64 * 24 * 24);
    }

    #[test]
    fn k1_preserves_map_size() {
        let p = ConvProblem::multi(64, 14, 128, 1);
        assert_eq!(p.oy(), 14);
        assert_eq!(p.ox(), 14);
    }

    #[test]
    fn fma_count_matches_paper_formula() {
        // eq.(1): every output element needs C*K*K FMAs
        let p = ConvProblem::multi(4, 10, 8, 3);
        assert_eq!(p.fma_ops(), (8 * 8 * 8) as u64 * (4 * 3 * 3) as u64);
        assert_eq!(p.flops(), 2 * p.fma_ops());
    }

    #[test]
    fn input_bytes_eq3() {
        // eq.(3): (K*K*M + Wx*Wy) * 4 for single channel
        let p = ConvProblem::single(32, 16, 3);
        assert_eq!(p.input_bytes(), (3 * 3 * 16 + 32 * 32) * 4);
    }

    #[test]
    fn validity() {
        assert!(ConvProblem::single(8, 1, 8).valid());
        assert!(!ConvProblem::single(8, 1, 9).valid());
        assert!(!ConvProblem { c: 0, wy: 8, wx: 8, m: 1, k: 1 }.valid());
    }

    #[test]
    fn multi_channel_intensity_higher_than_single() {
        // the paper's premise: multi-channel has enough work to prefetch-hide,
        // single-channel on small maps does not.
        let s = ConvProblem::single(28, 64, 3);
        let m = ConvProblem::multi(256, 28, 64, 3);
        assert!(m.arithmetic_intensity() > s.arithmetic_intensity());
    }

    #[test]
    fn labels_distinguish_kinds() {
        assert!(ConvProblem::single(28, 4, 3).label().starts_with("single"));
        assert!(ConvProblem::multi(8, 28, 4, 3).label().starts_with("multi"));
    }
}
