//! Batched convolution — the serving-regime payload: one problem shape,
//! `n` independent images pushed through the same filter set (the
//! batch > 1 regime cuConv (arXiv 2103.16234) serves and maxDNN
//! (arXiv 1501.06633) benchmarks).
//!
//! Semantics are strictly "n independent single-image convolutions":
//! the batched CPU reference is definitionally a loop over
//! `conv2d_multi_cpu`, and `rust/tests/fleet_proptests.rs` pins the
//! bit-identity.  The *performance* story differs — a batched kernel
//! launches once and keeps the prefetch pipeline warm across images —
//! and lives in `gpusim::KernelPlan::batched` / `plans::batched_cycles`.

use super::cpu::conv2d_multi_cpu;
use super::problem::{ConvProblem, BYTES_F32};

/// A batch of `n` images convolved against one filter set.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BatchedConv {
    pub problem: ConvProblem,
    /// images in the batch (n >= 1; n = 1 is exactly the single path)
    pub n: usize,
}

impl BatchedConv {
    pub fn new(problem: ConvProblem, n: usize) -> BatchedConv {
        BatchedConv { problem, n }
    }

    pub fn single(problem: ConvProblem) -> BatchedConv {
        BatchedConv { problem, n: 1 }
    }

    pub fn valid(&self) -> bool {
        self.n >= 1 && self.problem.valid()
    }

    /// Elements across all images of the batch.
    pub fn map_elems(&self) -> usize {
        self.n * self.problem.map_elems()
    }

    /// Filter elements (shared across the batch — loaded per image by
    /// the schedule, but one set exists).
    pub fn filter_elems(&self) -> usize {
        self.problem.filter_elems()
    }

    /// Output elements across all images.
    pub fn out_elems(&self) -> usize {
        self.n * self.problem.out_elems()
    }

    /// FMA operations for the whole batch.
    pub fn fma_ops(&self) -> u64 {
        self.n as u64 * self.problem.fma_ops()
    }

    /// Compulsory DRAM bytes: every image + output once, filters once.
    pub fn compulsory_bytes(&self) -> usize {
        (self.map_elems() + self.filter_elems() + self.out_elems()) * BYTES_F32
    }

    pub fn label(&self) -> String {
        format!("{} xb{}", self.problem.label(), self.n)
    }
}

/// Batched CPU reference: `images` is `n` concatenated image buffers
/// (row-major, `n * C*Wy*Wx` values); returns `n` concatenated outputs.
/// Definitionally `n` independent `conv2d_multi_cpu` runs — the
/// differential tests require bit-identity with that loop.
pub fn conv2d_batched_cpu(b: &BatchedConv, images: &[f32], filters: &[f32]) -> Vec<f32> {
    assert!(b.valid(), "invalid batched problem");
    assert_eq!(images.len(), b.map_elems(), "batched image size");
    let per_in = b.problem.map_elems();
    let per_out = b.problem.out_elems();
    let mut out = Vec::with_capacity(b.n * per_out);
    for i in 0..b.n {
        out.extend(conv2d_multi_cpu(&b.problem, &images[i * per_in..(i + 1) * per_in], filters));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn accounting_scales_with_n() {
        let p = ConvProblem::multi(4, 8, 6, 3);
        let b = BatchedConv::new(p, 5);
        assert!(b.valid());
        assert_eq!(b.map_elems(), 5 * p.map_elems());
        assert_eq!(b.filter_elems(), p.filter_elems());
        assert_eq!(b.out_elems(), 5 * p.out_elems());
        assert_eq!(b.fma_ops(), 5 * p.fma_ops());
        assert_eq!(
            b.compulsory_bytes(),
            (5 * p.map_elems() + p.filter_elems() + 5 * p.out_elems()) * BYTES_F32
        );
    }

    #[test]
    fn n1_is_the_single_problem() {
        let p = ConvProblem::single(16, 4, 3);
        let b = BatchedConv::single(p);
        assert_eq!(b.n, 1);
        assert_eq!(b.fma_ops(), p.fma_ops());
        assert!(b.label().contains("xb1"));
    }

    #[test]
    fn zero_batch_is_invalid() {
        assert!(!BatchedConv::new(ConvProblem::single(8, 2, 3), 0).valid());
    }

    #[test]
    fn batched_cpu_equals_single_loop_bitwise() {
        let p = ConvProblem::multi(3, 10, 4, 3);
        let b = BatchedConv::new(p, 4);
        let mut rng = Rng::new(77);
        let images = rng.normal_vec(b.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let batched = conv2d_batched_cpu(&b, &images, &filters);
        for i in 0..b.n {
            let single = conv2d_multi_cpu(
                &p,
                &images[i * p.map_elems()..(i + 1) * p.map_elems()],
                &filters,
            );
            assert_eq!(
                &batched[i * p.out_elems()..(i + 1) * p.out_elems()],
                &single[..],
                "image {i} differs"
            );
        }
    }

    #[test]
    #[should_panic(expected = "batched image size")]
    fn wrong_batched_image_size_panics() {
        let b = BatchedConv::new(ConvProblem::single(4, 1, 1), 2);
        conv2d_batched_cpu(&b, &[0.0; 16], &[1.0]);
    }
}
