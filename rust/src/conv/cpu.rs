//! Direct CPU convolution — the rust-side numeric oracle.
//!
//! Used by integration tests and examples to cross-check what comes back
//! from the PJRT executables (whose numerics were produced by the Pallas
//! kernels).  Plain nested loops, f32 accumulation in f64 for stability.
//!
//! Layouts match the artifacts: image row-major (C, Wy, Wx), filters
//! (M, C, K, K), output (M, Oy, Ox).

use super::problem::ConvProblem;

/// Multi-channel direct convolution (eq. 1). `image.len() == C*Wy*Wx`,
/// `filters.len() == M*C*K*K`; returns `M*Oy*Ox` values.
pub fn conv2d_multi_cpu(p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
    assert_eq!(image.len(), p.map_elems(), "image size");
    assert_eq!(filters.len(), p.filter_elems(), "filter size");
    let (c, wy, wx, m, k) = (p.c, p.wy, p.wx, p.m, p.k);
    let (oy, ox) = (p.oy(), p.ox());
    let mut out = vec![0f32; m * oy * ox];
    for fm in 0..m {
        for y in 0..oy {
            for x in 0..ox {
                let mut acc = 0f64;
                for ch in 0..c {
                    for i in 0..k {
                        let img_row = &image[ch * wy * wx + (y + i) * wx + x..];
                        let flt_row = &filters[fm * c * k * k + ch * k * k + i * k..];
                        for j in 0..k {
                            acc += img_row[j] as f64 * flt_row[j] as f64;
                        }
                    }
                }
                out[fm * oy * ox + y * ox + x] = acc as f32;
            }
        }
    }
    out
}

/// Single-channel direct convolution (eq. 2): image (Wy, Wx), filters (M, K, K).
pub fn conv2d_single_cpu(p: &ConvProblem, image: &[f32], filters: &[f32]) -> Vec<f32> {
    assert_eq!(p.c, 1, "single-channel problem expected");
    conv2d_multi_cpu(p, image, filters)
}

/// Max |a-b| over two equal-length slices — the allclose helper the
/// integration tests use against PJRT outputs.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_filter_single() {
        // K=1, single filter of value 1.0 => output == image
        let p = ConvProblem::single(4, 1, 1);
        let image: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = conv2d_single_cpu(&p, &image, &[1.0]);
        assert_eq!(out, image);
    }

    #[test]
    fn corner_tap_orientation() {
        // Tap at (0,0) selects the top-left window (cross-correlation, no
        // filter flip) — pins the same orientation the python oracle tests.
        let p = ConvProblem::single(3, 1, 2);
        let image: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let filt = [1.0, 0.0, 0.0, 0.0];
        let out = conv2d_single_cpu(&p, &image, &filt);
        assert_eq!(out, vec![0.0, 1.0, 3.0, 4.0]);
        let filt2 = [0.0, 0.0, 0.0, 1.0]; // tap at (1,1)
        let out2 = conv2d_single_cpu(&p, &image, &filt2);
        assert_eq!(out2, vec![4.0, 5.0, 7.0, 8.0]);
    }

    #[test]
    fn channel_summation() {
        // C channels of constant 2.0 with all-ones 1x1 filters => 2*C
        let c = 5;
        let p = ConvProblem::multi(c, 3, 1, 1);
        let image = vec![2.0f32; c * 9];
        let filters = vec![1.0f32; c];
        let out = conv2d_multi_cpu(&p, &image, &filters);
        assert!(out.iter().all(|&v| v == 2.0 * c as f32));
    }

    #[test]
    fn box_filter_known_sum() {
        let p = ConvProblem::single(3, 1, 3);
        let image: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let filters = vec![1.0f32; 9];
        let out = conv2d_single_cpu(&p, &image, &filters);
        assert_eq!(out, vec![45.0]);
    }

    #[test]
    fn linearity_under_scaling() {
        let p = ConvProblem::multi(3, 8, 4, 3);
        let mut rng = Rng::new(5);
        let image = rng.normal_vec(p.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let out1 = conv2d_multi_cpu(&p, &image, &filters);
        let scaled: Vec<f32> = image.iter().map(|x| 2.0 * x).collect();
        let out2 = conv2d_multi_cpu(&p, &scaled, &filters);
        for (a, b) in out1.iter().zip(&out2) {
            assert!((2.0 * a - b).abs() < 1e-4, "{a} {b}");
        }
    }

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    #[should_panic(expected = "image size")]
    fn wrong_image_size_panics() {
        let p = ConvProblem::single(4, 1, 1);
        conv2d_single_cpu(&p, &[0.0; 3], &[1.0]);
    }
}
