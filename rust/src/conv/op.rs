//! First-class convolution op: stride / padding / grouped (incl.
//! depthwise) convolution over a `ConvProblem` core, with an **exact
//! lowering** onto the paper-supported stride-1 / valid / dense regime.
//!
//! The paper's kernels (§3) compute stride-1 valid dense convolutions.
//! Real networks also need 'same' padding (VGG/ResNet bodies), stride-2
//! downsampling (ResNet stage transitions, MobileNet), and grouped /
//! depthwise convolution (the whole MobileNet family).  `ConvOp` makes
//! those parameters first-class and `lower()` maps any op onto the
//! paper regime exactly:
//!
//!  * **padding** folds into an enlarged map — a valid conv over the
//!    zero-embedded `(Wy+2p) x (Wx+2p)` map IS the padded conv
//!    (bit-identically: the extra terms are `0 * w`, which never change
//!    an f64 accumulator);
//!  * **groups** split into `G` per-group sub-problems of `C/G`
//!    channels and `M/G` filters, batched under one launch
//!    (`KernelPlan::batched`/`grouped` on the timing side, a
//!    concatenation of per-group convs on the numeric side);
//!  * **stride** is handled by output decimation in the reference
//!    (compute the stride-1 output, keep every `stride`-th row/column)
//!    and natively in the cost model by shrinking the output strip
//!    schedule (`KernelPlan::decimated` — only the kept outputs'
//!    FMAs/writeback are charged; the backend layer prices that
//!    against the naive compute-everything floor).
//!
//! The generalized CPU reference (`conv2d_op_cpu`) and the lowered
//! path (`conv2d_op_lowered_cpu`) are **bit-identical** by
//! construction; `rust/tests/op_proptests.rs` pins the zero-embed,
//! decimation and per-group-concatenation identities.

use super::cpu::conv2d_multi_cpu;
use super::problem::{ConvProblem, BYTES_F32};

/// One convolution op instance: the paper's problem core plus the
/// parameters real networks need.  `core` describes the *unpadded*
/// input geometry — `c` total input channels on a `wy x wx` map, `m`
/// total filters of size `k x k x (c/groups)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvOp {
    pub core: ConvProblem,
    /// output sampling stride (1 = the paper's dense output)
    pub stride: usize,
    /// symmetric zero padding on each map edge (0 = valid)
    pub pad: usize,
    /// filter groups: channels and filters split into `groups` equal
    /// parts, group g's filters reading only group g's channels
    /// (groups == c == m is depthwise)
    pub groups: usize,
}

impl ConvOp {
    /// The paper's regime: stride 1, valid, dense.
    pub fn dense(core: ConvProblem) -> ConvOp {
        ConvOp { core, stride: 1, pad: 0, groups: 1 }
    }

    /// 'same' convolution (odd K): stride 1, pad (K-1)/2 — output map
    /// equals the input map.
    pub fn same(core: ConvProblem) -> ConvOp {
        assert!(core.k % 2 == 1, "'same' padding needs odd K");
        ConvOp { core, stride: 1, pad: (core.k - 1) / 2, groups: 1 }
    }

    /// Strided dense convolution with explicit padding.
    pub fn strided(core: ConvProblem, stride: usize, pad: usize) -> ConvOp {
        ConvOp { core, stride, pad, groups: 1 }
    }

    /// Depthwise KxK ('same'-padded): one filter per channel.
    pub fn depthwise(c: usize, w: usize, k: usize, stride: usize) -> ConvOp {
        assert!(k % 2 == 1, "depthwise 'same' needs odd K");
        ConvOp { core: ConvProblem::multi(c, w, c, k), stride, pad: (k - 1) / 2, groups: c }
    }

    /// Pointwise 1x1 dense convolution.
    pub fn pointwise(c: usize, w: usize, m: usize) -> ConvOp {
        ConvOp::dense(ConvProblem::multi(c, w, m, 1))
    }

    /// Is this op already in the paper regime (no lowering needed)?
    pub fn is_dense(&self) -> bool {
        self.stride == 1 && self.pad == 0 && self.groups == 1
    }

    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.groups == self.core.c && self.groups == self.core.m
    }

    /// Padded map height/width.
    pub fn padded_wy(&self) -> usize {
        self.core.wy + 2 * self.pad
    }

    pub fn padded_wx(&self) -> usize {
        self.core.wx + 2 * self.pad
    }

    /// Output height: floor((Wy + 2p - K) / stride) + 1.
    pub fn oy(&self) -> usize {
        (self.padded_wy() - self.core.k) / self.stride + 1
    }

    pub fn ox(&self) -> usize {
        (self.padded_wx() - self.core.k) / self.stride + 1
    }

    pub fn valid(&self) -> bool {
        let p = &self.core;
        p.c >= 1
            && p.m >= 1
            && p.k >= 1
            && p.wy >= 1
            && p.wx >= 1
            && self.stride >= 1
            && self.groups >= 1
            && p.c % self.groups == 0
            && p.m % self.groups == 0
            // the kernel must always overlap at least one real input
            // element: pad < K, and the padded map must fit the kernel
            && self.pad < p.k
            && self.padded_wy() >= p.k
            && self.padded_wx() >= p.k
    }

    /// Input elements (unpadded, as stored): C * Wy * Wx.
    pub fn map_elems(&self) -> usize {
        self.core.map_elems()
    }

    /// Filter elements: M * (C/G) * K * K — grouped filters only read
    /// their group's channels.
    pub fn filter_elems(&self) -> usize {
        self.core.m * (self.core.c / self.groups) * self.core.k * self.core.k
    }

    /// Output elements: M * Oy * Ox.
    pub fn out_elems(&self) -> usize {
        self.core.m * self.oy() * self.ox()
    }

    /// FMAs to compute the op's own output (not the lowered
    /// super-set): out_elems * (C/G) * K * K.
    pub fn fma_ops(&self) -> u64 {
        self.out_elems() as u64
            * ((self.core.c / self.groups) * self.core.k * self.core.k) as u64
    }

    /// Compulsory DRAM bytes: inputs once + filters once + outputs once.
    pub fn compulsory_bytes(&self) -> usize {
        (self.map_elems() + self.filter_elems() + self.out_elems()) * BYTES_F32
    }

    /// Exact lowering onto the paper regime.
    pub fn lower(&self) -> Lowering {
        assert!(self.valid(), "invalid op {self:?}");
        let unit = ConvProblem {
            c: self.core.c / self.groups,
            wy: self.padded_wy(),
            wx: self.padded_wx(),
            m: self.core.m / self.groups,
            k: self.core.k,
        };
        Lowering { unit, groups: self.groups, stride: self.stride }
    }

    /// Fraction of the lowered unit's stride-1 output this op keeps
    /// (1.0 for stride 1; the cost model's decimation factor).
    pub fn output_keep_fraction(&self) -> f64 {
        let l = self.lower();
        (self.oy() * self.ox()) as f64 / (l.unit.oy() * l.unit.ox()) as f64
    }

    pub fn label(&self) -> String {
        if self.is_dense() {
            return self.core.label();
        }
        let mut s = self.core.label();
        if self.stride > 1 {
            s.push_str(&format!(" s{}", self.stride));
        }
        if self.pad > 0 {
            s.push_str(&format!(" p{}", self.pad));
        }
        if self.groups > 1 {
            if self.is_depthwise() {
                s.push_str(" dw");
            } else {
                s.push_str(&format!(" g{}", self.groups));
            }
        }
        s
    }
}

/// An op lowered onto the paper regime: `groups` independent copies of
/// the stride-1 valid dense `unit` (on the zero-embedded map), whose
/// outputs are decimated by `stride`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lowering {
    /// the per-group stride-1 valid dense problem (padded map folded in)
    pub unit: ConvProblem,
    pub groups: usize,
    pub stride: usize,
}

/// Zero-embed a `c x wy x wx` tensor into its `(wy+2p) x (wx+2p)` frame.
pub fn zero_embed(image: &[f32], c: usize, wy: usize, wx: usize, pad: usize) -> Vec<f32> {
    assert_eq!(image.len(), c * wy * wx, "embed input size");
    if pad == 0 {
        return image.to_vec();
    }
    let (py, px) = (wy + 2 * pad, wx + 2 * pad);
    let mut out = vec![0f32; c * py * px];
    for ch in 0..c {
        for y in 0..wy {
            let src = ch * wy * wx + y * wx;
            let dst = ch * py * px + (y + pad) * px + pad;
            out[dst..dst + wx].copy_from_slice(&image[src..src + wx]);
        }
    }
    out
}

/// Keep every `stride`-th row and column of an `m x oy x ox` stride-1
/// output (the lowering's output decimation).
pub fn decimate(full: &[f32], m: usize, oy: usize, ox: usize, stride: usize) -> Vec<f32> {
    assert_eq!(full.len(), m * oy * ox, "decimate input size");
    if stride == 1 {
        return full.to_vec();
    }
    let (dy, dx) = ((oy - 1) / stride + 1, (ox - 1) / stride + 1);
    let mut out = Vec::with_capacity(m * dy * dx);
    for fm in 0..m {
        for y in (0..oy).step_by(stride) {
            for x in (0..ox).step_by(stride) {
                out.push(full[fm * oy * ox + y * ox + x]);
            }
        }
    }
    out
}

/// Generalized direct CPU reference: eq.(1) with stride / padding /
/// groups.  Layouts: image `(C, Wy, Wx)`, filters `(M, C/G, K, K)`,
/// output `(M, Oy, Ox)`.  Each output element accumulates its real
/// terms in ascending (group-local channel, i, j) order into one f64 —
/// the same chain the lowered path produces, so the two are
/// bit-identical.
pub fn conv2d_op_cpu(op: &ConvOp, image: &[f32], filters: &[f32]) -> Vec<f32> {
    assert!(op.valid(), "invalid op {op:?}");
    assert_eq!(image.len(), op.map_elems(), "op image size");
    assert_eq!(filters.len(), op.filter_elems(), "op filter size");
    let (wy, wx, k) = (op.core.wy, op.core.wx, op.core.k);
    let (c_g, m_g) = (op.core.c / op.groups, op.core.m / op.groups);
    let (oy, ox) = (op.oy(), op.ox());
    let (stride, pad) = (op.stride, op.pad);
    let mut out = vec![0f32; op.out_elems()];
    for g in 0..op.groups {
        for fl in 0..m_g {
            let fm = g * m_g + fl;
            let fbase = fm * c_g * k * k;
            for y in 0..oy {
                for x in 0..ox {
                    let mut acc = 0f64;
                    for cl in 0..c_g {
                        let ch = g * c_g + cl;
                        for i in 0..k {
                            let iy = (y * stride + i) as isize - pad as isize;
                            if iy < 0 || iy >= wy as isize {
                                continue;
                            }
                            for j in 0..k {
                                let ix = (x * stride + j) as isize - pad as isize;
                                if ix < 0 || ix >= wx as isize {
                                    continue;
                                }
                                acc += image[ch * wy * wx + iy as usize * wx + ix as usize]
                                    as f64
                                    * filters[fbase + cl * k * k + i * k + j] as f64;
                            }
                        }
                    }
                    out[fm * oy * ox + y * ox + x] = acc as f32;
                }
            }
        }
    }
    out
}

/// The exact lowered execution with a pluggable stride-1 unit kernel:
/// zero-embed each group's channels, run `unit_conv` (any routine
/// bit-identical to `conv2d_multi_cpu` on the unit problem), decimate,
/// concatenate per-group outputs.  Bit-identical to `conv2d_op_cpu`
/// whenever `unit_conv` is bit-identical to the oracle — padding terms
/// are `0 * w` (never change an f64 accumulator) and decimation picks
/// finished elements.
pub fn conv2d_op_lowered_with(
    op: &ConvOp,
    image: &[f32],
    filters: &[f32],
    unit_conv: &dyn Fn(&ConvProblem, &[f32], &[f32]) -> Vec<f32>,
) -> Vec<f32> {
    assert!(op.valid(), "invalid op {op:?}");
    assert_eq!(image.len(), op.map_elems(), "op image size");
    assert_eq!(filters.len(), op.filter_elems(), "op filter size");
    let l = op.lower();
    let (wy, wx) = (op.core.wy, op.core.wx);
    let (c_g, m_g) = (l.unit.c, l.unit.m);
    let group_filters = m_g * c_g * op.core.k * op.core.k;
    let mut out = Vec::with_capacity(op.out_elems());
    for g in 0..l.groups {
        let embedded =
            zero_embed(&image[g * c_g * wy * wx..(g + 1) * c_g * wy * wx], c_g, wy, wx, op.pad);
        let full = unit_conv(
            &l.unit,
            &embedded,
            &filters[g * group_filters..(g + 1) * group_filters],
        );
        out.extend(decimate(&full, m_g, l.unit.oy(), l.unit.ox(), l.stride));
    }
    out
}

/// `conv2d_op_lowered_with` over the plain-loop oracle — the default
/// lowered executor (what the serving path's CPU fallback runs).
pub fn conv2d_op_lowered_cpu(op: &ConvOp, image: &[f32], filters: &[f32]) -> Vec<f32> {
    conv2d_op_lowered_with(op, image, filters, &|p, img, flt| conv2d_multi_cpu(p, img, flt))
}

/// A batch of `n` images through one op.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BatchedConvOp {
    pub op: ConvOp,
    pub n: usize,
}

impl BatchedConvOp {
    pub fn new(op: ConvOp, n: usize) -> BatchedConvOp {
        BatchedConvOp { op, n }
    }

    pub fn single(op: ConvOp) -> BatchedConvOp {
        BatchedConvOp { op, n: 1 }
    }

    /// A dense batched op from the historical batched problem.
    pub fn dense(b: &super::batched::BatchedConv) -> BatchedConvOp {
        BatchedConvOp { op: ConvOp::dense(b.problem), n: b.n }
    }

    pub fn valid(&self) -> bool {
        self.n >= 1 && self.op.valid()
    }

    pub fn map_elems(&self) -> usize {
        self.n * self.op.map_elems()
    }

    pub fn filter_elems(&self) -> usize {
        self.op.filter_elems()
    }

    pub fn out_elems(&self) -> usize {
        self.n * self.op.out_elems()
    }

    pub fn label(&self) -> String {
        format!("{} xb{}", self.op.label(), self.n)
    }

    /// Device bytes this job pins while resident on a shard: batched
    /// inputs + filters + batched outputs at f32, rounded up to the
    /// pool's 256 B class lattice (`graph::ARENA_ALIGN`).  This is the
    /// planned footprint the fleet's pool-pressure admission reserves
    /// at placement and releases at completion.
    pub fn footprint_bytes(&self) -> usize {
        const ALIGN: usize = 256; // = graph::ARENA_ALIGN (conv is below graph)
        let bytes = (self.map_elems() + self.filter_elems() + self.out_elems()) * BYTES_F32;
        (bytes + ALIGN - 1) / ALIGN * ALIGN
    }
}

/// Batched generalized reference: definitionally `n` independent
/// single-image `conv2d_op_cpu` runs.
pub fn conv2d_batched_op_cpu(b: &BatchedConvOp, images: &[f32], filters: &[f32]) -> Vec<f32> {
    assert!(b.valid(), "invalid batched op");
    assert_eq!(images.len(), b.map_elems(), "batched op image size");
    let per_in = b.op.map_elems();
    let mut out = Vec::with_capacity(b.out_elems());
    for i in 0..b.n {
        out.extend(conv2d_op_cpu(&b.op, &images[i * per_in..(i + 1) * per_in], filters));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn bit_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn footprint_is_aligned_and_scales_with_batch() {
        let op = ConvOp::dense(ConvProblem::multi(8, 14, 16, 3));
        let one = BatchedConvOp::single(op);
        let eight = BatchedConvOp::new(op, 8);
        assert_eq!(one.footprint_bytes() % 256, 0);
        let raw =
            |b: &BatchedConvOp| (b.map_elems() + b.filter_elems() + b.out_elems()) * BYTES_F32;
        assert!(one.footprint_bytes() >= raw(&one));
        assert!(one.footprint_bytes() - raw(&one) < 256);
        // maps and outputs scale with n, filters don't
        assert!(eight.footprint_bytes() > 4 * one.footprint_bytes());
        assert!(eight.footprint_bytes() < 8 * one.footprint_bytes());
    }

    #[test]
    fn dense_op_is_the_plain_problem() {
        let p = ConvProblem::multi(4, 10, 6, 3);
        let op = ConvOp::dense(p);
        assert!(op.is_dense() && op.valid());
        assert_eq!((op.oy(), op.ox()), (p.oy(), p.ox()));
        assert_eq!(op.filter_elems(), p.filter_elems());
        assert_eq!(op.fma_ops(), p.fma_ops());
        assert_eq!(op.label(), p.label());
        assert_eq!(op.lower().unit, p);
        let mut rng = Rng::new(3);
        let image = rng.normal_vec(p.map_elems());
        let filters = rng.normal_vec(p.filter_elems());
        let direct = conv2d_op_cpu(&op, &image, &filters);
        assert!(bit_eq(&direct, &crate::conv::conv2d_multi_cpu(&p, &image, &filters)));
    }

    #[test]
    fn same_padding_shapes() {
        let op = ConvOp::same(ConvProblem::multi(8, 14, 16, 3));
        assert_eq!(op.pad, 1);
        assert_eq!((op.oy(), op.ox()), (14, 14));
        let op5 = ConvOp::same(ConvProblem::multi(8, 28, 16, 5));
        assert_eq!(op5.pad, 2);
        assert_eq!(op5.oy(), 28);
    }

    #[test]
    fn strided_shapes_match_the_conv_formula() {
        // ResNet stage transition: 3x3/s2 'same' on 56 -> 28
        let op = ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1);
        assert_eq!((op.oy(), op.ox()), (28, 28));
        // 1x1/s2 projection: 56 -> 28
        let proj = ConvOp::strided(ConvProblem::multi(64, 56, 128, 1), 2, 0);
        assert_eq!(proj.oy(), 28);
        // MobileNet conv1: 3x3/s2 p1 on 224 -> 112
        let c1 = ConvOp::strided(ConvProblem::multi(3, 224, 32, 3), 2, 1);
        assert_eq!(c1.oy(), 112);
    }

    #[test]
    fn depthwise_accounting() {
        let dw = ConvOp::depthwise(32, 112, 3, 1);
        assert!(dw.valid() && dw.is_depthwise());
        assert_eq!(dw.filter_elems(), 32 * 9, "one KxK filter per channel");
        assert_eq!(dw.out_elems(), 32 * 112 * 112);
        assert_eq!(dw.fma_ops(), (32 * 112 * 112 * 9) as u64);
        let l = dw.lower();
        assert_eq!(l.groups, 32);
        assert_eq!(l.unit, ConvProblem { c: 1, wy: 114, wx: 114, m: 1, k: 3 });
    }

    #[test]
    fn validity_rules() {
        let p = ConvProblem::multi(6, 8, 9, 3);
        assert!(!ConvOp { core: p, stride: 0, pad: 0, groups: 1 }.valid());
        assert!(!ConvOp { core: p, stride: 1, pad: 3, groups: 1 }.valid(), "pad >= K");
        assert!(!ConvOp { core: p, stride: 1, pad: 0, groups: 4 }.valid(), "C % G != 0");
        assert!(!ConvOp { core: p, stride: 1, pad: 0, groups: 2 }.valid(), "M % G != 0");
        assert!(ConvOp { core: p, stride: 2, pad: 1, groups: 3 }.valid());
        // padding can make an otherwise-too-small map legal
        let tiny = ConvProblem::multi(2, 2, 2, 3);
        assert!(!ConvOp::dense(tiny).valid());
        assert!(ConvOp { core: tiny, stride: 1, pad: 1, groups: 1 }.valid());
    }

    #[test]
    fn zero_embed_frames_exactly() {
        let image: Vec<f32> = (1..=8).map(|i| i as f32).collect(); // 2ch 2x2
        let out = zero_embed(&image, 2, 2, 2, 1);
        assert_eq!(out.len(), 2 * 16);
        // channel 0 centre
        assert_eq!(out[5], 1.0);
        assert_eq!(out[6], 2.0);
        assert_eq!(out[9], 3.0);
        assert_eq!(out[10], 4.0);
        // frame is zero
        assert_eq!(out[0], 0.0);
        assert_eq!(out[15], 0.0);
        assert!(bit_eq(&zero_embed(&image, 2, 2, 2, 0), &image));
    }

    #[test]
    fn decimate_picks_the_grid() {
        let full: Vec<f32> = (0..16).map(|i| i as f32).collect(); // 1 x 4x4
        assert_eq!(decimate(&full, 1, 4, 4, 2), vec![0.0, 2.0, 8.0, 10.0]);
        assert_eq!(decimate(&full, 1, 4, 4, 3), vec![0.0, 3.0, 12.0, 15.0]);
        assert!(bit_eq(&decimate(&full, 1, 4, 4, 1), &full));
    }

    #[test]
    fn lowered_identities_bit_exact() {
        let mut rng = Rng::new(0x0505);
        let cases = [
            ConvOp::same(ConvProblem::multi(4, 9, 6, 3)),
            ConvOp::strided(ConvProblem::multi(3, 11, 4, 3), 2, 1),
            ConvOp::strided(ConvProblem::multi(2, 12, 4, 3), 3, 0),
            ConvOp { core: ConvProblem::multi(6, 8, 9, 3), stride: 2, pad: 1, groups: 3 },
            ConvOp::depthwise(5, 10, 3, 2),
            ConvOp::pointwise(7, 6, 4),
        ];
        for op in cases {
            let image = rng.normal_vec(op.map_elems());
            let filters = rng.normal_vec(op.filter_elems());
            let direct = conv2d_op_cpu(&op, &image, &filters);
            let lowered = conv2d_op_lowered_cpu(&op, &image, &filters);
            assert!(bit_eq(&direct, &lowered), "{} diverges", op.label());
            assert_eq!(direct.len(), op.out_elems());
        }
    }

    #[test]
    fn grouped_equals_concatenated_per_group_convs() {
        let op = ConvOp { core: ConvProblem::multi(6, 8, 4, 3), stride: 1, pad: 0, groups: 2 };
        let mut rng = Rng::new(0x6666);
        let image = rng.normal_vec(op.map_elems());
        let filters = rng.normal_vec(op.filter_elems());
        let got = conv2d_op_cpu(&op, &image, &filters);
        let unit = op.lower().unit; // C=3, M=2
        let mut want = vec![];
        for g in 0..2 {
            want.extend(crate::conv::conv2d_multi_cpu(
                &unit,
                &image[g * unit.map_elems()..(g + 1) * unit.map_elems()],
                &filters[g * unit.filter_elems()..(g + 1) * unit.filter_elems()],
            ));
        }
        assert!(bit_eq(&got, &want));
    }

    #[test]
    fn keep_fraction_and_labels() {
        let op = ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1);
        let l = op.lower();
        assert_eq!(l.unit.oy(), 56);
        let keep = op.output_keep_fraction();
        assert!((keep - (28.0 * 28.0) / (56.0 * 56.0)).abs() < 1e-12);
        assert!(op.label().contains("s2") && op.label().contains("p1"), "{}", op.label());
        assert!(ConvOp::depthwise(8, 14, 3, 1).label().contains("dw"));
    }

    #[test]
    fn batched_op_loops_single_images_bitwise() {
        let op = ConvOp::strided(ConvProblem::multi(3, 10, 4, 3), 2, 1);
        let b = BatchedConvOp::new(op, 3);
        assert!(b.valid());
        let mut rng = Rng::new(0xB0B);
        let images = rng.normal_vec(b.map_elems());
        let filters = rng.normal_vec(b.filter_elems());
        let batched = conv2d_batched_op_cpu(&b, &images, &filters);
        for i in 0..b.n {
            let single = conv2d_op_cpu(
                &op,
                &images[i * op.map_elems()..(i + 1) * op.map_elems()],
                &filters,
            );
            assert!(bit_eq(
                &batched[i * op.out_elems()..(i + 1) * op.out_elems()],
                &single
            ));
        }
        assert!(b.label().contains("xb3"));
        assert!(!BatchedConvOp::new(op, 0).valid());
    }
}
