//! Double-buffered prefetch pipeline — §2.2 method 1 / §3.2(4).
//!
//! A kernel is modelled as a sequence of *rounds* per SM.  Round r loads
//! its data set from global memory while round r-1's FMAs execute on the
//! cores (the paper's data prefetching; on the TPU mapping this is the
//! Pallas grid pipeline).  Total time is therefore
//!
//!   load(0) + sum_{r=1..n-1} max(load(r), compute(r-1)) + compute(n-1)
//!
//! plus a fixed kernel-launch overhead.  When compute(r) >= load(r+1)
//! for every r the memory latency is fully hidden — this is exactly the
//! paper's `Th >= N_FMA` condition, and `integration_simulation.rs`
//! asserts the equivalence on the paper's own workloads.

use super::memory::{latency_exposure, segment_efficiency};
use super::spec::GpuSpec;

/// How one pipeline stage's global->shared transfer is organised across
/// the block's warps (the multi-stage double-buffering axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Loading {
    /// default round-robin over warps; the paper's depth-2 schedule
    Cyclic,
    /// each warp owns a contiguous tile: merges adjacent segments, but
    /// serializes issue per warp so extra stages hide nothing
    Tilewise,
    /// issue-ordered merge: the segment gain AND stage amortization, at
    /// a per-round ordering-synchronisation cost
    Ordered,
}

impl Loading {
    pub const ALL: [Loading; 3] = [Loading::Cyclic, Loading::Tilewise, Loading::Ordered];

    /// short column tag for reports / plan names
    pub fn tag(self) -> &'static str {
        match self {
            Loading::Cyclic => "cyc",
            Loading::Tilewise => "tile",
            Loading::Ordered => "ord",
        }
    }

    /// full name for the plan cache / CLI
    pub fn name(self) -> &'static str {
        match self {
            Loading::Cyclic => "cyclic",
            Loading::Tilewise => "tilewise",
            Loading::Ordered => "ordered",
        }
    }

    pub fn parse(s: &str) -> Option<Loading> {
        Loading::ALL.iter().copied().find(|l| l.name() == s || l.tag() == s)
    }
}

/// Legal pipeline depths: 2 (the paper's ping-pong) through 4 buffers.
pub const MIN_STAGES: u32 = 2;
pub const MAX_STAGES: u32 = 4;
/// tilewise/ordered merge up to this many adjacent segments per issue
pub const TILE_MERGE_SEGMENTS: usize = 4;
/// per-round cost of the ordered strategy's issue-order synchronisation
pub const ORDERED_SYNC_CYCLES: f64 = 32.0;

/// Segment-coalescing profile of a loading strategy: tilewise and
/// ordered merge up to `TILE_MERGE_SEGMENTS` adjacent segments (capped
/// at the 128-byte transaction), scaling the stream efficiency by the
/// merged-over-base segment-efficiency ratio.
pub fn loading_efficiency(segment_bytes: usize, base_eff: f64, loading: Loading) -> f64 {
    if loading == Loading::Cyclic {
        return base_eff;
    }
    let merged = (TILE_MERGE_SEGMENTS * segment_bytes).min(128).max(segment_bytes);
    let gain = segment_efficiency(merged) / segment_efficiency(segment_bytes);
    (base_eff * gain).min(1.0)
}

/// One prefetch round on one SM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Round {
    /// bytes this SM fetches from global memory this round
    pub load_bytes: f64,
    /// contiguous-segment size of those fetches
    pub segment_bytes: usize,
    /// FMA operations this SM executes on the fetched data
    pub fma_ops: f64,
    /// when a round mixes streams with different coalescing (filter
    /// segments + map strips), plans pre-combine their efficiencies and
    /// set this instead of `segment_bytes`
    pub eff_override: Option<f64>,
    /// the share of `load_bytes` that is filter traffic — the stream a
    /// cross-image residency mode (`KernelPlan::batched_resident`) can
    /// drop from warm rounds.  0.0 = residency not expressible.
    pub filter_bytes: f64,
    /// contiguous-segment size of that filter stream (0 when untagged)
    pub filter_seg: usize,
    /// latency-hiding floor: bytes still in flight when `load_bytes`
    /// shrank because part of the traffic is served by a resident copy
    /// (L2 or smem) instead of DRAM.  0.0 = `load_bytes` is the
    /// in-flight volume.
    pub inflight_bytes: f64,
}

impl Round {
    pub fn new(load_bytes: f64, segment_bytes: usize, fma_ops: f64) -> Round {
        Round {
            load_bytes,
            segment_bytes,
            fma_ops,
            eff_override: None,
            filter_bytes: 0.0,
            filter_seg: 0,
            inflight_bytes: 0.0,
        }
    }

    /// Round whose access efficiency was combined from several streams,
    /// carrying an explicit effective segment size (the loading
    /// strategies' merge profile needs it; a hardcoded 128 would credit
    /// tilewise/ordered with zero segment gain on mixed rounds).
    pub fn with_efficiency(load_bytes: f64, segment_bytes: usize, eff: f64, fma_ops: f64) -> Round {
        assert!(eff > 0.0 && eff <= 1.0);
        Round {
            load_bytes,
            segment_bytes,
            fma_ops,
            eff_override: Some(eff),
            filter_bytes: 0.0,
            filter_seg: 0,
            inflight_bytes: 0.0,
        }
    }

    /// A round fetching several constituent streams
    /// `[(bytes, segment_bytes), ...]`.  Efficiency is the bus-time
    /// combination; the effective segment is total bytes over total
    /// segment issues (a bus-weighted harmonic mean).
    pub fn mixed(streams: &[(f64, usize)], fma_ops: f64) -> Round {
        let total: f64 = streams.iter().map(|&(b, _)| b).sum();
        let eff = combined_efficiency(
            &streams
                .iter()
                .map(|&(b, s)| (b, segment_efficiency(s)))
                .collect::<Vec<_>>(),
        );
        let issues: f64 =
            streams.iter().filter(|&&(_, s)| s > 0).map(|&(b, s)| b / s as f64).sum();
        let seg = if issues > 0.0 { (total / issues).round().max(1.0) as usize } else { 128 };
        Round::with_efficiency(total, seg, eff, fma_ops)
    }

    /// `mixed` with the first stream tagged as the filter component, so
    /// residency transforms know which bytes a warm image can skip.
    pub fn mixed_with_filter(
        filter: (f64, usize),
        rest: &[(f64, usize)],
        fma_ops: f64,
    ) -> Round {
        let mut streams = Vec::with_capacity(1 + rest.len());
        streams.push(filter);
        streams.extend_from_slice(rest);
        let mut r = Round::mixed(&streams, fma_ops);
        r.filter_bytes = filter.0;
        r.filter_seg = filter.1;
        r
    }

    /// Tag an already-built round's filter component (for rounds that
    /// fetch nothing but filters, e.g. a streamed filter-piece round).
    pub fn tagged_filter(mut self, filter_bytes: f64, filter_seg: usize) -> Round {
        assert!(filter_bytes <= self.load_bytes + 1e-9, "filter tag exceeds round load");
        self.filter_bytes = filter_bytes;
        self.filter_seg = filter_seg;
        self
    }

    /// The warm-image round.  Filter loads still issue (they hit the
    /// resident copy, so the issue pattern and in-flight volume that
    /// hide latency are the cold round's — `inflight_bytes` pins that
    /// floor), but they cost no DRAM bus time: the round's DRAM bytes
    /// drop to the non-filter share, repriced by subtracting the filter
    /// stream's bus time (floored at full speed).
    pub fn without_filter_loads(&self) -> Round {
        if self.filter_bytes <= 0.0 {
            return *self;
        }
        let rem_bytes = (self.load_bytes - self.filter_bytes).max(0.0);
        if rem_bytes <= 0.0 {
            // a pure-filter round streams nothing from DRAM warm, but
            // its loads still occupy the pipeline's in-flight window
            return Round {
                load_bytes: 0.0,
                eff_override: None,
                filter_bytes: 0.0,
                filter_seg: 0,
                inflight_bytes: self.load_bytes,
                ..*self
            };
        }
        let eff = self
            .eff_override
            .unwrap_or_else(|| segment_efficiency(self.segment_bytes));
        let filter_eff = segment_efficiency(self.filter_seg.max(1));
        let total_bus = self.load_bytes / eff.max(1e-9);
        // remaining bus time can never undercut moving rem_bytes at
        // efficiency 1.0, so the recomputed efficiency stays <= 1
        let rem_bus = (total_bus - self.filter_bytes / filter_eff.max(1e-9)).max(rem_bytes);
        let new_eff = (rem_bytes / rem_bus).min(1.0);
        Round {
            load_bytes: rem_bytes,
            eff_override: Some(new_eff),
            filter_bytes: 0.0,
            filter_seg: 0,
            inflight_bytes: self.load_bytes,
            ..*self
        }
    }
}

/// Issue-efficiency of the compute stream: fraction of the SM's peak FMA
/// rate the inner loop actually sustains (ILP, bank conflicts, tail
/// effects). Plans set this; 1.0 = perfect.
#[derive(Clone, Copy, Debug)]
pub struct ExecConfig {
    pub sms_active: u32,
    pub threads_per_sm: u32,
    pub compute_efficiency: f64,
    /// fixed launch + drain overhead in cycles (grid launch, tail wave)
    pub launch_overhead_cycles: f64,
    /// software-pipeline depth: number of shared-memory stage buffers
    pub stages: u32,
    /// how each stage's transfer is spread across the block's warps
    pub loading: Loading,
}

impl ExecConfig {
    pub fn new(spec: &GpuSpec, threads_per_sm: u32) -> ExecConfig {
        ExecConfig {
            sms_active: spec.sm_count,
            threads_per_sm,
            compute_efficiency: 0.9,
            launch_overhead_cycles: 4_000.0, // ~2.7 µs at 1.48 GHz
            stages: 2,
            loading: Loading::Cyclic,
        }
    }
}

/// Cycles to execute `fma_ops` on one SM.
pub fn compute_cycles(spec: &GpuSpec, cfg: &ExecConfig, fma_ops: f64) -> f64 {
    if fma_ops <= 0.0 {
        return 0.0;
    }
    // an SM with fewer threads than (cores x ILP-depth) cannot fill the
    // FMA pipes; 4 warps per SM quadrant is the floor for full issue
    let min_threads = 4 * spec.warp_size * (spec.cores_per_sm / spec.warp_size);
    let thread_fill = (cfg.threads_per_sm as f64 / min_threads as f64).min(1.0);
    fma_ops / (spec.fma_per_sm_cycle() as f64 * cfg.compute_efficiency * thread_fill)
}

/// Cycles to load one round on one SM inside the steady-state pipeline.
///
/// Unlike a cold `memory::transfer_cycles`, a pipelined round only pays
/// the share of the memory latency its in-flight volume cannot amortize
/// (`memory::latency_exposure` — Table 1's 768-thread / 3,072-B rows);
/// the full latency is charged once as the pipeline prologue in
/// `simulate_pipeline`.
/// With `s - 1` prefetches in flight the exposed latency is amortized
/// by `(s - 1)` for cyclic/ordered loading (tilewise serializes per
/// warp, so depth buys nothing there); §3.2's hiding condition
/// generalizes to `Th >= N_FMA / (s - 1)`.
pub fn load_cycles(spec: &GpuSpec, cfg: &ExecConfig, round: &Round) -> f64 {
    if round.load_bytes <= 0.0 {
        return 0.0;
    }
    let base = round
        .eff_override
        .unwrap_or_else(|| crate::gpusim::memory::segment_efficiency(round.segment_bytes));
    let eff = loading_efficiency(round.segment_bytes, base, cfg.loading);
    let per_sm_bw = spec.bytes_per_cycle() * eff / cfg.sms_active.max(1) as f64;
    let occ = (cfg.threads_per_sm as f64 / spec.threads_required_per_sm() as f64).min(1.0);
    let stream = round.load_bytes / (per_sm_bw * occ.max(1e-9));
    let depth = if cfg.loading == Loading::Tilewise { 1.0 } else { (cfg.stages - 1) as f64 };
    let exposed = spec.mem_latency_cycles as f64
        * latency_exposure(spec, cfg.threads_per_sm, round.load_bytes.max(round.inflight_bytes))
        / depth;
    let sync = if cfg.loading == Loading::Ordered { ORDERED_SYNC_CYCLES } else { 0.0 };
    exposed + stream + sync
}

/// Combine the coalescing efficiencies of several concurrent streams
/// (bytes_i at efficiency e_i) into one effective efficiency: total bytes
/// over total bus time.
pub fn combined_efficiency(streams: &[(f64, f64)]) -> f64 {
    let total: f64 = streams.iter().map(|(b, _)| b).sum();
    if total <= 0.0 {
        return 1.0;
    }
    let bus_time: f64 = streams.iter().map(|(b, e)| b / e.max(1e-9)).sum();
    total / bus_time
}

/// Outcome of a pipeline simulation.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub total_cycles: f64,
    pub load_cycles_sum: f64,
    pub compute_cycles_sum: f64,
    /// cycles in which compute stalled waiting for a fetch
    pub stall_cycles: f64,
    /// true if every round's fetch was fully hidden behind compute
    pub latency_hidden: bool,
}

impl PipelineResult {
    /// Which resource bounds this kernel.
    pub fn bottleneck(&self) -> &'static str {
        if self.stall_cycles > 0.05 * self.total_cycles {
            "memory"
        } else {
            "compute"
        }
    }
}

/// Simulate the double-buffered round pipeline on one SM.
///
/// Consecutive identical rounds are run-length compressed first, so the
/// cost is O(distinct runs) not O(rounds) — batched plans
/// (`KernelPlan::batched`) repeat the per-image schedule n times and
/// collapse right back here, and the result is the exact runs-form
/// arithmetic the tuner's scorer uses (score ≡ simulate by shared code,
/// not by tolerance).
pub fn simulate_pipeline(spec: &GpuSpec, cfg: &ExecConfig, rounds: &[Round]) -> PipelineResult {
    assert!(!rounds.is_empty(), "no rounds");
    let mut runs: Vec<(Round, usize)> = Vec::new();
    for &r in rounds {
        match runs.last_mut() {
            Some((prev, n)) if *prev == r => *n += 1,
            _ => runs.push((r, 1)),
        }
    }
    simulate_pipeline_runs(spec, cfg, &runs)
}

/// `simulate_pipeline` over a run-length round list: `(round, count)`
/// expands to `count` identical rounds.  Both our kernels produce
/// run-length-structured schedules (a cold first round, then identical
/// steady-state rounds), so a run of `count` rounds contributes its
/// prologue transition plus `(count-1) · max(load, compute)` — exactly
/// the expanded recurrence, in O(runs) instead of O(rounds).  The plan
/// builders' divisor sweeps and the tuner's scorer both use this; only
/// winning plans are ever materialized.
pub fn simulate_pipeline_runs(
    spec: &GpuSpec,
    cfg: &ExecConfig,
    runs: &[(Round, usize)],
) -> PipelineResult {
    assert!(!runs.is_empty() && runs.iter().all(|&(_, n)| n > 0), "no rounds");
    let loads: Vec<f64> = runs.iter().map(|(r, _)| load_cycles(spec, cfg, r)).collect();
    let computes: Vec<f64> =
        runs.iter().map(|(r, _)| compute_cycles(spec, cfg, r.fma_ops)).collect();

    // pipeline prologue: the very first fetch is cold — full latency
    let mut total = cfg.launch_overhead_cycles + spec.mem_latency_cycles as f64 + loads[0];
    let mut stall = 0.0;
    let mut hidden = true;
    for (k, &(_, count)) in runs.iter().enumerate() {
        // within a run, round r's load overlaps the identical round
        // r-1's compute: (count - 1) steady-state transitions
        if count > 1 {
            total += (count - 1) as f64 * loads[k].max(computes[k]);
            if loads[k] > computes[k] {
                stall += (count - 1) as f64 * (loads[k] - computes[k]);
                hidden = false;
            }
        }
        // transition into the next run: its first load overlaps this
        // run's last compute
        if k + 1 < runs.len() {
            total += loads[k + 1].max(computes[k]);
            if loads[k + 1] > computes[k] {
                stall += loads[k + 1] - computes[k];
                hidden = false;
            }
        }
    }
    total += computes[runs.len() - 1];

    let weights = |xs: &[f64]| -> f64 {
        xs.iter().zip(runs).map(|(x, &(_, n))| x * n as f64).sum()
    };
    PipelineResult {
        total_cycles: total,
        load_cycles_sum: weights(&loads),
        compute_cycles_sum: weights(&computes),
        stall_cycles: stall,
        latency_hidden: hidden,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::spec::gtx_1080ti;

    fn cfg() -> (GpuSpec, ExecConfig) {
        let g = gtx_1080ti();
        let c = ExecConfig::new(&g, 1024);
        (g, c)
    }

    fn round(bytes: f64, fma: f64) -> Round {
        Round::new(bytes, 128, fma)
    }

    #[test]
    fn single_round_is_load_plus_compute() {
        let (g, c) = cfg();
        let r = round(1e5, 1e6);
        let res = simulate_pipeline(&g, &c, &[r]);
        let expect = c.launch_overhead_cycles
            + g.mem_latency_cycles as f64 // cold-fetch prologue
            + load_cycles(&g, &c, &r)
            + compute_cycles(&g, &c, 1e6);
        assert!((res.total_cycles - expect).abs() < 1e-6);
    }

    #[test]
    fn pipeline_bounds() {
        // max(sum loads, sum computes) <= total <= sum loads + sum computes (+overhead)
        let (g, c) = cfg();
        let rounds: Vec<Round> = (1..=10).map(|i| round(1e4 * i as f64, 5e5)).collect();
        let res = simulate_pipeline(&g, &c, &rounds);
        let lo = res.load_cycles_sum.max(res.compute_cycles_sum);
        let hi = res.load_cycles_sum
            + res.compute_cycles_sum
            + c.launch_overhead_cycles
            + g.mem_latency_cycles as f64;
        assert!(res.total_cycles >= lo * 0.99);
        assert!(res.total_cycles <= hi + 1.0);
    }

    #[test]
    fn compute_bound_rounds_hide_latency() {
        // Th >= N_FMA with matching load volume: fetches hide behind compute.
        let (g, c) = cfg();
        let n_fma = g.n_fma() as f64;
        // compute per round: n_fma ops ~ 258 cycles at 0.9 eff -> ~287 cycles;
        // load per round small enough to fit under it
        let small_load = 100.0 * 4.0; // 400 B: latency-dominated, ~259 cycles
        let rounds: Vec<Round> = (0..20).map(|_| round(small_load, 1.2 * n_fma)).collect();
        let res = simulate_pipeline(&g, &c, &rounds);
        assert!(res.latency_hidden, "stall={}", res.stall_cycles);
        assert_eq!(res.bottleneck(), "compute");
    }

    #[test]
    fn starved_rounds_expose_latency() {
        // Th << N_FMA: every round stalls on memory.
        let (g, c) = cfg();
        let rounds: Vec<Round> = (0..20).map(|_| round(1e5, 1e3)).collect();
        let res = simulate_pipeline(&g, &c, &rounds);
        assert!(!res.latency_hidden);
        assert_eq!(res.bottleneck(), "memory");
        assert!(res.stall_cycles > 0.0);
    }

    #[test]
    fn n_fma_is_the_hiding_threshold() {
        // The paper's claim, §2.2: a round of N_FMA ops takes exactly the
        // memory latency to execute at peak; rounds with Th >= N_FMA can
        // hide a latency-dominated fetch, rounds below cannot.
        let g = gtx_1080ti();
        let mut c = ExecConfig::new(&g, 1024);
        c.compute_efficiency = 1.0; // the paper's idealized cores
        let tiny_fetch = round(4.0, 0.0).load_bytes; // latency-dominated
        let hide = simulate_pipeline(
            &g,
            &c,
            &[round(tiny_fetch, g.n_fma() as f64), round(tiny_fetch, g.n_fma() as f64)],
        );
        assert!(hide.stall_cycles < 2.0, "stall={}", hide.stall_cycles);
        let starve = simulate_pipeline(
            &g,
            &c,
            &[round(tiny_fetch, 0.5 * g.n_fma() as f64), round(tiny_fetch, 0.5 * g.n_fma() as f64)],
        );
        assert!(starve.stall_cycles > 100.0, "stall={}", starve.stall_cycles);
    }

    #[test]
    fn runs_form_equals_expanded_form() {
        let (g, c) = cfg();
        // mixed schedule: cold round + two distinct steady-state runs
        let r0 = Round::with_efficiency(5e4, 128, 0.8, 2e5);
        let ra = round(1e4, 8e5);
        let rb = round(3e4, 2e5);
        let mut expanded = vec![r0];
        expanded.extend(std::iter::repeat(ra).take(7));
        expanded.extend(std::iter::repeat(rb).take(5));
        let a = simulate_pipeline(&g, &c, &expanded);
        let b = simulate_pipeline_runs(&g, &c, &[(r0, 1), (ra, 7), (rb, 5)]);
        assert!((a.total_cycles - b.total_cycles).abs() < 1e-9 * a.total_cycles);
        assert!((a.stall_cycles - b.stall_cycles).abs() < 1e-9 * (1.0 + a.stall_cycles));
        assert!((a.load_cycles_sum - b.load_cycles_sum).abs() < 1e-9 * a.load_cycles_sum);
        assert!(
            (a.compute_cycles_sum - b.compute_cycles_sum).abs() < 1e-9 * a.compute_cycles_sum
        );
        assert_eq!(a.latency_hidden, b.latency_hidden);
    }

    #[test]
    fn monotone_in_fma_ops() {
        let (g, c) = cfg();
        let mut last = 0.0;
        for scale in [1.0, 2.0, 4.0, 8.0] {
            let rounds: Vec<Round> = (0..5).map(|_| round(1e4, scale * 1e6)).collect();
            let t = simulate_pipeline(&g, &c, &rounds).total_cycles;
            assert!(t > last);
            last = t;
        }
    }

    #[test]
    fn coalesced_beats_non_coalesced() {
        let (g, c) = cfg();
        let mk = |seg: usize| -> f64 {
            let rounds: Vec<Round> =
                (0..8).map(|_| Round::new(1e6, seg, 1e4)).collect();
            simulate_pipeline(&g, &c, &rounds).total_cycles
        };
        assert!(mk(128) < mk(32));
        assert!(mk(32) < mk(36)); // aligned-32 beats the odd 36-B filters of [1]
        assert!(mk(36) < mk(4));
    }

    #[test]
    fn deeper_cyclic_amortizes_exposure_but_tilewise_does_not() {
        let (g, mut c) = cfg();
        // small round: latency-exposed, so depth matters for cyclic
        let r = round(2e3, 1e3);
        let mut last = f64::INFINITY;
        for s in MIN_STAGES..=MAX_STAGES {
            c.stages = s;
            c.loading = Loading::Cyclic;
            let t = load_cycles(&g, &c, &r);
            assert!(t <= last + 1e-12, "stages={s}: {t} > {last}");
            last = t;
        }
        // tilewise serializes per warp: stages buy nothing
        c.loading = Loading::Tilewise;
        c.stages = 2;
        let t2 = load_cycles(&g, &c, &r);
        c.stages = 4;
        assert_eq!(load_cycles(&g, &c, &r), t2);
    }

    #[test]
    fn ordered_pays_sync_but_merges_segments() {
        let (g, mut c) = cfg();
        // 32-B segments: the merge profile lifts efficiency toward 128-B
        let r = Round::new(1e6, 32, 1e4);
        c.loading = Loading::Cyclic;
        let cyc = load_cycles(&g, &c, &r);
        c.loading = Loading::Ordered;
        let ord = load_cycles(&g, &c, &r);
        assert!(ord < cyc, "merge gain should beat the sync cost here");
        // on an already-128-B stream the merge buys nothing: sync only
        let r128 = Round::new(1e6, 128, 1e4);
        c.loading = Loading::Cyclic;
        let cyc128 = load_cycles(&g, &c, &r128);
        c.loading = Loading::Ordered;
        assert!((load_cycles(&g, &c, &r128) - cyc128 - ORDERED_SYNC_CYCLES).abs() < 1e-9);
    }

    #[test]
    fn filter_tagged_round_strips_to_the_map_stream() {
        let (g, c) = cfg();
        let r = Round::mixed_with_filter((1000.0, 36), &[(2000.0, 128)], 1e4);
        assert_eq!(r.filter_bytes, 1000.0);
        assert_eq!(r.load_bytes, 3000.0);
        let warm = r.without_filter_loads();
        assert_eq!(warm.filter_bytes, 0.0);
        assert_eq!(warm.load_bytes, 2000.0);
        // the filter share leaves the DRAM bus, so the blended
        // efficiency recovers toward the pure 128-B map stream's
        let eff = warm.eff_override.unwrap();
        assert!(eff > r.eff_override.unwrap(), "stripping the 36-B filters must help");
        assert!(eff <= 1.0 + 1e-12);
        // the issue pattern is unchanged (filter loads still issue and
        // hit the resident copy): segment kept, in-flight volume pinned
        // at the cold round's
        assert_eq!(warm.segment_bytes, r.segment_bytes);
        assert_eq!(warm.inflight_bytes, r.load_bytes);
        // same FMAs, cheaper load
        assert_eq!(warm.fma_ops, r.fma_ops);
        assert!(load_cycles(&g, &c, &warm) < load_cycles(&g, &c, &r));
        // untagged rounds are untouched; pure-filter rounds vanish
        let plain = round(1e4, 1e5);
        assert_eq!(plain.without_filter_loads(), plain);
        let pure = Round::new(500.0, 128, 1e4).tagged_filter(500.0, 128);
        let stripped = pure.without_filter_loads();
        assert_eq!(stripped.load_bytes, 0.0);
        assert_eq!(load_cycles(&g, &c, &stripped), 0.0);
    }

    #[test]
    fn mixed_round_derives_the_combined_segment() {
        // satellite fix: the effective segment is the bus-weighted
        // harmonic mean of the constituent streams, not a hardcoded 128
        let r = Round::mixed(&[(1000.0, 36), (1000.0, 128)], 1e4);
        assert_eq!(r.load_bytes, 2000.0);
        assert!(r.segment_bytes > 36 && r.segment_bytes < 128, "{}", r.segment_bytes);
        let expect = (2000.0 / (1000.0 / 36.0 + 1000.0 / 128.0)).round() as usize;
        assert_eq!(r.segment_bytes, expect);
        let eff = r.eff_override.unwrap();
        assert!(eff > 0.0 && eff <= 1.0);
    }
}
