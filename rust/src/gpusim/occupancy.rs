//! Theoretical occupancy calculator — the CUDA occupancy arithmetic the
//! paper's §4 launch geometry (2 blocks x 512 threads, 128 regs/thread)
//! implicitly performs.  Given a block's resource footprint it reports
//! how many blocks fit per SM and which resource limits residency;
//! plans use it to sanity-check their threads_per_sm assumptions.

use super::spec::GpuSpec;

/// Per-block resource footprint of a kernel.
#[derive(Clone, Copy, Debug)]
pub struct BlockResources {
    pub threads: u32,
    pub registers_per_thread: u32,
    pub shared_mem_bytes: u32,
}

/// What capped the residency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Limiter {
    Threads,
    Registers,
    SharedMemory,
    BlockSlots,
}

/// Result of the occupancy computation.
#[derive(Clone, Copy, Debug)]
pub struct Occupancy {
    pub blocks_per_sm: u32,
    pub threads_per_sm: u32,
    /// resident warps / max warps
    pub fraction: f64,
    pub limiter: Limiter,
}

/// Hardware block-slot limit per SM (32 on Kepler..Pascal).
pub const MAX_BLOCKS_PER_SM: u32 = 32;

/// Compute theoretical occupancy of a block shape on a GPU.
pub fn occupancy(spec: &GpuSpec, b: &BlockResources) -> Occupancy {
    assert!(b.threads > 0, "empty block");
    let by_threads = spec.max_threads_per_sm / b.threads;
    let regs_per_block = b.registers_per_thread.max(1) * b.threads;
    let by_regs = spec.registers_per_sm / regs_per_block;
    let by_smem = if b.shared_mem_bytes == 0 {
        u32::MAX
    } else {
        spec.shared_mem_bytes / b.shared_mem_bytes
    };
    let candidates = [
        (by_threads, Limiter::Threads),
        (by_regs, Limiter::Registers),
        (by_smem, Limiter::SharedMemory),
        (MAX_BLOCKS_PER_SM, Limiter::BlockSlots),
    ];
    let (blocks, limiter) =
        candidates.iter().min_by_key(|(n, _)| *n).copied().unwrap_or((0, Limiter::Threads));
    let threads = blocks * b.threads;
    let max_warps = spec.max_threads_per_sm / spec.warp_size;
    Occupancy {
        blocks_per_sm: blocks,
        threads_per_sm: threads,
        fraction: (threads / spec.warp_size) as f64 / max_warps as f64,
        limiter,
    }
}

/// Can the paper's launch geometry (2 blocks x 512 threads) reside with
/// a given register/shared-memory budget?
pub fn paper_geometry_fits(spec: &GpuSpec, regs_per_thread: u32, smem_per_block: u32) -> bool {
    let occ = occupancy(
        spec,
        &BlockResources {
            threads: 512,
            registers_per_thread: regs_per_thread,
            shared_mem_bytes: smem_per_block,
        },
    );
    occ.blocks_per_sm >= 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::spec::{gtx_1080ti, tesla_k40};

    #[test]
    fn paper_launch_two_blocks_fit_with_64_regs() {
        // 2 x 512 threads at 64 regs/thread: 65,536 regs exactly — the
        // physical ceiling behind the paper's geometry
        let g = gtx_1080ti();
        assert!(paper_geometry_fits(&g, 64, 32 * 1024));
        // at the paper's quoted 128 regs/thread only ONE block fits —
        // the register file is the true limiter of their own claim
        assert!(!paper_geometry_fits(&g, 128, 32 * 1024));
    }

    #[test]
    fn register_limiter_detected() {
        let g = gtx_1080ti();
        let occ = occupancy(
            &g,
            &BlockResources { threads: 512, registers_per_thread: 128, shared_mem_bytes: 1024 },
        );
        assert_eq!(occ.blocks_per_sm, 1);
        assert_eq!(occ.limiter, Limiter::Registers);
    }

    #[test]
    fn shared_memory_limiter_detected() {
        let g = gtx_1080ti();
        let occ = occupancy(
            &g,
            &BlockResources {
                threads: 128,
                registers_per_thread: 32,
                shared_mem_bytes: 48 * 1024, // half of S_shared each
            },
        );
        assert_eq!(occ.blocks_per_sm, 2);
        assert_eq!(occ.limiter, Limiter::SharedMemory);
    }

    #[test]
    fn thread_limiter_detected() {
        let g = gtx_1080ti();
        let occ = occupancy(
            &g,
            &BlockResources { threads: 1024, registers_per_thread: 16, shared_mem_bytes: 1024 },
        );
        assert_eq!(occ.blocks_per_sm, 2); // 2048 / 1024
        assert_eq!(occ.limiter, Limiter::Threads);
        assert!((occ.fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn block_slot_limiter_for_tiny_blocks() {
        let g = gtx_1080ti();
        let occ = occupancy(
            &g,
            &BlockResources { threads: 32, registers_per_thread: 8, shared_mem_bytes: 0 },
        );
        assert_eq!(occ.blocks_per_sm, MAX_BLOCKS_PER_SM);
        assert_eq!(occ.limiter, Limiter::BlockSlots);
        // 32 blocks x 1 warp each = half the 64-warp ceiling
        assert!((occ.fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stride_fixed_working_set_keeps_two_blocks_resident() {
        // §3.2(4)'s "<= S_shared/2" exists precisely so two blocks
        // double-buffer per SM — verify through the occupancy calculator
        let g = gtx_1080ti();
        let occ = occupancy(
            &g,
            &BlockResources {
                threads: 512,
                registers_per_thread: 64,
                shared_mem_bytes: g.shared_mem_bytes / 2,
            },
        );
        assert!(occ.blocks_per_sm >= 2, "{occ:?}");
    }

    #[test]
    fn kepler_tighter_than_pascal() {
        // K40's 48 KB shared memory halves smem-bound residency
        let (g, k) = (gtx_1080ti(), tesla_k40());
        let b = BlockResources { threads: 256, registers_per_thread: 32, shared_mem_bytes: 24 * 1024 };
        assert!(occupancy(&g, &b).blocks_per_sm > occupancy(&k, &b).blocks_per_sm);
    }
}
