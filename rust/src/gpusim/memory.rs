//! Global-memory access model: coalescing efficiency and transfer timing.
//!
//! §2.2 of the paper: "for accessing global memory, it is necessary to
//! confirm that the starting address and the size of the sequential
//! accessing segment is a multiple of 32-byte.  In Pascal GPU, a multiple
//! of 128-byte shows better performance than that of 32-byte and 64-byte,
//! but the performance for 32-byte and 64-byte is acceptable."
//!
//! The model has two parts:
//!  * **useful fraction** — DRAM moves whole 32-B sectors; a segment that
//!    is not a multiple of 32 B drags dead bytes (the paper's
//!    "non-coalescing memory access", e.g. the K*K*4 = 36-B filters of
//!    [1], or 4-B accesses when K = 1);
//!  * **segment-length factor** — short (but aligned) segments issue more
//!    transactions per byte and reach slightly lower bus utilization:
//!    1.0 at >=128 B, 0.95 at 64 B, 0.90 at 32 B (the paper's "a bit
//!    worse ... but acceptable").

use super::spec::GpuSpec;

/// DRAM sector granularity on Pascal/Maxwell.
pub const SECTOR_BYTES: usize = 32;

/// Fraction of fetched bytes that are useful for a contiguous segment of
/// `segment_bytes` starting sector-aligned.
pub fn useful_fraction(segment_bytes: usize) -> f64 {
    assert!(segment_bytes > 0, "zero-length segment");
    let sectors = (segment_bytes + SECTOR_BYTES - 1) / SECTOR_BYTES;
    segment_bytes as f64 / (sectors * SECTOR_BYTES) as f64
}

/// Bus-utilization factor for aligned segments of a given length.
pub fn length_factor(segment_bytes: usize) -> f64 {
    if segment_bytes >= 128 {
        1.0
    } else if segment_bytes >= 64 {
        0.95
    } else if segment_bytes >= 32 {
        0.90
    } else {
        // sub-sector requests: each still occupies a full transaction slot
        0.90 * segment_bytes as f64 / SECTOR_BYTES as f64
    }
}

/// Combined efficiency in (0, 1]: the fraction of peak DRAM bandwidth a
/// stream of `segment_bytes`-sized contiguous segments achieves.
pub fn segment_efficiency(segment_bytes: usize) -> f64 {
    (useful_fraction(segment_bytes) * length_factor(segment_bytes)).min(1.0)
}

/// How the SMs' concurrent loads share the bus, and how much of the
/// latency each round still exposes.
#[derive(Clone, Copy, Debug)]
pub struct AccessConfig {
    /// contiguous segment size of the access stream, bytes
    pub segment_bytes: usize,
    /// SMs loading concurrently (they share DRAM bandwidth)
    pub sms_active: u32,
    /// resident threads per SM issuing loads — fewer threads than the
    /// spec's requirement cannot keep enough transactions in flight
    /// (Table 1's "Thread Requirement/SM")
    pub threads_per_sm: u32,
}

/// Cycles for one SM to receive `bytes` from global memory under `cfg`.
///
/// latency term: one exposed latency per round (the steady-state pipe
/// refill); throughput term: bytes over this SM's share of effective
/// bandwidth, inflated if the SM has too few threads in flight.
pub fn transfer_cycles(spec: &GpuSpec, cfg: &AccessConfig, bytes: f64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    let eff = segment_efficiency(cfg.segment_bytes);
    let per_sm_bw = spec.bytes_per_cycle() * eff / cfg.sms_active.max(1) as f64;
    let occupancy =
        (cfg.threads_per_sm as f64 / spec.threads_required_per_sm() as f64).min(1.0);
    spec.mem_latency_cycles as f64 + bytes / (per_sm_bw * occupancy.max(1e-9))
}

/// Fraction of the memory latency a prefetch round still exposes.
///
/// Table 1's requirement rows: an SM needs ~768 threads each with a 4-B
/// load in flight (3,072 B per round) before successive fetches pipeline
/// and the 258-cycle latency amortizes away.  A round smaller than the
/// per-SM data requirement, or an SM with fewer resident threads, cannot
/// fill the pipe and pays the remainder of the latency per round.
pub fn latency_exposure(spec: &GpuSpec, threads_per_sm: u32, round_bytes: f64) -> f64 {
    let thread_fill = (threads_per_sm as f64 / spec.threads_required_per_sm() as f64).min(1.0);
    let volume_fill = (round_bytes / spec.data_requirement_per_sm() as f64).min(1.0);
    (1.0 - thread_fill * volume_fill).max(0.0)
}

/// Cycles for the *chip* to stream `bytes` split evenly over all SMs —
/// used for the V_s "keep the bus busy" strategy (§2.2 method 2).
pub fn stream_cycles_chip(spec: &GpuSpec, segment_bytes: usize, total_bytes: f64) -> f64 {
    let eff = segment_efficiency(segment_bytes);
    spec.mem_latency_cycles as f64 + total_bytes / (spec.bytes_per_cycle() * eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::spec::gtx_1080ti;

    #[test]
    fn useful_fraction_aligned_sizes() {
        assert_eq!(useful_fraction(32), 1.0);
        assert_eq!(useful_fraction(64), 1.0);
        assert_eq!(useful_fraction(128), 1.0);
    }

    #[test]
    fn useful_fraction_odd_filter_segments() {
        // K=3 filters: 36 B -> 2 sectors fetched for 36 useful bytes
        assert!((useful_fraction(36) - 36.0 / 64.0).abs() < 1e-12);
        // K=1 filters: 4 B -> 1/8 useful — the paper's "serious
        // performance reduction" case
        assert!((useful_fraction(4) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn paper_segment_preference_ordering() {
        // §2.2/§3.2: 128 > 64 > 32 >> non-multiples
        assert!(segment_efficiency(128) > segment_efficiency(64));
        assert!(segment_efficiency(64) > segment_efficiency(32));
        assert!(segment_efficiency(32) > segment_efficiency(36));
        assert!(segment_efficiency(36) > segment_efficiency(4));
        // but 32/64 stay "acceptable": within 10% of peak
        assert!(segment_efficiency(32) >= 0.9);
    }

    #[test]
    fn efficiency_bounded() {
        for s in [1, 4, 13, 32, 36, 64, 100, 128, 129, 4096] {
            let e = segment_efficiency(s);
            assert!(e > 0.0 && e <= 1.0, "s={s} e={e}");
        }
    }

    #[test]
    fn transfer_latency_floor() {
        // tiny transfers still pay the full memory latency
        let g = gtx_1080ti();
        let cfg = AccessConfig { segment_bytes: 128, sms_active: 1, threads_per_sm: 1024 };
        let c = transfer_cycles(&g, &cfg, 4.0);
        assert!(c >= g.mem_latency_cycles as f64);
        assert!(c < g.mem_latency_cycles as f64 + 1.0);
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let g = gtx_1080ti();
        let cfg = AccessConfig { segment_bytes: 128, sms_active: 28, threads_per_sm: 768 };
        let mut last = 0.0;
        for kb in [1, 2, 4, 8, 64, 1024] {
            let c = transfer_cycles(&g, &cfg, (kb * 1024) as f64);
            assert!(c > last);
            last = c;
        }
    }

    #[test]
    fn more_sms_sharing_is_slower_per_sm() {
        let g = gtx_1080ti();
        let a = AccessConfig { segment_bytes: 128, sms_active: 1, threads_per_sm: 768 };
        let b = AccessConfig { segment_bytes: 128, sms_active: 28, threads_per_sm: 768 };
        assert!(transfer_cycles(&g, &a, 1e6) < transfer_cycles(&g, &b, 1e6));
    }

    #[test]
    fn under_threaded_sm_cannot_reach_bandwidth() {
        // Table 1: 768 threads/SM are needed to keep the bus busy — an SM
        // with 96 threads gets ~1/8 of its share.
        let g = gtx_1080ti();
        let full = AccessConfig { segment_bytes: 128, sms_active: 28, threads_per_sm: 768 };
        let starved = AccessConfig { segment_bytes: 128, sms_active: 28, threads_per_sm: 96 };
        let ratio = transfer_cycles(&g, &starved, 1e7) / transfer_cycles(&g, &full, 1e7);
        assert!(ratio > 6.0 && ratio < 9.0, "ratio={ratio}");
    }

    #[test]
    fn chip_stream_rate_matches_table1() {
        // streaming V_s bytes at 128-B segments takes ~latency + V_s/327
        let g = gtx_1080ti();
        let c = stream_cycles_chip(&g, 128, g.v_s() as f64);
        let expect = 258.0 + 86_016.0 / g.bytes_per_cycle();
        assert!((c - expect).abs() < 1.0, "c={c} expect={expect}");
    }
}
