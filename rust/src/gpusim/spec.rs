//! GPU hardware specifications — Table 1 of the paper, plus derived
//! quantities (N_FMA, V_s, thread/warp requirements).
//!
//! The paper's whole argument is parameterized by these numbers; the
//! simulator and the analytic model both read them from here, and the
//! Table-1 unit tests pin every derived value to the paper's.

/// Static hardware parameters of one GPU (Table 1 rows).
#[derive(Clone, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub architecture: &'static str,
    /// global memory latency for single-precision loads, clock cycles
    /// (measured by the microbenchmarks of Mei & Chu [5])
    pub mem_latency_cycles: u32,
    /// peak DRAM bandwidth, GB/s
    pub bandwidth_gb_s: f64,
    /// base clock, MHz
    pub clock_mhz: f64,
    /// number of streaming multiprocessors
    pub sm_count: u32,
    /// CUDA cores per SM
    pub cores_per_sm: u32,
    /// FMA operations per core per clock ("Flops/clock cycle/core" = 2)
    pub fma_per_core_cycle: u32,
    /// shared memory per SM, bytes (S_shared)
    pub shared_mem_bytes: u32,
    /// 32-bit registers per SM
    pub registers_per_sm: u32,
    /// max resident threads per SM
    pub max_threads_per_sm: u32,
    pub warp_size: u32,
    /// device (DRAM) memory, bytes — the fleet pool's default hard cap
    pub dram_bytes: u64,
    /// L2 cache, bytes — the capacity tier cross-image filter residency
    /// falls back to when the working set outgrows shared memory
    pub l2_bytes: u64,
}

/// L2 lines the streaming traffic (map strips in, writeback lines out)
/// occupies while a resident filter set is held: the residency budget is
/// the cache minus this reserve.
pub const L2_STREAM_RESERVE_BYTES: u64 = 256 * 1024;

/// GeForce GTX 1080Ti — the paper's primary testbed (Table 1).
pub fn gtx_1080ti() -> GpuSpec {
    GpuSpec {
        name: "GTX 1080Ti",
        architecture: "Pascal",
        mem_latency_cycles: 258,
        bandwidth_gb_s: 484.0,
        clock_mhz: 1480.0,
        sm_count: 28,
        cores_per_sm: 128,
        fma_per_core_cycle: 2,
        shared_mem_bytes: 96 * 1024,
        registers_per_sm: 64 * 1024,
        max_threads_per_sm: 2048,
        warp_size: 32,
        dram_bytes: 11 * 1024 * 1024 * 1024,
        l2_bytes: 2816 * 1024,
    }
}

/// GTX Titan X (Maxwell) — the paper's §4 portability check.
/// Latency from the Mei & Chu [5] Maxwell measurements.
pub fn titan_x_maxwell() -> GpuSpec {
    GpuSpec {
        name: "GTX Titan X",
        architecture: "Maxwell",
        mem_latency_cycles: 368,
        bandwidth_gb_s: 336.5,
        clock_mhz: 1000.0,
        sm_count: 24,
        cores_per_sm: 128,
        fma_per_core_cycle: 2,
        shared_mem_bytes: 96 * 1024,
        registers_per_sm: 64 * 1024,
        max_threads_per_sm: 2048,
        warp_size: 32,
        dram_bytes: 12 * 1024 * 1024 * 1024,
        l2_bytes: 3 * 1024 * 1024,
    }
}

/// Tesla K40 (Kepler) — the GPU class used by [1] (DAC'17); needed for
/// the paper's "our GPU's peak is 2.4x theirs" normalization in §4.
pub fn tesla_k40() -> GpuSpec {
    GpuSpec {
        name: "Tesla K40",
        architecture: "Kepler",
        mem_latency_cycles: 230,
        bandwidth_gb_s: 288.0,
        clock_mhz: 745.0,
        sm_count: 15,
        cores_per_sm: 192,
        fma_per_core_cycle: 2,
        shared_mem_bytes: 48 * 1024,
        registers_per_sm: 64 * 1024,
        max_threads_per_sm: 2048,
        warp_size: 32,
        dram_bytes: 12 * 1024 * 1024 * 1024,
        l2_bytes: 1536 * 1024,
    }
}

impl GpuSpec {
    pub fn clock_hz(&self) -> f64 {
        self.clock_mhz * 1e6
    }

    /// DRAM transmission rate in bytes per clock cycle (exact).
    pub fn bytes_per_cycle(&self) -> f64 {
        self.bandwidth_gb_s * 1e9 / self.clock_hz()
    }

    /// Table 1's "Transmission Rate (Byte/clock cycle)" — the paper
    /// truncates (484e9 / 1.48e9 = 327.02... -> 327).
    pub fn bytes_per_cycle_int(&self) -> u64 {
        self.bytes_per_cycle() as u64
    }

    /// FMA operations per SM per clock: cores x 2 (= 256 on both testbeds).
    pub fn fma_per_sm_cycle(&self) -> u64 {
        (self.cores_per_sm * self.fma_per_core_cycle) as u64
    }

    /// Peak FMA throughput of the whole chip, ops/s.
    pub fn peak_fma_per_s(&self) -> f64 {
        self.fma_per_sm_cycle() as f64 * self.sm_count as f64 * self.clock_hz()
    }

    /// Peak single-precision FLOP/s under the paper's own convention
    /// (2 FMA/core/cycle — Table 1's "Flops/clock cycle/core = 2", the
    /// reading the paper's N_FMA = 66,048 derivation uses; it doubles the
    /// datasheet number uniformly, so all ratios are unaffected).
    pub fn peak_flops(&self) -> f64 {
        2.0 * self.peak_fma_per_s()
    }

    /// N_FMA — §2.2: FMA ops per SM needed to cover the memory latency
    /// with compute: latency x cores x 2 (= 66,048 on the 1080Ti).
    pub fn n_fma(&self) -> u64 {
        self.mem_latency_cycles as u64 * self.fma_per_sm_cycle()
    }

    /// Table 1's "Data Requirement (bytes)": the volume that must be in
    /// flight to cover the latency, = transmission-rate x latency
    /// (327 x 258 = 84,366 on the 1080Ti).
    pub fn data_requirement_bytes(&self) -> u64 {
        self.bytes_per_cycle_int() * self.mem_latency_cycles as u64
    }

    /// Threads needed chip-wide to issue that volume at 4 B per thread.
    pub fn threads_required_total(&self) -> u64 {
        (self.data_requirement_bytes() + 3) / 4
    }

    /// Table 1's "Thread Requirement/SM": per-SM share rounded up to a
    /// whole number of warps (768 = 24 warps on the 1080Ti).
    pub fn threads_required_per_sm(&self) -> u64 {
        let per_sm = (self.threads_required_total() + self.sm_count as u64 - 1) / self.sm_count as u64;
        let w = self.warp_size as u64;
        (per_sm + w - 1) / w * w
    }

    /// Table 1's "Warp Requirement/SM" (24 on the 1080Ti).
    pub fn warps_required_per_sm(&self) -> u64 {
        self.threads_required_per_sm() / self.warp_size as u64
    }

    /// Table 1's "Data Requirement/SM (bytes)" (3,072 on the 1080Ti).
    pub fn data_requirement_per_sm(&self) -> u64 {
        self.threads_required_per_sm() * 4
    }

    /// V_s — §2.2: the minimum volume for the "large continuous transfer"
    /// strategy: per-SM thread requirement x 4 B x SM count
    /// (768 x 4 x 28 = 86,016 on the 1080Ti; >= data_requirement_bytes).
    pub fn v_s(&self) -> u64 {
        self.data_requirement_per_sm() * self.sm_count as u64
    }

    /// Convert cycles to seconds at base clock.
    pub fn cycles_to_secs(&self, cycles: f64) -> f64 {
        cycles / self.clock_hz()
    }

    /// L2 capacity usable for cross-image filter residency: the cache
    /// minus a reserve for the streaming working set passing through.
    pub fn l2_resident_budget(&self) -> u64 {
        self.l2_bytes.saturating_sub(L2_STREAM_RESERVE_BYTES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin every derived value to Table 1 of the paper.
    #[test]
    fn table1_gtx_1080ti() {
        let g = gtx_1080ti();
        assert_eq!(g.mem_latency_cycles, 258);
        assert_eq!(g.sm_count, 28);
        assert_eq!(g.bytes_per_cycle_int(), 327, "Transmission Rate");
        assert_eq!(g.data_requirement_bytes(), 84_366, "Data Requirement");
        assert_eq!(g.threads_required_per_sm(), 768, "Thread Requirement/SM");
        assert_eq!(g.warps_required_per_sm(), 24, "Warp Requirement/SM");
        assert_eq!(g.data_requirement_per_sm(), 3_072, "Data Requirement/SM");
        assert_eq!(g.fma_per_core_cycle, 2, "Flops/clock cycle/core");
    }

    #[test]
    fn n_fma_is_66048() {
        // §2.2: "N_FMA = 66,048 FMA operations (66,048 = 258 x N_cores x 2)"
        assert_eq!(gtx_1080ti().n_fma(), 66_048);
    }

    #[test]
    fn v_s_is_86016() {
        // §2.2: "768 x 4 x 28 = 86,016 > 84,366"
        let g = gtx_1080ti();
        assert_eq!(g.v_s(), 86_016);
        assert!(g.v_s() > g.data_requirement_bytes());
    }

    #[test]
    fn peak_flops_1080ti() {
        // 28 SM x 128 cores x 2 FMA x 2 FLOP x 1.48 GHz ≈ 21.2 TFLOP/s
        let g = gtx_1080ti();
        let tflops = g.peak_flops() / 1e12;
        assert!((tflops - 21.2).abs() < 0.5, "tflops={tflops}");
    }

    #[test]
    fn titan_x_reasonable() {
        let t = titan_x_maxwell();
        // Under the paper's 2-FMA/core convention: 24 SM x 256 FMA x 2 FLOP
        // x 1.0 GHz ≈ 12.3 TFLOP/s (datasheet: 6.1 — uniform 2x, see
        // peak_flops doc).
        let tflops = t.peak_flops() / 1e12;
        assert!((tflops - 12.3).abs() < 0.5, "tflops={tflops}");
    }

    #[test]
    fn k40_peak_ratio_matches_paper_normalization() {
        // §4: "on GPU the peak performance of which is 2.4X faster than
        // that used in [1]" — [1] targeted Kepler (K40-class).
        let ratio = gtx_1080ti().peak_flops() / tesla_k40().peak_flops();
        assert!((ratio - 2.4).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    fn maxwell_n_fma_differs() {
        // Maxwell's longer latency demands more in-flight FMAs per SM.
        assert!(titan_x_maxwell().n_fma() > gtx_1080ti().n_fma());
    }

    #[test]
    fn dram_sizes_match_the_cards() {
        assert_eq!(gtx_1080ti().dram_bytes, 11 << 30);
        assert_eq!(titan_x_maxwell().dram_bytes, 12 << 30);
        assert_eq!(tesla_k40().dram_bytes, 12 << 30);
    }

    #[test]
    fn cycles_to_secs_roundtrip() {
        let g = gtx_1080ti();
        let s = g.cycles_to_secs(1.48e9);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
