//! GPU timing simulator — the hardware substrate (the paper's testbed is
//! a GTX 1080Ti we don't have; see DESIGN.md §3 Substitutions).
//!
//! The model is analytic and cycle-approximate, built from exactly the
//! quantities the paper's own performance argument uses: global-memory
//! latency and bandwidth (Table 1), 32/64/128-B coalescing classes
//! (§2.2), per-SM FMA throughput, shared-memory capacity, and the
//! double-buffered prefetch pipeline (§2.2 method 1 / §3.2(4)).
//!
//! `spec` — hardware parameters + Table-1 derivations (N_FMA, V_s);
//! `memory` — coalescing + transfer timing; `pipeline` — prefetch round
//! pipeline; `sim` — `KernelPlan` -> `SimResult`.

pub mod memory;
pub mod occupancy;
pub mod pipeline;
pub mod sim;
pub mod spec;

pub use occupancy::{occupancy, BlockResources, Limiter, Occupancy};
pub use pipeline::{ExecConfig, Loading, Round, MAX_STAGES, MIN_STAGES};
pub use sim::{
    simulate, simulate_detailed, speedup, writeback_tail_cycles, Epilogue, KernelPlan,
    SimBreakdown, SimResult,
};
pub use spec::{gtx_1080ti, tesla_k40, titan_x_maxwell, GpuSpec};
