//! Kernel-level simulation: a `KernelPlan` (the per-SM round schedule a
//! convolution algorithm produces) runs through the prefetch pipeline and
//! yields a `SimResult` with time, throughput and efficiency numbers —
//! the quantities Figs. 4/5 plot.

use super::pipeline::{
    simulate_pipeline, ExecConfig, Loading, PipelineResult, Round, MAX_STAGES, MIN_STAGES,
};
use super::spec::GpuSpec;

/// Share of output writeback that cannot overlap compute (the tail) at
/// the baseline pipeline depth of 2.  Shared with the tuner's scorer,
/// which must charge exactly what `simulate` charges.
pub const WRITEBACK_TAIL_FRACTION: f64 = 0.15;

/// Un-overlapped final store burst: the ping-pong staging is symmetric
/// (outputs flush through the same `s` smem buffers), so the tail is
/// the last stage's share — 15% of the output at the baseline depth 2,
/// scaled by 2/s at deeper pipelines.
pub fn writeback_tail_cycles(spec: &GpuSpec, output_bytes: f64, stages: u32) -> f64 {
    let frac = WRITEBACK_TAIL_FRACTION * 2.0 / stages as f64;
    frac * output_bytes / spec.bytes_per_cycle()
}

/// What the kernel does to its output tile *inside the writeback tail*,
/// instead of a separate glue stream re-reading the tensor from DRAM.
/// `None` is the unfused plan; the other arms reprice the tail:
/// `Relu` clamps registers in flight (no traffic change), `AddResidual`
/// streams the residual operand through the tail (priced as
/// `epilogue_read_bytes`), and `MaxPoolWriteback` folds each k×k window
/// before storing, so only the decimated output reaches DRAM.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Epilogue {
    None,
    Relu,
    AddResidual,
    MaxPoolWriteback { k: usize, stride: usize },
}

impl Epilogue {
    pub fn is_none(&self) -> bool {
        matches!(self, Epilogue::None)
    }

    /// Stable serialization tag (PlanCache v5 `epilogue=` field).
    pub fn tag(&self) -> String {
        match self {
            Epilogue::None => "none".to_string(),
            Epilogue::Relu => "relu".to_string(),
            Epilogue::AddResidual => "add".to_string(),
            Epilogue::MaxPoolWriteback { k, stride } => format!("pool{k}s{stride}"),
        }
    }

    /// Inverse of `tag` — `None` on anything unrecognised.
    pub fn parse(s: &str) -> Option<Epilogue> {
        match s {
            "none" => return Some(Epilogue::None),
            "relu" => return Some(Epilogue::Relu),
            "add" => return Some(Epilogue::AddResidual),
            _ => {}
        }
        let rest = s.strip_prefix("pool")?;
        let (k, stride) = rest.split_once('s')?;
        let (k, stride) = (k.parse().ok()?, stride.parse().ok()?);
        if k == 0 || stride == 0 {
            return None;
        }
        Some(Epilogue::MaxPoolWriteback { k, stride })
    }

    /// Pooled output map for a `oy`×`ox` conv output (valid windows).
    pub fn pooled_hw(&self, oy: usize, ox: usize) -> (usize, usize) {
        match self {
            Epilogue::MaxPoolWriteback { k, stride } => {
                assert!(*k >= 1 && *stride >= 1 && oy >= *k && ox >= *k, "pool{k}s{stride} does not fit {oy}x{ox}");
                ((oy - k) / stride + 1, (ox - k) / stride + 1)
            }
            _ => (oy, ox),
        }
    }
}

/// The execution schedule of one kernel on one GPU — what a CUDA kernel's
/// blocks would do, expressed as per-SM prefetch rounds.  Produced by
/// `plans::*` (ours) and `baselines::*` (cuDNN proxy, [1], [16]).
#[derive(Clone, Debug)]
pub struct KernelPlan {
    pub name: String,
    /// per-SM prefetch rounds (all SMs assumed symmetric; asymmetry is
    /// expressed through `sms_active` + the tail in the round list)
    pub rounds: Vec<Round>,
    /// SMs with work (< sm_count models under-utilization, e.g. [1] on
    /// small maps)
    pub sms_active: u32,
    /// resident threads per SM
    pub threads_per_sm: u32,
    /// fraction of peak FMA issue the inner loop sustains
    pub compute_efficiency: f64,
    /// bytes of output this kernel writes back to global memory (chip-wide)
    pub output_bytes: f64,
    /// shared memory per SM the plan requires — must respect S_shared
    pub smem_bytes_per_sm: u32,
    /// total FMA ops the kernel performs (chip-wide), for GFLOPS
    pub total_fma: f64,
    /// launch + API overhead in cycles (bare kernel ~4000; library paths
    /// like cuDNN pay more — see baselines::cudnn_proxy)
    pub launch_overhead_cycles: f64,
    /// software-pipeline depth: number of smem stage buffers (2 = the
    /// paper's ping-pong; up to `MAX_STAGES`)
    pub stages: u32,
    /// how each stage's global->shared transfer is organised
    pub loading: Loading,
    /// smem bytes one extra stage buffer costs (0 if the plan cannot be
    /// deepened); `staged` charges `(stages - 2) * stage_bytes`
    pub stage_bytes: u32,
    /// fused writeback epilogue (`Epilogue::None` = the plain conv)
    pub epilogue: Epilogue,
    /// bytes the epilogue streams IN through the writeback tail (the
    /// residual operand for `AddResidual`; 0 otherwise)
    pub epilogue_read_bytes: f64,
    /// shared-memory bytes per SM needed to pin this plan's filter
    /// working set across images, *on top of* `smem_bytes_per_sm`'s
    /// staging buffers.  0 = the plan cannot express filter residency
    /// (its builder did not tag the filter stream).
    pub filter_resident_smem_bytes: u32,
    /// total filter tensor the op touches per image (chip-wide) — what
    /// must stay in L2 for the cache-resident fallback tier.  0 = the
    /// plan never qualifies for L2 residency.
    pub filter_l2_footprint_bytes: u64,
}

/// Where `batched_resident` can keep the filter working set across
/// images.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidentTier {
    /// one SM's distinct filters pinned in shared memory left after the
    /// staging buffers (strongest tier: no cache pressure)
    Smem,
    /// the op's whole filter tensor fits the L2 residency budget, so
    /// warm images hit cache instead of DRAM
    L2,
}

impl KernelPlan {
    /// Total bytes the plan moves from global memory (chip-wide, loads).
    pub fn dram_load_bytes(&self) -> f64 {
        self.rounds.iter().map(|r| r.load_bytes).sum::<f64>() * self.sms_active as f64
    }

    /// Filter bytes the plan streams from global memory (chip-wide) —
    /// the share `batched_resident` charges once instead of per image.
    pub fn filter_load_bytes(&self) -> f64 {
        self.rounds.iter().map(|r| r.filter_bytes).sum::<f64>() * self.sms_active as f64
    }

    /// Where (if anywhere) this plan can keep its filter working set
    /// resident across batched images: shared memory left after the
    /// staging buffers first, the L2 residency budget as fallback.  The
    /// *capacity* half of the residency qualification; `batched_resident`
    /// also checks the warm rounds actually win under the pipeline model.
    pub fn resident_filter_tier(&self, spec: &GpuSpec) -> Option<ResidentTier> {
        if self.filter_resident_smem_bytes > 0
            && self.smem_bytes_per_sm as u64 + self.filter_resident_smem_bytes as u64
                <= spec.shared_mem_bytes as u64
        {
            return Some(ResidentTier::Smem);
        }
        if self.filter_l2_footprint_bytes > 0
            && self.filter_l2_footprint_bytes <= spec.l2_resident_budget()
        {
            return Some(ResidentTier::L2);
        }
        None
    }

    /// Whether any residency tier fits (see `resident_filter_tier`).
    pub fn filters_can_stay_resident(&self, spec: &GpuSpec) -> bool {
        self.resident_filter_tier(spec).is_some()
    }

    /// Deepen the ping-pong pipeline to `stages` buffers under
    /// `loading`; each stage past the baseline two costs one more
    /// `stage_bytes` of shared memory.  Only valid on an unstaged
    /// (depth-2 cyclic) plan.
    pub fn staged(&self, stages: u32, loading: Loading) -> KernelPlan {
        assert!(
            (MIN_STAGES..=MAX_STAGES).contains(&stages),
            "{}: stages {stages} outside {MIN_STAGES}..={MAX_STAGES}",
            self.name
        );
        assert!(
            self.stages == 2 && self.loading == Loading::Cyclic,
            "{}: already staged",
            self.name
        );
        if stages == 2 && loading == Loading::Cyclic {
            return self.clone();
        }
        KernelPlan {
            name: format!("{} s{stages}/{}", self.name, loading.tag()),
            smem_bytes_per_sm: self.smem_bytes_per_sm + (stages - 2) * self.stage_bytes,
            stages,
            loading,
            ..self.clone()
        }
    }

    /// FMA operations per loaded byte — the paper's figure of merit
    /// ("high ratio of floating point FMA operations per fetched data").
    pub fn fma_per_byte(&self) -> f64 {
        self.total_fma / self.dram_load_bytes().max(1.0)
    }

    /// The decimated-output schedule — how the op layer prices stride
    /// natively: only `keep` of the stride-1 output strip schedule's
    /// FMAs and writeback are charged (the kept rows/columns), while
    /// every load stays (the full map is still fetched — true whenever
    /// K >= stride, and conservative below).  Strictly no slower than
    /// the undecimated plan under `simulate` (per-round compute and the
    /// writeback tail only shrink), which is what makes the paper
    /// backends' native strided route never lose to the naive
    /// compute-everything lowering.
    pub fn decimated(&self, keep: f64) -> KernelPlan {
        assert!(keep > 0.0 && keep <= 1.0, "keep fraction out of (0, 1]");
        if keep == 1.0 {
            return self.clone();
        }
        let rounds = self
            .rounds
            .iter()
            .map(|r| Round { fma_ops: r.fma_ops * keep, ..*r })
            .collect();
        KernelPlan {
            rounds,
            output_bytes: self.output_bytes * keep,
            total_fma: self.total_fma * keep,
            epilogue_read_bytes: self.epilogue_read_bytes * keep,
            ..self.clone()
        }
    }

    /// The grouped schedule — how the op layer prices `groups`
    /// per-group sub-problems natively: side-by-side groups fill idle
    /// SMs (`par` groups in flight, bounded by `max_sms`), remaining
    /// groups run as sequential waves under the SAME launch.  Per-SM
    /// rounds are unchanged (each SM-group streams its own data); the
    /// shared bus contention of the wider `sms_active` is charged by
    /// the pipeline's per-SM bandwidth split.  Work and writeback scale
    /// by the true group count.
    pub fn grouped(&self, groups: usize, max_sms: u32) -> KernelPlan {
        assert!(groups >= 1, "groups must be >= 1");
        if groups == 1 {
            return self.clone();
        }
        let par = ((max_sms / self.sms_active).max(1) as usize).min(groups);
        let waves = (groups + par - 1) / par;
        let mut rounds = Vec::with_capacity(self.rounds.len() * waves);
        for _ in 0..waves {
            rounds.extend_from_slice(&self.rounds);
        }
        KernelPlan {
            name: format!("{} g{groups}", self.name),
            rounds,
            sms_active: self.sms_active * par as u32,
            output_bytes: self.output_bytes * groups as f64,
            total_fma: self.total_fma * groups as f64,
            epilogue_read_bytes: self.epilogue_read_bytes * groups as f64,
            // cross-image residency must pin EVERY wave's filters (an SM
            // cycles through `waves` different filter sets per image)
            filter_resident_smem_bytes: self
                .filter_resident_smem_bytes
                .saturating_mul(waves as u32),
            // the L2 tier must hold every group's filter tensor
            filter_l2_footprint_bytes: self
                .filter_l2_footprint_bytes
                .saturating_mul(groups as u64),
            ..self.clone()
        }
    }

    /// The batch-`n` schedule: the per-image round list repeated `n`
    /// times back to back.  One launch, one cold-fetch prologue — the
    /// pipeline stays warm across images, which is the batching win the
    /// serving path banks on; FMA work, DRAM traffic and output
    /// writeback all scale exactly by `n` (each image re-streams its
    /// inputs — a conservative model that never credits cross-image
    /// filter residency).
    pub fn batched(&self, n: usize) -> KernelPlan {
        assert!(n >= 1, "batch must be >= 1");
        if n == 1 {
            return self.clone();
        }
        let mut rounds = Vec::with_capacity(self.rounds.len() * n);
        for _ in 0..n {
            rounds.extend_from_slice(&self.rounds);
        }
        KernelPlan {
            name: format!("{} xb{n}", self.name),
            rounds,
            output_bytes: self.output_bytes * n as f64,
            total_fma: self.total_fma * n as f64,
            epilogue_read_bytes: self.epilogue_read_bytes * n as f64,
            ..self.clone()
        }
    }

    /// The batch-`n` schedule with cross-image filter residency: when
    /// the filter working set stays resident (smem-pinned, or the whole
    /// filter tensor within the L2 budget), only image 0 streams filters
    /// from DRAM — every warm image's rounds drop the tagged filter
    /// DRAM bytes (`Round::without_filter_loads`, which keeps the cold
    /// round's issue pattern and in-flight volume), so filter traffic
    /// is charged once per wave instead of once per image.
    ///
    /// Never-lose vs `batched(n)` by construction: the transform falls
    /// back to the conservative re-streaming schedule unless (a) a
    /// residency tier fits (`resident_filter_tier`; for the smem tier
    /// the extra bytes are *charged* to `smem_bytes_per_sm`, so
    /// `simulate_detailed`'s overflow assert is the legality proof) and
    /// (b) every warm round's load cycles are <= its cold counterpart's
    /// under the plan's own pipeline config.  Cycles stay monotone in
    /// `n`: each extra image appends the same warm-round block.
    pub fn batched_resident(&self, n: usize, spec: &GpuSpec) -> KernelPlan {
        assert!(n >= 1, "batch must be >= 1");
        if n == 1 {
            return self.clone();
        }
        let Some(tier) = self.resident_filter_tier(spec) else {
            return self.batched(n);
        };
        let smem_extra =
            if tier == ResidentTier::Smem { self.filter_resident_smem_bytes } else { 0 };
        let cfg = ExecConfig {
            sms_active: self.sms_active,
            threads_per_sm: self.threads_per_sm,
            compute_efficiency: self.compute_efficiency,
            launch_overhead_cycles: self.launch_overhead_cycles,
            stages: self.stages,
            loading: self.loading,
        };
        let warm: Vec<Round> = self.rounds.iter().map(|r| r.without_filter_loads()).collect();
        let wins = self.rounds.iter().zip(&warm).all(|(cold, w)| {
            super::pipeline::load_cycles(spec, &cfg, w)
                <= super::pipeline::load_cycles(spec, &cfg, cold) + 1e-9
        });
        if !wins {
            return self.batched(n);
        }
        let mut rounds = Vec::with_capacity(self.rounds.len() * n);
        rounds.extend_from_slice(&self.rounds);
        for _ in 1..n {
            rounds.extend_from_slice(&warm);
        }
        KernelPlan {
            name: format!("{} xb{n}+fr", self.name),
            rounds,
            smem_bytes_per_sm: self.smem_bytes_per_sm + smem_extra,
            output_bytes: self.output_bytes * n as f64,
            total_fma: self.total_fma * n as f64,
            epilogue_read_bytes: self.epilogue_read_bytes * n as f64,
            ..self.clone()
        }
    }

    /// The fused-epilogue schedule — the consuming glue op absorbed into
    /// this plan's writeback tail.  `out_hw` is the plan's output map
    /// (oy, ox); a `MaxPoolWriteback` folds k×k windows before storing,
    /// so stores shrink to the pooled fraction of the map, while an
    /// `AddResidual` streams the residual operand (same bytes as the
    /// output) in through the tail.  In every arm the intermediate
    /// tensor's DRAM round-trip — written by the conv, re-read by a
    /// separate glue kernel — disappears.  Only valid on an unfused
    /// plan; `Epilogue::None` is the identity.
    pub fn fused(&self, ep: Epilogue, out_hw: (usize, usize)) -> KernelPlan {
        assert!(self.epilogue.is_none(), "{}: already fused", self.name);
        match ep {
            Epilogue::None => self.clone(),
            Epilogue::Relu => KernelPlan {
                name: format!("{} +relu", self.name),
                epilogue: ep,
                ..self.clone()
            },
            Epilogue::AddResidual => KernelPlan {
                name: format!("{} +add", self.name),
                epilogue: ep,
                epilogue_read_bytes: self.output_bytes,
                ..self.clone()
            },
            Epilogue::MaxPoolWriteback { k, stride } => {
                let (oy, ox) = out_hw;
                let (py, px) = ep.pooled_hw(oy, ox);
                let frac = (py * px) as f64 / (oy * ox) as f64;
                KernelPlan {
                    name: format!("{} +pool{k}s{stride}", self.name),
                    epilogue: ep,
                    output_bytes: self.output_bytes * frac,
                    ..self.clone()
                }
            }
        }
    }
}

/// Simulation outcome for one kernel on one GPU.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub name: String,
    pub cycles: f64,
    pub seconds: f64,
    /// achieved FLOP/s (2 FLOPs per FMA, paper convention)
    pub gflops: f64,
    /// achieved fraction of peak FLOP/s
    pub efficiency: f64,
    /// fraction of SMs with work
    pub sm_utilization: f64,
    pub latency_hidden: bool,
    pub bottleneck: &'static str,
    pub stall_fraction: f64,
    pub dram_load_bytes: f64,
    pub fma_per_byte: f64,
}

/// `SimResult` plus the cycle decomposition the observability layer
/// (`trace::Roofline`) reports: where the critical path went, split into
/// load / compute / stall / writeback / launch shares.  `simulate` is
/// `simulate_detailed(..).result` — one arithmetic path, so tracing can
/// never drift from the pinned timings.
#[derive(Clone, Debug)]
pub struct SimBreakdown {
    pub result: SimResult,
    /// sum of per-round load cycles on the critical SM (bandwidth share)
    pub load_cycles: f64,
    /// sum of per-round compute cycles on the critical SM
    pub compute_cycles: f64,
    /// cycles the pipeline stalled waiting on memory
    pub stall_cycles: f64,
    /// non-overlappable output writeback tail
    pub writeback_cycles: f64,
    /// launch + API overhead charged once per kernel
    pub launch_overhead_cycles: f64,
}

/// Run `plan` on `spec`.
pub fn simulate(spec: &GpuSpec, plan: &KernelPlan) -> SimResult {
    simulate_detailed(spec, plan).result
}

/// Run `plan` on `spec`, keeping the pipeline's cycle split alongside the
/// headline result (for roofline reporting).
pub fn simulate_detailed(spec: &GpuSpec, plan: &KernelPlan) -> SimBreakdown {
    assert!(
        (MIN_STAGES..=MAX_STAGES).contains(&plan.stages),
        "{}: stages {} outside {MIN_STAGES}..={MAX_STAGES}",
        plan.name,
        plan.stages
    );
    assert!(
        plan.smem_bytes_per_sm <= spec.shared_mem_bytes,
        "{}: stage smem overflow ({} B at {} stages > {} B)",
        plan.name,
        plan.smem_bytes_per_sm,
        plan.stages,
        spec.shared_mem_bytes
    );
    assert!(plan.sms_active >= 1 && plan.sms_active <= spec.sm_count);

    let cfg = ExecConfig {
        sms_active: plan.sms_active,
        threads_per_sm: plan.threads_per_sm,
        compute_efficiency: plan.compute_efficiency,
        launch_overhead_cycles: plan.launch_overhead_cycles,
        stages: plan.stages,
        loading: plan.loading,
    };
    let pipe: PipelineResult = simulate_pipeline(spec, &cfg, &plan.rounds);

    // Output writeback streams at full segment width, overlapped with
    // compute except for its tail.  The charge is max(staged tail, DRAM
    // bus-floor excess): total time can never undercut moving ALL
    // traffic (loads + stores + epilogue reads) at peak bandwidth, so
    // both roofline bandwidth fractions stay <= 1.0 (the PR-7
    // store-accounting bug this fixes).  A fused epilogue prices its
    // residual-operand stream into the same tail: the bytes ride the
    // store burst instead of a separate glue kernel's launch + stream.
    let tail_bytes = plan.output_bytes + plan.epilogue_read_bytes;
    let tail = writeback_tail_cycles(spec, tail_bytes, plan.stages);
    let floor =
        (plan.dram_load_bytes() + plan.output_bytes + plan.epilogue_read_bytes) / spec.bytes_per_cycle();
    let wb_cycles = tail.max(floor - pipe.total_cycles);
    let cycles = pipe.total_cycles + wb_cycles;

    let seconds = spec.cycles_to_secs(cycles);
    let flops = 2.0 * plan.total_fma;
    let gflops = flops / seconds / 1e9;
    // memory-bound when the pipeline stalled on fetches OR the bus
    // floor (not the tail) set the writeback charge
    let bottleneck = if pipe.stall_cycles > 0.05 * pipe.total_cycles || wb_cycles > tail {
        "memory"
    } else {
        "compute"
    };
    let result = SimResult {
        name: plan.name.clone(),
        cycles,
        seconds,
        gflops,
        efficiency: flops / seconds / spec.peak_flops(),
        sm_utilization: plan.sms_active as f64 / spec.sm_count as f64,
        latency_hidden: pipe.latency_hidden,
        bottleneck,
        stall_fraction: pipe.stall_cycles / pipe.total_cycles,
        dram_load_bytes: plan.dram_load_bytes(),
        fma_per_byte: plan.fma_per_byte(),
    };
    SimBreakdown {
        result,
        load_cycles: pipe.load_cycles_sum,
        compute_cycles: pipe.compute_cycles_sum,
        stall_cycles: pipe.stall_cycles,
        writeback_cycles: wb_cycles,
        launch_overhead_cycles: plan.launch_overhead_cycles,
    }
}

/// Speedup of `ours` over `baseline` on the same spec (the Figs. 4/5 y-axis).
pub fn speedup(spec: &GpuSpec, ours: &KernelPlan, baseline: &KernelPlan) -> f64 {
    simulate(spec, baseline).seconds / simulate(spec, ours).seconds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::spec::gtx_1080ti;

    fn plan(rounds: usize, bytes: f64, fma: f64) -> KernelPlan {
        let g = gtx_1080ti();
        KernelPlan {
            name: "test".into(),
            rounds: (0..rounds)
                .map(|_| Round::new(bytes, 128, fma))
                .collect(),
            sms_active: g.sm_count,
            threads_per_sm: 1024,
            compute_efficiency: 0.9,
            output_bytes: 0.0,
            smem_bytes_per_sm: 48 * 1024,
            total_fma: fma * rounds as f64 * g.sm_count as f64,
            launch_overhead_cycles: 4_000.0,
            stages: 2,
            loading: Loading::Cyclic,
            stage_bytes: 8 * 1024,
            epilogue: Epilogue::None,
            epilogue_read_bytes: 0.0,
            filter_resident_smem_bytes: 0,
            filter_l2_footprint_bytes: 0,
        }
    }

    /// `plan` with every round's load tagged as `filter_frac` filters
    /// and a resident working set of `resident_kb` KiB per SM.
    fn resident_plan(
        rounds: usize,
        bytes: f64,
        fma: f64,
        filter_frac: f64,
        resident_kb: u32,
    ) -> KernelPlan {
        let mut p = plan(rounds, bytes, fma);
        for r in &mut p.rounds {
            *r = Round::mixed_with_filter(
                (bytes * filter_frac, 36),
                &[(bytes * (1.0 - filter_frac), 128)],
                fma,
            );
        }
        p.filter_resident_smem_bytes = resident_kb * 1024;
        p
    }

    #[test]
    fn gflops_consistent_with_time() {
        let g = gtx_1080ti();
        let p = plan(16, 1e4, 1e6);
        let r = simulate(&g, &p);
        let expect = 2.0 * p.total_fma / r.seconds / 1e9;
        assert!((r.gflops - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn efficiency_below_one() {
        let g = gtx_1080ti();
        for (bytes, fma) in [(1e3, 1e7), (1e6, 1e4), (1e5, 1e6)] {
            let r = simulate(&g, &plan(8, bytes, fma));
            assert!(r.efficiency > 0.0 && r.efficiency < 1.0, "{r:?}");
        }
    }

    #[test]
    fn compute_rich_plan_approaches_compute_efficiency() {
        // lots of FMAs per byte: the only loss is compute_efficiency + overheads
        let g = gtx_1080ti();
        let r = simulate(&g, &plan(64, 1e3, 5e7));
        assert!(r.efficiency > 0.8, "efficiency={}", r.efficiency);
        assert_eq!(r.bottleneck, "compute");
    }

    #[test]
    fn smem_overflow_panics() {
        let g = gtx_1080ti();
        let mut p = plan(2, 1e4, 1e5);
        p.smem_bytes_per_sm = g.shared_mem_bytes + 1;
        assert!(std::panic::catch_unwind(|| simulate(&g, &p)).is_err());
    }

    #[test]
    fn fewer_active_sms_is_slower() {
        let g = gtx_1080ti();
        let full = plan(16, 1e4, 1e6);
        let mut half = plan(32, 1e4, 1e6); // same total work on half the SMs
        half.sms_active = g.sm_count / 2;
        half.total_fma = full.total_fma;
        let t_full = simulate(&g, &full).seconds;
        let t_half = simulate(&g, &half).seconds;
        assert!(t_half > 1.5 * t_full, "full={t_full} half={t_half}");
    }

    #[test]
    fn speedup_identity() {
        let g = gtx_1080ti();
        let p = plan(8, 1e4, 1e6);
        assert!((speedup(&g, &p, &p) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fma_per_byte_definition() {
        let g = gtx_1080ti();
        let p = plan(10, 1e4, 1e6);
        let expect = p.total_fma / (1e4 * 10.0 * g.sm_count as f64);
        assert!((p.fma_per_byte() - expect).abs() < 1e-9);
    }

    #[test]
    fn batched_plan_scales_work_and_traffic_exactly() {
        let p = plan(8, 1e4, 1e6);
        let b = p.batched(4);
        assert_eq!(b.rounds.len(), 4 * p.rounds.len());
        assert!((b.total_fma - 4.0 * p.total_fma).abs() < 1e-9);
        assert!((b.dram_load_bytes() - 4.0 * p.dram_load_bytes()).abs() < 1e-6);
        assert!((b.output_bytes - 4.0 * p.output_bytes).abs() < 1e-9);
        // one launch: overhead is NOT scaled
        assert_eq!(b.launch_overhead_cycles, p.launch_overhead_cycles);
        assert!(b.name.contains("xb4"));
    }

    #[test]
    fn batch_of_one_is_identity() {
        let g = gtx_1080ti();
        let p = plan(8, 1e4, 1e6);
        let b = p.batched(1);
        assert_eq!(b.name, p.name);
        let (a, c) = (simulate(&g, &p).cycles, simulate(&g, &b).cycles);
        assert!((a - c).abs() < 1e-12 * a);
    }

    #[test]
    fn batched_cycles_monotone_and_amortized() {
        // cycles grow with n but stay under n independent launches: the
        // warm pipeline + single launch is the whole point of batching
        let g = gtx_1080ti();
        let p = plan(8, 1e4, 1e6);
        let single = simulate(&g, &p).cycles;
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8] {
            let c = simulate(&g, &p.batched(n)).cycles;
            assert!(c > last, "n={n}: {c} <= {last}");
            assert!(c < n as f64 * single + 1e-9, "n={n}: no amortization");
            last = c;
        }
    }

    #[test]
    #[should_panic(expected = "batch must be >= 1")]
    fn zero_batch_panics() {
        plan(2, 1e3, 1e4).batched(0);
    }

    #[test]
    fn batched_resident_drops_warm_filter_traffic() {
        let g = gtx_1080ti();
        // memory-bound rounds, half the traffic is filters, 16 KiB fits
        let p = resident_plan(8, 1e5, 1e4, 0.5, 16);
        assert!(p.filters_can_stay_resident(&g));
        let n = 8;
        let res = p.batched_resident(n, &g);
        assert!(res.name.ends_with("+fr"), "{}", res.name);
        // smem legality is charged, not assumed
        assert_eq!(res.smem_bytes_per_sm, p.smem_bytes_per_sm + 16 * 1024);
        // filters leave DRAM once, maps n times
        let expect_loads =
            p.dram_load_bytes() + (n - 1) as f64 * (p.dram_load_bytes() - p.filter_load_bytes());
        assert!((res.dram_load_bytes() - expect_loads).abs() < 1e-6 * expect_loads);
        // the honest post-residency FMA/byte rises
        assert!(res.fma_per_byte() > p.batched(n).fma_per_byte());
        // never-lose vs the re-streaming model, and a strict win here
        let t_res = simulate(&g, &res).cycles;
        let t_stream = simulate(&g, &p.batched(n)).cycles;
        assert!(t_res < t_stream, "resident {t_res} not below re-stream {t_stream}");
    }

    #[test]
    fn batched_resident_never_loses_and_is_monotone_in_n() {
        let g = gtx_1080ti();
        for (frac, kb) in [(0.5, 16), (0.9, 40), (0.1, 1)] {
            let p = resident_plan(6, 5e4, 2e4, frac, kb);
            let mut last = 0.0;
            for n in [1usize, 2, 4, 8, 16] {
                let t = simulate(&g, &p.batched_resident(n, &g)).cycles;
                let floor = simulate(&g, &p.batched(n)).cycles;
                assert!(t <= floor * (1.0 + 1e-9), "n={n}: {t} > re-stream {floor}");
                assert!(t > last, "n={n}: cycles not monotone");
                last = t;
            }
        }
    }

    #[test]
    fn batched_resident_falls_back_when_filters_do_not_fit() {
        let g = gtx_1080ti();
        // 64 KiB resident set on top of 48 KiB staging > 96 KiB budget
        let p = resident_plan(8, 1e5, 1e4, 0.5, 64);
        assert!(!p.filters_can_stay_resident(&g));
        let res = p.batched_resident(4, &g);
        assert!(!res.name.contains("+fr"), "{}", res.name);
        assert_eq!(
            simulate(&g, &res).cycles.to_bits(),
            simulate(&g, &p.batched(4)).cycles.to_bits()
        );
        // untagged plans (no resident bytes) also fall back
        let plain = plan(8, 1e5, 1e4);
        assert!(!plain.batched_resident(4, &g).name.contains("+fr"));
    }

    #[test]
    fn grouped_scales_the_resident_set_by_waves() {
        let g = gtx_1080ti();
        let mut unit = resident_plan(4, 1e4, 1e5, 0.5, 1);
        unit.sms_active = 1;
        // 56 groups over 28 SMs: 2 waves -> both waves' filters pinned
        let grouped = unit.grouped(56, g.sm_count);
        assert_eq!(grouped.filter_resident_smem_bytes, 2 * 1024);
        // decimation and fusion leave the residency fields alone
        assert_eq!(unit.decimated(0.5).filter_resident_smem_bytes, 1024);
        assert_eq!(
            unit.fused(Epilogue::Relu, (28, 28)).filter_resident_smem_bytes,
            1024
        );
    }

    #[test]
    fn decimated_never_slower_and_scales_work() {
        let g = gtx_1080ti();
        let p = plan(8, 1e4, 1e6);
        for keep in [1.0, 0.5, 0.25] {
            let d = p.decimated(keep);
            assert!((d.total_fma - keep * p.total_fma).abs() < 1e-9);
            assert!((d.output_bytes - keep * p.output_bytes).abs() < 1e-9);
            assert!((d.dram_load_bytes() - p.dram_load_bytes()).abs() < 1e-6, "loads stay");
            assert!(
                simulate(&g, &d).cycles <= simulate(&g, &p).cycles * (1.0 + 1e-12),
                "decimation slowed the plan at keep={keep}"
            );
        }
        assert!(std::panic::catch_unwind(|| p.decimated(0.0)).is_err());
    }

    #[test]
    fn grouped_fills_idle_sms_and_beats_sequential_batching() {
        let g = gtx_1080ti();
        // a one-SM unit plan (the depthwise regime): grouping must go
        // wide across idle SMs instead of serializing every group
        let mut unit = plan(4, 1e4, 1e5);
        unit.sms_active = 1;
        unit.total_fma = 1e5 * 4.0;
        let grouped = unit.grouped(56, g.sm_count);
        assert_eq!(grouped.sms_active, g.sm_count);
        assert!((grouped.total_fma - 56.0 * unit.total_fma).abs() < 1e-6);
        let t_grouped = simulate(&g, &grouped).cycles;
        let t_seq = simulate(&g, &unit.batched(56)).cycles;
        assert!(t_grouped < t_seq, "grouped {t_grouped} not below sequential {t_seq}");
        // identity at one group
        assert_eq!(unit.grouped(1, g.sm_count).name, unit.name);
    }

    #[test]
    fn detailed_breakdown_is_bit_identical_and_accounted() {
        let g = gtx_1080ti();
        for p in [
            plan(8, 1e4, 1e6),
            plan(8, 1e4, 1e6).batched(4),
            plan(8, 1e4, 1e6).decimated(0.5),
            plan(8, 1e4, 1e6).staged(3, Loading::Ordered),
        ] {
            let b = simulate_detailed(&g, &p);
            let r = simulate(&g, &p);
            assert_eq!(r.cycles.to_bits(), b.result.cycles.to_bits());
            assert_eq!(r.seconds.to_bits(), b.result.seconds.to_bits());
            assert!(b.load_cycles >= 0.0 && b.compute_cycles > 0.0 && b.stall_cycles >= 0.0);
            // writeback charge: max(staged tail, bus-floor excess)
            let tail = writeback_tail_cycles(&g, p.output_bytes, p.stages);
            assert!(b.writeback_cycles >= tail);
            assert_eq!(b.launch_overhead_cycles, p.launch_overhead_cycles);
        }
    }

    #[test]
    fn writeback_costs_time() {
        let g = gtx_1080ti();
        let a = plan(8, 1e4, 1e6);
        let mut b = plan(8, 1e4, 1e6);
        b.output_bytes = 1e8;
        assert!(simulate(&g, &b).seconds > simulate(&g, &a).seconds);
    }

    #[test]
    fn bus_floor_binds_store_heavy_plans() {
        // a plan writing far more than it computes can never beat the
        // time to move loads + stores at peak bandwidth
        let g = gtx_1080ti();
        let mut p = plan(2, 1e3, 1e3);
        p.output_bytes = 1e9;
        let r = simulate(&g, &p);
        let floor = (p.dram_load_bytes() + p.output_bytes) / g.bytes_per_cycle();
        assert!(r.cycles >= floor - 1e-6, "cycles {} under floor {floor}", r.cycles);
        assert_eq!(r.bottleneck, "memory");
        // and the total-traffic bandwidth fraction is <= 1.0
        let bw = (p.dram_load_bytes() + p.output_bytes) / r.seconds / 1e9;
        assert!(bw <= g.bandwidth_gb_s * (1.0 + 1e-9), "bw {bw} GB/s");
    }

    #[test]
    fn staged_depth2_cyclic_is_identity() {
        let g = gtx_1080ti();
        let p = plan(8, 1e4, 1e6);
        let s = p.staged(2, Loading::Cyclic);
        assert_eq!(s.name, p.name);
        assert_eq!(
            simulate(&g, &p).cycles.to_bits(),
            simulate(&g, &s).cycles.to_bits()
        );
    }

    #[test]
    fn staged_cycles_monotone_in_stages() {
        // cyclic: exposure/(s-1) and tail*2/s both shrink with depth
        let g = gtx_1080ti();
        let mut p = plan(16, 2e3, 1e3); // latency-exposed rounds
        p.output_bytes = 1e6;
        let mut last = f64::INFINITY;
        for s in MIN_STAGES..=MAX_STAGES {
            let c = simulate(&g, &p.staged(s, Loading::Cyclic)).cycles;
            assert!(c <= last * (1.0 + 1e-12), "stages={s}: {c} > {last}");
            last = c;
        }
    }

    #[test]
    fn staged_charges_smem_and_overflow_panics() {
        let g = gtx_1080ti();
        let mut p = plan(4, 1e4, 1e5);
        p.stage_bytes = 30 * 1024;
        let s3 = p.staged(3, Loading::Ordered);
        assert_eq!(s3.smem_bytes_per_sm, p.smem_bytes_per_sm + 30 * 1024);
        // 48 KiB base + 2 * 30 KiB > 96 KiB: depth-4 must panic cleanly
        let s4 = p.staged(4, Loading::Ordered);
        assert!(s4.smem_bytes_per_sm > g.shared_mem_bytes);
        assert!(std::panic::catch_unwind(|| simulate(&g, &s4)).is_err());
    }

    #[test]
    fn restaging_a_staged_plan_panics() {
        let p = plan(4, 1e4, 1e5).staged(3, Loading::Cyclic);
        assert!(std::panic::catch_unwind(|| p.staged(2, Loading::Cyclic)).is_err());
    }

    #[test]
    fn epilogue_tags_round_trip() {
        for ep in [
            Epilogue::None,
            Epilogue::Relu,
            Epilogue::AddResidual,
            Epilogue::MaxPoolWriteback { k: 2, stride: 2 },
            Epilogue::MaxPoolWriteback { k: 3, stride: 1 },
        ] {
            assert_eq!(Epilogue::parse(&ep.tag()), Some(ep), "{}", ep.tag());
        }
        assert_eq!(Epilogue::parse("pool0s2"), None);
        assert_eq!(Epilogue::parse("pool3"), None);
        assert_eq!(Epilogue::parse("maxpool3s2"), None);
        assert_eq!(Epilogue::parse(""), None);
    }

    #[test]
    fn fused_none_is_bit_identical() {
        let g = gtx_1080ti();
        let mut p = plan(8, 1e4, 1e6);
        p.output_bytes = 1e6;
        let f = p.fused(Epilogue::None, (28, 28));
        assert_eq!(f.name, p.name);
        assert_eq!(
            simulate(&g, &p).cycles.to_bits(),
            simulate(&g, &f).cycles.to_bits()
        );
    }

    #[test]
    fn fused_relu_timing_is_free() {
        // relu clamps registers in flight: same traffic, same cycles
        let g = gtx_1080ti();
        let mut p = plan(8, 1e4, 1e6);
        p.output_bytes = 1e6;
        let f = p.fused(Epilogue::Relu, (28, 28));
        assert!(f.name.ends_with("+relu"));
        assert_eq!(
            simulate(&g, &p).cycles.to_bits(),
            simulate(&g, &f).cycles.to_bits()
        );
    }

    #[test]
    fn fused_pool_shrinks_stores_by_the_pooled_fraction() {
        let g = gtx_1080ti();
        let mut p = plan(8, 1e4, 1e6);
        p.output_bytes = 28.0 * 28.0 * 4.0 * 256.0;
        let f = p.fused(Epilogue::MaxPoolWriteback { k: 2, stride: 2 }, (28, 28));
        assert!((f.output_bytes - p.output_bytes * (14.0 * 14.0) / (28.0 * 28.0)).abs() < 1e-9);
        assert!(simulate(&g, &f).cycles <= simulate(&g, &p).cycles);
        // odd map, overlap-free 2x2/s2 pool: floor((27-2)/2)+1 = 13
        let o = p.fused(Epilogue::MaxPoolWriteback { k: 2, stride: 2 }, (27, 27));
        assert!((o.output_bytes - p.output_bytes * (13.0 * 13.0) / (27.0 * 27.0)).abs() < 1e-9);
    }

    #[test]
    fn fused_add_streams_the_residual_through_the_tail() {
        let g = gtx_1080ti();
        let mut p = plan(8, 1e4, 1e6);
        p.output_bytes = 1e7;
        let f = p.fused(Epilogue::AddResidual, (28, 28));
        assert_eq!(f.epilogue_read_bytes, p.output_bytes);
        // the residual stream costs tail time...
        assert!(simulate(&g, &f).cycles > simulate(&g, &p).cycles);
        // ...but the bus floor still accounts every byte exactly once
        let r = simulate(&g, &f);
        let floor = (f.dram_load_bytes() + f.output_bytes + f.epilogue_read_bytes) / g.bytes_per_cycle();
        assert!(r.cycles >= floor - 1e-6);
    }

    #[test]
    fn fused_transforms_compose_with_batching_and_decimation() {
        let mut p = plan(8, 1e4, 1e6);
        p.output_bytes = 1e6;
        let f = p.fused(Epilogue::AddResidual, (28, 28));
        let b = f.batched(4);
        assert!((b.epilogue_read_bytes - 4.0 * f.epilogue_read_bytes).abs() < 1e-9);
        let d = f.decimated(0.25);
        assert!((d.epilogue_read_bytes - 0.25 * f.epilogue_read_bytes).abs() < 1e-9);
        let gr = f.grouped(4, 28);
        assert!((gr.epilogue_read_bytes - 4.0 * f.epilogue_read_bytes).abs() < 1e-9);
    }

    #[test]
    fn refusing_a_fused_plan_panics() {
        let p = plan(4, 1e4, 1e5).fused(Epilogue::Relu, (28, 28));
        assert!(std::panic::catch_unwind(|| p.fused(Epilogue::Relu, (28, 28))).is_err());
    }
}
