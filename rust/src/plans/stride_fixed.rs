//! §3.2 stride-fixed block kernel -> per-SM round schedule (Fig. 3).
//!
//! Work decomposition: the output pixels are tiled into strips of W'x;
//! the M filters into groups of M'.  A block owns one (strip, group)
//! pair and walks the filter stream along ch in S-byte segments; every
//! segment round loads
//!
//!   W'y x W'x / K *new* map pixels   (coalesced 128-B strips; the
//!                                     "red pixels" already on chip are
//!                                     reused, §3.2 / Fig. 3(b))
//! + its share of the S x M' filter segment (each segment leaves DRAM
//!   once per group — concurrent strips of the same group hit it in L2)
//!
//! and executes M' x (S/4) x W'x FMAs while the next round prefetches.
//! Small S keeps M' large, so the map stream is amortized over many
//! filters — the paper's FMA-per-loaded-byte objective.  `plan` tries
//! the paper's two S values (32, 64) and keeps the faster, exactly as
//! §4 does per workload.

use crate::analytic::multi::{choose, stage_bytes_multi, StrideFixedChoice};
use crate::analytic::occupancy::paper_launch;
use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::pipeline::simulate_pipeline_runs;
use crate::gpusim::{simulate, Epilogue, ExecConfig, GpuSpec, KernelPlan, Loading, Round};

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// The paper's multi-channel plan: best of S in {32, 64} (§3.2 step 1).
pub fn plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    plan_and_choice(p, spec).0
}

/// `plan`, also returning the winning (S, W'x, M') — the tuner caches it.
pub fn plan_and_choice(p: &ConvProblem, spec: &GpuSpec) -> (KernelPlan, StrideFixedChoice) {
    [32, 64]
        .iter()
        .map(|&s| plan_with_segment_choice(p, spec, s))
        .min_by(|a, b| {
            simulate(spec, &a.0).seconds.partial_cmp(&simulate(spec, &b.0).seconds).unwrap()
        })
        .unwrap()
}

/// Build the plan for an explicit segment size (the S ablation).
pub fn plan_with_segment(p: &ConvProblem, spec: &GpuSpec, s_bytes: usize) -> KernelPlan {
    plan_with_segment_choice(p, spec, s_bytes).0
}

/// `plan_with_segment`, also returning the winning choice.
///
/// M' is picked the way the paper's §4 did ("according to our
/// preliminary evaluation"): candidate divisors of M that satisfy the
/// §3.2(4) working-set bound are evaluated under the performance model
/// and the fastest kept.  The §3.2 closed-form `choose` seeds the
/// candidate set (it is always included).
pub fn plan_with_segment_choice(
    p: &ConvProblem,
    spec: &GpuSpec,
    s_bytes: usize,
) -> (KernelPlan, StrideFixedChoice) {
    let seed = choose(p, spec, s_bytes);
    let half = spec.shared_mem_bytes as usize / 2;
    // candidates are compared on their round *recipes* (run-length
    // pipeline, identical cycles to `simulate` up to the constant
    // writeback term); only the winner is materialized
    let mut best: Option<(f64, StrideFixedChoice)> = None;
    let mut consider = |c: &crate::analytic::StrideFixedChoice| {
        if c.smem_bytes > half {
            return;
        }
        let r = recipe(p, spec, c);
        let cfg = ExecConfig {
            sms_active: r.sms_active,
            threads_per_sm: r.threads_per_sm,
            compute_efficiency: super::COMPUTE_EFFICIENCY,
            launch_overhead_cycles: super::LAUNCH_OVERHEAD_CYCLES,
            stages: 2,
            loading: Loading::Cyclic,
        };
        let t = simulate_pipeline_runs(spec, &cfg, &[(r.round, r.count)]).total_cycles;
        if best.as_ref().map_or(true, |(bt, _)| t < *bt) {
            best = Some((t, *c));
        }
    };
    consider(&seed);
    for d in (1..=p.m).filter(|d| p.m % d == 0) {
        let c = crate::analytic::StrideFixedChoice {
            s_bytes,
            wx_prime: seed.wx_prime,
            m_prime: d,
            wy_prime: crate::analytic::multi::wy_prime(s_bytes, p.k),
            smem_bytes: crate::analytic::multi::working_set_bytes(
                s_bytes,
                seed.wx_prime,
                d,
                p.k,
            ),
            hides_latency: false,
        };
        consider(&c);
    }
    let (_, c) = best.unwrap();
    (plan_with_choice(p, spec, &c), c)
}

/// The round structure of a stride-fixed plan without the rounds
/// materialized: one identical round repeated `count` times per SM.
/// `plan_with_choice` expands it; the tuner scores it in closed form.
#[derive(Clone, Copy, Debug)]
pub struct StrideRecipe {
    pub round: Round,
    pub count: usize,
    pub sms_active: u32,
    pub threads_per_sm: u32,
    /// Distinct filter bytes one SM touches over the whole kernel — the
    /// shared-memory cost of pinning its filters across batched images.
    pub filter_resident_bytes: usize,
}

/// Per-SM round recipe for an explicit (S, W'x, M') choice.
pub fn recipe(p: &ConvProblem, spec: &GpuSpec, c: &StrideFixedChoice) -> StrideRecipe {
    assert!(p.valid());
    let launch = paper_launch(spec);

    let groups = ceil_div(p.m, c.m_prime);
    let strips = ceil_div(p.oy() * p.ox(), c.wx_prime).max(1);
    // segments along the whole filter depth (C channels x K*K taps)
    let segs = ceil_div(p.c * p.k * p.k * BYTES_F32, c.s_bytes).max(1);
    let blocks = groups * strips;
    let sms_active = blocks.min(spec.sm_count as usize) as u32;

    // per-round loads (per block):
    // new map pixels — the W'y-line window advances by W'y/K lines of
    // output coverage per segment; pixels already resident are reused
    let map_bytes = (c.wy_prime * c.wx_prime * BYTES_F32) as f64 / p.k as f64;
    // filter segment: leaves DRAM once per (group, seg); strips of the
    // same group running on other SMs reuse it through L2
    let filter_bytes = (c.s_bytes * c.m_prime) as f64 / strips.min(spec.sm_count as usize) as f64;
    let fma_per_round = (c.m_prime * (c.s_bytes / BYTES_F32) * c.wx_prime) as f64;

    // distinct filter groups one SM walks (strips of the same group
    // revisit the same filters, so this over-counts — conservative: it
    // only makes cross-image residency harder to qualify)
    let groups_per_sm = ceil_div(blocks, sms_active as usize).min(groups);
    let filter_resident_bytes = groups_per_sm * c.m_prime * p.c * p.k * p.k * BYTES_F32;

    StrideRecipe {
        round: Round::mixed_with_filter(
            (filter_bytes, c.s_bytes),
            &[(map_bytes, 128)],
            fma_per_round,
        ),
        count: ceil_div(blocks * segs, sms_active as usize),
        sms_active,
        threads_per_sm: launch.threads_per_sm(spec),
        filter_resident_bytes,
    }
}

/// Build the plan for an explicit (S, W'x, M') choice (the M'/W'x ablation).
pub fn plan_with_choice(p: &ConvProblem, spec: &GpuSpec, c: &StrideFixedChoice) -> KernelPlan {
    let r = recipe(p, spec, c);
    KernelPlan {
        name: format!("ours-multi[S={} M'={} W'x={}]", c.s_bytes, c.m_prime, c.wx_prime),
        rounds: vec![r.round; r.count],
        sms_active: r.sms_active,
        threads_per_sm: r.threads_per_sm,
        compute_efficiency: super::COMPUTE_EFFICIENCY,
        output_bytes: (p.out_elems() * BYTES_F32) as f64,
        smem_bytes_per_sm: c.smem_bytes as u32,
        total_fma: p.fma_ops() as f64,
        launch_overhead_cycles: super::LAUNCH_OVERHEAD_CYCLES,
        stages: 2,
        loading: Loading::Cyclic,
        stage_bytes: stage_bytes_multi(c.s_bytes, c.wx_prime, c.m_prime, p.k) as u32,
        epilogue: Epilogue::None,
        epilogue_read_bytes: 0.0,
        filter_resident_smem_bytes: r.filter_resident_bytes.min(u32::MAX as usize) as u32,
        filter_l2_footprint_bytes: (p.m * p.c * p.k * p.k * BYTES_F32) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::fig5_suite;
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn plans_simulate_for_all_fig5_cases() {
        let g = gtx_1080ti();
        for p in fig5_suite() {
            for s in [32, 64] {
                let pl = plan_with_segment(&p, &g, s);
                let r = simulate(&g, &pl);
                assert!(r.seconds > 0.0 && r.seconds.is_finite(), "{} S={s}", p.label());
                assert!(r.efficiency <= 1.0);
            }
        }
    }

    #[test]
    fn latency_mostly_hidden_on_compute_rich_fig5() {
        // §3: "In the multi-channel convolution, the size of input data is
        // large enough, and the number of FMA operations can be kept high
        // enough by data prefetching."  Holds whenever the problem's
        // arithmetic intensity clears the machine balance; the K=1
        // tiny-map cases are memory-bound on any schedule.
        let g = gtx_1080ti();
        let balance =
            g.fma_per_sm_cycle() as f64 * g.sm_count as f64 / g.bytes_per_cycle();
        let mut checked = 0;
        for p in fig5_suite() {
            // skip memory-bound problems and those whose output is too
            // small for a latency-hiding M' to also fill the SMs
            let strips = (p.oy() * p.ox() + 127) / 128;
            let occupancy_bound = (p.m + 63) / 64 * strips < g.sm_count as usize;
            if p.arithmetic_intensity() < 4.0 * balance || occupancy_bound {
                continue;
            }
            let r = simulate(&g, &plan(&p, &g));
            assert!(r.stall_fraction < 0.35, "{}: stall={}", p.label(), r.stall_fraction);
            checked += 1;
        }
        assert!(checked >= 5, "only {checked} compute-rich cases");
    }

    #[test]
    fn fma_per_byte_beats_small_m_prime() {
        // the paper's core claim: larger M' (small S) raises FMA/byte
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 56, 256, 3);
        let big = plan_with_choice(&p, &g, &choose(&p, &g, 32));
        let mut small_choice = choose(&p, &g, 32);
        small_choice.m_prime = 8;
        small_choice.smem_bytes =
            crate::analytic::multi::working_set_bytes(32, small_choice.wx_prime, 8, p.k);
        let small = plan_with_choice(&p, &g, &small_choice);
        assert!(
            big.fma_per_byte() > 2.0 * small.fma_per_byte(),
            "big={} small={}",
            big.fma_per_byte(),
            small.fma_per_byte()
        );
    }

    #[test]
    fn total_work_conserved() {
        // rounds x FMA/round covers the problem's FMAs (with tail padding)
        let g = gtx_1080ti();
        for p in fig5_suite() {
            let pl = plan(&p, &g);
            let scheduled: f64 =
                pl.rounds.iter().map(|r| r.fma_ops).sum::<f64>() * pl.sms_active as f64;
            assert!(
                scheduled >= 0.99 * p.fma_ops() as f64,
                "{}: scheduled {} < needed {}",
                p.label(),
                scheduled,
                p.fma_ops()
            );
        }
    }

    #[test]
    fn small_maps_adapt_better_than_dac17() {
        // unlike [1], the division adapts to 7x7 maps: several filter
        // groups keep a useful number of SMs fed, and the schedule beats
        // [1]'s fixed assignment outright (the paper's §1 critique).
        let g = gtx_1080ti();
        let p = ConvProblem::multi(512, 7, 512, 3);
        let pl = plan(&p, &g);
        assert!(pl.sms_active >= 8, "sms={}", pl.sms_active);
        let t_ours = simulate(&g, &pl).seconds;
        let t_dac = simulate(&g, &crate::baselines::dac17::plan(&p, &g)).seconds;
        assert!(t_ours < t_dac, "ours={t_ours} dac17={t_dac}");
    }

    #[test]
    fn smem_within_half_budget() {
        let g = gtx_1080ti();
        for p in fig5_suite() {
            let pl = plan(&p, &g);
            assert!(pl.smem_bytes_per_sm <= g.shared_mem_bytes / 2);
        }
    }

    #[test]
    fn map_traffic_scales_inversely_with_m_prime() {
        // halving M' ~doubles the map traffic (the §3.2 trade-off)
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 112, 256, 3);
        let c64 = choose(&p, &g, 32);
        let mut c16 = c64;
        c16.m_prime = 16;
        let t64 = plan_with_choice(&p, &g, &c64);
        let t16 = plan_with_choice(&p, &g, &c16);
        let ratio = t16.dram_load_bytes() / t64.dram_load_bytes();
        assert!(ratio > 1.8, "ratio={ratio} (M'_64={})", c64.m_prime);
    }
}
