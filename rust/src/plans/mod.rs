//! Per-SM execution schedules for the paper's two kernels, consumed by
//! `gpusim::simulate`.  `plan_for` is the router the coordinator and the
//! benches use: it serves the *tuned* plan (`tuner::tuned_plan`, memoized
//! per process).  `paper_plan_for` is the paper's verbatim §3 pick —
//! single-channel through the §3.1 P/Q procedure, multi-channel through
//! the §3.2 stride-fixed block method — kept as the `--no-tune` path and
//! as the regression baseline the tuner never loses to.

pub mod single_channel;
pub mod stride_fixed;

use crate::conv::ConvProblem;
use crate::gpusim::{GpuSpec, KernelPlan};

/// Launch + drain overhead our kernels pay (~2.7 µs at 1.48 GHz).  One
/// definition shared by both plan builders and the tuner's scorer — the
/// "score is exact under the simulator" premise depends on it.
pub const LAUNCH_OVERHEAD_CYCLES: f64 = 4_000.0;

/// Fraction of peak FMA issue our kernels' inner loops sustain.
pub const COMPUTE_EFFICIENCY: f64 = 0.9;

/// The serving plan for a problem: the tuner's pick (>= the paper's plan
/// under the simulator, memoized so repeated calls are cache hits).
pub fn plan_for(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    crate::tuner::tuned_plan(p, spec)
}

/// The paper's kernel for a problem (dispatch on C, as in §3) — no
/// search, exactly the closed-form procedures.
pub fn paper_plan_for(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    if p.is_single_channel() {
        single_channel::plan(p, spec)
    } else {
        stride_fixed::plan(p, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, simulate};

    #[test]
    fn dispatch_on_channel_count() {
        let g = gtx_1080ti();
        let s = plan_for(&ConvProblem::single(56, 64, 3), &g);
        assert!(s.name.contains("single"), "{}", s.name);
        let m = plan_for(&ConvProblem::multi(64, 56, 64, 3), &g);
        assert!(m.name.contains("multi"), "{}", m.name);
    }

    #[test]
    fn paper_plan_dispatches_too() {
        let g = gtx_1080ti();
        let s = paper_plan_for(&ConvProblem::single(56, 64, 3), &g);
        assert!(s.name.contains("single"), "{}", s.name);
        let m = paper_plan_for(&ConvProblem::multi(64, 56, 64, 3), &g);
        assert!(m.name.contains("multi"), "{}", m.name);
    }

    #[test]
    fn tuned_plan_at_least_as_fast_as_paper() {
        let g = gtx_1080ti();
        for p in [ConvProblem::single(1024, 32, 1), ConvProblem::multi(256, 14, 256, 3)] {
            let tuned = simulate(&g, &plan_for(&p, &g)).seconds;
            let paper = simulate(&g, &paper_plan_for(&p, &g)).seconds;
            assert!(tuned <= paper * (1.0 + 1e-9), "{}: {tuned} > {paper}", p.label());
        }
    }
}
