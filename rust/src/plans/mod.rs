//! Per-SM execution schedules for the paper's two kernels, consumed by
//! `gpusim::simulate`.
//!
//! The six historical entry points (`plan_for`, `paper_plan_for`, and
//! the batched variants) are kept for back-compat but are now thin
//! shims over the backend layer: `plan_for` is the paper-tuned backend
//! (`tuner::tuned_plan`, memoized per process), `paper_plan_for` the
//! verbatim §3 closed-form backend — single-channel through the §3.1
//! P/Q procedure, multi-channel through the §3.2 stride-fixed block
//! method — kept as the `--no-tune` path and as the regression baseline
//! the tuner never loses to.  Cross-backend selection lives one layer
//! up in `backend::dispatch`; nothing here ever picks a non-paper
//! algorithm.

pub mod single_channel;
pub mod stride_fixed;

use crate::backend::{ConvBackend, PaperClosedForm, PaperTuned};
use crate::conv::{BatchedConv, BatchedConvOp, ConvOp, ConvProblem};
use crate::gpusim::{Epilogue, GpuSpec, KernelPlan};

/// Launch + drain overhead our kernels pay (~2.7 µs at 1.48 GHz).  One
/// definition shared by both plan builders and the tuner's scorer — the
/// "score is exact under the simulator" premise depends on it.
pub const LAUNCH_OVERHEAD_CYCLES: f64 = 4_000.0;

/// Fraction of peak FMA issue our kernels' inner loops sustain.
pub const COMPUTE_EFFICIENCY: f64 = 0.9;

/// The paper kernel's serving plan: the tuner's pick (>= the paper's
/// plan under the simulator, memoized so repeated calls are cache hits).
pub fn plan_for(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    PaperTuned.plan(p, spec)
}

/// The paper's kernel for a problem (dispatch on C, as in §3) — no
/// search, exactly the closed-form procedures.
pub fn paper_plan_for(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    PaperClosedForm.plan(p, spec)
}

/// The serving plan for a batch: the tuned per-image plan repeated over
/// the batch (`KernelPlan::batched`) — one launch, warm pipeline.
pub fn batched_plan_for(b: &BatchedConv, spec: &GpuSpec) -> KernelPlan {
    PaperTuned.batched_plan(b, spec)
}

/// `batched_plan_for` with the paper's closed-form §3 pick (`--no-tune`).
pub fn batched_paper_plan_for(b: &BatchedConv, spec: &GpuSpec) -> KernelPlan {
    PaperClosedForm.batched_plan(b, spec)
}

/// Predicted execution cycles of a batch under the tuned paper plan —
/// the paper-kernel-only cost floor (fleet pricing now goes through
/// `backend::batched_dispatch_seconds`, which never exceeds this).
pub fn batched_cycles(b: &BatchedConv, spec: &GpuSpec) -> f64 {
    PaperTuned.batched_cycles(b, spec)
}

/// `batched_cycles` in seconds on `spec`.
pub fn batched_seconds(b: &BatchedConv, spec: &GpuSpec) -> f64 {
    PaperTuned.batched_seconds(b, spec)
}

// ---- the op layer (stride / padding / groups) ----

/// The paper kernel's serving plan for a conv op: tuned directly under
/// the op's own objective (decimated strips for stride, side-by-side
/// groups — never pricing above its own naive lowering), with the
/// requested writeback epilogue fused onto the plan's tail and the
/// geometry re-searched under that fused objective.  A
/// `graph::Planner`.
pub fn op_plan_for(op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> KernelPlan {
    PaperTuned.fused_op_plan(op, ep, spec)
}

/// `op_plan_for` with the paper's closed-form §3 unit picks
/// (`--no-tune`).
pub fn paper_op_plan_for(op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> KernelPlan {
    PaperClosedForm.op_plan(op, spec).fused(ep, (op.oy(), op.ox()))
}

/// Predicted cycles of a batched op under the tuned paper path.
pub fn batched_op_cycles(b: &BatchedConvOp, spec: &GpuSpec) -> f64 {
    PaperTuned.batched_op_cycles(b, spec)
}

/// `batched_op_cycles` in seconds.
pub fn batched_op_seconds(b: &BatchedConvOp, spec: &GpuSpec) -> f64 {
    PaperTuned.batched_op_seconds(b, spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, simulate};

    #[test]
    fn dispatch_on_channel_count() {
        let g = gtx_1080ti();
        let s = plan_for(&ConvProblem::single(56, 64, 3), &g);
        assert!(s.name.contains("single"), "{}", s.name);
        let m = plan_for(&ConvProblem::multi(64, 56, 64, 3), &g);
        assert!(m.name.contains("multi"), "{}", m.name);
    }

    #[test]
    fn paper_plan_dispatches_too() {
        let g = gtx_1080ti();
        let s = paper_plan_for(&ConvProblem::single(56, 64, 3), &g);
        assert!(s.name.contains("single"), "{}", s.name);
        let m = paper_plan_for(&ConvProblem::multi(64, 56, 64, 3), &g);
        assert!(m.name.contains("multi"), "{}", m.name);
    }

    #[test]
    fn batched_dispatch_and_identity_at_n1() {
        let g = gtx_1080ti();
        for p in [ConvProblem::single(56, 64, 3), ConvProblem::multi(64, 56, 64, 3)] {
            let single = simulate(&g, &plan_for(&p, &g)).cycles;
            let b1 = simulate(&g, &batched_plan_for(&BatchedConv::single(p), &g)).cycles;
            assert!((single - b1).abs() < 1e-12 * single, "{}", p.label());
            assert!((batched_cycles(&BatchedConv::single(p), &g) - single).abs()
                < 1e-12 * single);
        }
    }

    #[test]
    fn batched_cost_monotone_and_bounded_by_independent_launches() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(64, 14, 64, 3);
        let single = batched_seconds(&BatchedConv::single(p), &g);
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8] {
            let t = batched_seconds(&BatchedConv::new(p, n), &g);
            assert!(t > last, "n={n}");
            assert!(t <= n as f64 * single * (1.0 + 1e-9), "n={n}: slower than n launches");
            // the per-image marginal cost stays positive: at least the
            // image's own steady-state stream
            assert!(t >= single, "n={n}");
            last = t;
        }
    }

    #[test]
    fn op_plans_dispatch_and_degenerate_to_dense() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(64, 56, 64, 3);
        let none = Epilogue::None;
        assert_eq!(op_plan_for(&ConvOp::dense(p), none, &g).name, plan_for(&p, &g).name);
        assert_eq!(
            paper_op_plan_for(&ConvOp::dense(p), none, &g).name,
            paper_plan_for(&p, &g).name
        );
        // a strided op plan exists, simulates, and carries its tag
        let s2 = ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1);
        let plan = op_plan_for(&s2, none, &g);
        assert!(plan.name.contains("s2"), "{}", plan.name);
        assert!(simulate(&g, &plan).seconds > 0.0);
        // batched op helpers agree at n = 1
        let b1 = batched_op_cycles(&BatchedConvOp::single(s2), &g);
        assert!((b1 - simulate(&g, &plan).cycles).abs() < 1e-9 * b1);
        assert!(batched_op_seconds(&BatchedConvOp::new(s2, 4), &g) > 0.0);
    }

    #[test]
    fn fused_op_plans_reprice_the_writeback_tail() {
        let g = gtx_1080ti();
        let op = ConvOp::dense(ConvProblem::multi(64, 28, 64, 3));
        let base = simulate(&g, &op_plan_for(&op, Epilogue::None, &g)).cycles;
        // relu clamps in-register: same traffic, same cycles
        let relu = simulate(&g, &op_plan_for(&op, Epilogue::Relu, &g)).cycles;
        assert!((relu - base).abs() < 1e-9 * base);
        // pooled writeback stores the decimated map: never slower
        let ep = Epilogue::MaxPoolWriteback { k: 2, stride: 2 };
        let pool = simulate(&g, &op_plan_for(&op, ep, &g)).cycles;
        assert!(pool <= base, "{pool} > {base}");
        // the residual stream costs tail reads: never faster than base
        let add = simulate(&g, &op_plan_for(&op, Epilogue::AddResidual, &g)).cycles;
        assert!(add >= base, "{add} < {base}");
        assert!(op_plan_for(&op, ep, &g).name.contains("+pool2s2"));
    }

    #[test]
    fn tuned_plan_at_least_as_fast_as_paper() {
        let g = gtx_1080ti();
        for p in [ConvProblem::single(1024, 32, 1), ConvProblem::multi(256, 14, 256, 3)] {
            let tuned = simulate(&g, &plan_for(&p, &g)).seconds;
            let paper = simulate(&g, &paper_plan_for(&p, &g)).seconds;
            assert!(tuned <= paper * (1.0 + 1e-9), "{}: {tuned} > {paper}", p.label());
        }
    }
}
