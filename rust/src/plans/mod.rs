//! Per-SM execution schedules for the paper's two kernels, consumed by
//! `gpusim::simulate`.  `plan_for` is the router the coordinator and the
//! benches use: single-channel problems go through the §3.1 P/Q
//! procedure, multi-channel through the §3.2 stride-fixed block method.

pub mod single_channel;
pub mod stride_fixed;

use crate::conv::ConvProblem;
use crate::gpusim::{GpuSpec, KernelPlan};

/// The paper's kernel for a problem (dispatch on C, as in §3).
pub fn plan_for(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    if p.is_single_channel() {
        single_channel::plan(p, spec)
    } else {
        stride_fixed::plan(p, spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn dispatch_on_channel_count() {
        let g = gtx_1080ti();
        let s = plan_for(&ConvProblem::single(56, 64, 3), &g);
        assert!(s.name.contains("single"), "{}", s.name);
        let m = plan_for(&ConvProblem::multi(64, 56, 64, 3), &g);
        assert!(m.name.contains("multi"), "{}", m.name);
    }
}
