//! §3.1 single-channel kernel -> per-SM round schedule.
//!
//! Builds a `KernelPlan` from the analytic `SingleChoice`:
//!
//! * **FilterSplit (method 1)**: each SM keeps its ceil(M/N_sm) filters
//!   resident and streams the feature map in `P` pieces along y; round r
//!   loads one map piece (contiguous rows -> Wx*4-byte segments) and
//!   executes Th1 FMAs. Round 0 additionally loads the filter block
//!   (contiguous in memory, Fig. 1(a)).
//! * **MapSplit (method 2)**: each SM keeps its y-strip resident and
//!   streams the filters in `Q` pieces; round r loads ceil(M/Q)*K*K*4
//!   contiguous filter bytes and executes Th2 FMAs. Round 0 additionally
//!   loads the map strip.
//! * **Volume fallback**: everything in one round; the launch geometry's
//!   1024 threads/SM stream > V_s bytes to keep the bus busy (§2.2
//!   approach 2).

use crate::analytic::occupancy::paper_launch;
use crate::analytic::single::{choose, SingleChoice, SingleMethod};
use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::{Epilogue, GpuSpec, KernelPlan, Loading, Round};

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Smem bytes one extra pipeline stage buffer costs for a single-channel
/// plan: FilterSplit double-buffers a map piece (+ halo), MapSplit a
/// filter piece.  The tuner uses this to bound the staged sweep.
pub fn stage_bytes(p: &ConvProblem, method: SingleMethod, pp: usize, q: usize) -> usize {
    match method {
        SingleMethod::FilterSplit => (ceil_div(p.wy, pp) + p.k - 1) * p.wx * BYTES_F32,
        SingleMethod::MapSplit => ceil_div(p.m, q) * p.k * p.k * BYTES_F32,
    }
}

/// Build the paper's single-channel plan (choice made internally).
pub fn plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    let choice = choose(p, spec);
    plan_with_choice(p, spec, &choice)
}

/// The round structure of a single-channel plan without the rounds
/// materialized: the cold first round plus an optional run of identical
/// steady-state rounds.  `plan_with_choice` expands it; the tuner scores
/// it in closed form (same arithmetic, no allocation).
#[derive(Clone, Copy, Debug)]
pub struct SingleRecipe {
    pub first: Round,
    /// (steady-state round, repetitions) — absent when P = Q = 1
    pub tail: Option<(Round, usize)>,
    pub sms_active: u32,
    pub threads_per_sm: u32,
    pub smem_bytes: usize,
    /// smem cost of one extra pipeline stage buffer
    pub stage_bytes: usize,
    /// Distinct filter bytes one SM touches — the shared-memory cost of
    /// pinning its filters across batched images.
    pub filter_resident_bytes: usize,
}

/// Per-SM round recipe for an explicit `SingleChoice`.
pub fn recipe(p: &ConvProblem, spec: &GpuSpec, c: &SingleChoice) -> SingleRecipe {
    assert!(p.is_single_channel());
    let launch = paper_launch(spec);
    let threads = launch.threads_per_sm(spec);
    let row_seg = (p.wx * BYTES_F32).min(128); // one map row is the fetch unit

    match c.method {
        SingleMethod::FilterSplit => {
            let m_per_sm = ceil_div(p.m, spec.sm_count as usize);
            let sms = ceil_div(p.m, m_per_sm).min(spec.sm_count as usize) as u32;
            let filter_bytes = (m_per_sm * p.k * p.k * BYTES_F32) as f64;
            let piece_rows = ceil_div(p.wy, c.p);
            // every SM streams the same map piece against its own filters:
            // the piece leaves DRAM once and is broadcast through L2, so
            // the per-SM DRAM share divides by the SMs reading it
            let piece_bytes = (piece_rows * p.wx * BYTES_F32) as f64 / sms as f64;
            let halo_bytes = ((p.k - 1) * p.wx * BYTES_F32) as f64 / sms as f64;
            let fma = c.th1 as f64;
            let filter_seg = (m_per_sm * p.k * p.k * BYTES_F32).min(128);
            let first = Round::mixed_with_filter(
                (filter_bytes, filter_seg),
                &[(piece_bytes + halo_bytes, row_seg)],
                fma,
            );
            // subsequent pieces reuse the K-1 halo rows kept on chip
            let tail =
                (c.p > 1).then(|| (Round::new(piece_bytes, row_seg, fma), c.p - 1));
            SingleRecipe {
                first,
                tail,
                sms_active: sms,
                threads_per_sm: threads,
                smem_bytes: c.d1_bytes,
                stage_bytes: stage_bytes(p, c.method, c.p, c.q),
                // the SM's ceil(M/N_sm) filters are already resident by
                // construction — pinning them across images costs their size
                filter_resident_bytes: m_per_sm * p.k * p.k * BYTES_F32,
            }
        }
        SingleMethod::MapSplit => {
            let wy_per_sm = ceil_div(p.wy, spec.sm_count as usize);
            let sms = ceil_div(p.wy, wy_per_sm).min(spec.sm_count as usize) as u32;
            let strip_bytes = ((wy_per_sm + p.k - 1) * p.wx * BYTES_F32) as f64;
            let m_per_round = ceil_div(p.m, c.q);
            // every SM streams the same filter piece against its own map
            // strip: DRAM once, L2 broadcast (mirror of method 1's map)
            let piece_bytes = (m_per_round * p.k * p.k * BYTES_F32) as f64 / sms as f64;
            let filter_seg = (m_per_round * p.k * p.k * BYTES_F32).min(128);
            let fma = c.th2 as f64;
            let first = Round::mixed_with_filter(
                (piece_bytes, filter_seg),
                &[(strip_bytes, row_seg)],
                fma,
            );
            let tail = (c.q > 1).then(|| {
                (
                    Round::new(piece_bytes, filter_seg, fma)
                        .tagged_filter(piece_bytes, filter_seg),
                    c.q - 1,
                )
            });
            SingleRecipe {
                first,
                tail,
                sms_active: sms,
                threads_per_sm: threads,
                smem_bytes: c.d2_bytes,
                stage_bytes: stage_bytes(p, c.method, c.p, c.q),
                // each SM streams ALL M filters past its strip: pinning
                // them across images costs the full filter set
                filter_resident_bytes: p.m * p.k * p.k * BYTES_F32,
            }
        }
    }
}

/// Build the plan for an explicit `SingleChoice` (ablations force P/Q).
pub fn plan_with_choice(p: &ConvProblem, spec: &GpuSpec, c: &SingleChoice) -> KernelPlan {
    let r = recipe(p, spec, c);
    let mut rounds = Vec::with_capacity(1 + r.tail.map_or(0, |(_, n)| n));
    rounds.push(r.first);
    if let Some((tail, n)) = r.tail {
        rounds.extend(std::iter::repeat(tail).take(n));
    }

    KernelPlan {
        name: format!(
            "ours-single[{:?} P={} Q={}{}]",
            c.method,
            c.p,
            c.q,
            if c.uses_prefetch { "" } else { " volume" }
        ),
        rounds,
        sms_active: r.sms_active,
        threads_per_sm: r.threads_per_sm,
        compute_efficiency: super::COMPUTE_EFFICIENCY,
        output_bytes: (p.out_elems() * BYTES_F32) as f64,
        smem_bytes_per_sm: r.smem_bytes.min(spec.shared_mem_bytes as usize) as u32,
        total_fma: p.fma_ops() as f64,
        launch_overhead_cycles: super::LAUNCH_OVERHEAD_CYCLES,
        stages: 2,
        loading: Loading::Cyclic,
        stage_bytes: r.stage_bytes as u32,
        epilogue: Epilogue::None,
        epilogue_read_bytes: 0.0,
        filter_resident_smem_bytes: r.filter_resident_bytes.min(u32::MAX as usize) as u32,
        filter_l2_footprint_bytes: (p.m * p.k * p.k * BYTES_F32) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::fig4_suite;
    use crate::gpusim::{gtx_1080ti, simulate};

    #[test]
    fn plans_simulate_for_all_fig4_cases() {
        let g = gtx_1080ti();
        for p in fig4_suite() {
            let plan = plan(&p, &g);
            let r = simulate(&g, &plan);
            assert!(r.seconds > 0.0 && r.seconds.is_finite(), "{}: {:?}", p.label(), r);
            assert!(r.efficiency <= 1.0, "{}: eff {}", p.label(), r.efficiency);
        }
    }

    #[test]
    fn round_count_matches_division() {
        let g = gtx_1080ti();
        for p in fig4_suite() {
            let c = choose(&p, &g);
            let plan = plan_with_choice(&p, &g, &c);
            let expect = match c.method {
                SingleMethod::FilterSplit => c.p,
                SingleMethod::MapSplit => c.q,
            };
            assert_eq!(plan.rounds.len(), expect, "{}", p.label());
        }
    }

    #[test]
    fn dram_traffic_at_least_compulsory() {
        // the plan must load at least the whole input once
        let g = gtx_1080ti();
        for p in fig4_suite() {
            let pl = plan(&p, &g);
            // filters are replicated across SMs under MapSplit (and the map
            // under FilterSplit) so per-problem traffic >= one full input
            assert!(
                pl.dram_load_bytes() >= 0.99 * (p.map_elems() * BYTES_F32) as f64,
                "{}: {} < map bytes",
                p.label(),
                pl.dram_load_bytes()
            );
        }
    }

    #[test]
    fn total_fma_is_problem_fma() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(224, 64, 3);
        assert_eq!(plan(&p, &g).total_fma, p.fma_ops() as f64);
    }

    #[test]
    fn prefetch_cases_mostly_hide_latency() {
        // the point of the P/Q procedure: Fig.4 cases that picked prefetch
        // should simulate with latency hidden in the steady state
        let g = gtx_1080ti();
        let mut checked = 0;
        for p in fig4_suite() {
            let c = choose(&p, &g);
            if c.uses_prefetch && (c.p > 2 || c.q > 2) {
                let r = simulate(&g, &plan_with_choice(&p, &g, &c));
                assert!(r.stall_fraction < 0.4, "{}: stall {}", p.label(), r.stall_fraction);
                checked += 1;
            }
        }
        assert!(checked > 0, "no prefetch cases exercised");
    }
}
