//! Plan-space enumeration: every legal `KernelPlan` parameterization for
//! a problem, as compact `PlanParams` (the search key the cache stores).
//!
//! Single-channel: the paper's §3.1 procedure picks the *minimum*
//! feasible P (or Q); the tuner instead enumerates every division with a
//! distinct piece shape — `ceil(Wy/P)` (resp. `ceil(M/Q)`) values dedupe
//! the range to ~2·sqrt(n) candidates — and keeps any whose resident set
//! fits shared memory.
//!
//! Multi-channel: the paper fixes S ∈ {32, 64}, W'x = 128 and one M'
//! per problem; the tuner sweeps S over all coalescing-legal multiples
//! of 32 up to 128, W'x over 32-pixel multiples up to the output size
//! (capped at 256 px as in §3.2), and M' over the divisors of M, keeping
//! every triple whose §3.2(4) double-buffer fits half the shared memory.

use crate::analytic::multi::{staged_working_set_bytes, working_set_bytes, wy_prime};
use crate::analytic::single::{d1_bytes, d2_bytes, th1, th2};
use crate::analytic::{SingleChoice, SingleMethod, StrideFixedChoice};
use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::{GpuSpec, Loading};
use crate::plans::single_channel;

/// A point in the plan space — enough to rebuild the full `KernelPlan`.
/// Every variant carries the two pipeline axes: `stages` (buffer depth)
/// and `loading` (segment-coalescing strategy of the stage transfer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanParams {
    /// §3.1 shape: one divisor active, the other reset to 1 (paper step 4)
    Single { method: SingleMethod, p: usize, q: usize, stages: u32, loading: Loading },
    /// §3.2 shape: segment bytes, strip pixels, filters per block
    Multi { s_bytes: usize, wx_prime: usize, m_prime: usize, stages: u32, loading: Loading },
}

impl PlanParams {
    /// The pipeline axes common to both variants.
    pub fn staging(&self) -> (u32, Loading) {
        match *self {
            PlanParams::Single { stages, loading, .. }
            | PlanParams::Multi { stages, loading, .. } => (stages, loading),
        }
    }

    /// Is this point in the pre-multi-stage (depth-2 cyclic) subspace?
    pub fn is_depth2_cyclic(&self) -> bool {
        self.staging() == (2, Loading::Cyclic)
    }
}

/// The (stages, loading) variants the tuner crosses with every geometry.
/// Tilewise serializes its loads per warp, so stages > 2 only spend smem
/// without amortizing latency — the sweep skips those dominated points.
pub const STAGED_VARIANTS: [(u32, Loading); 7] = [
    (2, Loading::Cyclic),
    (3, Loading::Cyclic),
    (4, Loading::Cyclic),
    (2, Loading::Tilewise),
    (2, Loading::Ordered),
    (3, Loading::Ordered),
    (4, Loading::Ordered),
];

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Segment sizes the multi-channel sweep tries (multiples of 32 B, the
/// §2.2 coalescing constraint; 128 B is tan128's operating point).
pub const SEGMENT_SWEEP: [usize; 4] = [32, 64, 96, 128];

/// Strip widths in pixels (multiples of 32 px = 128 B, capped at 256 px).
pub const WX_SWEEP: [usize; 8] = [32, 64, 96, 128, 160, 192, 224, 256];

/// Divisors `d` of `1..=n` giving distinct `ceil(n/d)`, ascending.
pub fn distinct_divisions(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut d = 1;
    while d <= n {
        let q = ceil_div(n, d);
        out.push(d);
        // largest d' with ceil(n/d') == q is (n-1)/(q-1) for q > 1
        d = if q > 1 { (d + 1).max((n - 1) / (q - 1) + 1) } else { n + 1 };
    }
    out
}

/// All divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            out.push(d);
            if d != n / d {
                out.push(n / d);
            }
        }
        d += 1;
    }
    out.sort_unstable();
    out
}

/// Rebuild the full `SingleChoice` (eq. 5/6/8/9 terms) from parameters.
pub fn single_choice(
    p: &ConvProblem,
    spec: &GpuSpec,
    method: SingleMethod,
    pp: usize,
    q: usize,
) -> SingleChoice {
    let (d1, d2) = (d1_bytes(p, spec, pp), d2_bytes(p, spec, q));
    let (t1, t2) = (th1(p, spec, pp), th2(p, spec, q));
    let (d, th) = match method {
        SingleMethod::FilterSplit => (d1, t1),
        SingleMethod::MapSplit => (d2, t2),
    };
    SingleChoice {
        method,
        p: pp,
        q,
        d1_bytes: d1,
        d2_bytes: d2,
        th1: t1,
        th2: t2,
        uses_prefetch: th >= spec.n_fma() && d <= spec.shared_mem_bytes as usize,
    }
}

/// Rebuild the full `StrideFixedChoice` (§3.2 terms) from parameters.
pub fn multi_choice(
    p: &ConvProblem,
    spec: &GpuSpec,
    s_bytes: usize,
    wx_prime: usize,
    m_prime: usize,
) -> StrideFixedChoice {
    StrideFixedChoice {
        s_bytes,
        wx_prime,
        m_prime,
        wy_prime: wy_prime(s_bytes, p.k),
        smem_bytes: working_set_bytes(s_bytes, wx_prime, m_prime, p.k),
        hides_latency: (m_prime * (s_bytes / BYTES_F32) * wx_prime) as f64
            >= 0.95 * spec.n_fma() as f64,
    }
}

/// Every candidate parameterization for `p` on `spec`.
pub fn enumerate(p: &ConvProblem, spec: &GpuSpec) -> Vec<PlanParams> {
    assert!(p.valid(), "invalid problem");
    if p.is_single_channel() {
        enumerate_single(p, spec)
    } else {
        enumerate_multi(p, spec)
    }
}

fn enumerate_single(p: &ConvProblem, spec: &GpuSpec) -> Vec<PlanParams> {
    let budget = spec.shared_mem_bytes as usize;
    let mut bases: Vec<(SingleMethod, usize, usize, usize)> = Vec::new();
    for pp in distinct_divisions(p.wy) {
        let d = d1_bytes(p, spec, pp);
        if d <= budget {
            bases.push((SingleMethod::FilterSplit, pp, 1, d));
        }
    }
    for q in distinct_divisions(p.m) {
        let d = d2_bytes(p, spec, q);
        if d <= budget {
            bases.push((SingleMethod::MapSplit, 1, q, d));
        }
    }
    // the §2.2 volume fallback (undivided, smem clamped by the builder)
    // must stay reachable even when nothing fits the budget
    if !bases.iter().any(|&(m, pp, q, _)| m == SingleMethod::FilterSplit && pp == 1 && q == 1) {
        bases.push((SingleMethod::FilterSplit, 1, 1, d1_bytes(p, spec, 1)));
    }
    let mut out = Vec::new();
    for (method, pp, q, d) in bases {
        let stage = single_channel::stage_bytes(p, method, pp, q);
        for (st, ld) in STAGED_VARIANTS {
            // each stage past the baseline two buffers one more piece
            if d + (st as usize - 2) * stage <= budget {
                out.push(PlanParams::Single { method, p: pp, q, stages: st, loading: ld });
            }
        }
    }
    out
}

fn enumerate_multi(p: &ConvProblem, spec: &GpuSpec) -> Vec<PlanParams> {
    let half = spec.shared_mem_bytes as usize / 2;
    let out_px = p.oy() * p.ox();
    // strips wider than the (32-px-rounded) output waste fetches; the
    // whole-output strip itself is a multiple of 32 so it is always in
    // the sweep when it is <= 256 px
    let map_px = ceil_div(out_px, 32) * 32;
    let wx_opts: Vec<usize> =
        WX_SWEEP.iter().copied().filter(|&w| w <= map_px.max(32)).collect();
    let m_opts = divisors(p.m);
    let mut out = Vec::new();
    for &s in &SEGMENT_SWEEP {
        for &wx in &wx_opts {
            for &mp in &m_opts {
                for (st, ld) in STAGED_VARIANTS {
                    if staged_working_set_bytes(s, wx, mp, p.k, st) <= half {
                        out.push(PlanParams::Multi {
                            s_bytes: s,
                            wx_prime: wx,
                            m_prime: mp,
                            stages: st,
                            loading: ld,
                        });
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gtx_1080ti;

    #[test]
    fn distinct_divisions_cover_all_quotients() {
        for n in [1usize, 2, 3, 7, 28, 56, 100, 1024] {
            let ds = distinct_divisions(n);
            let qs: Vec<usize> = ds.iter().map(|&d| ceil_div(n, d)).collect();
            // strictly decreasing quotients == no duplicates, none missed
            for w in qs.windows(2) {
                assert!(w[0] > w[1], "n={n}: {qs:?}");
            }
            let all: std::collections::HashSet<usize> =
                (1..=n).map(|d| ceil_div(n, d)).collect();
            assert_eq!(all, qs.iter().copied().collect(), "n={n}");
            assert!(ds.len() <= 2 * (n as f64).sqrt() as usize + 2, "n={n}: {}", ds.len());
        }
    }

    #[test]
    fn divisors_exact() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(64), vec![1, 2, 4, 8, 16, 32, 64]);
    }

    #[test]
    fn single_candidates_fit_budget_and_include_fallback() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(1024, 32, 3);
        let cands = enumerate(&p, &g);
        assert!(cands.len() > 8, "{}", cands.len());
        let mut has_fallback = false;
        for c in &cands {
            match *c {
                PlanParams::Single { method, p: pp, q, stages, .. } => {
                    assert!(pp == 1 || q == 1);
                    assert!((2..=4).contains(&stages));
                    if (pp, q) == (1, 1) && method == SingleMethod::FilterSplit {
                        has_fallback = true;
                    }
                    let d = match method {
                        SingleMethod::FilterSplit => d1_bytes(&p, &g, pp),
                        SingleMethod::MapSplit => d2_bytes(&p, &g, q),
                    };
                    let stage = single_channel::stage_bytes(&p, method, pp, q);
                    assert!(
                        d + (stages as usize - 2) * stage <= g.shared_mem_bytes as usize,
                        "staged resident set over budget"
                    );
                }
                PlanParams::Multi { .. } => panic!("multi candidate for single problem"),
            }
        }
        assert!(has_fallback);
    }

    #[test]
    fn multi_candidates_fit_staged_smem() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 14, 256, 3);
        let cands = enumerate(&p, &g);
        assert!(!cands.is_empty());
        for c in &cands {
            let PlanParams::Multi { s_bytes, wx_prime, m_prime, stages, .. } = *c else {
                panic!("single candidate for multi problem");
            };
            assert_eq!(s_bytes % 32, 0);
            assert_eq!(wx_prime % 32, 0);
            assert_eq!(p.m % m_prime, 0);
            assert!((2..=4).contains(&stages));
            assert!(
                staged_working_set_bytes(s_bytes, wx_prime, m_prime, p.k, stages)
                    <= g.shared_mem_bytes as usize / 2
            );
        }
    }

    #[test]
    fn every_geometry_carries_the_depth2_cyclic_point() {
        // the pre-multi-stage plan space must stay a subset of the new
        // one: for every (geometry, stages, loading) candidate the plain
        // (geometry, 2, cyclic) point is also enumerated
        let g = gtx_1080ti();
        for p in [ConvProblem::multi(128, 28, 128, 3), ConvProblem::single(224, 64, 3)] {
            let cands = enumerate(&p, &g);
            assert!(cands.iter().any(|c| c.is_depth2_cyclic()));
            for c in &cands {
                let base = match *c {
                    PlanParams::Single { method, p: pp, q, .. } => PlanParams::Single {
                        method,
                        p: pp,
                        q,
                        stages: 2,
                        loading: Loading::Cyclic,
                    },
                    PlanParams::Multi { s_bytes, wx_prime, m_prime, .. } => PlanParams::Multi {
                        s_bytes,
                        wx_prime,
                        m_prime,
                        stages: 2,
                        loading: Loading::Cyclic,
                    },
                };
                assert!(cands.contains(&base), "{base:?} missing for {c:?}");
            }
        }
    }

    #[test]
    fn small_map_strips_clamped_to_output() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(512, 7, 512, 3); // 25 output px -> 32-px strip
        for c in enumerate(&p, &g) {
            let PlanParams::Multi { wx_prime, .. } = c else { unreachable!() };
            assert_eq!(wx_prime, 32);
        }
    }

    #[test]
    fn rebuilt_choices_match_formulas() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(224, 64, 3);
        let c = single_choice(&p, &g, SingleMethod::FilterSplit, 4, 1);
        assert_eq!(c.d1_bytes, d1_bytes(&p, &g, 4));
        assert_eq!(c.th1, th1(&p, &g, 4));
        let pm = ConvProblem::multi(128, 28, 128, 3);
        let mc = multi_choice(&pm, &g, 32, 128, 64);
        assert_eq!(mc.smem_bytes, working_set_bytes(32, 128, 64, 3));
        assert_eq!(mc.wy_prime, wy_prime(32, 3));
    }
}
