//! Memoized tuning results: `(problem, GPU) -> winning PlanParams`, with
//! a line-based serialization (same `key=value` grammar as the artifact
//! manifest — this repo's vendor set has no serde).  The coordinator
//! loads a cache at startup so serving pays zero per-request search.
//!
//! Format v2 adds `kind=dispatch` entries — the backend layer's
//! cross-backend decisions (`backend=<tag> cycles=... tuned_cycles=...`)
//! ride in the same file, keyed the same way.  Format v3 keys dispatch
//! entries by the full `ConvOp` — `stride=`/`pad=`/`groups=` fields
//! carry the op parameters, and are OPTIONAL on parse (defaulting to
//! the dense 1/0/1), so every v1 and v2 file parses unchanged.
//!
//! Format v4 adds the pipeline axes to every PLAN entry: `stages=` and
//! `loading=`.  These are NOT defaulted on parse — a pre-v4 plan entry
//! was tuned over a smaller plan space and its cycle counts no longer
//! match what `build_plan` produces, so serving it silently would
//! resurrect the stale-cache bug the validators pin against.  Pre-v4
//! plan lines are DROPPED (and counted in `stale_dropped`) so old files
//! still load, re-tune the dropped keys, and re-save as v4.
//!
//! Format v5 adds the fused-epilogue axis: `epilogue=` is REQUIRED on
//! every line.  Dispatch entries are now keyed by `(ConvOp, Epilogue)`
//! — a pre-v5 dispatch decision was ranked without the fused axis
//! (the fused floor reprices the writeback tail), so defaulting it to
//! `epilogue=none` and serving it is exactly the stale-cache bug the
//! v4 policy rejects for plans; pre-v5 dispatch lines are DROPPED and
//! counted too.  Plan entries stay epilogue-blind (the tuner searches
//! unit plans at `none`; fusion is applied to the tuned plan), so a
//! plan line carries `epilogue=none` always — any other value is
//! corruption, not staleness, and errors.
//!
//! Format v6 adds the OP-KEYED tuning slice: plan lines may carry
//! `stride=`/`pad=`/`groups=`/`n=` plus a real `epilogue=` tag, keyed
//! by the full `(ConvOp, Epilogue, n)` — the op-native tuner's results
//! under the decimated/grouped/fused/batched-residency objective.  The
//! `n=` field is the marker: a plan line without it is a v5 unit entry
//! and round-trips BYTE-IDENTICALLY (unit lines never serialize the op
//! fields); with it, the params were searched under the op objective
//! and must never be served for the unit key.  Dispatch lines gain an
//! optional `n=` batch field too (defaulting to 1, serialized only
//! when n > 1, so v5 dispatch lines also round-trip byte-identically)
//! — batched cross-backend decisions persist instead of living in a
//! per-process memo.  Bad op fields (`n=0`, garbage integers, pools
//! that don't fit the op) are corruption and hard-error, never dropped.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::analytic::SingleMethod;
use crate::backend::{self, Decision, BACKEND_NAMES};
use crate::conv::{ConvOp, ConvProblem};
use crate::gpusim::{
    gtx_1080ti, tesla_k40, titan_x_maxwell, Epilogue, GpuSpec, Loading, MAX_STAGES, MIN_STAGES,
};

use super::enumerate::PlanParams;

/// One memoized tuning outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tuned {
    pub params: PlanParams,
    /// simulated cycles of the tuned plan
    pub tuned_cycles: f64,
    /// simulated cycles of the paper's closed-form plan (the baseline the
    /// tuner never loses to: tuned_cycles <= paper_cycles always)
    pub paper_cycles: f64,
}

impl Tuned {
    /// Paper cycles over tuned cycles (>= 1 by construction).
    pub fn speedup(&self) -> f64 {
        self.paper_cycles / self.tuned_cycles
    }
}

/// GPU names contain spaces ("GTX 1080Ti"); the line grammar is
/// whitespace-separated, so spaces round-trip as underscores.
fn encode_gpu(name: &str) -> String {
    name.replace(' ', "_")
}

fn decode_gpu(name: &str) -> String {
    name.replace('_', " ")
}

fn field<'a>(fields: &HashMap<&str, &'a str>, idx: usize, key: &str) -> Result<&'a str> {
    fields
        .get(key)
        .copied()
        .ok_or_else(|| anyhow!("line {}: missing field {key}", idx + 1))
}

fn usize_field(fields: &HashMap<&str, &str>, idx: usize, key: &str) -> Result<usize> {
    field(fields, idx, key)?
        .parse()
        .with_context(|| format!("line {}: field {key} not an integer", idx + 1))
}

/// Optional integer field with a default — how v3 op parameters stay
/// backward compatible with v1/v2 lines that never carried them.
fn usize_field_or(
    fields: &HashMap<&str, &str>,
    idx: usize,
    key: &str,
    default: usize,
) -> Result<usize> {
    match fields.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .with_context(|| format!("line {}: field {key} not an integer", idx + 1)),
    }
}

fn f64_field(fields: &HashMap<&str, &str>, idx: usize, key: &str) -> Result<f64> {
    field(fields, idx, key)?
        .parse()
        .with_context(|| format!("line {}: field {key} not a float", idx + 1))
}

/// Cache files are inputs (hand-editable, possibly stale): reject
/// entries that would panic downstream — invalid problems, divisors
/// out of range, non-coalesced segment sizes, working sets that cannot
/// fit the named GPU, or a "tuned" plan slower than the paper baseline
/// (which would trip the never-lose asserts that guard the *search*).
fn validate_entry(idx: usize, p: &ConvProblem, gpu: &str, t: &Tuned) -> Result<()> {
    let line = idx + 1;
    if !p.valid() {
        bail!("line {line}: invalid problem {p:?}");
    }
    if !(t.tuned_cycles.is_finite() && t.tuned_cycles > 0.0 && t.paper_cycles.is_finite()) {
        bail!("line {line}: non-finite cycle counts");
    }
    if t.tuned_cycles > t.paper_cycles * (1.0 + 1e-9) {
        bail!("line {line}: tuned_cycles exceed paper_cycles — stale or edited entry");
    }
    let (stages, _) = t.params.staging();
    if !(MIN_STAGES..=MAX_STAGES).contains(&stages) {
        bail!("line {line}: stages {stages} outside {MIN_STAGES}..={MAX_STAGES}");
    }
    // known GPUs let us check resource bounds; unknown names are served
    // never (lookups key on the built-in specs) but must still parse
    let spec = [gtx_1080ti(), titan_x_maxwell(), tesla_k40()]
        .into_iter()
        .find(|s| s.name == gpu);
    match t.params {
        PlanParams::Single { p: pp, q, .. } => {
            if !p.is_single_channel() {
                bail!("line {line}: kind=single for a C={} problem", p.c);
            }
            if pp < 1 || pp > p.wy || q < 1 || q > p.m || (pp != 1 && q != 1) {
                bail!("line {line}: P/Q out of range (P={pp}, Q={q})");
            }
        }
        PlanParams::Multi { s_bytes, wx_prime, m_prime, stages, .. } => {
            if p.is_single_channel() {
                bail!("line {line}: kind=multi for a single-channel problem");
            }
            if s_bytes == 0 || s_bytes % 32 != 0 || wx_prime == 0 || wx_prime % 32 != 0 {
                bail!("line {line}: S/W'x must be non-zero multiples of 32");
            }
            if m_prime < 1 || m_prime > p.m {
                bail!("line {line}: M'={m_prime} out of range");
            }
            if let Some(spec) = spec {
                let ws = crate::analytic::multi::staged_working_set_bytes(
                    s_bytes, wx_prime, m_prime, p.k, stages,
                );
                if ws > spec.shared_mem_bytes as usize / 2 {
                    bail!(
                        "line {line}: staged working set {ws} B exceeds {}'s budget",
                        spec.name
                    );
                }
            }
        }
    }
    Ok(())
}

/// Validation for v6 op-keyed plan entries: the op itself must be
/// valid, the fused epilogue must fit its output map, the batch must
/// be positive, and the params must be sane for the op's LOWERED unit
/// problem — that is the space the op-native search enumerates, so
/// range and resource checks run against `op.lower().unit`, not the
/// grouped/strided core the line's c/wy/wx/m/k fields spell.
fn validate_op_entry(
    idx: usize,
    op: &ConvOp,
    ep: Epilogue,
    n: usize,
    gpu: &str,
    t: &Tuned,
) -> Result<()> {
    let line = idx + 1;
    if !op.valid() {
        bail!("line {line}: invalid op {op:?}");
    }
    if let Epilogue::MaxPoolWriteback { k, stride } = ep {
        if k == 0 || stride == 0 || op.oy() < k || op.ox() < k {
            bail!("line {line}: pool{k}s{stride} does not fit {}x{}", op.oy(), op.ox());
        }
    }
    if n == 0 {
        bail!("line {line}: batch n must be >= 1");
    }
    validate_entry(idx, &op.lower().unit, gpu, t)
}

/// Validation for `kind=dispatch` entries: the named backend must
/// exist, cover the op (natively or through the lowering), and not
/// claim to beat its own floor's definition (cycles <= tuned_cycles —
/// the dispatcher's never-lose invariant; an edited or stale entry
/// violating it would silently serve a losing backend).
fn validate_dispatch(idx: usize, op: &ConvOp, ep: Epilogue, d: &Decision) -> Result<()> {
    let line = idx + 1;
    if !op.valid() {
        bail!("line {line}: invalid op {op:?}");
    }
    if let Epilogue::MaxPoolWriteback { k, stride } = ep {
        if k == 0 || stride == 0 || op.oy() < k || op.ox() < k {
            bail!("line {line}: pool{k}s{stride} does not fit {}x{}", op.oy(), op.ox());
        }
    }
    if !BACKEND_NAMES.contains(&d.backend.as_str()) {
        bail!("line {line}: unknown backend {:?}", d.backend);
    }
    let registry = backend::dispatch::registry();
    let b = registry.backend(&d.backend).expect("name checked against BACKEND_NAMES");
    if !b.op_coverage(op).supported() {
        bail!("line {line}: backend {} does not cover {}", d.backend, op.label());
    }
    if !(d.cycles.is_finite() && d.cycles > 0.0 && d.tuned_cycles.is_finite()) {
        bail!("line {line}: non-finite dispatch cycle counts");
    }
    if d.cycles > d.tuned_cycles * (1.0 + 1e-9) {
        bail!("line {line}: dispatched cycles exceed the paper-tuned floor — stale entry");
    }
    Ok(())
}

/// Serializable map of tuning outcomes keyed by `(problem, GPU name)`,
/// plus the backend layer's dispatch decisions keyed by the full
/// `(ConvOp, GPU name)` — v3 keys carry stride/pad/groups, with dense
/// ops serializing exactly like the historical v2 problem keys plus
/// explicit dense fields.
#[derive(Clone, Debug, Default)]
pub struct PlanCache {
    entries: HashMap<(ConvProblem, String), Tuned>,
    /// v6 op-native tuning results keyed by `(op, epilogue, batch, gpu)`
    /// — a separate map so the unit slice can never serve op-objective
    /// params (or vice versa) through a key collision.
    op_entries: HashMap<(ConvOp, Epilogue, usize, String), Tuned>,
    dispatch: HashMap<(ConvOp, Epilogue, usize, String), Decision>,
    /// Stale entries dropped on parse — pre-v4 plan lines (missing
    /// `stages=`/`loading=`) and pre-v5 lines of either kind (missing
    /// `epilogue=`): counted so callers can report "N stale entries
    /// re-tuned" instead of silently serving pre-fusion decisions.
    stale_dropped: usize,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Plan (tuning) entries only — dispatch entries are counted by
    /// `dispatch_len` (callers that report "N cached plans" keep their
    /// historical meaning).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn dispatch_len(&self) -> usize {
        self.dispatch.len()
    }

    /// v6 op-keyed tuning entries only.
    pub fn op_len(&self) -> usize {
        self.op_entries.len()
    }

    /// How many pre-v5 (or pre-v4) lines the last `from_lines` dropped.
    pub fn stale_dropped(&self) -> usize {
        self.stale_dropped
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.op_entries.is_empty() && self.dispatch.is_empty()
    }

    pub fn get(&self, p: &ConvProblem, spec: &GpuSpec) -> Option<Tuned> {
        self.entries.get(&(*p, spec.name.to_string())).copied()
    }

    pub fn insert(&mut self, p: ConvProblem, spec: &GpuSpec, t: Tuned) {
        self.entries.insert((p, spec.name.to_string()), t);
    }

    /// Op-native tuning lookup on the v6 key `(op, epilogue, batch, gpu)`.
    pub fn get_op(&self, op: &ConvOp, ep: Epilogue, n: usize, spec: &GpuSpec) -> Option<Tuned> {
        self.op_entries.get(&(*op, ep, n, spec.name.to_string())).copied()
    }

    pub fn insert_op(&mut self, op: ConvOp, ep: Epilogue, n: usize, spec: &GpuSpec, t: Tuned) {
        self.op_entries.insert((op, ep, n, spec.name.to_string()), t);
    }

    pub fn get_dispatch(&self, op: &ConvOp, spec: &GpuSpec) -> Option<Decision> {
        self.get_dispatch_fused(op, Epilogue::None, spec)
    }

    pub fn insert_dispatch(&mut self, op: ConvOp, spec: &GpuSpec, d: Decision) {
        self.insert_dispatch_fused(op, Epilogue::None, spec, d);
    }

    /// Dispatch lookup on the v5 key `(op, epilogue, gpu)` — the
    /// unfused decisions are exactly the `Epilogue::None` slice, and
    /// single-image decisions are exactly the `n = 1` slice of v6.
    pub fn get_dispatch_fused(&self, op: &ConvOp, ep: Epilogue, spec: &GpuSpec) -> Option<Decision> {
        self.get_dispatch_batched(op, ep, 1, spec)
    }

    pub fn insert_dispatch_fused(&mut self, op: ConvOp, ep: Epilogue, spec: &GpuSpec, d: Decision) {
        self.insert_dispatch_batched(op, ep, 1, spec, d);
    }

    /// Dispatch lookup on the full v6 key `(op, epilogue, batch, gpu)`.
    pub fn get_dispatch_batched(
        &self,
        op: &ConvOp,
        ep: Epilogue,
        n: usize,
        spec: &GpuSpec,
    ) -> Option<Decision> {
        self.dispatch.get(&(*op, ep, n, spec.name.to_string())).cloned()
    }

    pub fn insert_dispatch_batched(
        &mut self,
        op: ConvOp,
        ep: Epilogue,
        n: usize,
        spec: &GpuSpec,
        d: Decision,
    ) {
        self.dispatch.insert((op, ep, n, spec.name.to_string()), d);
    }

    /// Absorb every entry of `other` (overwriting duplicates), whatever
    /// GPU name it carries; returns how many entries were absorbed
    /// (plan + dispatch).
    pub fn merge(&mut self, other: PlanCache) -> usize {
        let n = other.entries.len() + other.op_entries.len() + other.dispatch.len();
        self.entries.extend(other.entries);
        self.op_entries.extend(other.op_entries);
        self.dispatch.extend(other.dispatch);
        self.stale_dropped += other.stale_dropped;
        n
    }

    /// One line per entry, deterministically ordered (diff-stable
    /// files): unit plan entries first (byte-identical to their v5
    /// serialization), then op-keyed plan entries, then dispatch.
    pub fn to_lines(&self) -> String {
        fn params_str(params: &PlanParams) -> String {
            match *params {
                PlanParams::Single { method, p: pp, q, stages, loading } => {
                    let m = match method {
                        SingleMethod::FilterSplit => "filter_split",
                        SingleMethod::MapSplit => "map_split",
                    };
                    format!(
                        "kind=single method={m} p={pp} q={q} stages={stages} loading={}",
                        loading.name()
                    )
                }
                PlanParams::Multi { s_bytes, wx_prime, m_prime, stages, loading } => {
                    format!(
                        "kind=multi s={s_bytes} wxp={wx_prime} mp={m_prime} stages={stages} loading={}",
                        loading.name()
                    )
                }
            }
        }
        let mut keys: Vec<&(ConvProblem, String)> = self.entries.keys().collect();
        keys.sort_by_key(|(p, g)| (g.clone(), p.c, p.wy, p.wx, p.m, p.k));
        let mut out = String::from(
            "# pasconv plan cache v6: problem/op + gpu -> tuned plan params / fused op dispatch decisions\n",
        );
        for key in keys {
            let (p, gpu) = key;
            let t = &self.entries[key];
            out.push_str(&format!(
                "gpu={} c={} wy={} wx={} m={} k={} {} epilogue=none tuned_cycles={} paper_cycles={}\n",
                encode_gpu(gpu),
                p.c,
                p.wy,
                p.wx,
                p.m,
                p.k,
                params_str(&t.params),
                t.tuned_cycles,
                t.paper_cycles
            ));
        }
        let mut okeys: Vec<&(ConvOp, Epilogue, usize, String)> = self.op_entries.keys().collect();
        okeys.sort_by_key(|(o, e, n, g)| {
            let p = o.core;
            (g.clone(), p.c, p.wy, p.wx, p.m, p.k, o.stride, o.pad, o.groups, e.tag(), *n)
        });
        for key in okeys {
            let (o, ep, n, gpu) = key;
            let p = o.core;
            let t = &self.op_entries[key];
            out.push_str(&format!(
                "gpu={} c={} wy={} wx={} m={} k={} stride={} pad={} groups={} n={} {} epilogue={} tuned_cycles={} paper_cycles={}\n",
                encode_gpu(gpu),
                p.c,
                p.wy,
                p.wx,
                p.m,
                p.k,
                o.stride,
                o.pad,
                o.groups,
                n,
                params_str(&t.params),
                ep.tag(),
                t.tuned_cycles,
                t.paper_cycles
            ));
        }
        let mut dkeys: Vec<&(ConvOp, Epilogue, usize, String)> = self.dispatch.keys().collect();
        dkeys.sort_by_key(|(o, e, n, g)| {
            let p = o.core;
            (g.clone(), p.c, p.wy, p.wx, p.m, p.k, o.stride, o.pad, o.groups, e.tag(), *n)
        });
        for key in dkeys {
            let (o, ep, n, gpu) = key;
            let p = o.core;
            let d = &self.dispatch[key];
            // n=1 serializes without the field so v5 files round-trip
            // byte-identically (below the bumped header)
            let batch = if *n > 1 { format!(" n={n}") } else { String::new() };
            out.push_str(&format!(
                "gpu={} c={} wy={} wx={} m={} k={} stride={} pad={} groups={}{} epilogue={} kind=dispatch backend={} cycles={} tuned_cycles={}\n",
                encode_gpu(gpu),
                p.c,
                p.wy,
                p.wx,
                p.m,
                p.k,
                o.stride,
                o.pad,
                o.groups,
                batch,
                ep.tag(),
                d.backend,
                d.cycles,
                d.tuned_cycles
            ));
        }
        out
    }

    /// Parse the `to_lines` format (round-trip exact, floats included).
    pub fn from_lines(text: &str) -> Result<PlanCache> {
        let mut cache = PlanCache::new();
        for (idx, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields: HashMap<&str, &str> = HashMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .ok_or_else(|| anyhow!("line {}: malformed token {tok:?}", idx + 1))?;
                fields.insert(k, v);
            }
            let problem = ConvProblem {
                c: usize_field(&fields, idx, "c")?,
                wy: usize_field(&fields, idx, "wy")?,
                wx: usize_field(&fields, idx, "wx")?,
                m: usize_field(&fields, idx, "m")?,
                k: usize_field(&fields, idx, "k")?,
            };
            let (params, ep) = match field(&fields, idx, "kind")? {
                // dispatch entry: backend tag + cycle pair; op fields
                // optional (v1/v2 lines are dense)
                "dispatch" => {
                    let op = ConvOp {
                        core: problem,
                        stride: usize_field_or(&fields, idx, "stride", 1)?,
                        pad: usize_field_or(&fields, idx, "pad", 0)?,
                        groups: usize_field_or(&fields, idx, "groups", 1)?,
                    };
                    // v5 fused axis: REQUIRED — a pre-v5 decision was
                    // ranked without the epilogue in the key, so it is
                    // dropped (and counted), never defaulted to
                    // `epilogue=none` and served
                    let ep = match fields.get("epilogue") {
                        None => {
                            cache.stale_dropped += 1;
                            continue;
                        }
                        Some(e) => Epilogue::parse(e)
                            .ok_or_else(|| anyhow!("line {}: unknown epilogue {e:?}", idx + 1))?,
                    };
                    // v6 batch field: OPTIONAL, defaulting to 1 — a v5
                    // decision is exactly a single-image decision, so
                    // unlike the epilogue axis there is nothing stale
                    // about serving it on the n=1 slice
                    let n = usize_field_or(&fields, idx, "n", 1)?;
                    if n == 0 {
                        bail!("line {}: batch n must be >= 1", idx + 1);
                    }
                    let d = Decision {
                        backend: field(&fields, idx, "backend")?.to_string(),
                        cycles: f64_field(&fields, idx, "cycles")?,
                        tuned_cycles: f64_field(&fields, idx, "tuned_cycles")?,
                    };
                    validate_dispatch(idx, &op, ep, &d)?;
                    let gpu = decode_gpu(field(&fields, idx, "gpu")?);
                    cache.dispatch.insert((op, ep, n, gpu), d);
                    continue;
                }
                kind @ ("single" | "multi") => {
                    // v4 plan axes + the v5 epilogue marker: REQUIRED —
                    // a pre-v4/pre-v5 entry was tuned over a different
                    // plan space, so it is dropped (and counted), never
                    // defaulted and served
                    if !fields.contains_key("stages")
                        || !fields.contains_key("loading")
                        || !fields.contains_key("epilogue")
                    {
                        cache.stale_dropped += 1;
                        continue;
                    }
                    // unit plan entries are epilogue-blind by design
                    // (unit plans are tuned at `none`; fusion transforms
                    // the tuned plan) — any other value is corruption.
                    // v6 op-keyed entries (the `n=` marker) were tuned
                    // UNDER the fused objective, so they carry real tags.
                    let e = fields["epilogue"];
                    let ep = match Epilogue::parse(e) {
                        Some(ep) => ep,
                        None => bail!("line {}: unknown epilogue {e:?}", idx + 1),
                    };
                    if !fields.contains_key("n") && ep != Epilogue::None {
                        bail!(
                            "line {}: unit plan entries are tuned at epilogue=none; got {e:?}",
                            idx + 1
                        );
                    }
                    let stages = usize_field(&fields, idx, "stages")? as u32;
                    let loading_name = field(&fields, idx, "loading")?;
                    let loading = Loading::parse(loading_name).ok_or_else(|| {
                        anyhow!("line {}: unknown loading {loading_name:?}", idx + 1)
                    })?;
                    let params = if kind == "single" {
                        PlanParams::Single {
                            method: match field(&fields, idx, "method")? {
                                "filter_split" => SingleMethod::FilterSplit,
                                "map_split" => SingleMethod::MapSplit,
                                other => bail!("line {}: unknown method {other:?}", idx + 1),
                            },
                            p: usize_field(&fields, idx, "p")?,
                            q: usize_field(&fields, idx, "q")?,
                            stages,
                            loading,
                        }
                    } else {
                        PlanParams::Multi {
                            s_bytes: usize_field(&fields, idx, "s")?,
                            wx_prime: usize_field(&fields, idx, "wxp")?,
                            m_prime: usize_field(&fields, idx, "mp")?,
                            stages,
                            loading,
                        }
                    };
                    (params, ep)
                }
                other => bail!("line {}: unknown kind {other:?}", idx + 1),
            };
            let tuned = Tuned {
                params,
                tuned_cycles: f64_field(&fields, idx, "tuned_cycles")?,
                paper_cycles: f64_field(&fields, idx, "paper_cycles")?,
            };
            let gpu = decode_gpu(field(&fields, idx, "gpu")?);
            if fields.contains_key("n") {
                // v6 op-keyed entry: the op fields + batch join the key
                let n = usize_field(&fields, idx, "n")?;
                let op = ConvOp {
                    core: problem,
                    stride: usize_field_or(&fields, idx, "stride", 1)?,
                    pad: usize_field_or(&fields, idx, "pad", 0)?,
                    groups: usize_field_or(&fields, idx, "groups", 1)?,
                };
                validate_op_entry(idx, &op, ep, n, &gpu, &tuned)?;
                cache.op_entries.insert((op, ep, n, gpu), tuned);
            } else {
                validate_entry(idx, &problem, &gpu, &tuned)?;
                cache.entries.insert((problem, gpu), tuned);
            }
        }
        Ok(cache)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_lines())
            .with_context(|| format!("writing plan cache {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<PlanCache> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan cache {}", path.display()))?;
        PlanCache::from_lines(&text)
    }

    /// All op-keyed entries for one GPU, in the deterministic file
    /// order (the coordinator's warm-up and the `tune --ops` CLI both
    /// iterate this).
    pub fn op_entries_for(&self, spec: &GpuSpec) -> Vec<(ConvOp, Epilogue, usize, Tuned)> {
        let mut out: Vec<(ConvOp, Epilogue, usize, Tuned)> = self
            .op_entries
            .iter()
            .filter(|((_, _, _, g), _)| g == spec.name)
            .map(|((o, e, n, _), t)| (*o, *e, *n, *t))
            .collect();
        out.sort_by_key(|(o, e, n, _)| {
            let p = o.core;
            (p.c, p.wy, p.wx, p.m, p.k, o.stride, o.pad, o.groups, e.tag(), *n)
        });
        out
    }

    /// All entries for one GPU, in the deterministic file order.
    pub fn entries_for(&self, spec: &GpuSpec) -> Vec<(ConvProblem, Tuned)> {
        let mut out: Vec<(ConvProblem, Tuned)> = self
            .entries
            .iter()
            .filter(|((_, g), _)| g == spec.name)
            .map(|((p, _), t)| (*p, *t))
            .collect();
        out.sort_by_key(|(p, _)| (p.c, p.wy, p.wx, p.m, p.k));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{gtx_1080ti, titan_x_maxwell};

    fn sample() -> PlanCache {
        let g = gtx_1080ti();
        let t = titan_x_maxwell();
        let mut cache = PlanCache::new();
        cache.insert(
            ConvProblem::single(224, 64, 3),
            &g,
            Tuned {
                params: PlanParams::Single {
                    method: SingleMethod::FilterSplit,
                    p: 3,
                    q: 1,
                    stages: 3,
                    loading: Loading::Cyclic,
                },
                tuned_cycles: 10_234.5625,
                paper_cycles: 11_000.125,
            },
        );
        cache.insert(
            ConvProblem::multi(256, 14, 256, 3),
            &g,
            Tuned {
                params: PlanParams::Multi {
                    s_bytes: 128,
                    wx_prime: 32,
                    m_prime: 64,
                    stages: 2,
                    loading: Loading::Tilewise,
                },
                tuned_cycles: 25_000.0,
                paper_cycles: 30_303.030_303_030_303,
            },
        );
        cache.insert(
            ConvProblem::multi(64, 28, 128, 1),
            &t,
            Tuned {
                params: PlanParams::Multi {
                    s_bytes: 64,
                    wx_prime: 32,
                    m_prime: 128,
                    stages: 4,
                    loading: Loading::Ordered,
                },
                tuned_cycles: 5_813.77,
                paper_cycles: 6_900.01,
            },
        );
        cache
    }

    #[test]
    fn round_trip_is_exact() {
        let cache = sample();
        let text = cache.to_lines();
        let back = PlanCache::from_lines(&text).unwrap();
        assert_eq!(back.len(), cache.len());
        let g = gtx_1080ti();
        let t = titan_x_maxwell();
        for spec in [&g, &t] {
            for (p, tuned) in cache.entries_for(spec) {
                let got = back.get(&p, spec).unwrap();
                assert_eq!(got, tuned, "{} on {}", p.label(), spec.name);
            }
        }
        // and the serialized form itself is a fixed point
        assert_eq!(back.to_lines(), text);
    }

    #[test]
    fn file_round_trip() {
        let cache = sample();
        let dir = std::env::temp_dir().join("pasconv_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan_cache.txt");
        cache.save(&path).unwrap();
        let back = PlanCache::load(&path).unwrap();
        assert_eq!(back.len(), cache.len());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn gpu_names_with_spaces_round_trip() {
        let cache = sample();
        let text = cache.to_lines();
        assert!(text.contains("gpu=GTX_1080Ti"), "{text}");
        let back = PlanCache::from_lines(&text).unwrap();
        assert!(back.get(&ConvProblem::single(224, 64, 3), &gtx_1080ti()).is_some());
    }

    #[test]
    fn malformed_lines_are_errors() {
        assert!(PlanCache::from_lines("gpu=x c=1").is_err()); // missing fields
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=1 k=1 kind=wat tuned_cycles=1 paper_cycles=1"
        )
        .is_err());
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=1 k=1 kind=single method=nope p=1 q=1 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=1"
        )
        .is_err());
        // present-but-garbage v4/v5 axes are corruption, not staleness
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=4 k=1 kind=single method=filter_split p=1 q=1 stages=2 loading=warp_magic epilogue=none tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=4 k=1 kind=single method=filter_split p=1 q=1 stages=9 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        // a plan entry claiming a fused epilogue is corruption too: the
        // tuner searches unit plans at epilogue=none only
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=4 k=1 kind=single method=filter_split p=1 q=1 stages=2 loading=cyclic epilogue=relu tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=4 k=1 kind=single method=filter_split p=1 q=1 stages=2 loading=cyclic epilogue=blur3 tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        // comments and blanks are fine
        assert!(PlanCache::from_lines("# header\n\n").unwrap().is_empty());
    }

    #[test]
    fn stale_or_edited_entries_are_rejected_not_trusted() {
        // tuned slower than paper: would trip the never-lose asserts
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=4 k=1 kind=single method=filter_split p=1 q=1 stages=2 loading=cyclic epilogue=none tuned_cycles=2 paper_cycles=1"
        )
        .is_err());
        // invalid problem (K > W)
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=2 wx=2 m=4 k=3 kind=single method=filter_split p=1 q=1 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=1"
        )
        .is_err());
        // P out of range
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=8 wx=8 m=4 k=1 kind=single method=filter_split p=99 q=1 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=1"
        )
        .is_err());
        // non-coalesced segment size
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=8 wx=8 m=4 k=3 kind=multi s=36 wxp=32 mp=4 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=1"
        )
        .is_err());
        // working set beyond the named GPU's double-buffer budget
        assert!(PlanCache::from_lines(
            "gpu=GTX_1080Ti c=8 wy=64 wx=64 m=512 k=3 kind=multi s=128 wxp=256 mp=512 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=1"
        )
        .is_err());
        // a 4-stage working set can overflow where the depth-2 one fits
        assert!(PlanCache::from_lines(
            "gpu=GTX_1080Ti c=8 wy=64 wx=64 m=512 k=3 kind=multi s=128 wxp=128 mp=64 stages=4 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=1"
        )
        .is_err());
        // kind must match the problem's channel count (a single-channel
        // plan for C>1 would panic the builder on lookup)
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 kind=single method=filter_split p=1 q=1 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        assert!(PlanCache::from_lines(
            "gpu=G c=1 wy=14 wx=14 m=16 k=3 kind=multi s=32 wxp=32 mp=16 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
    }

    #[test]
    fn pre_v4_plan_entries_are_dropped_and_counted_not_served() {
        // exactly what a v3 `tune --save` produced: plan lines without
        // stages=/loading=, dispatch lines without epilogue=.  Serving
        // any of them would resurrect decisions made over a smaller
        // plan space than v5's builders and ranking reproduce.
        let v3 = "# pasconv plan cache v3: problem + gpu -> tuned plan params / op dispatch decisions\n\
            gpu=GTX_1080Ti c=1 wy=224 wx=224 m=64 k=3 kind=single method=filter_split \
            p=3 q=1 tuned_cycles=10234.5625 paper_cycles=11000.125\n\
            gpu=GTX_1080Ti c=256 wy=14 wx=14 m=256 k=3 kind=multi s=128 wxp=32 mp=64 \
            tuned_cycles=25000 paper_cycles=30303\n\
            gpu=G c=8 wy=14 wx=14 m=16 k=3 kind=dispatch backend=winograd \
            cycles=1 tuned_cycles=2\n";
        let cache = PlanCache::from_lines(v3).unwrap();
        assert_eq!(cache.len(), 0, "stale plan entries must not be served");
        assert_eq!(cache.dispatch_len(), 0, "pre-v5 dispatch entries must not be served");
        assert_eq!(cache.stale_dropped(), 3);
        assert!(cache.get(&ConvProblem::single(224, 64, 3), &gtx_1080ti()).is_none());
    }

    #[test]
    fn v4_files_load_with_epilogue_defaulted_rejected() {
        // the v5 migration gate: a genuine v4 file — plan lines WITH
        // stages=/loading= but no epilogue=, dispatch lines without
        // epilogue= — loads without error, but nothing is served with a
        // defaulted `epilogue=none`: every pre-v5 line is dropped and
        // counted, and a fresh save round-trips as v5.
        let v4 = "# pasconv plan cache v4: problem + gpu -> tuned plan params / op dispatch decisions\n\
            gpu=GTX_1080Ti c=1 wy=224 wx=224 m=64 k=3 kind=single method=filter_split \
            p=3 q=1 stages=3 loading=cyclic tuned_cycles=10234.5625 paper_cycles=11000.125\n\
            gpu=GTX_1080Ti c=256 wy=14 wx=14 m=256 k=3 kind=multi s=128 wxp=32 mp=64 \
            stages=2 loading=tilewise tuned_cycles=25000 paper_cycles=30303\n\
            gpu=G c=8 wy=14 wx=14 m=16 k=3 stride=1 pad=0 groups=1 kind=dispatch \
            backend=winograd cycles=1 tuned_cycles=2\n";
        let mut cache = PlanCache::from_lines(v4).unwrap();
        assert_eq!((cache.len(), cache.dispatch_len()), (0, 0));
        assert_eq!(cache.stale_dropped(), 3);
        // re-decide the dropped key and save: the new file is v6
        let g = gtx_1080ti();
        let op = ConvOp::same(ConvProblem::multi(64, 28, 64, 3));
        cache.insert_dispatch_fused(
            op,
            Epilogue::MaxPoolWriteback { k: 2, stride: 2 },
            &g,
            Decision { backend: "winograd".into(), cycles: 8_000.5, tuned_cycles: 9_000.0 },
        );
        let text = cache.to_lines();
        assert!(text.starts_with("# pasconv plan cache v6"), "{text}");
        assert!(text.contains("epilogue=pool2s2"), "{text}");
        let back = PlanCache::from_lines(&text).unwrap();
        assert_eq!(back.stale_dropped(), 0);
        let d = back
            .get_dispatch_fused(&op, Epilogue::MaxPoolWriteback { k: 2, stride: 2 }, &g)
            .unwrap();
        assert_eq!(d.backend, "winograd");
        // the None slice stays distinct: no entry bleeds across epilogues
        assert!(back.get_dispatch(&op, &g).is_none());
        assert_eq!(back.to_lines(), text);
    }

    #[test]
    fn v3_loads_then_a_fresh_save_round_trips_as_v6() {
        // the upgrade path: load a v3 file (plans dropped), re-tune the
        // dropped key, save — the new file is v6 and round-trips exactly
        let v3 = "gpu=GTX_1080Ti c=1 wy=224 wx=224 m=64 k=3 kind=single \
            method=filter_split p=3 q=1 tuned_cycles=10234.5625 paper_cycles=11000.125\n";
        let mut cache = PlanCache::from_lines(v3).unwrap();
        assert_eq!((cache.len(), cache.stale_dropped()), (0, 1));
        let g = gtx_1080ti();
        cache.insert(
            ConvProblem::single(224, 64, 3),
            &g,
            Tuned {
                params: PlanParams::Single {
                    method: SingleMethod::FilterSplit,
                    p: 3,
                    q: 1,
                    stages: 4,
                    loading: Loading::Ordered,
                },
                tuned_cycles: 9_500.25,
                paper_cycles: 11_000.125,
            },
        );
        let text = cache.to_lines();
        assert!(text.starts_with("# pasconv plan cache v6"), "{text}");
        assert!(text.contains("stages=4 loading=ordered epilogue=none"), "{text}");
        let back = PlanCache::from_lines(&text).unwrap();
        assert_eq!(back.stale_dropped(), 0);
        let t = back.get(&ConvProblem::single(224, 64, 3), &g).unwrap();
        assert_eq!(t.params.staging(), (4, Loading::Ordered));
        assert_eq!(back.to_lines(), text);
    }

    #[test]
    fn v5_entries_load_unit_keyed_and_resave_byte_identically() {
        // a genuine v5 file: unit plan lines without stride/pad/groups/n,
        // dispatch lines without n — every entry loads (nothing is
        // stale), plans serve on the unit key, and a re-save reproduces
        // the body byte-for-byte below the bumped header
        let v5_body = "gpu=GTX_1080Ti c=1 wy=224 wx=224 m=64 k=3 kind=single method=filter_split p=3 q=1 stages=3 loading=cyclic epilogue=none tuned_cycles=10234.5625 paper_cycles=11000.125\n\
gpu=GTX_1080Ti c=256 wy=14 wx=14 m=256 k=3 kind=multi s=128 wxp=32 mp=64 stages=2 loading=tilewise epilogue=none tuned_cycles=25000 paper_cycles=30303.030303030303\n\
gpu=G c=8 wy=14 wx=14 m=16 k=3 stride=1 pad=0 groups=1 epilogue=pool2s2 kind=dispatch backend=winograd cycles=1 tuned_cycles=2\n";
        let v5 = format!(
            "# pasconv plan cache v5: problem + gpu -> tuned plan params / fused op dispatch decisions\n{v5_body}"
        );
        let cache = PlanCache::from_lines(&v5).unwrap();
        assert_eq!(
            (cache.len(), cache.op_len(), cache.dispatch_len(), cache.stale_dropped()),
            (2, 0, 1, 0)
        );
        assert!(cache.get(&ConvProblem::single(224, 64, 3), &gtx_1080ti()).is_some());
        let text = cache.to_lines();
        assert!(text.starts_with("# pasconv plan cache v6"), "{text}");
        let body: String = text.lines().skip(1).map(|l| format!("{l}\n")).collect();
        assert_eq!(body, v5_body, "v5 entries must re-save byte-identically");
    }

    #[test]
    fn op_entries_round_trip_on_the_batched_key() {
        let g = gtx_1080ti();
        let mut cache = sample();
        let op = ConvOp::pointwise(512, 14, 512);
        let t = Tuned {
            params: PlanParams::Multi {
                s_bytes: 64,
                wx_prime: 32,
                m_prime: 32,
                stages: 2,
                loading: Loading::Cyclic,
            },
            tuned_cycles: 40_000.5,
            paper_cycles: 61_000.25,
        };
        cache.insert_op(op, Epilogue::None, 16, &g, t);
        // the same op at a different epilogue and batch: distinct keys
        cache.insert_op(op, Epilogue::Relu, 16, &g, Tuned { tuned_cycles: 41_000.0, ..t });
        let dw = ConvOp::depthwise(32, 28, 3, 1);
        cache.insert_op(
            dw,
            Epilogue::None,
            4,
            &g,
            Tuned {
                params: PlanParams::Single {
                    method: SingleMethod::FilterSplit,
                    p: 2,
                    q: 1,
                    stages: 2,
                    loading: Loading::Cyclic,
                },
                tuned_cycles: 9_000.0,
                paper_cycles: 9_500.0,
            },
        );
        let text = cache.to_lines();
        assert!(text.contains(" n=16 "), "{text}");
        assert!(text.contains(" n=4 "), "{text}");
        assert!(text.contains("epilogue=relu"), "{text}");
        assert!(text.contains("groups=32"), "{text}");
        let back = PlanCache::from_lines(&text).unwrap();
        assert_eq!((back.op_len(), back.stale_dropped()), (3, 0));
        assert_eq!(back.get_op(&op, Epilogue::None, 16, &g).unwrap(), t);
        // the op key never bleeds into the unit slice or other (ep, n)
        assert!(back.get_op(&op, Epilogue::None, 1, &g).is_none());
        assert!(back.get(&op.core, &g).is_none());
        assert_eq!(back.len(), cache.len(), "unit entries survive alongside");
        assert_eq!(back.op_entries_for(&g).len(), 3);
        assert_eq!(back.to_lines(), text, "fixed point");
    }

    #[test]
    fn bad_op_entry_fields_hard_error_not_drop() {
        // n=0 is corruption
        assert!(PlanCache::from_lines(
            "gpu=G c=512 wy=14 wx=14 m=512 k=1 stride=1 pad=0 groups=1 n=0 kind=multi \
             s=64 wxp=32 mp=32 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        // garbage batch field
        assert!(PlanCache::from_lines(
            "gpu=G c=512 wy=14 wx=14 m=512 k=1 stride=1 pad=0 groups=1 n=lots kind=multi \
             s=64 wxp=32 mp=32 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        // unknown epilogue tag on an op line
        assert!(PlanCache::from_lines(
            "gpu=G c=512 wy=14 wx=14 m=512 k=1 stride=1 pad=0 groups=1 n=16 kind=multi \
             s=64 wxp=32 mp=32 stages=2 loading=cyclic epilogue=blur3 tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        // a pool epilogue that does not fit the op's 14x14 output
        assert!(PlanCache::from_lines(
            "gpu=G c=512 wy=14 wx=14 m=512 k=1 stride=1 pad=0 groups=1 n=16 kind=multi \
             s=64 wxp=32 mp=32 stages=2 loading=cyclic epilogue=pool16s16 tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        // tuned above the inherited floor: stale or edited
        assert!(PlanCache::from_lines(
            "gpu=G c=512 wy=14 wx=14 m=512 k=1 stride=1 pad=0 groups=1 n=16 kind=multi \
             s=64 wxp=32 mp=32 stages=2 loading=cyclic epilogue=none tuned_cycles=3 paper_cycles=2"
        )
        .is_err());
        // params kind must match the op's LOWERED unit (groups=1 keeps
        // C=8 multi-channel, so kind=single is corruption)
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 stride=1 pad=0 groups=1 n=2 kind=single \
             method=filter_split p=1 q=1 stages=2 loading=cyclic epilogue=none tuned_cycles=1 paper_cycles=2"
        )
        .is_err());
        // a well-formed fused op entry parses and serves on its key
        let ok = PlanCache::from_lines(
            "gpu=G c=512 wy=14 wx=14 m=512 k=1 stride=1 pad=0 groups=1 n=16 kind=multi \
             s=64 wxp=32 mp=32 stages=2 loading=cyclic epilogue=relu tuned_cycles=1 paper_cycles=2"
        )
        .unwrap();
        assert_eq!((ok.op_len(), ok.stale_dropped()), (1, 0));
        let spec = GpuSpec { name: "G", ..gtx_1080ti() };
        assert!(ok
            .get_op(&ConvOp::pointwise(512, 14, 512), Epilogue::Relu, 16, &spec)
            .is_some());
    }

    #[test]
    fn batched_dispatch_entries_round_trip_and_default_to_n1() {
        let g = gtx_1080ti();
        let mut cache = PlanCache::new();
        let op = ConvOp::dense(ConvProblem::multi(256, 14, 256, 1));
        cache.insert_dispatch(
            op,
            &g,
            Decision { backend: "paper-tuned".into(), cycles: 5_000.0, tuned_cycles: 5_000.0 },
        );
        cache.insert_dispatch_batched(
            op,
            Epilogue::None,
            16,
            &g,
            Decision { backend: "paper-tuned".into(), cycles: 61_000.0, tuned_cycles: 80_000.0 },
        );
        let text = cache.to_lines();
        // only the batched decision serializes the n= field
        assert_eq!(text.matches(" n=16").count(), 1, "{text}");
        assert_eq!(text.matches("kind=dispatch").count(), 2, "{text}");
        let back = PlanCache::from_lines(&text).unwrap();
        assert_eq!(back.dispatch_len(), 2);
        let d = back.get_dispatch_batched(&op, Epilogue::None, 16, &g).unwrap();
        assert!((d.tuned_cycles - 80_000.0).abs() == 0.0);
        assert!(back.get_dispatch_batched(&op, Epilogue::None, 4, &g).is_none());
        // the n=1 slice is exactly the historical fused key
        assert!(back.get_dispatch(&op, &g).is_some());
        assert_eq!(back.to_lines(), text);
        // garbage batch fields on dispatch lines are corruption too
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 n=zero epilogue=none kind=dispatch \
             backend=winograd cycles=1 tuned_cycles=2"
        )
        .is_err());
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 n=0 epilogue=none kind=dispatch \
             backend=winograd cycles=1 tuned_cycles=2"
        )
        .is_err());
    }

    #[test]
    fn dispatch_entries_round_trip_and_v1_files_parse() {
        let g = gtx_1080ti();
        let mut cache = sample();
        cache.insert_dispatch(
            ConvOp::dense(ConvProblem::multi(256, 56, 256, 3)),
            &g,
            Decision { backend: "winograd".into(), cycles: 9_000.0, tuned_cycles: 12_000.5 },
        );
        cache.insert_dispatch(
            ConvOp::dense(ConvProblem::multi(256, 14, 256, 1)),
            &g,
            Decision { backend: "paper-tuned".into(), cycles: 5_000.0, tuned_cycles: 5_000.0 },
        );
        // a real op key: ResNet-18's stride-2 downsampling conv
        cache.insert_dispatch(
            ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1),
            &g,
            Decision { backend: "paper-tuned".into(), cycles: 7_000.25, tuned_cycles: 9_100.0 },
        );
        // a fused decision for the SAME op as an unfused one: distinct key
        cache.insert_dispatch_fused(
            ConvOp::dense(ConvProblem::multi(256, 56, 256, 3)),
            Epilogue::MaxPoolWriteback { k: 2, stride: 2 },
            &g,
            Decision { backend: "winograd".into(), cycles: 7_800.0, tuned_cycles: 11_500.0 },
        );
        let text = cache.to_lines();
        assert!(text.contains("kind=dispatch backend=winograd"), "{text}");
        assert!(text.contains("stride=2 pad=1 groups=1"), "{text}");
        assert!(text.contains("epilogue=none"), "{text}");
        assert!(text.contains("epilogue=pool2s2"), "{text}");
        let back = PlanCache::from_lines(&text).unwrap();
        assert_eq!(back.dispatch_len(), 4);
        assert_eq!(back.len(), cache.len(), "plan entries survive alongside");
        let d = back
            .get_dispatch(&ConvOp::dense(ConvProblem::multi(256, 56, 256, 3)), &g)
            .unwrap();
        assert_eq!(d.backend, "winograd");
        assert!((d.tuned_cycles - 12_000.5).abs() == 0.0, "float round-trip exact");
        let s2 = back
            .get_dispatch(&ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1), &g)
            .unwrap();
        assert!((s2.cycles - 7_000.25).abs() == 0.0);
        let fused = back
            .get_dispatch_fused(
                &ConvOp::dense(ConvProblem::multi(256, 56, 256, 3)),
                Epilogue::MaxPoolWriteback { k: 2, stride: 2 },
                &g,
            )
            .unwrap();
        assert_eq!(fused.backend, "winograd");
        // the serialized form is a fixed point
        assert_eq!(back.to_lines(), text);
    }

    #[test]
    fn pre_v5_dispatch_lines_are_dropped_not_defaulted() {
        // exactly what a v2..v4 `tune --save` produced: no epilogue=.
        // Defaulting to epilogue=none would serve a decision ranked
        // without the fused axis — dropped and counted instead.
        let v2 = "gpu=G c=8 wy=14 wx=14 m=16 k=3 kind=dispatch backend=winograd \
                  cycles=1 tuned_cycles=2\n";
        let cache = PlanCache::from_lines(v2).unwrap();
        assert_eq!(cache.dispatch_len(), 0);
        assert_eq!(cache.stale_dropped(), 1);
        let op = ConvOp::dense(ConvProblem::multi(8, 14, 16, 3));
        assert!(cache.get_dispatch(&op, &GpuSpec { name: "G", ..gtx_1080ti() }).is_none());
    }

    #[test]
    fn v1_files_still_load_but_their_plans_are_not_served() {
        // exactly what a pre-v2 `tune --save` produced: old header
        // comment, plan lines only — loading must not error (the
        // coordinator keeps starting), but the pre-v4 plan is dropped
        let v1 = "# pasconv plan cache: problem + gpu -> tuned plan params\n\
            gpu=GTX_1080Ti c=1 wy=224 wx=224 m=64 k=3 kind=single method=filter_split \
            p=3 q=1 tuned_cycles=10234.5625 paper_cycles=11000.125\n";
        let cache = PlanCache::from_lines(v1).unwrap();
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.stale_dropped(), 1);
        assert_eq!(cache.dispatch_len(), 0);
        assert!(cache.get(&ConvProblem::single(224, 64, 3), &gtx_1080ti()).is_none());
    }

    #[test]
    fn bad_dispatch_entries_are_rejected() {
        // every fixture carries epilogue=none: without it the line is
        // dropped as pre-v5 staleness and the corruption goes unnoticed
        // unknown backend tag
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 epilogue=none kind=dispatch backend=magic cycles=1 tuned_cycles=2"
        )
        .is_err());
        // backend outside its supports() envelope (winograd is K=3-only)
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=5 epilogue=none kind=dispatch backend=winograd cycles=1 tuned_cycles=2"
        )
        .is_err());
        // dispatched slower than the paper-tuned floor: stale or edited
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 epilogue=none kind=dispatch backend=winograd cycles=3 tuned_cycles=2"
        )
        .is_err());
        // missing cycle fields
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 epilogue=none kind=dispatch backend=winograd"
        )
        .is_err());
        // a well-formed entry parses and is served
        let ok = PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 epilogue=none kind=dispatch backend=winograd cycles=1 tuned_cycles=2"
        )
        .unwrap();
        assert_eq!((ok.dispatch_len(), ok.stale_dropped()), (1, 0));
        // op-parameter validation: a depthwise K=5 op is outside
        // winograd's unit envelope, and invalid group splits fail
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=8 k=5 stride=1 pad=2 groups=8 epilogue=none kind=dispatch \
             backend=winograd cycles=1 tuned_cycles=2"
        )
        .is_err());
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=15 k=3 stride=1 pad=0 groups=2 epilogue=none kind=dispatch \
             backend=paper-tuned cycles=1 tuned_cycles=2"
        )
        .is_err());
        // a depthwise K=3 op through the paper backend parses
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=8 k=3 stride=2 pad=1 groups=8 epilogue=none kind=dispatch \
             backend=paper-tuned cycles=1 tuned_cycles=2"
        )
        .is_ok());
        // v5 epilogue validation: an unknown tag is corruption, not
        // staleness — it errors rather than dropping
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 epilogue=blur3 kind=dispatch \
             backend=winograd cycles=1 tuned_cycles=2"
        )
        .is_err());
        // a pool epilogue that doesn't fit the op's output map errors
        assert!(PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 epilogue=pool16s16 kind=dispatch \
             backend=winograd cycles=1 tuned_cycles=2"
        )
        .is_err());
        // a well-formed fused entry parses and is served on the fused key
        let fused = PlanCache::from_lines(
            "gpu=G c=8 wy=14 wx=14 m=16 k=3 epilogue=pool2s2 kind=dispatch \
             backend=winograd cycles=1 tuned_cycles=2"
        )
        .unwrap();
        assert_eq!(fused.dispatch_len(), 1);
        let op = ConvOp::dense(ConvProblem::multi(8, 14, 16, 3));
        let spec = GpuSpec { name: "G", ..gtx_1080ti() };
        assert!(fused
            .get_dispatch_fused(&op, Epilogue::MaxPoolWriteback { k: 2, stride: 2 }, &spec)
            .is_some());
        assert!(fused.get_dispatch(&op, &spec).is_none(), "fused key must not shadow none");
    }

    #[test]
    fn merge_absorbs_both_entry_kinds() {
        let g = gtx_1080ti();
        let mut a = PlanCache::new();
        let mut b = sample();
        b.insert_dispatch(
            ConvOp::dense(ConvProblem::multi(64, 56, 64, 3)),
            &g,
            Decision { backend: "paper-tuned".into(), cycles: 10.0, tuned_cycles: 10.0 },
        );
        b.insert_op(
            ConvOp::pointwise(512, 14, 512),
            Epilogue::None,
            16,
            &g,
            Tuned {
                params: PlanParams::Multi {
                    s_bytes: 64,
                    wx_prime: 32,
                    m_prime: 32,
                    stages: 2,
                    loading: Loading::Cyclic,
                },
                tuned_cycles: 40_000.5,
                paper_cycles: 61_000.25,
            },
        );
        let absorbed = a.merge(b.clone());
        assert_eq!(absorbed, b.len() + b.op_len() + b.dispatch_len());
        assert_eq!(a.len(), b.len());
        assert_eq!(a.dispatch_len(), 1);
        assert_eq!(a.op_len(), 1);
        assert!(!a.is_empty());
    }

    #[test]
    fn speedup_definition() {
        let t = Tuned {
            params: PlanParams::Multi {
                s_bytes: 32,
                wx_prime: 32,
                m_prime: 1,
                stages: 2,
                loading: Loading::Cyclic,
            },
            tuned_cycles: 50.0,
            paper_cycles: 100.0,
        };
        assert!((t.speedup() - 2.0).abs() < 1e-12);
    }
}
