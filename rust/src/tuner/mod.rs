//! Plan-space autotuner: enumerate every legal kernel parameterization
//! for a problem, score each in closed form (`score`, exact under the
//! simulator's cost model), cross-check the top candidates with
//! `gpusim::simulate` on the materialized plans, and memoize the winner
//! in a serializable `PlanCache`.
//!
//! The paper's §3 closed-form pick (`plans::paper_plan_for`) is both the
//! fallback and a regression floor: it is always in the final simulate
//! set, so a tuned plan is *never* slower than the paper's — the worst
//! case is "the paper was already optimal" and the tuner returns its
//! parameters unchanged.
//!
//! Search pipeline (per `(problem, GpuSpec)`, ~100–500 candidates):
//!
//!   enumerate ──> score (closed form) ──> top-K simulate ──> PlanCache
//!                                          ^ paper's plan always included

pub mod cache;
pub mod enumerate;
pub mod score;

use std::sync::{Mutex, OnceLock};

pub use cache::{PlanCache, Tuned};
pub use enumerate::PlanParams;
pub use score::OpObjective;

use crate::analytic;
use crate::conv::{ConvOp, ConvProblem};
use crate::gpusim::{occupancy, simulate, BlockResources, Epilogue, GpuSpec, KernelPlan};
use crate::plans::{single_channel, stride_fixed};
use crate::util::bench::Table;
use crate::util::stats::geomean;

/// How many top-scored candidates get the full simulate cross-check.
pub const TOP_K: usize = 8;

/// Materialize the `KernelPlan` for a parameterization: the geometry's
/// base plan, deepened to the candidate's (stages, loading) point.
pub fn build_plan(p: &ConvProblem, spec: &GpuSpec, params: &PlanParams) -> KernelPlan {
    match *params {
        PlanParams::Single { method, p: pp, q, stages, loading } => {
            let c = enumerate::single_choice(p, spec, method, pp, q);
            single_channel::plan_with_choice(p, spec, &c).staged(stages, loading)
        }
        PlanParams::Multi { s_bytes, wx_prime, m_prime, stages, loading } => {
            let c = enumerate::multi_choice(p, spec, s_bytes, wx_prime, m_prime);
            stride_fixed::plan_with_choice(p, spec, &c).staged(stages, loading)
        }
    }
}

/// Is a plan executable under the paper's §4 launch geometry?  Checked
/// through `gpusim::occupancy`: its 512-thread / 64-register blocks must
/// reach the residency the plan's `threads_per_sm` assumes, with the
/// plan's shared memory split across them.
pub fn is_legal(spec: &GpuSpec, plan: &KernelPlan) -> bool {
    if plan.smem_bytes_per_sm > spec.shared_mem_bytes {
        return false;
    }
    if plan.sms_active < 1 || plan.sms_active > spec.sm_count {
        return false;
    }
    let blocks_needed = plan.threads_per_sm.div_ceil(512).max(1);
    let occ = occupancy(
        spec,
        &BlockResources {
            threads: 512,
            registers_per_thread: 64,
            shared_mem_bytes: plan.smem_bytes_per_sm / blocks_needed,
        },
    );
    occ.blocks_per_sm >= blocks_needed
}

/// The paper's closed-form pick as `(plan, params)` — the regression
/// baseline every search includes.
pub fn paper_params(p: &ConvProblem, spec: &GpuSpec) -> (KernelPlan, PlanParams) {
    use crate::gpusim::Loading;
    if p.is_single_channel() {
        let c = analytic::choose_single(p, spec);
        let plan = single_channel::plan_with_choice(p, spec, &c);
        (
            plan,
            PlanParams::Single {
                method: c.method,
                p: c.p,
                q: c.q,
                stages: 2,
                loading: Loading::Cyclic,
            },
        )
    } else {
        let (plan, c) = stride_fixed::plan_and_choice(p, spec);
        (
            plan,
            PlanParams::Multi {
                s_bytes: c.s_bytes,
                wx_prime: c.wx_prime,
                m_prime: c.m_prime,
                stages: 2,
                loading: Loading::Cyclic,
            },
        )
    }
}

/// Full search over the complete (geometry x stages x loading) space.
pub fn tune(p: &ConvProblem, spec: &GpuSpec) -> Tuned {
    tune_space(p, spec, true)
}

/// Search restricted to the pre-multi-stage (depth-2 cyclic) subspace —
/// the ablation floor the multi-stage gate compares against.
pub fn tune_depth2(p: &ConvProblem, spec: &GpuSpec) -> Tuned {
    tune_space(p, spec, false)
}

fn tune_space(p: &ConvProblem, spec: &GpuSpec, staged: bool) -> Tuned {
    let (paper_plan, paper) = paper_params(p, spec);
    let paper_cycles = simulate(spec, &paper_plan).cycles;

    let mut scored: Vec<(f64, PlanParams)> = enumerate::enumerate(p, spec)
        .into_iter()
        .filter(|c| staged || c.is_depth2_cyclic())
        .filter_map(|c| score::score(p, spec, &c).map(|s| (s, c)))
        .collect();
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut best = (paper_cycles, paper);
    // walk the ranking until TOP_K *legal* candidates have been
    // cross-checked — truncating first could let illegal near-budget
    // candidates crowd out a legal winner further down the ranking
    let mut checked = 0;
    for &(_, params) in scored.iter() {
        if checked == TOP_K {
            break;
        }
        let plan = build_plan(p, spec, &params);
        if !is_legal(spec, &plan) {
            continue;
        }
        checked += 1;
        let cycles = simulate(spec, &plan).cycles;
        if cycles < best.0 {
            best = (cycles, params);
        }
    }
    Tuned { params: best.1, tuned_cycles: best.0, paper_cycles }
}

/// Materialize the op-level `KernelPlan` for a unit parameterization:
/// the unit plan pushed through the serving transforms (decimated strips
/// for stride, side-by-side groups, fused epilogue, batched with
/// cross-image filter residency where it qualifies).  Both the native
/// route and the naive lowering are priced and the faster kept — the
/// same never-lose structure `backend::paper_op_plan` uses, so a tuned
/// op plan can never price above its own lowering either.
pub fn build_op_plan(
    op: &ConvOp,
    ep: Epilogue,
    n: usize,
    spec: &GpuSpec,
    params: &PlanParams,
) -> KernelPlan {
    assert!(op.valid(), "invalid op {op:?}");
    assert!(n >= 1, "batch must be >= 1");
    let l = op.lower();
    let unit = build_plan(&l.unit, spec, params);
    let finish = |p: KernelPlan| p.fused(ep, (op.oy(), op.ox())).batched_resident(n, spec);
    let mut native_base =
        unit.decimated(op.output_keep_fraction()).grouped(l.groups, spec.sm_count);
    native_base.name = crate::backend::op_plan_name(&unit.name, op, true);
    let native = finish(native_base);
    if l.groups == 1 && op.output_keep_fraction() == 1.0 {
        return native; // dense: the lowering IS the native route
    }
    let mut lowered_base = unit.batched(l.groups);
    lowered_base.name = crate::backend::op_plan_name(&unit.name, op, false);
    let lowered = finish(lowered_base);
    if simulate(spec, &native).cycles <= simulate(spec, &lowered).cycles {
        native
    } else {
        lowered
    }
}

/// Direct search over the unit plan space under the op-level objective
/// itself — decimated / grouped / fused / batched-resident cycles, not
/// the stride-1 unit cycles whose ranking the transforms flip.  The
/// inherited-geometry plan (the unit-tuned params pushed through the
/// same transforms — exactly what serving dispatched before this
/// search existed) is the floor: it seeds `best`, so op-native tuning
/// is never-lose by construction.  `paper_cycles` reports that floor.
pub fn tune_op(op: &ConvOp, ep: Epilogue, n: usize, spec: &GpuSpec) -> Tuned {
    assert!(op.valid(), "invalid op {op:?}");
    assert!(n >= 1, "batch must be >= 1");
    let l = op.lower();
    let inherited = tuned(&l.unit, spec).params;
    let inherited_cycles = simulate(spec, &build_op_plan(op, ep, n, spec, &inherited)).cycles;

    let obj = OpObjective::for_op(op, ep, n);
    let mut scored: Vec<(f64, PlanParams)> = enumerate::enumerate(&l.unit, spec)
        .into_iter()
        .filter_map(|c| score::score_op(&l.unit, spec, &c, &obj).map(|s| (s, c)))
        .collect();
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut best = (inherited_cycles, inherited);
    let mut checked = 0;
    for &(_, params) in scored.iter() {
        if checked == TOP_K {
            break;
        }
        let plan = build_op_plan(op, ep, n, spec, &params);
        if !is_legal(spec, &plan) {
            continue;
        }
        checked += 1;
        let cycles = simulate(spec, &plan).cycles;
        if cycles < best.0 {
            best = (cycles, params);
        }
    }
    Tuned { params: best.1, tuned_cycles: best.0, paper_cycles: inherited_cycles }
}

/// Memoized op-native tuning result for `(op, ep, n, spec)` — the
/// PlanCache v6 op-keyed slice, persisted by `tune --save` like the
/// unit entries.
pub fn tuned_op(op: &ConvOp, ep: Epilogue, n: usize, spec: &GpuSpec) -> Tuned {
    if let Some(t) = global().lock().unwrap().get_op(op, ep, n, spec) {
        return t;
    }
    let t = tune_op(op, ep, n, spec);
    global().lock().unwrap().insert_op(*op, ep, n, spec, t);
    t
}

/// The op-tuned `KernelPlan` (what the paper-tuned backend serves for
/// non-unit ops and batched dispatch).
pub fn tuned_op_plan(op: &ConvOp, ep: Epilogue, n: usize, spec: &GpuSpec) -> KernelPlan {
    build_op_plan(op, ep, n, spec, &tuned_op(op, ep, n, spec).params)
}

fn global() -> &'static Mutex<PlanCache> {
    static GLOBAL: OnceLock<Mutex<PlanCache>> = OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(PlanCache::new()))
}

/// Memoized tuning result for `(p, spec)` — searches once per process
/// (or never, when the entry was preloaded from a cache file).
pub fn tuned(p: &ConvProblem, spec: &GpuSpec) -> Tuned {
    if let Some(t) = global().lock().unwrap().get(p, spec) {
        return t;
    }
    // search outside the lock: tuning is the slow path and other threads
    // may be serving different problems concurrently
    let t = tune(p, spec);
    global().lock().unwrap().insert(*p, spec, t);
    t
}

/// The tuned `KernelPlan` for a problem (what `plans::plan_for` serves).
pub fn tuned_plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    build_plan(p, spec, &tuned(p, spec).params)
}

/// Memoized best plan of the depth-2 cyclic subspace (the pre-multi-
/// stage tuner).  Kept out of the serializable `PlanCache` — it is an
/// ablation floor, not a serving artifact.
pub fn depth2_tuned_plan(p: &ConvProblem, spec: &GpuSpec) -> KernelPlan {
    use std::collections::HashMap;
    static DEPTH2: OnceLock<Mutex<HashMap<(ConvProblem, &'static str), Tuned>>> =
        OnceLock::new();
    let memo = DEPTH2.get_or_init(|| Mutex::new(HashMap::new()));
    let key = (*p, spec.name);
    if let Some(t) = memo.lock().unwrap().get(&key).copied() {
        return build_plan(p, spec, &t.params);
    }
    let t = tune_depth2(p, spec);
    memo.lock().unwrap().insert(key, t);
    build_plan(p, spec, &t.params)
}

/// Human-readable description of the tuned pick (router/CLI advice).
pub fn advice(p: &ConvProblem, spec: &GpuSpec) -> String {
    let t = tuned(p, spec);
    let (stages, loading) = t.params.staging();
    let params = match t.params {
        PlanParams::Single { method, p: pp, q, .. } => {
            format!("single-channel {method:?} P={pp} Q={q}")
        }
        PlanParams::Multi { s_bytes, wx_prime, m_prime, .. } => {
            format!("stride-fixed S={s_bytes} M'={m_prime} W'x={wx_prime}")
        }
    };
    format!(
        "{params} s{stages}/{} (tuned, {:.2}x vs paper pick)",
        loading.tag(),
        t.speedup()
    )
}

/// Preload memoized entries (e.g. a `pasconv tune --save` file) so
/// serving never searches.  Returns how many entries were loaded (plan
/// + dispatch) — every entry is kept, whatever GPU name it carries.
pub fn preload(cache: PlanCache) -> usize {
    global().lock().unwrap().merge(cache)
}

/// Snapshot of the process-wide cache (what `pasconv tune --save` writes).
pub fn snapshot() -> PlanCache {
    global().lock().unwrap().clone()
}

/// Memoized cross-backend dispatch decision for an op, if one exists.
/// The backend layer's dispatcher rides in the same process-wide cache
/// as tuning results, so `tune --save/--load` persists both and the
/// coordinator's warm-up fills both with one pass.  (v3 keys carry
/// stride/pad/groups; dense ops are the historical problem keys.)
pub fn cached_dispatch(op: &crate::conv::ConvOp, spec: &GpuSpec) -> Option<crate::backend::Decision> {
    global().lock().unwrap().get_dispatch(op, spec)
}

/// Record a dispatch decision (called by `backend::dispatch` after a
/// full ranking; decisions are computed outside the lock).
pub fn store_dispatch(op: &crate::conv::ConvOp, spec: &GpuSpec, d: crate::backend::Decision) {
    global().lock().unwrap().insert_dispatch(*op, spec, d);
}

/// Memoized dispatch decision on the fused `(op, epilogue)` key — the
/// v5 cache axis.  `Epilogue::None` is the same slice `cached_dispatch`
/// reads, so fused and unfused lookups can never shadow each other.
pub fn cached_dispatch_fused(
    op: &crate::conv::ConvOp,
    ep: crate::gpusim::Epilogue,
    spec: &GpuSpec,
) -> Option<crate::backend::Decision> {
    global().lock().unwrap().get_dispatch_fused(op, ep, spec)
}

/// Record a fused dispatch decision (see `store_dispatch`).
pub fn store_dispatch_fused(
    op: &crate::conv::ConvOp,
    ep: crate::gpusim::Epilogue,
    spec: &GpuSpec,
    d: crate::backend::Decision,
) {
    global().lock().unwrap().insert_dispatch_fused(*op, ep, spec, d);
}

/// Memoized dispatch decision on the full v6 `(op, epilogue, batch)`
/// key — `n = 1` is exactly the slice `cached_dispatch_fused` reads.
pub fn cached_dispatch_batched(
    op: &crate::conv::ConvOp,
    ep: crate::gpusim::Epilogue,
    n: usize,
    spec: &GpuSpec,
) -> Option<crate::backend::Decision> {
    global().lock().unwrap().get_dispatch_batched(op, ep, n, spec)
}

/// Record a batched dispatch decision (see `store_dispatch`).
pub fn store_dispatch_batched(
    op: &crate::conv::ConvOp,
    ep: crate::gpusim::Epilogue,
    n: usize,
    spec: &GpuSpec,
    d: crate::backend::Decision,
) {
    global().lock().unwrap().insert_dispatch_batched(*op, ep, n, spec, d);
}

/// Tuned-vs-paper summary over one suite — shared by the `tune` CLI
/// subcommand and the `ablation_tuned_vs_paper` bench so they can never
/// report different numbers for the same workloads.
pub struct SuiteReport {
    pub table: Table,
    pub improved: usize,
    pub total: usize,
    pub geomean_speedup: f64,
    pub max_speedup: f64,
}

/// Speedups above this count as genuine improvements (not float noise).
const IMPROVED_THRESHOLD: f64 = 1.001;

/// Tune every workload in `suite` (memoized) and tabulate tuned vs paper.
/// Panics if any tuned plan is slower than the paper's — that invariant
/// is structural (`tune` always includes the paper plan) and a violation
/// means the search itself is broken.
pub fn suite_report(suite: &[ConvProblem], spec: &GpuSpec) -> SuiteReport {
    assert!(!suite.is_empty(), "empty suite");
    let mut table = Table::new(&["problem", "paper (µs)", "tuned (µs)", "speedup", "tuned plan"]);
    let mut speedups = Vec::with_capacity(suite.len());
    let mut improved = 0;
    for p in suite {
        let t = tuned(p, spec);
        assert!(
            t.tuned_cycles <= t.paper_cycles * (1.0 + 1e-9),
            "{}: tuner lost to the paper plan",
            p.label()
        );
        let plan = build_plan(p, spec, &t.params);
        let s = t.speedup();
        if s > IMPROVED_THRESHOLD {
            improved += 1;
        }
        speedups.push(s);
        table.row(&[
            p.label(),
            format!("{:.1}", spec.cycles_to_secs(t.paper_cycles) * 1e6),
            format!("{:.1}", spec.cycles_to_secs(t.tuned_cycles) * 1e6),
            format!("{s:.2}x"),
            plan.name,
        ]);
    }
    SuiteReport {
        table,
        improved,
        total: suite.len(),
        geomean_speedup: geomean(&speedups),
        max_speedup: speedups.iter().cloned().fold(1.0, f64::max),
    }
}

/// Op-tuned-vs-inherited summary over one op suite — shared by the
/// `tune --ops` CLI and the op-native ablations so they can never report
/// different numbers for the same workloads.
pub struct OpSuiteReport {
    pub table: Table,
    pub improved: usize,
    pub total: usize,
    /// rows whose served plan pins filters across images (`+fr`)
    pub resident: usize,
    pub geomean_speedup: f64,
    pub max_speedup: f64,
}

/// Tune every `(op, epilogue)` at batch `n` (memoized) and tabulate
/// op-native vs the inherited-geometry floor.  Panics if any op-tuned
/// plan is slower than inherited — that invariant is structural
/// (`tune_op` seeds its best with the inherited plan) and a violation
/// means the search itself is broken.
pub fn op_suite_report(ops: &[(ConvOp, Epilogue)], n: usize, spec: &GpuSpec) -> OpSuiteReport {
    assert!(!ops.is_empty(), "empty op suite");
    let mut table = Table::new(&[
        "op",
        "inherited (µs)",
        "op-tuned (µs)",
        "speedup",
        "resident",
        "tuned plan",
    ]);
    let mut speedups = Vec::with_capacity(ops.len());
    let (mut improved, mut resident) = (0, 0);
    for (op, ep) in ops {
        let t = tuned_op(op, *ep, n, spec);
        assert!(
            t.tuned_cycles <= t.paper_cycles * (1.0 + 1e-9),
            "{}: op-native tuning lost to the inherited-geometry plan",
            op.label()
        );
        let plan = build_op_plan(op, *ep, n, spec, &t.params);
        let fr = plan.name.contains("+fr");
        if fr {
            resident += 1;
        }
        let s = t.speedup();
        if s > IMPROVED_THRESHOLD {
            improved += 1;
        }
        speedups.push(s);
        let label = if ep.is_none() {
            format!("{} xb{n}", op.label())
        } else {
            format!("{} +{} xb{n}", op.label(), ep.tag())
        };
        table.row(&[
            label,
            format!("{:.1}", spec.cycles_to_secs(t.paper_cycles) * 1e6),
            format!("{:.1}", spec.cycles_to_secs(t.tuned_cycles) * 1e6),
            format!("{s:.2}x"),
            (if fr { "yes" } else { "no" }).to_string(),
            plan.name,
        ]);
    }
    OpSuiteReport {
        table,
        improved,
        total: ops.len(),
        resident,
        geomean_speedup: geomean(&speedups),
        max_speedup: speedups.iter().cloned().fold(1.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::suites::{fig4_suite, fig5_suite};
    use crate::gpusim::gtx_1080ti;
    use crate::plans::paper_plan_for;

    #[test]
    fn tuned_never_loses_to_paper_on_the_suites() {
        let g = gtx_1080ti();
        let mut strictly_better = 0;
        for p in fig4_suite().into_iter().chain(fig5_suite()) {
            let t = tune(&p, &g);
            assert!(
                t.tuned_cycles <= t.paper_cycles * (1.0 + 1e-9),
                "{}: tuned {} > paper {}",
                p.label(),
                t.tuned_cycles,
                t.paper_cycles
            );
            if t.tuned_cycles < t.paper_cycles * 0.999 {
                strictly_better += 1;
            }
        }
        // the whole point of searching: at least some workloads improve
        assert!(strictly_better >= 5, "only {strictly_better} workloads improved");
    }

    #[test]
    fn tuned_plan_simulates_and_is_legal() {
        let g = gtx_1080ti();
        for p in [
            ConvProblem::single(1024, 32, 3),
            ConvProblem::multi(256, 14, 256, 3),
            ConvProblem::multi(512, 7, 512, 5),
        ] {
            let plan = tuned_plan(&p, &g);
            assert!(is_legal(&g, &plan), "{}", p.label());
            let r = simulate(&g, &plan);
            assert!(r.seconds > 0.0 && r.seconds.is_finite());
            assert!(r.efficiency > 0.0 && r.efficiency <= 1.0);
        }
    }

    #[test]
    fn memoization_is_consistent_with_fresh_search() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(128, 28, 128, 3);
        let fresh = tune(&p, &g);
        let cached_a = tuned(&p, &g);
        let cached_b = tuned(&p, &g);
        assert_eq!(cached_a, cached_b);
        assert_eq!(cached_a.params, fresh.params);
    }

    #[test]
    fn paper_baseline_cycles_match_paper_plan() {
        let g = gtx_1080ti();
        for p in [ConvProblem::single(224, 64, 3), ConvProblem::multi(64, 56, 64, 3)] {
            let t = tune(&p, &g);
            let paper = simulate(&g, &paper_plan_for(&p, &g));
            assert!(
                (t.paper_cycles - paper.cycles).abs() < 1e-6 * paper.cycles,
                "{}",
                p.label()
            );
        }
    }

    #[test]
    fn full_space_never_loses_to_the_depth2_floor_and_sometimes_wins() {
        // the depth-2 cyclic subspace is a subset of the full space, so
        // the full search can never be slower; on latency-exposed rows
        // it must be strictly faster somewhere
        let g = gtx_1080ti();
        let mut strict = 0;
        for p in fig4_suite().into_iter().chain(fig5_suite()) {
            let full = simulate(&g, &build_plan(&p, &g, &tune(&p, &g).params)).cycles;
            let floor = simulate(&g, &depth2_tuned_plan(&p, &g)).cycles;
            assert!(full <= floor * (1.0 + 1e-9), "{}: {full} > {floor}", p.label());
            if full < floor * 0.999 {
                strict += 1;
            }
        }
        assert!(strict >= 3, "only {strict} rows improved over the depth-2 floor");
    }

    #[test]
    fn tuner_picks_multi_stage_plans_somewhere() {
        let g = gtx_1080ti();
        let deeper = fig4_suite()
            .into_iter()
            .chain(fig5_suite())
            .filter(|p| !tune(p, &g).params.is_depth2_cyclic())
            .count();
        assert!(deeper >= 5, "only {deeper} rows picked a staged variant");
    }

    #[test]
    fn op_native_never_loses_to_inherited_and_wins_on_batched_pointwise() {
        let g = gtx_1080ti();
        let ops = [
            (ConvOp::pointwise(512, 14, 512), Epilogue::None),
            (ConvOp::pointwise(256, 28, 256), Epilogue::None),
            (ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1), Epilogue::None),
            (ConvOp::depthwise(64, 56, 3, 1), Epilogue::None),
            (ConvOp::same(ConvProblem::multi(128, 28, 128, 3)), Epilogue::Relu),
        ];
        for n in [1usize, 16] {
            let rep = op_suite_report(&ops, n, &g); // asserts never-lose per row
            assert!(rep.geomean_speedup >= 1.0 - 1e-9, "geomean {}", rep.geomean_speedup);
        }
        // the residency mechanism must fire and pay on the MobileNet
        // pointwise regime: the 512->1024 head's 2 MB filter tensor fits
        // the L2 residency budget, so op-native search keeps the filters
        // resident across the batch and beats the inherited floor
        let t = tuned_op(&ConvOp::pointwise(512, 7, 1024), Epilogue::None, 16, &g);
        assert!(
            t.tuned_cycles < t.paper_cycles * 0.99,
            "batched pointwise: op-native {} not below inherited {}",
            t.tuned_cycles,
            t.paper_cycles
        );
        let plan = build_op_plan(
            &ConvOp::pointwise(512, 7, 1024),
            Epilogue::None,
            16,
            &g,
            &t.params,
        );
        assert!(plan.name.contains("+fr"), "winner does not pin filters: {}", plan.name);
    }

    #[test]
    fn op_tuning_degenerates_to_unit_tuning_at_n1_dense() {
        // a dense op at n = 1 with no epilogue IS the unit problem: the
        // op objective and the unit objective price the same plan space,
        // so the op-tuned plan can never lose to the unit-tuned one
        let g = gtx_1080ti();
        let p = ConvProblem::multi(256, 14, 256, 3);
        let t_op = tune_op(&ConvOp::dense(p), Epilogue::None, 1, &g);
        let t_unit = tuned(&p, &g);
        assert!(
            t_op.tuned_cycles <= simulate(&g, &build_plan(&p, &g, &t_unit.params)).cycles
                * (1.0 + 1e-9)
        );
    }

    #[test]
    fn fused_retuned_never_loses_to_fused_inherited() {
        // the epilogue axis (ROADMAP PR-9 leftover): retuning under the
        // fused objective's writeback pricing is never-lose vs pushing
        // the unfused tuned geometry through `fused` (structural), and
        // the pool tail's store-pattern change is visible to the search
        let g = gtx_1080ti();
        for (op, ep) in [
            (ConvOp::dense(ConvProblem::multi(64, 28, 64, 3)), Epilogue::MaxPoolWriteback { k: 2, stride: 2 }),
            (ConvOp::same(ConvProblem::multi(128, 28, 128, 3)), Epilogue::AddResidual),
            (ConvOp::pointwise(256, 14, 256), Epilogue::Relu),
        ] {
            let t = tune_op(&op, ep, 1, &g);
            assert!(
                t.tuned_cycles <= t.paper_cycles * (1.0 + 1e-9),
                "{} +{}: fused-retuned lost to fused-inherited",
                op.label(),
                ep.tag()
            );
        }
    }

    #[test]
    fn op_tuned_cycles_monotone_in_batch() {
        let g = gtx_1080ti();
        let op = ConvOp::pointwise(512, 14, 512);
        let mut last = 0.0;
        for n in [1usize, 4, 16, 64] {
            let t = tuned_op(&op, Epilogue::None, n, &g);
            assert!(t.tuned_cycles > last, "n={n}: {} <= {last}", t.tuned_cycles);
            last = t.tuned_cycles;
        }
    }

    #[test]
    fn advice_mentions_tuning() {
        let g = gtx_1080ti();
        let a = advice(&ConvProblem::multi(256, 14, 256, 3), &g);
        assert!(a.contains("stride-fixed") && a.contains("tuned"), "{a}");
        let s = advice(&ConvProblem::single(224, 64, 3), &g);
        assert!(s.contains("single-channel"), "{s}");
    }
}
