//! Candidate scoring: the exact simulated cycle count of a candidate,
//! computed in closed form from the plan builders' *round recipes* —
//! no `Vec<Round>` is materialized, so scoring the whole plan space is
//! O(candidates), not O(candidates × rounds).
//!
//! The round lists both kernels produce are run-length structured (a
//! cold first round, then identical steady-state rounds), so the
//! pipeline recurrence
//!
//!   launch + latency + load(0) + Σ max(load(r), compute(r-1)) + compute(n-1)
//!
//! collapses: each identical run contributes `(count-1)·max(load, comp)`
//! plus one cross-run transition.  `gpusim::simulate` on the
//! materialized plan produces the same number (the tuner tests pin the
//! equivalence), which is what lets the search trust the score and only
//! simulate the winners.

use super::enumerate::{multi_choice, single_choice, PlanParams};
use crate::conv::{ConvOp, ConvProblem, BYTES_F32};
use crate::gpusim::pipeline::{load_cycles, simulate_pipeline_runs};
use crate::gpusim::{writeback_tail_cycles, Epilogue, ExecConfig, GpuSpec, Loading, Round};
use crate::plans::{single_channel, stride_fixed, COMPUTE_EFFICIENCY, LAUNCH_OVERHEAD_CYCLES};

/// Candidates whose schedule exceeds this many rounds per SM are skipped
/// before materialization (they are never competitive — each round does
/// almost no work — and expanding them would dominate memory).
pub const MAX_ROUNDS: usize = 4_000_000;

fn exec_config(sms_active: u32, threads_per_sm: u32, stages: u32, loading: Loading) -> ExecConfig {
    ExecConfig {
        sms_active,
        threads_per_sm,
        compute_efficiency: COMPUTE_EFFICIENCY,
        launch_overhead_cycles: LAUNCH_OVERHEAD_CYCLES,
        stages,
        loading,
    }
}

/// Exact pipeline cycles for a run-length round list.
fn runs_cycles(spec: &GpuSpec, cfg: &ExecConfig, runs: &[(Round, usize)]) -> f64 {
    simulate_pipeline_runs(spec, cfg, runs).total_cycles
}

/// Charged writeback, matching `simulate_detailed`: max(staged tail,
/// DRAM bus-floor excess) so the score stays bit-identical to simulate.
fn writeback_cycles(
    spec: &GpuSpec,
    p: &ConvProblem,
    pipe_total: f64,
    load_bytes: f64,
    stages: u32,
) -> f64 {
    let out = (p.out_elems() * BYTES_F32) as f64;
    let tail = writeback_tail_cycles(spec, out, stages);
    let floor = (load_bytes + out) / spec.bytes_per_cycle();
    tail.max(floor - pipe_total)
}

/// Exact simulated cycles of a candidate, or `None` when the candidate's
/// schedule is too long to ever win (`MAX_ROUNDS`).
pub fn score(p: &ConvProblem, spec: &GpuSpec, params: &PlanParams) -> Option<f64> {
    match *params {
        PlanParams::Single { method, p: pp, q, stages, loading } => {
            let c = single_choice(p, spec, method, pp, q);
            let r = single_channel::recipe(p, spec, &c);
            let cfg = exec_config(r.sms_active, r.threads_per_sm, stages, loading);
            let mut runs = vec![(r.first, 1usize)];
            if let Some((tail, n)) = r.tail {
                if n > MAX_ROUNDS {
                    return None;
                }
                runs.push((tail, n));
            }
            let t = runs_cycles(spec, &cfg, &runs);
            let loads: f64 = runs.iter().map(|(r, n)| r.load_bytes * *n as f64).sum::<f64>()
                * r.sms_active as f64;
            Some(t + writeback_cycles(spec, p, t, loads, stages))
        }
        PlanParams::Multi { s_bytes, wx_prime, m_prime, stages, loading } => {
            let c = multi_choice(p, spec, s_bytes, wx_prime, m_prime);
            let r = stride_fixed::recipe(p, spec, &c);
            if r.count > MAX_ROUNDS {
                return None;
            }
            let cfg = exec_config(r.sms_active, r.threads_per_sm, stages, loading);
            let t = runs_cycles(spec, &cfg, &[(r.round, r.count)]);
            let loads = r.round.load_bytes * r.count as f64 * r.sms_active as f64;
            Some(t + writeback_cycles(spec, p, t, loads, stages))
        }
    }
}

/// The op-level objective the op-native search optimizes directly: the
/// decimated / grouped / fused / batched transforms the serving path
/// applies, so candidates are ranked on the cycles they actually cost at
/// the op — not on the stride-1 unit problem whose ranking the
/// transforms are known to flip (EXPERIMENTS §10).
#[derive(Clone, Copy, Debug)]
pub struct OpObjective {
    /// `ConvOp::output_keep_fraction()` — decimated-output share
    pub keep: f64,
    /// group count of the lowering (side-by-side on idle SMs)
    pub groups: usize,
    /// batch size the plan serves (1 = single image)
    pub n: usize,
    /// fused writeback epilogue
    pub ep: Epilogue,
    /// the op-level output map (oy, ox) the epilogue prices against
    pub out_hw: (usize, usize),
}

impl OpObjective {
    pub fn for_op(op: &ConvOp, ep: Epilogue, n: usize) -> OpObjective {
        assert!(n >= 1, "batch must be >= 1");
        OpObjective {
            keep: op.output_keep_fraction(),
            groups: op.lower().groups,
            n,
            ep,
            out_hw: (op.oy(), op.ox()),
        }
    }
}

/// Exact simulated cycles of a unit candidate pushed through the op
/// transforms (`decimated(keep).grouped(groups).fused(ep)` then
/// `batched_resident(n)` with its own qualification mirrored here), in
/// runs form — no `Vec<Round>` of length rounds × waves × n is ever
/// materialized.  Matches `simulate` on the materialized native-route
/// plan bit-for-bit (pinned by tests), which is what lets `tune_op`
/// trust the ranking and only simulate the winners.
pub fn score_op(
    unit: &ConvProblem,
    spec: &GpuSpec,
    params: &PlanParams,
    obj: &OpObjective,
) -> Option<f64> {
    // per-image base runs + geometry, mirroring `score`
    let (mut runs, sms, threads, smem_staged, resident, l2_fp, stages, loading) = match *params {
        PlanParams::Single { method, p: pp, q, stages, loading } => {
            let c = single_choice(unit, spec, method, pp, q);
            let r = single_channel::recipe(unit, spec, &c);
            let mut runs = vec![(r.first, 1usize)];
            if let Some((tail, cnt)) = r.tail {
                runs.push((tail, cnt));
            }
            let smem = r.smem_bytes.min(spec.shared_mem_bytes as usize)
                + (stages as usize - 2) * r.stage_bytes;
            let l2_fp = (unit.m * unit.k * unit.k * BYTES_F32) as u64;
            (
                runs,
                r.sms_active,
                r.threads_per_sm,
                smem,
                r.filter_resident_bytes,
                l2_fp,
                stages,
                loading,
            )
        }
        PlanParams::Multi { s_bytes, wx_prime, m_prime, stages, loading } => {
            let c = multi_choice(unit, spec, s_bytes, wx_prime, m_prime);
            let r = stride_fixed::recipe(unit, spec, &c);
            let smem = c.smem_bytes
                + (stages as usize - 2)
                    * crate::analytic::multi::stage_bytes_multi(
                        s_bytes, wx_prime, m_prime, unit.k,
                    );
            let l2_fp = (unit.m * unit.c * unit.k * unit.k * BYTES_F32) as u64;
            (
                vec![(r.round, r.count)],
                r.sms_active,
                r.threads_per_sm,
                smem,
                r.filter_resident_bytes,
                l2_fp,
                stages,
                loading,
            )
        }
    };
    // decimation: only the kept rows' FMAs are charged, loads stay
    for (r, _) in runs.iter_mut() {
        r.fma_ops *= obj.keep;
    }
    // grouping: `par` groups side by side, the rest as sequential waves
    let par = ((spec.sm_count / sms).max(1) as usize).min(obj.groups);
    let waves = (obj.groups + par - 1) / par;
    let sms_g = sms * par as u32;
    let per_image: usize = runs.iter().map(|&(_, c)| c).sum::<usize>().checked_mul(waves)?;
    if per_image.checked_mul(obj.n).map_or(true, |t| t > MAX_ROUNDS) {
        return None;
    }
    let image_runs: Vec<(Round, usize)> =
        std::iter::repeat(runs.iter().copied()).take(waves).flatten().collect();
    // epilogue pricing against the op-level output map
    let out_unit = (unit.out_elems() * BYTES_F32) as f64;
    let mut out = out_unit * obj.keep * obj.groups as f64;
    let mut ep_read = 0.0;
    match obj.ep {
        Epilogue::None | Epilogue::Relu => {}
        Epilogue::AddResidual => ep_read = out,
        Epilogue::MaxPoolWriteback { .. } => {
            let (oy, ox) = obj.out_hw;
            let (py, px) = obj.ep.pooled_hw(oy, ox);
            out *= (py * px) as f64 / (oy * ox) as f64;
        }
    }
    let cfg = exec_config(sms_g, threads, stages, loading);
    // cross-image filter residency: the two-tier legality and
    // warm-vs-cold guards of `KernelPlan::batched_resident`, in recipe
    // form — smem pinning (the grouped plan pins every wave's filters,
    // hence resident × waves) with an L2-capacity fallback (every
    // group's whole filter tensor must fit the residency budget)
    let resident_g = (resident as u64).saturating_mul(waves as u64);
    let l2_fp_g = l2_fp.saturating_mul(obj.groups as u64);
    let fits = (resident_g > 0
        && smem_staged as u64 + resident_g <= spec.shared_mem_bytes as u64)
        || (l2_fp_g > 0 && l2_fp_g <= spec.l2_resident_budget());
    let qualify = obj.n > 1
        && fits
        && image_runs.iter().all(|(r, _)| {
            load_cycles(spec, &cfg, &r.without_filter_loads())
                <= load_cycles(spec, &cfg, r) + 1e-9
        });
    let mut all_runs: Vec<(Round, usize)> =
        Vec::with_capacity(image_runs.len() * obj.n);
    all_runs.extend(image_runs.iter().copied());
    for _ in 1..obj.n {
        if qualify {
            all_runs.extend(image_runs.iter().map(|&(r, c)| (r.without_filter_loads(), c)));
        } else {
            all_runs.extend(image_runs.iter().copied());
        }
    }
    let t = runs_cycles(spec, &cfg, &all_runs);
    let loads: f64 = all_runs.iter().map(|&(r, c)| r.load_bytes * c as f64).sum::<f64>()
        * sms_g as f64;
    let out_total = out * obj.n as f64;
    let ep_total = ep_read * obj.n as f64;
    let tail = writeback_tail_cycles(spec, out_total + ep_total, stages);
    let floor = (loads + out_total + ep_total) / spec.bytes_per_cycle();
    Some(t + tail.max(floor - t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::SingleMethod;
    use crate::gpusim::{gtx_1080ti, simulate};
    use crate::plans::{single_channel, stride_fixed};

    #[test]
    fn single_score_equals_simulate() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(224, 64, 3);
        for (method, pp, q) in [
            (SingleMethod::FilterSplit, 1, 1),
            (SingleMethod::FilterSplit, 4, 1),
            (SingleMethod::MapSplit, 1, 8),
        ] {
            for (stages, loading) in crate::tuner::enumerate::STAGED_VARIANTS {
                let params = PlanParams::Single { method, p: pp, q, stages, loading };
                let s = score(&p, &g, &params).unwrap();
                let c = single_choice(&p, &g, method, pp, q);
                let plan =
                    single_channel::plan_with_choice(&p, &g, &c).staged(stages, loading);
                if plan.smem_bytes_per_sm > g.shared_mem_bytes {
                    continue; // enumerate never emits these; simulate would panic
                }
                let r = simulate(&g, &plan);
                assert!(
                    (s - r.cycles).abs() < 1e-6 * r.cycles,
                    "{method:?} P={pp} Q={q} s{stages}/{}: score {s} vs simulate {}",
                    loading.tag(),
                    r.cycles
                );
            }
        }
    }

    #[test]
    fn multi_score_equals_simulate() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(128, 28, 128, 3);
        for (s_bytes, wx, mp) in [(32, 128, 64), (64, 32, 128), (128, 64, 16)] {
            for (stages, loading) in crate::tuner::enumerate::STAGED_VARIANTS {
                let params =
                    PlanParams::Multi { s_bytes, wx_prime: wx, m_prime: mp, stages, loading };
                let s = score(&p, &g, &params).unwrap();
                let c = multi_choice(&p, &g, s_bytes, wx, mp);
                let plan = stride_fixed::plan_with_choice(&p, &g, &c).staged(stages, loading);
                let r = simulate(&g, &plan);
                assert!(
                    (s - r.cycles).abs() < 1e-6 * r.cycles,
                    "S={s_bytes} W'x={wx} M'={mp} s{stages}/{}: score {s} vs simulate {}",
                    loading.tag(),
                    r.cycles
                );
            }
        }
    }

    #[test]
    fn op_score_equals_simulate_on_the_native_route() {
        // score_op must price exactly what the serving path materializes:
        // build_plan -> decimated -> grouped -> fused -> batched_resident
        let g = gtx_1080ti();
        for (op, ep, n) in [
            (ConvOp::pointwise(512, 14, 512), Epilogue::None, 16),
            (ConvOp::strided(ConvProblem::multi(64, 56, 128, 3), 2, 1), Epilogue::Relu, 4),
            (ConvOp::depthwise(32, 28, 3, 1), Epilogue::None, 8),
            (
                ConvOp::dense(ConvProblem::multi(128, 28, 128, 3)),
                Epilogue::AddResidual,
                1,
            ),
        ] {
            let l = op.lower();
            let obj = OpObjective::for_op(&op, ep, n);
            let mut checked = 0;
            for params in crate::tuner::enumerate::enumerate(&l.unit, &g).iter().step_by(7) {
                let Some(s) = score_op(&l.unit, &g, params, &obj) else { continue };
                let plan = crate::tuner::build_plan(&l.unit, &g, params)
                    .decimated(op.output_keep_fraction())
                    .grouped(l.groups, g.sm_count)
                    .fused(ep, (op.oy(), op.ox()))
                    .batched_resident(n, &g);
                let r = simulate(&g, &plan);
                assert!(
                    (s - r.cycles).abs() < 1e-6 * r.cycles,
                    "{} +{} xb{n} {params:?}: score {s} vs simulate {}",
                    op.label(),
                    ep.tag(),
                    r.cycles
                );
                checked += 1;
            }
            assert!(checked >= 3, "{}: only {checked} candidates checked", op.label());
        }
    }

    #[test]
    fn op_score_credits_residency_where_it_qualifies() {
        // the mechanism the §15 gate banks on: at n=16 a geometry whose
        // filter working set fits shared memory scores below the same
        // geometry priced by the re-streaming model
        let g = gtx_1080ti();
        let op = ConvOp::pointwise(512, 14, 512);
        let obj = OpObjective::for_op(&op, Epilogue::None, 16);
        let found = crate::tuner::enumerate::enumerate(&op.core, &g).iter().any(|params| {
            let Some(s) = score_op(&op.core, &g, params, &obj) else { return false };
            let plan = crate::tuner::build_plan(&op.core, &g, params)
                .batched_resident(16, &g);
            plan.name.ends_with("+fr")
                && s < simulate(&g, &crate::tuner::build_plan(&op.core, &g, params)
                    .batched(16)).cycles
        });
        assert!(found, "no enumerated geometry qualified for residency at n=16");
    }

    #[test]
    fn oversized_schedules_are_rejected() {
        let g = gtx_1080ti();
        // C=512, W=512, M'=1, W'x=32: millions of near-empty rounds
        let p = ConvProblem::multi(512, 512, 512, 5);
        let params = PlanParams::Multi {
            s_bytes: 32,
            wx_prime: 32,
            m_prime: 1,
            stages: 2,
            loading: Loading::Cyclic,
        };
        assert!(score(&p, &g, &params).is_none());
    }
}
