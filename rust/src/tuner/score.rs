//! Candidate scoring: the exact simulated cycle count of a candidate,
//! computed in closed form from the plan builders' *round recipes* —
//! no `Vec<Round>` is materialized, so scoring the whole plan space is
//! O(candidates), not O(candidates × rounds).
//!
//! The round lists both kernels produce are run-length structured (a
//! cold first round, then identical steady-state rounds), so the
//! pipeline recurrence
//!
//!   launch + latency + load(0) + Σ max(load(r), compute(r-1)) + compute(n-1)
//!
//! collapses: each identical run contributes `(count-1)·max(load, comp)`
//! plus one cross-run transition.  `gpusim::simulate` on the
//! materialized plan produces the same number (the tuner tests pin the
//! equivalence), which is what lets the search trust the score and only
//! simulate the winners.

use super::enumerate::{multi_choice, single_choice, PlanParams};
use crate::conv::{ConvProblem, BYTES_F32};
use crate::gpusim::pipeline::simulate_pipeline_runs;
use crate::gpusim::{writeback_tail_cycles, ExecConfig, GpuSpec, Loading, Round};
use crate::plans::{single_channel, stride_fixed, COMPUTE_EFFICIENCY, LAUNCH_OVERHEAD_CYCLES};

/// Candidates whose schedule exceeds this many rounds per SM are skipped
/// before materialization (they are never competitive — each round does
/// almost no work — and expanding them would dominate memory).
pub const MAX_ROUNDS: usize = 4_000_000;

fn exec_config(sms_active: u32, threads_per_sm: u32, stages: u32, loading: Loading) -> ExecConfig {
    ExecConfig {
        sms_active,
        threads_per_sm,
        compute_efficiency: COMPUTE_EFFICIENCY,
        launch_overhead_cycles: LAUNCH_OVERHEAD_CYCLES,
        stages,
        loading,
    }
}

/// Exact pipeline cycles for a run-length round list.
fn runs_cycles(spec: &GpuSpec, cfg: &ExecConfig, runs: &[(Round, usize)]) -> f64 {
    simulate_pipeline_runs(spec, cfg, runs).total_cycles
}

/// Charged writeback, matching `simulate_detailed`: max(staged tail,
/// DRAM bus-floor excess) so the score stays bit-identical to simulate.
fn writeback_cycles(
    spec: &GpuSpec,
    p: &ConvProblem,
    pipe_total: f64,
    load_bytes: f64,
    stages: u32,
) -> f64 {
    let out = (p.out_elems() * BYTES_F32) as f64;
    let tail = writeback_tail_cycles(spec, out, stages);
    let floor = (load_bytes + out) / spec.bytes_per_cycle();
    tail.max(floor - pipe_total)
}

/// Exact simulated cycles of a candidate, or `None` when the candidate's
/// schedule is too long to ever win (`MAX_ROUNDS`).
pub fn score(p: &ConvProblem, spec: &GpuSpec, params: &PlanParams) -> Option<f64> {
    match *params {
        PlanParams::Single { method, p: pp, q, stages, loading } => {
            let c = single_choice(p, spec, method, pp, q);
            let r = single_channel::recipe(p, spec, &c);
            let cfg = exec_config(r.sms_active, r.threads_per_sm, stages, loading);
            let mut runs = vec![(r.first, 1usize)];
            if let Some((tail, n)) = r.tail {
                if n > MAX_ROUNDS {
                    return None;
                }
                runs.push((tail, n));
            }
            let t = runs_cycles(spec, &cfg, &runs);
            let loads: f64 = runs.iter().map(|(r, n)| r.load_bytes * *n as f64).sum::<f64>()
                * r.sms_active as f64;
            Some(t + writeback_cycles(spec, p, t, loads, stages))
        }
        PlanParams::Multi { s_bytes, wx_prime, m_prime, stages, loading } => {
            let c = multi_choice(p, spec, s_bytes, wx_prime, m_prime);
            let r = stride_fixed::recipe(p, spec, &c);
            if r.count > MAX_ROUNDS {
                return None;
            }
            let cfg = exec_config(r.sms_active, r.threads_per_sm, stages, loading);
            let t = runs_cycles(spec, &cfg, &[(r.round, r.count)]);
            let loads = r.round.load_bytes * r.count as f64 * r.sms_active as f64;
            Some(t + writeback_cycles(spec, p, t, loads, stages))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::SingleMethod;
    use crate::gpusim::{gtx_1080ti, simulate};
    use crate::plans::{single_channel, stride_fixed};

    #[test]
    fn single_score_equals_simulate() {
        let g = gtx_1080ti();
        let p = ConvProblem::single(224, 64, 3);
        for (method, pp, q) in [
            (SingleMethod::FilterSplit, 1, 1),
            (SingleMethod::FilterSplit, 4, 1),
            (SingleMethod::MapSplit, 1, 8),
        ] {
            for (stages, loading) in crate::tuner::enumerate::STAGED_VARIANTS {
                let params = PlanParams::Single { method, p: pp, q, stages, loading };
                let s = score(&p, &g, &params).unwrap();
                let c = single_choice(&p, &g, method, pp, q);
                let plan =
                    single_channel::plan_with_choice(&p, &g, &c).staged(stages, loading);
                if plan.smem_bytes_per_sm > g.shared_mem_bytes {
                    continue; // enumerate never emits these; simulate would panic
                }
                let r = simulate(&g, &plan);
                assert!(
                    (s - r.cycles).abs() < 1e-6 * r.cycles,
                    "{method:?} P={pp} Q={q} s{stages}/{}: score {s} vs simulate {}",
                    loading.tag(),
                    r.cycles
                );
            }
        }
    }

    #[test]
    fn multi_score_equals_simulate() {
        let g = gtx_1080ti();
        let p = ConvProblem::multi(128, 28, 128, 3);
        for (s_bytes, wx, mp) in [(32, 128, 64), (64, 32, 128), (128, 64, 16)] {
            for (stages, loading) in crate::tuner::enumerate::STAGED_VARIANTS {
                let params =
                    PlanParams::Multi { s_bytes, wx_prime: wx, m_prime: mp, stages, loading };
                let s = score(&p, &g, &params).unwrap();
                let c = multi_choice(&p, &g, s_bytes, wx, mp);
                let plan = stride_fixed::plan_with_choice(&p, &g, &c).staged(stages, loading);
                let r = simulate(&g, &plan);
                assert!(
                    (s - r.cycles).abs() < 1e-6 * r.cycles,
                    "S={s_bytes} W'x={wx} M'={mp} s{stages}/{}: score {s} vs simulate {}",
                    loading.tag(),
                    r.cycles
                );
            }
        }
    }

    #[test]
    fn oversized_schedules_are_rejected() {
        let g = gtx_1080ti();
        // C=512, W=512, M'=1, W'x=32: millions of near-empty rounds
        let p = ConvProblem::multi(512, 512, 512, 5);
        let params = PlanParams::Multi {
            s_bytes: 32,
            wx_prime: 32,
            m_prime: 1,
            stages: 2,
            loading: Loading::Cyclic,
        };
        assert!(score(&p, &g, &params).is_none());
    }
}
