//! Property tests for the op layer's exact lowering (the ISSUE-5
//! acceptance identities), all **bit-identity** (f32 bit patterns, not
//! allclose):
//!
//!  * padded conv == valid conv on the zero-embedded map;
//!  * strided conv == decimated stride-1 output;
//!  * grouped conv == concatenation of per-group CPU convs;
//!  * the composed lowering (`conv2d_op_lowered_cpu`) == the
//!    generalized direct reference (`conv2d_op_cpu`) on random ops
//!    mixing all three parameters;
//!  * every backend's `execute_op_reference` == the generalized
//!    reference wherever its coverage allows.
//!
//! Fixed seed + case counts: bounded debug-mode CI runtime,
//! deterministic replays.

use pasconv::backend::Dispatcher;
use pasconv::conv::{
    conv2d_multi_cpu, conv2d_op_cpu, conv2d_op_lowered_cpu, decimate, zero_embed, ConvOp,
    ConvProblem,
};
use pasconv::util::prop::{check_no_shrink, Config};
use pasconv::util::rng::Rng;

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0x0D1CE }
}

fn bit_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A random valid op: C/M split into 1..3 groups, maps 4..12 px, K in
/// {1,3,5} (clamped), stride 1..3, pad 0..K-1.
fn gen_op(rng: &mut Rng) -> (ConvOp, u64) {
    let groups = rng.range_usize(1, 3);
    let c = groups * rng.range_usize(1, 3);
    let m = groups * rng.range_usize(1, 3);
    let w = rng.range_usize(4, 12);
    let k = [1usize, 3, 5][rng.range_usize(0, 2)].min(w);
    let pad = rng.range_usize(0, k - 1);
    let stride = rng.range_usize(1, 3);
    let op = ConvOp { core: ConvProblem { c, wy: w, wx: w, m, k }, stride, pad, groups };
    (op, rng.next_u64())
}

#[test]
fn padded_conv_is_valid_conv_on_the_zero_embedded_map() {
    check_no_shrink(
        &cfg(48),
        |rng| {
            let c = rng.range_usize(1, 4);
            let m = rng.range_usize(1, 4);
            let w = rng.range_usize(3, 10);
            let k = [3usize, 5][rng.range_usize(0, 1)].min(w);
            let pad = rng.range_usize(1, k - 1);
            (ConvOp { core: ConvProblem { c, wy: w, wx: w, m, k }, stride: 1, pad, groups: 1 },
             rng.next_u64())
        },
        |&(op, seed)| {
            let mut rng = Rng::new(seed);
            let image = rng.normal_vec(op.map_elems());
            let filters = rng.normal_vec(op.filter_elems());
            let padded = conv2d_op_cpu(&op, &image, &filters);
            let embedded = zero_embed(&image, op.core.c, op.core.wy, op.core.wx, op.pad);
            let unit = op.lower().unit;
            let valid = conv2d_multi_cpu(&unit, &embedded, &filters);
            if !bit_eq(&padded, &valid) {
                return Err(format!("{}: padded != zero-embedded valid", op.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn strided_conv_is_the_decimated_stride1_output() {
    check_no_shrink(
        &cfg(48),
        |rng| {
            let c = rng.range_usize(1, 4);
            let m = rng.range_usize(1, 4);
            let w = rng.range_usize(5, 12);
            let k = [1usize, 3][rng.range_usize(0, 1)];
            let stride = rng.range_usize(2, 3);
            (ConvOp { core: ConvProblem { c, wy: w, wx: w, m, k }, stride, pad: 0, groups: 1 },
             rng.next_u64())
        },
        |&(op, seed)| {
            let mut rng = Rng::new(seed);
            let image = rng.normal_vec(op.map_elems());
            let filters = rng.normal_vec(op.filter_elems());
            let strided = conv2d_op_cpu(&op, &image, &filters);
            let s1 = conv2d_multi_cpu(&op.core, &image, &filters);
            let dec = decimate(&s1, op.core.m, op.core.oy(), op.core.ox(), op.stride);
            if !bit_eq(&strided, &dec) {
                return Err(format!("{}: strided != decimated stride-1", op.label()));
            }
            if strided.len() != op.out_elems() {
                return Err(format!("{}: wrong output size", op.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn grouped_conv_is_the_concatenation_of_per_group_convs() {
    check_no_shrink(
        &cfg(48),
        |rng| {
            let groups = rng.range_usize(2, 4);
            let c = groups * rng.range_usize(1, 3);
            let m = groups * rng.range_usize(1, 3);
            let w = rng.range_usize(3, 10);
            let k = [1usize, 3][rng.range_usize(0, 1)].min(w);
            (ConvOp { core: ConvProblem { c, wy: w, wx: w, m, k }, stride: 1, pad: 0, groups },
             rng.next_u64())
        },
        |&(op, seed)| {
            let mut rng = Rng::new(seed);
            let image = rng.normal_vec(op.map_elems());
            let filters = rng.normal_vec(op.filter_elems());
            let grouped = conv2d_op_cpu(&op, &image, &filters);
            let unit = op.lower().unit;
            let mut concat = Vec::with_capacity(op.out_elems());
            for g in 0..op.groups {
                concat.extend(conv2d_multi_cpu(
                    &unit,
                    &image[g * unit.map_elems()..(g + 1) * unit.map_elems()],
                    &filters[g * unit.filter_elems()..(g + 1) * unit.filter_elems()],
                ));
            }
            if !bit_eq(&grouped, &concat) {
                return Err(format!("{}: grouped != per-group concat", op.label()));
            }
            Ok(())
        },
    );
}

#[test]
fn composed_lowering_matches_direct_reference_on_mixed_ops() {
    check_no_shrink(&cfg(64), gen_op, |&(op, seed)| {
        if !op.valid() {
            return Err(format!("generator produced invalid op {op:?}"));
        }
        let mut rng = Rng::new(seed);
        let image = rng.normal_vec(op.map_elems());
        let filters = rng.normal_vec(op.filter_elems());
        let direct = conv2d_op_cpu(&op, &image, &filters);
        let lowered = conv2d_op_lowered_cpu(&op, &image, &filters);
        if !bit_eq(&direct, &lowered) {
            return Err(format!("{}: lowered execution diverges", op.label()));
        }
        Ok(())
    });
}

#[test]
fn every_backend_op_reference_matches_the_generalized_oracle() {
    let registry = Dispatcher::full();
    check_no_shrink(&cfg(24), gen_op, |&(op, seed)| {
        let mut rng = Rng::new(seed);
        let image = rng.normal_vec(op.map_elems());
        let filters = rng.normal_vec(op.filter_elems());
        let oracle = conv2d_op_cpu(&op, &image, &filters);
        let mut covered = 0;
        for b in registry.backends() {
            if !b.op_coverage(&op).supported() {
                continue;
            }
            covered += 1;
            let got = b.execute_op_reference(&op, &image, &filters);
            if !bit_eq(&got, &oracle) {
                return Err(format!("{}: {} diverges", op.label(), b.name()));
            }
        }
        if covered < 2 {
            return Err(format!("{}: only {covered} backends covered it", op.label()));
        }
        Ok(())
    });
}
