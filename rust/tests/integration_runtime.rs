//! Integration: PJRT runtime vs the rust CPU oracle, over the real AOT'd
//! artifacts.  This is the cross-language numeric gate: the Pallas
//! kernels (checked against the jnp oracle by pytest) round-trip through
//! HLO text -> PJRT and must agree with an independent rust
//! implementation of eq. (1)/(2).
//!
//! Requires `make artifacts`; every test skips (prints a notice) if the
//! artifact directory is absent so `cargo test` stays green pre-build.

use pasconv::conv::{conv2d_multi_cpu, max_abs_diff};
use pasconv::runtime::{default_artifact_dir, ArtifactKind, Runtime, Tensor};
use pasconv::util::rng::Rng;

const TOL: f32 = 2e-4;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built ({})", dir.display());
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn platform_is_cpu_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    let platform = rt.platform().to_lowercase();
    assert!(platform.contains("cpu") || platform.contains("host"), "{platform}");
}

#[test]
fn manifest_covers_all_kinds() {
    let Some(rt) = runtime_or_skip() else { return };
    for kind in [
        ArtifactKind::ConvSingle,
        ArtifactKind::ConvMulti,
        ArtifactKind::ConvIm2col,
        ArtifactKind::Cnn,
    ] {
        assert!(!rt.artifacts_of_kind(kind).is_empty(), "no artifact of kind {kind:?}");
    }
}

#[test]
fn every_conv_artifact_matches_cpu_oracle() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0xA11CE);
    let mut checked = 0;
    for kind in [ArtifactKind::ConvSingle, ArtifactKind::ConvMulti, ArtifactKind::ConvIm2col] {
        let names: Vec<String> =
            rt.artifacts_of_kind(kind).iter().map(|a| a.name.clone()).collect();
        for name in names {
            let p = rt.artifact(&name).unwrap().problem().unwrap();
            let (img_shape, flt_shape) = if kind == ArtifactKind::ConvSingle {
                (vec![p.wy, p.wx], vec![p.m, p.k, p.k])
            } else {
                (vec![p.c, p.wy, p.wx], vec![p.m, p.c, p.k, p.k])
            };
            let image = Tensor::randn(img_shape, &mut rng);
            let filters = Tensor::randn(flt_shape, &mut rng);
            let got = rt.execute_conv(&name, &image, &filters).expect(&name);
            assert_eq!(got.shape, vec![p.m, p.oy(), p.ox()], "{name} shape");
            let want = conv2d_multi_cpu(&p, &image.data, &filters.data);
            let diff = max_abs_diff(&got.data, &want);
            // tolerance scales with the contraction depth
            let tol = TOL * (p.c * p.k * p.k) as f32;
            assert!(diff < tol, "{name}: max|diff| = {diff} (tol {tol})");
            checked += 1;
        }
    }
    assert!(checked >= 9, "only {checked} conv artifacts checked");
}

#[test]
fn stride_fixed_and_im2col_artifacts_agree() {
    // the same operands through the §3.2 kernel and the Implicit-GEMM
    // baseline kernel must produce identical numerics (different
    // schedules, same math) — end-to-end through PJRT
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(7);
    let p = rt.artifact("multi_c32_w14_m32_k3").unwrap().problem().unwrap();
    let image = Tensor::randn(vec![p.c, p.wy, p.wx], &mut rng);
    let filters = Tensor::randn(vec![p.m, p.c, p.k, p.k], &mut rng);
    let a = rt.execute_conv("multi_c32_w14_m32_k3", &image, &filters).unwrap();
    let b = rt.execute_conv("im2col_c32_w14_m32_k3", &image, &filters).unwrap();
    let diff = max_abs_diff(&a.data, &b.data);
    assert!(diff < 1e-3, "kernel disagreement: {diff}");
}

#[test]
fn execute_conv_rejects_wrong_shapes() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(9);
    let image = Tensor::randn(vec![3, 3], &mut rng);
    let filters = Tensor::randn(vec![1, 1, 1], &mut rng);
    assert!(rt.execute_conv("multi_c32_w14_m32_k3", &image, &filters).is_err());
}

#[test]
fn papernet_executes_and_is_input_sensitive() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(1);
    let b1 = Tensor::randn(vec![1, 1, 28, 28], &mut rng);
    let out = rt.execute("papernet_b1", &[b1.clone()]).unwrap();
    assert_eq!(out.shape, vec![1, 10]);
    assert!(out.data.iter().all(|x| x.is_finite()));
    let b1b = Tensor::randn(vec![1, 1, 28, 28], &mut rng);
    let out2 = rt.execute("papernet_b1", &[b1b]).unwrap();
    assert!(max_abs_diff(&out.data, &out2.data) > 1e-6, "logits insensitive to input");
}

#[test]
fn papernet_batch8_consistent_with_batch1() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(2);
    let batch = Tensor::randn(vec![8, 1, 28, 28], &mut rng);
    let out8 = rt.execute("papernet_b8", &[batch.clone()]).unwrap();
    assert_eq!(out8.shape, vec![8, 10]);
    for i in 0..8 {
        let single = batch.slice_axis0(i, i + 1).unwrap();
        let out1 = rt.execute("papernet_b1", &[single]).unwrap();
        let got = out8.slice_axis0(i, i + 1).unwrap();
        let diff = max_abs_diff(&got.data, &out1.data);
        assert!(diff < 1e-3, "row {i}: {diff}");
    }
}

#[test]
fn executable_cache_compiles_once() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(3);
    let p = rt.artifact("single_w32_m32_k3").unwrap().problem().unwrap();
    let image = Tensor::randn(vec![p.wy, p.wx], &mut rng);
    let filters = Tensor::randn(vec![p.m, p.k, p.k], &mut rng);
    for _ in 0..3 {
        rt.execute_conv("single_w32_m32_k3", &image, &filters).unwrap();
    }
    let stats = rt.stats("single_w32_m32_k3").unwrap();
    assert_eq!(stats.executions, 3);
    assert!(stats.compile_secs > 0.0);
    // compile happened exactly once: re-running didn't add compile time
    let before = stats.compile_secs;
    rt.execute_conv("single_w32_m32_k3", &image, &filters).unwrap();
    let after = rt.stats("single_w32_m32_k3").unwrap().compile_secs;
    assert_eq!(after, before);
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn all_four_algorithm_families_agree_through_pjrt() {
    // direct (stride-fixed), GEMM (im2col), Winograd and FFT artifacts of
    // the same shape must produce the same numbers end-to-end — the §1
    // taxonomy is executable, not just documented
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0x7A11);
    let p = rt.artifact("multi_c32_w14_m32_k3").unwrap().problem().unwrap();
    let image = Tensor::randn(vec![p.c, p.wy, p.wx], &mut rng);
    let filters = Tensor::randn(vec![p.m, p.c, p.k, p.k], &mut rng);
    let direct = rt.execute_conv("multi_c32_w14_m32_k3", &image, &filters).unwrap();
    for name in ["im2col_c32_w14_m32_k3", "winograd_c32_w14_m32_k3", "fft_c32_w14_m32_k3"] {
        let other = rt.execute_conv(name, &image, &filters).unwrap();
        let diff = max_abs_diff(&direct.data, &other.data);
        assert!(diff < 5e-3, "{name} disagrees with direct: {diff}");
    }
}
